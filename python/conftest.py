"""Pytest path setup: make `compile.*` importable when the suite runs
from the repo root (`python -m pytest python/tests`), matching the CI
invocation in .github/workflows/ci.yml."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
