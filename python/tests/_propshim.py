"""Deterministic fallback for `hypothesis` when it isn't installed.

The offline image carries jax but not hypothesis; rather than skipping
the L1 kernel correctness sweep entirely, this shim re-implements the
tiny subset test_kernels.py uses (`given`, `settings`,
`strategies.integers`, `strategies.sampled_from`) as a fixed-count
deterministic sweep: each decorated test runs `MAX_EXAMPLES` times with
values drawn from a seeded PRNG, so failures replay bit-identically.
When hypothesis *is* available (e.g. in CI), test modules import the
real thing and this file is inert.
"""

import random

MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_for(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: rng.choice(opts))


st = strategies


def given(**param_strategies):
    """Run the test MAX_EXAMPLES times with deterministic draws."""

    def decorate(fn):
        def wrapper(*args, **kwargs):
            for case in range(MAX_EXAMPLES):
                rng = random.Random((hash(fn.__name__) & 0xFFFF_FFFF) ^ case)
                drawn = {
                    name: strat.example_for(rng)
                    for name, strat in param_strategies.items()
                }
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:  # annotate for replay
                    raise AssertionError(
                        f"{fn.__name__} failed at shim case {case} "
                        f"with {drawn}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return decorate


class settings:  # noqa: N801 — mimics `hypothesis.settings`
    @staticmethod
    def register_profile(name, **kwargs):
        pass

    @staticmethod
    def load_profile(name):
        pass

    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, fn):
        return fn
