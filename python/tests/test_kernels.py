"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps
over shapes and values — the CORE correctness signal of the AOT path)."""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # prefer real hypothesis; fall back to the deterministic shim
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image: jax yes, hypothesis no
    from _propshim import given, settings, strategies as st

from compile.kernels import costmodel, linkload, minplus, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def f32s(rng, *shape, lo=0.0, hi=10.0):
    return rng.uniform(lo, hi, shape).astype(np.float32)


# ---------------------------------------------------------------- minplus
@given(
    seed=st.integers(0, 2**32 - 1),
    gm=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
)
def test_minplus_matches_ref(seed, gm, block):
    rng = np.random.default_rng(seed)
    n = gm * block
    a = f32s(rng, n, n)
    b = f32s(rng, n, n)
    got = minplus.minplus_matmul(jnp.array(a), jnp.array(b), block=block)
    want = ref.minplus_matmul(jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@given(seed=st.integers(0, 2**32 - 1))
def test_minplus_with_inf_entries(seed):
    rng = np.random.default_rng(seed)
    n = 32
    a = f32s(rng, n, n)
    a[rng.uniform(size=(n, n)) < 0.5] = ref.INF
    got = minplus.minplus_matmul(jnp.array(a), jnp.array(a), block=16)
    want = ref.minplus_matmul(jnp.array(a), jnp.array(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    assert np.all(np.isfinite(np.asarray(got))), "INF must stay finite"


def test_minplus_rejects_misaligned():
    a = jnp.zeros((48, 48), jnp.float32)
    with pytest.raises(AssertionError):
        minplus.minplus_matmul(a, a, block=32)


def test_apsp_on_known_graph():
    # Path graph 0-1-2-3 embedded in a 32-node INF matrix.
    n = 32
    adj = np.full((n, n), ref.INF, np.float32)
    np.fill_diagonal(adj, 0.0)
    for i in range(3):
        adj[i, i + 1] = adj[i + 1, i] = 1.0
    d = np.asarray(minplus.apsp(jnp.array(adj), steps=2, block=16))
    assert d[0, 3] == 3.0
    assert d[0, 2] == 2.0
    assert d[3, 0] == 3.0
    assert d[5, 5] == 0.0


@given(seed=st.integers(0, 2**32 - 1))
def test_apsp_matches_ref_on_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n = 32
    adj = np.full((n, n), ref.INF, np.float32)
    np.fill_diagonal(adj, 0.0)
    # random symmetric edges
    for _ in range(64):
        i, j = rng.integers(0, n, 2)
        if i != j:
            adj[i, j] = adj[j, i] = 1.0
    got = np.asarray(minplus.apsp(jnp.array(adj), steps=3, block=16))
    want = np.asarray(ref.apsp(jnp.array(adj), steps=3))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # metric properties on the reachable part
    fin = got < ref.INF / 2
    assert np.all(got[fin] >= 0)
    assert np.allclose(got, got.T)  # symmetric graph → symmetric distances


# --------------------------------------------------------------- linkload
@given(
    seed=st.integers(0, 2**32 - 1),
    gp=st.integers(1, 3),
    gl=st.integers(1, 3),
)
def test_linkload_matches_ref(seed, gp, gl):
    rng = np.random.default_rng(seed)
    bp, bl = 32, 32
    p, l = gp * bp, gl * bl
    inc = f32s(rng, p, l, hi=1.0)
    d = f32s(rng, p, hi=5.0)
    got = linkload.link_load(jnp.array(inc), jnp.array(d), bp=bp, bl=bl)
    want = ref.link_load(jnp.array(inc), jnp.array(d))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


def test_linkload_zero_demand_zero_load():
    inc = jnp.ones((128, 128), jnp.float32)
    d = jnp.zeros((128,), jnp.float32)
    got = linkload.link_load(inc, d)
    assert np.allclose(np.asarray(got), 0.0)


# -------------------------------------------------------------- costmodel
@given(seed=st.integers(0, 2**32 - 1), gb=st.integers(1, 4))
def test_costmodel_matches_ref(seed, gb):
    rng = np.random.default_rng(seed)
    bb, t = 32, 6
    b = gb * bb
    vol = f32s(rng, b, t, lo=1e5, hi=1e9)
    bw = f32s(rng, b, t, lo=10, hi=400)
    tr = f32s(rng, b, t, lo=1, hi=5000)
    al = f32s(rng, t, lo=0, hi=5)
    co = f32s(rng, b, lo=100, hi=1e6)
    ex = f32s(rng, t, lo=0, hi=1)
    args = tuple(map(jnp.array, (vol, bw, tr, al, co, ex)))
    got = costmodel.cost_model(*args, bb=bb)
    want = ref.cost_model(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_costmodel_monotone_in_volume():
    b, t = 64, 6
    base = dict(
        bandwidths=jnp.full((b, t), 100.0),
        transfers=jnp.ones((b, t)),
        alphas=jnp.zeros((t,)),
        compute_us=jnp.zeros((b,)),
        exposure=jnp.ones((t,)),
    )
    lo = costmodel.cost_model(jnp.full((b, t), 1e6), **base)
    hi = costmodel.cost_model(jnp.full((b, t), 2e6), **base)
    assert np.all(np.asarray(hi) > np.asarray(lo))


def test_tier_bandwidths_pinned():
    # Hand-computed hop-chain minima, kept in lockstep with
    # rust/src/workload/placement.rs tests (ubmesh_tiers_are_min_over_hops).
    assert ref.tier_bandwidths(16, 1.0) == [175.0, 175.0, 18.75, 18.75, 12.5, 12.5]
    assert ref.tier_bandwidths(16, 1.6) == [175.0, 175.0, 37.5, 37.5, 12.5, 12.5]
    assert ref.tier_bandwidths(16, 1.85) == [175.0, 175.0, 50.0, 50.0, 12.5, 12.5]
    # 4:1 uplink oversubscription halves the mesh-bound pod tier.
    assert ref.tier_bandwidths(16, 1.0, oversub=4)[4] == 6.25
    # x4 mesh at Detour: row moves to the wire stage, pod to the uplink.
    assert ref.tier_bandwidths(16, 1.6, mesh_lanes=4)[2] == 60.0
    assert ref.tier_bandwidths(16, 1.6, mesh_lanes=4)[4] == 25.0
    # Provision is mesh-capped: x32 ties x16 on the row tier.
    assert ref.tier_bandwidths(32, 1.6)[2] == ref.tier_bandwidths(16, 1.6)[2]


def test_costmodel_zero_exposure_is_compute_only():
    b, t = 64, 6
    comp = jnp.arange(b, dtype=jnp.float32)
    got = costmodel.cost_model(
        jnp.full((b, t), 1e9),
        jnp.full((b, t), 10.0),
        jnp.full((b, t), 100.0),
        jnp.ones((t,)),
        comp,
        jnp.zeros((t,)),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(comp), atol=1e-6)
