"""AOT path smoke tests: every entry point lowers to parseable HLO text
with the manifest shapes (the rust loader's contract)."""

import os
import re

from compile import aot, model


def test_entry_points_cover_all_artifacts():
    names = [e[0] for e in aot.entry_points()]
    assert names == ["apsp64", "apsp256", "costmodel", "linkload"]


def test_lowering_produces_hlo_text():
    for name, fn, example in aot.entry_points():
        import jax

        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text
        # return_tuple=True → root is a tuple
        assert re.search(r"ROOT.*tuple", text), f"{name}: missing tuple root"


def test_shape_strings():
    import jax, jax.numpy as jnp

    s = jax.ShapeDtypeStruct((256, 6), jnp.float32)
    assert aot.shape_str(s) == "f32[256,6]"


def test_artifacts_on_disk_match_manifest_if_built():
    out = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(out, "manifest.txt")
    if not os.path.exists(manifest):
        import pytest

        pytest.skip("artifacts not built (run `make artifacts`)")
    lines = [l for l in open(manifest).read().splitlines() if l.strip()]
    names = [l.split(" :: ")[0] for l in lines]
    assert names == [e[0] for e in aot.entry_points()]
    for n in names:
        path = os.path.join(out, f"{n}.hlo.txt")
        assert os.path.exists(path), path
        head = open(path).read(64)
        assert head.startswith("HloModule")


def test_cost_batch_constant_matches_rust_side():
    # rust/src/runtime/artifacts.rs pads batches to this constant.
    assert model.COST_BATCH == 256
    assert model.COST_TIERS == 6
