"""L2 model entry points: shapes, semantics, and rust-parity checks."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def rack_2dfm_adjacency(n0=8, n1=8):
    """Hop-annotated adjacency of the 8×8 2D-FullMesh rack."""
    n = n0 * n1
    adj = np.full((n, n), ref.INF, np.float32)
    np.fill_diagonal(adj, 0.0)
    for y in range(n1):
        for x1 in range(n0):
            for x2 in range(n0):
                if x1 != x2:
                    adj[y * n0 + x1, y * n0 + x2] = 1.0
    for x in range(n0):
        for y1 in range(n1):
            for y2 in range(n1):
                if y1 != y2:
                    adj[y1 * n0 + x, y2 * n0 + x] = 1.0
    return adj


def test_apsp64_rack_has_diameter_2():
    adj = rack_2dfm_adjacency()
    (d,) = model.apsp64(jnp.array(adj))
    d = np.asarray(d)
    off = ~np.eye(64, dtype=bool)
    assert d[off].min() == 1.0
    assert d.max() == 2.0, "2D-FullMesh rack diameter must be 2 (§3.1)"
    # exactly 14 one-hop peers per NPU (7 X + 7 Y)
    assert np.all((d == 1.0).sum(axis=1) == 14)


def test_apsp256_handles_disconnected_nodes():
    n = model.APSP_LARGE
    adj = np.full((n, n), ref.INF, np.float32)
    np.fill_diagonal(adj, 0.0)
    adj[0, 1] = adj[1, 0] = 1.0
    (d,) = model.apsp256(jnp.array(adj))
    d = np.asarray(d)
    assert d[0, 1] == 1.0
    assert d[0, 2] >= ref.INF / 2, "unreachable stays INF-ish"


def test_cost_model_batch_shape_and_ordering():
    b, t = model.COST_BATCH, model.COST_TIERS
    rng = np.random.default_rng(1)
    vol = rng.uniform(1e6, 1e9, (b, t)).astype(np.float32)
    bw_fast = np.full((b, t), 400.0, np.float32)
    bw_slow = np.full((b, t), 40.0, np.float32)
    tr = np.ones((b, t), np.float32)
    al = np.zeros((t,), np.float32)
    co = np.zeros((b,), np.float32)
    ex = np.ones((t,), np.float32)
    (fast,) = model.cost_model_batch(*map(jnp.array, (vol, bw_fast, tr, al, co, ex)))
    (slow,) = model.cost_model_batch(*map(jnp.array, (vol, bw_slow, tr, al, co, ex)))
    assert fast.shape == (b,)
    assert np.all(np.asarray(slow) > np.asarray(fast))


def test_link_load_shapes():
    p, l = model.LOAD_PATHS, model.LOAD_LINKS
    inc = jnp.ones((p, l), jnp.float32) / p
    d = jnp.ones((p,), jnp.float32)
    (loads,) = model.link_load_1024x512(inc, d)
    assert loads.shape == (l,)
    np.testing.assert_allclose(np.asarray(loads), 1.0, rtol=1e-4)
