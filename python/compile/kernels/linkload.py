"""L1 Pallas kernel: link-load accumulation (incidenceᵀ × demand).

Given the APR traffic split as a weighted path×link incidence matrix and
per-path demands, produce per-link loads — the quantity the Detour/
Borrow optimizers balance (paper §4.1, Fig 10/13).

Tiling: grid walks (link-tile, path-tile); each step loads a (bp, bl)
incidence tile and a (bp,) demand slice into VMEM and accumulates
``loads[l] += Σ_p inc[p, l]·demand[p]`` into the (bl,) output tile that
stays resident across the path axis. This is a K-reduction mat-vec with
f32 accumulators — the memory-bound twin of the min-plus kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_PATHS = 128
DEFAULT_BLOCK_LINKS = 128


def _linkload_kernel(inc_ref, d_ref, o_ref):
    p = pl.program_id(1)
    inc = inc_ref[...]  # (bp, bl)
    d = d_ref[...]  # (bp, 1)
    partial = jnp.sum(inc * d, axis=0)  # (bl,)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(p != 0)
    def _acc():
        o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("bp", "bl"))
def link_load(
    incidence,
    demand,
    bp: int = DEFAULT_BLOCK_PATHS,
    bl: int = DEFAULT_BLOCK_LINKS,
):
    """loads[l] = Σ_p incidence[p, l] * demand[p] (f32).

    ``incidence``: (P, L); ``demand``: (P,). P % bp == 0, L % bl == 0.
    """
    paths, links = incidence.shape
    assert demand.shape == (paths,)
    assert paths % bp == 0 and links % bl == 0, (incidence.shape, bp, bl)
    d2 = demand[:, None]  # (P, 1) so BlockSpec can tile it
    return pl.pallas_call(
        _linkload_kernel,
        grid=(links // bl, paths // bp),
        in_specs=[
            pl.BlockSpec((bp, bl), lambda l, p: (p, l)),
            pl.BlockSpec((bp, 1), lambda l, p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((bl,), lambda l, p: (l,)),
        out_shape=jax.ShapeDtypeStruct((links,), jnp.float32),
        interpret=True,
    )(incidence, d2)
