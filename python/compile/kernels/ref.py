"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference twin here; pytest sweeps
shapes/values with hypothesis and asserts allclose (the CORE correctness
signal of the build path — see DESIGN.md §2).
"""

import jax.numpy as jnp

#: Value standing in for "unreachable" in min-plus adjacency matrices.
#: Finite (not jnp.inf) so MXU-friendly arithmetic stays NaN-free:
#: INF + INF must not overflow f32.
INF = 1.0e9


def minplus_matmul(a, b):
    """Tropical (min-plus) matrix product: C[i,j] = min_k A[i,k] + B[k,j].

    With A = B = hop-annotated adjacency, squaring log2(diameter) times
    yields all-pairs-shortest-hops — the metric APR uses to classify
    shortest vs detour paths (paper §4.1).
    """
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def apsp(adj, steps):
    """All-pairs shortest path by repeated min-plus squaring."""
    d = adj
    for _ in range(steps):
        d = minplus_matmul(d, d)
    return d


def link_load(incidence, demand):
    """Per-link load: loads[l] = sum_p incidence[p, l] * demand[p].

    ``incidence`` is the weighted path×link matrix (APR traffic split),
    ``demand`` the per-path flow demand (GB/s).
    """
    return incidence.T @ demand


#: GB/s per UB lane (rust/src/topology/ublink.rs::LANE_GB_S).
LANE_GB_S = 6.25


def tier_bandwidths(lanes, boost, mesh_lanes=2, oversub=1):
    """Per-NPU tier bandwidths (GB/s) of the UB-Mesh hierarchy.

    Mirrors ``rust/src/workload/placement.rs::TierBandwidth::ubmesh_mesh``:
    each tier is the min over its physical hop chain (NPU plane attach,
    board-LRS backplane-mesh lanes, inter-rack wire with the routing
    boost, uplink-LRS lanes with oversubscription, HRS ports, DCN NIC).
    Returns ``[board, rack, row, col, pod, dcn]``.
    """
    planes, boards, slots, npus = 4, 8, 8, 64.0
    attach = planes * 4.0 * LANE_GB_S
    board = (slots - 1) * 4.0 * LANE_GB_S
    out = 2.0 * lanes  # out-facing lanes per inter-rack LRS
    # Mesh exits usable per dimension: Shortest 3, Detour 6, Borrow 8.
    dim_slots = 8 if boost >= 1.8 else (6 if boost > 1.0 else 3)
    wire = 3.0 * out * planes / npus * LANE_GB_S * boost
    mesh = planes * boards * dim_slots * mesh_lanes / npus * LANE_GB_S
    row = min(attach, mesh, wire)
    mesh_up = planes * boards * 2.0 * mesh_lanes / npus * LANE_GB_S
    uplink = planes * 2.0 * (out / oversub) / npus * LANE_GB_S
    hrs = planes * 2.0 * out / npus * LANE_GB_S
    pod = min(attach, mesh_up, uplink, hrs)
    return [board, board, row, row, pod, min(12.5, pod)]


def cost_model(volumes, bandwidths, transfers, alphas, compute_us, exposure):
    """Batched α-β iteration-time model (§5.2 Step ②).

    Mirrors ``rust/src/workload/step.rs::iteration_time``:

      time_i = compute_us[i]
             + Σ_t exposure[t] · (volumes[i,t] / bandwidths[i,t] / 1e3
                                  + transfers[i,t] · alphas[t])

    Args:
      volumes:    [B, T] wire bytes per technique-tier slot.
      bandwidths: [B, T] GB/s available to that slot.
      transfers:  [B, T] transfer counts (α term).
      alphas:     [T]    per-transfer launch overhead (µs).
      compute_us: [B]    per-config compute time (µs).
      exposure:   [T]    fraction of each slot's time not hidden by
                         compute-communication overlap.
    Returns: [B] total iteration time (µs).
    """
    comm = volumes / (bandwidths * 1e3) + transfers * alphas[None, :]
    return compute_us + jnp.sum(comm * exposure[None, :], axis=1)
