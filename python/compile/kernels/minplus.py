"""L1 Pallas kernel: tiled tropical (min-plus) matrix multiplication.

Computes ``C[i,j] = min_k A[i,k] + B[k,j]`` with an MXU-shaped tiling:
the grid walks (i-tile, j-tile, k-tile); each step broadcasts an
(bm, bk) A-tile against a (bk, bn) B-tile in VMEM and folds the partial
minimum into the output tile, which stays resident across the k axis
(standard matmul accumulator schedule, with (+, min) replacing (×, +)).

Hardware adaptation (DESIGN.md §3): a GPU implementation would stage
tiles through shared memory per threadblock; here ``BlockSpec`` expresses
the same HBM→VMEM schedule, and the inner broadcast-add-reduce is the
VPU-friendly formulation of the tropical contraction. ``interpret=True``
is mandatory on CPU PJRT (real-TPU lowering emits Mosaic custom-calls the
CPU plugin cannot execute).

VMEM footprint per grid step: bm·bk + bk·bn + bm·bn f32 words — at the
default 64³ tiles ≈ 48 KiB, comfortably inside a TensorCore's ~16 MiB
VMEM even with double-buffering (×2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64


def _minplus_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: fold min(A_ik ⊕ B_kj) into O_ij."""
    k = pl.program_id(2)
    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    # Tropical contraction over the tile's k axis.
    partial = jnp.min(a[:, :, None] + b[None, :, :], axis=1)  # (bm, bn)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k != 0)
    def _fold():
        o_ref[...] = jnp.minimum(o_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("block",))
def minplus_matmul(a, b, block: int = DEFAULT_BLOCK):
    """Tiled min-plus product of square f32 matrices.

    Shapes must be divisible by ``block`` (pad with ``ref.INF`` rows/cols
    otherwise — INF is the tropical additive identity... strictly the
    multiplicative absorber, so padding K is safe; padding M/N just adds
    inert rows).
    """
    n = a.shape[0]
    assert a.shape == (n, n) and b.shape == (n, n), (a.shape, b.shape)
    assert n % block == 0, f"size {n} not divisible by block {block}"
    g = n // block
    return pl.pallas_call(
        _minplus_kernel,
        grid=(g, g, g),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, b)


def apsp(adj, steps: int, block: int = DEFAULT_BLOCK):
    """All-pairs shortest hops: square the hop matrix ``steps`` times.

    ``steps = ceil(log2(diameter))`` suffices; UB-Mesh graphs are
    shallow (rack diameter 2, pod ≤ 6) so 3–4 steps cover everything.
    Uses lax.fori_loop-free Python unrolling: ``steps`` is tiny and
    static, and unrolling keeps each squaring a separate pallas_call in
    the lowered HLO (no dynamic trip count for the AOT artifact).
    """
    d = adj
    for _ in range(steps):
        d = minplus_matmul(d, d, block=block)
    return d
