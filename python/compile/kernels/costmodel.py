"""L1 Pallas kernel: batched α-β cost-model evaluation (§5.2 Step ②).

Row-parallel map over a batch of parallelism configurations: each grid
cell evaluates a (bb, T) block of configs against its per-tier volumes,
bandwidths and transfer counts. Elementwise VPU work — one block per
grid step, fully fused in VMEM.

This is the kernel behind ``artifacts/costmodel.hlo.txt``: the rust
coordinator packs candidate configs into the fixed [B, T] layout and
gets the whole batch's iteration times in one PJRT execution
(`parallelism::search_with` plugs it in as the evaluator).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 64


def _cost_kernel(vol_ref, bw_ref, tr_ref, alpha_ref, comp_ref, exp_ref, o_ref):
    vol = vol_ref[...]  # (bb, T)
    bw = bw_ref[...]  # (bb, T)
    tr = tr_ref[...]  # (bb, T)
    alpha = alpha_ref[...]  # (1, T)
    comp = comp_ref[...]  # (bb, 1)
    exp = exp_ref[...]  # (1, T)
    comm = vol / (bw * 1e3) + tr * alpha
    o_ref[...] = comp[:, 0] + jnp.sum(comm * exp, axis=1)


@functools.partial(jax.jit, static_argnames=("bb",))
def cost_model(
    volumes, bandwidths, transfers, alphas, compute_us, exposure, bb: int = DEFAULT_BLOCK_B
):
    """[B] iteration times (µs) for B configs × T technique-tier slots.

    See ``ref.cost_model`` for the formula. B % bb == 0.
    """
    b, t = volumes.shape
    assert bandwidths.shape == (b, t) and transfers.shape == (b, t)
    assert alphas.shape == (t,) and exposure.shape == (t,)
    assert compute_us.shape == (b,)
    assert b % bb == 0, (b, bb)
    return pl.pallas_call(
        _cost_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, t), lambda i: (i, 0)),
            pl.BlockSpec((bb, t), lambda i: (i, 0)),
            pl.BlockSpec((bb, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (0, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(
        volumes,
        bandwidths,
        transfers,
        alphas[None, :],
        compute_us[:, None],
        exposure[None, :],
    )
