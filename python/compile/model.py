"""L2: JAX compute graphs built on the L1 Pallas kernels.

Three entry points are AOT-lowered by ``aot.py`` into the artifacts the
rust runtime executes (python never runs on the request path):

* ``apsp64`` / ``apsp256`` — all-pairs shortest hops over an adjacency
  matrix (rack-level 64 NPUs / 4-rack group with switches). The rust
  coordinator uses them to validate its routing tables and to classify
  shortest vs detour paths (§4.1).
* ``cost_model_batch`` — batched iteration-time evaluation for the
  topology-aware parallelization search (§5.2 Step ②).
* ``link_load_1024x512`` — APR traffic-engineering link loads
  (§4.1, Fig 10/13).
"""

import jax.numpy as jnp

from .kernels import costmodel as k_cost
from .kernels import linkload as k_link
from .kernels import minplus as k_minplus
from .kernels.ref import INF

# Fixed artifact shapes (the PJRT executables are monomorphic; the rust
# side pads to these — see rust/src/runtime/artifacts.rs).
APSP_SMALL = 64
APSP_LARGE = 256
COST_BATCH = 256
COST_TIERS = 6
LOAD_PATHS = 1024
LOAD_LINKS = 512


def _normalize_adj(adj):
    """Clamp self-distance to 0 and missing edges to INF-ish values."""
    n = adj.shape[0]
    eye = jnp.eye(n, dtype=adj.dtype)
    return jnp.where(eye > 0, 0.0, jnp.minimum(adj, INF))


def apsp64(adj):
    """All-pairs shortest hops on a 64-node graph (diameter ≤ 4)."""
    d = _normalize_adj(adj)
    return (k_minplus.apsp(d, steps=2, block=32),)


def apsp256(adj):
    """All-pairs shortest hops on a 256-node graph (diameter ≤ 16)."""
    d = _normalize_adj(adj)
    return (k_minplus.apsp(d, steps=4, block=64),)


def cost_model_batch(volumes, bandwidths, transfers, alphas, compute_us, exposure):
    """[COST_BATCH] iteration times (µs); see kernels.ref.cost_model."""
    return (
        k_cost.cost_model(
            volumes, bandwidths, transfers, alphas, compute_us, exposure
        ),
    )


def link_load_1024x512(incidence, demand):
    """[LOAD_LINKS] per-link loads from the weighted incidence matrix."""
    return (k_link.link_load(incidence, demand),)
