"""AOT compile path: lower the L2 entry points to HLO **text** artifacts.

Interchange format is HLO text, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
Writes ``<name>.hlo.txt`` per entry point plus ``manifest.txt``
(name, input shapes, output shape — parsed by rust/src/runtime).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points():
    """(name, fn, example_args) for every artifact."""
    n_s, n_l = model.APSP_SMALL, model.APSP_LARGE
    b, t = model.COST_BATCH, model.COST_TIERS
    p, l = model.LOAD_PATHS, model.LOAD_LINKS
    return [
        ("apsp64", model.apsp64, (f32(n_s, n_s),)),
        ("apsp256", model.apsp256, (f32(n_l, n_l),)),
        (
            "costmodel",
            model.cost_model_batch,
            (f32(b, t), f32(b, t), f32(b, t), f32(t), f32(b), f32(t)),
        ),
        ("linkload", model.link_load_1024x512, (f32(p, l), f32(p))),
    ]


def shape_str(s) -> str:
    return "f32[" + ",".join(str(d) for d in s.shape) + "]"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []
    for name, fn, example in entry_points():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        ins = " ".join(shape_str(s) for s in example)
        manifest_lines.append(f"{name} :: {ins}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
