"""L2 compile package: JAX compute graphs (model.py), AOT lowering to
HLO text (aot.py) and the Pallas L1 kernels (kernels/)."""
