//! Explore UB-Mesh topologies: census, cost, reliability and shortest-
//! hop structure for configurable scales — the architectural half of the
//! paper's evaluation in one binary.
//!
//! ```bash
//! cargo run --release --example topology_explorer -- [--pods 8]
//! ```

use ubmesh::cost::capex::{capex_full_clos, capex_ubmesh};
use ubmesh::cost::opex::opex;
use ubmesh::reliability::afr::afr_of_capex;
use ubmesh::reliability::availability::{availability, mtbf_hours, mttr};
use ubmesh::topology::census::{class_name, Census};
use ubmesh::topology::pod::{ubmesh_pod, PodConfig};
use ubmesh::topology::superpod::SuperPodConfig;
use ubmesh::util::cli::Args;
use ubmesh::util::table::{fmt, pct, Table};

fn main() {
    let args = Args::from_env();
    let pods: usize = args.get_parse("pods", 8);

    // --- Pod structure -------------------------------------------------
    let (pod, handles) = ubmesh_pod(&PodConfig::default());
    println!(
        "UB-Mesh-Pod: {} NPUs in {} racks; {} nodes, {} links",
        handles.npus().len(),
        handles.racks.len(),
        pod.node_count(),
        pod.link_count()
    );
    let c = Census::of(&pod);
    let mut t = Table::with_title("pod cable census", vec!["class", "cables", "share"]);
    for (k, share) in c.class_ratios() {
        t.row(vec![
            class_name(k).to_string(),
            format!("{}", c.cables.get(&k).map(|v| v.cables).unwrap_or(0)),
            pct(share, 1),
        ]);
    }
    t.print();

    // --- Hop distribution (locality, §3.1) ------------------------------
    let npus = handles.npus();
    let mut hist = [0u64; 16];
    for &src in npus.iter().step_by(64) {
        let d = pod.bfs_hops(src, true);
        for &dst in npus.iter().step_by(7) {
            let h = d[dst.idx()] as usize;
            if h < hist.len() {
                hist[h] += 1;
            }
        }
    }
    let total: u64 = hist.iter().sum();
    let mut t = Table::with_title("NPU→NPU hop distribution (sampled)", vec!["hops", "share"]);
    for (h, &n) in hist.iter().enumerate() {
        if n > 0 {
            t.row(vec![format!("{h}"), pct(n as f64 / total as f64, 1)]);
        }
    }
    t.print();

    // --- SuperPod cost + reliability ------------------------------------
    let mut sp = SuperPodConfig::default();
    sp.pods = pods;
    let ub = capex_ubmesh(&sp);
    let clos = capex_full_clos("x64T Clos", sp.npus(), 64);
    let mut t = Table::with_title(
        format!("{} NPUs: UB-Mesh vs Clos", sp.npus()),
        vec!["metric", "UB-Mesh", "Clos", "ratio"],
    );
    let ub_afr = afr_of_capex(&ub);
    let clos_afr = afr_of_capex(&clos);
    let rows: Vec<(&str, f64, f64)> = vec![
        ("CapEx (NPU units)", ub.total(), clos.total()),
        ("network share", ub.network_share(), clos.network_share()),
        ("power (kW)", ub.power_kw(), clos.power_kw()),
        ("AFR (failures/yr)", ub_afr.total(), clos_afr.total()),
        (
            "MTBF (h)",
            mtbf_hours(ub_afr.total()),
            mtbf_hours(clos_afr.total()),
        ),
        (
            "availability @75min",
            availability(mtbf_hours(ub_afr.total()), mttr::BASELINE_HOURS),
            availability(mtbf_hours(clos_afr.total()), mttr::BASELINE_HOURS),
        ),
    ];
    for (name, a, b) in rows {
        t.row(vec![
            name.to_string(),
            fmt(a, 3),
            fmt(b, 3),
            fmt(a / b, 3),
        ]);
    }
    t.print();
    let ub_opex = opex(&ub, ub_afr.total());
    let clos_opex = opex(&clos, clos_afr.total());
    println!(
        "lifetime OpEx: UB-Mesh {} vs Clos {} NPU-units",
        fmt(ub_opex.total(), 1),
        fmt(clos_opex.total(), 1)
    );
    println!("\ntopology_explorer OK");
}
