//! End-to-end driver: the full three-layer system on a real small
//! workload (DESIGN.md §4, row E2E).
//!
//! Pipeline: load AOT artifacts through PJRT (L1/L2) → validate routing
//! against the APSP kernel → topology-aware parallelization search with
//! the PJRT batch cost model (§5.2) → simulate training iterations on
//! the flow-level DES, injecting an NPU failure mid-run and activating
//! the 64+1 backup (§3.3.2) → report the paper's headline metrics
//! (perf vs Clos, cost-efficiency, availability).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_training
//! ```

use ubmesh::coordinator::{Arch, Job};
use ubmesh::cost::capex::{capex_full_clos, capex_ubmesh};
use ubmesh::cost::efficiency::cost_efficiency;
use ubmesh::cost::opex::opex;
use ubmesh::reliability::afr::afr_of_capex;
use ubmesh::reliability::availability::{availability, mtbf_hours, mttr};
use ubmesh::reliability::backup::{fail_npu, ranks_with_backup};
use ubmesh::runtime::artifacts::INF;
use ubmesh::runtime::Artifacts;
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::SuperPodConfig;
use ubmesh::util::table::{fmt, pct, Table};
use ubmesh::workload::models::by_name;
use ubmesh::workload::step::rack_iteration_dag;

fn main() -> ubmesh::util::error::Result<()> {
    println!("=== UB-Mesh end-to-end training driver ===\n");

    // ---- L1/L2: PJRT artifacts -----------------------------------------
    let artifacts = Artifacts::load(&Artifacts::default_dir())?;
    println!(
        "[1/5] PJRT {} up; AOT artifacts compiled (apsp64/apsp256/costmodel/linkload)",
        artifacts.engine.platform()
    );

    // ---- Routing validation: APSP kernel vs graph BFS -------------------
    let (topo, h) = ubmesh_rack(&RackConfig::default());
    let n = 64usize;
    let mut adj = vec![INF; n * n];
    for i in 0..n {
        adj[i * n + i] = 0.0;
    }
    for (i, &a) in h.npus.iter().enumerate() {
        for (j, &b) in h.npus.iter().enumerate() {
            if topo.link_between(a, b).is_some() {
                adj[i * n + j] = 1.0;
            }
        }
    }
    let hops = artifacts.apsp(&adj, n)?;
    let mut mismatches = 0;
    for (i, &a) in h.npus.iter().enumerate() {
        let bfs = topo.bfs_hops(a, false); // NPU mesh only
        for (j, &b) in h.npus.iter().enumerate() {
            // BFS includes switch paths; restrict to the pure mesh by
            // comparing against the kernel's 2-hop closure.
            let got = hops[i * n + j] as u32;
            let direct = topo.link_between(a, b).is_some();
            if i == j {
                assert_eq!(got, 0);
            } else if direct {
                assert_eq!(got, 1);
            } else if got != 2 {
                mismatches += 1;
            }
            let _ = bfs;
        }
    }
    assert_eq!(mismatches, 0, "2D-FM rack diameter must be 2");
    println!("[2/5] routing tables validated against the min-plus APSP kernel (diameter 2 ✓)");

    // ---- §5.2 search with the PJRT batch cost model ----------------------
    let model = "llama-70b";
    let scale = 128;
    let seq = 8192.0;
    let job = Job::new(model, scale, seq, Arch::ubmesh_default())?;
    let plan = job.plan(Some(&artifacts))?;
    println!(
        "[3/5] parallelization search ({} candidates via PJRT cost model):\n      best tp{} sp{} ep{} pp{} dp{} mb{} — iter {:.1} ms, MFU {}, {} tokens/s",
        plan.evaluated,
        plan.best.tp,
        plan.best.sp,
        plan.best.ep,
        plan.best.pp,
        plan.best.dp,
        plan.best.microbatches,
        plan.iter_us / 1e3,
        pct(plan.mfu, 1),
        fmt(plan.tokens_per_s, 0)
    );

    // ---- DES: training iterations with failure + backup ------------------
    let m = by_name(model).unwrap();
    let layers = 4; // scaled-down per-iteration slice for the DES
    let iters = 12;
    let fail_at = 6;
    let failed = h.npus[19];
    let mut log = Table::with_title(
        "training-loop DES (scaled slice, one rack)",
        vec!["iter", "time (ms)", "event"],
    );
    let mut healthy_t = 0.0;
    let mut failover_t = 0.0;
    for it in 0..iters {
        if it < fail_at {
            let net = SimNet::new(&topo);
            let dag = rack_iteration_dag(&topo, &h, &m, seq, layers);
            let r = sim::schedule::run(&net, &dag);
            healthy_t = r.makespan_us;
            log.row(vec![format!("{it}"), fmt(r.makespan_us / 1e3, 2), "-".into()]);
        } else {
            // NPU 19 died: links dark, backup stands in via the LRS.
            let mut net = SimNet::new(&topo);
            fail_npu(&mut net, &topo, failed);
            let ranks = ranks_with_backup(&h, failed);
            let mut h2 = h.clone();
            h2.npus = ranks;
            let dag = rack_iteration_dag(&topo, &h2, &m, seq, layers);
            let r = sim::schedule::run(&net, &dag);
            failover_t = r.makespan_us;
            let ev = if it == fail_at {
                "NPU(2,3) failed → backup activated (64+1)"
            } else {
                "running on backup"
            };
            log.row(vec![format!("{it}"), fmt(r.makespan_us / 1e3, 2), ev.into()]);
        }
    }
    log.print();
    println!(
        "[4/5] failover slowdown: {:.1}% (paper: \"negligible impact\" §3.3.2)",
        (failover_t / healthy_t - 1.0) * 100.0
    );

    // ---- Headline metrics -------------------------------------------------
    let rel = job.relative_perf(Arch::ClosIntraRack, Some(&artifacts))?;
    let ub_capex = capex_ubmesh(&SuperPodConfig::default());
    let clos_capex = capex_full_clos("x64T Clos", 8192, 64);
    let ub_afr = afr_of_capex(&ub_capex);
    let clos_afr = afr_of_capex(&clos_capex);
    let ub_ce = cost_efficiency(rel, &ub_capex, &opex(&ub_capex, ub_afr.total()));
    let clos_ce = cost_efficiency(1.0, &clos_capex, &opex(&clos_capex, clos_afr.total()));
    let ub_av = availability(mtbf_hours(ub_afr.total()), mttr::BASELINE_HOURS);
    let clos_av = availability(mtbf_hours(clos_afr.total()), mttr::BASELINE_HOURS);

    let mut t = Table::with_title(
        "headline metrics (paper §6 summary)",
        vec!["metric", "measured", "paper"],
    );
    t.row(vec![
        "training perf vs Clos".into(),
        pct(rel, 1),
        "93.2–95.9%".into(),
    ]);
    t.row(vec![
        "cost-efficiency vs Clos".into(),
        format!("{:.2}x", ub_ce / clos_ce),
        "2.04x".into(),
    ]);
    t.row(vec![
        "availability vs Clos".into(),
        format!("{} vs {}", pct(ub_av, 1), pct(clos_av, 1)),
        "98.8% vs 91.6%".into(),
    ]);
    t.print();
    println!("[5/5] e2e_training OK");
    Ok(())
}
