//! Fig 9 demo: kill an NPU mid-collective and activate the 64+1 backup.
//!
//! Compares three worlds on the DES: healthy board ring, failover ring
//! through the backup NPU (one LRS hop), and the degraded "mask the NPU"
//! alternative — plus the Fig 12 control-plane recovery comparison.
//!
//! ```bash
//! cargo run --release --example failover_demo
//! ```

use ubmesh::collectives::ring::ring_allreduce_dag;
use ubmesh::reliability::backup::{fail_npu, masked_compute_fraction, ranks_with_backup};
use ubmesh::routing::apr::{paths_2d, to_routed};
use ubmesh::routing::failure::{
    affected_sources, direct_notification_convergence_us, hop_by_hop_convergence_us,
    RecoveryModel,
};
use ubmesh::sim::sweep::sweep_default;
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::NodeId;
use ubmesh::util::table::{fmt, Table};

/// The three worlds compared on the DES (Fig 9).
#[derive(Copy, Clone)]
enum World {
    Healthy,
    BackupViaLrs,
    MaskedNpu,
}

fn main() {
    let (topo, h) = ubmesh_rack(&RackConfig::default());
    let bytes = 360e6;
    let board: Vec<NodeId> = (0..8).map(|s| h.npu(0, s, 8)).collect();
    let failed = board[3];
    let ring = ranks_with_backup(&h, failed);
    let _ = ring;

    // Each world is an independent scenario: build its own SimNet + ring
    // DAG and simulate, fanned out across threads by the sweep.
    let worlds = [World::Healthy, World::BackupViaLrs, World::MaskedNpu];
    let reports = sweep_default(&worlds, |_i, &w, _rng| {
        let mut net = SimNet::new(&topo);
        let ring: Vec<NodeId> = match w {
            World::Healthy => board.clone(),
            World::BackupViaLrs => {
                // Fig 9: backup activation — ring edge 5-3 becomes 5-LRS-B.
                fail_npu(&mut net, &topo, failed);
                board
                    .iter()
                    .map(|&n| if n == failed { h.backup.unwrap() } else { n })
                    .collect()
            }
            World::MaskedNpu => {
                // Masking: 7-NPU ring + lost compute.
                fail_npu(&mut net, &topo, failed);
                board.iter().copied().filter(|&n| n != failed).collect()
            }
        };
        sim::schedule::run(&net, &ring_allreduce_dag(&topo, &ring, bytes))
    });
    let (healthy, failover, masked) = (&reports[0], &reports[1], &reports[2]);

    let mut t = Table::with_title(
        "board AllReduce (360 MB) after NPU-3 failure",
        vec!["scenario", "allreduce µs", "compute capacity", "verdict"],
    );
    t.row(vec![
        "healthy (64 NPUs)".into(),
        fmt(healthy.makespan_us, 1),
        "100%".into(),
        "-".to_string(),
    ]);
    t.row(vec![
        "64+1 backup via LRS (Fig 9)".into(),
        fmt(failover.makespan_us, 1),
        "100%".into(),
        format!("{:.2}x slower allreduce", failover.makespan_us / healthy.makespan_us),
    ]);
    t.row(vec![
        "mask NPU (7-NPU board)".into(),
        fmt(masked.makespan_us, 1),
        format!("{:.1}%", masked_compute_fraction() * 100.0),
        "loses 12.5% of the rack's FLOPs".into(),
    ]);
    t.print();

    // Fig 12: hop-by-hop vs direct notification after a link failure.
    let node = |x: usize, y: usize| h.npu(y, x, 8);
    let mut paths = Vec::new();
    for s in 0..64usize {
        for d in 0..64usize {
            if s != d {
                for mp in paths_2d((s % 8, s / 8), (d % 8, d / 8), 8, 8, true) {
                    paths.push(to_routed(&mp, node));
                }
            }
        }
    }
    let failed_link = topo.link_between(node(0, 0), node(1, 0)).unwrap();
    let affected = affected_sources(&topo, &paths, failed_link);
    let m = RecoveryModel::default();
    let slow = hop_by_hop_convergence_us(&topo, failed_link, &affected, &m);
    let fast = direct_notification_convergence_us(&topo, failed_link, &affected, &m);
    println!(
        "\nFig 12 — link (0,0)-(1,0) fails; {} affected sources:\n  hop-by-hop convergence: {} µs\n  direct notification:    {} µs  ({:.1}x faster)",
        affected.len(),
        fmt(slow, 1),
        fmt(fast, 1),
        slow / fast
    );
    println!("\nfailover_demo OK");
}
