//! Quickstart: build a UB-Mesh rack, explore APR routing, verify
//! deadlock freedom, and run a Multi-Ring AllReduce on the simulator.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ubmesh::collectives::ring::{fullmesh_rings, multiring_allreduce_dag, ring_allreduce_dag};
use ubmesh::routing::apr::{paths_2d, to_routed, PathSet};
use ubmesh::routing::tfc::verify_deadlock_free;
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::NodeId;
use ubmesh::util::table::{fmt, Table};

fn main() {
    // 1. Build the paper's 2D-FullMesh rack: 8 boards × 8 NPUs + 64+1
    //    backup + the 4×18-LRS backplane (§3.3.1–3.3.2).
    let cfg = RackConfig::default();
    let (topo, h) = ubmesh_rack(&cfg);
    println!(
        "rack: {} nodes, {} links, {} NPUs (+{} backup), diameter {}",
        topo.node_count(),
        topo.link_count(),
        h.npus.len(),
        h.backup.is_some() as u32,
        topo.npu_diameter(),
    );

    // 2. APR: enumerate all paths between two unaligned NPUs (Fig 10-b),
    //    split traffic by bottleneck bandwidth, verify TFC 2-VL freedom.
    let node = |x: usize, y: usize| h.npu(y, x, 8);
    let routed: Vec<_> = paths_2d((0, 0), (3, 4), 8, 8, true)
        .iter()
        .map(|m| to_routed(m, node))
        .collect();
    verify_deadlock_free(&topo, &routed).expect("TFC: 2 VLs suffice");
    let ps = PathSet::weighted_by_bottleneck(routed, &topo);
    println!(
        "\nAPR NPU(0,0)→NPU(3,4): {} paths, aggregate {} GB/s (single path {} GB/s)",
        ps.paths.len(),
        fmt(ps.aggregate_gb_s(&topo), 0),
        fmt(ps.paths[0].bottleneck_gb_s(&topo), 0),
    );

    // 3. Multi-Ring AllReduce on one board (Fig 13): Walecki decomposes
    //    the 8-NPU full-mesh into 3 edge-disjoint rings.
    let board: Vec<NodeId> = (0..8).map(|s| h.npu(0, s, 8)).collect();
    let bytes = 360e6; // Table 1's TP transfer size
    let net = SimNet::new(&topo);
    let single = sim::schedule::run(&net, &ring_allreduce_dag(&topo, &board, bytes));
    let rings = fullmesh_rings(&board, 3);
    let multi = sim::schedule::run(
        &net,
        &multiring_allreduce_dag(&topo, &rings, &[1.0, 1.0, 1.0], bytes),
    );
    let mut t = Table::with_title(
        "AllReduce of 360 MB over 8 NPUs (x4-lane links)",
        vec!["algorithm", "time (µs)", "speedup"],
    );
    t.row(vec![
        "single ring".to_string(),
        fmt(single.makespan_us, 1),
        "1.00x".to_string(),
    ]);
    t.row(vec![
        "multi-ring (3 Walecki rings)".to_string(),
        fmt(multi.makespan_us, 1),
        format!("{:.2}x", single.makespan_us / multi.makespan_us),
    ]);
    t.print();

    println!("\nquickstart OK");
}
