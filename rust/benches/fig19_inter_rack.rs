//! Fig 19 — End-to-End Performance of Inter-Rack Interconnections:
//! 2D-FM with Shortest / Detour / Borrow routing vs the inter-rack Clos.

use ubmesh::coordinator::{Arch, Job, Routing};
use ubmesh::util::table::{pct, Table};

fn main() {
    let scale = 8192;
    let seq = 262144.0;
    let mut tbl = Table::with_title(
        "Fig 19: inter-rack 2D-FM vs Clos (relative tokens/s)",
        vec!["model", "Shortest", "Detour", "Borrow", "paper gap"],
    );
    for model in ["gpt3-175b", "gpt4-2t"] {
        let base = Job::new(model, scale, seq, Arch::ClosIntraRack)
            .unwrap()
            .plan(None)
            .unwrap()
            .tokens_per_s;
        let mut cells = vec![model.to_string()];
        let mut vals = Vec::new();
        for routing in [Routing::Shortest, Routing::Detour, Routing::Borrow] {
            let t = Job::new(
                model,
                scale,
                seq,
                Arch::UbMesh {
                    inter_rack_lanes: 16,
                    routing,
                    mesh_lanes: 2,
                    uplink_oversub: 1,
                },
            )
            .unwrap()
            .plan(None)
            .unwrap()
            .tokens_per_s;
            vals.push(t / base);
            cells.push(pct(t / base, 2));
        }
        cells.push(if model == "gpt4-2t" {
            "-0.73% → -0.46%".into()
        } else {
            "negligible".into()
        });
        tbl.row(cells);
        // Monotone: Borrow ≥ Detour ≥ Shortest; all close to Clos.
        assert!(vals[2] >= vals[1] && vals[1] >= vals[0]);
        assert!(vals[0] > 0.90, "{model}: shortest at {:.3}", vals[0]);
    }
    tbl.print();
    println!(
        "\n\"the 2D-FM inter-rack interconnects demonstrates almost the same \
         performance as the expensive Clos architecture\" ✓"
    );
    println!("\nfig19_inter_rack OK");
}
