//! Fig 22 — Linearity Analysis @ Sequence 256K: per-NPU throughput vs
//! base scale (Eq. 2), per model, 1×–64×.
//!
//! Every (model, scale) plan is an independent parallelization search;
//! PR 2: the (model × multiplier) grid is declared through
//! `sim::sweep::GridBuilder` (the 64K-NPU cap is the grid filter) and
//! fans out across threads, replacing the hand-rolled scenario loop.

use ubmesh::coordinator::{linearity, Arch, Job};
use ubmesh::sim::sweep::GridBuilder;
use ubmesh::util::table::{pct, Table};

fn main() {
    let seq = 262144.0;
    // (model, base scale) per §6.5.
    let cases = [
        ("llama-70b", 128usize),
        ("gpt3-175b", 512),
        ("dense-1t", 1024),
        ("gpt4-2t", 1024),
    ];
    let mults = [1usize, 2, 4, 8, 16, 32, 64];

    // Cartesian (model, base) × multiplier, capped at 64K NPUs.
    let grid = GridBuilder::cartesian2(&cases, &mults, |&(model, base), &m| {
        let scale = base * m;
        (scale <= 65536).then_some((model, scale))
    });
    let tputs: Vec<f64> = grid.run(|_i, &(model, scale), _rng| {
        Job::new(model, scale, seq, Arch::ubmesh_default())
            .unwrap()
            .plan(None)
            .unwrap()
            .tokens_per_s
    });
    let tput = |model: &str, scale: usize| -> f64 {
        let k = grid
            .position(|&(mo, sc)| mo == model && sc == scale)
            .expect("scenario grid covers all (model, scale)");
        tputs[k]
    };

    let mut t = Table::with_title(
        "Fig 22: linearity vs base scale (seq 256K)",
        vec!["model", "1x", "2x", "4x", "8x", "16x", "32x", "64x"],
    );
    for (model, base_scale) in cases {
        let base = (base_scale, tput(model, base_scale));
        let mut cells = vec![model.to_string()];
        for &m in &mults {
            let scale = base_scale * m;
            if scale > 65536 {
                cells.push("-".into());
                continue;
            }
            let lin = linearity(base, (scale, tput(model, scale)));
            cells.push(pct(lin, 1));
            assert!(
                lin > 0.95,
                "{model} linearity at {m}x = {lin:.3} (paper: ≥95%)"
            );
        }
        t.row(cells);
    }
    t.print();
    println!(
        "\n\"the linearity of UB-Mesh on all tasks exceeds 100% under 1x–32x \
         scales ... still above 95%\" — ≥95% reproduced ✓"
    );
    println!("\nfig22_linearity OK");
}
