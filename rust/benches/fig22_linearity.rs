//! Fig 22 — Linearity Analysis @ Sequence 256K: per-NPU throughput vs
//! base scale (Eq. 2), per model, 1×–64×.

use ubmesh::coordinator::{linearity, Arch, Job};
use ubmesh::util::table::{pct, Table};

fn main() {
    let seq = 262144.0;
    // (model, base scale) per §6.5.
    let cases = [
        ("llama-70b", 128usize),
        ("gpt3-175b", 512),
        ("dense-1t", 1024),
        ("gpt4-2t", 1024),
    ];
    let mults = [1usize, 2, 4, 8, 16, 32, 64];

    let mut t = Table::with_title(
        "Fig 22: linearity vs base scale (seq 256K)",
        vec!["model", "1x", "2x", "4x", "8x", "16x", "32x", "64x"],
    );
    for (model, base_scale) in cases {
        let tput = |scale: usize| {
            Job::new(model, scale, seq, Arch::ubmesh_default())
                .unwrap()
                .plan(None)
                .unwrap()
                .tokens_per_s
        };
        let base = (base_scale, tput(base_scale));
        let mut cells = vec![model.to_string()];
        for &m in &mults {
            let scale = base_scale * m;
            if scale > 65536 {
                cells.push("-".into());
                continue;
            }
            let lin = linearity(base, (scale, tput(scale)));
            cells.push(pct(lin, 1));
            assert!(
                lin > 0.95,
                "{model} linearity at {m}x = {lin:.3} (paper: ≥95%)"
            );
        }
        t.row(cells);
    }
    t.print();
    println!(
        "\n\"the linearity of UB-Mesh on all tasks exceeds 100% under 1x–32x \
         scales ... still above 95%\" — ≥95% reproduced ✓"
    );
    println!("\nfig22_linearity OK");
}
