//! Fig 22 — Linearity Analysis @ Sequence 256K, now **measured**.
//!
//! Two layers, asserted against each other:
//!
//! 1. **Analytic** (PR 1/2): per-NPU throughput vs base scale (Eq. 2)
//!    from `Job::plan`'s §5.2 cost-model search, per model, 1×–64×,
//!    fanned out through `sim::sweep::GridBuilder`. Retained unchanged —
//!    it is the differential oracle for the measured layer.
//! 2. **Measured** (PR 5): the full training iteration
//!    (`workload::step::iteration_dag` — TP/SP/EP, emergent 1F1B, DP
//!    tail) executed in the fluid simulator on the *real* rack and pod
//!    topologies at 256K-token microbatches. Linearity is computed from
//!    measured per-NPU throughput (rack 64 → pod 1024, DP×16), and the
//!    measured iteration is asserted to agree with the analytic
//!    `iteration_time` of the same configuration within the calibrated
//!    band (mirror-measured ratios: rack ≈ 1.000, pod ≈ 1.013 — the
//!    hop-chain tier model now prices the backplane-mesh ceiling the
//!    DES pays, so the pod band tightens from (0.90, 1.15) to
//!    (0.92, 1.12); the band edges are emitted as `fig22.band.*`).
//!
//! A third section completes the acceptance criterion: a 4096-NPU
//! 4-pod SuperPod iteration with **all five** parallelisms live
//! (TP8·SP8·EP16·PP8·DP8, the DP pairs crossing all four pods over the
//! HRS tier), lazy stages throughout, with the solver work counters
//! recorded.
//!
//! A fourth section (PR 10) measures the **full fig22 grid** to 32K and
//! 64K NPUs: TP8·SP8·EP32·PP32 scaling purely by DP (4 → 16 → 32, with
//! every EP all-to-all spanning four pods), executed through
//! `workload::symmetric` — channel-disjoint translated DP units advanced
//! by the component-parallel runner, one representative solve reused
//! across units, the coupled DP tail solved once. The replica-cache
//! speedup and the cache-vs-full bit-equality are asserted here
//! (`fig22.par.*`); CI re-runs the bench at `UBMESH_SIM_THREADS=1` and
//! diffs every non-wall key against the multi-worker run.
//!
//! Emits `BENCH_workload.json` (`BENCH_SIM_JSON` overrides the path;
//! keys documented in rust/benches/README.md).

// Benches measure wall-clock by definition; the Instant::now
// determinism lint (clippy.toml) is for the sim core, not harnesses.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use ubmesh::coordinator::{linearity, Arch, Job};
use ubmesh::sim::sweep::GridBuilder;
use ubmesh::sim::{self, SimNet, SimReport};
use ubmesh::topology::pod::{ubmesh_pod, PodConfig};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::util::bench::JsonReport;
use ubmesh::util::table::{pct, Table};
use ubmesh::workload::models::by_name;
use ubmesh::workload::placement::{Placement, TierBandwidth};
use ubmesh::workload::step::{iteration_dag, iteration_time, IterationSpec, RankOrder};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

/// Fig 22 measured configuration: TP on boards, SP on the rack column,
/// EP tiling SP×DP, scaling rack → pod purely by DP (the regime in
/// which the paper reports ≥95% linearity — PP constant, bubble
/// unchanged, DP the only added cost).
fn cfg(moe: bool, dp: usize, mb: usize) -> ParallelismConfig {
    ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: if moe { 8 } else { 1 },
        pp: 1,
        dp,
        microbatches: mb,
        tokens_per_microbatch: 262144.0, // the fig's 256K sequence
    }
}

fn run_measured(
    t: &ubmesh::topology::Topology,
    map: &ClusterMap,
    m: &ubmesh::workload::ModelConfig,
    p: &ParallelismConfig,
) -> (SimReport, f64) {
    let dag = iteration_dag(t, map, m, p, RankOrder::TopologyAware, &IterationSpec::default());
    assert!(dag.stages.iter().any(|s| s.is_lazy()), "lazy stages required");
    let net = SimNet::new(t);
    let t0 = Instant::now();
    let r = sim::schedule::run(&net, &dag);
    let wall = t0.elapsed().as_secs_f64();
    assert!(!r.is_stalled());
    (r, wall)
}

/// Calibrated DES/analytic ratio bands (half-open). The rack tier was
/// already exact; the pod tier tightened once the backplane-mesh hop
/// entered the analytic chain (pre-fix band: (0.90, 1.15) on both).
const RACK_BAND: (f64, f64) = (0.90, 1.15);
const POD_BAND: (f64, f64) = (0.92, 1.12);

fn main() {
    let mut json = JsonReport::new();

    // ---- 1. analytic linearity (the PR 1/2 experiment, unchanged) ----
    let seq = 262144.0;
    let cases = [
        ("llama-70b", 128usize),
        ("gpt3-175b", 512),
        ("dense-1t", 1024),
        ("gpt4-2t", 1024),
    ];
    let mults = [1usize, 2, 4, 8, 16, 32, 64];
    let grid = GridBuilder::cartesian2(&cases, &mults, |&(model, base), &m| {
        let scale = base * m;
        (scale <= 65536).then_some((model, scale))
    });
    let tputs: Vec<f64> = grid.run(|_i, &(model, scale), _rng| {
        Job::new(model, scale, seq, Arch::ubmesh_default())
            .unwrap()
            .plan(None)
            .unwrap()
            .tokens_per_s
    });
    let tput = |model: &str, scale: usize| -> f64 {
        let k = grid
            .position(|&(mo, sc)| mo == model && sc == scale)
            .expect("scenario grid covers all (model, scale)");
        tputs[k]
    };

    let mut t = Table::with_title(
        "Fig 22: analytic linearity vs base scale (seq 256K)",
        vec!["model", "1x", "2x", "4x", "8x", "16x", "32x", "64x"],
    );
    for (model, base_scale) in cases {
        let base = (base_scale, tput(model, base_scale));
        let mut cells = vec![model.to_string()];
        for &m in &mults {
            let scale = base_scale * m;
            if scale > 65536 {
                cells.push("-".into());
                continue;
            }
            let lin = linearity(base, (scale, tput(model, scale)));
            cells.push(pct(lin, 1));
            assert!(
                lin > 0.95,
                "{model} analytic linearity at {m}x = {lin:.3} (paper: ≥95%)"
            );
        }
        t.row(cells);
    }
    t.print();

    // ---- 2. measured linearity: DES iteration at rack + pod tier ----
    let mb = 4;
    let (rack_t, rack_h) = ubmesh_rack(&RackConfig::default());
    let rack_map = ClusterMap::rack(&rack_h);
    let (pod_t, pod_h) = ubmesh_pod(&PodConfig::default());
    let pod_map = ClusterMap::pod(&pod_h);
    let bw = TierBandwidth::ubmesh(16, 1.0);

    let mut tbl = Table::with_title(
        "Fig 22 (measured): DES iteration, rack 64 → pod 1024 (DP×16)",
        vec![
            "model",
            "rack iter (ms)",
            "pod iter (ms)",
            "linearity",
            "DES/analytic rack",
            "DES/analytic pod",
        ],
    );
    for name in ["llama-70b", "gpt4-2t"] {
        let m = by_name(name).unwrap();
        let pr = cfg(m.is_moe(), 1, mb);
        let pp = cfg(m.is_moe(), 16, mb);
        let (rr, wall_r) = run_measured(&rack_t, &rack_map, &m, &pr);
        let (rp, wall_p) = run_measured(&pod_t, &pod_map, &m, &pp);

        let tput_r = pr.tokens_per_iter() / (rr.makespan_us / 1e6);
        let tput_p = pp.tokens_per_iter() / (rp.makespan_us / 1e6);
        let lin = linearity((64, tput_r), (1024, tput_p));

        let an_r = iteration_time(&m, &pr, &Placement::topology_aware(&pr), &bw);
        let an_p = iteration_time(&m, &pp, &Placement::topology_aware(&pp), &bw);
        let ratio_r = rr.makespan_us / an_r.total_us;
        let ratio_p = rp.makespan_us / an_p.total_us;

        tbl.row(vec![
            name.to_string(),
            format!("{:.1}", rr.makespan_us / 1e3),
            format!("{:.1}", rp.makespan_us / 1e3),
            pct(lin, 1),
            format!("{ratio_r:.3}"),
            format!("{ratio_p:.3}"),
        ]);

        // The paper's band, from *measured* throughput (mirror: llama
        // 0.974, gpt4-2t 0.975 at mb=4 / 256K tokens).
        assert!(
            lin >= 0.95,
            "{name} measured linearity {lin:.3} below the paper's 95% band"
        );
        // Measured-vs-analytic agreement, calibrated: the rack iteration
        // sits on the exact tier bandwidths (mirror 1.000). The pod adds
        // the DP tail, whose backplane-mesh ceiling the hop-chain model
        // now prices — the mirror ratios drop to 1.013 (both models)
        // and the band tightens from the pre-fix (0.90, 1.15) to
        // (0.92, 1.12); the residual ~1.3% is DES queueing/striping
        // granularity, not a missing hop.
        assert!(
            (RACK_BAND.0..RACK_BAND.1).contains(&ratio_r),
            "{name} rack DES/analytic {ratio_r:.3} outside calibrated {RACK_BAND:?}"
        );
        assert!(
            (POD_BAND.0..POD_BAND.1).contains(&ratio_p),
            "{name} pod DES/analytic {ratio_p:.3} outside calibrated {POD_BAND:?}"
        );

        let key = name.replace('-', "_");
        json.metric(format!("fig22.{key}.rack_iter_us"), rr.makespan_us);
        json.metric(format!("fig22.{key}.pod_iter_us"), rp.makespan_us);
        json.metric(format!("fig22.{key}.measured_linearity"), lin);
        json.metric(format!("fig22.{key}.ratio_rack"), ratio_r);
        json.metric(format!("fig22.{key}.ratio_pod"), ratio_p);
        json.metric(format!("fig22.{key}.rack_events"), rr.events as f64);
        json.metric(format!("fig22.{key}.pod_events"), rp.events as f64);
        json.metric(format!("fig22.{key}.rack_wall_s"), wall_r);
        json.metric(format!("fig22.{key}.pod_wall_s"), wall_p);
    }
    json.metric("fig22.band.rack_lo", RACK_BAND.0);
    json.metric("fig22.band.rack_hi", RACK_BAND.1);
    json.metric("fig22.band.pod_lo", POD_BAND.0);
    json.metric("fig22.band.pod_hi", POD_BAND.1);
    tbl.print();

    // ---- 3. 4096-NPU SuperPod iteration: all five parallelisms ----
    // TP8 on boards, SP8 on rack columns, EP16 tiling SP×DP across the
    // rack rows of a pod, PP8 across the racks of a half-pod, and DP8
    // whose pairs cross all four pods over the HRS Clos tier. Lazy
    // stages keep peak memory at O(active phase); the solver work
    // counters land in BENCH_workload.json so the perf trajectory of
    // the workload hot path is tracked like the collective hot paths in
    // BENCH_sim.json.
    let mut sp_cfg = SuperPodConfig::default();
    sp_cfg.pods = 4;
    let (sp_t, sp_h) = ubmesh_superpod(&sp_cfg);
    let sp_map = ClusterMap::superpod(&sp_h);
    let m = by_name("gpt4-2t").unwrap();
    let p4k = ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 16,
        pp: 8,
        dp: 8,
        microbatches: 4,
        tokens_per_microbatch: 8192.0,
    };
    assert_eq!(p4k.npus(), 4096);
    let dag = iteration_dag(
        &sp_t,
        &sp_map,
        &m,
        &p4k,
        RankOrder::TopologyAware,
        &IterationSpec::default(),
    );
    assert!(dag.stages.iter().any(|s| s.is_lazy()));
    let flows = dag.total_flow_count();
    println!(
        "\n4096-NPU SuperPod iteration: {} stages, {} flows (lazy)",
        dag.stages.len(),
        flows
    );
    let net = SimNet::new(&sp_t);
    let t0 = Instant::now();
    let r = sim::schedule::run(&net, &dag);
    let wall = t0.elapsed().as_secs_f64();
    assert!(!r.is_stalled(), "4096-NPU iteration must complete");
    let an = iteration_time(&m, &p4k, &Placement::topology_aware(&p4k), &bw);
    let ratio = r.makespan_us / an.total_us;
    println!(
        "  makespan {:.1} ms ({ratio:.2}x analytic), {} events, peak {} flows, \
         wall {wall:.1}s ({:.2} µs/event)",
        r.makespan_us / 1e3,
        r.events,
        r.peak_flows,
        wall * 1e6 / r.events as f64
    );
    // The analytic model now pays the backplane-mesh and uplink-lane
    // ceilings itself (PR 3's oversubscription finding, modeled in the
    // hop chains), so the measured excess shrinks from the pre-fix 1.203
    // to a mirror-measured 1.158 — the remaining gap is multi-phase
    // contention the closed form cannot see. Accept (1.0, 1.6), down
    // from (1.0, 2.0).
    assert!(
        (1.0..1.6).contains(&ratio),
        "4096-NPU DES/analytic {ratio:.3} out of regime (mirror: 1.158)"
    );
    json.metric("fig22.band.pod4096_lo", 1.0);
    json.metric("fig22.band.pod4096_hi", 1.6);
    json.metric("iter.pod4096.npus", 4096.0);
    json.metric("iter.pod4096.makespan_us", r.makespan_us);
    json.metric("iter.pod4096.analytic_us", an.total_us);
    json.metric("iter.pod4096.ratio_analytic", ratio);
    json.metric("iter.pod4096.flows", flows as f64);
    json.metric("iter.pod4096.stages", dag.stages.len() as f64);
    json.metric("iter.pod4096.events", r.events as f64);
    json.metric("iter.pod4096.peak_flows", r.peak_flows as f64);
    json.metric("iter.pod4096.wall_s", wall);
    json.metric(
        "iter.pod4096.wall_us_per_event",
        wall * 1e6 / r.events as f64,
    );
    json.metric("iter.pod4096.rate_recomputes", r.solver.rate_recomputes as f64);
    json.metric(
        "iter.pod4096.add_rate_recomputes",
        r.solver.add_rate_recomputes as f64,
    );
    json.metric(
        "iter.pod4096.add_full_component_recomputes",
        r.solver.add_full_component_recomputes as f64,
    );
    json.metric("iter.pod4096.add_resolves", r.solver.add_resolves as f64);
    json.metric("iter.pod4096.fallbacks", r.solver.fallbacks as f64);
    json.metric("iter.pod4096.uf_rebuilds", r.solver.uf_rebuilds as f64);

    // ---- 4. PR 10: the 32K/64K measured grid via replica symmetry ----
    // All five parallelisms at 256K-token microbatches, scaling purely
    // by DP from an 8192-NPU base: TP8·SP8·EP32·PP32 with DP 4 → 16 →
    // 32 (8 → 32 → 64 pods). EP32 over SP8 makes a symmetric unit four
    // DP replicas = eight pods, with every EP all-to-all spanning four
    // pods over the HRS uplinks — the workload is genuinely
    // HRS-coupled, yet the only *cross-unit* coupling is the DP tail.
    // `workload::symmetric` factors the iteration accordingly: the
    // representative unit is solved once (replica cache), the tail once,
    // and 32K/64K makespans follow at ~constant cost per scale.
    //
    // `UBMESH_SIM_THREADS` sets the component-runner worker count (CI
    // runs the whole bench at 1 and N and diffs every non-wall key);
    // the replica-cache speedup below is worker-independent by
    // construction — it compares solves avoided, not threads used.
    let workers = std::env::var("UBMESH_SIM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    println!("\nfig22 grid: component workers = {workers}");
    let grid_cfg = |dp: usize| ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 32,
        pp: 32,
        dp,
        microbatches: 2,
        tokens_per_microbatch: 262144.0,
    };
    let build_sp = |pods: usize| {
        let mut c = SuperPodConfig::default();
        c.pods = pods;
        ubmesh_superpod(&c)
    };
    use ubmesh::workload::symmetric::{
        run_symmetric, symmetric_iteration, SymmetricConfig,
    };
    let spec = IterationSpec::default();
    let m2t = by_name("gpt4-2t").unwrap();

    // Base: 8192 NPUs (dp = 4). dp equals one symmetric unit here —
    // nothing to factor — so the base runs the plain coupled solve,
    // doubling as the ground-truth cost of "one unit + tail".
    let p_base = grid_cfg(4);
    assert_eq!(p_base.npus(), 8192);
    let (bt, bh) = build_sp(8);
    let bmap = ClusterMap::superpod(&bh);
    let (rb, wall_b) = run_measured(&bt, &bmap, &m2t, &p_base);
    let tput_base = p_base.tokens_per_iter() / (rb.makespan_us / 1e6);
    println!(
        "  base 8192: makespan {:.1} ms, {} events, wall {wall_b:.1}s",
        rb.makespan_us / 1e3,
        rb.events
    );
    json.metric("fig22.x8k.npus", 8192.0);
    json.metric("fig22.x8k.makespan_us", rb.makespan_us);
    json.metric("fig22.x8k.events", rb.events as f64);
    json.metric("fig22.x8k.wall_s", wall_b);

    let mut par_emitted = false;
    for (key, pods, dp) in [("x32k", 32usize, 16usize), ("x64k", 64, 32)] {
        let p = grid_cfg(dp);
        assert_eq!(p.npus(), pods * 1024);
        let (st, sh) = build_sp(pods);
        let smap = ClusterMap::superpod(&sh);
        let sym = symmetric_iteration(&st, &smap, &m2t, &p, RankOrder::TopologyAware, &spec)
            .expect("the fig22 grid config must factor");
        assert_eq!(sym.unit_dp, 4, "EP32/SP8 unit spans four replicas");
        assert_eq!(sym.units, dp / 4);
        assert!(sym.tail.is_some(), "DP ≥ 8× must expose a gradient tail");
        let net = SimNet::new(&st);

        let t0 = Instant::now();
        let cached = run_symmetric(
            &net,
            &sym,
            &SymmetricConfig {
                workers,
                replica_cache: true,
                strategy: Default::default(),
            },
        );
        let wall_c = t0.elapsed().as_secs_f64();
        assert!(!cached.report.is_stalled(), "{key} iteration must complete");
        assert_eq!(cached.cached_units, sym.units - 1);

        let r = &cached.report;
        let tput = p.tokens_per_iter() / (r.makespan_us / 1e6);
        let lin = linearity((8192, tput_base), (p.npus(), tput));
        println!(
            "  {key} ({} NPUs, {} units): makespan {:.1} ms, linearity {}, \
             {} events, wall {wall_c:.1}s ({} unit solves cached)",
            p.npus(),
            sym.units,
            r.makespan_us / 1e3,
            pct(lin, 1),
            r.events,
            cached.cached_units
        );
        assert!(
            lin >= 0.95,
            "{key} measured linearity {lin:.3} below the paper's 95% band"
        );
        json.metric(format!("fig22.{key}.npus"), p.npus() as f64);
        json.metric(format!("fig22.{key}.units"), sym.units as f64);
        json.metric(format!("fig22.{key}.unit_dp"), sym.unit_dp as f64);
        json.metric(format!("fig22.{key}.makespan_us"), r.makespan_us);
        json.metric(format!("fig22.{key}.linearity"), lin);
        json.metric(format!("fig22.{key}.events"), r.events as f64);
        json.metric(format!("fig22.{key}.peak_flows"), r.peak_flows as f64);
        json.metric(format!("fig22.{key}.rate_recomputes"), r.solver.rate_recomputes as f64);
        json.metric(format!("fig22.{key}.fallbacks"), r.solver.fallbacks as f64);
        json.metric(format!("fig22.{key}.resolves"), r.solver.resolves as f64);
        json.metric(format!("fig22.{key}.wall_s"), wall_c);

        // At 32K, also pay for every unit once: the no-cache component-
        // parallel run is the differential oracle for the cache (the
        // merged reports must agree bit-for-bit) and the honest
        // numerator of the replica-cache speedup — what a solver that
        // cannot exploit translation symmetry must spend, unit by unit.
        if key == "x32k" {
            let t0 = Instant::now();
            let solved = run_symmetric(
                &net,
                &sym,
                &SymmetricConfig {
                    workers,
                    replica_cache: false,
                    strategy: Default::default(),
                },
            );
            let wall_f = t0.elapsed().as_secs_f64();
            assert!(
                solved.report.makespan_us.to_bits() == r.makespan_us.to_bits()
                    && solved.report.byte_hops.to_bits() == r.byte_hops.to_bits()
                    && solved.report.events == r.events
                    && solved.report.solver.resolves == r.solver.resolves,
                "replica cache diverged from the full per-unit solve"
            );
            let serial_equiv = solved.serial_equivalent_wall_s();
            let speedup = serial_equiv / wall_c.max(1e-9);
            println!(
                "  x32k replica-cache speedup: {serial_equiv:.1}s serial-equivalent \
                 / {wall_c:.1}s cached = {speedup:.2}x (no-cache wall {wall_f:.1}s)"
            );
            assert!(
                speedup >= 2.0,
                "replica-cache speedup {speedup:.2}x below the 2x floor \
                 (serial-equivalent {serial_equiv:.2}s, cached {wall_c:.2}s)"
            );
            json.metric("fig22.par.workers", workers as f64);
            json.metric("fig22.par.serial_equiv_wall_s", serial_equiv);
            json.metric("fig22.par.cache_wall_s", wall_c);
            json.metric("fig22.par.nocache_wall_s", wall_f);
            json.metric("fig22.par.speedup", speedup);
            par_emitted = true;
        }
    }
    assert!(par_emitted, "the x32k parallel section must run");

    let path =
        std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_workload.json".into());
    match json.write(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nfailed to write {path}: {e}"),
    }
    println!(
        "\n\"the linearity of UB-Mesh on all tasks exceeds 100% under 1x–32x \
         scales ... still above 95%\" — ≥95% reproduced analytically AND from \
         measured DES throughput ✓"
    );
    println!("\nfig22_linearity OK");
}
