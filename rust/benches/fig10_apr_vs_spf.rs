//! Fig 10 — Shortest-Path Routing vs All-Path Routing: bandwidth
//! exposure and transfer completion on the rack 2D-FM, via the DES.

use ubmesh::routing::apr::{paths_2d, to_routed, PathSet};
use ubmesh::routing::spf::shortest_paths;
use ubmesh::sim::{self, FlowSpec, SimNet, Stage, StageDag};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::util::table::{fmt, Table};

fn main() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let node = |x: usize, y: usize| h.npu(y, x, 8);
    let bytes = 192e6;

    let mut tbl = Table::with_title(
        "Fig 10: P2P transfer of 192 MB, SPF vs APR",
        vec!["pair", "SPF paths", "SPF µs", "APR paths", "APR µs", "speedup"],
    );
    for (s, d) in [((0, 0), (3, 0)), ((0, 0), (3, 4)), ((1, 2), (6, 7))] {
        let src = node(s.0, s.1);
        let dst = node(d.0, d.1);
        let net = SimNet::new(&t);

        // SPF: equal-cost shortest paths only.
        let spf = shortest_paths(&t, src, dst, 8, true);
        let spf_paths: Vec<Vec<_>> = spf.iter().map(|p| p.nodes.clone()).collect();
        let w = vec![1.0; spf_paths.len()];
        let mut dag = StageDag::default();
        dag.push(Stage::new("spf").with_flows(FlowSpec::split(&t, &spf_paths, &w, bytes)));
        let r_spf = sim::schedule::run(&net, &dag);

        // APR: all paths, bottleneck-weighted.
        let routed: Vec<_> = paths_2d(s, d, 8, 8, true)
            .iter()
            .map(|m| to_routed(m, node))
            .collect();
        let ps = PathSet::weighted_by_bottleneck(routed, &t);
        let apr_paths: Vec<Vec<_>> = ps.paths.iter().map(|p| p.nodes.clone()).collect();
        let mut dag = StageDag::default();
        dag.push(
            Stage::new("apr").with_flows(FlowSpec::split(&t, &apr_paths, &ps.weights, bytes)),
        );
        let r_apr = sim::schedule::run(&net, &dag);

        tbl.row(vec![
            format!("{s:?}→{d:?}"),
            format!("{}", spf_paths.len()),
            fmt(r_spf.makespan_us, 1),
            format!("{}", apr_paths.len()),
            fmt(r_apr.makespan_us, 1),
            format!("{:.2}x", r_spf.makespan_us / r_apr.makespan_us),
        ]);
        assert!(r_apr.makespan_us < r_spf.makespan_us);
    }
    tbl.print();
    println!("\nAPR \"leverages all available paths between source and destination\" ✓");
    println!("\nfig10_apr_vs_spf OK");
}
