//! Table 2 — Usage Estimation of Different Types of Links.
//! Census of the constructed 8K SuperPod vs the paper's ratios.

use ubmesh::topology::census::{class_name, Census};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::topology::CableClass;
use ubmesh::util::bench::bench;
use ubmesh::util::table::{pct, Table};

fn main() {
    let cfg = SuperPodConfig::default();
    let mut built = None;
    let b = bench("build 8K SuperPod topology", || {
        built = Some(ubmesh_superpod(&cfg));
    });
    let (t, _) = built.unwrap();
    println!(
        "  ({} nodes, {} links, {:.1}k nodes/s)",
        t.node_count(),
        t.link_count(),
        t.node_count() as f64 / b.mean.as_secs_f64() / 1e3
    );
    let c = Census::of(&t);

    let paper = [
        ("XY (passive electrical, ~1 m)", CableClass::PassiveElectrical, 86.7),
        ("Z (active electrical, ~10 m)", CableClass::ActiveElectrical, 7.2),
        ("α/βγ (optical, 100–1000 m)", CableClass::Optical, 4.8 + 1.2),
    ];
    let total = c.external_cables() as f64;
    let mut tbl = Table::with_title(
        "Table 2: external cable mix (measured vs paper)",
        vec!["dimension / class", "cables", "measured", "paper"],
    );
    for (name, class, pshare) in paper {
        tbl.row(vec![
            name.to_string(),
            format!("{}", c.cables_of(class)),
            pct(c.cables_of(class) as f64 / total, 1),
            format!("{pshare}%"),
        ]);
    }
    tbl.print();
    println!("optical modules: {}", c.optical_modules);
    let passive_share = c.cables_of(CableClass::PassiveElectrical) as f64 / total;
    assert!(
        passive_share > 0.8,
        "passive electrical must dominate (shape of Table 2)"
    );
    // shape: passive >> active >= optical count
    let _ = class_name(0);
    println!("\ntable2_cables OK");
}
