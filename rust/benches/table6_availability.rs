//! Table 6 / §6.6 as a **DES experiment** (ROADMAP item 4): the paper's
//! +7.2% availability headline rests on Eq. 3 closed-form MTBF
//! arithmetic that charges every failure one flat MTTR. Here the same
//! AFR census instead drives a correlated FaultPlan sampler
//! (`reliability::faultgen`) whose blast-radius groups are replayed
//! against the *measured* training iteration
//! (`workload::step::iteration_dag`) on the real rack fabrics, and a
//! mission-length Monte-Carlo turns the measured per-class outcomes
//! into availability / effective-training-time distributions
//! (`reliability::montecarlo::measured_availability`), with checkpoint
//! economics (`reliability::checkpoint`) priced by real DCN flows.
//!
//! Emits `BENCH_avail.json` (`BENCH_SIM_JSON` overrides the path). CI
//! asserts the closed-form-vs-measured differential-oracle band, the
//! interior checkpoint-interval optimum, and a positive measured
//! UB-Mesh-vs-Clos delta — see `benches/README.md` for the key schema.

use ubmesh::cost::capex::{capex_full_clos, capex_ubmesh};
use ubmesh::reliability::afr::afr_of_capex;
use ubmesh::reliability::availability::{availability, mtbf_hours, mttr};
use ubmesh::reliability::checkpoint::{
    state_bytes_per_rank, young_optimum_hours, CheckpointConfig,
};
use ubmesh::reliability::faultgen::{
    BlastClass, FaultDomains, FaultGen, FaultGenConfig, HOURS_PER_YEAR,
};
use ubmesh::reliability::montecarlo::{
    measured_availability, measured_class_costs, ClassCosts, MeasureConfig, MissionConfig,
    NPU_AFR_PER_UNIT,
};
use ubmesh::sim::{self, RecoveryConfig, SimNet};
use ubmesh::topology::dcn::{add_dcn_layer, DcnAttach};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::SuperPodConfig;
use ubmesh::topology::variants::rack_clos;
use ubmesh::util::bench::JsonReport;
use ubmesh::util::table::{fmt, pct, Table};
use ubmesh::workload::models::by_name;
use ubmesh::workload::step::{
    checkpoint_flow_dag, iteration_dag, iteration_with_readmission, IterationSpec, RankOrder,
};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

/// The modeled fleet: the paper's 8K SuperPod (128 racks × 64 NPUs).
const FLEET: usize = 8192;
const RACKS: usize = FLEET / 64;
/// Power-domain AFR per rack (failures/year) — PSU/busbar class.
const RACK_POWER_AFR: f64 = 0.02;
/// Scheduler readmission floor after an abort (§4.2 fault localization
/// + task re-placement), on top of the measured checkpoint read-back.
const SCHEDULER_RESTART_HOURS: f64 = 5.0 / 60.0;

fn fleet_gen(domains: FaultDomains, afr: &ubmesh::reliability::AfrBreakdown) -> FaultGen {
    // Domains are rack-scale (the DES replay arena); rates are scaled to
    // the full 8K fleet so mission arrivals match the paper's census.
    FaultGen::new(
        domains,
        afr,
        FaultGenConfig {
            npu_fleet_afr: FLEET as f64 * NPU_AFR_PER_UNIT,
            rack_power_afr: RACK_POWER_AFR * RACKS as f64,
            ..FaultGenConfig::default()
        },
    )
}

fn abort_rate_per_year(gen: &FaultGen, costs: &ClassCosts) -> f64 {
    BlastClass::ALL
        .iter()
        .map(|&c| gen.rates.of(c) * costs.abort_fraction(c))
        .sum()
}

fn main() {
    let mut json = JsonReport::new();

    // --- censuses + Eq. 3 closed forms (the Table 6 numbers) ------------
    let ub_afr = afr_of_capex(&capex_ubmesh(&SuperPodConfig::default()));
    let clos_afr = afr_of_capex(&capex_full_clos("x64T Clos", FLEET, 64));
    let ub_cf = availability(mtbf_hours(ub_afr.total()), mttr::BASELINE_HOURS);
    let clos_cf = availability(mtbf_hours(clos_afr.total()), mttr::BASELINE_HOURS);
    json.metric("avail.ub.afr_total", ub_afr.total());
    json.metric("avail.clos.afr_total", clos_afr.total());
    json.metric("avail.ub.closed_form", ub_cf);
    json.metric("avail.clos.closed_form", clos_cf);
    json.metric("avail.closed_form.delta", ub_cf - clos_cf);
    println!(
        "closed form (Eq. 3, flat {:.0}-min MTTR): UB-Mesh {} vs Clos {} → +{}",
        mttr::BASELINE_HOURS * 60.0,
        pct(ub_cf, 1),
        pct(clos_cf, 1),
        pct(ub_cf - clos_cf, 1)
    );

    // --- the measured training iteration on both rack fabrics ----------
    let m = by_name("llama-70b").unwrap();
    let p = ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 1,
        pp: 1,
        dp: 1,
        microbatches: 2,
        tokens_per_microbatch: 8192.0,
    };
    let spec = IterationSpec::default();

    let (mut ub_t, ub_h) = ubmesh_rack(&RackConfig::default());
    let storage = add_dcn_layer(
        &mut ub_t,
        std::slice::from_ref(&ub_h),
        2,
        DcnAttach::UbSwitch { lanes_per_rack: 8 },
    );
    let ub_map = ClusterMap::rack(&ub_h);
    let ub_dag = iteration_dag(&ub_t, &ub_map, &m, &p, RankOrder::TopologyAware, &spec);

    let (cl_t, cl_h) = rack_clos();
    let cl_map = ClusterMap::clos_rack(&cl_h);
    let cl_dag = iteration_dag(&cl_t, &cl_map, &m, &p, RankOrder::TopologyAware, &spec);

    // --- checkpoint economics as real DCN flows -------------------------
    // Fleet-sharded state: every rank owns params × 18 B / 8192. The
    // write and read-back contend for the rack's 8 DCN uplink lanes —
    // the measured makespan, not a per-rank bandwidth guess, prices W.
    let fleet_p = ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 1,
        pp: 128,
        dp: 1,
        microbatches: 2,
        tokens_per_microbatch: 8192.0,
    };
    let bytes_per_rank = state_bytes_per_rank(&m, &fleet_p);
    let ub_net = SimNet::new(&ub_t);
    let write_dag = checkpoint_flow_dag(&ub_t, &ub_map, &storage, bytes_per_rank, true);
    let write_run = sim::schedule::run(&ub_net, &write_dag);
    assert!(!write_run.is_stalled());
    let write_hours = write_run.makespan_us / 3.6e9;

    let healthy_iter = sim::schedule::run(&ub_net, &ub_dag);
    assert!(!healthy_iter.is_stalled());
    let restart_dag = iteration_with_readmission(
        &ub_t, &ub_map, &m, &p, RankOrder::TopologyAware, &spec, &storage, bytes_per_rank,
    );
    let restart_run = sim::schedule::run(&ub_net, &restart_dag);
    assert!(!restart_run.is_stalled());
    // The readmission surcharge: first-iteration-after-restart minus a
    // normal iteration, plus the scheduler floor.
    let readmission_hours =
        (restart_run.makespan_us - healthy_iter.makespan_us).max(0.0) / 3.6e9;
    let restart_hours = SCHEDULER_RESTART_HOURS + readmission_hours;
    json.metric("avail.ckpt.state_bytes_per_rank", bytes_per_rank);
    json.metric("avail.ckpt.write_hours", write_hours);
    json.metric("avail.ckpt.readmission_hours", readmission_hours);
    json.metric("avail.ckpt.restart_hours", restart_hours);
    println!(
        "\ncheckpoint flows: {:.0} MB/rank, write {:.2} s (measured over 8 DCN lanes), \
         restart readmission +{:.2} s on the first iteration",
        bytes_per_rank / 1e6,
        write_hours * 3600.0,
        readmission_hours * 3600.0
    );

    // --- measured per-class costs: blast radii replayed in the DES -----
    let mcfg = MeasureConfig {
        trials_per_class: 4,
        ..MeasureConfig::default()
    };
    let ub_gen = fleet_gen(FaultDomains::rack(&ub_t, &ub_h), &ub_afr);
    let cl_gen = fleet_gen(FaultDomains::flat(&cl_t, &cl_h.npus, &cl_h.hrs), &clos_afr);
    let ub_costs =
        measured_class_costs(&ub_t, &ub_gen, &ub_dag, &RecoveryConfig::direct(), &mcfg, 11);
    let cl_costs =
        measured_class_costs(&cl_t, &cl_gen, &cl_dag, &RecoveryConfig::direct(), &mcfg, 13);

    let mut tbl = Table::with_title(
        "measured blast-radius outcomes (fraction aborting | mean slowdown)",
        vec!["class", "UB-Mesh", "Clos"],
    );
    for c in BlastClass::ALL {
        tbl.row(vec![
            c.label().into(),
            format!(
                "{} | {}",
                fmt(ub_costs.abort_fraction(c), 2),
                pct(ub_costs.mean_slowdown(c), 1)
            ),
            format!(
                "{} | {}",
                fmt(cl_costs.abort_fraction(c), 2),
                pct(cl_costs.mean_slowdown(c), 1)
            ),
        ]);
    }
    tbl.print();
    // The architectural asymmetry the closed form can't see: the 64+1
    // backup absorbs UB-Mesh NPU deaths, the Clos rack has no backup.
    assert_eq!(ub_costs.abort_fraction(BlastClass::NpuDeath), 0.0);
    assert_eq!(cl_costs.abort_fraction(BlastClass::NpuDeath), 1.0);
    assert_eq!(ub_costs.abort_fraction(BlastClass::SingleLink), 0.0);
    assert_eq!(cl_costs.abort_fraction(BlastClass::SingleLink), 0.0);

    let ub_abort_yr = abort_rate_per_year(&ub_gen, &ub_costs);
    let cl_abort_yr = abort_rate_per_year(&cl_gen, &cl_costs);
    json.metric("avail.ub.abort_per_year", ub_abort_yr);
    json.metric("avail.clos.abort_per_year", cl_abort_yr);

    // --- checkpoint-interval sweep (Clos: abort-dominated, the classic
    // optimum) — common random numbers across intervals, so the curve is
    // noise-free in the interval and the interior optimum is exact.
    let mission = MissionConfig::default();
    let cl_young = young_optimum_hours(write_hours, HOURS_PER_YEAR / cl_abort_yr);
    let intervals = [0.01, 0.02, 0.04, 0.08, 0.16, 0.32, 0.64, 1.28];
    let mut best = (0usize, f64::MIN);
    let mut tbl = Table::with_title(
        "checkpoint-interval sweep (Clos fleet, measured costs, CRN seed)",
        vec!["interval (h)", "effective training time"],
    );
    for (i, &t_h) in intervals.iter().enumerate() {
        let ck = CheckpointConfig::new(t_h, write_hours, restart_hours);
        let r = measured_availability(&cl_gen, &cl_costs, &ck, &mission, 96, 2026);
        let eff = r.effective.mean();
        if eff > best.1 {
            best = (i, eff);
        }
        tbl.row(vec![fmt(t_h, 2), pct(eff, 2)]);
    }
    tbl.print();
    let interior = best.0 > 0 && best.0 + 1 < intervals.len();
    println!(
        "optimum {} h (Young/Daly closed form: {} h) — interior: {interior}",
        fmt(intervals[best.0], 2),
        fmt(cl_young, 3)
    );
    assert!(interior, "sweep optimum pinned to a grid end");
    assert!(
        intervals[best.0] >= cl_young / 4.0 && intervals[best.0] <= cl_young * 4.0,
        "grid optimum {} vs Young {}",
        intervals[best.0],
        cl_young
    );
    json.metric("avail.ckpt.optimal_interval_hours", intervals[best.0]);
    json.metric("avail.ckpt.young_optimum_hours", cl_young);
    json.metric("avail.ckpt.best_effective", best.1);
    json.metric("avail.ckpt.interior", f64::from(interior));

    // --- differential oracle: the uncorrelated limit must reproduce
    // Eq. 3 (network-only rates, flat MTTR, no aborts, no checkpoint
    // overhead). This is the measured-vs-closed-form boundary: beyond
    // it, APR absorption and abort economics move the answer.
    let oracle_gen = FaultGen::new(
        FaultDomains::rack(&ub_t, &ub_h),
        &ub_afr,
        FaultGenConfig {
            npu_fleet_afr: 0.0,
            rack_power_afr: 0.0,
            ..FaultGenConfig::default()
        },
    );
    let oracle_costs = ClassCosts::uncorrelated_limit(mttr::BASELINE_HOURS);
    let no_ckpt = CheckpointConfig::new(1e12, 0.0, 0.0);
    let oracle = measured_availability(&oracle_gen, &oracle_costs, &no_ckpt, &mission, 256, 7);
    let oracle_err = (oracle.availability.mean() - ub_cf).abs();
    println!(
        "\ndifferential oracle (uncorrelated limit): measured {} vs Eq. 3 {} \
         (|err| = {:.4})",
        pct(oracle.availability.mean(), 2),
        pct(ub_cf, 2),
        oracle_err
    );
    json.metric("avail.oracle.measured_uncorrelated", oracle.availability.mean());
    json.metric("avail.oracle.closed_form", ub_cf);
    json.metric("avail.oracle.abs_err", oracle_err);
    assert!(oracle_err < 0.01, "oracle drift: {oracle_err}");

    // --- mission-length measured availability, UB-Mesh vs Clos ----------
    let ub_ck = CheckpointConfig::new(
        young_optimum_hours(write_hours, HOURS_PER_YEAR / ub_abort_yr),
        write_hours,
        restart_hours,
    );
    let cl_ck = CheckpointConfig::new(intervals[best.0], write_hours, restart_hours);
    let ub_m = measured_availability(&ub_gen, &ub_costs, &ub_ck, &mission, 256, 21);
    let cl_m = measured_availability(&cl_gen, &cl_costs, &cl_ck, &mission, 256, 22);
    let delta = ub_m.availability.mean() - cl_m.availability.mean();
    let eff_delta = ub_m.effective.mean() - cl_m.effective.mean();

    let mut tbl = Table::with_title(
        "measured mission availability (720 h, correlated faults, measured costs)",
        vec!["arch", "avail p50", "avail p99", "effective p50", "aborts"],
    );
    for (name, r) in [("UB-Mesh", &ub_m), ("Clos", &cl_m)] {
        tbl.row(vec![
            name.into(),
            pct(r.availability.p50(), 2),
            pct(r.availability.p99(), 2),
            pct(r.effective.p50(), 2),
            format!("{}", r.aborts),
        ]);
    }
    tbl.print();
    println!(
        "measured delta: availability +{} (closed form says +{}), \
         effective training time +{}",
        pct(delta, 2),
        pct(ub_cf - clos_cf, 1),
        pct(eff_delta, 2)
    );
    json.metric("avail.ub.measured_p50", ub_m.availability.p50());
    json.metric("avail.ub.measured_p99", ub_m.availability.p99());
    json.metric("avail.ub.effective_p50", ub_m.effective.p50());
    json.metric("avail.clos.measured_p50", cl_m.availability.p50());
    json.metric("avail.clos.measured_p99", cl_m.availability.p99());
    json.metric("avail.clos.effective_p50", cl_m.effective.p50());
    json.metric("avail.ubmesh_minus_clos", delta);
    json.metric("avail.effective.ubmesh_minus_clos", eff_delta);
    // The measured experiment *confirms the sign* of the paper's +7.2%
    // but attributes it differently: APR + 64+1 absorb most UB-Mesh
    // failures into degraded-mode slowdown (availability stays near
    // 100%), while the backup-less Clos fleet aborts on every NPU death
    // and pays restart + lost work. The closed form's flat-MTTR
    // arithmetic overstates both architectures' downtime — the
    // availability gap survives (asserted), while the effective-time
    // delta is emitted *unasserted*: it hinges on the measured
    // degraded-mode slowdown of backup substitution, which frequent
    // cheap checkpointing on the Clos side can out-compete.
    assert!(delta > 0.0, "measured UB-Mesh delta must stay positive");
    assert!(eff_delta.is_finite());

    let path = std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_avail.json".into());
    match json.write(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
    println!("\ntable6_availability OK");
}
