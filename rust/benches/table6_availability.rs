//! Table 6 / §6.6 as a **DES experiment** (ROADMAP item 4): the paper's
//! +7.2% availability headline rests on Eq. 3 closed-form MTBF
//! arithmetic that charges every failure one flat MTTR. Here the same
//! AFR census instead drives a correlated FaultPlan sampler
//! (`reliability::faultgen`) whose blast-radius groups are replayed
//! against the *measured* training iteration
//! (`workload::step::iteration_dag`) on the real rack fabrics, and a
//! mission-length Monte-Carlo turns the measured per-class outcomes
//! into availability / effective-training-time distributions
//! (`reliability::montecarlo::measured_availability`), with checkpoint
//! economics (`reliability::checkpoint`) priced by real DCN flows.
//!
//! Emits `BENCH_avail.json` (`BENCH_SIM_JSON` overrides the path). CI
//! asserts the closed-form-vs-measured differential-oracle band, the
//! interior checkpoint-interval optimum, and a positive measured
//! UB-Mesh-vs-Clos delta — see `benches/README.md` for the key schema.
//!
//! On top of the fleet experiment, two PR 8 sections: a **recovery-policy
//! tournament** (`avail.policy.*`) — AbortToCheckpoint vs BackupSwap vs
//! ElasticShrink on both 64-NPU arenas at DP = 4, with repair-aware
//! missions (`reliability::repair`) and measured shrink economics — and a
//! deterministic **link-flap damping** experiment (`flap.*`) showing the
//! reroute hysteresis cutting flap-chasing reroutes without hurting the
//! makespan.

use ubmesh::cost::capex::{capex_full_clos, capex_ubmesh};
use ubmesh::reliability::afr::afr_of_capex;
use ubmesh::reliability::availability::{availability, mtbf_hours, mttr};
use ubmesh::reliability::checkpoint::{
    state_bytes_per_rank, young_optimum_hours, CheckpointConfig,
};
use ubmesh::reliability::faultgen::{
    BlastClass, FaultDomains, FaultGen, FaultGenConfig, HOURS_PER_YEAR,
};
use ubmesh::reliability::montecarlo::{
    measured_availability, measured_class_costs, measured_shrink_costs, ClassCosts,
    MeasureConfig, MeasuredAvailability, MissionConfig, RecoveryPolicy, ReplicaMap,
    NPU_AFR_PER_UNIT,
};
use ubmesh::reliability::repair::RepairConfig;
use ubmesh::reliability::AfrBreakdown;
use ubmesh::sim::{self, FaultPlan, FlowSpec, RecoveryConfig, SimNet, Stage, StageDag};
use ubmesh::topology::dcn::{add_dcn_layer, DcnAttach};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::SuperPodConfig;
use ubmesh::topology::variants::rack_clos;
use ubmesh::topology::{CableClass, NodeId, Topology};
use ubmesh::util::bench::JsonReport;
use ubmesh::util::table::{fmt, pct, Table};
use ubmesh::workload::models::by_name;
use ubmesh::workload::step::{
    checkpoint_flow_dag, iteration_dag, iteration_with_readmission, IterationSpec, RankOrder,
};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

/// The modeled fleet: the paper's 8K SuperPod (128 racks × 64 NPUs).
const FLEET: usize = 8192;
const RACKS: usize = FLEET / 64;
/// Power-domain AFR per rack (failures/year) — PSU/busbar class.
const RACK_POWER_AFR: f64 = 0.02;
/// Scheduler readmission floor after an abort (§4.2 fault localization
/// + task re-placement), on top of the measured checkpoint read-back.
const SCHEDULER_RESTART_HOURS: f64 = 5.0 / 60.0;

fn fleet_gen(domains: FaultDomains, afr: &ubmesh::reliability::AfrBreakdown) -> FaultGen {
    // Domains are rack-scale (the DES replay arena); rates are scaled to
    // the full 8K fleet so mission arrivals match the paper's census.
    FaultGen::new(
        domains,
        afr,
        FaultGenConfig {
            npu_fleet_afr: FLEET as f64 * NPU_AFR_PER_UNIT,
            rack_power_afr: RACK_POWER_AFR * RACKS as f64,
            ..FaultGenConfig::default()
        },
    )
}

fn abort_rate_per_year(gen: &FaultGen, costs: &ClassCosts) -> f64 {
    BlastClass::ALL
        .iter()
        .map(|&c| gen.rates.of(c) * costs.abort_fraction(c))
        .sum()
}

fn main() {
    let mut json = JsonReport::new();

    // --- censuses + Eq. 3 closed forms (the Table 6 numbers) ------------
    let ub_afr = afr_of_capex(&capex_ubmesh(&SuperPodConfig::default()));
    let clos_afr = afr_of_capex(&capex_full_clos("x64T Clos", FLEET, 64));
    let ub_cf = availability(mtbf_hours(ub_afr.total()), mttr::BASELINE_HOURS);
    let clos_cf = availability(mtbf_hours(clos_afr.total()), mttr::BASELINE_HOURS);
    json.metric("avail.ub.afr_total", ub_afr.total());
    json.metric("avail.clos.afr_total", clos_afr.total());
    json.metric("avail.ub.closed_form", ub_cf);
    json.metric("avail.clos.closed_form", clos_cf);
    json.metric("avail.closed_form.delta", ub_cf - clos_cf);
    println!(
        "closed form (Eq. 3, flat {:.0}-min MTTR): UB-Mesh {} vs Clos {} → +{}",
        mttr::BASELINE_HOURS * 60.0,
        pct(ub_cf, 1),
        pct(clos_cf, 1),
        pct(ub_cf - clos_cf, 1)
    );

    // --- the measured training iteration on both rack fabrics ----------
    let m = by_name("llama-70b").unwrap();
    let p = ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 1,
        pp: 1,
        dp: 1,
        microbatches: 2,
        tokens_per_microbatch: 8192.0,
    };
    let spec = IterationSpec::default();

    let (mut ub_t, ub_h) = ubmesh_rack(&RackConfig::default());
    let storage = add_dcn_layer(
        &mut ub_t,
        std::slice::from_ref(&ub_h),
        2,
        DcnAttach::UbSwitch { lanes_per_rack: 8 },
    );
    let ub_map = std::sync::Arc::new(ClusterMap::rack(&ub_h));
    let ub_dag = iteration_dag(&ub_t, &ub_map, &m, &p, RankOrder::TopologyAware, &spec);

    let (cl_t, cl_h) = rack_clos();
    let cl_map = std::sync::Arc::new(ClusterMap::clos_rack(&cl_h));
    let cl_dag = iteration_dag(&cl_t, &cl_map, &m, &p, RankOrder::TopologyAware, &spec);

    // --- checkpoint economics as real DCN flows -------------------------
    // Fleet-sharded state: every rank owns params × 18 B / 8192. The
    // write and read-back contend for the rack's 8 DCN uplink lanes —
    // the measured makespan, not a per-rank bandwidth guess, prices W.
    let fleet_p = ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 1,
        pp: 128,
        dp: 1,
        microbatches: 2,
        tokens_per_microbatch: 8192.0,
    };
    let bytes_per_rank = state_bytes_per_rank(&m, &fleet_p);
    let ub_net = SimNet::new(&ub_t);
    let write_dag = checkpoint_flow_dag(&ub_t, &ub_map, &storage, bytes_per_rank, true);
    let write_run = sim::schedule::run(&ub_net, &write_dag);
    assert!(!write_run.is_stalled());
    let write_hours = write_run.makespan_us / 3.6e9;

    let healthy_iter = sim::schedule::run(&ub_net, &ub_dag);
    assert!(!healthy_iter.is_stalled());
    let restart_dag = iteration_with_readmission(
        &ub_t, &ub_map, &m, &p, RankOrder::TopologyAware, &spec, &storage, bytes_per_rank,
    );
    let restart_run = sim::schedule::run(&ub_net, &restart_dag);
    assert!(!restart_run.is_stalled());
    // The readmission surcharge: first-iteration-after-restart minus a
    // normal iteration, plus the scheduler floor.
    let readmission_hours =
        (restart_run.makespan_us - healthy_iter.makespan_us).max(0.0) / 3.6e9;
    let restart_hours = SCHEDULER_RESTART_HOURS + readmission_hours;
    json.metric("avail.ckpt.state_bytes_per_rank", bytes_per_rank);
    json.metric("avail.ckpt.write_hours", write_hours);
    json.metric("avail.ckpt.readmission_hours", readmission_hours);
    json.metric("avail.ckpt.restart_hours", restart_hours);
    println!(
        "\ncheckpoint flows: {:.0} MB/rank, write {:.2} s (measured over 8 DCN lanes), \
         restart readmission +{:.2} s on the first iteration",
        bytes_per_rank / 1e6,
        write_hours * 3600.0,
        readmission_hours * 3600.0
    );

    // --- measured per-class costs: blast radii replayed in the DES -----
    let mcfg = MeasureConfig {
        trials_per_class: 4,
        ..MeasureConfig::default()
    };
    let ub_gen = fleet_gen(FaultDomains::rack(&ub_t, &ub_h), &ub_afr);
    let cl_gen = fleet_gen(FaultDomains::flat(&cl_t, &cl_h.npus, &cl_h.hrs), &clos_afr);
    let ub_costs = measured_class_costs(
        &ub_t,
        &ub_gen,
        &ub_dag,
        &RecoveryConfig::direct(),
        None,
        &mcfg,
        11,
    );
    let cl_costs = measured_class_costs(
        &cl_t,
        &cl_gen,
        &cl_dag,
        &RecoveryConfig::direct(),
        None,
        &mcfg,
        13,
    );

    let mut tbl = Table::with_title(
        "measured blast-radius outcomes (fraction aborting | mean slowdown)",
        vec!["class", "UB-Mesh", "Clos"],
    );
    for c in BlastClass::ALL {
        tbl.row(vec![
            c.label().into(),
            format!(
                "{} | {}",
                fmt(ub_costs.abort_fraction(c), 2),
                pct(ub_costs.mean_slowdown(c), 1)
            ),
            format!(
                "{} | {}",
                fmt(cl_costs.abort_fraction(c), 2),
                pct(cl_costs.mean_slowdown(c), 1)
            ),
        ]);
    }
    tbl.print();
    // The architectural asymmetry the closed form can't see: the 64+1
    // backup absorbs UB-Mesh NPU deaths, the Clos rack has no backup.
    assert_eq!(ub_costs.abort_fraction(BlastClass::NpuDeath), 0.0);
    assert_eq!(cl_costs.abort_fraction(BlastClass::NpuDeath), 1.0);
    assert_eq!(ub_costs.abort_fraction(BlastClass::SingleLink), 0.0);
    assert_eq!(cl_costs.abort_fraction(BlastClass::SingleLink), 0.0);

    let ub_abort_yr = abort_rate_per_year(&ub_gen, &ub_costs);
    let cl_abort_yr = abort_rate_per_year(&cl_gen, &cl_costs);
    json.metric("avail.ub.abort_per_year", ub_abort_yr);
    json.metric("avail.clos.abort_per_year", cl_abort_yr);

    // --- checkpoint-interval sweep (Clos: abort-dominated, the classic
    // optimum) — common random numbers across intervals, so the curve is
    // noise-free in the interval and the interior optimum is exact.
    let mission = MissionConfig::default();
    let cl_young = young_optimum_hours(write_hours, HOURS_PER_YEAR / cl_abort_yr);
    let intervals = [0.01, 0.02, 0.04, 0.08, 0.16, 0.32, 0.64, 1.28];
    let mut best = (0usize, f64::MIN);
    let mut tbl = Table::with_title(
        "checkpoint-interval sweep (Clos fleet, measured costs, CRN seed)",
        vec!["interval (h)", "effective training time"],
    );
    for (i, &t_h) in intervals.iter().enumerate() {
        let ck = CheckpointConfig::new(t_h, write_hours, restart_hours);
        let r = measured_availability(&cl_gen, &cl_costs, &ck, &mission, 96, 2026);
        let eff = r.effective.mean();
        if eff > best.1 {
            best = (i, eff);
        }
        tbl.row(vec![fmt(t_h, 2), pct(eff, 2)]);
    }
    tbl.print();
    let interior = best.0 > 0 && best.0 + 1 < intervals.len();
    println!(
        "optimum {} h (Young/Daly closed form: {} h) — interior: {interior}",
        fmt(intervals[best.0], 2),
        fmt(cl_young, 3)
    );
    assert!(interior, "sweep optimum pinned to a grid end");
    assert!(
        intervals[best.0] >= cl_young / 4.0 && intervals[best.0] <= cl_young * 4.0,
        "grid optimum {} vs Young {}",
        intervals[best.0],
        cl_young
    );
    json.metric("avail.ckpt.optimal_interval_hours", intervals[best.0]);
    json.metric("avail.ckpt.young_optimum_hours", cl_young);
    json.metric("avail.ckpt.best_effective", best.1);
    json.metric("avail.ckpt.interior", f64::from(interior));

    // --- differential oracle: the uncorrelated limit must reproduce
    // Eq. 3 (network-only rates, flat MTTR, no aborts, no checkpoint
    // overhead). This is the measured-vs-closed-form boundary: beyond
    // it, APR absorption and abort economics move the answer.
    let oracle_gen = FaultGen::new(
        FaultDomains::rack(&ub_t, &ub_h),
        &ub_afr,
        FaultGenConfig {
            npu_fleet_afr: 0.0,
            rack_power_afr: 0.0,
            ..FaultGenConfig::default()
        },
    );
    let oracle_costs = ClassCosts::uncorrelated_limit(mttr::BASELINE_HOURS);
    let no_ckpt = CheckpointConfig::new(1e12, 0.0, 0.0);
    let oracle = measured_availability(&oracle_gen, &oracle_costs, &no_ckpt, &mission, 256, 7);
    let oracle_err = (oracle.availability.mean() - ub_cf).abs();
    println!(
        "\ndifferential oracle (uncorrelated limit): measured {} vs Eq. 3 {} \
         (|err| = {:.4})",
        pct(oracle.availability.mean(), 2),
        pct(ub_cf, 2),
        oracle_err
    );
    json.metric("avail.oracle.measured_uncorrelated", oracle.availability.mean());
    json.metric("avail.oracle.closed_form", ub_cf);
    json.metric("avail.oracle.abs_err", oracle_err);
    assert!(oracle_err < 0.01, "oracle drift: {oracle_err}");

    // --- mission-length measured availability, UB-Mesh vs Clos ----------
    let ub_ck = CheckpointConfig::new(
        young_optimum_hours(write_hours, HOURS_PER_YEAR / ub_abort_yr),
        write_hours,
        restart_hours,
    );
    let cl_ck = CheckpointConfig::new(intervals[best.0], write_hours, restart_hours);
    let ub_m = measured_availability(&ub_gen, &ub_costs, &ub_ck, &mission, 256, 21);
    let cl_m = measured_availability(&cl_gen, &cl_costs, &cl_ck, &mission, 256, 22);
    let delta = ub_m.availability.mean() - cl_m.availability.mean();
    let eff_delta = ub_m.effective.mean() - cl_m.effective.mean();

    let mut tbl = Table::with_title(
        "measured mission availability (720 h, correlated faults, measured costs)",
        vec!["arch", "avail p50", "avail p99", "effective p50", "aborts"],
    );
    for (name, r) in [("UB-Mesh", &ub_m), ("Clos", &cl_m)] {
        tbl.row(vec![
            name.into(),
            pct(r.availability.p50(), 2),
            pct(r.availability.p99(), 2),
            pct(r.effective.p50(), 2),
            format!("{}", r.aborts),
        ]);
    }
    tbl.print();
    println!(
        "measured delta: availability +{} (closed form says +{}), \
         effective training time +{}",
        pct(delta, 2),
        pct(ub_cf - clos_cf, 1),
        pct(eff_delta, 2)
    );
    json.metric("avail.ub.measured_p50", ub_m.availability.p50());
    json.metric("avail.ub.measured_p99", ub_m.availability.p99());
    json.metric("avail.ub.effective_p50", ub_m.effective.p50());
    json.metric("avail.clos.measured_p50", cl_m.availability.p50());
    json.metric("avail.clos.measured_p99", cl_m.availability.p99());
    json.metric("avail.clos.effective_p50", cl_m.effective.p50());
    json.metric("avail.ubmesh_minus_clos", delta);
    json.metric("avail.effective.ubmesh_minus_clos_fleet", eff_delta);
    // The measured experiment *confirms the sign* of the paper's +7.2%
    // but attributes it differently: APR + 64+1 absorb most UB-Mesh
    // failures into degraded-mode slowdown (availability stays near
    // 100%), while the backup-less Clos fleet aborts on every NPU death
    // and pays restart + lost work. The closed form's flat-MTTR
    // arithmetic overstates both architectures' downtime — the
    // availability gap survives (asserted), while the fleet-scale
    // effective-time delta stays *unasserted* (emitted under the
    // `_fleet` suffix): it hinges on the measured degraded-mode slowdown
    // of backup substitution, which frequent cheap checkpointing on the
    // Clos side can out-compete. The asserted effective-time headline
    // moved to the repair-aware policy tournament below, whose arena
    // economics make the comparison sign-stable.
    assert!(delta > 0.0, "measured UB-Mesh delta must stay positive");
    assert!(eff_delta.is_finite());

    // --- recovery-policy tournament: abort vs swap vs elastic shrink ----
    // One self-contained 64-NPU arena per architecture at DP = 4
    // (tp8·sp2·dp4), with the arena's *own* census — 64 NPUs' worth of
    // compute AFR, one rack power domain, 1/128th of the fleet's network
    // AFR — instead of fleet-scaled rates. Failures are rare, so the
    // Young interval stretches and every abort forfeits hours of work:
    // exactly the regime where graceful degradation has to earn its keep.
    // Policies see identical sampled blast radii (the classification rng
    // never draws), so the tournament isolates the policy decision.
    let p4 = ParallelismConfig {
        tp: 8,
        sp: 2,
        ep: 1,
        pp: 1,
        dp: 4,
        microbatches: 2,
        tokens_per_microbatch: 2048.0,
    };
    let arena_share = |a: &AfrBreakdown| AfrBreakdown {
        electrical_cables: a.electrical_cables / RACKS as f64,
        optical: a.optical / RACKS as f64,
        lrs: a.lrs / RACKS as f64,
        hrs: a.hrs / RACKS as f64,
    };
    let arena_gen = |domains: FaultDomains, afr: &AfrBreakdown| {
        FaultGen::new(
            domains,
            afr,
            FaultGenConfig {
                npu_fleet_afr: 64.0 * NPU_AFR_PER_UNIT,
                rack_power_afr: RACK_POWER_AFR,
                ..FaultGenConfig::default()
            },
        )
    };
    let ub_gen4 = arena_gen(FaultDomains::rack(&ub_t, &ub_h), &arena_share(&ub_afr));
    let cl_gen4 = arena_gen(
        FaultDomains::flat(&cl_t, &cl_h.npus, &cl_h.hrs),
        &arena_share(&clos_afr),
    );
    let ub_dag4 = iteration_dag(&ub_t, &ub_map, &m, &p4, RankOrder::TopologyAware, &spec);
    let cl_dag4 = iteration_dag(&cl_t, &cl_map, &m, &p4, RankOrder::TopologyAware, &spec);
    let ub_rm = ReplicaMap::new(&ub_map, &p4, RankOrder::TopologyAware);
    let cl_rm = ReplicaMap::new(&cl_map, &p4, RankOrder::TopologyAware);

    // DP = 4 checkpoint economics: only one replica writes, so each rank
    // ships a 1/dp shard — one full state copy on the wire. Write and
    // read-back are measured on the UB arena's DCN lanes and shared with
    // the Clos arena (which carries no storage fabric of its own).
    let bytes4 = state_bytes_per_rank(&m, &p4);
    let shard4 = bytes4 / p4.dp as f64;
    let write4 = sim::schedule::run(
        &ub_net,
        &checkpoint_flow_dag(&ub_t, &ub_map, &storage, shard4, true),
    );
    assert!(!write4.is_stalled());
    let write4_hours = write4.makespan_us / 3.6e9;
    let healthy4 = sim::schedule::run(&ub_net, &ub_dag4);
    assert!(!healthy4.is_stalled());
    let restart4 = sim::schedule::run(
        &ub_net,
        &iteration_with_readmission(
            &ub_t, &ub_map, &m, &p4, RankOrder::TopologyAware, &spec, &storage, shard4,
        ),
    );
    assert!(!restart4.is_stalled());
    let restart4_hours = SCHEDULER_RESTART_HOURS
        + (restart4.makespan_us - healthy4.makespan_us).max(0.0) / 3.6e9;

    // Elastic-shrink prices from the real shrink-path DAGs: UB re-shards
    // from DCN storage, the storage-less Clos arena fetches from
    // surviving DP peers.
    let ub_sc = measured_shrink_costs(
        &ub_t, &ub_map, &m, &p4, RankOrder::TopologyAware, &spec, &storage, bytes4,
    );
    let cl_sc = measured_shrink_costs(
        &cl_t, &cl_map, &m, &p4, RankOrder::TopologyAware, &spec, &[], bytes4,
    );
    let ub_mission = MissionConfig {
        mission_hours: 720.0,
        repair: RepairConfig::field_default(),
        shrink: Some(ub_sc),
    };
    let cl_mission = MissionConfig {
        mission_hours: 720.0,
        repair: RepairConfig::field_default(),
        shrink: Some(cl_sc),
    };
    json.metric("avail.policy.write_hours", write4_hours);
    json.metric("avail.policy.restart_hours", restart4_hours);
    json.metric("avail.policy.ub.degraded_loss", ub_sc.degraded_loss);
    json.metric("avail.policy.clos.degraded_loss", cl_sc.degraded_loss);

    let mut tbl = Table::with_title(
        "recovery-policy tournament (DP=4 arenas, repair-aware 720 h missions)",
        vec!["arch · policy", "avail", "effective", "aborts", "shrinks"],
    );
    let mut run_policy = |arch: &str,
                          label: &str,
                          t: &Topology,
                          gen: &FaultGen,
                          dag: &StageDag,
                          rm: &ReplicaMap,
                          mission: &MissionConfig,
                          cost_seed: u64,
                          mission_seed: u64,
                          policy: RecoveryPolicy|
     -> MeasuredAvailability {
        let costs = measured_class_costs(
            t,
            gen,
            dag,
            &RecoveryConfig::direct(),
            Some(rm),
            &MeasureConfig {
                trials_per_class: 4,
                policy,
                ..MeasureConfig::default()
            },
            cost_seed,
        );
        // Each policy checkpoints at its own Young optimum — the rack
        // power domain always aborts, so the rate is never zero and the
        // interval stays finite.
        let abort_yr = abort_rate_per_year(gen, &costs);
        let ck = CheckpointConfig::new(
            young_optimum_hours(write4_hours, HOURS_PER_YEAR / abort_yr),
            write4_hours,
            restart4_hours,
        );
        let r = measured_availability(gen, &costs, &ck, mission, 512, mission_seed);
        tbl.row(vec![
            format!("{arch} · {label}"),
            pct(r.availability.mean(), 3),
            pct(r.effective.mean(), 3),
            format!("{}", r.aborts),
            format!("{}", r.shrinks),
        ]);
        json.metric(format!("avail.policy.{arch}.{label}_avail"), r.availability.mean());
        json.metric(format!("avail.policy.{arch}.{label}_eff"), r.effective.mean());
        json.metric(format!("avail.policy.{arch}.{label}_shrinks"), r.shrinks as f64);
        r
    };
    // Same cost seed per arch (identical blast radii across policies),
    // same mission seed per arch (identical arrival skeleton).
    let ub_abort = run_policy(
        "ub", "abort", &ub_t, &ub_gen4, &ub_dag4, &ub_rm, &ub_mission, 31, 41,
        RecoveryPolicy::AbortToCheckpoint,
    );
    let ub_swap = run_policy(
        "ub", "swap", &ub_t, &ub_gen4, &ub_dag4, &ub_rm, &ub_mission, 31, 41,
        RecoveryPolicy::BackupSwap,
    );
    let ub_elastic = run_policy(
        "ub", "elastic", &ub_t, &ub_gen4, &ub_dag4, &ub_rm, &ub_mission, 31, 41,
        RecoveryPolicy::ElasticShrink,
    );
    let cl_abort = run_policy(
        "clos", "abort", &cl_t, &cl_gen4, &cl_dag4, &cl_rm, &cl_mission, 32, 42,
        RecoveryPolicy::AbortToCheckpoint,
    );
    let cl_swap = run_policy(
        "clos", "swap", &cl_t, &cl_gen4, &cl_dag4, &cl_rm, &cl_mission, 32, 42,
        RecoveryPolicy::BackupSwap,
    );
    let cl_elastic = run_policy(
        "clos", "elastic", &cl_t, &cl_gen4, &cl_dag4, &cl_rm, &cl_mission, 32, 42,
        RecoveryPolicy::ElasticShrink,
    );
    tbl.print();
    // Two grid cells are degenerate by construction, and that is the
    // finding, not a bug: Clos swap ≈ Clos abort (no 64+1 backup to
    // swap in), and UB elastic ≈ UB swap (the backup absorbs NPU deaths
    // before the shrink path is ever consulted).
    println!(
        "tournament: clos elastic {} vs clos abort {} — graceful degradation \
         is worth +{} effective on the backup-less arena",
        pct(cl_elastic.effective.mean(), 3),
        pct(cl_abort.effective.mean(), 3),
        pct(cl_elastic.effective.mean() - cl_abort.effective.mean(), 3)
    );
    // The headline of the tentpole: on the arena where aborting is the
    // only alternative, shrinking to DP−1 strictly beats rewinding.
    assert!(
        cl_elastic.effective.mean() > cl_abort.effective.mean(),
        "elastic shrink must beat abort-to-checkpoint on the Clos arena: {} vs {}",
        cl_elastic.effective.mean(),
        cl_abort.effective.mean()
    );
    assert!(
        cl_elastic.shrinks > 0,
        "tournament never exercised the shrink path"
    );
    assert!(cl_elastic.aborts < cl_abort.aborts);
    // UB's backup swap should not lose to its own abort policy either.
    assert!(ub_swap.effective.mean() >= ub_abort.effective.mean());
    let _ = &ub_elastic;
    // The now-asserted effective-time headline: both architectures under
    // their PR 7 default policy (BackupSwap), same repair economics —
    // the 64+1 backup plus APR absorption is the architectural delta.
    let policy_eff_delta = ub_swap.effective.mean() - cl_swap.effective.mean();
    json.metric("avail.effective.ubmesh_minus_clos", policy_eff_delta);
    println!(
        "repair-aware effective-time delta (BackupSwap vs BackupSwap): +{}",
        pct(policy_eff_delta, 3)
    );
    assert!(
        policy_eff_delta > 0.0,
        "UB-Mesh must beat Clos on repair-aware effective training time: {policy_eff_delta}"
    );

    // --- link-flap damping: hysteresis vs raw shortest-path reroute -----
    // A 5-node full mesh with one long-lived flow on the direct 0→1
    // link. Link A (0–1) flaps six 100/100 µs cycles from t=100; link B
    // (0–2, the first detour's first hop) flaps six 80/120 µs cycles
    // nested inside A's up-windows from t=210. The raw Shortest policy
    // chases every transition — direct ↔ via-2 ping-pong, two reroutes
    // per cycle — while the hysteresis window steers the second reroute
    // onto the never-flapped via-3 detour and stays there.
    let ft = nd_fullmesh(
        "flap",
        &[DimSpec::new(5, 4, CableClass::PassiveElectrical, 0.3)],
    );
    let (n0, n1, n2) = (NodeId(0), NodeId(1), NodeId(2));
    let fdag = StageDag::chain(vec![Stage::new("payload")
        .with_flows(vec![FlowSpec::along(&ft, &[n0, n1], 64e6)])]);
    let link_a = ft.link_between(n0, n1).expect("direct 0–1 link");
    let link_b = ft.link_between(n0, n2).expect("detour 0–2 link");
    let fnet = SimNet::new(&ft);
    let run_flap = |rc: RecoveryConfig| {
        let plan = FaultPlan::new()
            .flap_train(link_a, 100.0, 6, 100.0, 100.0)
            .flap_train(link_b, 210.0, 6, 80.0, 120.0)
            .with_recovery(rc);
        sim::schedule::run_faulted(&fnet, &fdag, &sim::SimConfig::default(), &plan)
    };
    let flap_raw = run_flap(RecoveryConfig::direct());
    let flap_damped = run_flap(RecoveryConfig::direct().with_flap_damping(10_000.0));
    assert!(!flap_raw.is_stalled() && !flap_damped.is_stalled());
    println!(
        "\nflap damping: {} reroutes → {} (makespan {} µs → {} µs)",
        flap_raw.reroutes,
        flap_damped.reroutes,
        fmt(flap_raw.makespan_us, 1),
        fmt(flap_damped.makespan_us, 1)
    );
    // Damping must still reroute (it is advisory, not a freeze) …
    assert!(flap_damped.reroutes >= 1);
    // … but at least halve the flap-chasing (the observed split is
    // 12 vs 2) without costing makespan: fewer reroutes means fewer
    // convergence stalls, so the damped run finishes no later.
    assert!(
        flap_raw.reroutes >= 2 * flap_damped.reroutes,
        "damping must at least halve reroutes: {} vs {}",
        flap_raw.reroutes,
        flap_damped.reroutes
    );
    assert!(
        flap_damped.makespan_us <= flap_raw.makespan_us * (1.0 + 1e-9),
        "damping must not cost makespan: {} vs {}",
        flap_damped.makespan_us,
        flap_raw.makespan_us
    );
    json.metric("flap.reroutes_raw", flap_raw.reroutes as f64);
    json.metric("flap.reroutes_damped", flap_damped.reroutes as f64);
    json.metric("flap.makespan_raw_us", flap_raw.makespan_us);
    json.metric("flap.makespan_damped_us", flap_damped.makespan_us);
    json.metric(
        "flap.reroute_reduction",
        flap_raw.reroutes as f64 / (flap_damped.reroutes as f64).max(1.0),
    );

    let path = std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_avail.json".into());
    match json.write(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
    println!("\ntable6_availability OK");
}
