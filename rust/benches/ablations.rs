//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * A1 — topology-aware vs naive parallelism placement (§5.2);
//! * A2 — multi-ring count k ∈ {1, 2, 3} (Fig 13 / Walecki budget);
//! * A3 — 64+1 backup vs masking the failed NPU (§3.3.2);
//! * A4 — CCU compute-communication overlap on vs off (§7);
//! * A5 — DCN attach Solution-(a) UB-switch vs Solution-(b) CPU-NIC
//!   (§3.3.4).

use ubmesh::collectives::ring::{fullmesh_rings, multiring_allreduce_dag, ring_allreduce_dag};
use ubmesh::reliability::backup::{fail_npu, masked_compute_fraction, ranks_with_backup};
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::dcn::DcnAttach;
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::NodeId;
use ubmesh::util::table::{fmt, pct, Table};
use ubmesh::workload::models::by_name;
use ubmesh::workload::placement::{Placement, TierBandwidth};
use ubmesh::workload::step::iteration_time;
use ubmesh::workload::traffic::table1_config;

fn main() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let m = by_name("gpt4-2t").unwrap();
    let p = table1_config();
    let bw = TierBandwidth::ubmesh(16, 1.6);

    // --- A1: placement --------------------------------------------------
    let aware = iteration_time(&m, &p, &Placement::topology_aware(&p), &bw);
    let naive = iteration_time(&m, &p, &Placement::naive(&p), &bw);
    let mut tbl = Table::with_title(
        "A1: parallelism placement (gpt4-2t, Table-1 config)",
        vec!["placement", "iter (ms)", "comm (ms)", "vs aware"],
    );
    for (name, it) in [("topology-aware", &aware), ("naive (PP innermost)", &naive)] {
        tbl.row(vec![
            name.into(),
            fmt(it.total_us / 1e3, 1),
            fmt(it.comm_us() / 1e3, 1),
            format!("{:.2}x", it.total_us / aware.total_us),
        ]);
    }
    tbl.print();
    assert!(naive.total_us > aware.total_us);

    // --- A2: multi-ring count -------------------------------------------
    let board: Vec<NodeId> = (0..8).map(|s| h.npu(0, s, 8)).collect();
    let net = SimNet::new(&t);
    let bytes = 360e6;
    let mut tbl = Table::with_title(
        "A2: ring count (board AllReduce, 360 MB)",
        vec!["rings", "time (µs)", "speedup"],
    );
    let single = sim::schedule::run(&net, &ring_allreduce_dag(&t, &board, bytes));
    tbl.row(vec!["1".into(), fmt(single.makespan_us, 1), "1.00x".into()]);
    let mut last = single.makespan_us;
    for k in [2usize, 3] {
        let rings = fullmesh_rings(&board, k);
        let w = vec![1.0; k];
        let r = sim::schedule::run(&net, &multiring_allreduce_dag(&t, &rings, &w, bytes));
        tbl.row(vec![
            format!("{k}"),
            fmt(r.makespan_us, 1),
            format!("{:.2}x", single.makespan_us / r.makespan_us),
        ]);
        assert!(r.makespan_us < last, "more rings must help");
        last = r.makespan_us;
    }
    tbl.print();

    // --- A3: backup vs masking -------------------------------------------
    let failed = board[3];
    let mut net2 = SimNet::new(&t);
    fail_npu(&mut net2, &t, failed);
    let backup_ring: Vec<NodeId> = board
        .iter()
        .map(|&n| if n == failed { h.backup.unwrap() } else { n })
        .collect();
    let fo = sim::schedule::run(&net2, &ring_allreduce_dag(&t, &backup_ring, bytes));
    let _ = ranks_with_backup(&h, failed);
    let healthy = single.makespan_us;
    let mut tbl = Table::with_title(
        "A3: failure handling (board AllReduce + compute capacity)",
        vec!["strategy", "allreduce µs", "compute", "effective throughput"],
    );
    tbl.row(vec![
        "healthy".into(),
        fmt(healthy, 1),
        "100%".into(),
        "1.00x".into(),
    ]);
    let slowdown = fo.makespan_us / healthy;
    tbl.row(vec![
        "64+1 backup (Fig 9)".into(),
        fmt(fo.makespan_us, 1),
        "100%".into(),
        format!("{:.2}x", 1.0 / slowdown.max(1.0)),
    ]);
    tbl.row(vec![
        "mask NPU".into(),
        "-".into(),
        pct(masked_compute_fraction(), 1),
        format!("{:.2}x", masked_compute_fraction()),
    ]);
    tbl.print();
    assert!(1.0 / slowdown > masked_compute_fraction(), "backup must win");

    // --- A4: CCU overlap ---------------------------------------------------
    // Overlap is a compile-time constant; emulate "off" by scaling the
    // exposed comm back up.
    let exposed_on = aware.tp_us + aware.sp_us + aware.ep_us;
    let exposed_off = exposed_on / (1.0 - ubmesh::workload::step::CCU_OVERLAP);
    let total_off = aware.total_us - exposed_on + exposed_off;
    let mut tbl = Table::with_title(
        "A4: CCU compute-communication overlap (§7)",
        vec!["CCU", "iter (ms)", "delta"],
    );
    tbl.row(vec![
        "on (65% hidden)".into(),
        fmt(aware.total_us / 1e3, 1),
        "-".into(),
    ]);
    tbl.row(vec![
        "off".into(),
        fmt(total_off / 1e3, 1),
        pct(total_off / aware.total_us - 1.0, 1),
    ]);
    tbl.print();
    assert!(total_off > aware.total_us);

    // --- A5: DCN attach ------------------------------------------------------
    let a = DcnAttach::UbSwitch { lanes_per_rack: 8 };
    let b = DcnAttach::CpuNic { nic_gb_s: 12.5 };
    let mut tbl = Table::with_title(
        "A5: DCN attach (per-NPU DP bandwidth beyond the SuperPod)",
        vec!["solution", "GB/s per NPU", "UB lanes consumed/rack"],
    );
    tbl.row(vec![
        "(a) UB switch".into(),
        fmt(a.per_npu_gb_s(4), 2),
        "8".into(),
    ]);
    tbl.row(vec![
        "(b) CPU NICs".into(),
        fmt(b.per_npu_gb_s(4), 2),
        "0".into(),
    ]);
    tbl.print();

    println!("\nablations OK");
}
