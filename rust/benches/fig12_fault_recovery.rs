//! Fig 12 — From Hop-by-hop to Direct Notification: routing-convergence
//! latency after a link failure, swept over topology scale.

use ubmesh::routing::apr::{paths_2d, to_routed};
use ubmesh::routing::failure::{
    affected_sources, direct_notification_convergence_us, hop_by_hop_convergence_us,
    RecoveryModel,
};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, NodeId};
use ubmesh::util::table::{fmt, Table};

fn main() {
    let m = RecoveryModel::default();
    let mut tbl = Table::with_title(
        "Fig 12: convergence after a link failure (µs)",
        vec!["mesh", "affected", "hop-by-hop", "direct", "speedup"],
    );
    for n in [4usize, 8, 16] {
        let t = nd_fullmesh(
            "g",
            &[
                DimSpec::new(n, 4, CableClass::PassiveElectrical, 0.3),
                DimSpec::new(n, 4, CableClass::PassiveElectrical, 1.0),
            ],
        );
        let node = |x: usize, y: usize| NodeId((y * n + x) as u32);
        let mut paths = Vec::new();
        for s in 0..(n * n) {
            for d in 0..(n * n) {
                if s != d {
                    for mp in paths_2d((s % n, s / n), (d % n, d / n), n, n, true) {
                        paths.push(to_routed(&mp, node));
                    }
                }
            }
        }
        let failed = t.link_between(node(0, 0), node(1, 0)).unwrap();
        let affected = affected_sources(&t, &paths, failed);
        let slow = hop_by_hop_convergence_us(&t, failed, &affected, &m);
        let fast = direct_notification_convergence_us(&t, failed, &affected, &m);
        tbl.row(vec![
            format!("{n}x{n} 2D-FM"),
            format!("{}", affected.len()),
            fmt(slow, 1),
            fmt(fast, 1),
            format!("{:.2}x", slow / fast),
        ]);
        assert!(fast < slow);
    }
    tbl.print();
    println!(
        "\ndirect notification removes the per-hop protocol processing \
         (\"the control plane overhead can be greatly reduced\", §4.2)"
    );
    println!("\nfig12_fault_recovery OK");
}
