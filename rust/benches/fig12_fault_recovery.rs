//! Fig 12 — From Hop-by-hop to Direct Notification, **measured
//! end-to-end** (PR 4).
//!
//! The PR 2 version of this bench evaluated the closed-form convergence
//! latencies only. Now the fault is *injected mid-collective* through a
//! `sim::fault::FaultPlan` and the cost is the measured makespan
//! degradation, in two regimes (mirror-validated; the reference port
//! reproduces every number below):
//!
//! * **absorbed** — a detour-routed all-to-all loses a link at 40% of
//!   its makespan: APR re-selection lands the cut flows in network
//!   slack and the measured degradation is ~0 under *both* notification
//!   modes — the nD-FullMesh resilience the paper's availability claim
//!   leans on (a single link failure costs bandwidth, not completion
//!   time, as long as slack exists).
//! * **tail** — a translation-symmetric 4-hop "snake" cohort (every
//!   +1-step channel equally loaded, every flow finishing together)
//!   loses a link at 85% of its makespan: the rerouted flows gate the
//!   finish, so the recovery latency lands 1:1 in the makespan and the
//!   measured hop-by-hop − direct gap equals the analytic convergence
//!   gap **exactly** — Fig 12's comparison, end to end.
//!
//! Both regimes also measure the naive stall-until-restore bound
//! (no recovery, restore at 2.5× the healthy makespan), and a
//! Monte-Carlo sweep (`reliability::montecarlo::measured_fault_cost`)
//! samples random (link, time) fault plans.
//!
//! Emits `fault.*` metrics in the `ubmesh.bench_sim.v1` schema (path
//! override: `BENCH_SIM_JSON`, default `BENCH_sim.json` — CI points it
//! at `BENCH_fault.json` next to perf_hotpaths' file).

use ubmesh::reliability::montecarlo::measured_fault_cost;
use ubmesh::routing::apr::{PathKind, RoutedPath};
use ubmesh::routing::failure::{
    affected_sources, direct_notification_convergence_us, hop_by_hop_convergence_us,
    RecoveryModel,
};
use ubmesh::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
use ubmesh::sim::{self, FlowSpec, GridBuilder, OnlineStats, SimConfig, SimNet, Stage, StageDag};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, NodeId, Topology};
use ubmesh::util::bench::JsonReport;
use ubmesh::util::table::{fmt, Table};

fn mesh(n: usize) -> Topology {
    nd_fullmesh(
        "g",
        &[
            DimSpec::new(n, 4, CableClass::PassiveElectrical, 0.3),
            DimSpec::new(n, 4, CableClass::PassiveElectrical, 1.0),
        ],
    )
}

fn routed(nodes: Vec<NodeId>) -> RoutedPath {
    RoutedPath {
        nodes,
        kind: PathKind::Detour,
        dims: Vec::new(),
    }
}

/// Absorbed-regime workload: aligned pairs direct, unaligned pairs on a
/// 3-hop Y,X,Y loop via row `(sy + 1) % n` (skipping the destination
/// row).
fn detour_exchange(t: &Topology, n: usize, bytes: f64) -> (StageDag, Vec<RoutedPath>) {
    let node = |x: usize, y: usize| NodeId((y * n + x) as u32);
    let mut flows = Vec::new();
    let mut paths = Vec::new();
    for sy in 0..n {
        for sx in 0..n {
            for dy in 0..n {
                for dx in 0..n {
                    if (sx, sy) == (dx, dy) {
                        continue;
                    }
                    let route: Vec<NodeId> = if sx == dx || sy == dy {
                        vec![node(sx, sy), node(dx, dy)]
                    } else {
                        let mut y3 = (sy + 1) % n;
                        if y3 == dy {
                            y3 = (y3 + 1) % n;
                        }
                        vec![node(sx, sy), node(sx, y3), node(dx, y3), node(dx, dy)]
                    };
                    flows.push(FlowSpec::along(t, &route, bytes));
                    paths.push(routed(route));
                }
            }
        }
    }
    let mut dag = StageDag::default();
    dag.push(Stage::new("detour-exchange").with_flows(flows));
    (dag, paths)
}

/// Tail-regime workload: one 4-hop +1-step "snake" per node —
/// translation-invariant, so every +1 row/column channel carries
/// exactly two crossings and the whole cohort finishes together. A cut
/// flow's 2-hop reroute lands on idle step-2 channels, so its restart
/// time (= the notification convergence) gates the makespan 1:1.
fn snake_exchange(t: &Topology, n: usize, bytes: f64) -> (StageDag, Vec<RoutedPath>) {
    let node = |x: usize, y: usize| NodeId((y * n + x) as u32);
    let mut flows = Vec::new();
    let mut paths = Vec::new();
    for sy in 0..n {
        for sx in 0..n {
            let route = vec![
                node(sx, sy),
                node((sx + 1) % n, sy),
                node((sx + 1) % n, (sy + 1) % n),
                node((sx + 2) % n, (sy + 1) % n),
                node((sx + 2) % n, (sy + 2) % n),
            ];
            flows.push(FlowSpec::along(t, &route, bytes));
            paths.push(routed(route));
        }
    }
    let mut dag = StageDag::default();
    dag.push(Stage::new("snake-exchange").with_flows(flows));
    (dag, paths)
}

struct SiteRow {
    n: usize,
    regime: &'static str,
    healthy_us: f64,
    deg_hbh_us: f64,
    deg_direct_us: f64,
    stall_deg_us: f64,
    conv_hbh_us: f64,
    conv_direct_us: f64,
    reroutes: u64,
}

fn main() {
    let mut json = JsonReport::new();
    let model = RecoveryModel::default();
    let sizes = [4usize, 8];
    let fault_sites = [0usize, 1, 2, 3];
    let bytes = 4e6;
    let regimes = ["absorbed", "tail"];

    let grid = GridBuilder::cartesian3(&regimes, &sizes, &fault_sites, |&r, &n, &k| {
        Some((r, n, k))
    });
    let rows: Vec<SiteRow> = grid.run(|_i, &(regime, n, k), _rng| {
        let t = mesh(n);
        let node = |x: usize, y: usize| NodeId((y * n + x) as u32);
        let (dag, paths, fail_frac) = match regime {
            "absorbed" => {
                let (d, p) = detour_exchange(&t, n, bytes);
                (d, p, 0.4)
            }
            _ => {
                let (d, p) = snake_exchange(&t, n, bytes);
                (d, p, 0.85)
            }
        };
        let net = SimNet::new(&t);
        let healthy = sim::schedule::run(&net, &dag);
        assert!(!healthy.is_stalled());

        // Failure site, cut at the regime's fraction of the makespan:
        // a column link for the detour exchange (its 3-hop loops put
        // sources 2 BFS hops from a column failure) and a row link for
        // the snakes (their h3 crossings do the same for row failures).
        let failed = if regime == "absorbed" {
            t.link_between(node(k, 0), node(k, 1)).unwrap()
        } else {
            t.link_between(node(k, 0), node((k + 1) % n, 0)).unwrap()
        };
        let t_fail = fail_frac * healthy.makespan_us;
        let t_restore = 2.5 * healthy.makespan_us;
        let faults = FaultPlan::new()
            .at(t_fail, FaultEvent::LinkDown(failed))
            .at(t_restore, FaultEvent::LinkUp(failed));

        // Naive bound: no recovery, the cut flows wait for the restore.
        let stall = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &faults);
        assert!(!stall.is_stalled());
        assert!(stall.makespan_us > t_restore);

        let run_mode = |rc: RecoveryConfig| {
            let plan = faults.clone().with_recovery(rc);
            let r = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &plan);
            assert!(!r.is_stalled(), "recovered run must complete ({regime} n={n} k={k})");
            assert!(r.reroutes >= 1, "fault must cut live flows ({regime} n={n} k={k})");
            r
        };
        let hbh = run_mode(RecoveryConfig::hop_by_hop());
        let direct = run_mode(RecoveryConfig::direct());
        assert_eq!(hbh.reroutes, direct.reroutes);

        let deg_hbh = hbh.makespan_us - healthy.makespan_us;
        let deg_direct = direct.makespan_us - healthy.makespan_us;
        assert!(deg_direct >= 0.0 && deg_hbh >= 0.0);
        assert!(
            deg_direct <= deg_hbh + 1e-6,
            "direct {deg_direct} must not lose to hop-by-hop {deg_hbh} ({regime} n={n} k={k})"
        );
        assert!(hbh.makespan_us < stall.makespan_us);

        let affected = affected_sources(&t, &paths, failed);
        let conv_hbh = hop_by_hop_convergence_us(&t, failed, &affected, &model);
        let conv_direct = direct_notification_convergence_us(&t, failed, &affected, &model);
        assert!(
            conv_direct < conv_hbh,
            "multi-hop paths must put sources ≥2 hops out ({regime} n={n} k={k})"
        );
        let gap = deg_hbh - deg_direct;
        let analytic = conv_hbh - conv_direct;
        // The sim charges exactly the modeled control-plane delay:
        // contention can absorb part of the gap, never inflate it.
        assert!(
            gap <= analytic * 1.01 + 1e-6,
            "measured gap {gap} exceeds analytic {analytic} ({regime} n={n} k={k})"
        );
        if regime == "tail" {
            // Rerouted flows gate the finish: the gap is the analytic
            // gap exactly, and every lost µs shows.
            assert!(deg_direct > 0.0, "tail fault must cost time (n={n} k={k})");
            assert!(
                (gap - analytic).abs() <= 0.01 * analytic + 1e-6,
                "tail gap {gap} vs analytic {analytic} (n={n} k={k})"
            );
        }
        SiteRow {
            n,
            regime,
            healthy_us: healthy.makespan_us,
            deg_hbh_us: deg_hbh,
            deg_direct_us: deg_direct,
            stall_deg_us: stall.makespan_us - healthy.makespan_us,
            conv_hbh_us: conv_hbh,
            conv_direct_us: conv_direct,
            reroutes: direct.reroutes,
        }
    });

    let mut tbl = Table::with_title(
        "Fig 12 (measured): mid-collective link failure, 4 sites per cell (µs)",
        vec![
            "mesh / regime",
            "healthy",
            "deg hbh (mean)",
            "deg direct (mean)",
            "stall bound",
            "conv hbh",
            "conv direct",
            "reroutes",
        ],
    );
    for &regime in &regimes {
        for &n in &sizes {
            let mut deg_h = OnlineStats::default();
            let mut deg_d = OnlineStats::default();
            let mut stall_b = OnlineStats::default();
            let mut conv_h = OnlineStats::default();
            let mut conv_d = OnlineStats::default();
            let mut healthy = 0.0;
            let mut reroutes = 0u64;
            for r in rows.iter().filter(|r| r.n == n && r.regime == regime) {
                healthy = r.healthy_us;
                deg_h.push(r.deg_hbh_us);
                deg_d.push(r.deg_direct_us);
                stall_b.push(r.stall_deg_us);
                conv_h.push(r.conv_hbh_us);
                conv_d.push(r.conv_direct_us);
                reroutes += r.reroutes;
            }
            tbl.row(vec![
                format!("{n}x{n} {regime}"),
                fmt(healthy, 1),
                fmt(deg_h.mean(), 1),
                fmt(deg_d.mean(), 1),
                fmt(stall_b.mean(), 1),
                fmt(conv_h.mean(), 1),
                fmt(conv_d.mean(), 1),
                format!("{reroutes}"),
            ]);
            let pre = format!("fault.m{n}.{regime}");
            json.metric(format!("{pre}.healthy_us"), healthy);
            json.metric(format!("{pre}.deg_hbh_us_mean"), deg_h.mean());
            json.metric(format!("{pre}.deg_direct_us_mean"), deg_d.mean());
            json.metric(format!("{pre}.stall_bound_deg_us_mean"), stall_b.mean());
            json.metric(format!("{pre}.conv_hbh_us_mean"), conv_h.mean());
            json.metric(format!("{pre}.conv_direct_us_mean"), conv_d.mean());
            json.metric(format!("{pre}.notify_gap_us"), deg_h.mean() - deg_d.mean());
            json.metric(format!("{pre}.reroutes"), reroutes as f64);
        }
    }
    tbl.print();

    // ---- Monte-Carlo sampled fault plans ------------------------------
    let fc = measured_fault_cost(4, 8e6, 24, 2024, &RecoveryConfig::direct());
    assert_eq!(fc.disconnected, 0, "2D full-mesh survives any single link");
    assert!(fc.degradation_us.min() >= -1e-9);
    println!(
        "\nMC fault plans (24 sampled link failures, APR recovery): healthy {} µs, \
         degradation mean {:.1} / p99 {:.1} µs, {} reroutes",
        fmt(fc.healthy_us, 1),
        fc.degradation_us.mean(),
        fc.degradation_us.p99(),
        fc.reroutes
    );
    json.metric("fault.mc.healthy_us", fc.healthy_us);
    json.metric("fault.mc.deg_us_mean", fc.degradation_us.mean());
    json.metric("fault.mc.deg_us_p99", fc.degradation_us.p99());
    json.metric("fault.mc.reroutes", fc.reroutes as f64);
    json.metric("fault.mc.disconnected", fc.disconnected as f64);

    let path = std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_sim.json".into());
    match json.write(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
    println!(
        "\ndirect notification removes the per-hop protocol processing \
         (\"the control plane overhead can be greatly reduced\", §4.2) — \
         measured 1:1 in the tail regime; in the absorbed regime APR \
         re-selection hides the failure entirely"
    );
    println!("\nfig12_fault_recovery OK");
}
