//! Fig 12 — From Hop-by-hop to Direct Notification: routing-convergence
//! latency after a link failure, swept over topology scale.
//!
//! PR 2: the scenario set is a cartesian grid (mesh size × failed link)
//! built with `sim::sweep::GridBuilder`, and per-size results aggregate
//! through `AggTable` (mean/p99 over the failure axis) instead of the
//! previous single-failure hand-rolled rows.

use ubmesh::routing::apr::{paths_2d, to_routed};
use ubmesh::routing::failure::{
    affected_sources, direct_notification_convergence_us, hop_by_hop_convergence_us,
    RecoveryModel,
};
use ubmesh::sim::sweep::{AggTable, GridBuilder};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, NodeId};
use ubmesh::util::table::{fmt, Table};

fn main() {
    let m = RecoveryModel::default();
    let sizes = [4usize, 8, 16];
    // Failure axis: break the dim-0 link (k,0)—(k+1 mod n,0); different
    // k exercise different affected-source populations.
    let faults = [0usize, 1, 2, 3];
    let grid = GridBuilder::cartesian2(&sizes, &faults, |&n, &k| Some((n, k)));

    let rows: Vec<(usize, usize, f64, f64)> = grid.run(|_i, &(n, k), _rng| {
        let t = nd_fullmesh(
            "g",
            &[
                DimSpec::new(n, 4, CableClass::PassiveElectrical, 0.3),
                DimSpec::new(n, 4, CableClass::PassiveElectrical, 1.0),
            ],
        );
        let node = |x: usize, y: usize| NodeId((y * n + x) as u32);
        let mut paths = Vec::new();
        for s in 0..(n * n) {
            for d in 0..(n * n) {
                if s != d {
                    for mp in paths_2d((s % n, s / n), (d % n, d / n), n, n, true) {
                        paths.push(to_routed(&mp, node));
                    }
                }
            }
        }
        let failed = t.link_between(node(k, 0), node((k + 1) % n, 0)).unwrap();
        let affected = affected_sources(&t, &paths, failed);
        let slow = hop_by_hop_convergence_us(&t, failed, &affected, &m);
        let fast = direct_notification_convergence_us(&t, failed, &affected, &m);
        assert!(fast < slow, "direct must beat hop-by-hop (n={n}, k={k})");
        (n, affected.len(), slow, fast)
    });

    // Aggregate over the failure axis, keyed by mesh size.
    let mut slow_agg = AggTable::default();
    let mut fast_agg = AggTable::default();
    let mut affected_agg = AggTable::default();
    for &(n, affected, slow, fast) in &rows {
        let key = format!("{n}x{n} 2D-FM");
        slow_agg.add(key.clone(), slow);
        fast_agg.add(key.clone(), fast);
        affected_agg.add(key, affected as f64);
    }

    let mut tbl = Table::with_title(
        "Fig 12: convergence after a link failure, over 4 failure sites (µs)",
        vec![
            "mesh",
            "affected(mean)",
            "hop-by-hop mean",
            "hop-by-hop p99",
            "direct mean",
            "direct p99",
            "speedup",
        ],
    );
    for (key, slow) in slow_agg.iter() {
        let fast = fast_agg.get(key).unwrap();
        let aff = affected_agg.get(key).unwrap();
        tbl.row(vec![
            key.to_string(),
            fmt(aff.mean(), 1),
            fmt(slow.mean(), 1),
            fmt(slow.p99(), 1),
            fmt(fast.mean(), 1),
            fmt(fast.p99(), 1),
            format!("{:.2}x", slow.mean() / fast.mean()),
        ]);
    }
    tbl.print();
    println!(
        "\ndirect notification removes the per-hop protocol processing \
         (\"the control plane overhead can be greatly reduced\", §4.2)"
    );
    println!("\nfig12_fault_recovery OK");
}
