//! Fig 12 — From Hop-by-hop to Direct Notification: routing-convergence
//! latency after a link failure, swept over topology scale.
//!
//! Each mesh size is an independent scenario; the sweep fans them out
//! across threads (`sim::sweep`) and returns rows in declaration order.

use ubmesh::routing::apr::{paths_2d, to_routed};
use ubmesh::routing::failure::{
    affected_sources, direct_notification_convergence_us, hop_by_hop_convergence_us,
    RecoveryModel,
};
use ubmesh::sim::sweep::sweep_default;
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, NodeId};
use ubmesh::util::table::{fmt, Table};

struct Row {
    n: usize,
    affected: usize,
    slow: f64,
    fast: f64,
}

fn main() {
    let m = RecoveryModel::default();
    let sizes = [4usize, 8, 16];
    let rows: Vec<Row> = sweep_default(&sizes, |_i, &n, _rng| {
        let t = nd_fullmesh(
            "g",
            &[
                DimSpec::new(n, 4, CableClass::PassiveElectrical, 0.3),
                DimSpec::new(n, 4, CableClass::PassiveElectrical, 1.0),
            ],
        );
        let node = |x: usize, y: usize| NodeId((y * n + x) as u32);
        let mut paths = Vec::new();
        for s in 0..(n * n) {
            for d in 0..(n * n) {
                if s != d {
                    for mp in paths_2d((s % n, s / n), (d % n, d / n), n, n, true) {
                        paths.push(to_routed(&mp, node));
                    }
                }
            }
        }
        let failed = t.link_between(node(0, 0), node(1, 0)).unwrap();
        let affected = affected_sources(&t, &paths, failed);
        let slow = hop_by_hop_convergence_us(&t, failed, &affected, &m);
        let fast = direct_notification_convergence_us(&t, failed, &affected, &m);
        Row {
            n,
            affected: affected.len(),
            slow,
            fast,
        }
    });

    let mut tbl = Table::with_title(
        "Fig 12: convergence after a link failure (µs)",
        vec!["mesh", "affected", "hop-by-hop", "direct", "speedup"],
    );
    for r in &rows {
        tbl.row(vec![
            format!("{}x{} 2D-FM", r.n, r.n),
            format!("{}", r.affected),
            fmt(r.slow, 1),
            fmt(r.fast, 1),
            format!("{:.2}x", r.slow / r.fast),
        ]);
        assert!(r.fast < r.slow);
    }
    tbl.print();
    println!(
        "\ndirect notification removes the per-hop protocol processing \
         (\"the control plane overhead can be greatly reduced\", §4.2)"
    );
    println!("\nfig12_fault_recovery OK");
}
