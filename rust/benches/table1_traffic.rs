//! Table 1 — Analysis of Data Traffic in LLM Training (MoE-2T).
//! Regenerates the traffic table from model math and compares the
//! shares/counts against the paper's values.

use ubmesh::util::table::{bytes, fmt, pct, Table};
use ubmesh::workload::models::by_name;
use ubmesh::workload::traffic::{analyze, table1_config};

fn main() {
    let m = by_name("gpt4-2t").unwrap();
    let cfg = table1_config();
    let t = analyze(&m, &cfg);

    // (technique, paper vol/transfer MB, paper transfers, paper share %)
    let paper = [
        ("TP", 360.0, 4992.0, 52.9),
        ("SP", 270.0, 6656.0, 44.08), // 180/360 MB over 4992/1664
        ("EP", 10.5, 4992.0, 1.54),
        ("PP", 192.0, 26.0, 0.14),
        ("DP", 711.75, 64.0, 1.34),
    ];

    let mut tbl = Table::with_title(
        "Table 1: traffic per iteration (measured vs paper)",
        vec![
            "technique",
            "pattern",
            "vol/transfer",
            "transfers",
            "share",
            "paper share",
        ],
    );
    for (tech, _pv, _pt, pshare) in paper {
        if let Some(r) = t.row(tech) {
            tbl.row(vec![
                tech.to_string(),
                r.pattern.to_string(),
                bytes(r.volume_per_transfer),
                fmt(r.transfers, 0),
                pct(r.total / t.total(), 2),
                format!("{pshare}%"),
            ]);
        }
    }
    tbl.print();
    let tp_sp = t.share("TP") + t.share("SP");
    println!(
        "TP+SP locality: measured {} (paper ≈ 97%)",
        pct(tp_sp, 1)
    );
    println!(
        "total per iteration: {} (paper 3338 GB)",
        bytes(t.total())
    );
    assert!(tp_sp > 0.9, "locality shape must hold");
    println!("\ntable1_traffic OK");
}
