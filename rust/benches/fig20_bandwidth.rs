//! Fig 20 — Inter-Rack Bandwidth Exploration: x4/x8/x16/x32 UB IO per
//! NPU across short and long sequence-length bands.

use ubmesh::coordinator::{Arch, Job, Routing};
use ubmesh::util::table::{pct, Table};

fn main() {
    let scale = 8192;
    let lanes = [4u32, 8, 16, 32];
    let bands: [(&str, &[f64]); 2] = [
        ("8K–32K", &[8192.0, 16384.0, 32768.0]),
        ("64K–10M", &[65536.0, 1048576.0, 10485760.0]),
    ];

    let mut tbl = Table::with_title(
        "Fig 20: throughput vs inter-rack lanes (normalized to x32)",
        vec!["seq band", "x4", "x8", "x16", "x32"],
    );
    let mut by_band = Vec::new();
    for (name, seqs) in bands {
        let mut tputs = Vec::new();
        for &l in &lanes {
            let mut total = 0.0;
            for &seq in seqs {
                total += Job::new(
                    "gpt4-2t",
                    scale,
                    seq,
                    Arch::UbMesh {
                        inter_rack_lanes: l,
                        routing: Routing::Detour,
                    },
                )
                .unwrap()
                .plan(None)
                .unwrap()
                .tokens_per_s;
            }
            tputs.push(total);
        }
        let x32 = tputs[3];
        let mut cells = vec![name.to_string()];
        for t in &tputs {
            cells.push(pct(t / x32, 2));
        }
        tbl.row(cells);
        by_band.push(tputs);
    }
    tbl.print();

    // Paper: x8→x16 gain small for short seqs (0.44%); x16→x32 gain
    // larger for long seqs (1.85%).
    let short_x8_x16 = by_band[0][2] / by_band[0][1] - 1.0;
    let long_x16_x32 = by_band[1][3] / by_band[1][2] - 1.0;
    println!(
        "\nshort-seq x8→x16 gain: {} (paper 0.44%) | long-seq x16→x32 gain: {} (paper 1.85%)",
        pct(short_x8_x16, 2),
        pct(long_x16_x32, 2)
    );
    assert!(
        long_x16_x32 >= short_x8_x16,
        "long sequences must benefit more from inter-rack bandwidth"
    );
    println!("default provision x16 balances cost and performance (§6.3) ✓");
    println!("\nfig20_bandwidth OK");
}
