//! Fig 20 — hardware-provisioning exploration, re-run under the
//! hop-chain tier model.
//!
//! **Section 1 — inter-rack lanes** (the paper's x4/x8/x16/x32 sweep):
//! with the backplane-mesh hop priced, the lane provision only pays
//! while the wire stage binds — x4→x8 helps, and from x16 the x2 LRS
//! mesh (37.5 GB/s Detour) is the ceiling, so x32 buys *nothing*. The
//! old model showed a residual x16→x32 long-sequence gain only because
//! it skipped that hop; the corrected curve flattens exactly where §6.3
//! picks the default ("x16 balances cost and performance").
//!
//! **Section 2 — backplane-mesh width** (new): the knob that actually
//! moves the ceiling. Sweeps x1/x2/x4/x8 mesh lanes per LRS pair at the
//! x16 default, analytic only — widths past x2 exceed the x72 LRS part
//! the DES topology builder wires, so the widened fabrics are priced
//! via [`lrs_radix_surcharge`] instead of constructed. Perf-per-CapEx
//! picks the cost-optimal width, recorded as
//! `fig20.mesh.optimal_mesh_lanes`.
//!
//! Merges its `fig20.*` metrics into the `BENCH_workload.json` the
//! fig22 bench wrote (`BENCH_SIM_JSON` overrides the path).

use ubmesh::coordinator::{Arch, Job, Routing};
use ubmesh::cost::capex::{capex_ubmesh, lrs_radix_surcharge};
use ubmesh::topology::superpod::SuperPodConfig;
use ubmesh::util::bench::JsonReport;
use ubmesh::util::table::{fmt, pct, Table};

fn band_tput(lanes: u32, mesh_lanes: u32, seqs: &[f64]) -> f64 {
    seqs.iter()
        .map(|&seq| {
            Job::new(
                "gpt4-2t",
                8192,
                seq,
                Arch::UbMesh {
                    inter_rack_lanes: lanes,
                    routing: Routing::Detour,
                    mesh_lanes,
                    uplink_oversub: 1,
                },
            )
            .unwrap()
            .plan(None)
            .unwrap()
            .tokens_per_s
        })
        .sum()
}

fn main() {
    let mut json = JsonReport::new();
    let short_band: &[f64] = &[8192.0, 16384.0, 32768.0];
    let long_band: &[f64] = &[65536.0, 1048576.0, 10485760.0];

    // ---- 1. inter-rack lane sweep (x2 mesh, the built hardware) ----
    let lanes = [4u32, 8, 16, 32];
    let mut tbl = Table::with_title(
        "Fig 20: throughput vs inter-rack lanes (normalized to x32)",
        vec!["seq band", "x4", "x8", "x16", "x32"],
    );
    let mut by_band = Vec::new();
    for (name, seqs) in [("8K–32K", short_band), ("64K–10M", long_band)] {
        let tputs: Vec<f64> = lanes.iter().map(|&l| band_tput(l, 2, seqs)).collect();
        let x32 = tputs[3];
        let mut cells = vec![name.to_string()];
        for t in &tputs {
            cells.push(pct(t / x32, 2));
        }
        tbl.row(cells);
        // More provision never hurts…
        for w in tputs.windows(2) {
            assert!(w[1] >= w[0] * 0.9999, "lane sweep must be monotone");
        }
        by_band.push(tputs);
    }
    tbl.print();

    let short_x8_x16 = by_band[0][2] / by_band[0][1] - 1.0;
    let long_x8_x16 = by_band[1][2] / by_band[1][1] - 1.0;
    let long_x16_x32 = by_band[1][3] / by_band[1][2] - 1.0;
    println!(
        "\nx8→x16 gain: short {} / long {} | x16→x32 long gain: {} (mesh-capped)",
        pct(short_x8_x16, 2),
        pct(long_x8_x16, 2),
        pct(long_x16_x32, 2)
    );
    // …but past x16 the x2 backplane mesh is the binding hop: the
    // long-sequence x16→x32 gain collapses to ~0 (mirror: 0.0000,
    // vs +1.03% for x8→x16), the corrected form of the paper's
    // "x16 balances cost and performance".
    assert!(
        long_x8_x16 > 0.005,
        "x8→x16 long-seq gain {long_x8_x16:.4} should still be real"
    );
    assert!(
        long_x16_x32 < 0.005,
        "x16→x32 long-seq gain {long_x16_x32:.4} should be mesh-capped"
    );
    json.metric("fig20.lanes.short_x8_x16_gain", short_x8_x16);
    json.metric("fig20.lanes.long_x8_x16_gain", long_x8_x16);
    json.metric("fig20.lanes.long_x16_x32_gain", long_x16_x32);
    for (i, &l) in lanes.iter().enumerate() {
        json.metric(format!("fig20.lanes.x{l}.short_tokens_per_s"), by_band[0][i]);
        json.metric(format!("fig20.lanes.x{l}.long_tokens_per_s"), by_band[1][i]);
    }

    // ---- 2. backplane-mesh width sweep + cost optimum (new) ----
    let base = capex_ubmesh(&SuperPodConfig::default());
    let widths = [1u32, 2, 4, 8];
    let mut tbl = Table::with_title(
        "Fig 20 (mesh): long-seq throughput & CapEx vs LRS-mesh width (x16 lanes)",
        vec!["mesh", "tokens/s (64K–10M)", "capex", "perf/capex vs x2"],
    );
    let mut scored = Vec::new();
    for &mw in &widths {
        let tput = band_tput(16, mw, long_band);
        let capex = base.total() + lrs_radix_surcharge(base.lrs, mw);
        scored.push((mw, tput, capex, tput / capex));
    }
    let norm = scored[1].3; // x2 = the built default
    for &(mw, tput, capex, ppc) in &scored {
        tbl.row(vec![
            format!("x{mw}"),
            fmt(tput, 0),
            fmt(capex, 0),
            pct(ppc / norm, 2),
        ]);
        json.metric(format!("fig20.mesh.m{mw}.long_tokens_per_s"), tput);
        json.metric(format!("fig20.mesh.m{mw}.capex"), capex);
        json.metric(format!("fig20.mesh.m{mw}.perf_per_capex"), ppc);
    }
    tbl.print();

    let optimal = scored
        .iter()
        .max_by(|a, b| a.3.total_cmp(&b.3))
        .unwrap()
        .0;
    println!(
        "\ncost-optimal backplane-mesh width: x{optimal} \
         (x4 lifts the Detour Row tier 37.5 → 60 GB/s and the Pod tier \
         12.5 → 25 GB/s for ~1.3% CapEx; x8 adds cost but the wire/uplink \
         stages already bind)"
    );
    assert_eq!(
        optimal, 4,
        "mirror-measured optimum is the x4 mesh (x2 under-provisions, x8 \
         pays for lanes the wire stage can't feed)"
    );
    json.metric("fig20.mesh.optimal_mesh_lanes", optimal as f64);

    let path =
        std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_workload.json".into());
    if let Err(e) = json.merge_metrics_from(&path) {
        println!("could not merge existing {path}: {e}");
    }
    match json.write(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nfailed to write {path}: {e}"),
    }
    println!("\nfig20_bandwidth OK");
}
