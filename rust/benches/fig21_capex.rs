//! Fig 21 — CapEx Comparison + the §6.4 cost-efficiency headline,
//! plus the backplane-mesh-width CapEx deltas that feed the fig20
//! cost-optimum (the widened LRS parts priced by `lrs_radix_surcharge`).

use ubmesh::coordinator::{Arch, Job};
use ubmesh::cost::capex::{
    capex_fm_clos, capex_full_clos, capex_ubmesh, lrs_radix_surcharge, savings,
};
use ubmesh::cost::efficiency::cost_efficiency;
use ubmesh::cost::opex::{network_opex, opex};
use ubmesh::reliability::afr::afr_of_capex;
use ubmesh::topology::superpod::SuperPodConfig;
use ubmesh::util::table::{fmt, pct, ratio, Table};

fn main() {
    let ub = capex_ubmesh(&SuperPodConfig::default());
    let rows = [
        (ub.clone(), 1.0),
        (capex_fm_clos("2D-FM+x16 Clos", 8192, 16, 2), 1.18),
        (capex_fm_clos("1D-FM+x16 Clos", 8192, 16, 1), 1.26),
        (capex_full_clos("x64T Clos", 8192, 64), 2.46),
    ];
    let mut t = Table::with_title(
        "Fig 21: CapEx per architecture (8K NPUs)",
        vec![
            "architecture",
            "HRS",
            "LRS",
            "optic-mods",
            "net-share",
            "CapEx vs UB",
            "paper",
        ],
    );
    let mut prev = f64::INFINITY;
    for (r, paper) in rows.iter().rev() {
        assert!(r.total() <= prev * 1.001, "cost ordering");
        prev = r.total();
        let _ = paper;
    }
    for (r, paper) in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{}", r.hrs),
            format!("{}", r.lrs),
            format!("{}", r.optical_modules),
            pct(r.network_share(), 0),
            ratio(r.total() / rows[0].0.total()),
            format!("{paper}x"),
        ]);
    }
    t.print();

    let clos = &rows[3].0;
    let (hrs_s, opt_s) = savings(&ub, clos);
    println!(
        "\nHRS saved {} (paper 98%) | optical modules saved {} (paper 93%)",
        pct(hrs_s, 0),
        pct(opt_s, 0)
    );
    println!(
        "network share of system cost: UB-Mesh {} vs Clos {} (paper: 20% vs 67%)",
        pct(ub.network_share(), 0),
        pct(clos.network_share(), 0)
    );

    // --- backplane-mesh width: what the fig20 optimum costs ---------------
    let mut t = Table::with_title(
        "mesh-width CapEx (widened LRS parts, 9216 LRS)",
        vec!["mesh", "surcharge", "vs UB total"],
    );
    for mw in [1u32, 2, 4, 8] {
        let s = lrs_radix_surcharge(ub.lrs, mw);
        t.row(vec![
            format!("x{mw}"),
            fmt(s, 0),
            pct(s / ub.total(), 1),
        ]);
    }
    t.print();
    // The fig20 cost-optimal x4 mesh must stay a small fraction of the
    // system (otherwise the perf-per-CapEx argmax there is suspect),
    // and the default x2 must be free (fits the x72 part exactly).
    assert_eq!(lrs_radix_surcharge(ub.lrs, 2), 0.0);
    let x4_share = lrs_radix_surcharge(ub.lrs, 4) / ub.total();
    assert!(
        x4_share < 0.03,
        "x4-mesh surcharge is {} of system CapEx",
        pct(x4_share, 1)
    );

    // --- OpEx + Eq. 1 cost-efficiency -------------------------------------
    let ub_afr = afr_of_capex(&ub);
    let clos_afr = afr_of_capex(clos);
    let ub_net_opex = network_opex(&ub, ub_afr.total());
    let clos_net_opex = network_opex(clos, clos_afr.total());
    println!(
        "network OpEx reduction: {} (paper ≈ 35%)",
        pct(1.0 - ub_net_opex / clos_net_opex, 0)
    );
    // performance factor from the fig17-style comparison
    let perf = Job::new("gpt3-175b", 8192, 262144.0, Arch::ubmesh_default())
        .unwrap()
        .relative_perf(Arch::ClosIntraRack, None)
        .unwrap();
    let ub_ce = cost_efficiency(perf, &ub, &opex(&ub, ub_afr.total()));
    let clos_ce = cost_efficiency(1.0, clos, &opex(clos, clos_afr.total()));
    println!(
        "cost-efficiency (Eq.1): {} at {} relative perf (paper: 2.04x)",
        ratio(ub_ce / clos_ce),
        pct(perf, 1)
    );
    assert!(ub_ce / clos_ce > 1.6, "cost-efficiency gain must be large");
    println!("\nfig21_capex OK (CapEx totals: UB {} vs Clos {})", fmt(ub.total(), 0), fmt(clos.total(), 0));
}
