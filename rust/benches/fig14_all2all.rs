//! Fig 14 — Multi-Path and Hierarchical All-to-All on the rack 2D-FM.

use ubmesh::collectives::alltoall::{
    hierarchical_alltoall_dag, multipath_alltoall_dag, singlepath_alltoall_dag, Grid,
};
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::util::table::{bytes as fmt_bytes, fmt, Table};

fn main() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let g = Grid::new(&h.npus, 8, 8);
    let net = SimNet::new(&t);

    let mut tbl = Table::with_title(
        "Fig 14: All2All over 64 NPUs (per-pair payload sweep)",
        vec![
            "payload/pair",
            "single-path µs",
            "multi-path µs",
            "bcast+reduce µs",
            "wire bytes (general vs hier)",
        ],
    );
    for per_pair in [0.17e6, 1.0e6, 4.0e6] {
        let sp = sim::schedule::run(&net, &singlepath_alltoall_dag(&t, &g, per_pair));
        let mp_dag = multipath_alltoall_dag(&t, &g, per_pair);
        let mp = sim::schedule::run(&net, &mp_dag);
        let h_dag = hierarchical_alltoall_dag(&t, &g, per_pair);
        let hr = sim::schedule::run(&net, &h_dag);
        tbl.row(vec![
            fmt_bytes(per_pair),
            fmt(sp.makespan_us, 1),
            fmt(mp.makespan_us, 1),
            fmt(hr.makespan_us, 1),
            format!(
                "{} vs {}",
                fmt_bytes(mp_dag.total_bytes()),
                fmt_bytes(h_dag.total_bytes())
            ),
        ]);
        // Fig 14-a: multipath never worse than single path; Fig 14-b/c:
        // broadcast+reduce moves far fewer wire bytes.
        assert!(mp.makespan_us <= sp.makespan_us * 1.01);
        assert!(h_dag.total_bytes() < mp_dag.total_bytes() / 2.0);
    }
    tbl.print();
    println!(
        "\n\"at most one-hop forwarding\" ✓ (all multipath flows ≤ 2 channels); \
         hierarchical bcast+reduce saves bandwidth for MoE token exchange ✓"
    );
    println!("\nfig14_all2all OK");
}
