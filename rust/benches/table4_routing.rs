//! Table 4 — Routing Systems Comparison: the qualitative feature matrix
//! plus a quantitative forwarding microbench (structured linear lookup
//! vs LPM trie — the "High-Performance Forwarding" column).

use ubmesh::routing::address::UbAddr;
use ubmesh::routing::table::{LinearTable, LpmTrie, Segment, SegmentRoute, StructuredTable};
use ubmesh::util::bench::{bench, black_box};
use ubmesh::util::rng::Rng;
use ubmesh::util::table::Table;

fn build_structured() -> StructuredTable {
    let mut st = StructuredTable::new(0, 0);
    for b in 0..8u8 {
        for s in 0..8u8 {
            st.set_local_route(b, s, (b as u16) * 32 + s as u16);
        }
    }
    for rack in 1..16u8 {
        st.set_rack_route(rack, 100 + rack as u16);
    }
    for pod in 1..8u16 {
        st.set_pod_route(pod, 200 + pod);
    }
    st
}

fn build_tables() -> (LinearTable, LpmTrie) {
    // Local rack (dense linear) + 127 remote racks + 7 remote pods.
    let mut lin = LinearTable::default();
    let local = UbAddr::new(0, 0, 0, 0, 0);
    let (prefix, bits) = local.rack_segment();
    let ports: Vec<u16> = (0..(8 << 5)).map(|i| i as u16).collect();
    lin.add(Segment {
        prefix,
        bits,
        route: SegmentRoute::Linear {
            base_shift: 8,
            ports,
        },
    });
    let mut lpm = LpmTrie::new();
    // host routes for the local rack
    for b in 0..8u8 {
        for s in 0..8u8 {
            lpm.insert(UbAddr::new(0, 0, b, s, 0).0, 32, (b as u16) * 32 + s as u16);
        }
    }
    for rack in 1..16u8 {
        let a = UbAddr::new(0, rack, 0, 0, 0);
        let (p, bits) = a.rack_segment();
        lin.add(Segment {
            prefix: p,
            bits,
            route: SegmentRoute::Aggregate(100 + rack as u16),
        });
        lpm.insert(p, bits, 100 + rack as u16);
    }
    for pod in 1..8u16 {
        let a = UbAddr::new(pod, 0, 0, 0, 0);
        let (p, bits) = a.pod_segment();
        lin.add(Segment {
            prefix: p,
            bits,
            route: SegmentRoute::Aggregate(200 + pod),
        });
        lpm.insert(p, bits, 200 + pod);
    }
    (lin, lpm)
}

fn main() {
    // --- feature matrix (Table 4) ---------------------------------------
    let mut t = Table::with_title(
        "Table 4: routing systems",
        vec![
            "property",
            "LPM+BGP",
            "host-based",
            "DOR",
            "APR (ours)",
        ],
    );
    t.row(vec!["hybrid topology", "yes", "partial", "no", "yes"]);
    t.row(vec!["high-perf forwarding", "no", "no", "yes", "yes"]);
    t.row(vec!["non-shortest paths", "no", "no", "no", "yes"]);
    t.row(vec!["fault tolerance", "yes", "yes", "no", "yes"]);
    t.print();

    // --- forwarding microbench -------------------------------------------
    let (lin, lpm) = build_tables();
    let st = build_structured();
    println!(
        "\ntable sizes: structured {} entries, segment-scan {} entries, LPM trie {} nodes",
        st.size(),
        lin.size(),
        lpm.size()
    );
    let mut rng = Rng::new(42);
    let addrs: Vec<UbAddr> = (0..4096)
        .map(|_| {
            UbAddr::new(
                rng.below(8) as u16,
                rng.below(16) as u8,
                rng.below(8) as u8,
                rng.below(8) as u8,
                0,
            )
        })
        .collect();
    // correctness parity on local rack first
    for b in 0..8u8 {
        for s in 0..8u8 {
            let a = UbAddr::new(0, 0, b, s, 0);
            assert_eq!(lin.lookup(a), lpm.lookup(a), "{a}");
            assert_eq!(st.lookup(a), lpm.lookup(a), "{a}");
        }
    }
    let rs = bench("structured indexed lookup ×4096", || {
        for a in &addrs {
            black_box(st.lookup(*a));
        }
    });
    let rl = bench("segment-scan lookup ×4096", || {
        for a in &addrs {
            black_box(lin.lookup(*a));
        }
    });
    let rt = bench("LPM trie lookup ×4096", || {
        for a in &addrs {
            black_box(lpm.lookup(*a));
        }
    });
    let _ = rl;
    let speedup = rt.mean.as_secs_f64() / rs.mean.as_secs_f64();
    println!(
        "\nstructured lookup is {speedup:.1}x faster than LPM \
         (Table 4: 'High-Performance Forwarding' ✓)"
    );
    assert!(speedup > 1.0, "structured indexing must beat the trie");
    println!("\ntable4_routing OK");
}
