//! Table 6 — MTBF Estimation + the §6.6 availability numbers, from the
//! component censuses and per-unit AFRs, plus a Monte-Carlo check.

use ubmesh::cost::capex::{capex_full_clos, capex_ubmesh};
use ubmesh::reliability::afr::afr_of_capex;
use ubmesh::reliability::availability::{availability, mtbf_hours, mttr};
use ubmesh::reliability::montecarlo::{run, McConfig};
use ubmesh::topology::superpod::SuperPodConfig;
use ubmesh::util::table::{fmt, pct, Table};

fn main() {
    let ub_capex = capex_ubmesh(&SuperPodConfig::default());
    let clos_capex = capex_full_clos("x64T Clos", 8192, 64);
    let ub = afr_of_capex(&ub_capex);
    let clos = afr_of_capex(&clos_capex);

    let mut t = Table::with_title(
        "Table 6: AFR / MTBF (measured | paper)",
        vec!["arch", "E-cables", "optical", "LRS", "HRS", "total", "MTBF (h)"],
    );
    t.row(vec![
        "UB-Mesh".into(),
        format!("{} | 5.82", fmt(ub.electrical_cables, 2)),
        format!("{} | 1.55", fmt(ub.optical, 2)),
        format!("{} | 81", fmt(ub.lrs, 1)),
        format!("{} | 0.56", fmt(ub.hrs, 2)),
        format!("{} | 88.9", fmt(ub.total(), 1)),
        format!("{} | 98.5", fmt(mtbf_hours(ub.total()), 1)),
    ]);
    t.row(vec![
        "Clos".into(),
        format!("{} | 13.8", fmt(clos.electrical_cables, 2)),
        format!("{} | 574", fmt(clos.optical, 1)),
        format!("{} | 18", fmt(clos.lrs, 1)),
        format!("{} | 27", fmt(clos.hrs, 1)),
        format!("{} | 632.8", fmt(clos.total(), 1)),
        format!("{} | 13.8", fmt(mtbf_hours(clos.total()), 1)),
    ]);
    t.print();

    let ub_av = availability(mtbf_hours(ub.total()), mttr::BASELINE_HOURS);
    let clos_av = availability(mtbf_hours(clos.total()), mttr::BASELINE_HOURS);
    let ub_opt = availability(mtbf_hours(ub.total()), mttr::OPTIMIZED_HOURS);
    println!(
        "\navailability @75min MTTR: UB-Mesh {} vs Clos {} (paper: 98.8% vs 91.6%)",
        pct(ub_av, 1),
        pct(clos_av, 1)
    );
    println!(
        "improvement: {} (paper: 7.2%)  | optimized-MTTR UB-Mesh: {} (paper: 99.78%)",
        pct(ub_av - clos_av, 1),
        pct(ub_opt, 2)
    );
    println!(
        "MTBF ratio: {:.2}x (paper: 7.14x)",
        mtbf_hours(ub.total()) / mtbf_hours(clos.total())
    );

    // Monte-Carlo cross-check of Eq. 3 (network failures only).
    let mut mc_cfg = McConfig::ubmesh_8k(&ub, false);
    mc_cfg.npu_afr = 0.0;
    let mc = run(&mc_cfg, 64, 2024);
    println!(
        "\nMonte-Carlo availability (network-only): {} (Eq.3: {}) over {} failures",
        pct(mc.availability, 2),
        pct(ub_av, 2),
        mc.failures
    );
    assert!((mc.availability - ub_av).abs() < 0.02);

    // 64+1 backup benefit under NPU failures.
    let with = run(&McConfig::ubmesh_8k(&ub, true), 64, 7);
    let without = run(&McConfig::ubmesh_8k(&ub, false), 64, 7);
    println!(
        "with NPU failures: backup 64+1 {} vs no-backup {}",
        pct(with.availability, 2),
        pct(without.availability, 2)
    );
    assert!(with.availability > without.availability);
    println!("\ntable6_mtbf OK");
}
