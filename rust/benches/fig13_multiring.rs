//! Fig 13 — Multi-Ring AllReduce: single logical ring vs Walecki
//! multi-rings with optimized traffic partitioning, on the DES.

use ubmesh::collectives::ring::{
    fullmesh_rings, multiring_allreduce_dag, ring_allreduce_dag, ring_allreduce_us,
};
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::ublink::LANE_GB_S;
use ubmesh::topology::NodeId;
use ubmesh::util::table::{fmt, Table};

fn main() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let board: Vec<NodeId> = (0..8).map(|s| h.npu(0, s, 8)).collect();
    let net = SimNet::new(&t);

    let mut tbl = Table::with_title(
        "Fig 13: AllReduce on one board (8 NPUs, x4 links)",
        vec!["bytes", "single ring µs", "multi-ring(3) µs", "speedup", "closed-form 3x"],
    );
    for bytes in [16e6, 90e6, 360e6, 1e9] {
        let single = sim::schedule::run(&net, &ring_allreduce_dag(&t, &board, bytes));
        let rings = fullmesh_rings(&board, 3);
        let multi = sim::schedule::run(
            &net,
            &multiring_allreduce_dag(&t, &rings, &[1.0; 3], bytes),
        );
        let cf = ring_allreduce_us(bytes, 8, 3.0 * 4.0 * LANE_GB_S, 0.0);
        tbl.row(vec![
            fmt(bytes / 1e6, 0) + " MB",
            fmt(single.makespan_us, 1),
            fmt(multi.makespan_us, 1),
            format!("{:.2}x", single.makespan_us / multi.makespan_us),
            fmt(cf, 1),
        ]);
    }
    tbl.print();

    // Uneven partition (Fig 13-b: "optimize traffic partitioning across
    // multiple paths to mitigate bottlenecks"): starving one ring hurts.
    let rings = fullmesh_rings(&board, 3);
    let bytes = 360e6;
    let balanced = sim::schedule::run(
        &net,
        &multiring_allreduce_dag(&t, &rings, &[1.0, 1.0, 1.0], bytes),
    );
    let skewed = sim::schedule::run(
        &net,
        &multiring_allreduce_dag(&t, &rings, &[2.0, 0.5, 0.5], bytes),
    );
    println!(
        "\npartitioning: balanced {} µs vs skewed {} µs — optimized split wins ✓",
        fmt(balanced.makespan_us, 1),
        fmt(skewed.makespan_us, 1)
    );
    assert!(balanced.makespan_us < skewed.makespan_us);
    println!("\nfig13_multiring OK");
}
