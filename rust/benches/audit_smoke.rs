//! Audit smoke: run the static model auditor (`verify::audit`) over
//! every built-in fabric and the seeded-mutation matrix
//! (`verify::mutate`), then emit `BENCH_audit.json` (schema
//! `ubmesh.bench_sim.v1`, path override `BENCH_SIM_JSON`) so CI can
//! assert the auditor's two ends of the contract in one artifact:
//! zero findings on clean models, and every planted defect caught by
//! its declared `AUD0xx` code. The timed sections track the cost of
//! the bake-off eligibility gate itself (`audit_fabric` is what every
//! ROADMAP item-3 candidate pays on entry).
//!
//! Metric keys (`audit.*`): `rules_checked` (distinct catalog rules
//! exercised across all fabrics), `fabrics_total` / `fabrics_clean`,
//! `findings` (total violations on built-ins — must be 0),
//! `mutations_seeded` / `mutations_caught` (caught = report contains
//! the expected code and nothing else).

use ubmesh::topology::pod::{ubmesh_pod, PodConfig};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::topology::variants::{rack_1dfm_a, rack_1dfm_b, rack_clos};
use ubmesh::util::bench::{bench, black_box, section, JsonReport};
use ubmesh::verify::mutate::seeded_mutations;
use ubmesh::verify::{audit_fabric, AuditConfig, AuditReport, CATALOG};
use ubmesh::workload::ClusterMap;

fn main() {
    let mut json = JsonReport::new();
    let cfg = AuditConfig::default();

    section("audit_fabric over the built-in fabrics");
    let fabrics: Vec<(&str, ubmesh::topology::Topology, ClusterMap)> = {
        let (t_rack, h_rack) = ubmesh_rack(&RackConfig::default());
        let map_rack = ClusterMap::rack(&h_rack);
        let (t_a, h_a) = rack_1dfm_a();
        let (t_b, h_b) = rack_1dfm_b();
        let (t_c, h_c) = rack_clos();
        let (t_pod, h_pod) = ubmesh_pod(&PodConfig::default());
        let map_pod = ClusterMap::pod(&h_pod);
        let (t_sp, h_sp) = ubmesh_superpod(&SuperPodConfig {
            pods: 4,
            ..SuperPodConfig::default()
        });
        let map_sp = ClusterMap::superpod(&h_sp);
        vec![
            ("rack_2dfm", t_rack, map_rack),
            ("rack_1dfm_a", t_a, ClusterMap::fm1d_a(&h_a)),
            ("rack_1dfm_b", t_b, ClusterMap::fm1d_b(&h_b)),
            ("rack_clos", t_c, ClusterMap::clos_rack(&h_c)),
            ("pod_4dfm", t_pod, map_pod),
            ("superpod_4pod", t_sp, map_sp),
        ]
    };

    let mut merged = AuditReport::new();
    let mut clean = 0usize;
    for (name, t, map) in &fabrics {
        let r = audit_fabric(t, map, &cfg);
        println!(
            "  {name:<14} {:>2} rules  {:>3} findings{}",
            r.rules_checked(),
            r.findings().len(),
            if r.is_clean() { "" } else { "  ← NOT CLEAN" }
        );
        if !r.is_clean() {
            print!("{}", r.render());
        } else {
            clean += 1;
        }
        merged.merge(r);
    }
    json.metric("audit.rules_checked", merged.rules_checked() as f64);
    json.metric("audit.catalog_rules", CATALOG.len() as f64);
    json.metric("audit.fabrics_total", fabrics.len() as f64);
    json.metric("audit.fabrics_clean", clean as f64);
    json.metric("audit.findings", merged.findings().len() as f64);

    // The gate's price of entry, timed on the two extremes of scale.
    let (name, t_rack, map_rack) = &fabrics[0];
    assert_eq!(*name, "rack_2dfm");
    let r = bench("audit_fabric(rack, 64 pairs)", || {
        black_box(audit_fabric(t_rack, map_rack, &cfg));
    });
    json.push(&r);
    let (name, t_sp, map_sp) = &fabrics[5];
    assert_eq!(*name, "superpod_4pod");
    let r = bench("audit_fabric(superpod_4pod, 64 pairs)", || {
        black_box(audit_fabric(t_sp, map_sp, &cfg));
    });
    json.push(&r);

    section("seeded-mutation matrix");
    let muts = seeded_mutations();
    let mut caught = 0usize;
    for m in &muts {
        let report = (m.run)();
        let hit = report.has(m.expect);
        let collateral = report.findings().iter().any(|f| f.code != m.expect);
        println!(
            "  {:<22} expect {}  {}",
            m.name,
            m.expect,
            match (hit, collateral) {
                (true, false) => "caught",
                (true, true) => "caught WITH COLLATERAL",
                (false, _) => "MISSED",
            }
        );
        if hit && !collateral {
            caught += 1;
        }
    }
    json.metric("audit.mutations_seeded", muts.len() as f64);
    json.metric("audit.mutations_caught", caught as f64);

    let path = std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_audit.json".into());
    json.write(&path).expect("write bench json");
    println!(
        "\n{clean}/{} fabrics clean, {caught}/{} mutations caught → {path}",
        fabrics.len(),
        muts.len()
    );
    assert_eq!(clean, fabrics.len(), "built-in fabric failed the audit");
    assert_eq!(caught, muts.len(), "a seeded mutation escaped its code");
}
