//! Fig 17 — Performance of Different Intra-Rack Topologies: 2D-FM /
//! 1D-FM-A / 1D-FM-B relative to the intra-rack Clos baseline, across
//! the Table 5 models and sequence lengths 8K–10M, at the 8K SuperPod
//! scale (inter-rack fixed to 2D-FM, as in §6.2).

use ubmesh::coordinator::{Arch, Job};
use ubmesh::util::table::{pct, Table};

const SCALE: usize = 8192;

fn rel(model: &str, seq: f64, arch: Arch) -> f64 {
    Job::new(model, SCALE, seq, arch)
        .unwrap()
        .relative_perf(Arch::ClosIntraRack, None)
        .unwrap()
}

fn main() {
    let models = ["llama-70b", "gpt3-175b", "dense-1t", "gpt4-2t", "moe-10t"];
    let seqs: [f64; 6] = [8192.0, 32768.0, 131072.0, 1048576.0, 4194304.0, 10485760.0];
    let archs = [
        ("2D-FM", Arch::ubmesh_default()),
        ("1D-FM-A", Arch::Fm1dA),
        ("1D-FM-B", Arch::Fm1dB),
    ];

    // --- (a) per-model averages over sequence lengths --------------------
    let mut tbl = Table::with_title(
        "Fig 17-a: training perf relative to Clos (avg over seq lengths)",
        vec!["model", "2D-FM", "1D-FM-A", "1D-FM-B", "paper 2D-FM"],
    );
    let mut avg_2dfm = 0.0;
    for model in models {
        let mut cells = vec![model.to_string()];
        for (_, arch) in archs {
            let mean: f64 =
                seqs.iter().map(|&s| rel(model, s, arch)).sum::<f64>() / seqs.len() as f64;
            if matches!(arch, Arch::UbMesh { .. }) {
                avg_2dfm += mean / models.len() as f64;
                assert!(
                    (0.88..=1.001).contains(&mean),
                    "{model}: 2D-FM at {mean:.3} of Clos"
                );
            }
            cells.push(pct(mean, 1));
        }
        cells.push("93.2–95.9%".into());
        tbl.row(cells);
    }
    tbl.print();

    // --- (b) per-seq-length averages over models --------------------------
    let mut tbl = Table::with_title(
        "Fig 17-b: all-model average by sequence length",
        vec!["seq", "2D-FM", "1D-FM-A", "1D-FM-B"],
    );
    for &seq in &seqs {
        let mut cells = vec![if seq >= 1048576.0 {
            format!("{}M", seq / 1048576.0)
        } else {
            format!("{}K", seq / 1024.0)
        }];
        for (_, arch) in archs {
            let mean: f64 = models
                .iter()
                .map(|m| rel(m, seq, arch))
                .sum::<f64>()
                / models.len() as f64;
            cells.push(pct(mean, 1));
        }
        tbl.row(cells);
    }
    tbl.print();
    println!(
        "\nall-model 2D-FM average: {} (paper: 93.2–95.9% — gap within 7%) ✓",
        pct(avg_2dfm, 1)
    );
    assert!(avg_2dfm > 0.9 && avg_2dfm <= 1.001);
    println!("\nfig17_intra_rack OK");
}
