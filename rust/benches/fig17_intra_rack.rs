//! Fig 17 — Performance of Different Intra-Rack Topologies: 2D-FM /
//! 1D-FM-A / 1D-FM-B relative to the intra-rack Clos baseline, across
//! the Table 5 models and sequence lengths 8K–10M, at the 8K SuperPod
//! scale (inter-rack fixed to 2D-FM, as in §6.2).
//!
//! PR 5 adds a **measured** rack-scale replica of the headline number:
//! the same training iteration (`workload::step::iteration_dag`, TP on
//! boards + SP on columns) executed in the fluid simulator on the real
//! 2D-FM rack vs the real Fig 16-d intra-rack Clos
//! (`topology::variants::rack_clos`, pairs striped over 4 HRS), so the
//! "within ~7% of Clos" claim is a simulator output rather than a
//! bandwidth-model ratio.

use ubmesh::coordinator::{Arch, Job};
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::variants::{rack_1dfm_a, rack_1dfm_b, rack_clos};
use ubmesh::util::table::{pct, Table};
use ubmesh::workload::models::by_name;
use ubmesh::workload::step::{iteration_dag, IterationSpec, RankOrder};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

const SCALE: usize = 8192;

fn rel(model: &str, seq: f64, arch: Arch) -> f64 {
    Job::new(model, SCALE, seq, arch)
        .unwrap()
        .relative_perf(Arch::ClosIntraRack, None)
        .unwrap()
}

fn main() {
    let models = ["llama-70b", "gpt3-175b", "dense-1t", "gpt4-2t", "moe-10t"];
    let seqs: [f64; 6] = [8192.0, 32768.0, 131072.0, 1048576.0, 4194304.0, 10485760.0];
    let archs = [
        ("2D-FM", Arch::ubmesh_default()),
        ("1D-FM-A", Arch::Fm1dA),
        ("1D-FM-B", Arch::Fm1dB),
    ];

    // --- (a) per-model averages over sequence lengths --------------------
    let mut tbl = Table::with_title(
        "Fig 17-a: training perf relative to Clos (avg over seq lengths)",
        vec!["model", "2D-FM", "1D-FM-A", "1D-FM-B", "paper 2D-FM"],
    );
    let mut avg_2dfm = 0.0;
    for model in models {
        let mut cells = vec![model.to_string()];
        for (_, arch) in archs {
            let mean: f64 =
                seqs.iter().map(|&s| rel(model, s, arch)).sum::<f64>() / seqs.len() as f64;
            if matches!(arch, Arch::UbMesh { .. }) {
                avg_2dfm += mean / models.len() as f64;
                assert!(
                    (0.88..=1.001).contains(&mean),
                    "{model}: 2D-FM at {mean:.3} of Clos"
                );
            }
            cells.push(pct(mean, 1));
        }
        cells.push("93.2–95.9%".into());
        tbl.row(cells);
    }
    tbl.print();

    // --- (b) per-seq-length averages over models --------------------------
    let mut tbl = Table::with_title(
        "Fig 17-b: all-model average by sequence length",
        vec!["seq", "2D-FM", "1D-FM-A", "1D-FM-B"],
    );
    for &seq in &seqs {
        let mut cells = vec![if seq >= 1048576.0 {
            format!("{}M", seq / 1048576.0)
        } else {
            format!("{}K", seq / 1024.0)
        }];
        for (_, arch) in archs {
            let mean: f64 = models
                .iter()
                .map(|m| rel(m, seq, arch))
                .sum::<f64>()
                / models.len() as f64;
            cells.push(pct(mean, 1));
        }
        tbl.row(cells);
    }
    tbl.print();
    println!(
        "\nall-model 2D-FM average: {} (paper: 93.2–95.9% — gap within 7%) ✓",
        pct(avg_2dfm, 1)
    );
    assert!(avg_2dfm > 0.9 && avg_2dfm <= 1.001);

    // --- (c) measured: DES iteration on the real rack fabrics ----------
    // All four Fig 16 fabrics now have ClusterMaps, completing the
    // measured figure: 2D-FM, 1D-FM-A (32-LRS cross-board mesh),
    // 1D-FM-B (8-HRS cross-board), each relative to the intra-rack
    // Clos baseline.
    let (ub_t, ub_h) = ubmesh_rack(&RackConfig::default());
    let ub_map = ClusterMap::rack(&ub_h);
    let (a_t, a_h) = rack_1dfm_a();
    let a_map = ClusterMap::fm1d_a(&a_h);
    let (b_t, b_h) = rack_1dfm_b();
    let b_map = ClusterMap::fm1d_b(&b_h);
    let (cl_t, cl_h) = rack_clos();
    let cl_map = ClusterMap::clos_rack(&cl_h);
    let mut tbl = Table::with_title(
        "Fig 17 (measured): rack-scale DES iteration vs intra-rack Clos",
        vec![
            "model",
            "Clos iter (ms)",
            "2D-FM rel",
            "1D-FM-A rel",
            "1D-FM-B rel",
        ],
    );
    for name in ["llama-70b", "gpt4-2t"] {
        let m = by_name(name).unwrap();
        let p = ParallelismConfig {
            tp: 8,
            sp: 8,
            ep: if m.is_moe() { 8 } else { 1 },
            pp: 1,
            dp: 1,
            microbatches: 2,
            tokens_per_microbatch: 8192.0,
        };
        let spec = IterationSpec::default();
        let run = |t: &ubmesh::topology::Topology, map: &ClusterMap| -> f64 {
            let dag = iteration_dag(t, map, &m, &p, RankOrder::TopologyAware, &spec);
            let r = sim::schedule::run(&SimNet::new(t), &dag);
            assert!(!r.is_stalled());
            r.makespan_us
        };
        let t_cl = run(&cl_t, &cl_map);
        // perf ∝ 1/iter-time: each fabric relative to Clos.
        let rel_ub = t_cl / run(&ub_t, &ub_map);
        let rel_a = t_cl / run(&a_t, &a_map);
        let rel_b = t_cl / run(&b_t, &b_map);
        tbl.row(vec![
            name.to_string(),
            format!("{:.1}", t_cl / 1e3),
            pct(rel_ub, 1),
            pct(rel_a, 1),
            pct(rel_b, 1),
        ]);
        // Mirror-measured: llama 0.935 (inside the paper's 93.2–95.9%
        // band); gpt4-2t 0.969 — just above it, because this rack-scale
        // EP config is milder than the paper's full MoE-2T. Both must
        // stay strictly below parity (the Clos fabric's x64/NPU wins
        // the comm phases) and within ~7–10% of it.
        assert!(
            (0.90..0.995).contains(&rel_ub),
            "{name}: measured 2D-FM at {rel_ub:.3} of Clos (paper: 0.932–0.959)"
        );
        // The 1D variants keep the on-board X mesh but funnel all
        // cross-board traffic through switches — Fig 17 orders them at
        // or below 2D-FM, and nothing beats the Clos fabric outright.
        for (label, r) in [("1D-FM-A", rel_a), ("1D-FM-B", rel_b)] {
            assert!(
                (0.35..=1.02).contains(&r),
                "{name}/{label}: measured {r:.3} of Clos out of range"
            );
        }
        assert!(
            rel_a <= rel_ub + 0.02,
            "{name}: 1D-FM-A ({rel_a:.3}) should not beat 2D-FM ({rel_ub:.3})"
        );
    }
    tbl.print();
    println!("\nfig17_intra_rack OK");
}
