//! §Perf — hot-path microbenchmarks for the three layers (see
//! EXPERIMENTS.md §Perf for targets and the iteration log).
//!
//! L3: DES event throughput, max-min allocation, routing lookups,
//!     topology construction, APR enumeration.
//! L2/L1 (via PJRT): artifact execution latency for the cost-model batch
//!     and APSP kernels.

use ubmesh::collectives::ring::ring_allreduce_dag;
use ubmesh::routing::apr::paths_2d;
use ubmesh::routing::table::{LinearTable, Segment, SegmentRoute};
use ubmesh::routing::address::UbAddr;
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::NodeId;
use ubmesh::util::bench::{bench, black_box, section};

fn main() {
    // ---------------- L3: simulator ------------------------------------
    section("L3: discrete-event simulator");
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let board: Vec<NodeId> = (0..8).map(|s| h.npu(0, s, 8)).collect();
    let net = SimNet::new(&t);
    let dag = ring_allreduce_dag(&t, &board, 360e6);
    let mut events_per_run = 0;
    let r = bench("board ring-allreduce DES (14 stages × 8 flows)", || {
        let rep = sim::schedule::run(&net, &dag);
        events_per_run = rep.events;
        black_box(rep.makespan_us);
    });
    println!(
        "  → {:.2}M events/s",
        events_per_run as f64 / r.mean.as_secs_f64() / 1e6
    );

    let rows: Vec<Vec<NodeId>> = (0..8)
        .map(|b| (0..8).map(|s| h.npu(b, s, 8)).collect())
        .collect();
    let cols: Vec<Vec<NodeId>> = (0..8)
        .map(|s| (0..8).map(|b| h.npu(b, s, 8)).collect())
        .collect();
    let hdag = ubmesh::collectives::hierarchical::hierarchical_allreduce_dag(
        &t, &rows, &cols, 360e6,
    );
    let mut ev = 0;
    let r = bench("rack hierarchical allreduce DES (~1.3k flows)", || {
        let rep = sim::schedule::run(&net, &hdag);
        ev = rep.events;
        black_box(rep.makespan_us);
    });
    println!("  → {:.2}M flow-events/s equivalent, {} peak flows", ev as f64 / r.mean.as_secs_f64() / 1e6, {
        let rep = sim::schedule::run(&net, &hdag);
        rep.peak_flows
    });

    // ---------------- L3: routing ----------------------------------------
    section("L3: routing");
    bench("APR enumerate all paths, one rack pair", || {
        black_box(paths_2d((0, 0), (3, 4), 8, 8, true));
    });
    let mut lin = LinearTable::default();
    let local = UbAddr::new(0, 0, 0, 0, 0);
    let (prefix, bits) = local.rack_segment();
    lin.add(Segment {
        prefix,
        bits,
        route: SegmentRoute::Linear {
            base_shift: 8,
            ports: (0..256).map(|i| i as u16).collect(),
        },
    });
    let addr = UbAddr::new(0, 0, 3, 5, 0);
    bench("linear table lookup (single)", || {
        black_box(lin.lookup(addr));
    });

    // ---------------- L3: topology construction ---------------------------
    section("L3: topology construction");
    bench("build 64-NPU rack (+LRS planes)", || {
        black_box(ubmesh_rack(&RackConfig::default()));
    });
    bench("build 1K-NPU pod", || {
        black_box(ubmesh::topology::pod::ubmesh_pod(
            &ubmesh::topology::pod::PodConfig::default(),
        ));
    });

    // ---------------- L2/L1 via PJRT --------------------------------------
    section("L2/L1: PJRT artifact execution");
    match ubmesh::runtime::Artifacts::load(&ubmesh::runtime::Artifacts::default_dir()) {
        Err(e) => println!("skipped (run `make artifacts`): {e:#}"),
        Ok(a) => {
            use ubmesh::workload::models::by_name;
            use ubmesh::workload::placement::TierBandwidth;
            use ubmesh::workload::traffic::table1_config;
            let m = by_name("gpt4-2t").unwrap();
            let bw = TierBandwidth::ubmesh(16, 1.0);
            let cfgs = vec![table1_config(); 256];
            bench("costmodel batch (256 configs, PJRT)", || {
                black_box(a.evaluate_configs(&m, &cfgs, &bw).unwrap());
            });
            let n = 64;
            let mut adj = vec![ubmesh::runtime::artifacts::INF; n * n];
            for i in 0..n {
                adj[i * n + i] = 0.0;
            }
            for l in &t.links {
                let (x, y) = (l.a.idx(), l.b.idx());
                if x < n && y < n {
                    adj[x * n + y] = 1.0;
                    adj[y * n + x] = 1.0;
                }
            }
            bench("apsp64 (min-plus Pallas kernel, PJRT)", || {
                black_box(a.apsp(&adj, n).unwrap());
            });
            // rust-side equivalent of the search evaluator for contrast:
            use ubmesh::workload::placement::Placement;
            use ubmesh::workload::step::iteration_time;
            bench("costmodel batch (256 configs, pure rust)", || {
                for c in &cfgs {
                    black_box(iteration_time(&m, c, &Placement::topology_aware(c), &bw));
                }
            });
        }
    }

    println!("\nperf_hotpaths OK");
}
