//! §Perf — hot-path microbenchmarks for the three layers (see
//! EXPERIMENTS.md §Perf for targets and the iteration log).
//!
//! L3: DES event throughput, max-min allocation, routing lookups,
//!     topology construction, APR enumeration, the SuperPod-scale
//!     solver comparison (rise-only vs the PR 1 full-component solver),
//!     the HRS-routed SuperPod add-path comparison (fall-only bounded
//!     adds vs full-component adds, measured at mid-scale and estimated
//!     at 32K), and the rack-uplink oversubscription sweep.
//! L2/L1 (via PJRT): artifact execution latency for the cost-model batch
//!     and APSP kernels.
//!
//! Emits `BENCH_sim.json` (override the path with the `BENCH_SIM_JSON`
//! env var; schema documented in `rust/benches/README.md`) so the perf
//! trajectory is tracked across PRs — CI uploads it as an artifact.

// Benches measure wall-clock by definition; the Instant::now
// determinism lint (clippy.toml) is for the sim core, not harnesses.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use ubmesh::collectives::alltoall::{superpod_alltoall_dag, superpod_hrs_alltoall_dag};
use ubmesh::collectives::ring::ring_allreduce_dag;
use ubmesh::routing::apr::paths_2d;
use ubmesh::routing::table::{LinearTable, Segment, SegmentRoute};
use ubmesh::routing::address::UbAddr;
use ubmesh::sim::{self, GridBuilder, ResolveStrategy, SimConfig, SimNet, SimReport};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::topology::{NodeId, Topology};
use ubmesh::util::bench::{bench, black_box, section, BenchResult, JsonReport};

/// Time one run of a DAG under the given solver strategy, print it as a
/// bench line, and return (report, timing).
fn timed_run(
    name: &str,
    net: &SimNet,
    dag: &ubmesh::sim::StageDag,
    strategy: ResolveStrategy,
) -> (SimReport, BenchResult) {
    let t0 = Instant::now();
    let rep = sim::schedule::run_with(net, dag, &SimConfig { strategy });
    let el = t0.elapsed();
    let r = BenchResult {
        name: name.to_string(),
        iters: 1,
        mean: el,
        p50: el,
        p99: el,
        total: el,
    };
    println!("{r}");
    println!(
        "  → {} events, {} rate recomputes, {} full-component equiv, \
         {} absorb restarts, {} fallbacks",
        rep.events,
        rep.solver.rate_recomputes,
        rep.solver.full_component_recomputes,
        rep.solver.absorb_restarts,
        rep.solver.fallbacks
    );
    (rep, r)
}

/// nd-fullmesh of `dims ++ [pods]`: electrical intra-pod dims, optical
/// pod tier (the generalized nD-FullMesh SuperPod of §3.3).
fn superpod_mesh(dims: &[usize], pods: usize) -> Topology {
    use ubmesh::topology::CableClass;
    let mut specs: Vec<DimSpec> = dims
        .iter()
        .map(|&d| DimSpec::new(d, 2, CableClass::PassiveElectrical, 1.0))
        .collect();
    specs.push(DimSpec::new(pods, 2, CableClass::Optical, 50.0));
    nd_fullmesh("superpod", &specs)
}

fn main() {
    let mut json = JsonReport::new();

    // ---------------- L3: simulator ------------------------------------
    section("L3: discrete-event simulator");
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let board: Vec<NodeId> = (0..8).map(|s| h.npu(0, s, 8)).collect();
    let net = SimNet::new(&t);
    let dag = ring_allreduce_dag(&t, &board, 360e6);
    let mut events_per_run = 0;
    let r = bench("board ring-allreduce DES (14 stages × 8 flows)", || {
        let rep = sim::schedule::run(&net, &dag);
        events_per_run = rep.events;
        black_box(rep.makespan_us);
    });
    println!(
        "  → {:.2}M events/s",
        events_per_run as f64 / r.mean.as_secs_f64() / 1e6
    );
    json.push(&r);

    let rows: Vec<Vec<NodeId>> = (0..8)
        .map(|b| (0..8).map(|s| h.npu(b, s, 8)).collect())
        .collect();
    let cols: Vec<Vec<NodeId>> = (0..8)
        .map(|s| (0..8).map(|b| h.npu(b, s, 8)).collect())
        .collect();
    let hdag = ubmesh::collectives::hierarchical::hierarchical_allreduce_dag(
        &t, &rows, &cols, 360e6,
    );
    let mut ev = 0;
    let mut pk = 0;
    let r = bench("rack hierarchical allreduce DES (~1.3k flows)", || {
        let rep = sim::schedule::run(&net, &hdag);
        ev = rep.events;
        pk = rep.peak_flows;
        black_box(rep.makespan_us);
    });
    println!(
        "  → {:.2}M flow-events/s equivalent, {} peak flows",
        ev as f64 / r.mean.as_secs_f64() / 1e6,
        pk
    );
    json.push(&r);

    // ---------------- L3: SuperPod-scale solver (ISSUE 2) ----------------
    section("L3: SuperPod-scale solver — rise-only vs PR 1 full-component");

    // Mid-scale slice (8 pods × 8×8 = 512 NPUs): small enough to *run*
    // the PR 1 solver, so the comparison is measured, not estimated.
    let mid_dims = [8usize, 8];
    let mid_pods = 8;
    let tm = superpod_mesh(&mid_dims, mid_pods);
    let netm = SimNet::new(&tm);
    let dagm = superpod_alltoall_dag(&tm, &mid_dims, mid_pods, 4e6, 1.0);
    let (rep_rise, br) = timed_run(
        "superpod 512-NPU a2a, rise-only solver",
        &netm,
        &dagm,
        ResolveStrategy::RiseOnly,
    );
    json.push(&br);
    let rise_wall = br.mean.as_secs_f64();
    let (rep_bfs, br) = timed_run(
        "superpod 512-NPU a2a, PR 1 full-component solver",
        &netm,
        &dagm,
        ResolveStrategy::FullComponentBfs,
    );
    json.push(&br);
    let bfs_wall = br.mean.as_secs_f64();
    // The two strategies must agree — this is a differential test at
    // workload scale, not just a benchmark.
    assert!(
        (rep_rise.makespan_us - rep_bfs.makespan_us).abs()
            <= 1e-6 * rep_bfs.makespan_us,
        "strategy divergence: rise {} vs bfs {} µs",
        rep_rise.makespan_us,
        rep_bfs.makespan_us
    );
    assert!(
        (rep_rise.byte_hops - rep_bfs.byte_hops).abs() <= 1e-6 * rep_bfs.byte_hops,
        "byte-hop divergence"
    );
    let mid_ratio =
        rep_bfs.solver.rate_recomputes as f64 / rep_rise.solver.rate_recomputes as f64;
    println!(
        "  → measured recompute ratio {mid_ratio:.1}x, wall-clock speedup {:.1}x",
        bfs_wall / rise_wall
    );
    assert!(
        mid_ratio >= 5.0,
        "acceptance: ≥5x fewer recomputations (measured {mid_ratio:.2}x)"
    );
    json.metric("superpod_mid.npus", (512) as f64);
    json.metric("superpod_mid.events", rep_rise.events as f64);
    json.metric(
        "superpod_mid.rate_recomputes_rise",
        rep_rise.solver.rate_recomputes as f64,
    );
    json.metric(
        "superpod_mid.rate_recomputes_pr1_measured",
        rep_bfs.solver.rate_recomputes as f64,
    );
    json.metric(
        "superpod_mid.full_component_estimate",
        rep_rise.solver.full_component_recomputes as f64,
    );
    json.metric("superpod_mid.recompute_ratio_measured", mid_ratio);
    json.metric("superpod_mid.wallclock_speedup", bfs_wall / rise_wall);
    json.metric(
        "superpod_mid.wall_us_per_event",
        rise_wall * 1e6 / rep_rise.events as f64,
    );
    json.metric(
        "superpod_mid.add_rate_recomputes",
        rep_rise.solver.add_rate_recomputes as f64,
    );

    // Full scale: 8 pods × 4096 = 32 768 NPUs, both solvers — the
    // inter-pod sharing graph keeps components bounded (hundreds of
    // flows), so even the PR 1 full-component solver completes and the
    // comparison is fully *measured* at acceptance scale, with the
    // union-find live-size estimate reported alongside as a
    // cross-check.
    let full_dims = [8usize, 8, 8, 8];
    let full_pods = 8;
    let tf = superpod_mesh(&full_dims, full_pods);
    let netf = SimNet::new(&tf);
    let dagf = superpod_alltoall_dag(&tf, &full_dims, full_pods, 2e6, 1.0);
    let (rep32, br) = timed_run(
        "superpod 32768-NPU a2a, rise-only solver",
        &netf,
        &dagf,
        ResolveStrategy::RiseOnly,
    );
    json.push(&br);
    let rise32_wall = br.mean.as_secs_f64();
    let (rep32b, br) = timed_run(
        "superpod 32768-NPU a2a, PR 1 full-component solver",
        &netf,
        &dagf,
        ResolveStrategy::FullComponentBfs,
    );
    json.push(&br);
    assert!(
        (rep32.makespan_us - rep32b.makespan_us).abs() <= 1e-6 * rep32b.makespan_us,
        "strategy divergence at 32K: rise {} vs bfs {} µs",
        rep32.makespan_us,
        rep32b.makespan_us
    );
    let ratio32 =
        rep32b.solver.rate_recomputes as f64 / rep32.solver.rate_recomputes as f64;
    let est32 = rep32.solver.full_component_recomputes as f64
        / rep32.solver.rate_recomputes as f64;
    let per_event_rise = rep32.solver.rate_recomputes as f64 / rep32.events as f64;
    let per_event_pr1 =
        rep32b.solver.rate_recomputes as f64 / rep32b.events as f64;
    println!(
        "  → {per_event_rise:.1} recomputes/event (rise-only) vs {per_event_pr1:.0} \
         (PR 1 measured): {ratio32:.0}x measured, {est32:.0}x estimated, \
         wall-clock speedup {:.1}x",
        br.mean.as_secs_f64() / rise32_wall
    );
    assert!(
        ratio32 >= 5.0,
        "acceptance: ≥5x fewer recomputations per event at 32K (measured {ratio32:.2}x)"
    );
    json.metric("superpod32k.npus", 32768.0);
    json.metric("superpod32k.makespan_us", rep32.makespan_us);
    json.metric("superpod32k.wall_s", rise32_wall);
    json.metric("superpod32k.pr1_wall_s", br.mean.as_secs_f64());
    json.metric("superpod32k.events", rep32.events as f64);
    json.metric("superpod32k.peak_flows", rep32.peak_flows as f64);
    json.metric(
        "superpod32k.rate_recomputes",
        rep32.solver.rate_recomputes as f64,
    );
    json.metric(
        "superpod32k.rate_recomputes_pr1_measured",
        rep32b.solver.rate_recomputes as f64,
    );
    json.metric(
        "superpod32k.full_component_recomputes",
        rep32.solver.full_component_recomputes as f64,
    );
    json.metric("superpod32k.recomputes_per_event", per_event_rise);
    json.metric("superpod32k.pr1_recomputes_per_event", per_event_pr1);
    json.metric("superpod32k.recompute_ratio", ratio32);
    json.metric("superpod32k.recompute_ratio_estimated", est32);
    json.metric(
        "superpod32k.absorb_restarts",
        rep32.solver.absorb_restarts as f64,
    );
    json.metric("superpod32k.fallbacks", rep32.solver.fallbacks as f64);
    json.metric("superpod32k.uf_rebuilds", rep32.solver.uf_rebuilds as f64);
    json.metric(
        "superpod32k.wall_us_per_event",
        rise32_wall * 1e6 / rep32.events as f64,
    );

    // ---------------- L3: HRS-routed SuperPod — fall-only adds (ISSUE 3) --
    section("L3: HRS SuperPod — fall-only bounded adds vs full-component");

    // Mid-scale (4 pods × 2×2 racks = 1024 NPUs, 3 peer pods): all
    // three strategies are *executed*, so the add-path comparison is
    // measured, and the union-find live estimate the 32K test relies on
    // is validated against the measured full-component add work.
    let mut mid_cfg = SuperPodConfig::default();
    mid_cfg.pods = 4;
    mid_cfg.pod.rows = 2;
    mid_cfg.pod.cols = 2;
    let (tm, hm) = ubmesh_superpod(&mid_cfg);
    let dagm = superpod_hrs_alltoall_dag(&tm, &hm, 2e6, 1.0, 3);
    let netm = SimNet::new(&tm);
    let (rep_bnd, br) = timed_run(
        "hrs superpod 1024-NPU a2a, bounded (fall-only adds)",
        &netm,
        &dagm,
        ResolveStrategy::Bounded,
    );
    json.push(&br);
    let bnd_wall = br.mean.as_secs_f64();
    let (rep_ros, br) = timed_run(
        "hrs superpod 1024-NPU a2a, rise-only (PR 2 full-component adds)",
        &netm,
        &dagm,
        ResolveStrategy::RiseOnly,
    );
    json.push(&br);
    let (rep_fcb, br) = timed_run(
        "hrs superpod 1024-NPU a2a, PR 1 full-component solver",
        &netm,
        &dagm,
        ResolveStrategy::FullComponentBfs,
    );
    json.push(&br);
    let fcb_wall = br.mean.as_secs_f64();
    for (name, rep) in [("rise-only", &rep_ros), ("PR 1", &rep_fcb)] {
        assert!(
            (rep_bnd.makespan_us - rep.makespan_us).abs() <= 1e-6 * rep.makespan_us,
            "strategy divergence vs {name}: {} vs {} µs",
            rep_bnd.makespan_us,
            rep.makespan_us
        );
        assert!(
            (rep_bnd.byte_hops - rep.byte_hops).abs() <= 1e-6 * rep.byte_hops,
            "byte-hop divergence vs {name}"
        );
    }
    let add_ratio_measured = rep_fcb.solver.add_rate_recomputes as f64
        / rep_bnd.solver.add_rate_recomputes as f64;
    let add_ratio_estimated = rep_bnd.solver.add_full_component_recomputes as f64
        / rep_bnd.solver.add_rate_recomputes as f64;
    println!(
        "  → add path: {} bounded vs {} full-component recomputes — \
         {add_ratio_measured:.1}x measured, {add_ratio_estimated:.1}x estimated, \
         wall-clock speedup {:.1}x",
        rep_bnd.solver.add_rate_recomputes,
        rep_fcb.solver.add_rate_recomputes,
        fcb_wall / bnd_wall
    );
    assert!(
        add_ratio_measured >= 3.0,
        "acceptance: ≥3x fewer add-path recomputations (measured {add_ratio_measured:.2}x)"
    );
    // The estimator the 32K scale test leans on must track the measured
    // full-component add work (exactly equal on the reference port; the
    // band allows for fp-batching differences between the two runs).
    let est = rep_bnd.solver.add_full_component_recomputes as f64;
    let meas = rep_ros.solver.add_rate_recomputes as f64;
    assert!(
        est >= 0.8 * meas && est <= 1.25 * meas,
        "estimate drifted from measured full-component add work: {est} vs {meas}"
    );
    json.metric("hrs_mid.npus", 1024.0);
    json.metric("hrs_mid.events", rep_bnd.events as f64);
    json.metric(
        "hrs_mid.add_rate_recomputes_bounded",
        rep_bnd.solver.add_rate_recomputes as f64,
    );
    json.metric(
        "hrs_mid.add_rate_recomputes_rise_measured",
        rep_ros.solver.add_rate_recomputes as f64,
    );
    json.metric(
        "hrs_mid.add_rate_recomputes_pr1_measured",
        rep_fcb.solver.add_rate_recomputes as f64,
    );
    json.metric(
        "hrs_mid.add_full_component_estimate",
        rep_bnd.solver.add_full_component_recomputes as f64,
    );
    json.metric("hrs_mid.add_recompute_ratio_measured", add_ratio_measured);
    json.metric("hrs_mid.add_recompute_ratio_estimated", add_ratio_estimated);
    json.metric(
        "hrs_mid.add_absorb_restarts",
        rep_bnd.solver.add_absorb_restarts as f64,
    );
    json.metric("hrs_mid.add_fallbacks", rep_bnd.solver.add_fallbacks as f64);
    json.metric(
        "hrs_mid.wall_us_per_event_bounded",
        bnd_wall * 1e6 / rep_bnd.events as f64,
    );
    json.metric(
        "hrs_mid.wall_us_per_event_pr1",
        fcb_wall * 1e6 / rep_fcb.events as f64,
    );
    json.metric("hrs_mid.wallclock_speedup", fcb_wall / bnd_wall);

    // Full scale: 32 pods × 1024 = 32 768 NPUs over 256 HRS, bounded
    // only — on this workload a full-component add pays the whole live
    // component per staggered gate (quadratic in the phase size), which
    // is exactly why the fall-only add exists; the measured comparison
    // lives at mid-scale above, the validated estimator reports the
    // ratio here.
    let mut full_cfg = SuperPodConfig::default();
    full_cfg.pods = 32;
    let (tf2, hf2) = ubmesh_superpod(&full_cfg);
    let dagf2 = superpod_hrs_alltoall_dag(&tf2, &hf2, 1e6, 1.0, 3);
    let netf2 = SimNet::new(&tf2);
    let (rep32h, br) = timed_run(
        "hrs superpod 32768-NPU a2a, bounded (fall-only adds)",
        &netf2,
        &dagf2,
        ResolveStrategy::Bounded,
    );
    json.push(&br);
    let h32_wall = br.mean.as_secs_f64();
    let s32 = &rep32h.solver;
    let add_ratio_32k =
        s32.add_full_component_recomputes as f64 / s32.add_rate_recomputes as f64;
    println!(
        "  → 32K add path: {:.1} recomputes per stage-gate add (bounded) vs \
         {:.0} (full-component estimate): {add_ratio_32k:.0}x",
        s32.add_rate_recomputes as f64 / s32.add_resolves.max(1) as f64,
        s32.add_full_component_recomputes as f64 / s32.add_resolves.max(1) as f64,
    );
    assert!(
        add_ratio_32k >= 3.0,
        "acceptance: ≥3x fewer add-path recomputations at 32K (estimated {add_ratio_32k:.2}x)"
    );
    json.metric("hrs32k.npus", 32768.0);
    json.metric("hrs32k.makespan_us", rep32h.makespan_us);
    json.metric("hrs32k.wall_s", h32_wall);
    json.metric("hrs32k.events", rep32h.events as f64);
    json.metric(
        "hrs32k.wall_us_per_event",
        h32_wall * 1e6 / rep32h.events as f64,
    );
    json.metric("hrs32k.peak_flows", rep32h.peak_flows as f64);
    json.metric("hrs32k.add_resolves", s32.add_resolves as f64);
    json.metric("hrs32k.add_rate_recomputes", s32.add_rate_recomputes as f64);
    json.metric(
        "hrs32k.add_full_component_recomputes",
        s32.add_full_component_recomputes as f64,
    );
    json.metric("hrs32k.add_recompute_ratio_estimated", add_ratio_32k);
    json.metric("hrs32k.add_absorb_restarts", s32.add_absorb_restarts as f64);
    json.metric("hrs32k.add_fallbacks", s32.add_fallbacks as f64);
    json.metric("hrs32k.fallbacks", s32.fallbacks as f64);
    json.metric("hrs32k.uf_rebuilds", s32.uf_rebuilds as f64);

    // ---------------- L3: rack-uplink oversubscription sweep ---------------
    section("L3: SuperPod rack-uplink oversubscription sweep (1:1 / 2:1 / 4:1)");
    // GridBuilder sweep at 512 NPUs: uniform payloads (batched events)
    // isolate the bandwidth effect. Structural expectation: the rack's
    // board→uplink backplane mesh aggregates 8×8×x2 = 800 GB/s per
    // direction, *half* the 1:1 uplink's x256 = 1600 GB/s — so up to
    // 2:1 the mesh saturates first and oversubscription is (nearly)
    // free, while 4:1 (400 GB/s) pushes the bottleneck onto the
    // uplinks and strictly lengthens the phase. The sweep records all
    // three and asserts non-decreasing overall + strictly longer at
    // 4:1 — the switch-port-economy trade the §3.3.4 analysis makes.
    let ratios = [1u32, 2, 4];
    let grid = GridBuilder::cartesian1(&ratios, |&r| Some(r));
    let interpod: Vec<(u32, f64)> = grid.run(|_i, &os, _rng| {
        let mut cfg = SuperPodConfig::default();
        cfg.pods = 2;
        cfg.pod.rows = 2;
        cfg.pod.cols = 2;
        cfg.uplink_oversub = os;
        let (t, h) = ubmesh_superpod(&cfg);
        let dag = superpod_hrs_alltoall_dag(&t, &h, 4e6, 0.0, 1);
        let net = SimNet::new(&t);
        let r = sim::schedule::run(&net, &dag);
        (os, r.makespan_us - r.stage_done_us[1])
    });
    for &(os, us) in &interpod {
        println!("  {os}:1 rack-uplink oversubscription → inter-pod phase {us:.0} µs");
        json.metric(format!("oversub.r{os}.interpod_us"), us);
    }
    assert!(
        interpod.windows(2).all(|w| w[1].1 >= w[0].1 * (1.0 - 1e-9)),
        "inter-pod phase must not shorten with oversubscription: {interpod:?}"
    );
    assert!(
        interpod[2].1 > interpod[0].1 * 1.5,
        "4:1 must strictly lengthen the inter-pod phase: {interpod:?}"
    );

    // ---------------- L3: routing ----------------------------------------
    section("L3: routing");
    let r = bench("APR enumerate all paths, one rack pair", || {
        black_box(paths_2d((0, 0), (3, 4), 8, 8, true));
    });
    json.push(&r);
    let mut lin = LinearTable::default();
    let local = UbAddr::new(0, 0, 0, 0, 0);
    let (prefix, bits) = local.rack_segment();
    lin.add(Segment {
        prefix,
        bits,
        route: SegmentRoute::Linear {
            base_shift: 8,
            ports: (0..256).map(|i| i as u16).collect(),
        },
    });
    let addr = UbAddr::new(0, 0, 3, 5, 0);
    let r = bench("linear table lookup (single)", || {
        black_box(lin.lookup(addr));
    });
    json.push(&r);

    // ---------------- L3: topology construction ---------------------------
    section("L3: topology construction");
    let r = bench("build 64-NPU rack (+LRS planes)", || {
        black_box(ubmesh_rack(&RackConfig::default()));
    });
    json.push(&r);
    let r = bench("build 1K-NPU pod", || {
        black_box(ubmesh::topology::pod::ubmesh_pod(
            &ubmesh::topology::pod::PodConfig::default(),
        ));
    });
    json.push(&r);

    // ---------------- L2/L1 via PJRT --------------------------------------
    section("L2/L1: PJRT artifact execution");
    match ubmesh::runtime::Artifacts::load(&ubmesh::runtime::Artifacts::default_dir()) {
        Err(e) => println!("skipped (run `make artifacts`): {e:#}"),
        Ok(a) => {
            use ubmesh::workload::models::by_name;
            use ubmesh::workload::placement::TierBandwidth;
            use ubmesh::workload::traffic::table1_config;
            let m = by_name("gpt4-2t").unwrap();
            let bw = TierBandwidth::ubmesh(16, 1.0);
            let cfgs = vec![table1_config(); 256];
            bench("costmodel batch (256 configs, PJRT)", || {
                black_box(a.evaluate_configs(&m, &cfgs, &bw).unwrap());
            });
            let n = 64;
            let mut adj = vec![ubmesh::runtime::artifacts::INF; n * n];
            for i in 0..n {
                adj[i * n + i] = 0.0;
            }
            for l in &t.links {
                let (x, y) = (l.a.idx(), l.b.idx());
                if x < n && y < n {
                    adj[x * n + y] = 1.0;
                    adj[y * n + x] = 1.0;
                }
            }
            bench("apsp64 (min-plus Pallas kernel, PJRT)", || {
                black_box(a.apsp(&adj, n).unwrap());
            });
            // rust-side equivalent of the search evaluator for contrast:
            use ubmesh::workload::placement::Placement;
            use ubmesh::workload::step::iteration_time;
            bench("costmodel batch (256 configs, pure rust)", || {
                for c in &cfgs {
                    black_box(iteration_time(&m, c, &Placement::topology_aware(c), &bw));
                }
            });
        }
    }

    let path = std::env::var("BENCH_SIM_JSON").unwrap_or_else(|_| "BENCH_sim.json".into());
    match json.write(&path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\nfailed to write {path}: {e}"),
    }
    println!("\nperf_hotpaths OK");
}
