//! Integration: topology construction ↔ routing (APR, TFC, addressing)
//! across the real UB-Mesh structures, not synthetic meshes.

use ubmesh::routing::address::UbAddr;
use ubmesh::routing::apr::{paths_2d, to_routed, PathKind, PathSet};
use ubmesh::routing::spf::shortest_paths;
use ubmesh::routing::srheader::{HopMode, SrHeader};
use ubmesh::routing::tfc::{routing_dims, verify_deadlock_free};
use ubmesh::topology::pod::{ubmesh_pod, PodConfig};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::NodeKind;

#[test]
fn rack_apr_paths_are_physical_and_deadlock_free() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let node = |x: usize, y: usize| h.npu(y, x, 8);
    let mut all = Vec::new();
    for (s, d) in [(0usize, 27usize), (5, 62), (8, 9), (0, 7), (1, 57)] {
        let mesh = paths_2d((s % 8, s / 8), (d % 8, d / 8), 8, 8, true);
        for mp in &mesh {
            let r = to_routed(mp, node);
            t.validate_path(&r.nodes).unwrap();
            all.push(r);
        }
    }
    let vls = verify_deadlock_free(&t, &all).unwrap();
    assert!(vls.iter().flatten().all(|&v| v <= 1), "2 VLs max");
}

#[test]
fn apr_aggregate_bandwidth_exceeds_spf() {
    // Fig 10: APR exposes far more bandwidth than shortest-path-first.
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let node = |x: usize, y: usize| h.npu(y, x, 8);
    let src = node(0, 0);
    let dst = node(3, 4);
    let spf = shortest_paths(&t, src, dst, 64, true);
    let spf_bw: f64 = spf.iter().map(|p| p.bottleneck_gb_s(&t)).sum();
    let apr: Vec<_> = paths_2d((0, 0), (4, 3), 8, 8, true)
        .iter()
        .map(|m| to_routed(m, |x, y| h.npu(y, x, 8)))
        .collect();
    let ps = PathSet::weighted_by_bottleneck(apr, &t);
    assert!(
        ps.aggregate_gb_s(&t) > spf_bw,
        "APR {} vs SPF {} GB/s",
        ps.aggregate_gb_s(&t),
        spf_bw
    );
}

#[test]
fn sr_header_covers_pod_scale_paths() {
    // Any intra-pod path fits the 12-hop / 6-SR-instruction budget.
    let cfg = PodConfig::default();
    let (t, h) = ubmesh_pod(&cfg);
    let a = h.rack(0, 0).npus[0];
    let b = h.rack(3, 3).npus[63];
    let path = t.shortest_path(a, b, true).unwrap();
    assert!(path.len() - 1 <= 12, "pod path {} hops", path.len() - 1);
    let hops: Vec<HopMode> = (0..path.len() - 1).map(|i| HopMode::Source(i as u8)).collect();
    let hdr = SrHeader::for_path(&hops[..hops.len().min(6)]);
    let bytes = hdr.encode();
    assert_eq!(SrHeader::decode(&bytes), hdr);
}

#[test]
fn pod_paths_have_valid_tfc_dims() {
    let cfg = PodConfig::default();
    let (t, h) = ubmesh_pod(&cfg);
    // Cross-rack path: NPU → LRS fabric → peer rack NPU.
    let a = h.rack(0, 0).npus[7];
    let b = h.rack(0, 2).npus[40];
    let p = t.shortest_path(a, b, true).unwrap();
    let dims = routing_dims(&t, &p);
    assert!(
        ubmesh::routing::tfc::assign_vls(&dims).is_some(),
        "cross-rack path dims {dims:?} must be ≤2-VL schedulable"
    );
}

#[test]
fn structured_addresses_match_topology() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    for (i, &n) in h.npus.iter().enumerate() {
        let loc = t.node(n).loc;
        let addr = UbAddr::of(&loc, NodeKind::Npu);
        assert_eq!(addr.board() as usize, i / 8);
        assert_eq!(addr.slot() as usize, i % 8);
        assert_eq!(addr.kind(), 0);
    }
}

#[test]
fn detour_paths_only_when_requested() {
    let ps = paths_2d((0, 0), (3, 3), 8, 8, false);
    assert!(ps.iter().all(|p| p.kind == PathKind::Direct));
    let ps = paths_2d((0, 0), (3, 3), 8, 8, true);
    assert!(ps.iter().any(|p| p.kind == PathKind::Detour));
}
