//! Pod-scale acceptance test (ISSUE 1): the incremental max-min solver +
//! heap-driven DAG runner complete a 4096-node (8×8×8×8) nd-fullmesh
//! dimension-wise all-to-all — 4 chained phases of 28 672 single-hop
//! flows each (114 688 flows total, ~57k links / ~115k directed
//! channels). The seed's quadratic solver re-scanned every active flow ×
//! hop per filling round per event; this finishes because the rebuilt
//! core touches only the channels that actually bind.

use ubmesh::collectives::alltoall::dimwise_alltoall_dag;
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::ndmesh::{expected_links, nd_fullmesh, DimSpec};
use ubmesh::topology::ublink::LANE_GB_S;
use ubmesh::topology::CableClass;

#[test]
fn pod_scale_4d_fullmesh_alltoall_completes() {
    let dims = [8usize, 8, 8, 8]; // 4096 NPUs — the paper's Pod
    let specs: Vec<DimSpec> = dims
        .iter()
        .map(|&d| DimSpec::new(d, 2, CableClass::PassiveElectrical, 1.0))
        .collect();
    let t = nd_fullmesh("pod4096", &specs);
    assert_eq!(t.node_count(), 4096);
    assert_eq!(t.link_count(), expected_links(&dims)); // 57 344

    let bytes = 4e6; // per (node, dim-peer) payload
    let dag = dimwise_alltoall_dag(&t, &dims, bytes);
    assert_eq!(dag.stages.len(), 4);
    let flows_per_phase = 4096 * 7;
    for s in &dag.stages {
        assert!(s.is_lazy(), "phases must be lazily materialized");
        assert_eq!(s.flow_count(), flows_per_phase);
    }

    let net = SimNet::new(&t);
    let r = sim::schedule::run(&net, &dag);

    // Every directed channel carries exactly one flow per phase, so each
    // phase runs at full per-link bandwidth (x2 lanes = 12.5 GB/s) and
    // the makespan has a closed form: 4 × (latency + bytes / bw).
    let bw = 2.0 * LANE_GB_S;
    let phase_us = bytes / (bw * 1e3);
    let expect = 4.0 * phase_us;
    assert!(
        (r.makespan_us - expect).abs() / expect < 0.02,
        "makespan {} vs closed-form {expect}",
        r.makespan_us
    );

    // All four phases really ran (byte-hop conservation at scale).
    let total_bytes = 4.0 * flows_per_phase as f64 * bytes;
    assert!(
        (r.byte_hops - total_bytes).abs() / total_bytes < 1e-6,
        "byte-hops {} vs {total_bytes}",
        r.byte_hops
    );
    assert_eq!(r.peak_flows, flows_per_phase, "phases are serialized");
    assert!(r.events as usize >= 4 * flows_per_phase, "events {}", r.events);
}
