//! The static model auditor's integration suite (ISSUE 9 tentpole).
//!
//! Three claims, each load-bearing for the ROADMAP item-3 bake-off
//! gate:
//!
//! 1. **Clean fabrics are clean.** Every built-in fabric — 2D-FM rack,
//!    the Fig 16 1D-FM-A/B and Clos variants, the 4D-FM pod, a 4-pod
//!    SuperPod, plus the torus/dragonfly candidates — passes
//!    [`audit_fabric`] (or the topology/path subset that applies) with
//!    zero findings. So do the iteration / checkpoint / shrunk DAGs,
//!    sampled fault groups, fault plans and replica maps. Any finding
//!    here is either a real model defect or an auditor false positive;
//!    both block the gate.
//! 2. **Seeded defects are caught, precisely.** Every mutation in
//!    [`seeded_mutations`] is detected by exactly the diagnostic code
//!    its class declares — no misses, no collateral findings from
//!    other rules (a noisy auditor trains people to ignore it).
//! 3. **Cleanliness generalizes.** Random valid rack geometries (the
//!    property test) and a [`GridBuilder`] grid of board/slot
//!    configurations audit clean, not just the defaults the other
//!    tests pin.

use std::collections::BTreeSet;

use ubmesh::reliability::faultgen::{BlastClass, FaultDomains, FaultGen, FaultGenConfig};
use ubmesh::reliability::montecarlo::ReplicaMap;
use ubmesh::reliability::AfrBreakdown;
use ubmesh::sim::sweep::GridBuilder;
use ubmesh::topology::dcn::{add_dcn_layer, DcnAttach};
use ubmesh::topology::dragonfly::dragonfly;
use ubmesh::topology::pod::{ubmesh_pod, PodConfig};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::topology::torus::torus;
use ubmesh::topology::variants::{rack_1dfm_a, rack_1dfm_b, rack_clos};
use ubmesh::topology::{NodeId, Topology};
use ubmesh::util::prop::forall;
use ubmesh::verify::audit::{
    audit_checkpoint_dag, audit_fault_group, audit_fault_plan, audit_iteration_bytes,
    audit_path_family, audit_replica_map, audit_shrunk_dag, audit_stage_dag,
    audit_stage_dag_flows, audit_topology,
};
use ubmesh::verify::mutate::seeded_mutations;
use ubmesh::verify::{audit_fabric, AuditConfig, AuditReport, CATALOG};
use ubmesh::workload::models::by_name;
use ubmesh::workload::step::{
    checkpoint_flow_dag, iteration_dag, shrunk_iteration_dag, IterationSpec, RankOrder,
};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

/// Fail with the rendered report so the finding list is in the test
/// output, not just a count.
fn assert_clean(what: &str, r: &AuditReport) {
    assert!(r.is_clean(), "{what} is not audit-clean:\n{}", r.render());
}

fn rack_parallelism(model: &'static str, ep: usize) -> (ubmesh::workload::ModelConfig, ParallelismConfig) {
    // 64-NPU rack: tp·sp·pp·dp = 8·2·2·2 = 64; ep ∈ {1, 2} divides sp·dp.
    let m = by_name(model).unwrap();
    let p = ParallelismConfig {
        tp: 8,
        sp: 2,
        ep,
        pp: 2,
        dp: 2,
        microbatches: 2,
        tokens_per_microbatch: 4096.0,
    };
    (m, p)
}

// ---------------------------------------------------------------------
// Catalog shape
// ---------------------------------------------------------------------

#[test]
fn catalog_is_well_formed() {
    assert!(CATALOG.len() >= 15, "only {} rules cataloged", CATALOG.len());
    let codes: BTreeSet<&str> = CATALOG.iter().map(|(c, _)| *c).collect();
    assert_eq!(codes.len(), CATALOG.len(), "duplicate codes in CATALOG");
    for (code, what) in CATALOG {
        assert!(code.starts_with("AUD") && code.len() == 6, "malformed code {code}");
        assert!(!what.is_empty(), "{code} has no description");
    }
    // Codes are listed in ascending order — the catalog doubles as the
    // docs/AUDIT.md table of contents.
    let listed: Vec<&str> = CATALOG.iter().map(|(c, _)| *c).collect();
    let mut sorted = listed.clone();
    sorted.sort_unstable();
    assert_eq!(listed, sorted, "CATALOG not in code order");
}

// ---------------------------------------------------------------------
// Claim 1: every built-in fabric audits clean
// ---------------------------------------------------------------------

#[test]
fn rack_fabric_audits_clean() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let r = audit_fabric(&t, &ClusterMap::rack(&h), &AuditConfig::default());
    assert_clean("2D-FM rack", &r);
    // The bake-off gate actually exercises the breadth it claims:
    // topology, path and selector families all ran.
    assert!(
        r.rules_checked() >= 10,
        "audit_fabric checked only {} rules: {:?}",
        r.rules_checked(),
        r.checked_codes()
    );
}

#[test]
fn variant_fabrics_audit_clean() {
    let cfg = AuditConfig::default();
    let (t, h) = rack_1dfm_a();
    assert_clean("1D-FM-A", &audit_fabric(&t, &ClusterMap::fm1d_a(&h), &cfg));
    let (t, h) = rack_1dfm_b();
    assert_clean("1D-FM-B", &audit_fabric(&t, &ClusterMap::fm1d_b(&h), &cfg));
    let (t, h) = rack_clos();
    assert_clean("Clos rack", &audit_fabric(&t, &ClusterMap::clos_rack(&h), &cfg));
}

#[test]
fn pod_fabric_audits_clean() {
    let (t, h) = ubmesh_pod(&PodConfig::default());
    let r = audit_fabric(&t, &ClusterMap::pod(&h), &AuditConfig::default());
    assert_clean("4D-FM pod", &r);
}

#[test]
fn superpod_4pod_fabric_audits_clean() {
    let cfg = SuperPodConfig {
        pods: 4,
        ..SuperPodConfig::default()
    };
    let (t, h) = ubmesh_superpod(&cfg);
    assert_eq!(h.npus(), 4096);
    let r = audit_fabric(&t, &ClusterMap::superpod(&h), &AuditConfig::default());
    assert_clean("4-pod SuperPod", &r);
}

/// The non-UB candidates (ROADMAP item 3) get the topology rules plus
/// sampled shortest-path audits — they have no ClusterMap yet, which is
/// exactly why `audit_fabric` is the eligibility seam: wiring one up
/// and passing it is the price of entry to the bake-off.
#[test]
fn torus_and_dragonfly_audit_clean() {
    let fabrics: Vec<(Topology, Vec<NodeId>)> =
        vec![torus("torus-4x4x4", &[4, 4, 4], 2), dragonfly("dragonfly-p4", 4, 2)];
    for (t, npus) in &fabrics {
        let mut r = AuditReport::new();
        audit_topology(&mut r, t);
        let n = npus.len();
        for i in 0..32usize {
            let a = npus[(i * 13) % n];
            let b = npus[((i * 13) + 1 + (i * 29) % (n - 1)) % n];
            if a == b {
                continue;
            }
            let path = t
                .shortest_path(a, b, true)
                .unwrap_or_else(|| panic!("{}: no path {a} → {b}", t.name));
            audit_path_family(&mut r, t, &format!("{} {a}->{b}", t.name), &[path], a, b, false);
        }
        assert_clean(&t.name, &r);
    }
}

// ---------------------------------------------------------------------
// Claim 1 continued: DAGs, faults, replicas
// ---------------------------------------------------------------------

#[test]
fn iteration_dags_audit_clean() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let map = ClusterMap::rack(&h);
    let spec = IterationSpec::default();
    // Dense and MoE (the latter exercises the -ep stage family).
    for (model, ep) in [("llama-70b", 1), ("moe-10t", 2)] {
        let (m, p) = rack_parallelism(model, ep);
        let dag = iteration_dag(&t, &map, &m, &p, RankOrder::TopologyAware, &spec);
        let mut r = AuditReport::new();
        audit_stage_dag(&mut r, model, &dag);
        audit_stage_dag_flows(&mut r, &t, model, &dag);
        audit_iteration_bytes(&mut r, model, &m, &p, &spec, &dag);
        assert_clean(&format!("iteration DAG ({model})"), &r);
        assert!(r.rules_checked() >= 4);
    }
}

#[test]
fn checkpoint_dags_audit_clean() {
    let (mut t, h) = ubmesh_rack(&RackConfig::default());
    let dcn = add_dcn_layer(
        &mut t,
        std::slice::from_ref(&h),
        2,
        DcnAttach::UbSwitch { lanes_per_rack: 8 },
    );
    let map = ClusterMap::rack(&h);
    let bytes = 10e6;
    for to_storage in [true, false] {
        let dag = checkpoint_flow_dag(&t, &map, &dcn, bytes, to_storage);
        let mut r = AuditReport::new();
        audit_stage_dag(&mut r, "ckpt", &dag);
        audit_checkpoint_dag(&mut r, &t, "ckpt", &map, &dcn, bytes, to_storage, &dag);
        assert_clean(
            if to_storage { "checkpoint write DAG" } else { "checkpoint read DAG" },
            &r,
        );
    }
}

#[test]
fn shrunk_dag_audits_clean_and_replica_map_partitions() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let map = ClusterMap::rack(&h);
    let (m, p) = rack_parallelism("llama-70b", 1);
    let order = RankOrder::TopologyAware;

    let rm = ReplicaMap::new(&map, &p, order);
    let mut r = AuditReport::new();
    audit_replica_map(&mut r, "rack dp=2", &map, &p, &rm);
    assert_clean("replica map", &r);

    let dead_dp = 1;
    let dead: BTreeSet<NodeId> = map
        .npus()
        .iter()
        .copied()
        .filter(|&n| rm.replica_of(n) == Some(dead_dp))
        .collect();
    assert_eq!(dead.len(), map.npu_count() / p.dp);
    let dag = shrunk_iteration_dag(&t, &map, &m, &p, order, &IterationSpec::default(), dead_dp);
    let mut r = AuditReport::new();
    audit_stage_dag(&mut r, "shrunk", &dag);
    audit_shrunk_dag(&mut r, &t, "shrunk", &dag, &dead);
    assert_clean("shrunk iteration DAG", &r);
}

#[test]
fn sampled_fault_groups_and_plans_audit_clean() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let domains = FaultDomains::rack(&t, &h);
    let afr = AfrBreakdown {
        electrical_cables: 30.0,
        optical: 30.0,
        lrs: 20.0,
        hrs: 8.9,
    };
    let cfg = FaultGenConfig {
        npu_fleet_afr: 5.0,
        ..FaultGenConfig::default()
    };
    let gen = FaultGen::new(domains, &afr, cfg);
    let mut rng = ubmesh::util::rng::Rng::new(0xAD17);
    let mut r = AuditReport::new();
    // Every blast class, several draws each: the group must stay inside
    // its declared domain and its plan must be a well-ordered timeline.
    for class in BlastClass::ALL {
        for i in 0..8 {
            let g = gen.sample_group(class, &mut rng);
            audit_fault_group(&mut r, &format!("{class:?}/{i}"), gen.domains(), &g);
            let plan = g.plan_at(1_000.0 + i as f64, None);
            audit_fault_plan(&mut r, &t, &format!("{class:?}/{i}"), &plan);
        }
    }
    // And a whole sampled mission's arrival stream.
    for (i, (t_h, g)) in gen.sample_mission(2_000.0, &mut rng).iter().enumerate() {
        audit_fault_group(&mut r, &format!("mission/{i}"), gen.domains(), g);
        audit_fault_plan(&mut r, &t, &format!("mission/{i}"), &g.plan_at(t_h * 3.6e9, None));
    }
    assert_clean("sampled fault groups/plans", &r);
    assert!(r.rules_checked() >= 2);
}

// ---------------------------------------------------------------------
// Claim 2: the mutation matrix — every defect caught by its own code
// ---------------------------------------------------------------------

#[test]
fn every_seeded_mutation_is_caught_by_its_declared_code() {
    let muts = seeded_mutations();
    assert!(muts.len() >= 10, "only {} mutation classes seeded", muts.len());
    // One mutation per family at minimum: topology, path set, DAG,
    // fault/replica.
    for prefix in ["AUD00", "AUD01", "AUD02", "AUD03"] {
        assert!(
            muts.iter().any(|m| m.expect.starts_with(prefix)),
            "no mutation targets the {prefix}x family"
        );
    }
    for m in muts {
        let report = (m.run)();
        assert!(
            report.has(m.expect),
            "mutation '{}' was NOT caught by {}:\n{}",
            m.name,
            m.expect,
            report.render()
        );
        // Zero false positives: the planted defect trips its own rule
        // and nothing else.
        for f in report.findings() {
            assert_eq!(
                f.code, m.expect,
                "mutation '{}' caused collateral finding {} ({}: {})",
                m.name, f.code, f.subject, f.detail
            );
        }
    }
}

/// The mutation→code map is injective enough to be trusted as a CI
/// metric: seeded count and caught count are what `BENCH_audit.json`
/// reports, so pin the count here too.
#[test]
fn mutation_matrix_covers_nineteen_classes() {
    let muts = seeded_mutations();
    assert_eq!(muts.len(), 19);
    let names: BTreeSet<&str> = muts.iter().map(|m| m.name).collect();
    assert_eq!(names.len(), 19, "duplicate mutation names");
    let catalog: BTreeSet<&str> = CATALOG.iter().map(|(c, _)| *c).collect();
    for m in seeded_mutations() {
        assert!(catalog.contains(m.expect), "mutation '{}' expects unknown code {}", m.name, m.expect);
    }
}

// ---------------------------------------------------------------------
// Claim 3: cleanliness generalizes beyond the default geometries
// ---------------------------------------------------------------------

/// Random valid rack geometries audit clean. Bounds keep every config
/// inside the x72 NPU lane budget (x_lanes·(slots−1) + y_lanes·(boards−1)
/// + planes·npu_plane_lanes ≤ 72 holds for all boards, slots ≤ 8 at the
/// default per-dimension lane widths — `ubmesh_rack` debug-asserts it).
#[test]
fn random_rack_geometries_audit_clean() {
    let cfg = AuditConfig {
        max_pairs: 16,
        sels: 2,
    };
    forall("audit-random-rack", 12, |rng| {
        let rc = RackConfig {
            boards: rng.range(2, 9),
            slots: rng.range(2, 9),
            cpus: rng.range(0, 5),
            backup: rng.chance(0.5),
            ..RackConfig::default()
        };
        let (t, h) = ubmesh_rack(&rc);
        let r = audit_fabric(&t, &ClusterMap::rack(&h), &cfg);
        assert!(
            r.is_clean(),
            "rack boards={} slots={} cpus={} backup={} not clean:\n{}",
            rc.boards,
            rc.slots,
            rc.cpus,
            rc.backup,
            r.render()
        );
    });
}

/// The sweep-harness integration: a [`GridBuilder`] grid of rack
/// geometries runs through the auditor exactly like a bake-off grid
/// would, and every cell comes back clean.
#[test]
fn gridbuilder_rack_grid_audits_clean() {
    let grid = GridBuilder::cartesian2(&[4usize, 6, 8], &[4usize, 8], |&boards, &slots| {
        Some(RackConfig {
            boards,
            slots,
            ..RackConfig::default()
        })
    });
    assert_eq!(grid.len(), 6);
    let acfg = AuditConfig {
        max_pairs: 16,
        sels: 2,
    };
    let reports = grid.run(|_, rc, _| {
        let (t, h) = ubmesh_rack(rc);
        audit_fabric(&t, &ClusterMap::rack(&h), &acfg)
    });
    for (rc, r) in grid.scenarios().iter().zip(&reports) {
        assert!(
            r.is_clean(),
            "grid cell boards={} slots={} not clean:\n{}",
            rc.boards,
            rc.slots,
            r.render()
        );
    }
}
