//! Integration: coordinator jobs across architectures and scales —
//! the Fig 17/19/20/22 quantities at test granularity.

use ubmesh::coordinator::{linearity, Arch, Job, Routing};

#[test]
fn all_table5_models_plan_on_ubmesh() {
    for (model, scale) in [
        ("llama-70b", 128),
        ("gpt3-175b", 512),
        ("dense-1t", 1024),
        ("gpt4-2t", 1024),
        ("moe-10t", 4096),
    ] {
        let job = Job::new(model, scale, 32768.0, Arch::ubmesh_default()).unwrap();
        let r = job.plan(None).unwrap();
        assert!(r.iter_us > 0.0, "{model}");
        assert!(r.mfu > 0.05 && r.mfu < 0.65, "{model} mfu {}", r.mfu);
        assert_eq!(r.best.npus(), scale, "{model}");
    }
}

#[test]
fn fig17_shape_2dfm_within_7pct() {
    // Average across models at 8K-scale proxy (1024 for test speed).
    let mut worst: f64 = 1.0;
    for model in ["llama-70b", "gpt3-175b", "gpt4-2t"] {
        let job = Job::new(model, 1024, 32768.0, Arch::ubmesh_default()).unwrap();
        let rel = job.relative_perf(Arch::ClosIntraRack, None).unwrap();
        worst = worst.min(rel);
    }
    assert!(
        worst > 0.90,
        "2D-FM worst-case {worst:.3} of Clos (paper ≥ 0.932)"
    );
}

#[test]
fn fig19_shape_routing_strategies_ordered() {
    let mk = |routing| {
        Job::new(
            "gpt4-2t",
            1024,
            262144.0,
            Arch::UbMesh {
                inter_rack_lanes: 16,
                routing,
                mesh_lanes: 2,
                uplink_oversub: 1,
            },
        )
        .unwrap()
        .plan(None)
        .unwrap()
        .tokens_per_s
    };
    let shortest = mk(Routing::Shortest);
    let detour = mk(Routing::Detour);
    let borrow = mk(Routing::Borrow);
    assert!(detour >= shortest);
    assert!(borrow >= detour);
    // Gap is small (paper: ≤0.73% shortest, 0.46% with detour+borrow).
    assert!(shortest / borrow > 0.95, "routing gap too large");
}

#[test]
fn fig20_shape_mesh_width_matters_more_at_long_seq() {
    // Fig 20's mechanism under the hop-chain model: the binding
    // provision knob is the backplane-mesh width, not the inter-rack
    // lanes (those are mesh-capped from x16 up). With long sequences,
    // SP groups outgrow the rack ("a portion of the TP and SP traffic
    // inevitably traverses the inter-rack link"), so widening the
    // x2 → x8 LRS mesh pays off; with short sequences TP/SP stay inside
    // the rack and the wider mesh barely matters.
    use ubmesh::workload::models::by_name;
    use ubmesh::workload::placement::{Placement, TierBandwidth};
    use ubmesh::workload::step::iteration_time;
    use ubmesh::workload::traffic::ParallelismConfig;
    let m = by_name("gpt3-175b").unwrap();
    let gain = |sp: usize, seq: f64| {
        let p = ParallelismConfig {
            tp: 8,
            sp,
            ep: 1,
            pp: 8,
            dp: 1024 / (8 * sp * 8),
            microbatches: 16,
            tokens_per_microbatch: seq,
        };
        let place = Placement::topology_aware(&p);
        let m2 = iteration_time(&m, &p, &place, &TierBandwidth::ubmesh_mesh(32, 1.0, 2, 1))
            .total_us;
        let m8 = iteration_time(&m, &p, &place, &TierBandwidth::ubmesh_mesh(32, 1.0, 8, 1))
            .total_us;
        m2 / m8
    };
    let short = gain(2, 8192.0); // SP span 16 → intra-rack
    let long = gain(16, 1_048_576.0); // SP span 128 → crosses racks
    assert!(
        long > short + 0.01,
        "x8-mesh gain: 1M-seq {long:.4} vs 8K-seq {short:.4}"
    );
    // Residual short-seq gain comes from the DP tier (the uplink mesh
    // slots also widen); the TP/SP-driven gain is the long-seq one.
    assert!(short < 1.10, "short-seq gain {short:.4} suspiciously large");
}

#[test]
fn fig22_shape_linearity_above_95pct() {
    let tput = |scale: usize| {
        Job::new("gpt3-175b", scale, 262144.0, Arch::ubmesh_default())
            .unwrap()
            .plan(None)
            .unwrap()
            .tokens_per_s
    };
    let base = (512usize, tput(512));
    for target_scale in [1024usize, 2048, 4096] {
        let lin = linearity(base, (target_scale, tput(target_scale)));
        assert!(
            lin > 0.95,
            "linearity at {}x = {lin:.3}",
            target_scale / 512
        );
    }
}
