//! End-to-end tests of the measured-availability pipeline (ROADMAP item
//! 4): correlated FaultPlan sampling (`reliability::faultgen`) →
//! DES-measured per-class costs → mission-length availability
//! distributions (`reliability::montecarlo`), plus the checkpoint /
//! restart traffic builders (`workload::step::{checkpoint_flow_dag,
//! iteration_with_readmission}`) that price the abort economics.

use ubmesh::reliability::checkpoint::CheckpointConfig;
use ubmesh::reliability::faultgen::{BlastClass, FaultDomains, FaultGen, FaultGenConfig};
use ubmesh::reliability::montecarlo::{
    measured_availability, measured_class_costs, ClassCosts, MeasureConfig, MissionConfig,
};
use ubmesh::reliability::{availability, AfrBreakdown};
use ubmesh::sim::{self, FlowSpec, RecoveryConfig, SimNet, Stage, StageDag};
use ubmesh::topology::dcn::{add_dcn_layer, DcnAttach};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::ublink::LANE_GB_S;
use ubmesh::topology::{NodeId, Topology};
use ubmesh::util::rng::Rng;
use ubmesh::workload::models::by_name;
use ubmesh::workload::step::{
    checkpoint_flow_dag, iteration_dag, iteration_with_readmission, IterationSpec, RankOrder,
};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

fn rack_with_dcn() -> (Topology, ubmesh::topology::rack::RackHandles, Vec<NodeId>) {
    let (mut t, h) = ubmesh_rack(&RackConfig::default());
    let dcn = add_dcn_layer(
        &mut t,
        std::slice::from_ref(&h),
        2,
        DcnAttach::UbSwitch { lanes_per_rack: 8 },
    );
    (t, h, dcn)
}

fn census() -> AfrBreakdown {
    AfrBreakdown {
        electrical_cables: 30.0,
        optical: 30.0,
        lrs: 20.0,
        hrs: 8.9,
    }
}

/// Checkpoint writes are real flows: 64 ranks × 10 MB funneled through
/// the rack's 8 DCN uplink lanes drain at the uplink ceiling (50 GB/s),
/// not at some per-rank fiction — and the read-back direction costs the
/// same.
#[test]
fn checkpoint_write_prices_dcn_contention() {
    let (t, h, dcn) = rack_with_dcn();
    let map = ClusterMap::rack(&h);
    let bytes = 10e6;
    let net = SimNet::new(&t);
    let write = checkpoint_flow_dag(&t, &map, &dcn, bytes, true);
    assert_eq!(write.total_flow_count(), 64);
    assert_eq!(write.total_bytes(), 64.0 * bytes);
    let r = sim::schedule::run(&net, &write);
    assert!(!r.is_stalled());
    // 640 MB over 8 × 6.25 GB/s of DCN lanes ≈ 12.8 ms ideal.
    let ideal_us = 64.0 * bytes / (8.0 * LANE_GB_S * 1e9) * 1e6;
    assert!(
        r.makespan_us > 0.95 * ideal_us && r.makespan_us < 2.0 * ideal_us,
        "write makespan {} vs uplink-bound ideal {}",
        r.makespan_us,
        ideal_us
    );
    let read = checkpoint_flow_dag(&t, &map, &dcn, bytes, false);
    let rr = sim::schedule::run(&net, &read);
    assert!(!rr.is_stalled());
    assert!((rr.makespan_us - r.makespan_us).abs() < 0.1 * r.makespan_us);
}

/// The restart iteration is the read-back *gating* the training step:
/// every original root of the iteration DAG now depends on the
/// readmission stage, so the measured makespan exceeds a healthy
/// iteration by at least the read-back time.
#[test]
fn readmission_gates_the_first_iteration() {
    let (t, h, dcn) = rack_with_dcn();
    let map = ClusterMap::rack(&h);
    let m = by_name("llama-70b").unwrap();
    let p = ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 1,
        pp: 1,
        dp: 1,
        microbatches: 2,
        tokens_per_microbatch: 8192.0,
    };
    let spec = IterationSpec::default();
    let iter = iteration_dag(&t, &map, &m, &p, RankOrder::TopologyAware, &spec);
    let restart = iteration_with_readmission(
        &t,
        &map,
        &m,
        &p,
        RankOrder::TopologyAware,
        &spec,
        &dcn,
        10e6,
    );
    assert_eq!(restart.stages.len(), iter.stages.len() + 1);
    assert!(restart.stages[0].deps.is_empty(), "read-back is the sole root");
    for (i, st) in restart.stages.iter().enumerate().skip(1) {
        assert!(!st.deps.is_empty(), "stage {i} lost its root gating");
        assert!(st.deps.iter().all(|&d| d < i));
    }
    // Former roots now wait on stage 0.
    let orig_roots = iter.stages.iter().filter(|s| s.deps.is_empty()).count();
    let gated = restart.stages[1..]
        .iter()
        .filter(|s| s.deps == vec![0])
        .count();
    assert_eq!(gated, orig_roots);

    let net = SimNet::new(&t);
    let healthy = sim::schedule::run(&net, &iter);
    let readback = sim::schedule::run(
        &net,
        &checkpoint_flow_dag(&t, &map, &dcn, 10e6, false),
    );
    let restarted = sim::schedule::run(&net, &restart);
    assert!(!restarted.is_stalled());
    assert!(
        restarted.makespan_us >= healthy.makespan_us + 0.9 * readback.makespan_us,
        "restart {} vs healthy {} + readback {}",
        restarted.makespan_us,
        healthy.makespan_us,
        readback.makespan_us
    );
}

/// The full pipeline on the real rack: sampler → DES class costs →
/// mission distributions. Sampled single links and switch deaths are
/// APR-absorbed, rack power loss aborts, and the resulting mission
/// availability is a proper distribution (deterministic in seed,
/// effective ≤ availability).
#[test]
fn mission_pipeline_end_to_end() {
    let (t, h, _dcn) = rack_with_dcn();
    let gen = FaultGen::new(
        FaultDomains::rack(&t, &h),
        &census(),
        FaultGenConfig {
            npu_fleet_afr: 64.0 * 0.05,
            ..FaultGenConfig::default()
        },
    );
    // A light probe DAG keeps the replay fast while still exercising
    // reroute-vs-stall classification on the real fabric.
    let mut flows = Vec::new();
    for (a, b) in [(0usize, 63usize), (9, 36)] {
        let path = t.shortest_path(h.npus[a], h.npus[b], true).unwrap();
        flows.push(FlowSpec::along(&t, &path, 2e6));
    }
    let dag = StageDag::chain(vec![Stage::new("probe").with_flows(flows)]);
    let mcfg = MeasureConfig {
        trials_per_class: 3,
        ..MeasureConfig::default()
    };
    let costs =
        measured_class_costs(&t, &gen, &dag, &RecoveryConfig::direct(), None, &mcfg, 5);
    assert_eq!(costs.abort_fraction(BlastClass::SingleLink), 0.0);
    assert_eq!(costs.abort_fraction(BlastClass::SwitchDeath), 0.0);
    assert_eq!(costs.abort_fraction(BlastClass::RackPower), 1.0);
    assert_eq!(costs.abort_fraction(BlastClass::NpuDeath), 0.0, "64+1 absorbs");

    let ck = CheckpointConfig::new(0.5, 1e-4, 0.1);
    let mission = MissionConfig::default();
    let r1 = measured_availability(&gen, &costs, &ck, &mission, 64, 9);
    let r2 = measured_availability(&gen, &costs, &ck, &mission, 64, 9);
    assert_eq!(r1.availability.mean(), r2.availability.mean());
    assert_eq!(r1.failures, r2.failures);
    assert!(r1.availability.mean() > 0.9 && r1.availability.mean() <= 1.0);
    assert!(r1.effective.mean() <= r1.availability.mean() + 1e-12);
    assert!(r1.availability.p99() <= 1.0 && r1.availability.p50() >= r1.availability.min());
    assert!(r1.failures > 0, "the census must produce arrivals over 720 h");
}

/// Differential oracle at integration scope: the uncorrelated limit
/// reproduces Eq. 3, and the measured correlated run — where APR
/// absorbs network failures into slowdown instead of downtime — sits
/// *above* the closed form, which is exactly the boundary recorded in
/// the ROADMAP.
#[test]
fn oracle_band_and_absorption_boundary() {
    let (t, h, _dcn) = rack_with_dcn();
    let net_only = FaultGen::new(
        FaultDomains::rack(&t, &h),
        &census(),
        FaultGenConfig {
            npu_fleet_afr: 0.0,
            rack_power_afr: 0.0,
            ..FaultGenConfig::default()
        },
    );
    let mttr = 75.0 / 60.0;
    let no_ckpt = CheckpointConfig::new(1e12, 0.0, 0.0);
    let mission = MissionConfig::default();
    let oracle = measured_availability(
        &net_only,
        &ClassCosts::uncorrelated_limit(mttr),
        &no_ckpt,
        &mission,
        256,
        17,
    );
    let expect = availability(
        ubmesh::reliability::mtbf_hours(net_only.rates.total()),
        mttr,
    );
    assert!(
        (oracle.availability.mean() - expect).abs() < 0.01,
        "oracle {} vs Eq3 {expect}",
        oracle.availability.mean()
    );

    // Correlated + absorbed: network failures cost slowdown, not pause.
    let absorbed = ClassCosts {
        samples: std::array::from_fn(|_| {
            vec![ubmesh::reliability::montecarlo::FailureOutcome::Absorbed {
                pause_hours: 0.0,
                slowdown: 0.05,
            }]
        }),
    };
    let measured =
        measured_availability(&net_only, &absorbed, &no_ckpt, &mission, 256, 17);
    assert!(
        measured.availability.mean() > expect,
        "absorption must beat the flat-MTTR closed form ({} vs {expect})",
        measured.availability.mean()
    );
    // …but not for free: the slowdown shows up in effective time.
    assert!(measured.effective.mean() < measured.availability.mean());
}

/// Satellite (PR 8): repair-aware mission plans emit a matching restore
/// for every fault, honoring the sampled (crew-queued) repair time —
/// and folding the whole replayable plan through the link state machine
/// leaves the fabric fully healthy: no link still down, no capacity
/// still rescaled.
#[test]
fn mission_repair_plans_fully_restore_the_fabric() {
    use std::collections::{BTreeMap, BTreeSet};
    use ubmesh::reliability::repair::RepairConfig;
    use ubmesh::sim::fault::FaultEvent;
    use ubmesh::topology::LinkId;

    let (t, h, _dcn) = rack_with_dcn();
    let gen = FaultGen::new(
        FaultDomains::rack(&t, &h),
        &census(),
        FaultGenConfig {
            npu_fleet_afr: 64.0 * 0.05,
            ..FaultGenConfig::default()
        },
    );
    let repair = RepairConfig::field_default();
    let mission = gen.sample_mission_with_repair(720.0, &repair, &mut Rng::new(11));
    assert!(!mission.is_empty());
    for me in &mission {
        assert!(me.t_hours >= 0.0 && me.t_hours < 720.0);
        assert!(me.restore_hours.is_finite() && me.restore_hours > me.t_hours);
        assert!(me.window_hours(720.0) >= 0.0);
    }
    // Deterministic in seed, through the sampled repair durations.
    let again = gen.sample_mission_with_repair(720.0, &repair, &mut Rng::new(11));
    assert_eq!(mission.len(), again.len());
    for (a, b) in mission.iter().zip(&again) {
        assert_eq!(a.t_hours, b.t_hours);
        assert_eq!(a.restore_hours, b.restore_hours);
    }

    // The replayable plan carries fault + restore for every group…
    let plan = gen.mission_fault_plan(&t, &mission, Some(RecoveryConfig::direct()));
    let expect: usize = mission
        .iter()
        .map(|me| me.group.events.len() + me.group.restore_events(&t).len())
        .sum();
    assert_eq!(plan.len(), expect);

    // …and replaying it through the link state machine ends healthy.
    let mut evs: Vec<(f64, FaultEvent)> = plan.events.clone();
    evs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut down: BTreeSet<u32> = BTreeSet::new();
    let mut rescaled: BTreeMap<u32, f64> = BTreeMap::new();
    for (_, ev) in &evs {
        match ev {
            FaultEvent::LinkDown(l) => {
                down.insert(l.0);
            }
            FaultEvent::LinkUp(l) => {
                down.remove(&l.0);
            }
            FaultEvent::LinkCapacity(l, gb_s) => {
                rescaled.insert(l.0, *gb_s);
            }
            FaultEvent::NpuDown { npu, .. } => {
                for &(_, l) in t.neighbors(*npu) {
                    down.insert(l.0);
                }
            }
        }
    }
    assert!(
        down.is_empty(),
        "{} links still down after the last restore",
        down.len()
    );
    for (l, gb_s) in &rescaled {
        assert_eq!(
            *gb_s,
            t.link(LinkId(*l)).capacity_gb_s(),
            "link {l} left at a degraded capacity"
        );
    }
}

/// Mission plans stay inside the horizon and inherit the sampler's
/// determinism through the whole faultgen → FaultPlan path.
#[test]
fn mission_plans_replayable_as_fault_plans() {
    let (t, h, _dcn) = rack_with_dcn();
    let gen = FaultGen::new(
        FaultDomains::rack(&t, &h),
        &census(),
        FaultGenConfig {
            npu_fleet_afr: 64.0 * 0.05,
            ..FaultGenConfig::default()
        },
    );
    let mission = gen.sample_mission(720.0, &mut Rng::new(3));
    assert!(!mission.is_empty());
    for (t_h, group) in &mission {
        assert!(*t_h >= 0.0 && *t_h < 720.0);
        let plan = group.plan_at(t_h * 3.6e9, Some(RecoveryConfig::direct()));
        assert_eq!(plan.len(), group.events.len());
        assert!(plan
            .events
            .iter()
            .all(|(at, _)| (*at - t_h * 3.6e9).abs() < 1e-6));
    }
}
