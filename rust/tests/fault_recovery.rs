//! End-to-end fault-injection integration tests (PR 4): mid-run
//! `FaultPlan` events through the event loop, online APR recovery, the
//! fig12 sim-vs-analytic consistency check, and the strategy
//! differential under faults.

use ubmesh::collectives::alltoall::{
    hrs_reroute, multipath_alltoall_dag, superpod_hrs_alltoall_dag, Grid,
};
use ubmesh::routing::failure::{
    direct_notification_convergence_us, hop_by_hop_convergence_us, RecoveryModel,
};
use ubmesh::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
use ubmesh::sim::{self, FlowSpec, ResolveStrategy, SimConfig, SimNet, Stage, StageDag};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig, SuperPodHandles};
use ubmesh::topology::{CableClass, NodeId, Topology};

fn mesh_4x4() -> Topology {
    nd_fullmesh(
        "m44",
        &[
            DimSpec::new(4, 4, CableClass::PassiveElectrical, 0.3),
            DimSpec::new(4, 4, CableClass::PassiveElectrical, 1.0),
        ],
    )
}

/// Fig 12, measured against analytic: with a single rerouted flow on
/// the critical path, the makespan gap between hop-by-hop and direct
/// notification equals the convergence-latency gap *exactly* — the
/// simulator charges precisely the modeled control-plane delay, nothing
/// else differs between the two runs.
#[test]
fn measured_notification_gap_matches_analytic_convergence_gap() {
    let t = mesh_4x4();
    let node = |x: usize, y: usize| NodeId((y * 4 + x) as u32);
    let (a, b, c, d) = (node(0, 0), node(1, 0), node(1, 1), node(2, 1));
    let failed = t.link_between(c, d).unwrap();
    let net = SimNet::new(&t);
    let bytes = 100e6;
    let mut dag = StageDag::default();
    dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(&t, &[a, b, c, d], bytes)]));

    let t_fail = 1_000.0;
    let run_mode = |rc: RecoveryConfig| {
        let plan = FaultPlan::new()
            .at(t_fail, FaultEvent::LinkDown(failed))
            .with_recovery(rc);
        let r = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &plan);
        assert!(!r.is_stalled());
        assert_eq!(r.reroutes, 1);
        r.makespan_us
    };
    let m_hbh = run_mode(RecoveryConfig::hop_by_hop());
    let m_direct = run_mode(RecoveryConfig::direct());

    // The affected source `a` is 2 hops from both link endpoints, the
    // regime where direct notification wins (worst = 2 ⇒ flooding pays
    // two per-router processing steps, direct pays one total).
    let m = RecoveryModel::default();
    let conv_hbh = hop_by_hop_convergence_us(&t, failed, &[a], &m);
    let conv_direct = direct_notification_convergence_us(&t, failed, &[a], &m);
    assert!(conv_direct < conv_hbh, "{conv_direct} vs {conv_hbh}");
    assert!(m_direct < m_hbh, "direct {m_direct} vs hop-by-hop {m_hbh}");
    let measured_gap = m_hbh - m_direct;
    let analytic_gap = conv_hbh - conv_direct;
    assert!(
        (measured_gap - analytic_gap).abs() < 1e-6,
        "measured gap {measured_gap} vs analytic {analytic_gap}"
    );
}

/// A mid-run `LinkCapacity` rescale flows through the bounded
/// capacity-change re-solve and lands on the closed-form makespan.
#[test]
fn midrun_rescale_matches_closed_form() {
    let t = nd_fullmesh(
        "k4",
        &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
    );
    let net = SimNet::new(&t);
    let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
    let bytes = 500e6; // 10_000 µs at the x8 = 50 GB/s full rate
    let spec = FlowSpec::along(&t, &[NodeId(0), NodeId(1)], bytes);
    let gate = spec.latency_us;
    let mut dag = StageDag::default();
    dag.push(Stage::new("xfer").with_flows(vec![spec]));

    let t_change = 4_000.0;
    let plan = FaultPlan::new().at(t_change, FaultEvent::LinkCapacity(l, 25.0));
    let r = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &plan);
    assert!(!r.is_stalled());
    assert_eq!(r.reroutes, 0, "a slower link is not a cut");
    assert_eq!(r.solver.cap_resolves, 1);
    assert!(r.solver.cap_rate_recomputes >= 1);
    let drained = (t_change - gate) * 50.0 * 1e3;
    let expect = t_change + (bytes - drained) / (25.0 * 1e3);
    assert!(
        (r.makespan_us - expect).abs() / expect < 1e-6,
        "makespan {} vs closed form {expect}",
        r.makespan_us
    );
}

/// The full strategy differential under faults: an all-to-all with a
/// mid-run link death, APR recovery and a later restore must produce
/// identical reports under the bounded solver, the PR 2 rise-only
/// solver and the PR 1 full-component oracle.
#[test]
fn faulted_runs_agree_across_strategies() {
    let t = mesh_4x4();
    let nodes = t.npus.clone();
    let g = Grid::new(&nodes, 4, 4);
    let net = SimNet::new(&t);
    let dag = multipath_alltoall_dag(&t, &g, 4e6);
    let healthy = sim::schedule::run(&net, &dag);
    let failed = t.link_between(NodeId(0), NodeId(1)).unwrap();
    let plan = FaultPlan::new()
        .at(healthy.makespan_us * 0.3, FaultEvent::LinkDown(failed))
        .at(healthy.makespan_us * 2.0, FaultEvent::LinkUp(failed))
        .with_recovery(RecoveryConfig::direct());
    let run = |strategy: ResolveStrategy| {
        sim::schedule::run_faulted(&net, &dag, &SimConfig { strategy }, &plan)
    };
    let bounded = run(ResolveStrategy::Bounded);
    let rise = run(ResolveStrategy::RiseOnly);
    let bfs = run(ResolveStrategy::FullComponentBfs);
    assert!(!bounded.is_stalled());
    assert!(bounded.reroutes >= 1, "{} reroutes", bounded.reroutes);
    for (name, r) in [("rise", &rise), ("bfs", &bfs)] {
        assert!(
            (bounded.makespan_us - r.makespan_us).abs() <= 1e-6 * r.makespan_us,
            "{name}: {} vs bounded {}",
            r.makespan_us,
            bounded.makespan_us
        );
        assert!(
            (bounded.byte_hops - r.byte_hops).abs() <= 1e-6 * r.byte_hops,
            "{name} byte-hops"
        );
        assert_eq!(bounded.reroutes, r.reroutes, "{name} reroutes");
        assert_eq!(bounded.fault_events, r.fault_events, "{name} fault events");
    }
    assert!(
        bounded.makespan_us > healthy.makespan_us,
        "the fault must cost something: {} vs {}",
        bounded.makespan_us,
        healthy.makespan_us
    );
}

/// Fault under training (PR 5): a full measured iteration loses an
/// intra-rack Y link mid-run and recovers online. The striped SP/DP
/// exchanges put the pair's traffic on 7 paths, so losing the direct
/// link is the fig12 *absorbed* regime — APR reroutes soak the failure
/// wherever slack exists (mirror-measured degradation: exactly 0, with
/// 16 reroutes as every later stage gates onto the dead link and
/// re-paths) — while the no-recovery run must stall until the scripted
/// restore, bounding the recovered run from above.
#[test]
fn training_iteration_survives_intra_rack_link_death() {
    use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
    use ubmesh::workload::models::by_name;
    use ubmesh::workload::step::{iteration_dag, IterationSpec, RankOrder};
    use ubmesh::workload::{ClusterMap, ParallelismConfig};
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let map = ClusterMap::rack(&h);
    let m = by_name("llama-70b").unwrap();
    let p = ParallelismConfig {
        tp: 8,
        sp: 2,
        ep: 1,
        pp: 2,
        dp: 2,
        microbatches: 2,
        tokens_per_microbatch: 8192.0,
    };
    let dag = iteration_dag(&t, &map, &m, &p, RankOrder::TopologyAware, &IterationSpec::default());
    let net = SimNet::new(&t);
    let healthy = sim::schedule::run(&net, &dag);
    assert!(!healthy.is_stalled());

    // The Y link between ranks 0 and 8 (boards 0/1, slot 0) carries the
    // direct seventh of their SP exchange in every layer-unit; kill it
    // at 40% of the healthy makespan (the fig12 mid-run regime).
    let failed = t
        .link_between(map.npus()[0], map.npus()[8])
        .expect("SP pair must be directly linked");
    let t_fail = 0.4 * healthy.makespan_us;
    let faults = FaultPlan::new().at(t_fail, FaultEvent::LinkDown(failed));

    let rec = sim::schedule::run_faulted(
        &net,
        &dag,
        &SimConfig::default(),
        &faults.clone().with_recovery(RecoveryConfig::direct()),
    );
    assert!(!rec.is_stalled(), "recovery must complete the iteration");
    assert!(rec.reroutes >= 1, "{} reroutes", rec.reroutes);
    // Bounded degradation: the absorbed regime costs (near) nothing.
    let deg = rec.makespan_us / healthy.makespan_us;
    assert!(
        (1.0 - 1e-9..1.10).contains(&deg),
        "degradation {deg:.4} outside the absorbed-regime bound"
    );

    // Naive bound: no recovery — the cut flows stall until a restore at
    // 1.5× the healthy makespan revives them (mirror: ratio 1.94).
    let stall = sim::schedule::run_faulted(
        &net,
        &dag,
        &SimConfig::default(),
        &faults.at(1.5 * healthy.makespan_us, FaultEvent::LinkUp(failed)),
    );
    assert!(!stall.is_stalled(), "the restore must revive the run");
    assert!(
        stall.makespan_us > 1.5 * healthy.makespan_us,
        "stall-until-restore {} must exceed the restore time",
        stall.makespan_us
    );
    assert!(
        rec.makespan_us < stall.makespan_us,
        "recovered {} vs stall bound {}",
        rec.makespan_us,
        stall.makespan_us
    );
}

/// 2 pods × 2×2 racks = 512 NPUs over a real 4-HRS Clos tier.
fn small_hrs_superpod() -> (Topology, SuperPodHandles) {
    let mut cfg = SuperPodConfig::default();
    cfg.pods = 2;
    cfg.pod.rows = 2;
    cfg.pod.cols = 2;
    ubmesh_superpod(&cfg)
}

/// The SuperPod-tier rehearsal of the 32K acceptance scenario: an
/// uplink dies mid-inter-pod-phase, `hrs_reroute` re-picks a surviving
/// plane, the run completes, and the makespan sits strictly between the
/// healthy run and the stall-until-restore bound.
#[test]
fn hrs_uplink_death_reroutes_and_bounds_makespan() {
    let (t, h) = small_hrs_superpod();
    let dag = superpod_hrs_alltoall_dag(&t, &h, 4e6, 0.0, 1);
    let net = SimNet::new(&t);
    let healthy = sim::schedule::run(&net, &dag);
    assert!(!healthy.is_stalled());

    // Kill the uplink-LRS → HRS hop of the first inter-pod flow,
    // mid-phase.
    let inter = dag.stages[2].materialize_flows(&t);
    let failed = inter[0].channels[2].link;
    let t_fail = (healthy.stage_done_us[1] + healthy.makespan_us) / 2.0;
    let t_restore = healthy.makespan_us * 3.0;
    let faults = FaultPlan::new()
        .at(t_fail, FaultEvent::LinkDown(failed))
        .at(t_restore, FaultEvent::LinkUp(failed));

    let stall = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &faults);
    assert!(!stall.is_stalled(), "restore must revive the cut flows");
    assert!(stall.makespan_us > t_restore);

    let plan = faults
        .clone()
        .with_recovery(RecoveryConfig::direct().with_reroute(hrs_reroute(&h)));
    let rec = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &plan);
    assert!(!rec.is_stalled());
    assert!(rec.reroutes >= 1, "{} reroutes", rec.reroutes);
    assert!(
        rec.makespan_us > healthy.makespan_us,
        "degraded {} vs healthy {}",
        rec.makespan_us,
        healthy.makespan_us
    );
    assert!(
        rec.makespan_us < stall.makespan_us,
        "degraded {} vs stall bound {}",
        rec.makespan_us,
        stall.makespan_us
    );
}
