//! Differential testing: the scalable inverted-index/saturation-heap
//! max-min solver (`sim::fair::Rates` behind `max_min_rates`) against
//! the retained naive progressive-filling oracle
//! (`sim::fair::naive_max_min_rates`) on randomized topologies and flow
//! sets, the incremental add/remove entry points against fresh solves of
//! the surviving flow set, and (PR 2/PR 3) the bounded solvers — the
//! default `Bounded` strategy (fall-only adds + rise-only removals) and
//! `RiseOnly` (full-component adds) — against the PR 1
//! full-component-BFS solver on randomized add/remove *interleavings*,
//! checked after **every** mutation — the workload shape where the
//! bounded re-solves' absorption chains must hold up in both
//! directions.
//!
//! Tolerance: the oracle accumulates the fill level through repeated
//! `committed += delta` additions and freezes channels within a 1e-9
//! relative headroom band, so the two solvers may differ by accumulated
//! fp noise — we assert agreement within `1e-6 · max(rate, 1)` per flow
//! (the bound the ISSUE specifies).

use ubmesh::sim::fair::{max_min_rates, naive_max_min_rates, Rates, ResolveStrategy};
use ubmesh::sim::SimNet;
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, Channel, LinkId, Topology};
use ubmesh::util::prop::forall;
use ubmesh::util::rng::Rng;

/// Random nd-fullmesh, 1–4 dimensions of size 2–5, mixed lane counts.
fn random_topology(rng: &mut Rng) -> Topology {
    let ndims = rng.range(1, 5);
    let specs: Vec<DimSpec> = (0..ndims)
        .map(|_| {
            DimSpec::new(
                rng.range(2, 6),
                rng.range(1, 9) as u32,
                CableClass::PassiveElectrical,
                0.5,
            )
        })
        .collect();
    nd_fullmesh("rand", &specs)
}

/// Random flow = 1–5 random directed channels (not necessarily a path —
/// the solver contract is over channel lists).
fn random_flows(rng: &mut Rng, t: &Topology, lo: usize, hi: usize) -> Vec<Vec<Channel>> {
    let nflows = rng.range(lo, hi);
    (0..nflows)
        .map(|_| {
            (0..rng.range(1, 6))
                .map(|_| Channel {
                    link: LinkId(rng.range(0, t.link_count()) as u32),
                    rev: rng.chance(0.5),
                })
                .collect()
        })
        .collect()
}

fn assert_close(fast: &[f64], slow: &[f64], ctx: &str) {
    assert_eq!(fast.len(), slow.len());
    for (i, (a, b)) in fast.iter().zip(slow).enumerate() {
        assert!(
            (a - b).abs() <= 1e-6 * b.max(1.0),
            "{ctx}: flow {i} fast {a} vs naive {b}"
        );
    }
}

#[test]
fn indexed_solver_matches_oracle_on_random_instances() {
    // ≥64 randomized cases (ISSUE acceptance bar); each case draws its
    // own topology, flow set and failure pattern.
    forall("indexed vs naive (randomized)", 96, |rng: &mut Rng| {
        let t = random_topology(rng);
        let mut net = SimNet::new(&t);
        // Random failures on up to 20% of links.
        for l in 0..t.link_count() {
            if rng.chance(0.2) {
                net.fail_link(LinkId(l as u32));
            }
        }
        let flows = random_flows(rng, &t, 1, 48);
        let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
        let fast = max_min_rates(&net, &refs);
        let slow = naive_max_min_rates(&net, &refs);
        assert_close(&fast, &slow, "full solve");
    });
}

#[test]
fn incremental_removal_matches_oracle_on_survivors() {
    forall("incremental remove vs naive", 64, |rng: &mut Rng| {
        let t = random_topology(rng);
        let net = SimNet::new(&t);
        let flows = random_flows(rng, &t, 2, 32);
        let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &refs);
        // Remove a random subset, one batch.
        let mut removed = Vec::new();
        let mut survivors = Vec::new();
        for (k, &id) in ids.iter().enumerate() {
            if rng.chance(0.4) {
                removed.push(id);
            } else {
                survivors.push(k);
            }
        }
        if removed.is_empty() || survivors.is_empty() {
            return;
        }
        r.remove_flows(&net, &removed);
        let surv_refs: Vec<&[Channel]> =
            survivors.iter().map(|&k| flows[k].as_slice()).collect();
        let oracle = naive_max_min_rates(&net, &surv_refs);
        let got: Vec<f64> = survivors.iter().map(|&k| r.rate(ids[k])).collect();
        assert_close(&got, &oracle, "post-removal");
    });
}

/// The PR 2/PR 3 acceptance differential: all three strategies and the
/// naive oracle stepped through the *same* randomized add/remove
/// interleaving, compared after every mutation. Failures here mean a
/// bounded candidate seeding (rise-only removal or fall-only add) or an
/// absorption trigger missed a chain.
#[test]
fn bounded_strategies_match_oracles_on_randomized_interleavings() {
    forall(
        "bounded vs rise-only vs bfs vs naive interleavings",
        96,
        |rng: &mut Rng| {
            let t = random_topology(rng);
            let mut net = SimNet::new(&t);
            // Random failures on up to 10% of links — blocked (rate-0)
            // flows must stay inert through the bounded re-solves.
            for l in 0..t.link_count() {
                if rng.chance(0.1) {
                    net.fail_link(LinkId(l as u32));
                }
            }
            let mut bounded = Rates::new();
            assert_eq!(bounded.strategy(), ResolveStrategy::Bounded);
            let mut rise = Rates::with_strategy(ResolveStrategy::RiseOnly);
            let mut bfs = Rates::with_strategy(ResolveStrategy::FullComponentBfs);

            // Alive bookkeeping: spec k → per-solver flow ids.
            let mut specs: Vec<Vec<Channel>> = Vec::new();
            let mut ids_bnd: Vec<usize> = Vec::new();
            let mut ids_rise: Vec<usize> = Vec::new();
            let mut ids_bfs: Vec<usize> = Vec::new();
            let mut alive: Vec<usize> = Vec::new();

            let steps = rng.range(6, 16);
            for _ in 0..steps {
                let removing = !alive.is_empty() && rng.chance(0.45);
                if removing {
                    // Remove a random batch (1..=3 flows).
                    let nrem = rng.range(1, 4.min(alive.len() + 1));
                    let mut batch_n = Vec::new();
                    let mut batch_r = Vec::new();
                    let mut batch_b = Vec::new();
                    for _ in 0..nrem.min(alive.len()) {
                        let k = alive.swap_remove(rng.range(0, alive.len()));
                        batch_n.push(ids_bnd[k]);
                        batch_r.push(ids_rise[k]);
                        batch_b.push(ids_bfs[k]);
                    }
                    bounded.remove_flows(&net, &batch_n);
                    rise.remove_flows(&net, &batch_r);
                    bfs.remove_flows(&net, &batch_b);
                } else {
                    // Add a random batch (1..=4 flows) — the fall-only
                    // add path under test.
                    let extra = random_flows(rng, &t, 1, 5);
                    let refs: Vec<&[Channel]> =
                        extra.iter().map(|f| f.as_slice()).collect();
                    let new_n = bounded.add_flows(&net, &refs);
                    let new_r = rise.add_flows(&net, &refs);
                    let new_b = bfs.add_flows(&net, &refs);
                    for (j, f) in extra.into_iter().enumerate() {
                        alive.push(specs.len());
                        specs.push(f);
                        ids_bnd.push(new_n[j]);
                        ids_rise.push(new_r[j]);
                        ids_bfs.push(new_b[j]);
                    }
                }
                // After EVERY mutation: all four solvers agree on the
                // alive set.
                let alive_refs: Vec<&[Channel]> =
                    alive.iter().map(|&k| specs[k].as_slice()).collect();
                let oracle = naive_max_min_rates(&net, &alive_refs);
                for (j, &k) in alive.iter().enumerate() {
                    let rn = bounded.rate(ids_bnd[k]);
                    let rr = rise.rate(ids_rise[k]);
                    let rb = bfs.rate(ids_bfs[k]);
                    assert!(
                        (rn - rb).abs() <= 1e-6 * rb.max(1.0),
                        "bounded {rn} vs bfs {rb} (flow {k})"
                    );
                    assert!(
                        (rr - rb).abs() <= 1e-6 * rb.max(1.0),
                        "rise {rr} vs bfs {rb} (flow {k})"
                    );
                    assert!(
                        (rn - oracle[j]).abs() <= 1e-6 * oracle[j].max(1.0),
                        "bounded {rn} vs naive {} (flow {k})",
                        oracle[j]
                    );
                }
            }
        },
    );
}

/// PR 4 acceptance differential: randomized interleavings of flow
/// adds/removes **and mid-run capacity changes** (link fail / restore /
/// rescale through `links_changed`), all three strategies plus the
/// naive oracle compared after every mutation. Failures here mean the
/// capacity-change candidate seeding or an absorption trigger missed a
/// chain set off by a constraint moving instead of a flow.
#[test]
fn capacity_changes_match_oracles_on_randomized_interleavings() {
    forall(
        "fault-event interleavings vs oracles",
        96,
        |rng: &mut Rng| {
            let t = random_topology(rng);
            let mut net = SimNet::new(&t);
            let mut bounded = Rates::new();
            let mut rise = Rates::with_strategy(ResolveStrategy::RiseOnly);
            let mut bfs = Rates::with_strategy(ResolveStrategy::FullComponentBfs);

            let mut specs: Vec<Vec<Channel>> = Vec::new();
            let mut ids_bnd: Vec<usize> = Vec::new();
            let mut ids_rise: Vec<usize> = Vec::new();
            let mut ids_bfs: Vec<usize> = Vec::new();
            let mut alive: Vec<usize> = Vec::new();

            // Seed with an initial flow population so the first fault
            // events land on a live allocation.
            let initial = random_flows(rng, &t, 2, 12);
            let refs: Vec<&[Channel]> = initial.iter().map(|f| f.as_slice()).collect();
            let new_n = bounded.add_flows(&net, &refs);
            let new_r = rise.add_flows(&net, &refs);
            let new_b = bfs.add_flows(&net, &refs);
            for (j, f) in initial.into_iter().enumerate() {
                alive.push(specs.len());
                specs.push(f);
                ids_bnd.push(new_n[j]);
                ids_rise.push(new_r[j]);
                ids_bfs.push(new_b[j]);
            }

            let steps = rng.range(8, 20);
            for _ in 0..steps {
                let roll = rng.f64();
                if roll < 0.5 {
                    // Capacity change on a random link: fail, restore or
                    // rescale — the mutation class under test.
                    let l = LinkId(rng.range(0, t.link_count()) as u32);
                    match rng.range(0, 3) {
                        0 => net.fail_link(l),
                        1 => net.restore_link(l),
                        _ => {
                            net.restore_link(l);
                            net.set_link_capacity(l, 1.0 + 99.0 * rng.f64());
                        }
                    }
                    bounded.links_changed(&net, &[l]);
                    rise.links_changed(&net, &[l]);
                    bfs.links_changed(&net, &[l]);
                } else if roll < 0.75 && !alive.is_empty() {
                    let k = alive.swap_remove(rng.range(0, alive.len()));
                    bounded.remove_flows(&net, &[ids_bnd[k]]);
                    rise.remove_flows(&net, &[ids_rise[k]]);
                    bfs.remove_flows(&net, &[ids_bfs[k]]);
                } else {
                    let extra = random_flows(rng, &t, 1, 4);
                    let refs: Vec<&[Channel]> =
                        extra.iter().map(|f| f.as_slice()).collect();
                    let new_n = bounded.add_flows(&net, &refs);
                    let new_r = rise.add_flows(&net, &refs);
                    let new_b = bfs.add_flows(&net, &refs);
                    for (j, f) in extra.into_iter().enumerate() {
                        alive.push(specs.len());
                        specs.push(f);
                        ids_bnd.push(new_n[j]);
                        ids_rise.push(new_r[j]);
                        ids_bfs.push(new_b[j]);
                    }
                }
                // After EVERY mutation: all four agree on the alive set
                // under the *current* capacities.
                let alive_refs: Vec<&[Channel]> =
                    alive.iter().map(|&k| specs[k].as_slice()).collect();
                let oracle = naive_max_min_rates(&net, &alive_refs);
                for (j, &k) in alive.iter().enumerate() {
                    let rn = bounded.rate(ids_bnd[k]);
                    let rr = rise.rate(ids_rise[k]);
                    let rb = bfs.rate(ids_bfs[k]);
                    assert!(
                        (rn - oracle[j]).abs() <= 1e-6 * oracle[j].max(1.0),
                        "bounded {rn} vs naive {} (flow {k})",
                        oracle[j]
                    );
                    assert!(
                        (rr - oracle[j]).abs() <= 1e-6 * oracle[j].max(1.0),
                        "rise {rr} vs naive {} (flow {k})",
                        oracle[j]
                    );
                    assert!(
                        (rb - oracle[j]).abs() <= 1e-6 * oracle[j].max(1.0),
                        "bfs {rb} vs naive {} (flow {k})",
                        oracle[j]
                    );
                }
            }
        },
    );
}

#[test]
fn incremental_readdition_matches_oracle() {
    forall("incremental add vs naive", 64, |rng: &mut Rng| {
        let t = random_topology(rng);
        let net = SimNet::new(&t);
        let first = random_flows(rng, &t, 1, 16);
        let second = random_flows(rng, &t, 1, 16);
        let mut r = Rates::new();
        let ids1 = r.add_flows(&net, &first.iter().map(|f| f.as_slice()).collect::<Vec<_>>());
        let ids2 = r.add_flows(&net, &second.iter().map(|f| f.as_slice()).collect::<Vec<_>>());
        let all: Vec<&[Channel]> = first
            .iter()
            .chain(second.iter())
            .map(|f| f.as_slice())
            .collect();
        let oracle = naive_max_min_rates(&net, &all);
        let got: Vec<f64> = ids1
            .iter()
            .chain(ids2.iter())
            .map(|&id| r.rate(id))
            .collect();
        assert_close(&got, &oracle, "post-addition");
    });
}
