//! Differential testing: the scalable inverted-index/saturation-heap
//! max-min solver (`sim::fair::Rates` behind `max_min_rates`) against
//! the retained naive progressive-filling oracle
//! (`sim::fair::naive_max_min_rates`) on randomized topologies and flow
//! sets, and the incremental add/remove entry points against fresh
//! solves of the surviving flow set.
//!
//! Tolerance: the oracle accumulates the fill level through repeated
//! `committed += delta` additions and freezes channels within a 1e-9
//! relative headroom band, so the two solvers may differ by accumulated
//! fp noise — we assert agreement within `1e-6 · max(rate, 1)` per flow
//! (the bound the ISSUE specifies).

use ubmesh::sim::fair::{max_min_rates, naive_max_min_rates, Rates};
use ubmesh::sim::SimNet;
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, Channel, LinkId, Topology};
use ubmesh::util::prop::forall;
use ubmesh::util::rng::Rng;

/// Random nd-fullmesh, 1–4 dimensions of size 2–5, mixed lane counts.
fn random_topology(rng: &mut Rng) -> Topology {
    let ndims = rng.range(1, 5);
    let specs: Vec<DimSpec> = (0..ndims)
        .map(|_| {
            DimSpec::new(
                rng.range(2, 6),
                rng.range(1, 9) as u32,
                CableClass::PassiveElectrical,
                0.5,
            )
        })
        .collect();
    nd_fullmesh("rand", &specs)
}

/// Random flow = 1–5 random directed channels (not necessarily a path —
/// the solver contract is over channel lists).
fn random_flows(rng: &mut Rng, t: &Topology, lo: usize, hi: usize) -> Vec<Vec<Channel>> {
    let nflows = rng.range(lo, hi);
    (0..nflows)
        .map(|_| {
            (0..rng.range(1, 6))
                .map(|_| Channel {
                    link: LinkId(rng.range(0, t.link_count()) as u32),
                    rev: rng.chance(0.5),
                })
                .collect()
        })
        .collect()
}

fn assert_close(fast: &[f64], slow: &[f64], ctx: &str) {
    assert_eq!(fast.len(), slow.len());
    for (i, (a, b)) in fast.iter().zip(slow).enumerate() {
        assert!(
            (a - b).abs() <= 1e-6 * b.max(1.0),
            "{ctx}: flow {i} fast {a} vs naive {b}"
        );
    }
}

#[test]
fn indexed_solver_matches_oracle_on_random_instances() {
    // ≥64 randomized cases (ISSUE acceptance bar); each case draws its
    // own topology, flow set and failure pattern.
    forall("indexed vs naive (randomized)", 96, |rng: &mut Rng| {
        let t = random_topology(rng);
        let mut net = SimNet::new(&t);
        // Random failures on up to 20% of links.
        for l in 0..t.link_count() {
            if rng.chance(0.2) {
                net.fail_link(LinkId(l as u32));
            }
        }
        let flows = random_flows(rng, &t, 1, 48);
        let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
        let fast = max_min_rates(&net, &refs);
        let slow = naive_max_min_rates(&net, &refs);
        assert_close(&fast, &slow, "full solve");
    });
}

#[test]
fn incremental_removal_matches_oracle_on_survivors() {
    forall("incremental remove vs naive", 64, |rng: &mut Rng| {
        let t = random_topology(rng);
        let net = SimNet::new(&t);
        let flows = random_flows(rng, &t, 2, 32);
        let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &refs);
        // Remove a random subset, one batch.
        let mut removed = Vec::new();
        let mut survivors = Vec::new();
        for (k, &id) in ids.iter().enumerate() {
            if rng.chance(0.4) {
                removed.push(id);
            } else {
                survivors.push(k);
            }
        }
        if removed.is_empty() || survivors.is_empty() {
            return;
        }
        r.remove_flows(&net, &removed);
        let surv_refs: Vec<&[Channel]> =
            survivors.iter().map(|&k| flows[k].as_slice()).collect();
        let oracle = naive_max_min_rates(&net, &surv_refs);
        let got: Vec<f64> = survivors.iter().map(|&k| r.rate(ids[k])).collect();
        assert_close(&got, &oracle, "post-removal");
    });
}

#[test]
fn incremental_readdition_matches_oracle() {
    forall("incremental add vs naive", 64, |rng: &mut Rng| {
        let t = random_topology(rng);
        let net = SimNet::new(&t);
        let first = random_flows(rng, &t, 1, 16);
        let second = random_flows(rng, &t, 1, 16);
        let mut r = Rates::new();
        let ids1 = r.add_flows(&net, &first.iter().map(|f| f.as_slice()).collect::<Vec<_>>());
        let ids2 = r.add_flows(&net, &second.iter().map(|f| f.as_slice()).collect::<Vec<_>>());
        let all: Vec<&[Channel]> = first
            .iter()
            .chain(second.iter())
            .map(|f| f.as_slice())
            .collect();
        let oracle = naive_max_min_rates(&net, &all);
        let got: Vec<f64> = ids1
            .iter()
            .chain(ids2.iter())
            .map(|&id| r.rate(id))
            .collect();
        assert_close(&got, &oracle, "post-addition");
    });
}
