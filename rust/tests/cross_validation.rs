//! Cross-validation: Rust `SimReport.makespan_us` vs the Python
//! reference cost model (`python/compile/kernels/ref.py::cost_model`)
//! on three small canned DAGs.
//!
//! The DAGs are chosen contention-free (one flow per channel per stage),
//! where the fluid simulation has an exact α-β closed form — precisely
//! what `ref.cost_model` computes:
//!
//!   time = compute_us + Σ_t exposure·(volume/(bw·1e3) + transfers·α)
//!
//! The test shells out to `python3` to evaluate the *actual* reference
//! kernel; when Python/JAX is unavailable in the environment it falls
//! back to the same formula mirrored in Rust (and says so), so the
//! DES↔model agreement is always checked.
//!
//! Tolerance: **1e-3 relative**. Sources of divergence, in order:
//! the DES's event-batching epsilon (≤1e-9·t), the 0.5-byte completion
//! remnant (≤1e-9 relative at these payloads), and f32 rounding inside
//! the JAX kernel (~1e-7 relative). 1e-3 leaves two orders of headroom
//! over all three combined.

use std::process::Command;

use ubmesh::sim::{self, FlowSpec, SimNet, Stage, StageDag};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::ublink::{hop_latency_us, MESSAGE_ALPHA_US};
use ubmesh::topology::{CableClass, NodeId, Topology};

const TOLERANCE: f64 = 1e-3;

fn k4() -> Topology {
    // 1D full-mesh of 4, x8 lanes = 50 GB/s per channel.
    nd_fullmesh(
        "k4",
        &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
    )
}

/// α for a 1-hop flow on the k4 mesh: message overhead + wire latency.
fn alpha_1hop() -> f64 {
    MESSAGE_ALPHA_US + hop_latency_us(CableClass::PassiveElectrical)
}

/// One cost-model slot: (volume bytes, bw GB/s, transfers, alpha µs).
struct Slot {
    volume: f64,
    bw: f64,
    transfers: f64,
    alpha: f64,
}

/// Evaluate `ref.cost_model` for a single config whose communication is
/// fully serialized into `slots` (exposure 1) plus `compute_us`.
/// Shells out to the Python reference kernel; mirrors it in Rust if the
/// interpreter (or JAX) is missing.
fn reference_time_us(slots: &[Slot], compute_us: f64) -> f64 {
    let t = slots.len();
    let fmt_list =
        |f: &dyn Fn(&Slot) -> f64| -> String {
            slots
                .iter()
                .map(|s| format!("{:.17e}", f(s)))
                .collect::<Vec<_>>()
                .join(",")
        };
    let script = format!(
        "import sys; sys.path.insert(0, {root:?} + '/python')\n\
         import jax.numpy as jnp\n\
         from compile.kernels import ref\n\
         vol = jnp.array([[{vols}]]); bw = jnp.array([[{bws}]])\n\
         tr = jnp.array([[{trs}]]); al = jnp.array([{als}])\n\
         comp = jnp.array([{comp:.17e}]); ex = jnp.ones(({t},))\n\
         print(float(ref.cost_model(vol, bw, tr, al, comp, ex)[0]))\n",
        root = env!("CARGO_MANIFEST_DIR"),
        vols = fmt_list(&|s| s.volume),
        bws = fmt_list(&|s| s.bw),
        trs = fmt_list(&|s| s.transfers),
        als = fmt_list(&|s| s.alpha),
        comp = compute_us,
        t = t,
    );
    let mirror = || {
        eprintln!(
            "python3/jax unavailable — mirroring ref.cost_model in rust \
             (same α-β formula, f64)"
        );
        compute_us
            + slots
                .iter()
                .map(|s| s.volume / (s.bw * 1e3) + s.transfers * s.alpha)
                .sum::<f64>()
    };
    match Command::new("python3").arg("-c").arg(&script).output() {
        Ok(out) if out.status.success() => {
            let text = String::from_utf8_lossy(&out.stdout);
            text.trim()
                .parse::<f64>()
                .expect("ref.cost_model printed a non-number")
        }
        Ok(out) => {
            // Only a missing-environment error (no jax/numpy on this
            // machine) may fall back; a genuine ref.cost_model failure
            // must fail the test, not be silently mirrored away.
            let stderr = String::from_utf8_lossy(&out.stderr);
            assert!(
                stderr.contains("ModuleNotFoundError") || stderr.contains("ImportError"),
                "python ref.cost_model raised:\n{stderr}"
            );
            mirror()
        }
        Err(_) => mirror(), // no python3 interpreter at all
    }
}

fn check(name: &str, got_us: f64, expect_us: f64) {
    let rel = (got_us - expect_us).abs() / expect_us;
    assert!(
        rel < TOLERANCE,
        "{name}: DES {got_us} µs vs ref.cost_model {expect_us} µs (rel {rel:.2e})"
    );
}

/// The corrected tier formula must agree between
/// `TierBandwidth::ubmesh_mesh` (min over [`ubmesh_hop_chains`]) and
/// the Python mirror `ref.tier_bandwidths` at 1e-3 over every knob:
/// lanes, routing boost, mesh width, uplink oversubscription.
#[test]
fn corrected_tier_formula_matches_python_reference() {
    use ubmesh::workload::placement::TierBandwidth;
    let cases: [(u32, f64, u32, u32); 6] = [
        (16, 1.0, 2, 1),  // paper default, Shortest
        (16, 1.6, 2, 1),  // Detour
        (4, 1.85, 1, 2),  // thin provision, Borrow, narrow mesh, 2:1
        (16, 1.0, 2, 4),  // the measured 4:1 sweep
        (32, 1.6, 4, 1),  // fig20 mesh-sweep corner
        (8, 1.6, 8, 1),   // wide mesh on thin provision
    ];
    for (lanes, boost, mesh, oversub) in cases {
        let rust = TierBandwidth::ubmesh_mesh(lanes, boost, mesh, oversub);
        let script = format!(
            "import sys; sys.path.insert(0, {root:?} + '/python')\n\
             from compile.kernels import ref\n\
             print(','.join(repr(b) for b in \
             ref.tier_bandwidths({lanes}, {boost}, {mesh}, {oversub})))\n",
            root = env!("CARGO_MANIFEST_DIR"),
        );
        let reference: Vec<f64> = match Command::new("python3").arg("-c").arg(&script).output() {
            Ok(out) if out.status.success() => String::from_utf8_lossy(&out.stdout)
                .trim()
                .split(',')
                .map(|v| v.parse().expect("ref.tier_bandwidths printed a non-number"))
                .collect(),
            Ok(out) => {
                let stderr = String::from_utf8_lossy(&out.stderr);
                assert!(
                    stderr.contains("ModuleNotFoundError") || stderr.contains("ImportError"),
                    "python ref.tier_bandwidths raised:\n{stderr}"
                );
                eprintln!("python unavailable — skipping tier cross-check");
                return;
            }
            Err(_) => {
                eprintln!("no python3 — skipping tier cross-check");
                return;
            }
        };
        assert_eq!(reference.len(), rust.gb_s.len());
        for (tier, (&r, &p)) in rust.gb_s.iter().zip(&reference).enumerate() {
            let rel = (r - p).abs() / p.max(1e-12);
            assert!(
                rel < TOLERANCE,
                "x{lanes} boost {boost} mesh {mesh} {oversub}:1 tier {tier}: \
                 rust {r} vs ref {p} (rel {rel:.2e})"
            );
        }
    }
}

#[test]
fn canned_dag_single_transfer() {
    // DAG A: one 500 MB flow over one 50 GB/s hop.
    let t = k4();
    let net = SimNet::new(&t);
    let bytes = 500e6;
    let mut dag = StageDag::default();
    dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
        &t,
        &[NodeId(0), NodeId(1)],
        bytes,
    )]));
    let r = sim::schedule::run(&net, &dag);
    let expect = reference_time_us(
        &[Slot {
            volume: bytes,
            bw: 50.0,
            transfers: 1.0,
            alpha: alpha_1hop(),
        }],
        0.0,
    );
    check("single-transfer", r.makespan_us, expect);
}

#[test]
fn canned_dag_serial_chain() {
    // DAG B: three serial stages, different payloads, same 1-hop link
    // pattern — the α-β model adds the three transfer terms.
    let t = k4();
    let net = SimNet::new(&t);
    let payloads = [200e6, 120e6, 80e6];
    let mut dag = StageDag::default();
    let mut prev: Option<usize> = None;
    for (k, &b) in payloads.iter().enumerate() {
        let mut s = Stage::new(format!("s{k}")).with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            b,
        )]);
        if let Some(p) = prev {
            s = s.after(vec![p]);
        }
        prev = Some(dag.push(s));
    }
    let r = sim::schedule::run(&net, &dag);
    let slots: Vec<Slot> = payloads
        .iter()
        .map(|&b| Slot {
            volume: b,
            bw: 50.0,
            transfers: 1.0,
            alpha: alpha_1hop(),
        })
        .collect();
    let expect = reference_time_us(&slots, 0.0);
    check("serial-chain", r.makespan_us, expect);
}

#[test]
fn canned_dag_compute_then_transfer() {
    // DAG C: a compute-only stage feeding a transfer — compute is fully
    // exposed (no overlap), matching the cost model's compute_us term.
    let t = k4();
    let net = SimNet::new(&t);
    let compute_us = 5_000.0;
    let bytes = 300e6;
    let mut dag = StageDag::default();
    let gemm = dag.push(Stage::new("gemm").with_compute(compute_us));
    dag.push(
        Stage::new("xfer")
            .with_flows(vec![FlowSpec::along(&t, &[NodeId(0), NodeId(1)], bytes)])
            .after(vec![gemm]),
    );
    let r = sim::schedule::run(&net, &dag);
    let expect = reference_time_us(
        &[Slot {
            volume: bytes,
            bw: 50.0,
            transfers: 1.0,
            alpha: alpha_1hop(),
        }],
        compute_us,
    );
    check("compute-then-transfer", r.makespan_us, expect);
}
