//! Chaos fuzz for the fault runner (ISSUE-8 satellite): randomized
//! fault storms — overlapping flap trains, same-instant blast groups,
//! capacity dips — replayed against a live all-to-all under every
//! solver strategy ([`ResolveStrategy::Bounded`] / `RiseOnly` /
//! `FullComponentBfs`), with flap damping off and on.
//!
//! Properties pinned per storm:
//! * every run **completes** (storms always restore what they break, so
//!   a stall would be a recovery bug, not a scripted disconnection);
//! * the three strategies agree on makespan and byte-hops (the PR 1–3
//!   differential oracle, now under fault churn) and perform the exact
//!   same reroutes;
//! * the whole pipeline is deterministic in the storm seed;
//! * the event count stays bounded — a reroute livelock (flows
//!   endlessly re-selecting flapping links) would blow through the
//!   ceiling long before wall-clock timeouts trip.

use ubmesh::collectives::alltoall::dimwise_alltoall_dag;
use ubmesh::sim::fault::{FaultEvent, FaultPlan};
use ubmesh::sim::{self, RecoveryConfig, ResolveStrategy, SimConfig, SimNet};
use ubmesh::topology::ndmesh::{nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, LinkId, Topology};
use ubmesh::util::rng::Rng;

fn mesh() -> Topology {
    nd_fullmesh(
        "chaos",
        &[
            DimSpec::new(4, 4, CableClass::PassiveElectrical, 0.3),
            DimSpec::new(4, 4, CableClass::PassiveElectrical, 1.0),
        ],
    )
}

/// A randomized storm: two flap trains, one 3-link same-instant blast
/// group (restored as a group), one capacity dip-and-recover — all on
/// distinct links, all timed inside the healthy makespan so the DAG is
/// live when they hit. Every fault is eventually undone.
fn storm(t: &Topology, healthy_us: f64, seed: u64) -> FaultPlan {
    let mut rng = Rng::new(seed);
    let nlinks = t.link_count();
    let mut picked: Vec<u32> = Vec::new();
    let mut pick = |rng: &mut Rng, picked: &mut Vec<u32>| -> LinkId {
        loop {
            let l = rng.range(0, nlinks) as u32;
            if !picked.contains(&l) {
                picked.push(l);
                return LinkId(l);
            }
        }
    };
    let mut plan = FaultPlan::new();
    for _ in 0..2 {
        let l = pick(&mut rng, &mut picked);
        let t0 = rng.f64() * 0.5 * healthy_us;
        let cycles = 2 + rng.range(0, 3);
        let down = 20.0 + rng.f64() * 100.0;
        let up = 20.0 + rng.f64() * 100.0;
        plan = plan.flap_train(l, t0, cycles, down, up);
    }
    let gt = rng.f64() * 0.5 * healthy_us;
    let group: Vec<LinkId> = (0..3).map(|_| pick(&mut rng, &mut picked)).collect();
    plan = plan.group_at(gt, group.iter().map(|&l| FaultEvent::LinkDown(l)).collect());
    let restore_at = gt + 50.0 + rng.f64() * 200.0;
    plan = plan.group_at(
        restore_at,
        group.iter().map(|&l| FaultEvent::LinkUp(l)).collect(),
    );
    let l = pick(&mut rng, &mut picked);
    let full = t.link(l).capacity_gb_s();
    let td = rng.f64() * 0.5 * healthy_us;
    plan = plan.at(td, FaultEvent::LinkCapacity(l, full * 0.25));
    plan = plan.at(td + 100.0 + rng.f64() * 200.0, FaultEvent::LinkCapacity(l, full));
    plan
}

const STRATEGIES: [ResolveStrategy; 3] = [
    ResolveStrategy::Bounded,
    ResolveStrategy::RiseOnly,
    ResolveStrategy::FullComponentBfs,
];

/// Run one storm under one recovery config across all three strategies,
/// asserting completion, agreement, and the livelock bound; returns the
/// Bounded run for cross-config assertions.
fn run_storm(
    net: &SimNet,
    dag: &ubmesh::sim::StageDag,
    plan_base: &FaultPlan,
    rc: &RecoveryConfig,
) -> sim::schedule::SimReport {
    let plan = FaultPlan {
        events: plan_base.events.clone(),
        recovery: Some(rc.clone()),
    };
    let runs: Vec<_> = STRATEGIES
        .iter()
        .map(|&strategy| {
            sim::schedule::run_faulted(net, dag, &SimConfig { strategy }, &plan)
        })
        .collect();
    for (s, r) in STRATEGIES.iter().zip(&runs) {
        assert!(!r.is_stalled(), "{s:?}: stalled under a fully-restored storm");
        assert!(r.makespan_us.is_finite() && r.makespan_us > 0.0);
        // Livelock bound: a reroute loop on a flapping link would spin
        // the event count far beyond anything this DAG legitimately
        // needs (healthy runs take a few thousand events).
        assert!(r.events < 1_000_000, "{s:?}: {} events — livelock?", r.events);
        assert!(r.fault_events <= plan.len() as u64);
    }
    let b = runs[0].clone();
    for (s, r) in STRATEGIES.iter().zip(&runs).skip(1) {
        assert!(
            (r.makespan_us - b.makespan_us).abs() < 1e-6 * b.makespan_us,
            "{s:?} makespan {} vs Bounded {}",
            r.makespan_us,
            b.makespan_us
        );
        assert!(
            (r.byte_hops - b.byte_hops).abs() < 1e-6 * b.byte_hops,
            "{s:?} byte-hops {} vs Bounded {}",
            r.byte_hops,
            b.byte_hops
        );
        assert_eq!(r.reroutes, b.reroutes, "{s:?} reroute count diverged");
    }
    b
}

#[test]
fn fault_storms_agree_across_strategies_and_damping() {
    let t = mesh();
    let net = SimNet::new(&t);
    let dag = dimwise_alltoall_dag(&t, &[4, 4], 4e6);
    let healthy = sim::schedule::run(&net, &dag);
    assert!(!healthy.is_stalled());

    for seed in 0..6u64 {
        let plan = storm(&t, healthy.makespan_us, seed);
        let raw = run_storm(&net, &dag, &plan, &RecoveryConfig::direct());
        let damped = run_storm(
            &net,
            &dag,
            &plan,
            &RecoveryConfig::direct().with_flap_damping(500.0),
        );
        // Damping is advisory path-steering: it must never break the
        // run or lose traffic, only change which links reroutes pick.
        assert!(damped.makespan_us.is_finite());
        assert!(raw.makespan_us >= healthy.makespan_us * (1.0 - 1e-9));
    }
}

/// The exact same storm seed reproduces the exact same run,
/// bit-for-bit, including reroute and event counts — the replay
/// property every measured-availability experiment leans on.
#[test]
fn storm_replay_is_deterministic_in_seed() {
    let t = mesh();
    let net = SimNet::new(&t);
    let dag = dimwise_alltoall_dag(&t, &[4, 4], 4e6);
    let healthy = sim::schedule::run(&net, &dag);

    for &hyst in &[0.0, 500.0] {
        let rc = RecoveryConfig::direct().with_flap_damping(hyst);
        for seed in [3u64, 4] {
            let p1 = storm(&t, healthy.makespan_us, seed);
            let p2 = storm(&t, healthy.makespan_us, seed);
            assert_eq!(p1.len(), p2.len(), "storm builder must be deterministic");
            let cfg = SimConfig::default();
            let r1 = sim::schedule::run_faulted(
                &net,
                &dag,
                &cfg,
                &FaultPlan {
                    events: p1.events,
                    recovery: Some(rc.clone()),
                },
            );
            let r2 = sim::schedule::run_faulted(
                &net,
                &dag,
                &cfg,
                &FaultPlan {
                    events: p2.events,
                    recovery: Some(rc.clone()),
                },
            );
            assert_eq!(r1.makespan_us.to_bits(), r2.makespan_us.to_bits());
            assert_eq!(r1.byte_hops.to_bits(), r2.byte_hops.to_bits());
            assert_eq!(r1.reroutes, r2.reroutes);
            assert_eq!(r1.events, r2.events);
            assert_eq!(r1.fault_events, r2.fault_events);
        }
    }
}

/// Same-instant groups apply atomically: a three-link blast fired as
/// one group gives the same end state as the same events scripted as
/// three `at()` calls at the same timestamp (FaultPlan order is the
/// tiebreak, and it is identical here).
#[test]
fn same_instant_groups_match_sequential_scripting() {
    let t = mesh();
    let net = SimNet::new(&t);
    let dag = dimwise_alltoall_dag(&t, &[4, 4], 4e6);
    let healthy = sim::schedule::run(&net, &dag);
    let at = 0.25 * healthy.makespan_us;
    let links = [LinkId(0), LinkId(7), LinkId(19)];

    let grouped = FaultPlan::new()
        .group_at(at, links.iter().map(|&l| FaultEvent::LinkDown(l)).collect())
        .group_at(
            at + 400.0,
            links.iter().map(|&l| FaultEvent::LinkUp(l)).collect(),
        )
        .with_recovery(RecoveryConfig::direct());
    let mut seq = FaultPlan::new();
    for &l in &links {
        seq = seq.at(at, FaultEvent::LinkDown(l));
    }
    for &l in &links {
        seq = seq.at(at + 400.0, FaultEvent::LinkUp(l));
    }
    let seq = seq.with_recovery(RecoveryConfig::direct());

    let cfg = SimConfig::default();
    let rg = sim::schedule::run_faulted(&net, &dag, &cfg, &grouped);
    let rs = sim::schedule::run_faulted(&net, &dag, &cfg, &seq);
    assert!(!rg.is_stalled() && !rs.is_stalled());
    assert_eq!(rg.makespan_us.to_bits(), rs.makespan_us.to_bits());
    assert_eq!(rg.reroutes, rs.reroutes);
}

/// PR 10: fault storms replayed **under the component-parallel loop**.
/// Each row of the mesh runs its own all-to-all component with its own
/// scripted flap train on one of its private dim-0 links; the parallel
/// runner must reproduce the single-worker runs bit-for-bit — reroutes,
/// fault-event counts, makespans — at every worker count, because a
/// component's faults touch only its own links.
#[test]
fn fault_storm_under_parallel_loop_matches_serial() {
    use ubmesh::collectives::alltoall::row_alltoall_dags;
    use ubmesh::sim::{run_components_faulted, ParallelConfig};
    use ubmesh::topology::ndmesh::index_of;

    let t = mesh();
    let net = SimNet::new(&t);
    let dags = row_alltoall_dags(&t, &[4, 4], 4e6, 2);
    assert_eq!(dags.len(), 4);

    // One plan per row: flap a link interior to the row (its first
    // dim-0 edge), restored before the end, with direct-notification
    // recovery so cut-off flows reroute mid-run. Fault times scale off
    // the row's healthy makespan so every flap lands while the DAG is
    // live: two cycles of a long outage starting at 0.15·h stay inside
    // ~0.85·h.
    let healthy = ubmesh::sim::run_components(&net, &dags, &ParallelConfig::serial());
    let plans: Vec<FaultPlan> = (0..4usize)
        .map(|row| {
            let h = healthy[row].makespan_us;
            assert!(h.is_finite() && h > 0.0);
            let a = t.npus[index_of(&[0, row], &[4, 4])];
            let b = t.npus[index_of(&[1, row], &[4, 4])];
            let l = t.link_between(a, b).expect("dim-0 row link");
            let mut plan = FaultPlan::new().flap_train(
                l,
                (0.15 + 0.02 * row as f64) * h,
                2,
                0.25 * h,
                0.05 * h,
            );
            plan.recovery = Some(RecoveryConfig::direct());
            plan
        })
        .collect();

    for &strategy in &STRATEGIES {
        let serial = run_components_faulted(
            &net,
            &dags,
            &ParallelConfig::serial().with_strategy(strategy),
            &plans,
        );
        for r in &serial {
            assert!(!r.is_stalled(), "flap train restores every link");
            assert!(r.fault_events > 0, "the storm must actually fire");
        }
        assert!(
            serial.iter().any(|r| r.reroutes > 0),
            "at least one row must reroute mid-flap"
        );
        for workers in [2usize, 8] {
            let par = run_components_faulted(
                &net,
                &dags,
                &ParallelConfig::serial()
                    .with_workers(workers)
                    .with_strategy(strategy),
                &plans,
            );
            for (a, b) in par.iter().zip(&serial) {
                assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
                assert_eq!(a.byte_hops.to_bits(), b.byte_hops.to_bits());
                assert_eq!(a.events, b.events);
                assert_eq!(a.reroutes, b.reroutes);
                assert_eq!(a.fault_events, b.fault_events);
                assert_eq!(a.stalled.len(), b.stalled.len());
            }
        }
    }
}
