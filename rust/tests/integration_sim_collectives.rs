//! Integration: collectives executed on the DES over real UB-Mesh
//! topologies, cross-checked against closed forms.

use ubmesh::collectives::alltoall::{multipath_alltoall_dag, Grid};
use ubmesh::collectives::cost::{allreduce_multiring_us, allreduce_ring_us};
use ubmesh::collectives::hierarchical::hierarchical_allreduce_dag;
use ubmesh::collectives::ring::{fullmesh_rings, multiring_allreduce_dag, ring_allreduce_dag};
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::ublink::LANE_GB_S;
use ubmesh::topology::NodeId;

fn rack() -> (ubmesh::topology::Topology, ubmesh::topology::rack::RackHandles) {
    ubmesh_rack(&RackConfig::default())
}

#[test]
fn board_multiring_reaches_3x_on_real_rack() {
    let (t, h) = rack();
    let board: Vec<NodeId> = (0..8).map(|s| h.npu(0, s, 8)).collect();
    let bytes = 360e6;
    let net = SimNet::new(&t);
    let single = sim::schedule::run(&net, &ring_allreduce_dag(&t, &board, bytes));
    let rings = fullmesh_rings(&board, 3);
    let multi = sim::schedule::run(
        &net,
        &multiring_allreduce_dag(&t, &rings, &[1.0; 3], bytes),
    );
    let speedup = single.makespan_us / multi.makespan_us;
    assert!((2.5..3.3).contains(&speedup), "speedup {speedup}");
    // closed forms agree
    let bw = 4.0 * LANE_GB_S;
    assert!(allreduce_multiring_us(bytes, 8, bw, 3, 0.0) < allreduce_ring_us(bytes, 8, bw, 0.0));
}

#[test]
fn rack_hierarchical_allreduce_uses_both_dims() {
    let (t, h) = rack();
    let rows: Vec<Vec<NodeId>> = (0..8)
        .map(|b| (0..8).map(|s| h.npu(b, s, 8)).collect())
        .collect();
    let cols: Vec<Vec<NodeId>> = (0..8)
        .map(|s| (0..8).map(|b| h.npu(b, s, 8)).collect())
        .collect();
    let bytes = 360e6;
    let net = SimNet::new(&t);
    let dag = hierarchical_allreduce_dag(&t, &rows, &cols, bytes);
    let r = sim::schedule::run(&net, &dag);
    // Single global snake ring for contrast.
    let mut snake = Vec::new();
    for b in 0..8 {
        if b % 2 == 0 {
            for s in 0..8 {
                snake.push(h.npu(b, s, 8));
            }
        } else {
            for s in (0..8).rev() {
                snake.push(h.npu(b, s, 8));
            }
        }
    }
    let flat = sim::schedule::run(&net, &ring_allreduce_dag(&t, &snake, bytes));
    assert!(
        r.makespan_us < flat.makespan_us,
        "hierarchical {} flat {}",
        r.makespan_us,
        flat.makespan_us
    );
}

#[test]
fn rack_alltoall_completes_with_one_hop_forwarding() {
    let (t, h) = rack();
    let g = Grid::new(&h.npus, 8, 8);
    let dag = multipath_alltoall_dag(&t, &g, 10.5e6 / 63.0); // Table 1 EP volume
    assert!(dag.stages[0]
        .materialize_flows(&t)
        .iter()
        .all(|f| f.channels.len() <= 2));
    let net = SimNet::new(&t);
    let r = sim::schedule::run(&net, &dag);
    assert!(r.makespan_us > 0.0);
    assert!(r.peak_flows > 4000, "64×63 pairs in flight");
}

#[test]
fn failed_link_degrades_but_multipath_survives() {
    use ubmesh::routing::apr::{paths_2d, to_routed, PathSet};
    use ubmesh::sim::{FlowSpec, Stage, StageDag};
    let (t, h) = rack();
    let node = |x: usize, y: usize| h.npu(y, x, 8);
    let routed: Vec<_> = paths_2d((0, 0), (3, 4), 8, 8, true)
        .iter()
        .map(|m| to_routed(m, node))
        .collect();
    let ps = PathSet::weighted_by_bottleneck(routed, &t);
    let bytes = 64e6;
    let paths: Vec<Vec<NodeId>> = ps.paths.iter().map(|p| p.nodes.clone()).collect();

    // Fail the direct corner link used by the first shortest path.
    let mut net = SimNet::new(&t);
    let l = t.link_between(paths[0][0], paths[0][1]).unwrap();
    net.fail_link(l);

    // Drop flows crossing the failed link (APR reroutes around it).
    let surviving: Vec<(Vec<NodeId>, f64)> = paths
        .iter()
        .zip(&ps.weights)
        .filter(|(p, _)| {
            p.windows(2)
                .all(|w| t.link_between(w[0], w[1]) != Some(l))
        })
        .map(|(p, &w)| (p.clone(), w))
        .collect();
    assert!(surviving.len() >= ps.paths.len() - 4, "most paths survive");
    let flows: Vec<FlowSpec> = surviving
        .iter()
        .map(|(p, w)| FlowSpec::along(&t, p, bytes * w))
        .collect();
    let mut dag = StageDag::default();
    dag.push(Stage::new("apr-after-failure").with_flows(flows));
    let r = sim::schedule::run(&net, &dag);
    assert!(r.makespan_us > 0.0, "transfer completes despite failure");
}
