//! Measured-vs-analytic tests for the full training iteration
//! (`workload::step::iteration_dag`, PR 5): the DES step on the real
//! rack/pod/SuperPod topologies against the §5.2 analytic model as the
//! differential oracle, the emergent 1F1B pipeline bubble, and the
//! cross-pod (HRS-tier) iteration.
//!
//! Tolerances are calibrated from the statement-level Python mirror
//! (see CHANGES.md): each band's expected value is quoted inline, and
//! the band leaves ≥8% margin on the structural sources of gap —
//! backplane-mesh ceilings on DP/EP traffic vs the analytic tier
//! bandwidths, α gates, per-hop latencies, and 1F1B steady-state
//! relay poaching.

use ubmesh::sim::{self, SimNet};
use ubmesh::topology::pod::{ubmesh_pod, PodConfig};
use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::workload::models::by_name;
use ubmesh::workload::placement::{Placement, TierBandwidth};
use ubmesh::workload::step::{iteration_dag, iteration_time, IterationSpec, RankOrder};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

fn pcfg(
    tp: usize,
    sp: usize,
    ep: usize,
    pp: usize,
    dp: usize,
    mb: usize,
    tokens: f64,
) -> ParallelismConfig {
    ParallelismConfig {
        tp,
        sp,
        ep,
        pp,
        dp,
        microbatches: mb,
        tokens_per_microbatch: tokens,
    }
}

fn measure(
    t: &ubmesh::topology::Topology,
    map: &ClusterMap,
    m: &ubmesh::workload::ModelConfig,
    p: &ParallelismConfig,
    order: RankOrder,
) -> f64 {
    let dag = iteration_dag(t, map, m, p, order, &IterationSpec::default());
    let r = sim::schedule::run(&SimNet::new(t), &dag);
    assert!(!r.is_stalled());
    r.makespan_us
}

fn analytic(m: &ubmesh::workload::ModelConfig, p: &ParallelismConfig) -> f64 {
    iteration_time(m, p, &Placement::topology_aware(p), &TierBandwidth::ubmesh(16, 1.0))
        .total_us
}

/// The measured-vs-analytic grid: 2 models × 2 parallelisms × 2 scales
/// (rack 64, pod 1024). Mirror-measured ratios: rack 1.036 / 1.021,
/// pod 1.038 / 1.028 — the rack band is dominated by striping-relay
/// contention in 1F1B steady state. The pod cases sat at 1.063 / 1.055
/// while the analytic Col tier ignored the board-LRS backplane-mesh
/// hop; with the hop-chain model pricing it (Shortest Col: 18.75 GB/s,
/// not the wire-stage 37.5) the pod band tightens from the pre-fix
/// (0.95, 1.30) to (0.93, 1.18). The residual ~3–4% on both scales is
/// 1F1B relay poaching and α-gate serialization, which the closed form
/// does not model.
#[test]
fn measured_iteration_tracks_analytic_across_grid() {
    let (rack_t, rack_h) = ubmesh_rack(&RackConfig::default());
    let rack_map = ClusterMap::rack(&rack_h);
    let (pod_t, pod_h) = ubmesh_pod(&PodConfig::default());
    let pod_map = ClusterMap::pod(&pod_h);

    // (model, parallelism, map, lo, hi, label)
    let rack_band = (0.90, 1.15);
    let pod_band = (0.93, 1.18);
    let grid: Vec<(&str, ParallelismConfig, bool, (f64, f64))> = vec![
        ("llama-70b", pcfg(8, 2, 1, 2, 2, 4, 8192.0), false, rack_band),
        ("gpt4-2t", pcfg(8, 2, 4, 2, 2, 4, 8192.0), false, rack_band),
        ("llama-70b", pcfg(8, 8, 1, 4, 4, 2, 32768.0), true, pod_band),
        ("gpt4-2t", pcfg(8, 8, 8, 4, 4, 2, 32768.0), true, pod_band),
    ];
    for (name, p, is_pod, (lo, hi)) in grid {
        let m = by_name(name).unwrap();
        let (t, map) = if is_pod {
            (&pod_t, &pod_map)
        } else {
            (&rack_t, &rack_map)
        };
        let des = measure(t, map, &m, &p, RankOrder::TopologyAware);
        let an = analytic(&m, &p);
        let ratio = des / an;
        assert!(
            (lo..hi).contains(&ratio),
            "{name} {}: DES {des:.0} vs analytic {an:.0} — ratio {ratio:.3} \
             outside calibrated ({lo}, {hi})",
            if is_pod { "pod" } else { "rack" },
        );
    }
}

/// The pipeline bubble is *emergent* — nothing in `iteration_dag`
/// computes (pp−1)/mb, yet the measured makespans reproduce it:
/// M(mb) ≈ mb·u + (pp−1)·u for per-microbatch unit time u, so the
/// measured bubble fraction M(mb)/(mb·u) − 1 must track (pp−1)/mb,
/// grow with pp and shrink with mb. Mirror-measured relative error:
/// −1.1% (pp=4), −11.7% (pp=2, the comm-tail share of the warmup
/// units); asserted within ±25%.
#[test]
fn pipeline_bubble_is_emergent_and_tracks_pp_over_mb() {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let map = ClusterMap::rack(&h);
    let m = by_name("llama-70b").unwrap();
    let mut fracs = Vec::new();
    for (sp, pp) in [(4usize, 2usize), (2, 4)] {
        let mk = |mb: usize| {
            measure(
                &t,
                &map,
                &m,
                &pcfg(8, sp, 1, pp, 1, mb, 4096.0),
                RankOrder::TopologyAware,
            )
        };
        let (m2, m4, m8) = (mk(2), mk(4), mk(8));
        // Per-unit time from the slope: adding 4 microbatches adds 4
        // units to every device's serialized chain.
        let u = (m8 - m4) / 4.0;
        assert!(u > 0.0);
        for (mb, ms) in [(2u32, m2), (4, m4), (8, m8)] {
            let frac = ms / (mb as f64 * u) - 1.0;
            let predict = (pp as f64 - 1.0) / mb as f64;
            assert!(
                (frac / predict - 1.0).abs() < 0.25,
                "pp={pp} mb={mb}: measured bubble frac {frac:.4} vs (pp-1)/mb \
                 {predict:.4}"
            );
        }
        let f4 = m4 / (4.0 * u) - 1.0;
        let f8 = m8 / (8.0 * u) - 1.0;
        assert!(f8 < f4, "bubble must shrink with more microbatches");
        fracs.push(m4 / (4.0 * u) - 1.0);
    }
    assert!(
        fracs[1] > fracs[0] * 2.0,
        "bubble at pp=4 ({:.3}) must dwarf pp=2 ({:.3}) at equal mb",
        fracs[1],
        fracs[0]
    );
}

/// Full five-technique iteration crossing pods: EP tiles SP×DP across
/// two pods and DP pairs ride the HRS Clos tier. The hop-chain model
/// prices that traffic at the uplink-mesh-bound 12.5 GB/s/NPU (the old
/// model's 25 GB/s uplink figure skipped the mesh hop, putting the
/// ratio at 1.843); what remains above the oracle is genuine multi-
/// phase HRS contention the closed form cannot see — mirror-measured
/// ratio 1.639, asserted inside (1.3, 2.0), down from (1.0, 2.5).
#[test]
fn cross_pod_iteration_completes_with_bounded_contention_excess() {
    let mut cfg = SuperPodConfig::default();
    cfg.pods = 2;
    cfg.pod.rows = 2;
    cfg.pod.cols = 2;
    let (t, h) = ubmesh_superpod(&cfg);
    let map = ClusterMap::superpod(&h);
    let m = by_name("gpt4-2t").unwrap();
    let p = pcfg(8, 8, 16, 2, 4, 2, 4096.0);
    assert_eq!(p.npus(), 512);
    let des = measure(&t, &map, &m, &p, RankOrder::TopologyAware);
    let an = analytic(&m, &p);
    let ratio = des / an;
    assert!(
        (1.3..2.0).contains(&ratio),
        "cross-pod DES {des:.0} vs analytic {an:.0} — ratio {ratio:.3} \
         outside calibrated (1.3, 2.0), mirror 1.639"
    );
}

/// `SuperPodConfig::uplink_oversub` must degrade the *analytic* plan
/// the way the measured 4:1 sweep degrades the DES phase
/// (`oversub.rN.interpod_us` ≈ 325 / 325 / 645 µs): 2:1 is free because
/// the x2 uplink mesh slots (12.5 GB/s/NPU) saturate before the halved
/// uplink-LRS lanes, and 4:1 halves the Pod tier (6.25 GB/s). The
/// analytic DP-phase ratio t(4:1)/t(1:1) = 2.000 must agree with the
/// measured 645/325 = 1.985 within 10%.
#[test]
fn analytic_oversub_degrades_like_the_measured_sweep() {
    let m = by_name("gpt4-2t").unwrap();
    // DP spans all 4096 NPUs → the Pod tier prices the DP tail.
    let p = pcfg(8, 8, 16, 8, 8, 4, 8192.0);
    assert_eq!(p.npus(), 4096);
    let dp_us = |oversub| {
        let bw = TierBandwidth::ubmesh_mesh(16, 1.0, 2, oversub);
        assert!(
            (bw.gb_s[4] - if oversub == 4 { 6.25 } else { 12.5 }).abs() < 1e-9,
            "{oversub}:1 pod tier {}",
            bw.gb_s[4]
        );
        iteration_time(&m, &p, &Placement::topology_aware(&p), &bw).dp_us
    };
    let (r1, r2, r4) = (dp_us(1), dp_us(2), dp_us(4));
    assert_eq!(r1, r2, "2:1 oversubscription must be free (mesh-bound)");
    let analytic_ratio = r4 / r1;
    let measured_ratio = 645.0 / 325.0; // oversub.r4/r1.interpod_us
    assert!(
        (analytic_ratio / measured_ratio - 1.0).abs() < 0.10,
        "4:1/1:1 analytic {analytic_ratio:.3} vs measured {measured_ratio:.3}"
    );
}
