//! SuperPod-scale acceptance tests (ISSUE 2): 32 768 NPUs — 8 Pods of
//! 4096 — as the generalized 5D nd-fullmesh ([8,8,8,8,8], the 4D
//! intra-pod mesh plus the pod tier as the 5th dimension).
//!
//! Two workloads:
//!
//! * the uniform dimension-wise all-to-all, whose makespan has an exact
//!   closed form (every directed channel carries exactly one flow per
//!   phase) — proves the solver + event loop complete and stay exact at
//!   8× the PR 1 Pod scale;
//! * the jittered SuperPod all-to-all with APR two-path inter-pod
//!   transmission — hundreds of thousands of *staggered* completions
//!   inside shared-channel components hundreds of flows wide, the
//!   workload the rise-only bounded re-solve exists for. The test pins
//!   the ≥5× recompute reduction vs the PR 1 full-component solver (the
//!   acceptance bar; `benches/perf_hotpaths.rs` measures the same ratio
//!   by actually running both solvers — at 512 NPUs *and* at the full
//!   32K — and records it in BENCH_sim.json).
//!
//! Lazy stage materialization + flow-slot recycling keep peak memory at
//! one phase's flows (≈230–460k) instead of the whole 1.6M-flow DAG.

use ubmesh::collectives::alltoall::{dimwise_alltoall_dag, superpod_alltoall_dag};
use ubmesh::sim::{self, SimNet};
use ubmesh::topology::ndmesh::{expected_links, nd_fullmesh, DimSpec};
use ubmesh::topology::ublink::LANE_GB_S;
use ubmesh::topology::{CableClass, Topology};

/// 8 pods × 8×8×8×8: x2 lanes per link keeps every NPU within its x72
/// budget (5 dims × 7 peers × 2 lanes = 70).
fn superpod_32k() -> Topology {
    let dims = [8usize, 8, 8, 8, 8];
    let specs: Vec<DimSpec> = dims
        .iter()
        .enumerate()
        .map(|(d, &size)| {
            if d == 4 {
                DimSpec::new(size, 2, CableClass::Optical, 50.0) // pod tier
            } else {
                DimSpec::new(size, 2, CableClass::PassiveElectrical, 1.0)
            }
        })
        .collect();
    nd_fullmesh("superpod32k", &specs)
}

#[test]
fn superpod_scale_5d_dimwise_alltoall_completes() {
    let dims = [8usize, 8, 8, 8, 8]; // 32 768 NPUs — the 8-Pod SuperPod
    let t = superpod_32k();
    assert_eq!(t.node_count(), 32768);
    assert_eq!(t.link_count(), expected_links(&dims)); // 573 440

    let bytes = 4e6; // per (node, dim-peer) payload
    let dag = dimwise_alltoall_dag(&t, &dims, bytes);
    assert_eq!(dag.stages.len(), 5);
    let flows_per_phase = 32768 * 7;
    for s in &dag.stages {
        assert!(s.is_lazy(), "phases must be lazily materialized");
        assert_eq!(s.flow_count(), flows_per_phase);
    }

    let net = SimNet::new(&t);
    let r = sim::schedule::run(&net, &dag);

    // Every directed channel carries exactly one flow per phase, so each
    // phase runs at full per-link bandwidth (x2 lanes = 12.5 GB/s) and
    // the makespan has a closed form: 5 × (latency + bytes / bw).
    let bw = 2.0 * LANE_GB_S;
    let phase_us = bytes / (bw * 1e3);
    let expect = 5.0 * phase_us;
    assert!(
        (r.makespan_us - expect).abs() / expect < 0.02,
        "makespan {} vs closed-form {expect}",
        r.makespan_us
    );

    // All five phases really ran (byte-hop conservation at scale).
    let total_bytes = 5.0 * flows_per_phase as f64 * bytes;
    assert!(
        (r.byte_hops - total_bytes).abs() / total_bytes < 1e-6,
        "byte-hops {} vs {total_bytes}",
        r.byte_hops
    );
    assert_eq!(r.peak_flows, flows_per_phase, "phases are serialized");
    assert!(r.events as usize >= 5 * flows_per_phase, "events {}", r.events);
}

#[test]
fn superpod_apr_alltoall_rise_only_solver_wins() {
    let intra = [8usize, 8, 8, 8];
    let pods = 8;
    let t = superpod_32k();
    let bytes = 1e6;
    let jitter = 1.0;
    let dag = superpod_alltoall_dag(&t, &intra, pods, bytes, jitter);
    assert_eq!(dag.stages.len(), 5); // 4 intra dims + inter-pod
    let inter_flows = 32768 * (pods - 1) * 2; // 458 752: direct + detour halves
    assert_eq!(dag.stages[4].flow_count(), inter_flows);

    let net = SimNet::new(&t);
    let r = sim::schedule::run(&net, &dag);

    // Byte-hop conservation against the materialized schedule (jittered
    // payloads, 1-hop direct + 3-hop detours — computed independently).
    let expect: f64 = dag
        .stages
        .iter()
        .map(|s| {
            s.materialize_flows(&t)
                .iter()
                .map(|f| f.bytes * f.channels.len() as f64)
                .sum::<f64>()
        })
        .sum();
    assert!(
        (r.byte_hops - expect).abs() / expect < 1e-6,
        "byte-hops {} vs {expect}",
        r.byte_hops
    );

    // The inter-pod phase holds the most concurrent flows; slot
    // recycling means earlier phases' slots were reused, so the peak is
    // exactly the inter-pod release.
    assert_eq!(r.peak_flows, inter_flows);

    // Jittered staggering really happened: far more event batches than
    // the 10 a uniform run produces (5 gates + 5 phase completions).
    assert!(
        r.solver.resolves > 10_000,
        "expected staggered completions, got {} resolves",
        r.solver.resolves
    );

    // Acceptance: ≥5× fewer flow-rate recomputations per event than the
    // PR 1 full-component solver would perform on the same event
    // sequence (its per-event cost is the union-find component size,
    // accumulated in full_component_recomputes).
    let ratio =
        r.solver.full_component_recomputes as f64 / r.solver.rate_recomputes as f64;
    assert!(
        ratio >= 5.0,
        "rise-only solver must be ≥5x narrower: {} full-component vs {} actual ({ratio:.2}x)",
        r.solver.full_component_recomputes,
        r.solver.rate_recomputes
    );

    // Makespan sanity: at least the 4 serialized intra phases at full
    // per-link bandwidth, and not absurdly beyond the loosest serial
    // bound for the inter phase.
    let bw = 2.0 * LANE_GB_S;
    let intra_us = 4.0 * bytes / (bw * 1e3);
    assert!(r.makespan_us > intra_us, "makespan {}", r.makespan_us);
    let inter_bytes_worst = (1.0 + jitter) * bytes * (pods - 1) as f64 * 4.0;
    assert!(
        r.makespan_us < intra_us + inter_bytes_worst / (bw * 1e3) * 100.0,
        "makespan {} suspiciously large",
        r.makespan_us
    );
}
