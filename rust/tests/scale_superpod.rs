//! SuperPod-scale acceptance tests (ISSUE 2 + ISSUE 3): 32 768 NPUs.
//!
//! Three workloads:
//!
//! * the uniform dimension-wise all-to-all on the generalized 5D
//!   nd-fullmesh ([8,8,8,8,8]), whose makespan has an exact closed form
//!   (every directed channel carries exactly one flow per phase) —
//!   proves the solver + event loop complete and stay exact at 8× the
//!   PR 1 Pod scale;
//! * the jittered SuperPod all-to-all with APR two-path inter-pod
//!   transmission — hundreds of thousands of *staggered* completions
//!   inside shared-channel components hundreds of flows wide, the
//!   workload the rise-only bounded re-solve exists for. The test pins
//!   the ≥5× recompute reduction vs the PR 1 full-component solver (the
//!   acceptance bar; `benches/perf_hotpaths.rs` measures the same ratio
//!   by actually running both solvers — at 512 NPUs *and* at the full
//!   32K — and records it in BENCH_sim.json);
//! * (ISSUE 3) the **HRS-routed** SuperPod all-to-all on the *real*
//!   Clos tier (32 pods × 1024-NPU pods, 512 racks, 256 HRS): staggered
//!   gate opens spawn ~200k six-hop flows one event at a time into the
//!   live switch-contention component — the fall-only bounded add's
//!   acceptance workload. The test pins the ≥3× add-path recompute
//!   reduction vs a full-component add (the union-find live estimate,
//!   which `benches/perf_hotpaths.rs` validates against a *measured*
//!   full-component run at the 1024-NPU mid-scale) and the 4:1 vs 1:1
//!   rack-uplink oversubscription ordering.
//!
//! Lazy stage materialization + flow-slot recycling keep peak memory at
//! one phase's flows instead of the whole DAG.

use ubmesh::collectives::alltoall::{
    dimwise_alltoall_dag, hrs_reroute, superpod_alltoall_dag, superpod_hrs_alltoall_dag,
};
use ubmesh::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
use ubmesh::sim::{self, SimConfig, SimNet};
use ubmesh::topology::ndmesh::{expected_links, nd_fullmesh, DimSpec};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::topology::ublink::LANE_GB_S;
use ubmesh::topology::{CableClass, Topology};

/// 8 pods × 8×8×8×8: x2 lanes per link keeps every NPU within its x72
/// budget (5 dims × 7 peers × 2 lanes = 70).
fn superpod_32k() -> Topology {
    let dims = [8usize, 8, 8, 8, 8];
    let specs: Vec<DimSpec> = dims
        .iter()
        .enumerate()
        .map(|(d, &size)| {
            if d == 4 {
                DimSpec::new(size, 2, CableClass::Optical, 50.0) // pod tier
            } else {
                DimSpec::new(size, 2, CableClass::PassiveElectrical, 1.0)
            }
        })
        .collect();
    nd_fullmesh("superpod32k", &specs)
}

#[test]
fn superpod_scale_5d_dimwise_alltoall_completes() {
    let dims = [8usize, 8, 8, 8, 8]; // 32 768 NPUs — the 8-Pod SuperPod
    let t = superpod_32k();
    assert_eq!(t.node_count(), 32768);
    assert_eq!(t.link_count(), expected_links(&dims)); // 573 440

    let bytes = 4e6; // per (node, dim-peer) payload
    let dag = dimwise_alltoall_dag(&t, &dims, bytes);
    assert_eq!(dag.stages.len(), 5);
    let flows_per_phase = 32768 * 7;
    for s in &dag.stages {
        assert!(s.is_lazy(), "phases must be lazily materialized");
        assert_eq!(s.flow_count(), flows_per_phase);
    }

    let net = SimNet::new(&t);
    let r = sim::schedule::run(&net, &dag);

    // Every directed channel carries exactly one flow per phase, so each
    // phase runs at full per-link bandwidth (x2 lanes = 12.5 GB/s) and
    // the makespan has a closed form: 5 × (latency + bytes / bw).
    let bw = 2.0 * LANE_GB_S;
    let phase_us = bytes / (bw * 1e3);
    let expect = 5.0 * phase_us;
    assert!(
        (r.makespan_us - expect).abs() / expect < 0.02,
        "makespan {} vs closed-form {expect}",
        r.makespan_us
    );

    // All five phases really ran (byte-hop conservation at scale).
    let total_bytes = 5.0 * flows_per_phase as f64 * bytes;
    assert!(
        (r.byte_hops - total_bytes).abs() / total_bytes < 1e-6,
        "byte-hops {} vs {total_bytes}",
        r.byte_hops
    );
    assert_eq!(r.peak_flows, flows_per_phase, "phases are serialized");
    assert!(r.events as usize >= 5 * flows_per_phase, "events {}", r.events);
}

#[test]
fn superpod_apr_alltoall_rise_only_solver_wins() {
    let intra = [8usize, 8, 8, 8];
    let pods = 8;
    let t = superpod_32k();
    let bytes = 1e6;
    let jitter = 1.0;
    let dag = superpod_alltoall_dag(&t, &intra, pods, bytes, jitter);
    assert_eq!(dag.stages.len(), 5); // 4 intra dims + inter-pod
    let inter_flows = 32768 * (pods - 1) * 2; // 458 752: direct + detour halves
    assert_eq!(dag.stages[4].flow_count(), inter_flows);

    let net = SimNet::new(&t);
    let r = sim::schedule::run(&net, &dag);

    // Byte-hop conservation against the materialized schedule (jittered
    // payloads, 1-hop direct + 3-hop detours — computed independently).
    let expect: f64 = dag
        .stages
        .iter()
        .map(|s| {
            s.materialize_flows(&t)
                .iter()
                .map(|f| f.bytes * f.channels.len() as f64)
                .sum::<f64>()
        })
        .sum();
    assert!(
        (r.byte_hops - expect).abs() / expect < 1e-6,
        "byte-hops {} vs {expect}",
        r.byte_hops
    );

    // The inter-pod phase holds the most concurrent flows; slot
    // recycling means earlier phases' slots were reused, so the peak is
    // exactly the inter-pod release.
    assert_eq!(r.peak_flows, inter_flows);

    // Jittered staggering really happened: far more event batches than
    // the 10 a uniform run produces (5 gates + 5 phase completions).
    assert!(
        r.solver.resolves > 10_000,
        "expected staggered completions, got {} resolves",
        r.solver.resolves
    );

    // Acceptance: ≥5× fewer flow-rate recomputations per event than the
    // PR 1 full-component solver would perform on the same event
    // sequence (its per-event cost is the union-find component size,
    // accumulated in full_component_recomputes).
    let ratio =
        r.solver.full_component_recomputes as f64 / r.solver.rate_recomputes as f64;
    assert!(
        ratio >= 5.0,
        "rise-only solver must be ≥5x narrower: {} full-component vs {} actual ({ratio:.2}x)",
        r.solver.full_component_recomputes,
        r.solver.rate_recomputes
    );

    // Makespan sanity: at least the 4 serialized intra phases at full
    // per-link bandwidth, and not absurdly beyond the loosest serial
    // bound for the inter phase.
    let bw = 2.0 * LANE_GB_S;
    let intra_us = 4.0 * bytes / (bw * 1e3);
    assert!(r.makespan_us > intra_us, "makespan {}", r.makespan_us);
    let inter_bytes_worst = (1.0 + jitter) * bytes * (pods - 1) as f64 * 4.0;
    assert!(
        r.makespan_us < intra_us + inter_bytes_worst / (bw * 1e3) * 100.0,
        "makespan {} suspiciously large",
        r.makespan_us
    );
}

/// ISSUE 3 acceptance: the HRS-routed SuperPod all-to-all at 32 768
/// NPUs (32 pods × 4×4 racks × 64 NPUs over 256 HRS), lazy stages.
///
/// The jittered 1:1 run staggers both gate opens and completions, so
/// every add lands in a live contention component; the fall-only
/// bounded add must do ≥3× less work per stage-gate add than a
/// full-component re-solve would (the union-find live estimate —
/// *exactly* equal to the measured PR 2 full-component add work on this
/// workload shape, see `benches/perf_hotpaths.rs` which executes both
/// at mid-scale and asserts so). The oversubscription pair then runs
/// uniform (batched) payloads — cheap at full scale — and pins the
/// 4:1 > 1:1 inter-pod phase ordering.
///
/// The ≥3× bar is asserted on the 1:1 workload deliberately: at 4:1
/// the saturated uplinks chain nearly the whole component into every
/// add's absorption set (measured ~1.2–1.7× on the reference port), so
/// oversubscribed fabrics fall back toward full-component cost — the
/// bounded add buys the most exactly where the fabric is provisioned
/// sanely.
#[test]
fn superpod_hrs_32k_bounded_adds_and_oversubscription() {
    let mut cfg = SuperPodConfig::default();
    cfg.pods = 32;
    let (t, h) = ubmesh_superpod(&cfg);
    assert_eq!(h.npus().len(), 32768);
    assert_eq!(h.hrs.len(), 256);

    let bytes = 1e6;
    let peer_pods = 3;
    let dag = superpod_hrs_alltoall_dag(&t, &h, bytes, 1.0, peer_pods);
    assert_eq!(dag.stages.len(), 3);
    assert!(dag.stages.iter().all(|s| s.is_lazy()), "stages must be lazy");
    assert_eq!(dag.stages[0].flow_count(), 32768 * 7);
    assert_eq!(dag.stages[1].flow_count(), 32768 * 7);
    let inter_flows = 32768 * peer_pods * 2; // 196 608 six-hop flows
    assert_eq!(dag.stages[2].flow_count(), inter_flows);

    let net = SimNet::new(&t);
    let r = sim::schedule::run(&net, &dag); // default = Bounded

    // Byte-hop conservation against the independently materialized
    // schedule (jittered payloads, 1-hop intra + 6-hop inter flows).
    let expect: f64 = dag
        .stages
        .iter()
        .map(|s| {
            s.materialize_flows(&t)
                .iter()
                .map(|f| f.bytes * f.channels.len() as f64)
                .sum::<f64>()
        })
        .sum();
    assert!(
        (r.byte_hops - expect).abs() / expect < 1e-6,
        "byte-hops {} vs {expect}",
        r.byte_hops
    );

    // Gate staggering really spread the adds into separate events.
    let s = &r.solver;
    assert!(
        s.add_resolves > 10_000,
        "expected staggered gate opens, got {} add resolves",
        s.add_resolves
    );

    // Acceptance: ≥3× fewer rate recomputations per stage-gate add than
    // the full-component add path on the same event sequence.
    let ratio = s.add_full_component_recomputes as f64 / s.add_rate_recomputes as f64;
    assert!(
        ratio >= 3.0,
        "fall-only add must be ≥3x narrower: {} full-component vs {} actual ({ratio:.2}x)",
        s.add_full_component_recomputes,
        s.add_rate_recomputes
    );

    // Oversubscription sanity at full scale: uniform payloads (no
    // jitter → batched gates/completions, so both runs stay cheap);
    // 4:1 rack uplinks must strictly lengthen the inter-pod phase.
    let interpod_us = |cfg: &SuperPodConfig| {
        let (t, h) = ubmesh_superpod(cfg);
        let dag = superpod_hrs_alltoall_dag(&t, &h, bytes, 0.0, 1);
        let net = SimNet::new(&t);
        let r = sim::schedule::run(&net, &dag);
        r.makespan_us - r.stage_done_us[1]
    };
    let base = interpod_us(&cfg);
    let mut over = cfg.clone();
    over.uplink_oversub = 4;
    let slowed = interpod_us(&over);
    assert!(
        slowed > base * 1.5,
        "4:1 oversubscription must lengthen the inter-pod phase: {slowed} vs {base} µs"
    );
}

/// PR 4 acceptance: the 32 768-NPU degraded run. An uplink-LRS → HRS
/// link dies mid-inter-pod-phase; with online recovery the affected
/// flows re-select a surviving uplink plane (`hrs_reroute`, the
/// `hrs_plane_pair` rotation) after the direct-notification convergence
/// latency, and the run **completes** with a makespan strictly between
/// the healthy run and the naive stall-until-restore bound. Uniform
/// payloads keep the three full-scale runs batched and affordable.
#[test]
fn superpod_hrs_32k_degraded_run_completes_via_apr_reroute() {
    let mut cfg = SuperPodConfig::default();
    cfg.pods = 32;
    let (t, h) = ubmesh_superpod(&cfg);
    assert_eq!(h.npus().len(), 32768);

    let dag = superpod_hrs_alltoall_dag(&t, &h, 1e6, 0.0, 1);
    let net = SimNet::new(&t);
    let healthy = sim::schedule::run(&net, &dag);
    assert!(!healthy.is_stalled());

    // The failure site: the uplink-LRS → HRS hop of a live inter-pod
    // flow, cut halfway through the inter-pod phase.
    let inter = dag.stages[2].materialize_flows(&t);
    let failed = inter[0].channels[2].link;
    let t_fail = (healthy.stage_done_us[1] + healthy.makespan_us) / 2.0;
    let t_restore = healthy.makespan_us * 3.0;
    let faults = FaultPlan::new()
        .at(t_fail, FaultEvent::LinkDown(failed))
        .at(t_restore, FaultEvent::LinkUp(failed));

    // Naive bound: no recovery — the cut flows wait for the restore.
    let stall = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &faults);
    assert!(!stall.is_stalled(), "the restore must revive the cut flows");
    assert!(stall.makespan_us > t_restore, "{}", stall.makespan_us);

    // Degraded run: APR reroute onto surviving planes.
    let plan = faults
        .clone()
        .with_recovery(RecoveryConfig::direct().with_reroute(hrs_reroute(&h)));
    let rec = sim::schedule::run_faulted(&net, &dag, &SimConfig::default(), &plan);
    assert!(!rec.is_stalled(), "degraded run must complete: {:?}", rec.stalled.len());
    assert!(rec.reroutes >= 1, "{} reroutes", rec.reroutes);
    assert!(rec.fault_events >= 1);
    assert!(
        rec.makespan_us > healthy.makespan_us,
        "degraded {} vs healthy {}",
        rec.makespan_us,
        healthy.makespan_us
    );
    assert!(
        rec.makespan_us < stall.makespan_us,
        "degraded {} must beat the stall bound {}",
        rec.makespan_us,
        stall.makespan_us
    );
    // The capacity-change path did bounded work, not full components.
    let s = &rec.solver;
    assert!(s.cap_resolves >= 1);
    assert!(
        s.cap_rate_recomputes <= s.rate_recomputes,
        "cap slice within aggregate"
    );
}
