//! Cross-module property tests (see DESIGN.md §6): randomized invariants
//! over topology construction, routing, flow simulation and cost models.

use ubmesh::routing::apr::{paths_2d, to_routed};
use ubmesh::routing::tfc::verify_deadlock_free;
use ubmesh::sim::fair::{max_min_rates, Rates};
use ubmesh::sim::{self, FlowSpec, SimNet, Stage, StageDag};
use ubmesh::topology::ndmesh::{expected_links, nd_fullmesh, DimSpec};
use ubmesh::topology::{CableClass, Channel, NodeId};
use ubmesh::util::prop::forall;
use ubmesh::util::rng::Rng;

fn random_mesh(rng: &mut Rng) -> (ubmesh::topology::Topology, usize, usize) {
    let n0 = rng.range(2, 9);
    let n1 = rng.range(2, 9);
    let t = nd_fullmesh(
        "rand",
        &[
            DimSpec::new(n0, rng.range(1, 8) as u32, CableClass::PassiveElectrical, 0.3),
            DimSpec::new(n1, rng.range(1, 8) as u32, CableClass::PassiveElectrical, 1.0),
        ],
    );
    (t, n0, n1)
}

#[test]
fn ndmesh_structure_invariants() {
    forall("nd-fullmesh structure", 64, |rng| {
        let dims: Vec<usize> = (0..rng.range(1, 4)).map(|_| rng.range(2, 6)).collect();
        let specs: Vec<DimSpec> = dims
            .iter()
            .map(|&d| DimSpec::new(d, 2, CableClass::PassiveElectrical, 1.0))
            .collect();
        let t = nd_fullmesh("p", &specs);
        assert_eq!(t.link_count(), expected_links(&dims));
        assert!(t.npus_connected());
        // diameter = number of dims (one hop per dimension)
        assert_eq!(t.npu_diameter() as usize, dims.len());
        // handshake lemma
        let degsum: usize = (0..t.node_count())
            .map(|i| t.neighbors(NodeId(i as u32)).len())
            .sum();
        assert_eq!(degsum, 2 * t.link_count());
    });
}

#[test]
fn apr_path_sets_always_deadlock_free() {
    forall("APR + TFC on random meshes", 24, |rng| {
        let (t, n0, n1) = random_mesh(rng);
        let node = |x: usize, y: usize| NodeId((y * n0 + x) as u32);
        let mut paths = Vec::new();
        for _ in 0..rng.range(5, 60) {
            let s = (rng.range(0, n0), rng.range(0, n1));
            let d = (rng.range(0, n0), rng.range(0, n1));
            if s == d {
                continue;
            }
            for mp in paths_2d(s, d, n0, n1, true) {
                if rng.chance(0.4) {
                    paths.push(to_routed(&mp, node));
                }
            }
        }
        if !paths.is_empty() {
            verify_deadlock_free(&t, &paths).unwrap();
        }
    });
}

#[test]
fn max_min_never_oversubscribes_and_is_work_conserving() {
    forall("max-min feasibility", 48, |rng| {
        let (t, _, _) = random_mesh(rng);
        let net = SimNet::new(&t);
        let nflows = rng.range(1, 40);
        let flows: Vec<Vec<Channel>> = (0..nflows)
            .map(|_| {
                (0..rng.range(1, 4))
                    .map(|_| Channel {
                        link: ubmesh::topology::LinkId(rng.range(0, t.link_count()) as u32),
                        rev: rng.chance(0.5),
                    })
                    .collect()
            })
            .collect();
        let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
        let rates = max_min_rates(&net, &refs);
        let mut load = vec![0.0f64; net.channel_count()];
        for (i, f) in flows.iter().enumerate() {
            assert!(rates[i] > 0.0, "work conservation");
            for c in f {
                load[c.idx()] += rates[i];
            }
        }
        for (ci, &l) in load.iter().enumerate() {
            assert!(l <= net.cap_by_idx(ci) * (1.0 + 1e-6) + 1e-9);
        }
    });
}

/// Random nd-fullmesh up to 4D (sizes 2–4 per dim) for the incremental
/// solver invariants.
fn random_nd_mesh(rng: &mut Rng) -> ubmesh::topology::Topology {
    let ndims = rng.range(1, 5);
    let specs: Vec<DimSpec> = (0..ndims)
        .map(|_| {
            DimSpec::new(
                rng.range(2, 5),
                rng.range(1, 8) as u32,
                CableClass::PassiveElectrical,
                0.5,
            )
        })
        .collect();
    nd_fullmesh("nd", &specs)
}

fn random_channel_flows(
    rng: &mut Rng,
    t: &ubmesh::topology::Topology,
    n: usize,
) -> Vec<Vec<Channel>> {
    (0..n)
        .map(|_| {
            (0..rng.range(1, 5))
                .map(|_| Channel {
                    link: ubmesh::topology::LinkId(rng.range(0, t.link_count()) as u32),
                    rev: rng.chance(0.5),
                })
                .collect()
        })
        .collect()
}

#[test]
fn incremental_solver_respects_capacity_and_conserves_work() {
    // Invariants 2 & 3 of sim::fair::Rates, checked *through* the
    // incremental entry points (add, then staged removals): per-channel
    // load ≤ capacity and strictly positive rates on live paths.
    forall("incremental feasibility on nD meshes", 48, |rng| {
        let t = random_nd_mesh(rng);
        let net = SimNet::new(&t);
        let flows = random_channel_flows(rng, &t, rng.range(2, 32));
        let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &refs);
        let mut alive: Vec<usize> = (0..flows.len()).collect();
        loop {
            // Feasibility of the current allocation.
            let mut load = vec![0.0f64; net.channel_count()];
            for &k in &alive {
                let rate = r.rate(ids[k]);
                assert!(rate > 0.0, "work conservation (flow {k})");
                for c in &flows[k] {
                    load[c.idx()] += rate;
                }
            }
            for (ci, &l) in load.iter().enumerate() {
                assert!(
                    l <= net.cap_by_idx(ci) * (1.0 + 1e-6) + 1e-9,
                    "ch {ci} over capacity: {l}"
                );
            }
            if alive.len() <= 1 {
                break;
            }
            // Remove a random non-empty batch and re-check.
            let nrem = rng.range(1, alive.len());
            let mut batch = Vec::new();
            for _ in 0..nrem {
                let k = alive.swap_remove(rng.range(0, alive.len()));
                batch.push(ids[k]);
            }
            r.remove_flows(&net, &batch);
        }
    });
}

#[test]
fn incremental_solver_is_order_invariant() {
    // Invariant 1 of sim::fair::Rates: any add/remove sequence reaching
    // the same surviving flow set yields the same rates as a single
    // from-scratch solve — on nd-fullmesh topologies up to 4D.
    forall("add/remove order invariance", 48, |rng| {
        let t = random_nd_mesh(rng);
        let net = SimNet::new(&t);
        let flows = random_channel_flows(rng, &t, rng.range(3, 24));
        let n = flows.len();
        // Choose the survivor set up front.
        let survive: Vec<bool> = (0..n).map(|_| rng.chance(0.6)).collect();
        if !survive.iter().any(|&s| s) {
            return;
        }

        // Sequence A: add all in one batch, remove the victims in
        // random batches.
        let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
        let mut ra = Rates::new();
        let ids_a = ra.add_flows(&net, &refs);
        let mut victims: Vec<usize> = (0..n).filter(|&k| !survive[k]).collect();
        rng.shuffle(&mut victims);
        let mut i = 0;
        while i < victims.len() {
            let take = rng.range(1, victims.len() - i + 1);
            let batch: Vec<_> = victims[i..i + take].iter().map(|&k| ids_a[k]).collect();
            ra.remove_flows(&net, &batch);
            i += take;
        }

        // Sequence B: add one by one in a shuffled order, interleaving
        // removals of the victims as soon as they are in.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut rb = Rates::new();
        let mut ids_b = vec![usize::MAX; n];
        for &k in &order {
            ids_b[k] = rb.add_flows(&net, &[flows[k].as_slice()])[0];
            if !survive[k] && rng.chance(0.5) {
                rb.remove_flows(&net, &[ids_b[k]]);
                ids_b[k] = usize::MAX;
            }
        }
        let stragglers: Vec<usize> = (0..n)
            .filter(|&k| !survive[k] && ids_b[k] != usize::MAX)
            .map(|k| ids_b[k])
            .collect();
        if !stragglers.is_empty() {
            rb.remove_flows(&net, &stragglers);
        }

        // Both must equal the from-scratch allocation of the survivors.
        let surv_refs: Vec<&[Channel]> = (0..n)
            .filter(|&k| survive[k])
            .map(|k| flows[k].as_slice())
            .collect();
        let fresh = max_min_rates(&net, &surv_refs);
        for (j, k) in (0..n).filter(|&k| survive[k]).enumerate() {
            let fa = ra.rate(ids_a[k]);
            let fb = rb.rate(ids_b[k]);
            assert!(
                (fa - fresh[j]).abs() <= 1e-6 * fresh[j].max(1.0),
                "seq A flow {k}: {fa} vs fresh {}",
                fresh[j]
            );
            assert!(
                (fb - fresh[j]).abs() <= 1e-6 * fresh[j].max(1.0),
                "seq B flow {k}: {fb} vs fresh {}",
                fresh[j]
            );
        }
    });
}

#[test]
fn des_makespan_monotone_in_bytes_and_bandwidth() {
    forall("DES monotonicity", 24, |rng| {
        let (t, n0, n1) = random_mesh(rng);
        let node = |x: usize, y: usize| NodeId((y * n0 + x) as u32);
        let src = node(0, 0);
        let dst = node(n0 - 1, n1 - 1);
        let path = t.shortest_path(src, dst, true).unwrap();
        let bytes = 1e6 + rng.f64() * 1e8;
        let run = |b: f64| {
            let net = SimNet::new(&t);
            let mut dag = StageDag::default();
            dag.push(Stage::new("x").with_flows(vec![FlowSpec::along(&t, &path, b)]));
            sim::schedule::run(&net, &dag).makespan_us
        };
        assert!(run(2.0 * bytes) > run(bytes));
    });
}

#[test]
fn des_conserves_byte_hops() {
    forall("byte-hop conservation", 16, |rng| {
        let (t, n0, n1) = random_mesh(rng);
        let node = |x: usize, y: usize| NodeId((y * n0 + x) as u32);
        let mut dag = StageDag::default();
        let mut expect = 0.0;
        let mut flows = Vec::new();
        for _ in 0..rng.range(1, 10) {
            let s = (rng.range(0, n0), rng.range(0, n1));
            let d = (rng.range(0, n0), rng.range(0, n1));
            if s == d {
                continue;
            }
            let path = t
                .shortest_path(node(s.0, s.1), node(d.0, d.1), true)
                .unwrap();
            let bytes = 1e6 * (1.0 + rng.f64() * 9.0);
            expect += bytes * (path.len() - 1) as f64;
            flows.push(FlowSpec::along(&t, &path, bytes));
        }
        if flows.is_empty() {
            return;
        }
        dag.push(Stage::new("x").with_flows(flows));
        let net = SimNet::new(&t);
        let r = sim::schedule::run(&net, &dag);
        assert!(
            (r.byte_hops - expect).abs() / expect < 1e-6,
            "byte-hops {} vs {}",
            r.byte_hops,
            expect
        );
    });
}

#[test]
fn lazy_and_eager_stage_materialization_agree() {
    // Lazy builders are deterministic and the runner consumes flows in
    // the same order either way, so the reports must be *identical* —
    // not merely close — across the lazy DAG producers.
    use ubmesh::collectives::alltoall::{
        dimwise_alltoall_dag, multipath_alltoall_dag, superpod_alltoall_dag, Grid,
    };
    use ubmesh::collectives::ring::ring_allreduce_dag;
    forall("lazy == eager stage materialization", 12, |rng| {
        let d0 = rng.range(2, 5);
        let d1 = rng.range(2, 4);
        let pods = rng.range(2, 4);
        let t = nd_fullmesh(
            "lz",
            &[
                DimSpec::new(d0, 2, CableClass::PassiveElectrical, 0.5),
                DimSpec::new(d1, 2, CableClass::PassiveElectrical, 1.0),
                DimSpec::new(pods, 2, CableClass::Optical, 20.0),
            ],
        );
        let bytes = 1e6 * (1.0 + rng.f64() * 7.0);
        let dags = [
            dimwise_alltoall_dag(&t, &[d0, d1, pods], bytes),
            superpod_alltoall_dag(&t, &[d0, d1], pods, bytes, rng.f64()),
            ring_allreduce_dag(
                &t,
                &(0..d0).map(|i| NodeId(i as u32)).collect::<Vec<_>>(),
                bytes,
            ),
        ];
        let net = SimNet::new(&t);
        // The 2D grid producers need a genuine 2D mesh (grid rows and
        // columns must be directly linked).
        let t2 = nd_fullmesh(
            "lz2",
            &[
                DimSpec::new(d0, 2, CableClass::PassiveElectrical, 0.5),
                DimSpec::new(d1, 2, CableClass::PassiveElectrical, 1.0),
            ],
        );
        let g_nodes = t2.npus.clone();
        let dag2 = multipath_alltoall_dag(&t2, &Grid::new(&g_nodes, d0, d1), bytes / 10.0);
        let net2 = SimNet::new(&t2);
        let l2 = sim::schedule::run(&net2, &dag2);
        let e2 = sim::schedule::run(&net2, &dag2.materialized(&t2));
        assert_eq!(l2.makespan_us, e2.makespan_us);
        assert_eq!(l2.byte_hops, e2.byte_hops);
        for dag in &dags {
            assert!(dag.stages.iter().any(|s| s.is_lazy()));
            let lazy = sim::schedule::run(&net, dag);
            let eager = sim::schedule::run(&net, &dag.materialized(&t));
            assert_eq!(lazy.makespan_us, eager.makespan_us);
            assert_eq!(lazy.byte_hops, eager.byte_hops);
            assert_eq!(lazy.events, eager.events);
            assert_eq!(lazy.peak_flows, eager.peak_flows);
            assert_eq!(lazy.stage_done_us, eager.stage_done_us);
            // Declared lazy metadata matches what materialization built.
            let total: f64 = dag
                .stages
                .iter()
                .map(|s| {
                    s.materialize_flows(&t).iter().map(|f| f.bytes).sum::<f64>()
                })
                .sum();
            assert!(
                (dag.total_bytes() - total).abs() <= 1e-6 * total.max(1.0),
                "declared {} vs built {total}",
                dag.total_bytes()
            );
        }
    });
}

#[test]
fn hrs_superpod_lazy_and_eager_agree() {
    // The HRS-routed SuperPod producer draws plane/HRS selections, the
    // payload jitter AND the gate stagger from deterministic SplitMix64
    // streams, so a lazily materialized run must be *identical* — not
    // merely close — to the eagerly materialized copy, across
    // oversubscription ratios and jitter settings.
    use ubmesh::collectives::alltoall::superpod_hrs_alltoall_dag;
    use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
    forall("hrs lazy == eager", 4, |rng| {
        let mut cfg = SuperPodConfig::default();
        cfg.pods = 2;
        cfg.pod.rows = 2;
        cfg.pod.cols = 2;
        cfg.uplink_oversub = [1, 2, 4][rng.range(0, 3)];
        let (t, h) = ubmesh_superpod(&cfg);
        let bytes = 1e6 * (1.0 + rng.f64() * 7.0);
        let jitter = rng.f64();
        let dag = superpod_hrs_alltoall_dag(&t, &h, bytes, jitter, 1);
        assert!(dag.stages.iter().all(|s| s.is_lazy()));
        let net = SimNet::new(&t);
        let lazy = sim::schedule::run(&net, &dag);
        let eager = sim::schedule::run(&net, &dag.materialized(&t));
        assert_eq!(lazy.makespan_us, eager.makespan_us);
        assert_eq!(lazy.byte_hops, eager.byte_hops);
        assert_eq!(lazy.events, eager.events);
        assert_eq!(lazy.peak_flows, eager.peak_flows);
        assert_eq!(lazy.stage_done_us, eager.stage_done_us);
        // Declared lazy metadata matches what materialization built.
        let total: f64 = dag
            .stages
            .iter()
            .map(|s| s.materialize_flows(&t).iter().map(|f| f.bytes).sum::<f64>())
            .sum();
        assert!(
            (dag.total_bytes() - total).abs() <= 1e-6 * total.max(1.0),
            "declared {} vs built {total}",
            dag.total_bytes()
        );
    });
}

#[test]
fn iteration_dag_lazy_and_eager_agree() {
    // The full training iteration is built from lazy stages whose
    // builders draw every path/plane selection from deterministic
    // rotations, so a lazily materialized run must be *identical* to
    // the eagerly materialized copy — across models, parallelisms and
    // rank orders.
    use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
    use ubmesh::workload::models::by_name;
    use ubmesh::workload::step::{iteration_dag, IterationSpec, RankOrder};
    use ubmesh::workload::{ClusterMap, ParallelismConfig};
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let map = ClusterMap::rack(&h);
    let net = SimNet::new(&t);
    forall("iteration_dag lazy == eager", 6, |rng| {
        let m = by_name(["llama-70b", "gpt4-2t"][rng.range(0, 2)]).unwrap();
        let (sp, pp, dp) = [(2, 2, 2), (4, 2, 1), (2, 4, 1), (8, 1, 1)][rng.range(0, 4)];
        let p = ParallelismConfig {
            tp: 8,
            sp,
            ep: if m.is_moe() && sp * dp >= 2 { sp * dp } else { 1 },
            pp,
            dp,
            microbatches: rng.range(1, 4),
            tokens_per_microbatch: 1024.0 * (1 + rng.range(0, 4)) as f64,
        };
        let order = if rng.chance(0.5) {
            RankOrder::TopologyAware
        } else {
            RankOrder::Naive
        };
        let dag = iteration_dag(&t, &map, &m, &p, order, &IterationSpec::default());
        assert!(dag.stages.iter().any(|s| s.is_lazy()));
        let lazy = sim::schedule::run(&net, &dag);
        let eager = sim::schedule::run(&net, &dag.materialized(&t));
        assert_eq!(lazy.makespan_us, eager.makespan_us);
        assert_eq!(lazy.byte_hops, eager.byte_hops);
        assert_eq!(lazy.events, eager.events);
        assert_eq!(lazy.peak_flows, eager.peak_flows);
        assert_eq!(lazy.stage_done_us, eager.stage_done_us);
    });
}

#[test]
fn topology_aware_placement_beats_naive_measured() {
    // §5.2's placement claim as a *measured* quantity: the same
    // iteration mapped TP-innermost (boards) must finish no later than
    // the PP-innermost naive order, whose TP groups smear across the
    // rack (mirror-measured gap: naive/aware ≈ 1.043 — compute
    // dominates, every extra comm µs is pure serial addition).
    use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
    use ubmesh::workload::models::by_name;
    use ubmesh::workload::step::{iteration_dag, IterationSpec, RankOrder};
    use ubmesh::workload::{ClusterMap, ParallelismConfig};
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let map = ClusterMap::rack(&h);
    let net = SimNet::new(&t);
    let m = by_name("gpt4-2t").unwrap();
    let p = ParallelismConfig {
        tp: 8,
        sp: 2,
        ep: 4,
        pp: 2,
        dp: 2,
        microbatches: 2,
        tokens_per_microbatch: 4096.0,
    };
    let run = |order: RankOrder| {
        let dag = iteration_dag(&t, &map, &m, &p, order, &IterationSpec::default());
        let r = sim::schedule::run(&net, &dag);
        assert!(!r.is_stalled());
        r.makespan_us
    };
    let aware = run(RankOrder::TopologyAware);
    let naive = run(RankOrder::Naive);
    assert!(
        naive > aware * 1.01,
        "naive placement {naive:.0} must measurably exceed topology-aware {aware:.0}"
    );
}

#[test]
fn cost_models_are_scale_homogeneous() {
    // Doubling every price doubles CapEx but leaves ratios unchanged —
    // guards the Fig 21 ratios against price-book drift.
    use ubmesh::cost::capex::{capex_full_clos, capex_ubmesh};
    use ubmesh::topology::superpod::SuperPodConfig;
    let mut cfg = SuperPodConfig::default();
    cfg.pods = 2;
    cfg.pod.rows = 2;
    cfg.pod.cols = 2;
    let ub = capex_ubmesh(&cfg);
    let clos = capex_full_clos("c", cfg.npus(), 64);
    let r1 = clos.total() / ub.total();
    assert!(r1 > 1.0, "Clos must cost more ({r1})");
    // network share bounded
    assert!(ub.network_share() < clos.network_share());
}

#[test]
fn traffic_analysis_totals_are_consistent() {
    use ubmesh::workload::models;
    use ubmesh::workload::traffic::{analyze, ParallelismConfig};
    forall("traffic consistency", 48, |rng| {
        let m = models::by_name(models::MODELS[rng.range(0, 5)]).unwrap();
        let p = ParallelismConfig {
            tp: 1 << rng.range(0, 4),
            sp: 1 << rng.range(0, 4),
            ep: if m.is_moe() { 1 << rng.range(1, 5) } else { 1 },
            pp: 1 << rng.range(0, 4),
            dp: 1 << rng.range(0, 4),
            microbatches: rng.range(1, 32),
            tokens_per_microbatch: 4096.0 * (1 + rng.range(0, 8)) as f64,
        };
        let t = analyze(&m, &p);
        let sum: f64 = t.rows.iter().map(|r| r.total).sum();
        assert!((sum - t.total()).abs() < 1.0);
        for r in &t.rows {
            assert!(r.total >= 0.0 && r.volume_per_transfer >= 0.0);
            assert!(
                (r.total - r.volume_per_transfer * r.transfers).abs()
                    <= 1e-6 * r.total.max(1.0) + 1.0
                    || r.technique == "SP", // SP adds the RS term
                "{:?}",
                r
            );
        }
    });
}

/// PR 10: advancing channel-disjoint components on worker threads is
/// **bit-identical** to the single-worker loop — every [`SimReport`]
/// field (makespan, stage completions, byte-hops, event and peak-flow
/// counts, solver counters) matches exactly across worker counts and
/// under every solver strategy, and each component's report equals what
/// a standalone `run_with` of that DAG produces. Worker threads decide
/// only *where* a component runs, never *what* it computes.
#[test]
fn component_parallel_is_bit_identical_to_serial() {
    use ubmesh::collectives::alltoall::row_alltoall_dags;
    use ubmesh::sim::fair::ResolveStrategy;
    use ubmesh::sim::{run_components, run_with, ParallelConfig, SimConfig};
    const STRATEGIES: [ResolveStrategy; 3] = [
        ResolveStrategy::Bounded,
        ResolveStrategy::RiseOnly,
        ResolveStrategy::FullComponentBfs,
    ];
    forall("parallel == serial component advancement", 6, |rng| {
        let (t, n0, n1) = random_mesh(rng);
        let net = SimNet::new(&t);
        let bytes = 1e6 * (1.0 + rng.f64() * 4.0);
        let rounds = 1 + rng.range(0, 2);
        let dims = [n0, n1];
        let dags = row_alltoall_dags(&t, &dims, bytes, rounds);
        assert_eq!(dags.len(), n1, "one component per row");
        for &strategy in &STRATEGIES {
            let serial = run_components(
                &net,
                &dags,
                &ParallelConfig::serial().with_strategy(strategy),
            );
            // Ground truth: each component standalone.
            for (dag, r) in dags.iter().zip(&serial) {
                let solo = run_with(&net, dag, &SimConfig { strategy });
                assert_eq!(r.makespan_us.to_bits(), solo.makespan_us.to_bits());
                assert_eq!(r.byte_hops.to_bits(), solo.byte_hops.to_bits());
                assert_eq!(r.events, solo.events);
            }
            for workers in [2usize, 8] {
                let par = run_components(
                    &net,
                    &dags,
                    &ParallelConfig::serial()
                        .with_workers(workers)
                        .with_strategy(strategy),
                );
                assert_eq!(par.len(), serial.len());
                for (a, b) in par.iter().zip(&serial) {
                    assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
                    assert_eq!(a.byte_hops.to_bits(), b.byte_hops.to_bits());
                    assert_eq!(a.events, b.events);
                    assert_eq!(a.peak_flows, b.peak_flows);
                    assert_eq!(a.reroutes, b.reroutes);
                    assert_eq!(a.stalled.len(), b.stalled.len());
                    assert_eq!(
                        a.stage_done_us.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                        b.stage_done_us.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                    );
                    assert_eq!(a.solver.resolves, b.solver.resolves);
                    assert_eq!(a.solver.rate_recomputes, b.solver.rate_recomputes);
                    assert_eq!(a.solver.fallbacks, b.solver.fallbacks);
                }
            }
        }
    });
}
