//! PR 10 differentials for `workload::symmetric`: the DP-replica
//! translation-symmetry fast path against the full coupled solve.
//!
//! Fixture: a 4-pod SuperPod (2×2-rack pods, 1024 NPUs) running the
//! gpt4-2t MoE iteration at TP8·SP8·EP16·PP2·DP8 — EP blocks span two
//! DP replicas, so a unit is exactly one pod (unit_dp = 2, 4 units),
//! and the DP tail couples all four pods through the HRS tier.
//!
//! Pinned properties:
//! * the unit DAGs are **channel-disjoint** (no two units route a flow
//!   over the same link) — the precondition of the parallel loop;
//! * the units are **translations**: every unit's standalone report is
//!   bit-identical to unit 0's;
//! * `replica_cache` on == off, bitwise, at every worker count — the
//!   representative solve loses nothing;
//! * the factored run reproduces the full `iteration_dag` solve's
//!   makespan and byte-hops (tolerance-level: the factoring is exact in
//!   exact arithmetic; only f64 association order differs);
//! * misuse demotes instead of mis-solving: naive rank order and
//!   non-mesh fabrics are rejected up front.

use std::collections::BTreeSet;

use ubmesh::sim::{self, run_components, ParallelConfig, SimNet};
use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
use ubmesh::workload::models::by_name;
use ubmesh::workload::step::{iteration_dag, IterationSpec, RankOrder};
use ubmesh::workload::symmetric::{run_symmetric, symmetric_iteration, SymmetricConfig};
use ubmesh::workload::{ClusterMap, ParallelismConfig};

fn fixture() -> (ubmesh::topology::Topology, ClusterMap, ParallelismConfig) {
    let mut cfg = SuperPodConfig::default();
    cfg.pods = 4;
    cfg.pod.rows = 2;
    cfg.pod.cols = 2;
    let (t, h) = ubmesh_superpod(&cfg);
    let map = ClusterMap::superpod(&h);
    let p = ParallelismConfig {
        tp: 8,
        sp: 8,
        ep: 16,
        pp: 2,
        dp: 8,
        microbatches: 2,
        tokens_per_microbatch: 2048.0,
    };
    assert_eq!(p.npus(), map.npu_count());
    (t, map, p)
}

#[test]
fn units_are_channel_disjoint_and_translated() {
    let (t, map, p) = fixture();
    let m = by_name("gpt4-2t").unwrap();
    let spec = IterationSpec::default();
    let sym =
        symmetric_iteration(&t, &map, &m, &p, RankOrder::TopologyAware, &spec).unwrap();
    assert_eq!(sym.unit_dp, 2, "EP16 over SP8 spans two replicas");
    assert_eq!(sym.units, 4, "one unit per pod");
    assert!(sym.tail.is_some(), "gpt4-2t exposes DP traffic");

    // Channel-disjointness: the union of each unit's materialized flow
    // links must not intersect any other unit's.
    let link_sets: Vec<BTreeSet<u32>> = sym
        .unit_dags
        .iter()
        .map(|dag| {
            let mut s = BTreeSet::new();
            for stage in &dag.stages {
                for f in stage.materialize_flows(&t) {
                    for c in &f.channels {
                        s.insert(c.link.0);
                    }
                }
            }
            assert!(!s.is_empty(), "a unit must carry traffic");
            s
        })
        .collect();
    for i in 0..link_sets.len() {
        for j in i + 1..link_sets.len() {
            assert!(
                link_sets[i].is_disjoint(&link_sets[j]),
                "units {i} and {j} share links: {:?}",
                link_sets[i].intersection(&link_sets[j]).take(5).collect::<Vec<_>>()
            );
        }
    }

    // Translation symmetry: every unit's standalone run is bit-identical
    // to unit 0's — the fact the replica cache banks on.
    let net = SimNet::new(&t);
    let reports = run_components(&net, &sym.unit_dags, &ParallelConfig::serial());
    let r0 = &reports[0];
    assert!(!r0.is_stalled());
    for (u, r) in reports.iter().enumerate().skip(1) {
        assert_eq!(
            r.makespan_us.to_bits(),
            r0.makespan_us.to_bits(),
            "unit {u} makespan diverged from the representative"
        );
        assert_eq!(r.byte_hops.to_bits(), r0.byte_hops.to_bits(), "unit {u}");
        assert_eq!(r.events, r0.events, "unit {u}");
        assert_eq!(r.peak_flows, r0.peak_flows, "unit {u}");
        assert_eq!(
            r.stage_done_us.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            r0.stage_done_us.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            "unit {u} stage completions"
        );
        assert_eq!(r.solver.resolves, r0.solver.resolves, "unit {u}");
        assert_eq!(r.solver.rate_recomputes, r0.solver.rate_recomputes, "unit {u}");
    }
}

#[test]
fn replica_cache_matches_full_solve_bitwise_and_full_dag_numerically() {
    let (t, map, p) = fixture();
    let m = by_name("gpt4-2t").unwrap();
    let spec = IterationSpec::default();
    let sym =
        symmetric_iteration(&t, &map, &m, &p, RankOrder::TopologyAware, &spec).unwrap();
    let net = SimNet::new(&t);

    let base = SymmetricConfig {
        workers: 1,
        replica_cache: false,
        strategy: Default::default(),
    };
    let full = run_symmetric(&net, &sym, &base);
    assert!(!full.report.is_stalled());
    assert_eq!(full.cached_units, 0);
    assert_eq!(full.unit_walls_s.len(), sym.units);

    for workers in [1usize, 2, 8] {
        for replica_cache in [false, true] {
            let r = run_symmetric(
                &net,
                &sym,
                &SymmetricConfig {
                    workers,
                    replica_cache,
                    strategy: Default::default(),
                },
            );
            assert_eq!(
                r.report.makespan_us.to_bits(),
                full.report.makespan_us.to_bits(),
                "workers={workers} cache={replica_cache}"
            );
            assert_eq!(
                r.report.byte_hops.to_bits(),
                full.report.byte_hops.to_bits(),
                "workers={workers} cache={replica_cache}"
            );
            assert_eq!(r.report.events, full.report.events);
            assert_eq!(r.report.peak_flows, full.report.peak_flows);
            assert_eq!(
                r.report.stage_done_us.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                full.report.stage_done_us.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            );
            assert_eq!(r.report.solver.resolves, full.report.solver.resolves);
            assert_eq!(
                r.report.solver.rate_recomputes,
                full.report.solver.rate_recomputes
            );
            assert_eq!(r.report.solver.fallbacks, full.report.solver.fallbacks);
            if replica_cache {
                assert_eq!(r.cached_units, sym.units - 1);
                assert_eq!(r.unit_walls_s.len(), 1);
            }
        }
    }

    // Against the one big coupled DAG: the factoring is exact in exact
    // arithmetic (every unit stage is an ancestor of the tail; units
    // share no channels), so only f64 association order separates the
    // two paths.
    let whole = iteration_dag(&t, &map, &m, &p, RankOrder::TopologyAware, &spec);
    let rw = sim::schedule::run(&net, &whole);
    assert!(!rw.is_stalled());
    let rel = (full.report.makespan_us - rw.makespan_us).abs() / rw.makespan_us;
    assert!(
        rel < 1e-9,
        "factored {:.6} vs full {:.6} (rel {rel:.3e})",
        full.report.makespan_us,
        rw.makespan_us
    );
    let relb = (full.report.byte_hops - rw.byte_hops).abs() / rw.byte_hops;
    assert!(relb < 1e-9, "byte-hops rel {relb:.3e}");
}

#[test]
fn misaligned_workloads_are_demoted_not_mis_solved() {
    let (t, map, p) = fixture();
    let m = by_name("gpt4-2t").unwrap();
    let spec = IterationSpec::default();
    // Naive rank order smears replicas across pods: rejected.
    assert!(symmetric_iteration(&t, &map, &m, &p, RankOrder::Naive, &spec).is_err());
    // dp = 1 leaves nothing to factor.
    let mut p1 = p;
    p1.dp = 1;
    p1.pp = 16;
    assert_eq!(p1.npus(), map.npu_count());
    assert!(
        symmetric_iteration(&t, &map, &m, &p1, RankOrder::TopologyAware, &spec).is_err()
    );
}

/// Units that *span* pods (EP32 over SP8 → unit_dp = 4 = two pods):
/// intra-unit EP traffic now rides the LRS→HRS uplinks, and the two
/// units share HRS switch *nodes* — but never links, because each rack
/// owns its uplinks. Disjointness, translation bit-equality and the
/// cache differential must all survive the cross-pod regime; this is
/// the small-scale image of the 32K/64K fig22 configurations.
#[test]
fn cross_pod_units_stay_disjoint_and_translated() {
    let (t, map, mut p) = fixture();
    p.ep = 32;
    let m = by_name("gpt4-2t").unwrap();
    let spec = IterationSpec::default();
    let sym =
        symmetric_iteration(&t, &map, &m, &p, RankOrder::TopologyAware, &spec).unwrap();
    assert_eq!(sym.unit_dp, 4, "EP32 over SP8 spans four replicas");
    assert_eq!(sym.units, 2, "two two-pod units");

    let link_sets: Vec<BTreeSet<u32>> = sym
        .unit_dags
        .iter()
        .map(|dag| {
            let mut s = BTreeSet::new();
            for stage in &dag.stages {
                for f in stage.materialize_flows(&t) {
                    for c in &f.channels {
                        s.insert(c.link.0);
                    }
                }
            }
            s
        })
        .collect();
    assert!(
        link_sets[0].is_disjoint(&link_sets[1]),
        "cross-pod units share links: {:?}",
        link_sets[0].intersection(&link_sets[1]).take(5).collect::<Vec<_>>()
    );

    let net = SimNet::new(&t);
    let reports = run_components(&net, &sym.unit_dags, &ParallelConfig::serial());
    assert!(!reports[0].is_stalled());
    assert_eq!(
        reports[1].makespan_us.to_bits(),
        reports[0].makespan_us.to_bits(),
        "pod translation must preserve the solve bit-for-bit across the HRS uplinks"
    );
    assert_eq!(reports[1].byte_hops.to_bits(), reports[0].byte_hops.to_bits());
    assert_eq!(reports[1].events, reports[0].events);

    let cached = run_symmetric(
        &net,
        &sym,
        &SymmetricConfig {
            workers: 2,
            replica_cache: true,
            strategy: Default::default(),
        },
    );
    let solved = run_symmetric(
        &net,
        &sym,
        &SymmetricConfig {
            workers: 1,
            replica_cache: false,
            strategy: Default::default(),
        },
    );
    assert_eq!(
        cached.report.makespan_us.to_bits(),
        solved.report.makespan_us.to_bits()
    );
    assert_eq!(cached.report.byte_hops.to_bits(), solved.report.byte_hops.to_bits());
    assert_eq!(cached.report.events, solved.report.events);
}
