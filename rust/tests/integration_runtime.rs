//! Integration: PJRT runtime ↔ coordinator — the AOT artifacts drive the
//! same decisions as the pure-rust model. Skips (with a notice) when
//! `make artifacts` hasn't run.

use ubmesh::coordinator::{Arch, Job};
use ubmesh::parallelism::space::{enumerate_configs, SearchSpace};
use ubmesh::runtime::Artifacts;
use ubmesh::workload::models::by_name;
use ubmesh::workload::placement::{Placement, TierBandwidth};
use ubmesh::workload::step::iteration_time;

fn artifacts() -> Option<Artifacts> {
    let dir = Artifacts::default_dir();
    if dir.join("manifest.txt").exists() {
        Some(Artifacts::load(&dir).expect("artifacts load"))
    } else {
        eprintln!("skipping runtime integration: run `make artifacts`");
        None
    }
}

#[test]
fn pjrt_and_rust_evaluators_agree_on_ranking() {
    let Some(a) = artifacts() else { return };
    let m = by_name("gpt3-175b").unwrap();
    let bw = TierBandwidth::ubmesh(16, 1.0);
    let cfgs = enumerate_configs(&m, &SearchSpace::paper_default(512, 32768.0));
    assert!(cfgs.len() > 4);
    let pjrt = a.evaluate_configs(&m, &cfgs, &bw).unwrap();
    let rust: Vec<f64> = cfgs
        .iter()
        .map(|c| iteration_time(&m, c, &Placement::topology_aware(c), &bw).total_us)
        .collect();
    // Same argmin and strong rank agreement.
    let argmin = |v: &[f64]| {
        v.iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    };
    assert_eq!(argmin(&pjrt), argmin(&rust), "evaluators disagree on best");
    for (p, r) in pjrt.iter().zip(&rust) {
        assert!((p - r).abs() / r < 0.06, "pjrt {p} rust {r}");
    }
}

#[test]
fn job_plans_identically_with_and_without_pjrt() {
    let Some(a) = artifacts() else { return };
    let job = Job::new("llama-70b", 128, 8192.0, Arch::ubmesh_default()).unwrap();
    let with = job.plan(Some(&a)).unwrap();
    let without = job.plan(None).unwrap();
    assert_eq!(with.best.tp, without.best.tp);
    assert_eq!(with.best.pp, without.best.pp);
    assert_eq!(with.evaluated, without.evaluated);
}

#[test]
fn apsp_artifact_agrees_with_bfs_on_pod_rack_graph() {
    let Some(a) = artifacts() else { return };
    use ubmesh::runtime::artifacts::INF;
    use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
    let (t, h) = ubmesh_rack(&RackConfig::default());
    // NPU+LRS subgraph (board 0 plane 0) ≤ 256 nodes: take the 64 NPUs
    // plus plane-0 LRS (18) = 82 nodes.
    let mut nodes = h.npus.clone();
    nodes.extend(h.npu_lrs[0].iter().copied());
    nodes.extend(h.ir_lrs[0].iter().copied());
    nodes.push(h.cpu_lrs[0]);
    nodes.push(h.bk_lrs[0]);
    let n = nodes.len();
    let mut adj = vec![INF; n * n];
    for i in 0..n {
        adj[i * n + i] = 0.0;
        for j in 0..n {
            if i != j && t.link_between(nodes[i], nodes[j]).is_some() {
                adj[i * n + j] = 1.0;
            }
        }
    }
    let d = a.apsp(&adj, n).unwrap();
    // d(npu0, npu63) = 2 through the mesh; d(npu, its board LRS) = 1.
    assert_eq!(d[0 * n + 63], 2.0);
    let lrs0 = 64; // first plane-0 LRS (board 0)
    assert_eq!(d[0 * n + lrs0], 1.0);
}

#[test]
fn linkload_artifact_balances_apr_split() {
    let Some(a) = artifacts() else { return };
    use ubmesh::runtime::artifacts::{LOAD_LINKS, LOAD_PATHS};
    // Two disjoint 2-link paths with 50/50 split: equal loads.
    let mut inc = vec![0.0f32; LOAD_PATHS * LOAD_LINKS];
    let mut demand = vec![0.0f32; LOAD_PATHS];
    inc[0 * LOAD_LINKS + 0] = 1.0;
    inc[0 * LOAD_LINKS + 1] = 1.0;
    inc[1 * LOAD_LINKS + 2] = 1.0;
    inc[1 * LOAD_LINKS + 3] = 1.0;
    demand[0] = 0.5;
    demand[1] = 0.5;
    let loads = a.link_load(&inc, &demand).unwrap();
    assert!((loads[0] - 0.5).abs() < 1e-6);
    assert!((loads[2] - 0.5).abs() < 1e-6);
    assert!(loads[4].abs() < 1e-6);
}
