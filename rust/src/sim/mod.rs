//! Flow-level discrete-event network simulator.
//!
//! This is the substrate substitution for the paper's unreleased
//! "in-house simulation infrastructure ... aligned with the real PoC
//! hardware" (§6.1). It is a *fluid* model: flows traverse directed
//! channels, share link capacity max-min fairly ([`fair`]), and complete
//! when their bytes drain. Collectives and training steps are expressed
//! as stage DAGs ([`schedule`]) whose stages release flows when their
//! dependencies finish.
//!
//! Fidelity notes (DESIGN.md §1): the paper reports architecture
//! *ratios* (e.g. 2D-FM at 93–96% of Clos), which a fluid model
//! preserves; packet-level effects (credit stalls, VL arbitration) are
//! abstracted — deadlock freedom is verified structurally by
//! [`crate::routing::tfc`] instead.

pub mod fair;
pub mod flow;
pub mod network;
pub mod schedule;

pub use flow::FlowSpec;
pub use network::SimNet;
pub use schedule::{SimReport, Stage, StageDag};
