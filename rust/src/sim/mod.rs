//! Flow-level discrete-event network simulator.
//!
//! This is the substrate substitution for the paper's unreleased
//! "in-house simulation infrastructure ... aligned with the real PoC
//! hardware" (§6.1). It is a *fluid* model: flows traverse directed
//! channels, share link capacity max-min fairly ([`fair`]), and complete
//! when their bytes drain. Collectives and training steps are expressed
//! as stage DAGs ([`schedule`]) whose stages release flows when their
//! dependencies finish. Independent scenarios fan out across OS threads
//! via [`sweep`].
//!
//! # Scaling architecture (this is the Pod-scale hot path)
//!
//! * [`fair::Rates`] is the incremental max-min solver: a channel→flow
//!   inverted index plus a *saturation heap* ordered by the fill level
//!   at which each channel binds, so a filling round touches only the
//!   channels whose flows freeze — not every active flow. Its
//!   `add_flows`/`remove_flows` re-solve only the connected component of
//!   the flow/channel bipartite graph the change touches.
//!
//!   **Invariants** (pinned by `rust/tests/properties.rs` and the
//!   differential oracle in `rust/tests/differential_fair.rs`):
//!   1. after every call, each alive flow's rate equals the from-scratch
//!      max-min allocation of the alive flow set (order-invariance: any
//!      add/remove sequence reaching the same set yields the same rates);
//!   2. per-channel load never exceeds capacity;
//!   3. work conservation — every flow whose channels are all live gets
//!      a strictly positive rate;
//!   4. flows crossing a failed (zero-capacity) channel sit at rate 0.
//!
//! * [`schedule::run`] drives the DAG from a binary-heap event queue
//!   (gates, flow completions, compute) with **lazy deletion**: rate
//!   changes stamp-invalidate predictions instead of rebuilding the
//!   queue, and simultaneous completions are batched into a single
//!   solver update so symmetric collectives stay linear.
//!
//! * [`sweep::sweep`] runs scenario batches (failure sets × topologies ×
//!   collectives) across threads with deterministic per-scenario RNG
//!   seeding — results are bit-identical for any thread count.
//!
//! The original O(flows × hops)-per-round solver is retained as
//! [`fair::naive_max_min_rates`], the oracle the differential tests
//! compare against.
//!
//! Fidelity notes (DESIGN.md §1): the paper reports architecture
//! *ratios* (e.g. 2D-FM at 93–96% of Clos), which a fluid model
//! preserves; packet-level effects (credit stalls, VL arbitration) are
//! abstracted — deadlock freedom is verified structurally by
//! [`crate::routing::tfc`] instead.

pub mod fair;
pub mod flow;
pub mod network;
pub mod schedule;
pub mod sweep;

pub use fair::{max_min_rates, FlowId, Rates};
pub use flow::FlowSpec;
pub use network::SimNet;
pub use schedule::{SimReport, Stage, StageDag};
pub use sweep::{scenario_seed, sweep as run_sweep, SweepConfig};
