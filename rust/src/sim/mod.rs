//! Flow-level discrete-event network simulator.
//!
//! This is the substrate substitution for the paper's unreleased
//! "in-house simulation infrastructure ... aligned with the real PoC
//! hardware" (§6.1). It is a *fluid* model: flows traverse directed
//! channels, share link capacity max-min fairly ([`fair`]), and complete
//! when their bytes drain. Collectives and training steps are expressed
//! as stage DAGs ([`schedule`]) whose stages release flows when their
//! dependencies finish. Independent scenarios fan out across OS threads
//! via [`sweep`].
//!
//! # Scaling architecture (SuperPod-scale hot path, PR 2 + PR 3)
//!
//! * [`fair::Rates`] is the incremental max-min solver: a channel→flow
//!   inverted index plus a *saturation heap* ordered by the fill level
//!   at which each channel binds. Component discovery is a **union-find
//!   over channels** maintained incrementally by `add_flows` (union) and
//!   split lazily on `remove_flows` (epoch-tagged component rebuild once
//!   enough possibly-splitting removals accumulate). Removals run a
//!   **rise-only bounded re-solve**: only flows sharing a bottleneck
//!   chain with the removed flows are recomputed, against the frozen
//!   rates of everything else, with three absorption triggers catching
//!   the non-monotone chains (falls past frozen flows, rises on
//!   de-loaded channels, under-served frozen flows on newly saturated
//!   channels). Additions run the symmetric **fall-only bounded
//!   re-solve** (PR 3): the new flows water-fill against the frozen
//!   background and existing flows are absorbed only along
//!   binding-channel chains, with the mirrored triggers — the last
//!   O(component) hot path. Both are combined by the default
//!   [`fair::ResolveStrategy::Bounded`]; the PR 2 full-component-add
//!   behavior survives as [`fair::ResolveStrategy::RiseOnly`] and the
//!   PR 1 solver as [`fair::ResolveStrategy::FullComponentBfs`] —
//!   two differential oracles next to [`fair::naive_max_min_rates`].
//!   [`fair::SolverStats`] slices the add-path work out
//!   (`add_rate_recomputes` vs `add_full_component_recomputes`) so the
//!   bounded-vs-full comparison is measurable per stage-gate add.
//!
//!   **Invariants** (pinned by `rust/tests/properties.rs` and the
//!   differential interleavings in `rust/tests/differential_fair.rs`):
//!   1. after every call, each alive flow's rate equals the from-scratch
//!      max-min allocation of the alive flow set (order-invariance: any
//!      add/remove sequence reaching the same set yields the same rates);
//!   2. per-channel load never exceeds capacity;
//!   3. work conservation — every flow whose channels are all live gets
//!      a strictly positive rate;
//!   4. flows crossing a failed (zero-capacity) channel sit at rate 0.
//!
//! * [`schedule::run`] drives the DAG from a binary-heap event queue
//!   (gates, flow completions, compute) with **lazy deletion**: rate
//!   changes stamp-invalidate predictions instead of rebuilding the
//!   queue, and simultaneous completions are batched into a single
//!   solver update so symmetric collectives stay linear. Stages may be
//!   **lazily materialized** ([`schedule::StageFlows::Lazy`]) and flow
//!   slots are recycled, so peak memory is O(active flows) rather than
//!   O(stages × flows). [`schedule::run_with`] selects the solver
//!   strategy; [`SimReport::solver`] reports the solver work counters.
//!
//! * [`schedule::run_components`] (PR 10) advances **channel-disjoint
//!   components on worker threads**: each component DAG runs its own
//!   event loop and solver, legitimate because max-min fairness factors
//!   across connected components, and bit-identical to the serial loop
//!   at any worker count because every component's run is a pure
//!   function of `(net, dag, strategy)` — thread assignment never feeds
//!   back into results. `workload::symmetric` builds the DP-replica
//!   partition (translation-symmetric units below the HRS tier, one
//!   representative solve reused across replicas) that makes the
//!   64K-NPU fig22 grid tractable on top of it.
//!
//! * [`fault::FaultPlan`] (PR 4) scripts mid-run failures as first-class
//!   events in that heap: link down/up/rescale and NPU death (with 64+1
//!   backup substitution) mutate the runner's private [`SimNet`] clone,
//!   re-solve through [`fair::Rates::links_changed`] (the bounded
//!   capacity-change path, `cap_*` counters), and — with a
//!   [`fault::RecoveryConfig`] — re-route cut-off flows onto surviving
//!   APR paths after the §4.2 convergence latency (hop-by-hop vs direct
//!   notification). Runs that end blocked return a structured stall
//!   report ([`schedule::SimReport::stalled`]) instead of panicking.
//!
//! * [`sweep::sweep`] runs scenario batches across threads with
//!   deterministic per-scenario RNG seeding — results are bit-identical
//!   for any thread count. [`sweep::GridBuilder`] generates cartesian
//!   (failure set × topology × collective) scenario grids and
//!   [`sweep::OnlineStats`]/[`sweep::AggTable`] aggregate mean/p99
//!   tables online; the paper benches and the reliability Monte-Carlo
//!   build on these instead of hand-rolled loops.
//!
//! Fidelity notes (DESIGN.md §1): the paper reports architecture
//! *ratios* (e.g. 2D-FM at 93–96% of Clos), which a fluid model
//! preserves; packet-level effects (credit stalls, VL arbitration) are
//! abstracted — deadlock freedom is verified structurally by
//! [`crate::routing::tfc`] instead.

pub mod fair;
pub mod fault;
pub mod flow;
pub mod network;
pub mod schedule;
pub mod sweep;

pub use fair::{max_min_rates, FlowId, Rates, ResolveStrategy, SolverStats};
pub use fault::{FaultEvent, FaultPlan, NotifyMode, RecoveryConfig, Reroute};
pub use flow::FlowSpec;
pub use network::SimNet;
pub use schedule::{
    run_components, run_components_faulted, run_components_timed, run_faulted, run_with,
    ParallelConfig, SimConfig, SimReport, Stage, StageDag, StageFlows, StalledFlow,
};
pub use sweep::{
    scenario_seed, sweep as run_sweep, AggTable, GridBuilder, OnlineStats, SweepConfig,
};
