//! Parallel scenario sweeps: run many independent simulations (failure
//! sets × topologies × collectives × seeds) across OS threads.
//!
//! Every paper-scale experiment is embarrassingly parallel at the
//! scenario granularity — each scenario builds its own `SimNet`/DAG and
//! shares nothing mutable — so the sweep is a simple work-stealing loop
//! over an atomic index. Two properties the benches rely on:
//!
//! * **Determinism**: each scenario gets its own [`Rng`] seeded by
//!   [`scenario_seed`]`(base_seed, index)` — a pure function of the
//!   scenario's position, never of thread assignment — so results are
//!   bit-identical across thread counts (including `threads = 1`).
//! * **Order preservation**: results come back indexed like the input
//!   scenario slice, so tables print in the order the sweep was declared.
//!
//! Used by `benches/fig12_fault_recovery.rs`, `benches/fig22_linearity.rs`,
//! the reliability Monte-Carlo ([`crate::reliability::montecarlo::run_par`])
//! and `examples/failover_demo.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::rng::{splitmix64, Rng};

/// Deterministic per-scenario seed: mixes `base` with the scenario index
/// through SplitMix64 so neighbouring indices get decorrelated streams.
pub fn scenario_seed(base: u64, index: usize) -> u64 {
    let mut s = base ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Worker threads (≥ 1). Defaults to the machine's parallelism.
    pub threads: usize,
    /// Base seed mixed into every scenario's RNG.
    pub base_seed: u64,
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            base_seed: 0x0B5E_5EED_0002,
        }
    }
}

impl SweepConfig {
    /// Single-threaded sweep (useful to confirm determinism).
    pub fn serial() -> SweepConfig {
        SweepConfig {
            threads: 1,
            ..SweepConfig::default()
        }
    }

    pub fn with_seed(mut self, seed: u64) -> SweepConfig {
        self.base_seed = seed;
        self
    }
}

/// Run `f(index, scenario, rng)` for every scenario, in parallel, and
/// return the results in scenario order. Panics in a worker propagate
/// once the scope joins (the sweep does not swallow failures).
pub fn sweep<S, T, F>(cfg: &SweepConfig, scenarios: &[S], f: F) -> Vec<T>
where
    S: Sync,
    T: Send,
    F: Fn(usize, &S, &mut Rng) -> T + Sync,
{
    let n = scenarios.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = cfg.threads.max(1).min(n);
    if threads == 1 {
        return scenarios
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut rng = Rng::new(scenario_seed(cfg.base_seed, i));
                f(i, s, &mut rng)
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let mut rng = Rng::new(scenario_seed(cfg.base_seed, i));
                let out = f(i, &scenarios[i], &mut rng);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("sweep: scenario produced no result")
        })
        .collect()
}

/// [`sweep`] with the default config (all cores, fixed base seed).
pub fn sweep_default<S, T, F>(scenarios: &[S], f: F) -> Vec<T>
where
    S: Sync,
    T: Send,
    F: Fn(usize, &S, &mut Rng) -> T + Sync,
{
    sweep(&SweepConfig::default(), scenarios, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_visits_all() {
        let scenarios: Vec<usize> = (0..100).collect();
        let out = sweep_default(&scenarios, |i, &s, _rng| {
            assert_eq!(i, s);
            s * 3
        });
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let scenarios: Vec<u32> = (0..64).collect();
        let draw = |_i: usize, _s: &u32, rng: &mut Rng| rng.next_u64();
        let serial = sweep(&SweepConfig::serial().with_seed(9), &scenarios, draw);
        let par = sweep(
            &SweepConfig {
                threads: 8,
                base_seed: 9,
            },
            &scenarios,
            draw,
        );
        assert_eq!(serial, par);
    }

    #[test]
    fn scenario_seeds_are_decorrelated() {
        let a = scenario_seed(1, 0);
        let b = scenario_seed(1, 1);
        assert_ne!(a, b);
        assert_ne!(scenario_seed(1, 0), scenario_seed(2, 0));
    }

    #[test]
    fn empty_sweep_is_empty() {
        let out: Vec<u32> = sweep_default(&[] as &[u8], |_, _, _| 1);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_simulations_match_serial() {
        use crate::sim::{self, FlowSpec, SimNet, Stage, StageDag};
        use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
        use crate::topology::{CableClass, NodeId};
        // Same DAG executed per-scenario: identical makespans regardless
        // of which thread ran it.
        let t = nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        );
        let scenarios: Vec<f64> = (1..9).map(|i| i as f64 * 50e6).collect();
        let run_one = |_i: usize, &bytes: &f64, _rng: &mut Rng| {
            let net = SimNet::new(&t);
            let mut dag = StageDag::default();
            dag.push(Stage::new("x").with_flows(vec![FlowSpec::along(
                &t,
                &[NodeId(0), NodeId(1)],
                bytes,
            )]));
            sim::schedule::run(&net, &dag).makespan_us
        };
        let serial = sweep(&SweepConfig::serial(), &scenarios, run_one);
        let par = sweep_default(&scenarios, run_one);
        assert_eq!(serial, par);
        for w in serial.windows(2) {
            assert!(w[1] > w[0], "more bytes → longer makespan");
        }
    }
}
