//! Parallel scenario sweeps: run many independent simulations (failure
//! sets × topologies × collectives × seeds) across OS threads.
//!
//! Every paper-scale experiment is embarrassingly parallel at the
//! scenario granularity — each scenario builds its own `SimNet`/DAG and
//! shares nothing mutable — so the sweep is a simple work-stealing loop
//! over an atomic index. Two properties the benches rely on:
//!
//! * **Determinism**: each scenario gets its own [`Rng`] seeded by
//!   [`scenario_seed`]`(base_seed, index)` — a pure function of the
//!   scenario's position, never of thread assignment — so results are
//!   bit-identical across thread counts (including `threads = 1`).
//! * **Order preservation**: results come back indexed like the input
//!   scenario slice, so tables print in the order the sweep was declared.
//!
//! Used by `benches/fig12_fault_recovery.rs`, `benches/fig22_linearity.rs`,
//! the reliability Monte-Carlo ([`crate::reliability::montecarlo::run_par`])
//! and `examples/failover_demo.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::rng::{splitmix64, Rng};

/// Deterministic per-scenario seed: avalanche `base` through SplitMix64
/// *before* mixing in the index, then finalize with a second SplitMix64
/// round, so both neighbouring indices and neighbouring base seeds get
/// decorrelated streams.
///
/// The base must be hashed first: a single-round mix of
/// `base ⊕ index·φ` (the old scheme) makes whole streams overlap for
/// related bases — `seed(b, 1) == seed(b ⊕ φ, 0)` for every `b`, so two
/// sweeps launched at XOR-adjacent seeds silently replay each other's
/// scenarios shifted by one. Hashing the base turns any cross-stream
/// collision into `splitmix64(b) − splitmix64(b′) ≡ (j−i)·φ (mod 2⁶⁴)`,
/// which has no structured small-index solutions.
pub fn scenario_seed(base: u64, index: usize) -> u64 {
    let mut h = base;
    let hashed = splitmix64(&mut h);
    let mut s = hashed.wrapping_add((index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    splitmix64(&mut s)
}

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Worker threads (≥ 1). Defaults to the machine's parallelism.
    pub threads: usize,
    /// Base seed mixed into every scenario's RNG.
    pub base_seed: u64,
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            base_seed: 0x0B5E_5EED_0002,
        }
    }
}

impl SweepConfig {
    /// Single-threaded sweep (useful to confirm determinism).
    pub fn serial() -> SweepConfig {
        SweepConfig {
            threads: 1,
            ..SweepConfig::default()
        }
    }

    pub fn with_seed(mut self, seed: u64) -> SweepConfig {
        self.base_seed = seed;
        self
    }
}

/// Run `f(index, scenario, rng)` for every scenario, in parallel, and
/// return the results in scenario order. Panics in a worker propagate
/// once the scope joins (the sweep does not swallow failures).
pub fn sweep<S, T, F>(cfg: &SweepConfig, scenarios: &[S], f: F) -> Vec<T>
where
    S: Sync,
    T: Send,
    F: Fn(usize, &S, &mut Rng) -> T + Sync,
{
    let n = scenarios.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = cfg.threads.max(1).min(n);
    if threads == 1 {
        return scenarios
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut rng = Rng::new(scenario_seed(cfg.base_seed, i));
                f(i, s, &mut rng)
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let mut rng = Rng::new(scenario_seed(cfg.base_seed, i));
                let out = f(i, &scenarios[i], &mut rng);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("sweep: scenario produced no result")
        })
        .collect()
}

/// [`sweep`] with the default config (all cores, fixed base seed).
pub fn sweep_default<S, T, F>(scenarios: &[S], f: F) -> Vec<T>
where
    S: Sync,
    T: Send,
    F: Fn(usize, &S, &mut Rng) -> T + Sync,
{
    sweep(&SweepConfig::default(), scenarios, f)
}

// ----------------------------------------------------------------------
// Cartesian scenario grids + online aggregation (PR 2 sweep ergonomics)
// ----------------------------------------------------------------------

/// Cartesian scenario grid feeding [`sweep`]. Replaces the hand-rolled
/// scenario-vector + index-lookup loops in the paper benches: declare
/// the axes, get the ordered scenario list and a parallel runner.
///
/// ```ignore
/// let grid = GridBuilder::cartesian2(&sizes, &faults, |&n, &k| Some((n, k)));
/// let rows = grid.run(|_i, &(n, k), rng| simulate(n, k, rng));
/// ```
pub struct GridBuilder<S> {
    scenarios: Vec<S>,
    cfg: SweepConfig,
}

impl<S: Sync> GridBuilder<S> {
    /// Wrap an explicit scenario list (chunked Monte-Carlo, custom
    /// grids).
    pub fn from_scenarios(scenarios: Vec<S>) -> GridBuilder<S> {
        GridBuilder {
            scenarios,
            cfg: SweepConfig::default(),
        }
    }

    /// One-axis grid: `make` may veto combinations by returning `None`.
    pub fn cartesian1<A>(a: &[A], make: impl Fn(&A) -> Option<S>) -> GridBuilder<S> {
        GridBuilder::from_scenarios(a.iter().filter_map(make).collect())
    }

    /// Two-axis cartesian product, row-major (`a` outer, `b` inner).
    pub fn cartesian2<A, B>(
        a: &[A],
        b: &[B],
        make: impl Fn(&A, &B) -> Option<S>,
    ) -> GridBuilder<S> {
        let mut scenarios = Vec::with_capacity(a.len() * b.len());
        for x in a {
            for y in b {
                if let Some(s) = make(x, y) {
                    scenarios.push(s);
                }
            }
        }
        GridBuilder::from_scenarios(scenarios)
    }

    /// Three-axis cartesian product (failure set × topology ×
    /// collective), row-major.
    pub fn cartesian3<A, B, C>(
        a: &[A],
        b: &[B],
        c: &[C],
        make: impl Fn(&A, &B, &C) -> Option<S>,
    ) -> GridBuilder<S> {
        let mut scenarios = Vec::with_capacity(a.len() * b.len() * c.len());
        for x in a {
            for y in b {
                for z in c {
                    if let Some(s) = make(x, y, z) {
                        scenarios.push(s);
                    }
                }
            }
        }
        GridBuilder::from_scenarios(scenarios)
    }

    pub fn with_config(mut self, cfg: SweepConfig) -> GridBuilder<S> {
        self.cfg = cfg;
        self
    }

    pub fn scenarios(&self) -> &[S] {
        &self.scenarios
    }

    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    /// Index of the first scenario matching `pred` (benches use this to
    /// look results back up by axis values).
    pub fn position(&self, pred: impl Fn(&S) -> bool) -> Option<usize> {
        self.scenarios.iter().position(pred)
    }

    /// Run the grid through [`sweep`]; results come back in scenario
    /// order (deterministic for any thread count).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &S, &mut Rng) -> T + Sync,
    {
        sweep(&self.cfg, &self.scenarios, f)
    }

    /// Run the grid and fold each scenario's `f64` result into an
    /// [`AggTable`] keyed by `key` (insertion-ordered, so table rows
    /// print in axis order).
    pub fn run_agg<F, K>(&self, key: K, f: F) -> AggTable
    where
        F: Fn(usize, &S, &mut Rng) -> f64 + Sync,
        K: Fn(&S) -> String,
    {
        let vals = self.run(f);
        let mut agg = AggTable::default();
        for (s, v) in self.scenarios.iter().zip(vals) {
            agg.add(key(s), v);
        }
        agg
    }
}

/// Retained-sample cap for [`OnlineStats`]: quantiles are exact up to
/// this many pushes, then the store degrades to Algorithm-R reservoir
/// sampling behind the same API. Sized to cover every current sweep
/// (the largest tables aggregate a few thousand scenarios per key)
/// while bounding memory/sort cost at 64K-grid volumes.
const SAMPLE_CAP: usize = 4096;

/// Streaming summary statistics: Welford mean/variance (always exact),
/// exact running sum/min/max, and quantiles from a bounded sample
/// store — exact below [`SAMPLE_CAP`] samples, uniform reservoir
/// estimates beyond it.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    sum: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    /// SplitMix64 state for reservoir replacement — deterministic in
    /// push order, so aggregation stays bit-reproducible run-to-run.
    rstate: u64,
}

impl OnlineStats {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(x);
        } else {
            // Algorithm R: the x-th arrival replaces a random slot with
            // probability CAP/n, keeping the store a uniform sample of
            // everything seen.
            let j = splitmix64(&mut self.rstate) % self.n;
            if (j as usize) < SAMPLE_CAP {
                self.samples[j as usize] = x;
            }
        }
    }

    pub fn n(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Exact running sum (the Monte-Carlo reducer needs sums, not means).
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Nearest-rank quantile, q in [0, 1]. Exact while at most
    /// [`SAMPLE_CAP`] samples were pushed; beyond that it is computed
    /// over the uniform reservoir (extremes stay exact: q = 0 and q = 1
    /// return the true running min/max).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile {q} out of range");
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if q == 0.0 {
            return self.min;
        }
        if q == 1.0 {
            return self.max;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let idx = ((sorted.len() as f64 * q).ceil() as usize)
            .saturating_sub(1)
            .min(sorted.len() - 1);
        sorted[idx]
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// Insertion-ordered table of key → [`OnlineStats`]: the mean/p99
/// aggregation behind the sweep benches' tables.
#[derive(Clone, Debug, Default)]
pub struct AggTable {
    rows: Vec<(String, OnlineStats)>,
}

impl AggTable {
    pub fn add(&mut self, key: impl Into<String>, value: f64) {
        let key = key.into();
        match self.rows.iter_mut().find(|(k, _)| *k == key) {
            Some((_, stats)) => stats.push(value),
            None => {
                let mut stats = OnlineStats::default();
                stats.push(value);
                self.rows.push((key, stats));
            }
        }
    }

    pub fn get(&self, key: &str) -> Option<&OnlineStats> {
        self.rows.iter().find(|(k, _)| k == key).map(|(_, s)| s)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &OnlineStats)> {
        self.rows.iter().map(|(k, s)| (k.as_str(), s))
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_visits_all() {
        let scenarios: Vec<usize> = (0..100).collect();
        let out = sweep_default(&scenarios, |i, &s, _rng| {
            assert_eq!(i, s);
            s * 3
        });
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let scenarios: Vec<u32> = (0..64).collect();
        let draw = |_i: usize, _s: &u32, rng: &mut Rng| rng.next_u64();
        let serial = sweep(&SweepConfig::serial().with_seed(9), &scenarios, draw);
        let par = sweep(
            &SweepConfig {
                threads: 8,
                base_seed: 9,
            },
            &scenarios,
            draw,
        );
        assert_eq!(serial, par);
    }

    #[test]
    fn scenario_seeds_are_decorrelated() {
        let a = scenario_seed(1, 0);
        let b = scenario_seed(1, 1);
        assert_ne!(a, b);
        assert_ne!(scenario_seed(1, 0), scenario_seed(2, 0));
    }

    #[test]
    fn adjacent_base_seeds_produce_disjoint_streams() {
        use std::collections::BTreeSet;
        // Sweeps launched at related base seeds must not share any
        // per-scenario seed. The old single-round mix of
        // `base ^ index·φ` failed exactly this: seed(b, 1) == seed(b ^ φ,
        // 0) for every b, so the b ^ φ sweep replayed b's stream shifted
        // by one scenario.
        const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
        let b = 0x0B5E_5EED_0002_u64;
        let bases = [b, b + 1, b ^ GOLDEN, b.wrapping_add(GOLDEN)];
        let per_base = 4096_usize;
        let mut seen = BTreeSet::new();
        for &base in &bases {
            for i in 0..per_base {
                seen.insert(scenario_seed(base, i));
            }
        }
        assert_eq!(
            seen.len(),
            bases.len() * per_base,
            "adjacent-base sweeps share scenario seeds"
        );
        // The specific historical collision, pinned directly.
        assert_ne!(scenario_seed(b, 1), scenario_seed(b ^ GOLDEN, 0));
    }

    #[test]
    fn empty_sweep_is_empty() {
        let out: Vec<u32> = sweep_default(&[] as &[u8], |_, _, _| 1);
        assert!(out.is_empty());
    }

    #[test]
    fn grid_builder_cartesian_orders_and_filters() {
        let a = [1usize, 2, 3];
        let b = ["x", "y"];
        let g = GridBuilder::cartesian2(&a, &b, |&n, &s| {
            (n != 2).then(|| (n, s.to_string()))
        });
        assert_eq!(g.len(), 4); // n=2 vetoed on both b values
        assert_eq!(g.scenarios()[0], (1, "x".to_string()));
        assert_eq!(g.scenarios()[1], (1, "y".to_string()));
        assert_eq!(g.scenarios()[3], (3, "y".to_string()));
        assert_eq!(g.position(|s| s.0 == 3), Some(2));
        let out = g.run(|i, s, _| (i, s.0));
        assert_eq!(out, vec![(0, 1), (1, 1), (2, 3), (3, 3)]);
    }

    #[test]
    fn grid_builder_cartesian3_row_major() {
        let g = GridBuilder::cartesian3(&[0u8, 1], &[0u8, 1], &[0u8, 1], |&a, &b, &c| {
            Some((a, b, c))
        });
        assert_eq!(g.len(), 8);
        assert_eq!(g.scenarios()[0], (0, 0, 0));
        assert_eq!(g.scenarios()[1], (0, 0, 1));
        assert_eq!(g.scenarios()[7], (1, 1, 1));
    }

    #[test]
    fn online_stats_mean_quantiles_and_sum() {
        let mut s = OnlineStats::default();
        for x in [4.0, 1.0, 3.0, 2.0, 5.0] {
            s.push(x);
        }
        assert_eq!(s.n(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.sum() - 15.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.p99(), 5.0);
        assert!((s.var() - 2.5).abs() < 1e-12); // sample variance of 1..5
    }

    #[test]
    fn bounded_store_is_exact_below_cap() {
        // The reservoir must be invisible at small n: quantiles over ≤1k
        // samples match the old keep-everything nearest-rank exactly.
        let mut rng = Rng::new(0xE5A);
        let xs: Vec<f64> = (0..1000).map(|_| rng.f64() * 1e6 - 5e5).collect();
        let mut s = OnlineStats::default();
        for &x in &xs {
            s.push(x);
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for q in [0.0, 0.01, 0.25, 0.50, 0.75, 0.99, 1.0] {
            let idx = ((sorted.len() as f64 * q).ceil() as usize)
                .saturating_sub(1)
                .min(sorted.len() - 1);
            assert_eq!(s.quantile(q), sorted[idx], "q={q}");
        }
        assert_eq!(s.min(), sorted[0]);
        assert_eq!(s.max(), sorted[sorted.len() - 1]);
        assert!((s.sum() - xs.iter().sum::<f64>()).abs() < 1e-6);
    }

    #[test]
    fn bounded_store_caps_memory_and_keeps_exact_moments() {
        // 50k pushes: the store must stay at SAMPLE_CAP while the
        // streaming moments/extremes remain exact and the reservoir p50
        // lands near the true median.
        let n = 50_000_usize;
        let mut s = OnlineStats::default();
        for i in 0..n {
            // Deterministic scramble of 0..n so arrival order is not
            // sorted (a sorted stream would hide replacement bugs).
            let v = (i.wrapping_mul(7919) % n) as f64;
            s.push(v);
        }
        assert_eq!(s.samples.len(), SAMPLE_CAP);
        assert_eq!(s.n(), n as u64);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), (n - 1) as f64);
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), (n - 1) as f64);
        let true_sum = (n * (n - 1) / 2) as f64;
        assert!((s.sum() - true_sum).abs() / true_sum < 1e-12);
        let true_mean = true_sum / n as f64;
        assert!((s.mean() - true_mean).abs() / true_mean < 1e-12);
        // Reservoir median of a uniform population: SE ≈ 0.5/√4096 of
        // the range, so ±5% is a ~6σ band.
        let p50 = s.p50();
        assert!(
            (p50 - true_mean).abs() < 0.05 * n as f64,
            "reservoir p50 drifted: {p50}"
        );
    }

    #[test]
    fn agg_table_groups_in_insertion_order() {
        let sizes = [4usize, 8];
        let reps = [0u64, 1, 2];
        let agg = GridBuilder::cartesian2(&sizes, &reps, |&n, &r| Some((n, r)))
            .run_agg(|&(n, _)| format!("n={n}"), |_i, &(n, r), _rng| (n + r as usize) as f64);
        assert_eq!(agg.len(), 2);
        let keys: Vec<&str> = agg.iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["n=4", "n=8"]);
        let s4 = agg.get("n=4").unwrap();
        assert_eq!(s4.n(), 3);
        assert!((s4.mean() - 5.0).abs() < 1e-12); // (4+5+6)/3
    }

    #[test]
    fn parallel_simulations_match_serial() {
        use crate::sim::{self, FlowSpec, SimNet, Stage, StageDag};
        use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
        use crate::topology::{CableClass, NodeId};
        // Same DAG executed per-scenario: identical makespans regardless
        // of which thread ran it.
        let t = nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        );
        let scenarios: Vec<f64> = (1..9).map(|i| i as f64 * 50e6).collect();
        let run_one = |_i: usize, &bytes: &f64, _rng: &mut Rng| {
            let net = SimNet::new(&t);
            let mut dag = StageDag::default();
            dag.push(Stage::new("x").with_flows(vec![FlowSpec::along(
                &t,
                &[NodeId(0), NodeId(1)],
                bytes,
            )]));
            sim::schedule::run(&net, &dag).makespan_us
        };
        let serial = sweep(&SweepConfig::serial(), &scenarios, run_one);
        let par = sweep_default(&scenarios, run_one);
        assert_eq!(serial, par);
        for w in serial.windows(2) {
            assert!(w[1] > w[0], "more bytes → longer makespan");
        }
    }
}
