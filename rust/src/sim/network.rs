//! Per-channel capacity state, including failures.

use crate::topology::{Channel, LinkId, Topology};

/// Directed-channel capacity view over a topology, with link up/down
/// state for failure-injection experiments.
///
/// `Clone` copies the capacity/down state (the topology is shared by
/// reference) — the fault-injecting runner
/// ([`crate::sim::schedule::run_faulted`]) works on a private clone so
/// a scripted [`crate::sim::fault::FaultPlan`] never mutates the
/// caller's view.
#[derive(Clone)]
pub struct SimNet<'a> {
    pub topo: &'a Topology,
    /// Capacity per channel index (GB/s). 2 channels per link.
    cap: Vec<f64>,
    down: Vec<bool>,
}

impl<'a> SimNet<'a> {
    pub fn new(topo: &'a Topology) -> SimNet<'a> {
        let mut cap = Vec::with_capacity(topo.link_count() * 2);
        for (i, l) in topo.links.iter().enumerate() {
            let c = l.capacity_gb_s();
            assert!(
                c.is_finite() && c >= 0.0,
                "link {i} capacity {c} GB/s must be finite and ≥ 0"
            );
            cap.push(c);
            cap.push(c);
        }
        SimNet {
            topo,
            cap,
            down: vec![false; topo.link_count()],
        }
    }

    #[inline]
    pub fn capacity(&self, ch: Channel) -> f64 {
        if self.down[ch.link.idx()] {
            0.0
        } else {
            self.cap[ch.idx()]
        }
    }

    pub fn channel_count(&self) -> usize {
        self.cap.len()
    }

    /// Capacity by raw channel index (see [`Channel::idx`]).
    #[inline]
    pub fn cap_by_idx(&self, idx: usize) -> f64 {
        if self.down[idx / 2] {
            0.0
        } else {
            self.cap[idx]
        }
    }

    pub fn fail_link(&mut self, l: LinkId) {
        self.down[l.idx()] = true;
    }

    pub fn restore_link(&mut self, l: LinkId) {
        self.down[l.idx()] = false;
    }

    pub fn is_down(&self, l: LinkId) -> bool {
        self.down[l.idx()]
    }

    /// True if the link can carry traffic: not failed *and* not rescaled
    /// to zero capacity. Rerouting and stall analysis use this rather
    /// than [`SimNet::is_down`] — a `set_link_capacity(l, 0.0)` link is
    /// as dead as a failed one, and re-selecting a path across it would
    /// loop forever.
    pub fn is_usable(&self, l: LinkId) -> bool {
        !self.down[l.idx()]
            && self.cap[l.idx() * 2].max(self.cap[l.idx() * 2 + 1]) > 0.0
    }

    /// Scale a single link's capacity (e.g. backup NPU attach with fewer
    /// lanes, degraded links).
    pub fn set_link_capacity(&mut self, l: LinkId, gb_s: f64) {
        assert!(
            gb_s.is_finite() && gb_s >= 0.0,
            "link {l} capacity {gb_s} GB/s must be finite and ≥ 0"
        );
        self.cap[l.idx() * 2] = gb_s;
        self.cap[l.idx() * 2 + 1] = gb_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::CableClass;

    #[test]
    fn capacity_and_failures() {
        let t = nd_fullmesh(
            "m4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        );
        let mut net = SimNet::new(&t);
        let ch = Channel::forward(LinkId(0));
        assert!(net.capacity(ch) > 0.0);
        net.fail_link(LinkId(0));
        assert_eq!(net.capacity(ch), 0.0);
        net.restore_link(LinkId(0));
        assert!(net.capacity(ch) > 0.0);
    }

    #[test]
    fn zero_capacity_rescale_is_unusable() {
        let t = nd_fullmesh(
            "m4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        );
        let mut net = SimNet::new(&t);
        assert!(net.is_usable(LinkId(0)));
        net.fail_link(LinkId(0));
        assert!(!net.is_usable(LinkId(0)));
        net.restore_link(LinkId(0));
        net.set_link_capacity(LinkId(0), 0.0);
        assert!(!net.is_usable(LinkId(0)), "zero-capacity link is dead");
        net.set_link_capacity(LinkId(0), 10.0);
        assert!(net.is_usable(LinkId(0)));
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn nan_capacity_rejected() {
        let t = nd_fullmesh(
            "m4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        );
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), f64::NAN);
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn negative_capacity_rejected() {
        let t = nd_fullmesh(
            "m4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        );
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), -5.0);
    }
}
