//! Max-min fair rate allocation (progressive filling / water-filling).
//!
//! Given active flows and per-channel capacities, all flows' rates grow
//! uniformly until some channel saturates; flows crossing it freeze at
//! the current level, and filling continues for the rest. This is the
//! standard fluid-model allocation used by flow-level DC simulators.
//!
//! Four solver layers live here (PR 2 rise-only removals, PR 3 fall-only
//! adds):
//!
//! * [`naive_max_min_rates`] — the original O(rounds × flows × hops)
//!   scan, retained verbatim as the differential-test oracle.
//! * [`Rates`] with [`ResolveStrategy::FullComponentBfs`] — the PR 1
//!   solver: a channel→flow inverted index drives a **saturation heap**
//!   (each heap entry is the uniform fill level at which a channel
//!   binds), and every `add_flows`/`remove_flows` re-solves the
//!   connected component(s) of the flow/channel bipartite graph the
//!   change touches, discovered by BFS. Kept as the second differential
//!   oracle and for measured before/after comparisons in
//!   `benches/perf_hotpaths.rs`.
//! * [`Rates`] with [`ResolveStrategy::RiseOnly`] — the PR 2
//!   SuperPod-scale solver (rise-only bounded removals, full-component
//!   adds):
//!
//!   1. **Union-find over channels** replaces the per-event component
//!      BFS. `add_flows` unions the channels of each new flow (near-O(α)
//!      per hop) and attaches the flow to the component root's member
//!      list; `remove_flows` only decrements the root's live count. A
//!      removal of a multi-channel flow *may* split its component; the
//!      split is reclaimed lazily — the component is rebuilt (reset +
//!      re-union of its alive members, epoch-tagged so only that
//!      component's channels are touched) once enough such removals
//!      accumulate. Until then the component is a *conservative union*
//!      of true components, which is always correct (re-solving extra
//!      components reproduces their rates) and only costs accuracy in
//!      the [`SolverStats::full_component_recomputes`] estimate.
//!   2. **Rise-only bounded re-solve on removal**: removing flows can
//!      only free capacity, so only flows sharing a bottleneck chain
//!      with the removed flows can change rate. The re-solve seeds a
//!      candidate set from the flows on the removed flows' *saturated*
//!      channels and water-fills just those candidates against the
//!      frozen rates of everything else. Three absorption triggers grow
//!      the candidate set when the bounded solve would be inconsistent
//!      with global max-min (see `resolve_rise` for the derivation):
//!      (a) a binding channel carries a frozen non-candidate with a
//!      higher rate than the binding level — that flow may have to
//!      *fall* (a candidate rising past it steals shared capacity);
//!      (b) a previously saturated candidate channel ends with less
//!      candidate load than before — flows frozen on it may now *rise*;
//!      (c) a now-saturated candidate channel carries a frozen flow
//!      *below* the level the candidates reached and that flow has no
//!      valid bottleneck elsewhere — it is under-served and must rise
//!      to the common level. Each trigger restarts the solve with the
//!      enlarged set; the set grows monotonically, and a (rare) runaway
//!      chain falls back to a full component solve.
//!
//! * [`Rates`] with [`ResolveStrategy::Bounded`] (the default, PR 3) —
//!   rise-only removals **plus the symmetric fall-only bounded add
//!   re-solve**. Adding flows is dual to removing them: new flows can
//!   only *steal* capacity, so existing rates can only fall along
//!   binding-channel chains reachable from the new flows' channels
//!   (with second-order rises where a fall de-loads another channel).
//!   The add path:
//!
//!   1. **Seeding** — the candidate set is exactly the new flows
//!      (pre-solve rate 0). Unlike the removal path there is no
//!      saturation pre-test: an unsaturated channel of a new flow simply
//!      lets it rise through, and a saturated one binds at the current
//!      bottleneck level during the very first fill, which is where
//!      existing flows get pulled in.
//!   2. **Absorption** — the same three triggers as the removal path,
//!      mirrored in direction: (a) the new flow's binding channel
//!      carries a frozen flow *above* the binding level — that flow must
//!      fall to make room (the primary add direction); (b) an absorbed
//!      fall de-loads a previously saturated channel — flows frozen on
//!      it may rise; (c) a now-saturated channel carries an under-served
//!      frozen flow with no valid bottleneck elsewhere — it must rise.
//!      Each trigger restarts the bounded fill with the enlarged set
//!      ([`SolverStats::add_absorb_restarts`]); runaway chains fall back
//!      to the full component solve ([`SolverStats::add_fallbacks`]).
//!   3. **Fallback + oracle** — the full-component solve is retained
//!      both as the in-band fallback and, via
//!      [`ResolveStrategy::FullComponentBfs`] /
//!      [`ResolveStrategy::RiseOnly`], as differential oracles; the
//!      add-path work counters ([`SolverStats::add_rate_recomputes`] vs
//!      [`SolverStats::add_full_component_recomputes`]) make the
//!      bounded-vs-full comparison measurable per stage-gate add.
//!
//! A fourth mutation class (PR 4, mid-run fault injection) changes the
//! *constraints* instead of the flow set: [`Rates::links_changed`] /
//! [`Rates::channels_changed`] re-solve after a link fails, restores or
//! rescales mid-run. The bounded strategies seed from every flow
//! crossing a changed channel and reuse the same absorption machinery —
//! fall-dominated on capacity loss, rise-dominated on restore — with
//! work sliced into the [`SolverStats`] `cap_*` counters;
//! `FullComponentBfs` re-solves the affected component, remaining the
//! differential oracle.
//!
//! Invariant (after every public call, any strategy): `rate(id)` of
//! every alive flow equals the max-min fair allocation of the full alive
//! flow set — under the *current* [`SimNet`] capacities —
//! incrementality is a pure optimization, never a semantic
//! change. `rust/tests/differential_fair.rs` pins this with randomized
//! add/remove interleavings against both oracles, and
//! `rust/tests/properties.rs` with order-invariance/feasibility
//! properties.
//!
//! [`max_min_rates`] keeps the original one-shot API as a thin wrapper
//! over [`Rates`].

use std::collections::BinaryHeap;

use crate::topology::{Channel, LinkId};

use super::network::SimNet;

/// Compute max-min fair rates (GB/s) for `flows`, where each flow is the
/// list of channels it crosses. Flows crossing a zero-capacity (failed)
/// channel get rate 0.
pub fn max_min_rates(net: &SimNet, flows: &[&[Channel]]) -> Vec<f64> {
    let mut r = Rates::new();
    let ids = r.add_flows(net, flows);
    ids.iter().map(|&id| r.rate(id)).collect()
}

/// Original from-scratch progressive-filling solver. Quadratic in the
/// worst case; kept as the oracle for the differential tests
/// (`rust/tests/differential_fair.rs`) and for spot-checking the
/// incremental solver from benches.
pub fn naive_max_min_rates(net: &SimNet, flows: &[&[Channel]]) -> Vec<f64> {
    let n = flows.len();
    let mut rate = vec![0.0f64; n];
    if n == 0 {
        return rate;
    }
    let nch = net.channel_count();
    // Channel load bookkeeping. Only channels actually used matter.
    let mut unfrozen_cnt = vec![0u32; nch];
    let mut committed = vec![0.0f64; nch];
    let mut frozen = vec![false; n];

    // Flows over failed channels are stuck at 0.
    for (i, f) in flows.iter().enumerate() {
        if f.iter().any(|&c| net.capacity(c) <= 0.0) {
            frozen[i] = true;
        }
    }
    for (i, f) in flows.iter().enumerate() {
        if !frozen[i] {
            for c in *f {
                unfrozen_cnt[c.idx()] += 1;
            }
        }
    }

    let mut remaining = frozen.iter().filter(|&&f| !f).count();
    let mut fill = 0.0f64; // current uniform fill level
    while remaining > 0 {
        // Find the binding channel: min residual headroom per unfrozen flow.
        let mut delta = f64::INFINITY;
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            for c in *f {
                let ci = c.idx();
                let head =
                    (net.capacity(*c) - committed[ci]) / unfrozen_cnt[ci] as f64;
                if head < delta {
                    delta = head;
                }
            }
        }
        if !delta.is_finite() || delta < 0.0 {
            delta = 0.0;
        }
        fill += delta;
        // Commit the increment.
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            rate[i] = fill;
            for c in *f {
                committed[c.idx()] += delta;
            }
        }
        // Freeze flows on (near-)saturated channels.
        let mut froze_any = false;
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            let saturated = f.iter().any(|&c| {
                let ci = c.idx();
                net.capacity(c) - committed[ci]
                    <= 1e-9 * net.capacity(c).max(1.0)
            });
            if saturated {
                frozen[i] = true;
                froze_any = true;
                remaining -= 1;
                for c in *f {
                    unfrozen_cnt[c.idx()] -= 1;
                }
            }
        }
        if !froze_any {
            // Numerical safety: freeze everything at the current level.
            for (i, _) in flows.iter().enumerate() {
                if !frozen[i] {
                    frozen[i] = true;
                    remaining -= 1;
                }
            }
        }
    }
    rate
}

/// Handle of a flow registered in a [`Rates`] solver.
pub type FlowId = usize;

/// How [`Rates`] re-solves after a mutation.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum ResolveStrategy {
    /// The combined bounded mode (default): additions run the fall-only
    /// bounded re-solve seeded from the new flows, removals the
    /// rise-only bounded re-solve seeded from the removed flows'
    /// saturated channels.
    #[default]
    Bounded,
    /// PR 2 behavior, kept as a differential oracle and for the add-path
    /// before/after comparison: additions solve the whole union-find
    /// component; removals run the rise-only bounded re-solve.
    RiseOnly,
    /// PR 1 behavior, kept as a differential oracle: BFS the affected
    /// component and water-fill it from zero on every mutation.
    FullComponentBfs,
}

/// Work counters, reset via [`Rates::reset_stats`]. The headline perf
/// metric of `benches/perf_hotpaths.rs` is
/// `full_component_recomputes / rate_recomputes` — how much narrower the
/// bounded re-solve is than a full component re-solve per event.
#[derive(Clone, Debug, Default)]
pub struct SolverStats {
    /// Mutating calls that triggered a re-solve.
    pub resolves: u64,
    /// Flow-rate assignments actually performed (all solve attempts).
    pub rate_recomputes: u64,
    /// Flow-rate assignments a full-component re-solve (the PR 1
    /// strategy) would perform on the same call sequence. Exact under
    /// `FullComponentBfs`; under `RiseOnly` it is the union-find live
    /// component size — a sharp estimate that can only over-count while
    /// a split component awaits its lazy rebuild.
    pub full_component_recomputes: u64,
    /// Bounded solves that restarted with an enlarged candidate set.
    pub absorb_restarts: u64,
    /// Bounded solves that gave up and ran a full component solve.
    pub fallbacks: u64,
    /// Flow-rate assignments performed *by* those fallbacks. Kept out of
    /// `rate_recomputes` (which counts only bounded-path work) but
    /// included in the [`SolverStats::add_recompute_ratio`] /
    /// [`SolverStats::cap_recompute_ratio`] denominators, so the ratio
    /// stays honest — `Some`, not "no data" — on runs where every event
    /// fell back.
    pub fallback_recomputes: u64,
    /// Lazy union-find component rebuilds (split reclamation).
    pub uf_rebuilds: u64,
    /// Add-path slices of the aggregate counters above (each `add_*`
    /// value is also included in its aggregate): `add_flows` calls that
    /// re-solved, the rate recomputes they performed, what a full
    /// component re-solve would have performed on the same calls, and
    /// the add-path absorption restarts / fallbacks. The headline
    /// add-path metric is `add_full_component_recomputes /
    /// add_rate_recomputes` — how much narrower the fall-only add is
    /// than the PR 2 full-component add per stage-gate event.
    pub add_resolves: u64,
    pub add_rate_recomputes: u64,
    pub add_full_component_recomputes: u64,
    pub add_absorb_restarts: u64,
    pub add_fallbacks: u64,
    pub add_fallback_recomputes: u64,
    /// Capacity-change-path slices (PR 4, mid-run fault injection): the
    /// same accounting for [`Rates::channels_changed`] /
    /// [`Rates::links_changed`] calls — re-solves after a link
    /// fails/restores/rescales mid-run, their rate recomputes, the
    /// full-component equivalent, and absorption restarts / fallbacks.
    pub cap_resolves: u64,
    pub cap_rate_recomputes: u64,
    pub cap_full_component_recomputes: u64,
    pub cap_absorb_restarts: u64,
    pub cap_fallbacks: u64,
    pub cap_fallback_recomputes: u64,
}

impl SolverStats {
    /// Add-path narrowness: full-component-equivalent recomputes per
    /// recompute actually performed on the add path, bounded attempts
    /// *and* fallback solves alike (≥ 1 when no event fell back; `None`
    /// until an add re-solved something). Counting fallback work in the
    /// denominator keeps the ratio honest under forced-fallback runs —
    /// the old `add_rate_recomputes`-only denominator reported "no
    /// data" for work that did happen whenever every add event fell
    /// back before performing a bounded recompute.
    pub fn add_recompute_ratio(&self) -> Option<f64> {
        let denom = self.add_rate_recomputes + self.add_fallback_recomputes;
        (denom > 0).then(|| self.add_full_component_recomputes as f64 / denom as f64)
    }

    /// Capacity-change-path narrowness, mirroring
    /// [`SolverStats::add_recompute_ratio`] for mid-run fault events
    /// (same fallback-inclusive denominator).
    pub fn cap_recompute_ratio(&self) -> Option<f64> {
        let denom = self.cap_rate_recomputes + self.cap_fallback_recomputes;
        (denom > 0).then(|| self.cap_full_component_recomputes as f64 / denom as f64)
    }

    /// Sum `other` into `self`, field by field — merging the per-worker
    /// solver counters of a component-parallel run back into one report.
    pub fn merge(&mut self, other: &SolverStats) {
        self.resolves += other.resolves;
        self.rate_recomputes += other.rate_recomputes;
        self.full_component_recomputes += other.full_component_recomputes;
        self.absorb_restarts += other.absorb_restarts;
        self.fallbacks += other.fallbacks;
        self.fallback_recomputes += other.fallback_recomputes;
        self.uf_rebuilds += other.uf_rebuilds;
        self.add_resolves += other.add_resolves;
        self.add_rate_recomputes += other.add_rate_recomputes;
        self.add_full_component_recomputes += other.add_full_component_recomputes;
        self.add_absorb_restarts += other.add_absorb_restarts;
        self.add_fallbacks += other.add_fallbacks;
        self.add_fallback_recomputes += other.add_fallback_recomputes;
        self.cap_resolves += other.cap_resolves;
        self.cap_rate_recomputes += other.cap_rate_recomputes;
        self.cap_full_component_recomputes += other.cap_full_component_recomputes;
        self.cap_absorb_restarts += other.cap_absorb_restarts;
        self.cap_fallbacks += other.cap_fallbacks;
        self.cap_fallback_recomputes += other.cap_fallback_recomputes;
    }

    /// Re-home the double counts of a bounded-solve fallback: the
    /// fallback runs `resolve_component_uf`, which counts its own
    /// resolve, adds the member count to the full-component estimate
    /// that the mutating entry point already pre-charged from the
    /// union-find live counts, and books its rate assignments as
    /// bounded-path work. Undo the resolve and the estimate, and move
    /// the rate assignments from `rate_recomputes` to
    /// `fallback_recomputes`. Saturating: the counters are adjusted,
    /// never trusted to be large enough (a `reset_stats` between the
    /// pre-charge and the fallback, or a conservative pre-charge
    /// undercount, must clamp to zero rather than wrap to `u64::MAX`
    /// and wreck every later ratio).
    fn discount_fallback(&mut self, members: u64) {
        self.resolves = self.resolves.saturating_sub(1);
        self.full_component_recomputes = self.full_component_recomputes.saturating_sub(members);
        self.rate_recomputes = self.rate_recomputes.saturating_sub(members);
        self.fallback_recomputes += members;
    }
}

#[derive(Clone, Debug, Default)]
struct FlowState {
    channels: Vec<Channel>,
    rate: f64,
    alive: bool,
    /// Generation stamps (== the solver's current `gen`) marking
    /// membership in the set being re-solved / frozen-ness within that
    /// solve. Stamps avoid O(all flows) clears per solve.
    in_component: u64,
    frozen_at: u64,
}

/// Saturation-heap entry: the fill level at which `ch` binds, valid only
/// while `ver` matches the channel's version (lazy deletion).
struct Sat {
    fill: f64,
    ch: usize,
    ver: u32,
}

impl PartialEq for Sat {
    fn eq(&self, other: &Self) -> bool {
        self.fill == other.fill && self.ch == other.ch
    }
}
impl Eq for Sat {}
impl PartialOrd for Sat {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Sat {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the smallest fill.
        other
            .fill
            .total_cmp(&self.fill)
            .then_with(|| other.ch.cmp(&self.ch))
    }
}

/// Union-find over channel indices, maintaining per-component alive-flow
/// counts and member lists (flow ids attached beneath each root).
///
/// Member lists are only ever non-empty at current roots: `attach`
/// pushes at the root and `union` moves the losing root's list into the
/// winner, so an alive flow's entry is always reachable from
/// `find(any of its channels)`. Entries of dead flows — and duplicate
/// entries for a recycled [`FlowId`] — are purged lazily whenever a
/// component is collected ([`Rates::collect_members`]) or rebuilt.
#[derive(Default)]
struct ChannelUf {
    parent: Vec<u32>,
    rank: Vec<u8>,
    members: Vec<Vec<FlowId>>,
    /// Alive flows in the component (valid at roots).
    live: Vec<u32>,
    /// Multi-channel-flow removals since the last rebuild (valid at
    /// roots); each may have split the component.
    splits: Vec<u32>,
}

impl ChannelUf {
    fn ensure(&mut self, upto: usize) {
        let from = self.parent.len();
        if from < upto {
            self.parent.extend((from..upto).map(|i| i as u32));
            self.rank.resize(upto, 0);
            self.members.resize_with(upto, Vec::new);
            self.live.resize(upto, 0);
            self.splits.resize(upto, 0);
        }
    }

    fn find(&mut self, mut c: usize) -> usize {
        while self.parent[c] as usize != c {
            let gp = self.parent[self.parent[c] as usize];
            self.parent[c] = gp; // path halving
            c = gp as usize;
        }
        c
    }

    /// Union the components of roots/channels `a` and `b`; returns the
    /// surviving root.
    fn union(&mut self, a: usize, b: usize) -> usize {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return ra;
        }
        let (w, l) = if self.rank[ra] >= self.rank[rb] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        if self.rank[w] == self.rank[l] {
            self.rank[w] += 1;
        }
        self.parent[l] = w as u32;
        let moved = std::mem::take(&mut self.members[l]);
        if self.members[w].is_empty() {
            self.members[w] = moved;
        } else {
            self.members[w].extend(moved);
        }
        self.live[w] += self.live[l];
        self.live[l] = 0;
        self.splits[w] += self.splits[l];
        self.splits[l] = 0;
        w
    }

    /// Reset a channel to a fresh singleton (used by component rebuild).
    fn reset(&mut self, c: usize) {
        self.parent[c] = c as u32;
        self.rank[c] = 0;
        self.members[c].clear();
        self.live[c] = 0;
        self.splits[c] = 0;
    }
}

/// Incremental max-min fair solver over a mutable flow set. See the
/// module docs for the three-layer architecture and invariants.
#[derive(Default)]
pub struct Rates {
    strategy: ResolveStrategy,
    stats: SolverStats,
    flows: Vec<FlowState>,
    free: Vec<FlowId>,
    /// Channel idx → alive flow ids, one entry per crossing (a flow that
    /// crosses a channel twice appears twice — multiplicity matters for
    /// the fair share, matching the oracle's bookkeeping).
    by_channel: Vec<Vec<FlowId>>,
    /// Flows whose rate may have changed in the last mutating call.
    touched: Vec<FlowId>,
    uf: ChannelUf,

    // ---- per-solve scratch (generation-stamped, never cleared) -------
    gen: u64,
    chan_gen: Vec<u64>,
    chan_occ: Vec<u32>,
    chan_frozen_load: Vec<f64>,
    chan_ver: Vec<u32>,
    /// Rise-only scratch: pre-solve candidate load per involved channel.
    chan_old_cand: Vec<f64>,
    /// Heap-seeding dedup stamp (one entry per channel per fill).
    chan_seeded: Vec<u64>,
    /// Override for [`MAX_RISE_ATTEMPTS`] (`None` = the default). Tests
    /// set it to 0 to force every bounded solve straight into the
    /// full-component fallback.
    max_rise_attempts: Option<u32>,
}

/// Give up on a bounded re-solve (rise-only removal or fall-only add)
/// after this many absorption restarts and solve the whole component
/// (each restart strictly grows the candidate set, so this only
/// triggers on pathological chains).
const MAX_RISE_ATTEMPTS: u32 = 32;

impl Rates {
    pub fn new() -> Rates {
        Rates::default()
    }

    /// Solver with an explicit re-solve strategy (benches/tests pit the
    /// strategies against each other).
    pub fn with_strategy(strategy: ResolveStrategy) -> Rates {
        Rates {
            strategy,
            ..Rates::default()
        }
    }

    pub fn strategy(&self) -> ResolveStrategy {
        self.strategy
    }

    /// Work counters accumulated since construction / the last reset.
    pub fn stats(&self) -> &SolverStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = SolverStats::default();
    }

    /// Cap the bounded solver's absorption restarts before it falls back
    /// to the full component solve (default [`MAX_RISE_ATTEMPTS`]).
    /// Setting 0 forces the fallback on every bounded solve — the
    /// forced-fallback regime the counter tests pin down. Results are
    /// identical at any setting (the fallback is exact); only the work
    /// accounting moves.
    pub fn set_max_rise_attempts(&mut self, attempts: u32) {
        self.max_rise_attempts = Some(attempts);
    }

    /// Number of alive flows.
    pub fn len(&self) -> usize {
        self.flows.iter().filter(|f| f.alive).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current rate (GB/s) of an alive flow.
    #[inline]
    pub fn rate(&self, id: FlowId) -> f64 {
        debug_assert!(self.flows[id].alive, "rate() on dead flow {id}");
        self.flows[id].rate
    }

    /// Flows whose rate may have changed in the last `add_flows` /
    /// `remove_flows` / `channels_changed` call (the re-solved set,
    /// including the new flows themselves). The DAG runner uses this to
    /// re-settle only what moved.
    pub fn touched(&self) -> &[FlowId] {
        &self.touched
    }

    /// Channel list of an alive flow (the runner's stall report and
    /// reroute path both inspect this).
    pub fn channels(&self, id: FlowId) -> &[Channel] {
        debug_assert!(self.flows[id].alive, "channels() on dead flow {id}");
        &self.flows[id].channels
    }

    fn ensure_channels(&mut self, upto: usize) {
        if self.by_channel.len() < upto {
            self.by_channel.resize_with(upto, Vec::new);
            self.chan_gen.resize(upto, 0);
            self.chan_occ.resize(upto, 0);
            self.chan_frozen_load.resize(upto, 0.0);
            self.chan_ver.resize(upto, 0);
            self.chan_old_cand.resize(upto, 0.0);
            self.chan_seeded.resize(upto, 0);
        }
        self.uf.ensure(upto);
    }

    /// Register new flows and re-solve the affected component(s).
    /// Returns one [`FlowId`] per input flow, in order.
    pub fn add_flows(&mut self, net: &SimNet, flows: &[&[Channel]]) -> Vec<FlowId> {
        self.ensure_channels(net.channel_count());
        let mut ids = Vec::with_capacity(flows.len());
        let mut dirty: Vec<usize> = Vec::new();
        for chans in flows {
            assert!(!chans.is_empty(), "flow with no channels");
            let id = match self.free.pop() {
                Some(id) => id,
                None => {
                    self.flows.push(FlowState::default());
                    self.flows.len() - 1
                }
            };
            let st = &mut self.flows[id];
            st.channels = chans.to_vec();
            st.rate = 0.0;
            st.alive = true;
            st.in_component = 0;
            st.frozen_at = 0;
            for c in chans.iter() {
                let ci = c.idx();
                debug_assert!(ci < self.by_channel.len(), "channel beyond net");
                self.by_channel[ci].push(id);
                dirty.push(ci);
            }
            ids.push(id);
            // Union-find maintenance: merge the flow's channels into one
            // component and attach the flow to its root.
            let mut root = self.uf.find(chans[0].idx());
            for c in &chans[1..] {
                root = self.uf.union(root, c.idx());
            }
            self.uf.members[root].push(id);
            self.uf.live[root] += 1;
            // The bounded add path never collects members (only the
            // fallback does), so dead/duplicate entries from recycled
            // ids would otherwise accumulate; compact opportunistically.
            if self.uf.members[root].len() > 2 * self.uf.live[root] as usize + 16 {
                self.compact_members(root);
            }
        }
        // Slice this call's solver work into the add_* counters.
        let before = self.stats.clone();
        match self.strategy {
            ResolveStrategy::FullComponentBfs => self.resolve_bfs(net, &dirty),
            ResolveStrategy::RiseOnly => self.resolve_component_uf(net, &dirty),
            ResolveStrategy::Bounded => {
                // PR 2-equivalent work estimate for the add path: a
                // full-component re-solve would recompute every alive
                // member of the touched components (new flows included).
                self.gen += 1;
                let rgen = self.gen;
                for &ci in &dirty {
                    let r = self.uf.find(ci);
                    if self.chan_gen[r] != rgen {
                        self.chan_gen[r] = rgen;
                        self.stats.full_component_recomputes += self.uf.live[r] as u64;
                    }
                }
                self.resolve_fall(net, &ids);
            }
        }
        let s = &mut self.stats;
        s.add_resolves += s.resolves.saturating_sub(before.resolves);
        s.add_rate_recomputes += s.rate_recomputes.saturating_sub(before.rate_recomputes);
        s.add_full_component_recomputes += s
            .full_component_recomputes
            .saturating_sub(before.full_component_recomputes);
        s.add_absorb_restarts += s.absorb_restarts.saturating_sub(before.absorb_restarts);
        s.add_fallbacks += s.fallbacks.saturating_sub(before.fallbacks);
        s.add_fallback_recomputes += s
            .fallback_recomputes
            .saturating_sub(before.fallback_recomputes);
        ids
    }

    /// Deregister flows and re-solve the affected flows. Rates of the
    /// removed flows become meaningless; their ids are recycled.
    pub fn remove_flows(&mut self, net: &SimNet, ids: &[FlowId]) {
        // (channel, removed crossing's rate) — the rate part lets the
        // rise-only path reconstruct pre-removal loads.
        let mut dirty: Vec<(usize, f64)> = Vec::new();
        let mut roots: Vec<usize> = Vec::new();
        self.gen += 1;
        let root_gen = self.gen; // dedups roots in O(1) per removed flow
        for &id in ids {
            assert!(self.flows[id].alive, "remove of dead flow {id}");
            self.flows[id].alive = false;
            let old_rate = self.flows[id].rate;
            let channels = std::mem::take(&mut self.flows[id].channels);
            for c in &channels {
                let ci = c.idx();
                // Remove ONE occurrence per crossing.
                let lst = &mut self.by_channel[ci];
                let pos = lst
                    .iter()
                    .position(|&f| f == id)
                    .expect("flow missing from inverted index");
                lst.swap_remove(pos);
                dirty.push((ci, old_rate));
            }
            // Union-find maintenance. The member-list entry is purged
            // lazily; a single-channel flow can never have bridged two
            // channel groups, so only multi-channel removals may split.
            let root = self.uf.find(channels[0].idx());
            self.uf.live[root] = self.uf.live[root].saturating_sub(1);
            if channels.iter().any(|c| c.idx() != channels[0].idx()) {
                self.uf.splits[root] += 1;
            }
            if self.chan_gen[root] != root_gen {
                self.chan_gen[root] = root_gen;
                roots.push(root);
            }
            self.free.push(id);
        }
        match self.strategy {
            ResolveStrategy::FullComponentBfs => {
                let chans: Vec<usize> = dirty.iter().map(|&(ci, _)| ci).collect();
                self.resolve_bfs(net, &chans);
            }
            ResolveStrategy::RiseOnly | ResolveStrategy::Bounded => {
                // PR 1-equivalent work estimate: re-solving the whole
                // component would recompute every surviving member.
                for &r in &roots {
                    self.stats.full_component_recomputes += self.uf.live[r] as u64;
                }
                self.resolve_rise(net, &dirty);
            }
        }
        // Lazy split reclamation: once removals that may have split a
        // component outnumber half its survivors, rebuild it so the
        // conservative union doesn't degrade add-path solves and the
        // full-component estimate.
        for r in roots {
            let r = self.uf.find(r); // unions in resolve paths can't happen, but be safe
            if self.uf.splits[r] > 8 && self.uf.splits[r] as u64 * 2 > self.uf.live[r] as u64 {
                self.rebuild_component(r);
            }
        }
    }

    /// Re-solve after the capacities of `links` changed in `net` — the
    /// mid-run fault-injection entry point (PR 4): call
    /// [`SimNet::fail_link`] / [`SimNet::restore_link`] /
    /// [`SimNet::set_link_capacity`] first, then hand the changed links
    /// here. Both directed channels of each link are re-solved.
    pub fn links_changed(&mut self, net: &SimNet, links: &[LinkId]) {
        let chans: Vec<usize> = links
            .iter()
            .flat_map(|l| {
                let c = l.idx() * 2;
                [c, c + 1]
            })
            .collect();
        self.channels_changed(net, &chans);
    }

    /// Re-solve after the capacities of raw channel indices `chans`
    /// changed in `net`. The flow set is untouched — only the
    /// constraints moved — so there is no union-find maintenance; under
    /// [`ResolveStrategy::Bounded`]/[`ResolveStrategy::RiseOnly`] the
    /// candidate set seeds from **every flow crossing a changed
    /// channel** and the shared bounded machinery absorbs the chains in
    /// either direction: a capacity *loss* makes the seeded flows fall
    /// (a failed link pins them at 0 outright) with second-order rises
    /// on the channels they de-load (triggers b/c), and a *restore*
    /// lets them rise with second-order falls where they steal shared
    /// capacity (trigger a). Seeding the whole crossing set — rather
    /// than the saturation-filtered seed of the removal path — keeps
    /// the changed channel free of frozen non-candidates, so the
    /// triggers never have to reason about a channel whose capacity
    /// itself moved. Work lands in the [`SolverStats`] `cap_*` slices.
    pub fn channels_changed(&mut self, net: &SimNet, chans: &[usize]) {
        self.ensure_channels(net.channel_count());
        let before = self.stats.clone();
        match self.strategy {
            ResolveStrategy::FullComponentBfs => self.resolve_bfs(net, chans),
            ResolveStrategy::RiseOnly | ResolveStrategy::Bounded => {
                // Full-component work estimate, as on the other bounded
                // paths: a PR 1 re-solve would recompute every alive
                // member of the touched components.
                self.gen += 1;
                let rgen = self.gen;
                for &ci in chans {
                    let r = self.uf.find(ci);
                    if self.chan_gen[r] != rgen {
                        self.chan_gen[r] = rgen;
                        self.stats.full_component_recomputes += self.uf.live[r] as u64;
                    }
                }
                self.resolve_cap(net, chans);
            }
        }
        let s = &mut self.stats;
        s.cap_resolves += s.resolves.saturating_sub(before.resolves);
        s.cap_rate_recomputes += s.rate_recomputes.saturating_sub(before.rate_recomputes);
        s.cap_full_component_recomputes += s
            .full_component_recomputes
            .saturating_sub(before.full_component_recomputes);
        s.cap_absorb_restarts += s.absorb_restarts.saturating_sub(before.absorb_restarts);
        s.cap_fallbacks += s.fallbacks.saturating_sub(before.fallbacks);
        s.cap_fallback_recomputes += s
            .fallback_recomputes
            .saturating_sub(before.fallback_recomputes);
    }

    // ------------------------------------------------------------------
    // Component discovery
    // ------------------------------------------------------------------

    /// Collect the alive member flows of the union-find components that
    /// contain `dirty` channels, compacting the member lists as a side
    /// effect (dead entries and recycled-id duplicates are dropped,
    /// survivors re-homed at their current root).
    fn collect_members(&mut self, dirty: &[usize]) -> Vec<FlowId> {
        self.gen += 1;
        let gen = self.gen;
        let mut roots: Vec<usize> = Vec::new();
        for &ci in dirty {
            let r = self.uf.find(ci);
            if self.chan_gen[r] != gen {
                self.chan_gen[r] = gen;
                roots.push(r);
            }
        }
        let mut flows: Vec<FlowId> = Vec::new();
        for &r in &roots {
            for fid in std::mem::take(&mut self.uf.members[r]) {
                if self.flows[fid].alive && self.flows[fid].in_component != gen {
                    // A recycled id may appear in a foreign root's stale
                    // list; its real entry lives at its current root, so
                    // only keep it if it belongs here.
                    let home = self.uf.find(self.flows[fid].channels[0].idx());
                    if self.chan_gen[home] == gen {
                        self.flows[fid].in_component = gen;
                        flows.push(fid);
                    }
                }
            }
            // live is recounted below; splits is deliberately kept — a
            // collection does not undo possible splits, only a rebuild
            // does.
            self.uf.live[r] = 0;
        }
        // Re-home the survivors at their current roots.
        for &fid in &flows {
            let home = self.uf.find(self.flows[fid].channels[0].idx());
            self.uf.members[home].push(fid);
            self.uf.live[home] += 1;
        }
        flows
    }

    /// Rebuild one component's union-find structure from its alive
    /// members, splitting it back into true components. Epoch-tagged via
    /// `chan_gen`: only this component's channels are touched.
    fn rebuild_component(&mut self, root: usize) {
        self.gen += 1;
        let gen = self.gen;
        let mut flows: Vec<FlowId> = Vec::new();
        for fid in std::mem::take(&mut self.uf.members[root]) {
            if self.flows[fid].alive && self.flows[fid].in_component != gen {
                let home = self.uf.find(self.flows[fid].channels[0].idx());
                if home == root {
                    self.flows[fid].in_component = gen;
                    flows.push(fid);
                }
                // else: stale duplicate of a recycled id — its real
                // entry lives at its own root; drop this one.
            }
        }
        // Reset every channel the alive members touch (plus the old root
        // itself so it cannot keep a stale member list or counters).
        self.gen += 1;
        let rgen = self.gen;
        self.chan_gen[root] = rgen;
        self.uf.reset(root);
        for &fid in &flows {
            for j in 0..self.flows[fid].channels.len() {
                let ci = self.flows[fid].channels[j].idx();
                if self.chan_gen[ci] != rgen {
                    self.chan_gen[ci] = rgen;
                    self.uf.reset(ci);
                }
            }
        }
        // Re-union per flow, then attach each flow at its new root.
        for &fid in &flows {
            let c0 = self.flows[fid].channels[0].idx();
            let mut r = self.uf.find(c0);
            for j in 1..self.flows[fid].channels.len() {
                let cj = self.flows[fid].channels[j].idx();
                r = self.uf.union(r, cj);
            }
        }
        for &fid in &flows {
            let r = self.uf.find(self.flows[fid].channels[0].idx());
            self.uf.members[r].push(fid);
            self.uf.live[r] += 1;
        }
        self.stats.uf_rebuilds += 1;
    }

    /// Drop dead and recycled-duplicate entries from one root's member
    /// list (no union-find structure change, unlike a rebuild). The
    /// bounded add path calls this when a list outgrows its live count:
    /// unlike the PR 2 add path it never collects members, so a pure
    /// add/remove churn of single-channel flows (which never trigger a
    /// split rebuild) would otherwise grow the list without bound.
    fn compact_members(&mut self, root: usize) {
        self.gen += 1;
        let gen = self.gen;
        let mut kept: Vec<FlowId> = Vec::with_capacity(self.uf.live[root] as usize);
        for fid in std::mem::take(&mut self.uf.members[root]) {
            if self.flows[fid].alive && self.flows[fid].in_component != gen {
                let home = self.uf.find(self.flows[fid].channels[0].idx());
                if home == root {
                    self.flows[fid].in_component = gen;
                    kept.push(fid);
                }
                // else: stale duplicate of a recycled id, homed elsewhere.
            }
        }
        self.uf.live[root] = kept.len() as u32;
        self.uf.members[root] = kept;
    }

    // ------------------------------------------------------------------
    // Solvers
    // ------------------------------------------------------------------

    /// Full solve of the union-find component(s) containing `dirty`
    /// channels (the add path, and the rise-only fallback).
    fn resolve_component_uf(&mut self, net: &SimNet, dirty: &[usize]) {
        self.touched.clear();
        if dirty.is_empty() {
            return;
        }
        self.stats.resolves += 1;
        let members = self.collect_members(dirty);
        self.stats.rate_recomputes += members.len() as u64;
        self.stats.full_component_recomputes += members.len() as u64;
        self.solve_from_zero(net, &members);
        self.touched = members;
    }

    /// Re-solve the union of components reachable from `dirty` channels,
    /// discovered by BFS over the flow/channel bipartite graph — the
    /// PR 1 code path, retained as [`ResolveStrategy::FullComponentBfs`].
    ///
    /// Correctness: a max-min allocation factors across connected
    /// components (no shared channel → no shared constraint), so
    /// restricting the water-filling to the affected component
    /// reproduces the global solution for it exactly.
    fn resolve_bfs(&mut self, net: &SimNet, dirty: &[usize]) {
        self.touched.clear();
        if dirty.is_empty() {
            return;
        }
        self.stats.resolves += 1;
        self.gen += 1;
        let gen = self.gen;

        // ---- component discovery: BFS channels ↔ flows ----------------
        let mut chan_stack: Vec<usize> = Vec::new();
        for &ci in dirty {
            if self.chan_gen[ci] != gen {
                self.chan_gen[ci] = gen;
                chan_stack.push(ci);
            }
        }
        let mut member_flows: Vec<FlowId> = Vec::new();
        while let Some(ci) = chan_stack.pop() {
            for k in 0..self.by_channel[ci].len() {
                let fid = self.by_channel[ci][k];
                if self.flows[fid].in_component == gen {
                    continue;
                }
                self.flows[fid].in_component = gen;
                member_flows.push(fid);
                // Borrow dance: clone-free walk over this flow's channels.
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    if self.chan_gen[cj] != gen {
                        self.chan_gen[cj] = gen;
                        chan_stack.push(cj);
                    }
                }
            }
        }
        self.stats.rate_recomputes += member_flows.len() as u64;
        self.stats.full_component_recomputes += member_flows.len() as u64;
        self.solve_from_zero(net, &member_flows);
        self.touched = member_flows;
    }

    /// Water-fill `members` from fill level zero with no background load
    /// (the member set must be closed under channel sharing — a union of
    /// whole components). Stamps its own generation.
    fn solve_from_zero(&mut self, net: &SimNet, members: &[FlowId]) {
        self.gen += 1;
        let gen = self.gen;
        for &fid in members {
            self.flows[fid].in_component = gen;
            for j in 0..self.flows[fid].channels.len() {
                let cj = self.flows[fid].channels[j].idx();
                if self.chan_gen[cj] != gen {
                    self.chan_gen[cj] = gen;
                    self.chan_occ[cj] = 0;
                    self.chan_frozen_load[cj] = 0.0;
                }
            }
        }
        self.fill(net, members, gen, None);
    }

    /// Bounded re-solve after removals: only flows sharing a bottleneck
    /// chain with the removed flows are recomputed; everything else is
    /// frozen background.
    ///
    /// Seeding: removing a flow frees capacity only on its own channels,
    /// and a frozen flow's rate can change only if (i) a channel it
    /// crosses gains slack while being its bottleneck — it *rises* — or
    /// (ii) a flow sharing one of its saturated channels rises past it —
    /// it may *fall* (the classic non-monotone chain: freeing `a` lets
    /// `b` rise on one channel, which steals from `c` on another). Flows
    /// bottlenecked on an *unsaturated* removed channel don't exist (an
    /// unsaturated channel pins nobody), so the initial candidates are
    /// the flows on the removed flows' saturated channels. Chains of
    /// type (i)/(ii) beyond the seed are caught by the three absorption
    /// triggers during/after the fill (see module docs) which restart
    /// with the larger set; `rust/tests/differential_fair.rs` hammers
    /// exactly these chains against the oracles, and the
    /// statement-level Python port of this algorithm was differentially
    /// fuzzed against the naive oracle on 13k+ randomized interleavings
    /// (the fuzz found and fixed the missing trigger (c)).
    fn resolve_rise(&mut self, net: &SimNet, dirty: &[(usize, f64)]) {
        self.touched.clear();
        if dirty.is_empty() {
            return;
        }
        self.stats.resolves += 1;

        // ---- pre-removal saturation test per dirty channel -----------
        // Pre-removal load = current alive load + the removed crossings.
        self.gen += 1;
        let gen0 = self.gen;
        let mut dirty_chans: Vec<usize> = Vec::new();
        for &(ci, removed_rate) in dirty {
            if self.chan_gen[ci] != gen0 {
                self.chan_gen[ci] = gen0;
                self.chan_old_cand[ci] = 0.0; // accumulates removed load
                dirty_chans.push(ci);
            }
            self.chan_old_cand[ci] += removed_rate;
        }
        let mut cands: Vec<FlowId> = Vec::new();
        let mut cand_old: Vec<f64> = Vec::new();
        self.gen += 1;
        let cgen = self.gen; // stamps candidate membership (flows)
        for &ci in &dirty_chans {
            let mut load = self.chan_old_cand[ci];
            for k in 0..self.by_channel[ci].len() {
                load += self.flows[self.by_channel[ci][k]].rate;
            }
            let cap = net.cap_by_idx(ci);
            if load < cap - 1e-6 * cap.max(1.0) {
                // The channel had slack before the removal, so it pinned
                // nobody — its flows cannot rise through it.
                continue;
            }
            for k in 0..self.by_channel[ci].len() {
                let fid = self.by_channel[ci][k];
                if self.flows[fid].in_component != cgen {
                    self.flows[fid].in_component = cgen;
                    cands.push(fid);
                    cand_old.push(self.flows[fid].rate);
                }
            }
        }
        if cands.is_empty() {
            return;
        }
        self.bounded_solve(net, cands, cand_old, cgen, &dirty_chans);
    }

    /// Bounded re-solve after additions — the fall-only dual of
    /// [`Rates::resolve_rise`]: new flows can only *steal* capacity, so
    /// existing rates can only fall (with second-order rises where a
    /// fall de-loads another channel).
    ///
    /// Seeding: the candidates are exactly the new flows. A new flow
    /// water-fills against the frozen background and stops at its
    /// current bottleneck level; if that binding channel carries frozen
    /// flows above the level (they must fall to make room), absorption
    /// trigger (a) pulls them in during the fill, and triggers (b)/(c)
    /// then catch the second-order rise chains — see the module docs.
    /// The differential interleavings in
    /// `rust/tests/differential_fair.rs` hammer these chains against
    /// three oracles, and the statement-level Python port of this
    /// algorithm was differentially fuzzed against the naive oracle on
    /// 20k+ randomized add/remove interleavings.
    fn resolve_fall(&mut self, net: &SimNet, new_ids: &[FlowId]) {
        self.touched.clear();
        if new_ids.is_empty() {
            return;
        }
        self.stats.resolves += 1;
        self.gen += 1;
        let cgen = self.gen; // stamps candidate membership (flows)
        let mut cands: Vec<FlowId> = Vec::with_capacity(new_ids.len());
        let mut cand_old: Vec<f64> = Vec::with_capacity(new_ids.len());
        for &fid in new_ids {
            debug_assert!(self.flows[fid].alive);
            if self.flows[fid].in_component != cgen {
                self.flows[fid].in_component = cgen;
                cands.push(fid);
                cand_old.push(0.0); // new flows carried no pre-add load
            }
        }
        self.bounded_solve(net, cands, cand_old, cgen, &[]);
    }

    /// Bounded re-solve after capacity changes on `chans` (see
    /// [`Rates::channels_changed`] for the seeding/direction argument):
    /// candidates are every flow crossing a changed channel, with their
    /// pre-change rates as the trigger baseline.
    fn resolve_cap(&mut self, net: &SimNet, chans: &[usize]) {
        self.touched.clear();
        self.gen += 1;
        let cgen = self.gen; // stamps candidate membership (flows)
        let mut cands: Vec<FlowId> = Vec::new();
        let mut cand_old: Vec<f64> = Vec::new();
        for &ci in chans {
            for k in 0..self.by_channel[ci].len() {
                let fid = self.by_channel[ci][k];
                if self.flows[fid].in_component != cgen {
                    self.flows[fid].in_component = cgen;
                    cands.push(fid);
                    cand_old.push(self.flows[fid].rate);
                }
            }
        }
        if cands.is_empty() {
            return; // changed channels carry no flows: no rate can move
        }
        self.stats.resolves += 1;
        self.bounded_solve(net, cands, cand_old, cgen, chans);
    }

    /// The shared absorption loop behind [`Rates::resolve_rise`] and
    /// [`Rates::resolve_fall`]: water-fill `cands` against the frozen
    /// background, enlarging the set via the three absorption triggers
    /// until the bounded solution is consistent with global max-min.
    /// `cand_old` holds each candidate's pre-mutation rate (0 for new
    /// flows) and every candidate must already carry the `cgen` stamp;
    /// `fallback_seed` lists extra channels (beyond the candidates' own)
    /// whose components the fallback must cover.
    fn bounded_solve(
        &mut self,
        net: &SimNet,
        mut cands: Vec<FlowId>,
        mut cand_old: Vec<f64>,
        cgen: u64,
        fallback_seed: &[usize],
    ) {
        let mut involved: Vec<usize> = Vec::new();
        let mut absorb: Vec<usize> = Vec::new();
        let mut attempts = 0u32;
        let max_attempts = self.max_rise_attempts.unwrap_or(MAX_RISE_ATTEMPTS);
        loop {
            attempts += 1;
            if attempts > max_attempts {
                // Pathological absorption chain: solve the whole
                // component instead (always correct).
                self.stats.fallbacks += 1;
                let mut seed: Vec<usize> = fallback_seed.to_vec();
                for &fid in &cands {
                    seed.extend(self.flows[fid].channels.iter().map(|c| c.idx()));
                }
                self.resolve_component_uf(net, &seed);
                let members = self.touched.len() as u64;
                self.stats.discount_fallback(members);
                return;
            }

            // ---- stamp this attempt: members + involved channels ------
            self.gen += 1;
            let gen = self.gen;
            for &fid in &cands {
                self.flows[fid].in_component = gen;
            }
            involved.clear();
            for &fid in &cands {
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    if self.chan_gen[cj] != gen {
                        self.chan_gen[cj] = gen;
                        self.chan_occ[cj] = 0;
                        self.chan_frozen_load[cj] = 0.0;
                        self.chan_old_cand[cj] = 0.0;
                        involved.push(cj);
                    }
                }
            }
            // Frozen background: alive non-candidates keep their rates.
            for &ci in &involved {
                for k in 0..self.by_channel[ci].len() {
                    let fid = self.by_channel[ci][k];
                    if self.flows[fid].in_component != gen {
                        self.chan_frozen_load[ci] += self.flows[fid].rate;
                    }
                }
            }
            // Pre-solve candidate load (for the rise trigger below).
            for (k, &fid) in cands.iter().enumerate() {
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    self.chan_old_cand[cj] += cand_old[k];
                }
            }

            // ---- fill the candidates against the background -----------
            absorb.clear();
            self.fill(net, &cands, gen, Some(&mut absorb));
            self.stats.rate_recomputes += cands.len() as u64;

            // ---- post-solve absorption triggers on involved channels:
            // (b) rise: the channel was saturated and now carries less
            //     candidate load — frozen flows on it may rise;
            // (c) under-served: the channel is saturated *now* and a
            //     frozen flow sits below the level the candidates
            //     reached — unless it is validly pinned on another
            //     saturated channel (where it is maximal), max-min
            //     fairness says it must rise to the common level.
            for &ci in &involved {
                let cap = net.cap_by_idx(ci);
                let bg = self.chan_frozen_load_snapshot(ci, gen);
                let old_total = bg + self.chan_old_cand[ci];
                let mut new_cand = 0.0;
                let mut max_cand = 0.0f64;
                let mut has_frozen = false;
                for k in 0..self.by_channel[ci].len() {
                    let fid = self.by_channel[ci][k];
                    if self.flows[fid].in_component == gen {
                        new_cand += self.flows[fid].rate;
                        max_cand = max_cand.max(self.flows[fid].rate);
                    } else {
                        has_frozen = true;
                    }
                }
                if !has_frozen {
                    continue; // all flows here are already candidates
                }
                if old_total >= cap - 1e-6 * cap.max(1.0)
                    && new_cand < self.chan_old_cand[ci] - 1e-7 * self.chan_old_cand[ci].max(1.0)
                {
                    absorb.push(ci); // trigger (b)
                    continue;
                }
                if bg + new_cand < cap - 1e-6 * cap.max(1.0) {
                    continue; // unsaturated now: pins nobody (c)
                }
                for k in 0..self.by_channel[ci].len() {
                    let fid = self.by_channel[ci][k];
                    if self.flows[fid].in_component == gen {
                        continue;
                    }
                    if self.flows[fid].rate >= max_cand - 1e-6 * max_cand.max(1.0) - 1e-9 {
                        continue;
                    }
                    if !self.pinned_elsewhere(net, fid, ci) {
                        absorb.push(ci); // trigger (c)
                        break;
                    }
                }
            }

            if absorb.is_empty() {
                break; // converged
            }
            // Enlarge the candidate set with every frozen flow on the
            // flagged channels and re-solve.
            let mut grew = false;
            for a in 0..absorb.len() {
                let ci = absorb[a];
                for k in 0..self.by_channel[ci].len() {
                    let fid = self.by_channel[ci][k];
                    if self.flows[fid].in_component != gen && self.flows[fid].in_component != cgen
                    {
                        grew = true;
                        self.flows[fid].in_component = cgen;
                        cands.push(fid);
                        cand_old.push(self.flows[fid].rate);
                    }
                }
            }
            // Re-stamp existing candidates so the cgen membership test
            // above stays valid next round.
            for &fid in &cands {
                self.flows[fid].in_component = cgen;
            }
            if !grew {
                break; // flagged flows were already candidates
            }
            self.stats.absorb_restarts += 1;
        }
        self.touched = cands;
    }

    /// True if the flow has a saturated channel other than `skip_ci`
    /// where it is maximal — a valid max-min bottleneck that justifies
    /// its current rate (used by absorption trigger (c) to avoid
    /// absorbing flows that provably cannot rise).
    fn pinned_elsewhere(&self, net: &SimNet, fid: FlowId, skip_ci: usize) -> bool {
        let rate = self.flows[fid].rate;
        for c in &self.flows[fid].channels {
            let d = c.idx();
            if d == skip_ci {
                continue;
            }
            let mut load = 0.0;
            let mut mx = 0.0f64;
            for &other in &self.by_channel[d] {
                let r = self.flows[other].rate;
                load += r;
                mx = mx.max(r);
            }
            let cap = net.cap_by_idx(d);
            if load >= cap * (1.0 - 1e-6) - 1e-9 && rate >= mx - 1e-6 * mx.max(1.0) - 1e-9 {
                return true;
            }
        }
        false
    }

    /// Background (frozen non-candidate) load of channel `ci` as
    /// initialized for generation `gen`. `chan_frozen_load` accumulates
    /// frozen *candidate* rates during the fill, so recompute the
    /// background from the inverted index.
    fn chan_frozen_load_snapshot(&self, ci: usize, gen: u64) -> f64 {
        let mut bg = 0.0;
        for &fid in &self.by_channel[ci] {
            if self.flows[fid].in_component != gen {
                bg += self.flows[fid].rate;
            }
        }
        bg
    }

    /// Water-filling driven by the saturation heap over `members`, whose
    /// channels must already be stamped with `gen` and initialized
    /// (`chan_occ = 0`, `chan_frozen_load` = background load). If
    /// `absorb` is given, channels that bind while carrying a frozen
    /// non-member with a higher rate are recorded (absorption trigger a).
    fn fill(
        &mut self,
        net: &SimNet,
        members: &[FlowId],
        gen: u64,
        mut absorb: Option<&mut Vec<usize>>,
    ) {
        // ---- freeze dead-channel flows at 0, count multiplicities -----
        let mut unfrozen = 0usize;
        for &fid in members {
            let blocked = self.flows[fid]
                .channels
                .iter()
                .any(|&c| net.capacity(c) <= 0.0);
            if blocked {
                self.flows[fid].rate = 0.0;
                self.flows[fid].frozen_at = gen;
            } else {
                unfrozen += 1;
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    self.chan_occ[cj] += 1;
                }
            }
        }

        // ---- seed the heap over the members' channels -----------------
        let mut heap: BinaryHeap<Sat> = BinaryHeap::new();
        for &fid in members {
            for j in 0..self.flows[fid].channels.len() {
                let ci = self.flows[fid].channels[j].idx();
                // First touch per channel: bump the version so any stale
                // entries from earlier solves die, then push one entry.
                if self.chan_seeded[ci] != gen {
                    self.chan_seeded[ci] = gen;
                    self.chan_ver[ci] = self.chan_ver[ci].wrapping_add(1);
                    if self.chan_occ[ci] > 0 {
                        heap.push(Sat {
                            fill: (net.cap_by_idx(ci) - self.chan_frozen_load[ci])
                                / self.chan_occ[ci] as f64,
                            ch: ci,
                            ver: self.chan_ver[ci],
                        });
                    }
                }
            }
        }

        let mut fill = 0.0f64;
        while unfrozen > 0 {
            let Some(top) = heap.pop() else {
                // Defensive: should be unreachable (every unfrozen flow
                // keeps a live heap entry on each of its channels).
                break;
            };
            let ci = top.ch;
            if top.ver != self.chan_ver[ci] || self.chan_occ[ci] == 0 {
                continue; // lazily-deleted stale entry
            }
            fill = top.fill.max(fill).max(0.0);

            // Absorption trigger (a): a frozen non-member on the binding
            // channel with a higher rate lacks a valid bottleneck here —
            // it may have to fall; the caller must re-solve with it.
            if let Some(out) = absorb.as_mut() {
                for k in 0..self.by_channel[ci].len() {
                    let fid = self.by_channel[ci][k];
                    if self.flows[fid].in_component != gen
                        && self.flows[fid].rate > fill * (1.0 + 1e-6) + 1e-9
                    {
                        out.push(ci);
                        break;
                    }
                }
            }

            // Freeze every unfrozen member crossing the binding channel.
            // Collect first (freezing mutates channel state), marking
            // `frozen_at` during collection so a flow crossing this
            // channel twice dedups in O(1) instead of a Vec scan.
            let mut to_freeze: Vec<FlowId> = Vec::new();
            for k in 0..self.by_channel[ci].len() {
                let fid = self.by_channel[ci][k];
                if self.flows[fid].in_component == gen && self.flows[fid].frozen_at != gen {
                    self.flows[fid].frozen_at = gen;
                    to_freeze.push(fid);
                }
            }
            for fid in to_freeze {
                self.flows[fid].rate = fill;
                unfrozen -= 1;
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    self.chan_occ[cj] -= 1;
                    self.chan_frozen_load[cj] += fill;
                    self.chan_ver[cj] = self.chan_ver[cj].wrapping_add(1);
                    if self.chan_occ[cj] > 0 {
                        heap.push(Sat {
                            fill: ((net.cap_by_idx(cj) - self.chan_frozen_load[cj])
                                / self.chan_occ[cj] as f64)
                                .max(fill),
                            ch: cj,
                            ver: self.chan_ver[cj],
                        });
                    }
                }
            }
        }
        debug_assert_eq!(unfrozen, 0, "water-filling left unfrozen flows");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::{CableClass, LinkId, Topology};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn k4() -> Topology {
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn single_flow_gets_bottleneck() {
        let t = k4();
        let net = SimNet::new(&t);
        let chans = [Channel::forward(LinkId(0))];
        let rates = max_min_rates(&net, &[&chans]);
        assert!((rates[0] - 50.0).abs() < 1e-6); // x8 × 6.25
    }

    #[test]
    fn two_flows_share_equally() {
        let t = k4();
        let net = SimNet::new(&t);
        let chans = [Channel::forward(LinkId(0))];
        let rates = max_min_rates(&net, &[&chans, &chans]);
        assert!((rates[0] - 25.0).abs() < 1e-6);
        assert!((rates[1] - 25.0).abs() < 1e-6);
    }

    #[test]
    fn bottlenecked_flow_frees_capacity_elsewhere() {
        let t = k4();
        let net = SimNet::new(&t);
        // f0 crosses links 0 and 1; f1 crosses link 0; f2 crosses link 1.
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let f0 = [c0, c1];
        let f1 = [c0];
        let f2 = [c1];
        let r = max_min_rates(&net, &[&f0, &f1, &f2]);
        // Max-min: all equal at 25 (both links split 50/50).
        assert!((r[0] - 25.0).abs() < 1e-6, "{r:?}");
        // Now remove f2: f0 still bottlenecked by link0 share, f1 gets 25.
        let r2 = max_min_rates(&net, &[&f0, &f1]);
        assert!((r2[0] - 25.0).abs() < 1e-6);
        assert!((r2[1] - 25.0).abs() < 1e-6);
    }

    #[test]
    fn failed_channel_zeroes_flows() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.fail_link(LinkId(0));
        let chans = [Channel::forward(LinkId(0))];
        let r = max_min_rates(&net, &[&chans]);
        assert_eq!(r[0], 0.0);
    }

    #[test]
    fn rates_never_exceed_capacity() {
        let t = k4();
        let net = SimNet::new(&t);
        forall("max-min respects capacity", 64, |rng: &mut Rng| {
            let nflows = rng.range(1, 20);
            let flows: Vec<Vec<Channel>> = (0..nflows)
                .map(|_| {
                    let nhops = rng.range(1, 4);
                    (0..nhops)
                        .map(|_| Channel {
                            link: LinkId(rng.range(0, t.link_count()) as u32),
                            rev: rng.chance(0.5),
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
            let rates = max_min_rates(&net, &refs);
            // Per-channel sum ≤ capacity.
            let mut load = vec![0.0; net.channel_count()];
            for (i, f) in flows.iter().enumerate() {
                // a flow crossing the same channel twice counts twice
                for c in f {
                    load[c.idx()] += rates[i];
                }
            }
            for (ci, &l) in load.iter().enumerate() {
                let cap = net.cap_by_idx(ci);
                assert!(l <= cap * (1.0 + 1e-6) + 1e-9, "ch {ci}: {l} > {cap}");
            }
            // Work conservation: every flow with all-live channels gets > 0.
            for (i, _f) in flows.iter().enumerate() {
                assert!(rates[i] > 0.0);
            }
        });
    }

    #[test]
    fn indexed_solver_matches_naive_oracle() {
        let t = k4();
        let net = SimNet::new(&t);
        forall("indexed vs naive", 128, |rng: &mut Rng| {
            let nflows = rng.range(1, 24);
            let flows: Vec<Vec<Channel>> = (0..nflows)
                .map(|_| {
                    (0..rng.range(1, 5))
                        .map(|_| Channel {
                            link: LinkId(rng.range(0, t.link_count()) as u32),
                            rev: rng.chance(0.5),
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
            let fast = max_min_rates(&net, &refs);
            let slow = naive_max_min_rates(&net, &refs);
            for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6 * b.max(1.0),
                    "flow {i}: fast {a} vs naive {b}"
                );
            }
        });
    }

    #[test]
    fn incremental_remove_matches_fresh_solve() {
        let t = k4();
        let net = SimNet::new(&t);
        let c0 = [Channel::forward(LinkId(0))];
        let c01 = [Channel::forward(LinkId(0)), Channel::forward(LinkId(1))];
        let c1 = [Channel::forward(LinkId(1))];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&c01, &c0, &c1]);
        assert!((r.rate(ids[0]) - 25.0).abs() < 1e-6);
        // Remove the link-1-only flow: the shared flow is still capped by
        // link 0's 50/50 split, and the link-0 flow keeps 25.
        r.remove_flows(&net, &[ids[2]]);
        let fresh = max_min_rates(&net, &[&c01, &c0]);
        assert!((r.rate(ids[0]) - fresh[0]).abs() < 1e-9);
        assert!((r.rate(ids[1]) - fresh[1]).abs() < 1e-9);
    }

    #[test]
    fn disjoint_components_are_untouched() {
        let t = k4();
        let net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let b = [Channel::forward(LinkId(3))];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&a, &a, &b]);
        let before = r.rate(ids[2]);
        r.remove_flows(&net, &[ids[0]]);
        // The link-3 component was not part of the change.
        assert!(!r.touched().contains(&ids[2]));
        assert_eq!(r.rate(ids[2]), before);
        // And the surviving link-0 flow reclaims the full link.
        assert!((r.rate(ids[1]) - 50.0).abs() < 1e-6);
    }

    #[test]
    fn flow_ids_are_recycled() {
        let t = k4();
        let net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let mut r = Rates::new();
        let first = r.add_flows(&net, &[&a]);
        r.remove_flows(&net, &first);
        let second = r.add_flows(&net, &[&a]);
        assert_eq!(first, second, "freed slot should be reused");
        assert!((r.rate(second[0]) - 50.0).abs() < 1e-6);
    }

    /// The classic non-monotone removal chain (absorption trigger a):
    /// freeing `a` lets `b` rise on link 0, which *steals* from `c` on
    /// link 1 — c must fall from 95 to 90 even though only a was removed.
    #[test]
    fn removal_fall_chain_is_absorbed() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), 10.0);
        net.set_link_capacity(LinkId(1), 100.0);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let fa = [c0];
        let fb = [c0, c1];
        let fc = [c1];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&fa, &fb, &fc]);
        assert!((r.rate(ids[0]) - 5.0).abs() < 1e-9);
        assert!((r.rate(ids[1]) - 5.0).abs() < 1e-9);
        assert!((r.rate(ids[2]) - 95.0).abs() < 1e-9);
        r.remove_flows(&net, &[ids[0]]);
        assert!((r.rate(ids[1]) - 10.0).abs() < 1e-9, "{}", r.rate(ids[1]));
        assert!((r.rate(ids[2]) - 90.0).abs() < 1e-9, "{}", r.rate(ids[2]));
        assert!(r.stats().absorb_restarts >= 1, "chain must trigger absorb");
    }

    /// The two-step chain (absorption triggers a then b): removing `a`
    /// lets `b` rise, which makes `c` fall on their shared link, which
    /// frees capacity for `g` to *rise* on a third link.
    #[test]
    fn removal_rise_chain_is_absorbed() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), 10.0);
        net.set_link_capacity(LinkId(1), 60.0);
        net.set_link_capacity(LinkId(2), 120.0);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let c2 = Channel::forward(LinkId(2));
        let fa = [c0];
        let fb = [c0, c1];
        let fc = [c1, c2];
        let fg = [c2];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&fa, &fb, &fc, &fg]);
        assert!((r.rate(ids[2]) - 55.0).abs() < 1e-9);
        assert!((r.rate(ids[3]) - 65.0).abs() < 1e-9);
        r.remove_flows(&net, &[ids[0]]);
        let fresh = max_min_rates(&net, &[&fb, &fc, &fg]);
        assert!((r.rate(ids[1]) - fresh[0]).abs() < 1e-9, "b {}", r.rate(ids[1]));
        assert!((r.rate(ids[2]) - fresh[1]).abs() < 1e-9, "c {}", r.rate(ids[2]));
        assert!((r.rate(ids[3]) - fresh[2]).abs() < 1e-9, "g {}", r.rate(ids[3]));
        assert!((r.rate(ids[3]) - 70.0).abs() < 1e-9, "g must rise to 70");
    }

    /// Both strategies agree through an add/remove sequence, and the
    /// rise-only strategy does strictly less re-solve work on a
    /// many-component workload.
    #[test]
    fn strategies_agree_and_rise_only_is_narrower() {
        let t = k4();
        let net = SimNet::new(&t);
        // Two independent bottleneck groups + one bridging flow.
        let chans: Vec<[Channel; 1]> =
            (0..6).map(|l| [Channel::forward(LinkId(l))]).collect();
        let bridge = [Channel::forward(LinkId(0)), Channel::forward(LinkId(5))];
        let mut rise = Rates::new();
        let mut bfs = Rates::with_strategy(ResolveStrategy::FullComponentBfs);
        let mut specs: Vec<&[Channel]> = chans.iter().map(|c| c.as_slice()).collect();
        specs.push(&bridge);
        let ids_r = rise.add_flows(&net, &specs);
        let ids_b = bfs.add_flows(&net, &specs);
        for (&a, &b) in ids_r.iter().zip(&ids_b) {
            assert!((rise.rate(a) - bfs.rate(b)).abs() < 1e-9);
        }
        // Remove the link-0 flow: only the bridge (its channel-mate) can
        // change; the link-5 flow keeps its share.
        rise.remove_flows(&net, &[ids_r[0]]);
        bfs.remove_flows(&net, &[ids_b[0]]);
        for k in [1usize, 2, 3, 4, 5, 6] {
            assert!(
                (rise.rate(ids_r[k]) - bfs.rate(ids_b[k])).abs() < 1e-9,
                "flow {k}"
            );
        }
        // Rise-only recomputed just the bridge (1 flow); the BFS solver
        // re-walked the whole bridged component (bridge + link-5 flow).
        assert_eq!(rise.touched(), &[ids_r[6]][..]);
        assert!(
            rise.stats().rate_recomputes < bfs.stats().rate_recomputes,
            "rise {} vs bfs {}",
            rise.stats().rate_recomputes,
            bfs.stats().rate_recomputes
        );
    }

    /// Union-find split reclamation: enough multi-channel removals
    /// trigger a component rebuild that separates the halves again.
    #[test]
    fn lazy_rebuild_splits_components() {
        let t = k4();
        let net = SimNet::new(&t);
        let left = [Channel::forward(LinkId(0))];
        let right = [Channel::forward(LinkId(5))];
        let bridge = [Channel::forward(LinkId(0)), Channel::forward(LinkId(5))];
        let mut r = Rates::new();
        let l = r.add_flows(&net, &[&left])[0];
        let rt = r.add_flows(&net, &[&right])[0];
        // Repeatedly add and remove bridging flows: every removal is a
        // potential split; the counters must eventually trigger a
        // rebuild instead of letting the merged component persist.
        for _ in 0..24 {
            let b = r.add_flows(&net, &[&bridge]);
            r.remove_flows(&net, &b);
        }
        assert!(r.stats().uf_rebuilds >= 1, "rebuild never fired");
        // Rates stay exact throughout.
        assert!((r.rate(l) - 50.0).abs() < 1e-6);
        assert!((r.rate(rt) - 50.0).abs() < 1e-6);
    }

    /// The mirror of `removal_fall_chain_is_absorbed` (fall-only add,
    /// absorption triggers a then b): adding `a` on link 0 forces `b`
    /// to *fall* from 10 to 5, which frees link-1 capacity and lets `c`
    /// *rise* from 90 to 95 — even though only one flow was added.
    #[test]
    fn addition_fall_chain_is_absorbed() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), 10.0);
        net.set_link_capacity(LinkId(1), 100.0);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let fb = [c0, c1];
        let fc = [c1];
        let mut r = Rates::new();
        assert_eq!(r.strategy(), ResolveStrategy::Bounded);
        let ids = r.add_flows(&net, &[&fb, &fc]);
        assert!((r.rate(ids[0]) - 10.0).abs() < 1e-9);
        assert!((r.rate(ids[1]) - 90.0).abs() < 1e-9);
        let fa = [c0];
        let a = r.add_flows(&net, &[&fa])[0];
        assert!((r.rate(a) - 5.0).abs() < 1e-9, "{}", r.rate(a));
        assert!((r.rate(ids[0]) - 5.0).abs() < 1e-9, "{}", r.rate(ids[0]));
        assert!((r.rate(ids[1]) - 95.0).abs() < 1e-9, "{}", r.rate(ids[1]));
        assert!(
            r.stats().add_absorb_restarts >= 1,
            "add chain must trigger absorb"
        );
    }

    /// The three-link mirror of `removal_rise_chain_is_absorbed`:
    /// adding `a` makes `b` fall on their shared link, which lets `c`
    /// rise on link 1, which in turn steals from `g` on link 2 — a
    /// fall → rise → fall chain through all three triggers.
    #[test]
    fn addition_rise_chain_is_absorbed() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), 10.0);
        net.set_link_capacity(LinkId(1), 60.0);
        net.set_link_capacity(LinkId(2), 120.0);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let c2 = Channel::forward(LinkId(2));
        let fb = [c0, c1];
        let fc = [c1, c2];
        let fg = [c2];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&fb, &fc, &fg]);
        assert!((r.rate(ids[0]) - 10.0).abs() < 1e-9);
        assert!((r.rate(ids[1]) - 50.0).abs() < 1e-9);
        assert!((r.rate(ids[2]) - 70.0).abs() < 1e-9);
        let fa = [c0];
        let a = r.add_flows(&net, &[&fa])[0];
        let fresh = max_min_rates(&net, &[&fb, &fc, &fg, &fa]);
        assert!((r.rate(ids[0]) - fresh[0]).abs() < 1e-9, "b {}", r.rate(ids[0]));
        assert!((r.rate(ids[1]) - fresh[1]).abs() < 1e-9, "c {}", r.rate(ids[1]));
        assert!((r.rate(ids[2]) - fresh[2]).abs() < 1e-9, "g {}", r.rate(ids[2]));
        assert!((r.rate(a) - fresh[3]).abs() < 1e-9, "a {}", r.rate(a));
        assert!((r.rate(ids[1]) - 55.0).abs() < 1e-9, "c must rise to 55");
        assert!((r.rate(ids[2]) - 65.0).abs() < 1e-9, "g must fall to 65");
    }

    /// A fall-only add re-solves only the chains reachable from the new
    /// flow's channels, not the whole component — the add-path
    /// counters record both the actual and the full-component work.
    #[test]
    fn bounded_add_is_narrower_than_full_component() {
        let t = k4();
        let mut net = SimNet::new(&t);
        // `left` is pinned at 10 by its private link 3, so the add-side
        // chain (bridge/right on link 5) never reaches it even though
        // all four flows share one union-find component via link 0.
        net.set_link_capacity(LinkId(3), 10.0);
        let left = [Channel::forward(LinkId(3)), Channel::forward(LinkId(0))];
        let right = [Channel::forward(LinkId(5))];
        let bridge = [Channel::forward(LinkId(0)), Channel::forward(LinkId(5))];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&left, &left, &right, &bridge]);
        assert!((r.rate(ids[0]) - 5.0).abs() < 1e-9);
        r.reset_stats();
        // New flow on link 5: only the right/bridge chain can change;
        // the two pinned left flows keep their rates, untouched.
        let x = r.add_flows(&net, &[&right])[0];
        assert!(!r.touched().contains(&ids[0]), "left flow must stay frozen");
        assert!(!r.touched().contains(&ids[1]), "left flow must stay frozen");
        let fresh = max_min_rates(&net, &[&left, &left, &right, &bridge, &right]);
        for (got, want) in [ids[0], ids[1], ids[2], ids[3], x].iter().zip(&fresh) {
            assert!((r.rate(*got) - want).abs() <= 1e-9, "{} vs {want}", r.rate(*got));
        }
        let s = r.stats();
        assert_eq!(s.add_resolves, 1);
        assert_eq!(s.add_full_component_recomputes, 5, "component live count");
        assert!(
            s.add_rate_recomputes < s.add_full_component_recomputes,
            "bounded add did {} recomputes, full component would do {}",
            s.add_rate_recomputes,
            s.add_full_component_recomputes
        );
        // The add-path slices stayed within the aggregates.
        assert!(s.add_rate_recomputes <= s.rate_recomputes);
        assert!(s.add_full_component_recomputes <= s.full_component_recomputes);
        assert_eq!(s.add_recompute_ratio().map(|r| r >= 1.0), Some(true));
    }

    /// Mid-run capacity loss pins crossing flows at 0; restore revives
    /// them — the fail/restore round-trip through `links_changed`.
    #[test]
    fn link_fail_and_restore_round_trip() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let b = [Channel::forward(LinkId(1))];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&a, &a, &b]);
        assert!((r.rate(ids[0]) - 25.0).abs() < 1e-9);
        net.fail_link(LinkId(0));
        r.links_changed(&net, &[LinkId(0)]);
        assert_eq!(r.rate(ids[0]), 0.0);
        assert_eq!(r.rate(ids[1]), 0.0);
        assert!((r.rate(ids[2]) - 50.0).abs() < 1e-9, "disjoint flow untouched");
        assert!(r.touched().contains(&ids[0]) && r.touched().contains(&ids[1]));
        net.restore_link(LinkId(0));
        r.links_changed(&net, &[LinkId(0)]);
        assert!((r.rate(ids[0]) - 25.0).abs() < 1e-9);
        assert!((r.rate(ids[1]) - 25.0).abs() < 1e-9);
        let s = r.stats();
        assert_eq!(s.cap_resolves, 2);
        assert!(s.cap_rate_recomputes >= 4); // 2 flows × 2 events
        assert!(s.cap_rate_recomputes <= s.rate_recomputes);
    }

    /// Capacity *loss* chain (the fall direction): shrinking link 0 makes
    /// the two-hop flow fall, which de-loads link 1 and lets the frozen
    /// link-1 flow rise — trigger (b) from a constraint change.
    #[test]
    fn capacity_loss_fall_chain_is_absorbed() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), 10.0);
        net.set_link_capacity(LinkId(1), 100.0);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let fb = [c0, c1];
        let fc = [c1];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&fb, &fc]);
        assert!((r.rate(ids[0]) - 10.0).abs() < 1e-9);
        assert!((r.rate(ids[1]) - 90.0).abs() < 1e-9);
        net.set_link_capacity(LinkId(0), 4.0);
        r.links_changed(&net, &[LinkId(0)]);
        assert!((r.rate(ids[0]) - 4.0).abs() < 1e-9, "{}", r.rate(ids[0]));
        assert!((r.rate(ids[1]) - 96.0).abs() < 1e-9, "{}", r.rate(ids[1]));
        assert!(r.stats().cap_absorb_restarts >= 1, "chain must absorb");
    }

    /// Capacity *restore* chain (the rise direction): growing link 0
    /// lets the two-hop flow rise past the frozen link-1 flow's share —
    /// trigger (a) pulls the frozen flow in and it falls.
    #[test]
    fn capacity_gain_rise_chain_is_absorbed() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.set_link_capacity(LinkId(0), 10.0);
        net.set_link_capacity(LinkId(1), 100.0);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let fb = [c0, c1];
        let fc = [c1];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&fb, &fc]);
        net.set_link_capacity(LinkId(0), 80.0);
        r.links_changed(&net, &[LinkId(0)]);
        // Fresh max-min under the new caps: both share link 1 at 50/50.
        assert!((r.rate(ids[0]) - 50.0).abs() < 1e-9, "{}", r.rate(ids[0]));
        assert!((r.rate(ids[1]) - 50.0).abs() < 1e-9, "{}", r.rate(ids[1]));
    }

    /// The oracle strategy handles capacity changes by full-component
    /// re-solve, and both strategies agree with a fresh naive solve.
    #[test]
    fn capacity_change_strategies_match_naive() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let c2 = Channel::forward(LinkId(2));
        let specs: Vec<Vec<Channel>> =
            vec![vec![c0, c1], vec![c1, c2], vec![c0], vec![c2], vec![c1]];
        let refs: Vec<&[Channel]> = specs.iter().map(|f| f.as_slice()).collect();
        let mut bounded = Rates::new();
        let mut bfs = Rates::with_strategy(ResolveStrategy::FullComponentBfs);
        let ids_n = bounded.add_flows(&net, &refs);
        let ids_b = bfs.add_flows(&net, &refs);
        for step in [
            (LinkId(1), 12.0),
            (LinkId(0), 0.0), // dead
            (LinkId(2), 77.0),
            (LinkId(0), 35.0), // revived
        ] {
            let (l, cap) = step;
            if cap == 0.0 {
                net.fail_link(l);
            } else {
                net.restore_link(l);
                net.set_link_capacity(l, cap);
            }
            bounded.links_changed(&net, &[l]);
            bfs.links_changed(&net, &[l]);
            let oracle = naive_max_min_rates(&net, &refs);
            for (k, (&idn, &idb)) in ids_n.iter().zip(&ids_b).enumerate() {
                assert!(
                    (bounded.rate(idn) - oracle[k]).abs() <= 1e-6 * oracle[k].max(1.0),
                    "bounded flow {k}: {} vs naive {}",
                    bounded.rate(idn),
                    oracle[k]
                );
                assert!(
                    (bfs.rate(idb) - oracle[k]).abs() <= 1e-6 * oracle[k].max(1.0),
                    "bfs flow {k}: {} vs naive {}",
                    bfs.rate(idb),
                    oracle[k]
                );
            }
        }
        let s = bounded.stats();
        assert_eq!(s.cap_resolves, 4);
        assert!(s.cap_rate_recomputes <= s.rate_recomputes);
        assert!(s.cap_full_component_recomputes <= s.full_component_recomputes);
        // On a tiny chain-heavy instance the absorption restarts can
        // recount candidates past the one-shot full-component estimate,
        // so only the ratio's existence is asserted here — the 32K
        // scale test pins the large-component win.
        assert!(s.cap_recompute_ratio().is_some());
    }

    /// A capacity change on a channel carrying no flows is a no-op.
    #[test]
    fn capacity_change_on_idle_channel_is_noop() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&a]);
        net.fail_link(LinkId(4));
        r.links_changed(&net, &[LinkId(4)]);
        assert!(r.touched().is_empty());
        assert!((r.rate(ids[0]) - 50.0).abs() < 1e-9);
        assert_eq!(r.stats().cap_rate_recomputes, 0);
    }

    /// Satellite fix: the fallback's counter discounts must saturate
    /// instead of wrapping when the counters were reset (or the
    /// pre-charge undercounted) between charge and discount.
    #[test]
    fn fallback_discount_saturates_at_zero() {
        let mut s = SolverStats::default();
        s.discount_fallback(10);
        assert_eq!(s.resolves, 0, "resolves must clamp, not wrap");
        assert_eq!(s.full_component_recomputes, 0);
        assert_eq!(s.rate_recomputes, 0, "rate recomputes must clamp too");
        assert_eq!(s.fallback_recomputes, 10, "fallback work still booked");
        s.resolves = 2;
        s.full_component_recomputes = 7;
        s.rate_recomputes = 5;
        s.discount_fallback(3);
        assert_eq!(s.resolves, 1);
        assert_eq!(s.full_component_recomputes, 4);
        assert_eq!(s.rate_recomputes, 2);
        assert_eq!(s.fallback_recomputes, 13);
    }

    /// Satellite fix: under a forced-fallback regime the bounded path
    /// performs zero rate recomputes, yet full-component work happens on
    /// every event — the recompute ratios must report it (`Some`, with
    /// the fallback solves in the denominator) instead of "no data",
    /// and the rates must still land on the exact max-min solution.
    #[test]
    fn recompute_ratios_stay_honest_under_forced_fallback() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let fb = [c0, c1];
        let fc = [c1];
        let mut r = Rates::new();
        r.set_max_rise_attempts(0);
        let ids = r.add_flows(&net, &[&fb, &fb, &fc]);
        let fresh = max_min_rates(&net, &[&fb, &fb, &fc]);
        for (id, want) in ids.iter().zip(&fresh) {
            assert!((r.rate(*id) - want).abs() < 1e-9, "{} vs {want}", r.rate(*id));
        }
        let s = r.stats().clone();
        assert!(s.add_fallbacks >= 1, "max_rise_attempts=0 must fall back");
        assert_eq!(s.add_rate_recomputes, 0, "bounded add path did no work");
        assert!(s.add_fallback_recomputes >= 3, "fallback solved the component");
        let ratio = s.add_recompute_ratio().expect("ratio must report fallback work");
        assert!(ratio > 0.0 && ratio.is_finite());

        // Same honesty on the capacity-change path.
        net.set_link_capacity(LinkId(1), 40.0);
        r.links_changed(&net, &[LinkId(1)]);
        let s = r.stats();
        assert!(s.cap_fallbacks >= 1);
        assert_eq!(s.cap_rate_recomputes, 0);
        assert!(s.cap_fallback_recomputes >= 3);
        assert!(s.cap_recompute_ratio().is_some(), "cap ratio must report fallback work");
        let fresh = max_min_rates(&net, &[&fb, &fb, &fc]);
        for (id, want) in ids.iter().zip(&fresh) {
            assert!((r.rate(*id) - want).abs() < 1e-9, "{} vs {want}", r.rate(*id));
        }
    }

    /// Per-worker counter merge: summing split stats reproduces the
    /// aggregate a single solver would have recorded, field by field.
    #[test]
    fn solver_stats_merge_sums_every_field() {
        let t = k4();
        let net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let b = [Channel::forward(LinkId(1))];
        let run = |flows: &[&[Channel]]| -> SolverStats {
            let mut r = Rates::new();
            let ids = r.add_flows(&net, flows);
            r.remove_flows(&net, &ids[..1]);
            r.stats().clone()
        };
        let s1 = run(&[&a, &a]);
        let s2 = run(&[&b, &b, &b]);
        let mut merged = s1.clone();
        merged.merge(&s2);
        assert_eq!(merged.resolves, s1.resolves + s2.resolves);
        assert_eq!(merged.rate_recomputes, s1.rate_recomputes + s2.rate_recomputes);
        assert_eq!(
            merged.full_component_recomputes,
            s1.full_component_recomputes + s2.full_component_recomputes
        );
        assert_eq!(merged.add_resolves, s1.add_resolves + s2.add_resolves);
        assert_eq!(
            merged.add_rate_recomputes,
            s1.add_rate_recomputes + s2.add_rate_recomputes
        );
        assert_eq!(merged.cap_resolves, s1.cap_resolves + s2.cap_resolves);
        assert_eq!(
            merged.fallback_recomputes,
            s1.fallback_recomputes + s2.fallback_recomputes
        );
    }

    /// Single-channel add/remove churn never triggers a split rebuild,
    /// so the bounded add path must compact member lists itself or they
    /// grow without bound.
    #[test]
    fn member_lists_stay_compact_under_churn() {
        let t = k4();
        let net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let mut r = Rates::new();
        let keep = r.add_flows(&net, &[&a])[0];
        for _ in 0..256 {
            let tmp = r.add_flows(&net, &[&a, &a, &a]);
            r.remove_flows(&net, &tmp);
        }
        // Without compaction the list would hold ~768 dead entries; the
        // compaction threshold keeps it within a small constant of the
        // live count (1) regardless of churn length.
        let root = r.uf.find(a[0].idx());
        assert!(
            r.uf.members[root].len() < 64,
            "member list grew to {} for {} live flows",
            r.uf.members[root].len(),
            r.uf.live[root]
        );
        assert!((r.rate(keep) - 50.0).abs() < 1e-6);
    }
}
