//! Max-min fair rate allocation (progressive filling / water-filling).
//!
//! Given active flows and per-channel capacities, all flows' rates grow
//! uniformly until some channel saturates; flows crossing it freeze at
//! the current level, and filling continues for the rest. This is the
//! standard fluid-model allocation used by flow-level DC simulators.

use crate::topology::Channel;

use super::network::SimNet;

/// Compute max-min fair rates (GB/s) for `flows`, where each flow is the
/// list of channels it crosses. Flows crossing a zero-capacity (failed)
/// channel get rate 0.
pub fn max_min_rates(net: &SimNet, flows: &[&[Channel]]) -> Vec<f64> {
    let n = flows.len();
    let mut rate = vec![0.0f64; n];
    if n == 0 {
        return rate;
    }
    let nch = net.channel_count();
    // Channel load bookkeeping. Only channels actually used matter.
    let mut unfrozen_cnt = vec![0u32; nch];
    let mut committed = vec![0.0f64; nch];
    let mut frozen = vec![false; n];

    // Flows over failed channels are stuck at 0.
    for (i, f) in flows.iter().enumerate() {
        if f.iter().any(|&c| net.capacity(c) <= 0.0) {
            frozen[i] = true;
        }
    }
    for (i, f) in flows.iter().enumerate() {
        if !frozen[i] {
            for c in *f {
                unfrozen_cnt[c.idx()] += 1;
            }
        }
    }

    let mut remaining = frozen.iter().filter(|&&f| !f).count();
    let mut fill = 0.0f64; // current uniform fill level
    while remaining > 0 {
        // Find the binding channel: min residual headroom per unfrozen flow.
        let mut delta = f64::INFINITY;
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            for c in *f {
                let ci = c.idx();
                let head =
                    (net.capacity(*c) - committed[ci]) / unfrozen_cnt[ci] as f64;
                if head < delta {
                    delta = head;
                }
            }
        }
        if !delta.is_finite() || delta < 0.0 {
            delta = 0.0;
        }
        fill += delta;
        // Commit the increment.
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            rate[i] = fill;
            for c in *f {
                committed[c.idx()] += delta;
            }
        }
        // Freeze flows on (near-)saturated channels.
        let mut froze_any = false;
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            let saturated = f.iter().any(|&c| {
                let ci = c.idx();
                net.capacity(c) - committed[ci]
                    <= 1e-9 * net.capacity(c).max(1.0)
            });
            if saturated {
                frozen[i] = true;
                froze_any = true;
                remaining -= 1;
                for c in *f {
                    unfrozen_cnt[c.idx()] -= 1;
                }
            }
        }
        if !froze_any {
            // Numerical safety: freeze everything at the current level.
            for (i, _) in flows.iter().enumerate() {
                if !frozen[i] {
                    frozen[i] = true;
                    remaining -= 1;
                }
            }
        }
    }
    rate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::{CableClass, LinkId, Topology};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn k4() -> Topology {
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn single_flow_gets_bottleneck() {
        let t = k4();
        let net = SimNet::new(&t);
        let chans = [Channel::forward(LinkId(0))];
        let rates = max_min_rates(&net, &[&chans]);
        assert!((rates[0] - 50.0).abs() < 1e-6); // x8 × 6.25
    }

    #[test]
    fn two_flows_share_equally() {
        let t = k4();
        let net = SimNet::new(&t);
        let chans = [Channel::forward(LinkId(0))];
        let rates = max_min_rates(&net, &[&chans, &chans]);
        assert!((rates[0] - 25.0).abs() < 1e-6);
        assert!((rates[1] - 25.0).abs() < 1e-6);
    }

    #[test]
    fn bottlenecked_flow_frees_capacity_elsewhere() {
        let t = k4();
        let net = SimNet::new(&t);
        // f0 crosses links 0 and 1; f1 crosses link 0; f2 crosses link 1.
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let f0 = [c0, c1];
        let f1 = [c0];
        let f2 = [c1];
        let r = max_min_rates(&net, &[&f0, &f1, &f2]);
        // Max-min: all equal at 25 (both links split 50/50).
        assert!((r[0] - 25.0).abs() < 1e-6, "{r:?}");
        // Now remove f2: f0 still bottlenecked by link0 share, f1 gets 25.
        let r2 = max_min_rates(&net, &[&f0, &f1]);
        assert!((r2[0] - 25.0).abs() < 1e-6);
        assert!((r2[1] - 25.0).abs() < 1e-6);
    }

    #[test]
    fn failed_channel_zeroes_flows() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.fail_link(LinkId(0));
        let chans = [Channel::forward(LinkId(0))];
        let r = max_min_rates(&net, &[&chans]);
        assert_eq!(r[0], 0.0);
    }

    #[test]
    fn rates_never_exceed_capacity() {
        let t = k4();
        let net = SimNet::new(&t);
        forall("max-min respects capacity", 64, |rng: &mut Rng| {
            let nflows = rng.range(1, 20);
            let flows: Vec<Vec<Channel>> = (0..nflows)
                .map(|_| {
                    let nhops = rng.range(1, 4);
                    (0..nhops)
                        .map(|_| Channel {
                            link: LinkId(rng.range(0, t.link_count()) as u32),
                            rev: rng.chance(0.5),
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
            let rates = max_min_rates(&net, &refs);
            // Per-channel sum ≤ capacity.
            let mut load = vec![0.0; net.channel_count()];
            for (i, f) in flows.iter().enumerate() {
                // a flow crossing the same channel twice counts twice
                for c in f {
                    load[c.idx()] += rates[i];
                }
            }
            for (ci, &l) in load.iter().enumerate() {
                let cap = net.cap_by_idx(ci);
                assert!(l <= cap * (1.0 + 1e-6) + 1e-9, "ch {ci}: {l} > {cap}");
            }
            // Work conservation: every flow with all-live channels gets > 0.
            for (i, _f) in flows.iter().enumerate() {
                assert!(rates[i] > 0.0);
            }
        });
    }
}
