//! Max-min fair rate allocation (progressive filling / water-filling).
//!
//! Given active flows and per-channel capacities, all flows' rates grow
//! uniformly until some channel saturates; flows crossing it freeze at
//! the current level, and filling continues for the rest. This is the
//! standard fluid-model allocation used by flow-level DC simulators.
//!
//! Two implementations live here:
//!
//! * [`naive_max_min_rates`] — the original O(rounds × flows × hops)
//!   scan, retained verbatim as the differential-test oracle.
//! * [`Rates`] — the scalable solver. It keeps a channel→flow inverted
//!   index and drives each filling round from a **saturation heap**: for
//!   a channel `c` with unfrozen multiplicity `k_c` and frozen load
//!   `F_c`, the uniform fill level at which it binds is
//!   `(cap_c − F_c) / k_c`; the heap pops the next binding channel
//!   directly, so a round costs O(hops of the frozen flows × log C)
//!   instead of O(all flows × hops). Heap entries are invalidated lazily
//!   (per-channel version stamps) rather than removed.
//!
//! [`Rates`] is also **incremental**: [`Rates::add_flows`] and
//! [`Rates::remove_flows`] re-solve only the connected component(s) of
//! the flow/channel bipartite graph that the change touches. Flows in
//! other components share no channel with the changed flows — max-min
//! allocations factor across components, so their rates are provably
//! unaffected (the invariant the property tests in
//! `rust/tests/properties.rs` pin down: any add/remove sequence yields
//! the same rates as a from-scratch solve of the surviving flow set).
//!
//! [`max_min_rates`] keeps the original one-shot API as a thin wrapper
//! over [`Rates`].

use std::collections::BinaryHeap;

use crate::topology::Channel;

use super::network::SimNet;

/// Compute max-min fair rates (GB/s) for `flows`, where each flow is the
/// list of channels it crosses. Flows crossing a zero-capacity (failed)
/// channel get rate 0.
pub fn max_min_rates(net: &SimNet, flows: &[&[Channel]]) -> Vec<f64> {
    let mut r = Rates::new();
    let ids = r.add_flows(net, flows);
    ids.iter().map(|&id| r.rate(id)).collect()
}

/// Original from-scratch progressive-filling solver. Quadratic in the
/// worst case; kept as the oracle for the differential tests
/// (`rust/tests/differential_fair.rs`) and for spot-checking the
/// incremental solver from benches.
pub fn naive_max_min_rates(net: &SimNet, flows: &[&[Channel]]) -> Vec<f64> {
    let n = flows.len();
    let mut rate = vec![0.0f64; n];
    if n == 0 {
        return rate;
    }
    let nch = net.channel_count();
    // Channel load bookkeeping. Only channels actually used matter.
    let mut unfrozen_cnt = vec![0u32; nch];
    let mut committed = vec![0.0f64; nch];
    let mut frozen = vec![false; n];

    // Flows over failed channels are stuck at 0.
    for (i, f) in flows.iter().enumerate() {
        if f.iter().any(|&c| net.capacity(c) <= 0.0) {
            frozen[i] = true;
        }
    }
    for (i, f) in flows.iter().enumerate() {
        if !frozen[i] {
            for c in *f {
                unfrozen_cnt[c.idx()] += 1;
            }
        }
    }

    let mut remaining = frozen.iter().filter(|&&f| !f).count();
    let mut fill = 0.0f64; // current uniform fill level
    while remaining > 0 {
        // Find the binding channel: min residual headroom per unfrozen flow.
        let mut delta = f64::INFINITY;
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            for c in *f {
                let ci = c.idx();
                let head =
                    (net.capacity(*c) - committed[ci]) / unfrozen_cnt[ci] as f64;
                if head < delta {
                    delta = head;
                }
            }
        }
        if !delta.is_finite() || delta < 0.0 {
            delta = 0.0;
        }
        fill += delta;
        // Commit the increment.
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            rate[i] = fill;
            for c in *f {
                committed[c.idx()] += delta;
            }
        }
        // Freeze flows on (near-)saturated channels.
        let mut froze_any = false;
        for (i, f) in flows.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            let saturated = f.iter().any(|&c| {
                let ci = c.idx();
                net.capacity(c) - committed[ci]
                    <= 1e-9 * net.capacity(c).max(1.0)
            });
            if saturated {
                frozen[i] = true;
                froze_any = true;
                remaining -= 1;
                for c in *f {
                    unfrozen_cnt[c.idx()] -= 1;
                }
            }
        }
        if !froze_any {
            // Numerical safety: freeze everything at the current level.
            for (i, _) in flows.iter().enumerate() {
                if !frozen[i] {
                    frozen[i] = true;
                    remaining -= 1;
                }
            }
        }
    }
    rate
}

/// Handle of a flow registered in a [`Rates`] solver.
pub type FlowId = usize;

#[derive(Clone, Debug, Default)]
struct FlowState {
    channels: Vec<Channel>,
    rate: f64,
    alive: bool,
    /// Generation stamps (== the solver's current `gen`) marking
    /// membership in the component being re-solved / frozen-ness within
    /// that solve. Stamps avoid O(all flows) clears per solve.
    in_component: u64,
    frozen_at: u64,
}

/// Saturation-heap entry: the fill level at which `ch` binds, valid only
/// while `ver` matches the channel's version (lazy deletion).
struct Sat {
    fill: f64,
    ch: usize,
    ver: u32,
}

impl PartialEq for Sat {
    fn eq(&self, other: &Self) -> bool {
        self.fill == other.fill && self.ch == other.ch
    }
}
impl Eq for Sat {}
impl PartialOrd for Sat {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Sat {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the smallest fill.
        other
            .fill
            .total_cmp(&self.fill)
            .then_with(|| other.ch.cmp(&self.ch))
    }
}

/// Incremental max-min fair solver over a mutable flow set.
///
/// Invariant (after every public call): `rate(id)` of every alive flow
/// equals the max-min fair allocation of the full alive flow set on the
/// network passed to the mutating calls — i.e. incrementality is a pure
/// optimization, never a semantic change.
#[derive(Default)]
pub struct Rates {
    flows: Vec<FlowState>,
    free: Vec<FlowId>,
    /// Channel idx → alive flow ids, one entry per crossing (a flow that
    /// crosses a channel twice appears twice — multiplicity matters for
    /// the fair share, matching the oracle's bookkeeping).
    by_channel: Vec<Vec<FlowId>>,
    /// Flows whose rate may have changed in the last mutating call.
    touched: Vec<FlowId>,

    // ---- per-solve scratch (generation-stamped, never cleared) -------
    gen: u64,
    chan_gen: Vec<u64>,
    chan_occ: Vec<u32>,
    chan_frozen_load: Vec<f64>,
    chan_ver: Vec<u32>,
}

impl Rates {
    pub fn new() -> Rates {
        Rates::default()
    }

    /// Number of alive flows.
    pub fn len(&self) -> usize {
        self.flows.iter().filter(|f| f.alive).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current rate (GB/s) of an alive flow.
    #[inline]
    pub fn rate(&self, id: FlowId) -> f64 {
        debug_assert!(self.flows[id].alive, "rate() on dead flow {id}");
        self.flows[id].rate
    }

    /// Flows whose rate may have changed in the last `add_flows` /
    /// `remove_flows` call (the affected component, including the new
    /// flows themselves). The DAG runner uses this to re-settle only
    /// what moved.
    pub fn touched(&self) -> &[FlowId] {
        &self.touched
    }

    fn ensure_channels(&mut self, upto: usize) {
        if self.by_channel.len() < upto {
            self.by_channel.resize_with(upto, Vec::new);
            self.chan_gen.resize(upto, 0);
            self.chan_occ.resize(upto, 0);
            self.chan_frozen_load.resize(upto, 0.0);
            self.chan_ver.resize(upto, 0);
        }
    }

    /// Register new flows and re-solve the affected component(s).
    /// Returns one [`FlowId`] per input flow, in order.
    pub fn add_flows(&mut self, net: &SimNet, flows: &[&[Channel]]) -> Vec<FlowId> {
        self.ensure_channels(net.channel_count());
        let mut ids = Vec::with_capacity(flows.len());
        let mut dirty: Vec<usize> = Vec::new();
        for chans in flows {
            assert!(!chans.is_empty(), "flow with no channels");
            let id = match self.free.pop() {
                Some(id) => id,
                None => {
                    self.flows.push(FlowState::default());
                    self.flows.len() - 1
                }
            };
            let st = &mut self.flows[id];
            st.channels = chans.to_vec();
            st.rate = 0.0;
            st.alive = true;
            st.in_component = 0;
            st.frozen_at = 0;
            for c in chans.iter() {
                let ci = c.idx();
                debug_assert!(ci < self.by_channel.len(), "channel beyond net");
                self.by_channel[ci].push(id);
                dirty.push(ci);
            }
            ids.push(id);
        }
        self.resolve(net, &dirty);
        ids
    }

    /// Deregister flows and re-solve the affected component(s). Rates of
    /// the removed flows become meaningless; their ids are recycled.
    pub fn remove_flows(&mut self, net: &SimNet, ids: &[FlowId]) {
        let mut dirty: Vec<usize> = Vec::new();
        for &id in ids {
            assert!(self.flows[id].alive, "remove of dead flow {id}");
            self.flows[id].alive = false;
            let channels = std::mem::take(&mut self.flows[id].channels);
            for c in &channels {
                let ci = c.idx();
                // Remove ONE occurrence per crossing.
                let lst = &mut self.by_channel[ci];
                let pos = lst
                    .iter()
                    .position(|&f| f == id)
                    .expect("flow missing from inverted index");
                lst.swap_remove(pos);
                dirty.push(ci);
            }
            self.free.push(id);
        }
        self.resolve(net, &dirty);
    }

    /// Re-solve the union of components reachable from `dirty` channels.
    ///
    /// Correctness: a max-min allocation factors across connected
    /// components of the flow/channel bipartite graph (no shared channel
    /// → no shared constraint), so restricting the water-filling to the
    /// affected component reproduces the global solution for it exactly.
    fn resolve(&mut self, net: &SimNet, dirty: &[usize]) {
        self.touched.clear();
        if dirty.is_empty() {
            return;
        }
        self.gen += 1;
        let gen = self.gen;

        // ---- component discovery: BFS channels ↔ flows ----------------
        let mut chan_stack: Vec<usize> = Vec::new();
        for &ci in dirty {
            if self.chan_gen[ci] != gen {
                self.chan_gen[ci] = gen;
                self.chan_occ[ci] = 0;
                self.chan_frozen_load[ci] = 0.0;
                chan_stack.push(ci);
            }
        }
        let mut member_flows: Vec<FlowId> = Vec::new();
        while let Some(ci) = chan_stack.pop() {
            for k in 0..self.by_channel[ci].len() {
                let fid = self.by_channel[ci][k];
                if self.flows[fid].in_component == gen {
                    continue;
                }
                self.flows[fid].in_component = gen;
                member_flows.push(fid);
                // Borrow dance: clone-free walk over this flow's channels.
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    if self.chan_gen[cj] != gen {
                        self.chan_gen[cj] = gen;
                        self.chan_occ[cj] = 0;
                        self.chan_frozen_load[cj] = 0.0;
                        chan_stack.push(cj);
                    }
                }
            }
        }

        // ---- freeze dead-channel flows at 0, count multiplicities -----
        let mut unfrozen = 0usize;
        for &fid in &member_flows {
            let blocked = self.flows[fid]
                .channels
                .iter()
                .any(|&c| net.capacity(c) <= 0.0);
            if blocked {
                self.flows[fid].rate = 0.0;
                self.flows[fid].frozen_at = gen;
            } else {
                unfrozen += 1;
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    self.chan_occ[cj] += 1;
                }
            }
        }

        // ---- water-filling driven by the saturation heap ---------------
        let mut heap: BinaryHeap<Sat> = BinaryHeap::new();
        let mut seed_channels: Vec<usize> = Vec::new();
        for &fid in &member_flows {
            for c in &self.flows[fid].channels {
                let ci = c.idx();
                if self.chan_occ[ci] > 0 {
                    seed_channels.push(ci);
                }
            }
        }
        seed_channels.sort_unstable();
        seed_channels.dedup();
        for &ci in &seed_channels {
            self.chan_ver[ci] = self.chan_ver[ci].wrapping_add(1);
            if self.chan_occ[ci] > 0 {
                heap.push(Sat {
                    fill: (net.cap_by_idx(ci) - self.chan_frozen_load[ci])
                        / self.chan_occ[ci] as f64,
                    ch: ci,
                    ver: self.chan_ver[ci],
                });
            }
        }

        let mut fill = 0.0f64;
        while unfrozen > 0 {
            let Some(top) = heap.pop() else {
                // Defensive: should be unreachable (every unfrozen flow
                // keeps a live heap entry on each of its channels).
                break;
            };
            let ci = top.ch;
            if top.ver != self.chan_ver[ci] || self.chan_occ[ci] == 0 {
                continue; // lazily-deleted stale entry
            }
            fill = top.fill.max(fill).max(0.0);

            // Freeze every unfrozen flow crossing the binding channel.
            // Collect first (freezing mutates by_channel-adjacent state),
            // marking `frozen_at` during collection so a flow crossing
            // this channel twice dedups in O(1) instead of a Vec scan.
            let mut to_freeze: Vec<FlowId> = Vec::new();
            for k in 0..self.by_channel[ci].len() {
                let fid = self.by_channel[ci][k];
                if self.flows[fid].frozen_at != gen {
                    self.flows[fid].frozen_at = gen;
                    to_freeze.push(fid);
                }
            }
            for fid in to_freeze {
                self.flows[fid].rate = fill;
                unfrozen -= 1;
                for j in 0..self.flows[fid].channels.len() {
                    let cj = self.flows[fid].channels[j].idx();
                    self.chan_occ[cj] -= 1;
                    self.chan_frozen_load[cj] += fill;
                    self.chan_ver[cj] = self.chan_ver[cj].wrapping_add(1);
                    if self.chan_occ[cj] > 0 {
                        heap.push(Sat {
                            fill: ((net.cap_by_idx(cj) - self.chan_frozen_load[cj])
                                / self.chan_occ[cj] as f64)
                                .max(fill),
                            ch: cj,
                            ver: self.chan_ver[cj],
                        });
                    }
                }
            }
        }
        debug_assert_eq!(unfrozen, 0, "water-filling left unfrozen flows");
        self.touched = member_flows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::{CableClass, LinkId, Topology};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn k4() -> Topology {
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn single_flow_gets_bottleneck() {
        let t = k4();
        let net = SimNet::new(&t);
        let chans = [Channel::forward(LinkId(0))];
        let rates = max_min_rates(&net, &[&chans]);
        assert!((rates[0] - 50.0).abs() < 1e-6); // x8 × 6.25
    }

    #[test]
    fn two_flows_share_equally() {
        let t = k4();
        let net = SimNet::new(&t);
        let chans = [Channel::forward(LinkId(0))];
        let rates = max_min_rates(&net, &[&chans, &chans]);
        assert!((rates[0] - 25.0).abs() < 1e-6);
        assert!((rates[1] - 25.0).abs() < 1e-6);
    }

    #[test]
    fn bottlenecked_flow_frees_capacity_elsewhere() {
        let t = k4();
        let net = SimNet::new(&t);
        // f0 crosses links 0 and 1; f1 crosses link 0; f2 crosses link 1.
        let c0 = Channel::forward(LinkId(0));
        let c1 = Channel::forward(LinkId(1));
        let f0 = [c0, c1];
        let f1 = [c0];
        let f2 = [c1];
        let r = max_min_rates(&net, &[&f0, &f1, &f2]);
        // Max-min: all equal at 25 (both links split 50/50).
        assert!((r[0] - 25.0).abs() < 1e-6, "{r:?}");
        // Now remove f2: f0 still bottlenecked by link0 share, f1 gets 25.
        let r2 = max_min_rates(&net, &[&f0, &f1]);
        assert!((r2[0] - 25.0).abs() < 1e-6);
        assert!((r2[1] - 25.0).abs() < 1e-6);
    }

    #[test]
    fn failed_channel_zeroes_flows() {
        let t = k4();
        let mut net = SimNet::new(&t);
        net.fail_link(LinkId(0));
        let chans = [Channel::forward(LinkId(0))];
        let r = max_min_rates(&net, &[&chans]);
        assert_eq!(r[0], 0.0);
    }

    #[test]
    fn rates_never_exceed_capacity() {
        let t = k4();
        let net = SimNet::new(&t);
        forall("max-min respects capacity", 64, |rng: &mut Rng| {
            let nflows = rng.range(1, 20);
            let flows: Vec<Vec<Channel>> = (0..nflows)
                .map(|_| {
                    let nhops = rng.range(1, 4);
                    (0..nhops)
                        .map(|_| Channel {
                            link: LinkId(rng.range(0, t.link_count()) as u32),
                            rev: rng.chance(0.5),
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
            let rates = max_min_rates(&net, &refs);
            // Per-channel sum ≤ capacity.
            let mut load = vec![0.0; net.channel_count()];
            for (i, f) in flows.iter().enumerate() {
                // a flow crossing the same channel twice counts twice
                for c in f {
                    load[c.idx()] += rates[i];
                }
            }
            for (ci, &l) in load.iter().enumerate() {
                let cap = net.cap_by_idx(ci);
                assert!(l <= cap * (1.0 + 1e-6) + 1e-9, "ch {ci}: {l} > {cap}");
            }
            // Work conservation: every flow with all-live channels gets > 0.
            for (i, _f) in flows.iter().enumerate() {
                assert!(rates[i] > 0.0);
            }
        });
    }

    #[test]
    fn indexed_solver_matches_naive_oracle() {
        let t = k4();
        let net = SimNet::new(&t);
        forall("indexed vs naive", 128, |rng: &mut Rng| {
            let nflows = rng.range(1, 24);
            let flows: Vec<Vec<Channel>> = (0..nflows)
                .map(|_| {
                    (0..rng.range(1, 5))
                        .map(|_| Channel {
                            link: LinkId(rng.range(0, t.link_count()) as u32),
                            rev: rng.chance(0.5),
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[Channel]> = flows.iter().map(|f| f.as_slice()).collect();
            let fast = max_min_rates(&net, &refs);
            let slow = naive_max_min_rates(&net, &refs);
            for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6 * b.max(1.0),
                    "flow {i}: fast {a} vs naive {b}"
                );
            }
        });
    }

    #[test]
    fn incremental_remove_matches_fresh_solve() {
        let t = k4();
        let net = SimNet::new(&t);
        let c0 = [Channel::forward(LinkId(0))];
        let c01 = [Channel::forward(LinkId(0)), Channel::forward(LinkId(1))];
        let c1 = [Channel::forward(LinkId(1))];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&c01, &c0, &c1]);
        assert!((r.rate(ids[0]) - 25.0).abs() < 1e-6);
        // Remove the link-1-only flow: the shared flow is still capped by
        // link 0's 50/50 split, and the link-0 flow keeps 25.
        r.remove_flows(&net, &[ids[2]]);
        let fresh = max_min_rates(&net, &[&c01, &c0]);
        assert!((r.rate(ids[0]) - fresh[0]).abs() < 1e-9);
        assert!((r.rate(ids[1]) - fresh[1]).abs() < 1e-9);
    }

    #[test]
    fn disjoint_components_are_untouched() {
        let t = k4();
        let net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let b = [Channel::forward(LinkId(3))];
        let mut r = Rates::new();
        let ids = r.add_flows(&net, &[&a, &a, &b]);
        let before = r.rate(ids[2]);
        r.remove_flows(&net, &[ids[0]]);
        // The link-3 component was not part of the change.
        assert!(!r.touched().contains(&ids[2]));
        assert_eq!(r.rate(ids[2]), before);
        // And the surviving link-0 flow reclaims the full link.
        assert!((r.rate(ids[1]) - 50.0).abs() < 1e-6);
    }

    #[test]
    fn flow_ids_are_recycled() {
        let t = k4();
        let net = SimNet::new(&t);
        let a = [Channel::forward(LinkId(0))];
        let mut r = Rates::new();
        let first = r.add_flows(&net, &[&a]);
        r.remove_flows(&net, &first);
        let second = r.add_flows(&net, &[&a]);
        assert_eq!(first, second, "freed slot should be reused");
        assert!((r.rate(second[0]) - 50.0).abs() < 1e-6);
    }
}
