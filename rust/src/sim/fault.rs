//! Mid-run fault injection: a [`FaultPlan`] timeline of link/NPU events
//! executed inside the [`super::schedule`] event loop, with optional
//! online APR recovery.
//!
//! The paper's availability story (§3.3.2 64+1 backup, §4.2 fast
//! recovery, Fig 12) is *dynamic*: a link dies mid-collective, the
//! control plane converges (hop-by-hop flooding or topology-aware
//! direct notification, [`RecoveryModel`] timing), and affected sources
//! re-select APR paths around the failure instead of stalling the
//! training step. A `FaultPlan` scripts exactly that: capacity changes
//! flow through [`super::fair::Rates::links_changed`] (the bounded
//! mid-run re-solve) and, when a [`RecoveryConfig`] is present, flows
//! cut off by a dead channel are re-routed mid-flight — retired from
//! the solver and respawned with their *remaining* bytes on a surviving
//! path — once the per-link routing tables have converged.
//!
//! Without a `RecoveryConfig` the plan is the *naive bound*: blocked
//! flows stall until a `LinkUp` revives them (or the run ends in the
//! structured stall report, [`super::schedule::SimReport::stalled`]).
//! The measured gap between the recovered run and this bound is the
//! fig12 experiment.

use std::sync::Arc;

use crate::routing::failure::{
    direct_notification_convergence_us, hop_by_hop_convergence_us, RecoveryModel,
};
use crate::topology::{LinkId, NodeId, Topology};

use super::network::SimNet;

/// How routing-table updates reach affected sources after a failure
/// (§4.2, Fig 12).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum NotifyMode {
    /// Link-state flooding: every router on the way adds processing
    /// latency.
    HopByHop,
    /// Topology-aware direct notification: the detecting endpoint
    /// unicasts each affected source (wire latency only per hop).
    Direct,
}

/// Path re-selection policy for flows cut off by a fault.
#[derive(Clone, Default)]
pub enum Reroute {
    /// BFS shortest path over live links — the generic APR reselection
    /// (on a full-mesh tier this finds a direct/detour path; on the
    /// SuperPod Clos tier, a surviving uplink plane).
    #[default]
    Shortest,
    /// Workload-aware selector (e.g.
    /// [`crate::collectives::alltoall::hrs_reroute`], which re-picks
    /// uplink planes via `hrs_plane_pair`; policies holding an APR
    /// [`crate::routing::apr::PathSet`] can prune it with
    /// `PathSet::filter_alive(t, |l| !net.is_usable(l))` — `is_usable`,
    /// not `!is_down`, so zero-capacity rescaled links are pruned too —
    /// before falling back to full reselection). Returns the full node path
    /// src → dst, or `None` if the pair is disconnected.
    Custom(Arc<dyn Fn(&Topology, &SimNet, NodeId, NodeId) -> Option<Vec<NodeId>> + Send + Sync>),
}

impl std::fmt::Debug for Reroute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reroute::Shortest => write!(f, "Shortest"),
            Reroute::Custom(_) => write!(f, "Custom(..)"),
        }
    }
}

impl Reroute {
    /// Resolve a live path for `src → dst` under the current link
    /// state.
    pub fn path(
        &self,
        t: &Topology,
        net: &SimNet,
        src: NodeId,
        dst: NodeId,
        npu_routable: bool,
    ) -> Option<Vec<NodeId>> {
        match self {
            Reroute::Shortest => shortest_alive_path(t, net, src, dst, npu_routable),
            Reroute::Custom(f) => f(t, net, src, dst),
        }
    }
}

/// Online recovery configuration. Present in a [`FaultPlan`], it makes
/// the runner re-route cut-off flows after the control-plane
/// convergence latency of the failed link.
#[derive(Clone, Debug)]
pub struct RecoveryConfig {
    pub model: RecoveryModel,
    pub mode: NotifyMode,
    pub reroute: Reroute,
    /// NPUs may serve as interior forwarding hops on rerouted paths
    /// (they can in UB-Mesh: the UB IO controller routes, §3.3.1).
    /// Applies to the built-in [`Reroute::Shortest`] BFS only — a
    /// [`Reroute::Custom`] selector owns its forwarding rules (e.g.
    /// `hrs_reroute` always routes through the switch tier and uses an
    /// NPU-routable BFS as last resort).
    pub npu_routable: bool,
    /// Flap-damping hysteresis window (µs). When > 0, reroute path
    /// selection first tries to avoid links that went down within the
    /// last `flap_hysteresis_us` — a link that just flapped is likely
    /// to flap again, and rerouting onto it churns the whole fan-out
    /// every cycle ([`crate::routing::failure::FlapDamper`]). Damping
    /// is *advisory*: if no path avoids recently-flapped links, the
    /// undamped selection is used, so damping can never disconnect a
    /// pair the raw policy could route. `0.0` (the default) disables
    /// it.
    pub flap_hysteresis_us: f64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            model: RecoveryModel::default(),
            mode: NotifyMode::Direct,
            reroute: Reroute::Shortest,
            npu_routable: true,
            flap_hysteresis_us: 0.0,
        }
    }
}

impl RecoveryConfig {
    pub fn hop_by_hop() -> RecoveryConfig {
        RecoveryConfig {
            mode: NotifyMode::HopByHop,
            ..RecoveryConfig::default()
        }
    }

    pub fn direct() -> RecoveryConfig {
        RecoveryConfig::default()
    }

    pub fn with_reroute(mut self, reroute: Reroute) -> RecoveryConfig {
        self.reroute = reroute;
        self
    }

    /// Enable flap damping with the given hysteresis window (µs).
    pub fn with_flap_damping(mut self, hysteresis_us: f64) -> RecoveryConfig {
        assert!(
            hysteresis_us.is_finite() && hysteresis_us >= 0.0,
            "hysteresis {hysteresis_us}"
        );
        self.flap_hysteresis_us = hysteresis_us;
        self
    }

    /// Routing-convergence latency (µs) for `failed`, given the sources
    /// whose in-flight flows traverse it — the moment their tables are
    /// updated and rerouting may begin.
    pub fn convergence_us(&self, t: &Topology, failed: LinkId, affected: &[NodeId]) -> f64 {
        match self.mode {
            NotifyMode::HopByHop => hop_by_hop_convergence_us(t, failed, affected, &self.model),
            NotifyMode::Direct => {
                direct_notification_convergence_us(t, failed, affected, &self.model)
            }
        }
    }
}

/// One timeline entry.
#[derive(Clone, Debug)]
pub enum FaultEvent {
    /// Link capacity drops to zero.
    LinkDown(LinkId),
    /// Clears a [`FaultEvent::LinkDown`] failure: capacity returns to
    /// the link's *current configured* value. A
    /// [`FaultEvent::LinkCapacity`] rescale — including a rescale to
    /// zero — persists across `LinkUp`; script another `LinkCapacity`
    /// to lift it.
    LinkUp(LinkId),
    /// Link rescaled (degraded lanes, backup attach with fewer lanes).
    /// A rescale to `0.0` is a failure for recovery purposes: the link
    /// becomes unusable ([`SimNet::is_usable`]) and cut flows re-route
    /// off it instead of endlessly re-selecting a zero-bandwidth path.
    LinkCapacity(LinkId, f64),
    /// NPU death: every link of `npu` goes down (§3.3.2). With
    /// `backup: Some((b, activation_us))`, flows terminating at the
    /// dead NPU are redirected to `b` once it activates,
    /// `activation_us` after this event — the 64+1 substitution.
    NpuDown {
        npu: NodeId,
        backup: Option<(NodeId, f64)>,
    },
}

/// A scripted failure timeline plus the recovery behavior, consumed by
/// [`super::schedule::run_faulted`].
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// `(time µs, event)` — any order; the runner feeds them through
    /// its event heap.
    pub events: Vec<(f64, FaultEvent)>,
    /// Online recovery; `None` = faults only (the stall-until-restore
    /// naive bound).
    pub recovery: Option<RecoveryConfig>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Append an event at `t_us` (builder style). Fails fast on
    /// malformed inputs (negative/NaN times, non-finite or negative
    /// capacities) — a NaN capacity would otherwise flow silently
    /// through the water-fill and poison every downstream rate.
    pub fn at(mut self, t_us: f64, ev: FaultEvent) -> FaultPlan {
        assert!(t_us >= 0.0 && t_us.is_finite(), "fault at t={t_us}");
        match &ev {
            FaultEvent::LinkCapacity(l, gb_s) => {
                assert!(
                    gb_s.is_finite() && *gb_s >= 0.0,
                    "LinkCapacity({l}, {gb_s}): capacity must be finite and ≥ 0"
                );
            }
            FaultEvent::NpuDown {
                npu,
                backup: Some((_, activation_us)),
            } => {
                assert!(
                    activation_us.is_finite() && *activation_us >= 0.0,
                    "NpuDown({npu}): activation delay {activation_us} must be finite and ≥ 0"
                );
            }
            _ => {}
        }
        self.events.push((t_us, ev));
        self
    }

    /// Append a correlated blast-radius *group*: every event lands at the
    /// same `t_us`, in the given order. Same-instant fault events apply
    /// in FaultPlan order (not heap tie order), so a group models one
    /// physical failure with a multi-component blast radius — an LRS
    /// death takes its uplinks in the same instant, a power domain takes
    /// a whole rack — with deterministic intra-group semantics (e.g. a
    /// `NpuDown` backup redirect sees every link of the group already
    /// dead). [`crate::reliability::faultgen`] is the sampler that
    /// produces these groups from the AFR census.
    pub fn group_at(mut self, t_us: f64, events: Vec<FaultEvent>) -> FaultPlan {
        for ev in events {
            self = self.at(t_us, ev);
        }
        self
    }

    /// Append a flap train on `link`: `cycles` down/up pairs starting
    /// at `t0_us`, each cycle `down_us` dead then `up_us` alive — the
    /// marginal-connector fault shape (a cable that bounces instead of
    /// dying clean). The final cycle's `LinkUp` is still emitted, so a
    /// replayed train always ends restored.
    pub fn flap_train(
        mut self,
        link: LinkId,
        t0_us: f64,
        cycles: usize,
        down_us: f64,
        up_us: f64,
    ) -> FaultPlan {
        assert!(cycles > 0, "empty flap train");
        assert!(
            down_us > 0.0 && up_us > 0.0,
            "degenerate flap cycle ({down_us}, {up_us})"
        );
        let mut t = t0_us;
        for _ in 0..cycles {
            self = self.at(t, FaultEvent::LinkDown(link));
            self = self.at(t + down_us, FaultEvent::LinkUp(link));
            t += down_us + up_us;
        }
        self
    }

    pub fn with_recovery(mut self, recovery: RecoveryConfig) -> FaultPlan {
        self.recovery = Some(recovery);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of scripted events.
    pub fn len(&self) -> usize {
        self.events.len()
    }
}

/// BFS shortest path from `src` to `dst` crossing only *usable* links
/// (up and non-zero capacity, [`SimNet::is_usable`]) — the shared
/// [`Topology::shortest_path_filtered`] BFS with the live-link
/// predicate. NPUs are allowed as interior hops iff `npu_routable`.
pub fn shortest_alive_path(
    t: &Topology,
    net: &SimNet,
    src: NodeId,
    dst: NodeId,
    npu_routable: bool,
) -> Option<Vec<NodeId>> {
    t.shortest_path_filtered(src, dst, npu_routable, |l| net.is_usable(l))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::CableClass;

    fn k4() -> Topology {
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn shortest_alive_path_avoids_down_links() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let (a, b) = (t.npus[0], t.npus[1]);
        let direct = t.link_between(a, b).unwrap();
        let p = shortest_alive_path(&t, &net, a, b, true).unwrap();
        assert_eq!(p, vec![a, b]);
        net.fail_link(direct);
        let p = shortest_alive_path(&t, &net, a, b, true).unwrap();
        assert_eq!(p.len(), 3, "detour via a relay: {p:?}");
        assert_ne!(p[1], a);
        assert_ne!(p[1], b);
        // Fully cut: no path.
        for &(_, l) in t.neighbors(a) {
            net.fail_link(l);
        }
        assert!(shortest_alive_path(&t, &net, a, b, true).is_none());
    }

    #[test]
    fn convergence_modes_order() {
        let t = k4();
        let rc_slow = RecoveryConfig::hop_by_hop();
        let rc_fast = RecoveryConfig::direct();
        let l = t.link_between(t.npus[0], t.npus[1]).unwrap();
        // A source 2+ hops from the failure must hear about it later
        // under flooding than under direct notification.
        let affected = vec![t.npus[2], t.npus[3]];
        let slow = rc_slow.convergence_us(&t, l, &affected);
        let fast = rc_fast.convergence_us(&t, l, &affected);
        assert!(slow >= fast, "hop-by-hop {slow} vs direct {fast}");
    }

    #[test]
    fn group_at_shares_one_timestamp_in_plan_order() {
        let plan = FaultPlan::new()
            .at(5.0, FaultEvent::LinkDown(LinkId(0)))
            .group_at(
                20.0,
                vec![
                    FaultEvent::LinkDown(LinkId(1)),
                    FaultEvent::LinkDown(LinkId(2)),
                    FaultEvent::NpuDown {
                        npu: NodeId(0),
                        backup: None,
                    },
                ],
            );
        assert_eq!(plan.len(), 4);
        let group: Vec<_> = plan
            .events
            .iter()
            .filter(|(t, _)| *t == 20.0)
            .collect();
        assert_eq!(group.len(), 3);
        // Plan order is preserved within the group — the same-instant
        // application rule makes this the execution order.
        assert!(matches!(group[0].1, FaultEvent::LinkDown(LinkId(1))));
        assert!(matches!(group[1].1, FaultEvent::LinkDown(LinkId(2))));
        assert!(matches!(group[2].1, FaultEvent::NpuDown { .. }));
    }

    #[test]
    fn flap_train_alternates_and_ends_up() {
        let plan = FaultPlan::new().flap_train(LinkId(7), 10.0, 3, 5.0, 20.0);
        assert_eq!(plan.len(), 6);
        for (i, (t, ev)) in plan.events.iter().enumerate() {
            let cycle = (i / 2) as f64;
            if i % 2 == 0 {
                assert!(matches!(ev, FaultEvent::LinkDown(LinkId(7))));
                assert_eq!(*t, 10.0 + cycle * 25.0);
            } else {
                assert!(matches!(ev, FaultEvent::LinkUp(LinkId(7))));
                assert_eq!(*t, 15.0 + cycle * 25.0);
            }
        }
        // The train ends restored.
        assert!(matches!(plan.events.last().unwrap().1, FaultEvent::LinkUp(_)));
    }

    #[test]
    fn flap_damping_knob_round_trips() {
        let rc = RecoveryConfig::direct();
        assert_eq!(rc.flap_hysteresis_us, 0.0);
        let rc = rc.with_flap_damping(500.0);
        assert_eq!(rc.flap_hysteresis_us, 500.0);
    }

    #[test]
    fn fault_plan_builder() {
        let plan = FaultPlan::new()
            .at(10.0, FaultEvent::LinkDown(LinkId(3)))
            .at(50.0, FaultEvent::LinkUp(LinkId(3)))
            .with_recovery(RecoveryConfig::direct());
        assert_eq!(plan.events.len(), 2);
        assert!(plan.recovery.is_some());
        assert!(!plan.is_empty());
        assert!(FaultPlan::new().is_empty());
    }
}
