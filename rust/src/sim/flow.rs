//! Flow descriptions handed to the simulator.

use crate::topology::{Channel, NodeId, Topology};

/// One unidirectional data transfer along a fixed path.
#[derive(Clone, Debug)]
pub struct FlowSpec {
    pub src: NodeId,
    pub dst: NodeId,
    /// Payload bytes.
    pub bytes: f64,
    /// Directed channels from src to dst.
    pub channels: Vec<Channel>,
    /// Path latency (µs) charged before bytes start draining: per-hop
    /// wire + switch latency + the α message overhead.
    pub latency_us: f64,
}

impl FlowSpec {
    /// Build a flow along a node path, deriving channels and latency
    /// from the topology.
    pub fn along(t: &Topology, path: &[NodeId], bytes: f64) -> FlowSpec {
        assert!(path.len() >= 2, "flow needs at least one hop");
        let mut channels = Vec::with_capacity(path.len() - 1);
        let mut latency = crate::topology::ublink::MESSAGE_ALPHA_US;
        for w in path.windows(2) {
            let l = t
                .link_between(w[0], w[1])
                .unwrap_or_else(|| panic!("flow hop {}-{} not adjacent", w[0], w[1]));
            let link = t.link(l);
            channels.push(Channel {
                link: l,
                rev: link.a != w[0],
            });
            latency += link.latency_us();
            if t.node(w[1]).kind.is_switch() {
                latency += crate::topology::ublink::SWITCH_LATENCY_US;
            }
        }
        FlowSpec {
            src: path[0],
            dst: *path.last().unwrap(),
            bytes,
            channels,
            latency_us: latency,
        }
    }

    /// Split this flow across several node paths with the given weights
    /// (APR multi-path transmission).
    pub fn split(
        t: &Topology,
        paths: &[Vec<NodeId>],
        weights: &[f64],
        bytes: f64,
    ) -> Vec<FlowSpec> {
        assert_eq!(paths.len(), weights.len());
        let total: f64 = weights.iter().sum();
        paths
            .iter()
            .zip(weights)
            .filter(|&(_, &w)| w > 0.0)
            .map(|(p, &w)| FlowSpec::along(t, p, bytes * w / total))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::CableClass;

    fn mesh() -> Topology {
        nd_fullmesh(
            "m44",
            &[
                DimSpec::new(4, 4, CableClass::PassiveElectrical, 0.3),
                DimSpec::new(4, 4, CableClass::PassiveElectrical, 1.0),
            ],
        )
    }

    #[test]
    fn along_derives_channels_and_latency() {
        let t = mesh();
        let f = FlowSpec::along(&t, &[NodeId(0), NodeId(1), NodeId(5)], 1e6);
        assert_eq!(f.channels.len(), 2);
        assert!(f.latency_us > 0.0);
    }

    #[test]
    fn split_conserves_bytes() {
        let t = mesh();
        let paths = vec![
            vec![NodeId(0), NodeId(1), NodeId(5)],
            vec![NodeId(0), NodeId(4), NodeId(5)],
        ];
        let flows = FlowSpec::split(&t, &paths, &[0.5, 0.5], 1e6);
        let total: f64 = flows.iter().map(|f| f.bytes).sum();
        assert!((total - 1e6).abs() < 1e-6);
    }
}
