//! Stage-DAG execution over the fluid-flow simulator.
//!
//! A [`StageDag`] models a collective or a whole training iteration:
//! each [`Stage`] holds flows plus an optional local compute duration,
//! and starts when all its dependencies complete.
//!
//! The runner is event-driven around a **binary-heap event queue**
//! (gate openings, flow completions, compute completions) with lazy
//! deletion: a flow completion is predicted from its current rate and
//! stamped; when rates change the stamp is bumped and the stale heap
//! entry is simply skipped on pop, so rate changes never force a queue
//! rebuild. Rates come from the incremental [`Rates`] solver: at each
//! event batch only the affected flows are re-solved and only flows the
//! solver reports as touched are re-settled (their drained bytes
//! accounted at the old rate before the new rate applies). Events that
//! land at the same instant are processed as one batch — a single
//! remove/add pair on the solver — which keeps symmetric collectives
//! (all flows of a phase finishing together) linear instead of
//! quadratic. Under the default [`ResolveStrategy::Bounded`] both
//! halves of that pair are bounded re-solves: the removal runs the
//! rise-only re-solve and the **gate-open add runs the fall-only
//! re-solve** (PR 3), so a staggered stage gate — thousands of flows
//! joining a live contention component one event at a time, the
//! HRS-routed SuperPod shape — costs per-event work proportional to
//! the new flows' binding chains, not to the component.
//!
//! # SuperPod-scale memory (PR 2)
//!
//! Two mechanisms keep peak memory at O(active flows) instead of
//! O(all flows in the DAG):
//!
//! * **Lazy stage materialization** ([`StageFlows::Lazy`]): a stage may
//!   carry a closure that generates its flow vector on demand; the
//!   runner materializes it the moment the stage starts and moves the
//!   channel vectors straight into the solver, so a 5-phase SuperPod
//!   all-to-all never holds more than one phase's flows. Declared
//!   `count`/`bytes` metadata keeps [`Stage::flow_count`] and
//!   [`StageDag::total_bytes`] cheap without materializing.
//! * **Flow-slot recycling**: completed flows' slots (and their channel
//!   vectors) are reused by later stages via a free list; stale
//!   completion events are fended off by the per-slot stamp that lazy
//!   deletion already maintains.
//!
//! [`run_with`] exposes the solver [`ResolveStrategy`] so benches and
//! differential tests can pit the PR 1 full-component solver against the
//! rise-only solver on identical workloads ([`run`] uses the default).

use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::topology::{Channel, Topology};

use super::fair::{FlowId, Rates, ResolveStrategy, SolverStats};
use super::flow::FlowSpec;
use super::network::SimNet;

/// Flows are considered drained below this remnant (bytes). Sub-byte
/// remnants otherwise produce completion deltas that underflow f64 time
/// resolution once `now` is large, starving the event loop.
///
/// Flows *created* at or below the remnant complete the instant their
/// gate opens (the previous linear-scan runner deadlocked on them: they
/// were excluded from event generation but never retired).
const REMNANT_BYTES: f64 = 0.5;

/// A stage's flows: either an eager vector (the PR 1 representation,
/// still the default for small hand-built DAGs) or a builder closure
/// materialized when the scheduler reaches the stage.
#[derive(Clone, Default)]
pub enum StageFlows {
    #[default]
    Empty,
    Eager(Vec<FlowSpec>),
    Lazy {
        /// Generates the stage's flows; must be deterministic and must
        /// produce exactly `count` flows totalling `bytes` payload
        /// bytes (the runner asserts the count). Receives the topology
        /// the simulation runs on, so producers capture only cheap
        /// parameters (node lists, dims, payload sizes).
        build: Arc<dyn Fn(&Topology) -> Vec<FlowSpec> + Send + Sync>,
        count: usize,
        bytes: f64,
    },
}

impl std::fmt::Debug for StageFlows {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageFlows::Empty => write!(f, "Empty"),
            StageFlows::Eager(v) => write!(f, "Eager({} flows)", v.len()),
            StageFlows::Lazy { count, bytes, .. } => {
                write!(f, "Lazy({count} flows, {bytes:.0} B)")
            }
        }
    }
}

/// One DAG stage.
#[derive(Clone, Debug, Default)]
pub struct Stage {
    pub name: String,
    flows: StageFlows,
    /// Local computation overlapped with nothing else in this stage; the
    /// stage ends when flows *and* compute are done.
    pub compute_us: f64,
    /// Indices of stages that must finish first.
    pub deps: Vec<usize>,
}

impl Stage {
    pub fn new(name: impl Into<String>) -> Stage {
        Stage {
            name: name.into(),
            ..Default::default()
        }
    }

    /// Attach an eager flow vector.
    pub fn with_flows(mut self, flows: Vec<FlowSpec>) -> Stage {
        self.flows = StageFlows::Eager(flows);
        self
    }

    /// Attach a lazy flow builder. `count` and `bytes` must match what
    /// `build` produces (count is asserted at materialization; bytes
    /// feeds [`StageDag::total_bytes`]).
    pub fn with_lazy_flows(
        mut self,
        count: usize,
        bytes: f64,
        build: impl Fn(&Topology) -> Vec<FlowSpec> + Send + Sync + 'static,
    ) -> Stage {
        self.flows = StageFlows::Lazy {
            build: Arc::new(build),
            count,
            bytes,
        };
        self
    }

    pub fn with_compute(mut self, us: f64) -> Stage {
        self.compute_us = us;
        self
    }

    pub fn after(mut self, deps: Vec<usize>) -> Stage {
        self.deps = deps;
        self
    }

    /// Number of flows this stage will release (no materialization).
    pub fn flow_count(&self) -> usize {
        match &self.flows {
            StageFlows::Empty => 0,
            StageFlows::Eager(v) => v.len(),
            StageFlows::Lazy { count, .. } => *count,
        }
    }

    /// Total payload bytes this stage carries (no materialization).
    pub fn flow_bytes(&self) -> f64 {
        match &self.flows {
            StageFlows::Empty => 0.0,
            StageFlows::Eager(v) => v.iter().map(|f| f.bytes).sum(),
            StageFlows::Lazy { bytes, .. } => *bytes,
        }
    }

    pub fn is_lazy(&self) -> bool {
        matches!(self.flows, StageFlows::Lazy { .. })
    }

    /// The eager flow vector, if this stage has one (tests and DAG
    /// composition helpers use this; lazy stages return `None`).
    pub fn eager_flows(&self) -> Option<&[FlowSpec]> {
        match &self.flows {
            StageFlows::Empty => Some(&[]),
            StageFlows::Eager(v) => Some(v),
            StageFlows::Lazy { .. } => None,
        }
    }

    /// Materialize this stage's flows (clones eager vectors).
    pub fn materialize_flows(&self, t: &Topology) -> Vec<FlowSpec> {
        match &self.flows {
            StageFlows::Empty => Vec::new(),
            StageFlows::Eager(v) => v.clone(),
            StageFlows::Lazy { build, count, .. } => {
                let v = build(t);
                assert_eq!(
                    v.len(),
                    *count,
                    "lazy stage '{}' declared {count} flows but built {}",
                    self.name,
                    v.len()
                );
                v
            }
        }
    }
}

/// A collective / iteration schedule.
#[derive(Clone, Debug, Default)]
pub struct StageDag {
    pub stages: Vec<Stage>,
}

impl StageDag {
    pub fn push(&mut self, stage: Stage) -> usize {
        self.stages.push(stage);
        self.stages.len() - 1
    }

    /// Serially chain a list of stages (each depends on the previous).
    pub fn chain(stages: Vec<Stage>) -> StageDag {
        let mut dag = StageDag::default();
        let mut prev: Option<usize> = None;
        for mut s in stages {
            if let Some(p) = prev {
                s.deps.push(p);
            }
            prev = Some(dag.push(s));
        }
        dag
    }

    /// Total payload bytes across all stages (lazy stages answer from
    /// their declared metadata, no materialization).
    pub fn total_bytes(&self) -> f64 {
        self.stages.iter().map(|s| s.flow_bytes()).sum()
    }

    /// Total flow count across all stages.
    pub fn total_flow_count(&self) -> usize {
        self.stages.iter().map(|s| s.flow_count()).sum()
    }

    /// An all-eager copy of this DAG (every lazy stage materialized
    /// against `t`). The lazy/eager equivalence property test runs both
    /// through [`run`] and asserts identical reports.
    pub fn materialized(&self, t: &Topology) -> StageDag {
        StageDag {
            stages: self
                .stages
                .iter()
                .map(|s| Stage {
                    name: s.name.clone(),
                    flows: StageFlows::Eager(s.materialize_flows(t)),
                    compute_us: s.compute_us,
                    deps: s.deps.clone(),
                })
                .collect(),
        }
    }
}

/// Runner configuration (see [`run_with`]).
#[derive(Clone, Debug, Default)]
pub struct SimConfig {
    /// Re-solve strategy for the max-min solver.
    pub strategy: ResolveStrategy,
}

/// Result of executing a DAG.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Wall-clock makespan, µs.
    pub makespan_us: f64,
    /// Completion time of each stage, µs.
    pub stage_done_us: Vec<f64>,
    /// Total bytes × distance actually carried (byte-hops).
    pub byte_hops: f64,
    /// Events processed (completions + stage starts) — perf metric.
    pub events: u64,
    /// Peak concurrently-active flows.
    pub peak_flows: usize,
    /// Solver work counters for the whole run (re-solves, rate
    /// recomputes, the full-component equivalent, absorb restarts).
    pub solver: SolverStats,
}

#[derive(Default)]
struct ActiveFlow {
    stage: usize,
    /// Channels, present until the flow joins the solver (then owned by
    /// the solver's inverted index).
    channels: Option<Vec<Channel>>,
    hops: f64,
    /// Remaining payload bytes (capacity is GB/s and time µs, so drain
    /// is `rate × 1e3` bytes/µs).
    remaining_bytes: f64,
    rate_gb_s: f64,
    /// Last time `remaining_bytes` was brought up to date.
    settled_us: f64,
    /// Solver handle once the gate opened.
    solver_id: Option<FlowId>,
    done: bool,
    /// Lazy-deletion stamp for completion events. Survives slot reuse —
    /// a recycled slot keeps counting up, so events addressed to the
    /// previous occupant stay stale.
    stamp: u64,
}

#[derive(Copy, Clone)]
enum EvKind {
    /// Gate opens: flow starts draining (joins the rate allocation).
    Gate(usize),
    /// Predicted completion of active flow (valid if stamp matches).
    FlowDone(usize, u64),
    /// Stage-local compute finishes.
    Compute(usize),
}

struct Ev {
    t: f64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.t.total_cmp(&self.t) // reversed: min-heap on time
    }
}

/// Execute the DAG on the network with the default configuration.
/// Panics on cyclic dependencies.
pub fn run(net: &SimNet, dag: &StageDag) -> SimReport {
    run_with(net, dag, &SimConfig::default())
}

/// Execute the DAG with an explicit [`SimConfig`].
pub fn run_with(net: &SimNet, dag: &StageDag, cfg: &SimConfig) -> SimReport {
    let n = dag.stages.len();
    let mut dep_left: Vec<usize> = dag.stages.iter().map(|s| s.deps.len()).collect();
    let mut dependants: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, s) in dag.stages.iter().enumerate() {
        for &d in &s.deps {
            assert!(d < n, "dep out of range");
            dependants[d].push(i);
        }
    }

    let mut stage_done = vec![f64::NAN; n];
    let mut flows_left: Vec<usize> = dag.stages.iter().map(|s| s.flow_count()).collect();
    let mut compute_done_at: Vec<f64> = vec![f64::NAN; n];
    let mut started = vec![false; n];
    let mut done_count = 0usize;

    let mut active: Vec<ActiveFlow> = Vec::new();
    let mut free_slots: Vec<usize> = Vec::new();
    let mut rates = Rates::with_strategy(cfg.strategy);
    // Reverse map: solver FlowId → index in `active` (MAX = free).
    let mut sid_to_active: Vec<usize> = Vec::new();
    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut now = 0.0f64;
    let mut events = 0u64;
    let mut byte_hops = 0.0f64;
    let mut alive = 0usize;
    let mut peak = 0usize;

    // Spawn one gated flow into a (possibly recycled) slot. All inputs
    // are evaluated before any local binding — the caller's expressions
    // may reference names this macro would otherwise shadow.
    macro_rules! spawn_flow {
        ($stage:expr, $bytes:expr, $latency:expr, $channels:expr) => {{
            let spawn_stage: usize = $stage;
            let spawn_bytes: f64 = $bytes;
            let gate = now + $latency;
            let channels: Vec<Channel> = $channels;
            let slot = match free_slots.pop() {
                Some(s) => s,
                None => {
                    active.push(ActiveFlow::default());
                    active.len() - 1
                }
            };
            let slot_f = &mut active[slot];
            slot_f.stage = spawn_stage;
            slot_f.hops = channels.len() as f64;
            slot_f.channels = Some(channels);
            slot_f.remaining_bytes = spawn_bytes;
            slot_f.rate_gb_s = 0.0;
            slot_f.settled_us = gate;
            slot_f.solver_id = None;
            slot_f.done = false;
            slot_f.stamp += 1; // fence off events for the previous occupant
            alive += 1;
            heap.push(Ev {
                t: gate,
                kind: EvKind::Gate(slot),
            });
        }};
    }

    // Start a stage: materialize + spawn its gated flows, compute event.
    macro_rules! start_stage {
        ($i:expr) => {{
            let i = $i;
            debug_assert!(!started[i]);
            started[i] = true;
            match &dag.stages[i].flows {
                StageFlows::Empty => {}
                StageFlows::Eager(v) => {
                    for f in v {
                        spawn_flow!(i, f.bytes, f.latency_us, f.channels.clone());
                    }
                }
                StageFlows::Lazy { build, count, .. } => {
                    let v = build(net.topo);
                    assert_eq!(
                        v.len(),
                        *count,
                        "lazy stage '{}' declared {} flows but built {}",
                        dag.stages[i].name,
                        count,
                        v.len()
                    );
                    for f in v {
                        // Move the channel vectors: the materialized
                        // stage is dropped right here, not retained.
                        spawn_flow!(i, f.bytes, f.latency_us, f.channels);
                    }
                }
            }
            peak = peak.max(alive);
            compute_done_at[i] = now + dag.stages[i].compute_us;
            if dag.stages[i].compute_us > 0.0 {
                heap.push(Ev {
                    t: compute_done_at[i],
                    kind: EvKind::Compute(i),
                });
            }
            events += 1;
        }};
    }

    // Settle a flow's drained bytes up to `t` at its current rate.
    macro_rules! settle {
        ($f:expr, $t:expr) => {{
            let f = &mut *$f; // reborrow: caller keeps its &mut afterwards
            if !f.done && f.solver_id.is_some() {
                let dt = $t - f.settled_us;
                if dt > 0.0 && f.rate_gb_s > 0.0 {
                    let drained = (f.rate_gb_s * 1e3 * dt).min(f.remaining_bytes);
                    f.remaining_bytes -= drained;
                    byte_hops += drained * f.hops;
                }
            }
            f.settled_us = $t;
        }};
    }

    for i in 0..n {
        if dep_left[i] == 0 {
            start_stage!(i);
        }
    }

    loop {
        // Settle stage completions at the current instant (fixpoint:
        // zero-duration stages may cascade, starting new stages now).
        loop {
            let mut changed = false;
            for i in 0..n {
                if started[i]
                    && stage_done[i].is_nan()
                    && flows_left[i] == 0
                    && compute_done_at[i] <= now + 1e-9
                {
                    stage_done[i] = now;
                    done_count += 1;
                    events += 1;
                    changed = true;
                    for k in 0..dependants[i].len() {
                        let d = dependants[i][k];
                        dep_left[d] -= 1;
                        if dep_left[d] == 0 {
                            start_stage!(d);
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
        if done_count == n {
            break;
        }

        // ---- next event batch (lazy deletion + simultaneity merge) ----
        let t0 = loop {
            match heap.pop() {
                None => break f64::NAN,
                Some(ev) => {
                    if let EvKind::FlowDone(i, stamp) = ev.kind {
                        if active[i].done || active[i].stamp != stamp {
                            continue; // stale
                        }
                    }
                    heap.push(ev); // fresh: put back, pop in the batch loop
                    break heap.peek().unwrap().t;
                }
            }
        };
        if t0.is_nan() {
            break; // queue drained with stages outstanding → stalled
        }
        now = now.max(t0);
        let batch_eps = 1e-9 * now.abs().max(1.0);

        let mut opened: Vec<usize> = Vec::new(); // active idx joining solver
        let mut completed: Vec<usize> = Vec::new(); // active idx finishing
        while let Some(ev) = heap.peek() {
            if ev.t > t0 + batch_eps {
                break;
            }
            let ev = heap.pop().unwrap();
            match ev.kind {
                EvKind::Gate(i) => {
                    if active[i].remaining_bytes <= REMNANT_BYTES {
                        // Degenerate zero-byte flow: completes at the gate.
                        completed.push(i);
                    } else {
                        opened.push(i);
                    }
                    events += 1;
                }
                EvKind::FlowDone(i, stamp) => {
                    if active[i].done || active[i].stamp != stamp {
                        continue; // stale entry, lazily deleted
                    }
                    completed.push(i);
                    events += 1;
                }
                EvKind::Compute(_) => {
                    events += 1; // handled by the settle fixpoint above
                }
            }
        }

        // ---- apply the batch to the solver ----------------------------
        for &i in &completed {
            let f = &mut active[i];
            settle!(f, now);
            // Credit the fp remnant so byte-hop conservation holds exactly.
            if f.remaining_bytes > 0.0 {
                byte_hops += f.remaining_bytes * f.hops;
                f.remaining_bytes = 0.0;
            }
            f.done = true;
            f.stamp += 1;
            // An un-gated degenerate flow still owns its channel vector;
            // drop it now so recycled slots don't hoard memory.
            f.channels = None;
            alive -= 1;
            flows_left[f.stage] -= 1;
        }
        let mut done_ids: Vec<FlowId> = Vec::with_capacity(completed.len());
        for &i in &completed {
            if let Some(id) = active[i].solver_id.take() {
                sid_to_active[id] = usize::MAX;
                done_ids.push(id);
            }
        }
        if !done_ids.is_empty() {
            rates.remove_flows(net, &done_ids);
            byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
        }
        if !opened.is_empty() {
            // Register the newly-gated flows in one call.
            let chans: Vec<Vec<Channel>> = opened
                .iter()
                .map(|&i| active[i].channels.take().expect("gate fired twice"))
                .collect();
            let refs: Vec<&[Channel]> = chans.iter().map(|c| c.as_slice()).collect();
            let ids = rates.add_flows(net, &refs);
            for (&i, id) in opened.iter().zip(ids) {
                active[i].solver_id = Some(id);
                active[i].settled_us = now;
                if sid_to_active.len() <= id {
                    sid_to_active.resize(id + 1, usize::MAX);
                }
                sid_to_active[id] = i;
            }
            byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
        }
        // Recycle the completed slots for stages started at the next
        // settle fixpoint. (Safe: their stamps were bumped above, so any
        // still-queued event for them is stale.)
        free_slots.extend_from_slice(&completed);
    }

    assert!(
        done_count == n,
        "DAG stalled: {}/{} stages done at t={now}µs (failed links or cyclic deps?)",
        done_count,
        n
    );
    SimReport {
        makespan_us: now,
        stage_done_us: stage_done,
        byte_hops,
        events,
        peak_flows: peak,
        solver: rates.stats().clone(),
    }
}

/// After a solver change: re-settle every touched flow at its old rate
/// (returning the byte-hops drained in the process), adopt the new rate,
/// and push a fresh completion prediction. The old heap entry is
/// invalidated by the stamp bump — lazy deletion, no queue rebuild.
fn retime(
    active: &mut [ActiveFlow],
    sid_to_active: &[usize],
    rates: &Rates,
    now: f64,
    heap: &mut BinaryHeap<Ev>,
) -> f64 {
    let mut byte_hops = 0.0;
    for &fid in rates.touched() {
        let i = sid_to_active.get(fid).copied().unwrap_or(usize::MAX);
        if i == usize::MAX {
            continue; // removed in this same batch
        }
        let f = &mut active[i];
        let new_rate = rates.rate(fid);
        if new_rate == f.rate_gb_s {
            // Unchanged rate → the pending completion prediction is
            // still exact; leave the heap entry alone (no churn).
            continue;
        }
        // Settle at the old rate up to now before the new rate applies.
        let dt = now - f.settled_us;
        if dt > 0.0 && f.rate_gb_s > 0.0 {
            let drained = (f.rate_gb_s * 1e3 * dt).min(f.remaining_bytes);
            f.remaining_bytes -= drained;
            byte_hops += drained * f.hops;
        }
        f.settled_us = now;
        f.rate_gb_s = new_rate;
        f.stamp += 1;
        if f.remaining_bytes <= REMNANT_BYTES {
            // Already (numerically) drained: complete at once.
            heap.push(Ev {
                t: now,
                kind: EvKind::FlowDone(i, f.stamp),
            });
        } else if new_rate > 0.0 {
            heap.push(Ev {
                t: now + f.remaining_bytes / (new_rate * 1e3),
                kind: EvKind::FlowDone(i, f.stamp),
            });
        }
        // rate 0 (blocked): no event — the stall assert reports it.
    }
    byte_hops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::{CableClass, NodeId, Topology};

    fn k4() -> Topology {
        // K4 full-mesh, x8 lanes = 50 GB/s per link direction.
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn single_flow_time_matches_closed_form() {
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6; // 500 MB over 50 GB/s = 10_000 µs
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            bytes,
        )]));
        let r = run(&net, &dag);
        let expect = bytes / (50.0 * 1e3);
        assert!(
            (r.makespan_us - expect).abs() / expect < 0.01,
            "{} vs {expect}",
            r.makespan_us
        );
    }

    #[test]
    fn two_flows_on_one_link_take_twice_as_long() {
        let t = k4();
        let net = SimNet::new(&t);
        let f = |_| FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![f(0), f(1)]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    fn dependencies_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mk = || FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        let a = dag.push(Stage::new("a").with_flows(vec![mk()]));
        dag.push(Stage::new("b").with_flows(vec![mk()]).after(vec![a]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
        assert!(r.stage_done_us[0] < r.stage_done_us[1]);
    }

    #[test]
    fn compute_only_stage() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("gemm").with_compute(123.0));
        let r = run(&net, &dag);
        assert!((r.makespan_us - 123.0).abs() < 1e-6);
    }

    #[test]
    fn compute_overlaps_communication() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(
            Stage::new("overlap")
                .with_flows(vec![FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6)])
                .with_compute(20_000.0),
        );
        let r = run(&net, &dag);
        // max(10_000 comm, 20_000 compute) ≈ 20_000.
        assert!((r.makespan_us - 20_000.0).abs() < 50.0, "{}", r.makespan_us);
    }

    #[test]
    fn parallel_disjoint_flows_dont_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("par").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6),
            FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 500e6),
        ]));
        let r = run(&net, &dag);
        let expect = 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    fn empty_dag_is_a_noop() {
        let t = k4();
        let net = SimNet::new(&t);
        let r = run(&net, &StageDag::default());
        assert_eq!(r.makespan_us, 0.0);
        assert_eq!(r.events, 0);
    }

    #[test]
    fn completion_frees_bandwidth_for_survivors() {
        // Small flow + big flow share a link; once the small one drains,
        // the big one must speed up to the full link (the incremental
        // re-solve in action). Closed form: both at 25 GB/s until the
        // 100 MB flow ends (t1 = 100e6/25e3 = 4000 µs), then the 900 MB
        // flow finishes its remaining 800 MB at 50 GB/s (16_000 µs more).
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("pair").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 100e6),
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 900e6),
        ]));
        let r = run(&net, &dag);
        let expect = 4000.0 + 16_000.0;
        assert!(
            (r.makespan_us - expect).abs() / expect < 0.01,
            "{} vs {expect}",
            r.makespan_us
        );
    }

    #[test]
    fn both_strategies_produce_identical_reports() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        let a = dag.push(Stage::new("a").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 100e6),
            FlowSpec::along(&t, &[NodeId(0), NodeId(1), NodeId(2)], 250e6),
            FlowSpec::along(&t, &[NodeId(1), NodeId(2)], 400e6),
        ]));
        dag.push(
            Stage::new("b")
                .with_flows(vec![FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 80e6)])
                .after(vec![a]),
        );
        let rise = run_with(&net, &dag, &SimConfig::default());
        let bfs = run_with(
            &net,
            &dag,
            &SimConfig {
                strategy: ResolveStrategy::FullComponentBfs,
            },
        );
        assert!((rise.makespan_us - bfs.makespan_us).abs() < 1e-6 * bfs.makespan_us);
        assert!((rise.byte_hops - bfs.byte_hops).abs() < 1e-6 * bfs.byte_hops);
        assert_eq!(rise.peak_flows, bfs.peak_flows);
    }

    #[test]
    fn lazy_stage_materializes_and_matches_eager() {
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6;
        let mut lazy = StageDag::default();
        lazy.push(Stage::new("xfer").with_lazy_flows(2, 2.0 * bytes, move |t| {
            vec![
                FlowSpec::along(t, &[NodeId(0), NodeId(1)], bytes),
                FlowSpec::along(t, &[NodeId(2), NodeId(3)], bytes),
            ]
        }));
        assert!(lazy.stages[0].is_lazy());
        assert_eq!(lazy.stages[0].flow_count(), 2);
        assert!((lazy.total_bytes() - 2.0 * bytes).abs() < 1.0);
        let r1 = run(&net, &lazy);
        let r2 = run(&net, &lazy.materialized(&t));
        assert_eq!(r1.makespan_us, r2.makespan_us);
        assert_eq!(r1.byte_hops, r2.byte_hops);
        assert_eq!(r1.events, r2.events);
    }

    #[test]
    #[should_panic(expected = "declared 3 flows but built 2")]
    fn lazy_stage_count_mismatch_panics() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("bad").with_lazy_flows(3, 1e6, |t| {
            vec![
                FlowSpec::along(t, &[NodeId(0), NodeId(1)], 5e5),
                FlowSpec::along(t, &[NodeId(1), NodeId(2)], 5e5),
            ]
        }));
        run(&net, &dag);
    }

    #[test]
    fn flow_slots_are_recycled_across_stages() {
        // 6 serial stages of 2 flows each: peak concurrency is 2, so the
        // active table should recycle instead of growing 12 slots.
        let t = k4();
        let net = SimNet::new(&t);
        let mut stages = Vec::new();
        for k in 0..6 {
            stages.push(Stage::new(format!("s{k}")).with_flows(vec![
                FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 10e6),
                FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 10e6),
            ]));
        }
        let dag = StageDag::chain(stages);
        let r = run(&net, &dag);
        assert_eq!(r.peak_flows, 2);
        assert!((r.byte_hops - 12.0 * 10e6).abs() < 1.0);
    }

    #[test]
    #[should_panic(expected = "DAG stalled")]
    fn failed_link_stalls_and_reports() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        net.fail_link(l);
        let mut dag = StageDag::default();
        dag.push(Stage::new("x").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            1e6,
        )]));
        run(&net, &dag);
    }
}
