//! Stage-DAG execution over the fluid-flow simulator.
//!
//! A [`StageDag`] models a collective or a whole training iteration:
//! each [`Stage`] holds flows plus an optional local compute duration,
//! and starts when all its dependencies complete.
//!
//! The runner is event-driven around a **binary-heap event queue**
//! (gate openings, flow completions, compute completions) with lazy
//! deletion: a flow completion is predicted from its current rate and
//! stamped; when rates change the stamp is bumped and the stale heap
//! entry is simply skipped on pop, so rate changes never force a queue
//! rebuild. Rates come from the incremental [`Rates`] solver: at each
//! event batch only the affected component is re-solved and only flows
//! the solver reports as touched are re-settled (their drained bytes
//! accounted at the old rate before the new rate applies). Events that
//! land at the same instant are processed as one batch — a single
//! remove/add pair on the solver — which keeps symmetric collectives
//! (all flows of a phase finishing together) linear instead of
//! quadratic.

use std::collections::BinaryHeap;

use crate::topology::Channel;

use super::fair::{FlowId, Rates};
use super::flow::FlowSpec;
use super::network::SimNet;

/// Flows are considered drained below this remnant (bytes). Sub-byte
/// remnants otherwise produce completion deltas that underflow f64 time
/// resolution once `now` is large, starving the event loop.
///
/// Flows *created* at or below the remnant complete the instant their
/// gate opens (the previous linear-scan runner deadlocked on them: they
/// were excluded from event generation but never retired).
const REMNANT_BYTES: f64 = 0.5;

/// One DAG stage.
#[derive(Clone, Debug, Default)]
pub struct Stage {
    pub name: String,
    pub flows: Vec<FlowSpec>,
    /// Local computation overlapped with nothing else in this stage; the
    /// stage ends when flows *and* compute are done.
    pub compute_us: f64,
    /// Indices of stages that must finish first.
    pub deps: Vec<usize>,
}

impl Stage {
    pub fn new(name: impl Into<String>) -> Stage {
        Stage {
            name: name.into(),
            ..Default::default()
        }
    }
    pub fn with_flows(mut self, flows: Vec<FlowSpec>) -> Stage {
        self.flows = flows;
        self
    }
    pub fn with_compute(mut self, us: f64) -> Stage {
        self.compute_us = us;
        self
    }
    pub fn after(mut self, deps: Vec<usize>) -> Stage {
        self.deps = deps;
        self
    }
}

/// A collective / iteration schedule.
#[derive(Clone, Debug, Default)]
pub struct StageDag {
    pub stages: Vec<Stage>,
}

impl StageDag {
    pub fn push(&mut self, stage: Stage) -> usize {
        self.stages.push(stage);
        self.stages.len() - 1
    }

    /// Serially chain a list of stages (each depends on the previous).
    pub fn chain(stages: Vec<Stage>) -> StageDag {
        let mut dag = StageDag::default();
        let mut prev: Option<usize> = None;
        for mut s in stages {
            if let Some(p) = prev {
                s.deps.push(p);
            }
            prev = Some(dag.push(s));
        }
        dag
    }

    pub fn total_bytes(&self) -> f64 {
        self.stages
            .iter()
            .flat_map(|s| &s.flows)
            .map(|f| f.bytes)
            .sum()
    }
}

/// Result of executing a DAG.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Wall-clock makespan, µs.
    pub makespan_us: f64,
    /// Completion time of each stage, µs.
    pub stage_done_us: Vec<f64>,
    /// Total bytes × distance actually carried (byte-hops).
    pub byte_hops: f64,
    /// Events processed (completions + stage starts) — perf metric.
    pub events: u64,
    /// Peak concurrently-active flows.
    pub peak_flows: usize,
}

struct ActiveFlow {
    stage: usize,
    /// Channels, present until the flow joins the solver (then owned by
    /// the solver's inverted index).
    channels: Option<Vec<Channel>>,
    hops: f64,
    /// Remaining payload bytes (capacity is GB/s and time µs, so drain
    /// is `rate × 1e3` bytes/µs).
    remaining_bytes: f64,
    rate_gb_s: f64,
    /// Last time `remaining_bytes` was brought up to date.
    settled_us: f64,
    /// Solver handle once the gate opened.
    solver_id: Option<FlowId>,
    done: bool,
    /// Lazy-deletion stamp for completion events.
    stamp: u64,
}

#[derive(Copy, Clone)]
enum EvKind {
    /// Gate opens: flow starts draining (joins the rate allocation).
    Gate(usize),
    /// Predicted completion of active flow (valid if stamp matches).
    FlowDone(usize, u64),
    /// Stage-local compute finishes.
    Compute(usize),
}

struct Ev {
    t: f64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.t.total_cmp(&self.t) // reversed: min-heap on time
    }
}

/// Execute the DAG on the network. Panics on cyclic dependencies.
pub fn run(net: &SimNet, dag: &StageDag) -> SimReport {
    let n = dag.stages.len();
    let mut dep_left: Vec<usize> = dag.stages.iter().map(|s| s.deps.len()).collect();
    let mut dependants: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, s) in dag.stages.iter().enumerate() {
        for &d in &s.deps {
            assert!(d < n, "dep out of range");
            dependants[d].push(i);
        }
    }

    let mut stage_done = vec![f64::NAN; n];
    let mut flows_left: Vec<usize> = dag.stages.iter().map(|s| s.flows.len()).collect();
    let mut compute_done_at: Vec<f64> = vec![f64::NAN; n];
    let mut started = vec![false; n];
    let mut done_count = 0usize;

    let mut active: Vec<ActiveFlow> = Vec::new();
    let mut rates = Rates::new();
    // Reverse map: solver FlowId → index in `active` (MAX = free).
    let mut sid_to_active: Vec<usize> = Vec::new();
    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut now = 0.0f64;
    let mut events = 0u64;
    let mut byte_hops = 0.0f64;
    let mut alive = 0usize;
    let mut peak = 0usize;

    // Start a stage: spawn its gated flows + compute event.
    macro_rules! start_stage {
        ($i:expr) => {{
            let i = $i;
            debug_assert!(!started[i]);
            started[i] = true;
            for f in &dag.stages[i].flows {
                let gate = now + f.latency_us;
                active.push(ActiveFlow {
                    stage: i,
                    hops: f.channels.len() as f64,
                    channels: Some(f.channels.clone()),
                    remaining_bytes: f.bytes,
                    rate_gb_s: 0.0,
                    settled_us: gate,
                    solver_id: None,
                    done: false,
                    stamp: 0,
                });
                alive += 1;
                heap.push(Ev {
                    t: gate,
                    kind: EvKind::Gate(active.len() - 1),
                });
            }
            peak = peak.max(alive);
            compute_done_at[i] = now + dag.stages[i].compute_us;
            if dag.stages[i].compute_us > 0.0 {
                heap.push(Ev {
                    t: compute_done_at[i],
                    kind: EvKind::Compute(i),
                });
            }
            events += 1;
        }};
    }

    // Settle a flow's drained bytes up to `t` at its current rate.
    macro_rules! settle {
        ($f:expr, $t:expr) => {{
            let f = &mut *$f; // reborrow: caller keeps its &mut afterwards
            if !f.done && f.solver_id.is_some() {
                let dt = $t - f.settled_us;
                if dt > 0.0 && f.rate_gb_s > 0.0 {
                    let drained = (f.rate_gb_s * 1e3 * dt).min(f.remaining_bytes);
                    f.remaining_bytes -= drained;
                    byte_hops += drained * f.hops;
                }
            }
            f.settled_us = $t;
        }};
    }

    for i in 0..n {
        if dep_left[i] == 0 {
            start_stage!(i);
        }
    }

    loop {
        // Settle stage completions at the current instant (fixpoint:
        // zero-duration stages may cascade, starting new stages now).
        loop {
            let mut changed = false;
            for i in 0..n {
                if started[i]
                    && stage_done[i].is_nan()
                    && flows_left[i] == 0
                    && compute_done_at[i] <= now + 1e-9
                {
                    stage_done[i] = now;
                    done_count += 1;
                    events += 1;
                    changed = true;
                    for k in 0..dependants[i].len() {
                        let d = dependants[i][k];
                        dep_left[d] -= 1;
                        if dep_left[d] == 0 {
                            start_stage!(d);
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
        if done_count == n {
            break;
        }

        // ---- next event batch (lazy deletion + simultaneity merge) ----
        let t0 = loop {
            match heap.pop() {
                None => break f64::NAN,
                Some(ev) => {
                    if let EvKind::FlowDone(i, stamp) = ev.kind {
                        if active[i].done || active[i].stamp != stamp {
                            continue; // stale
                        }
                    }
                    heap.push(ev); // fresh: put back, pop in the batch loop
                    break heap.peek().unwrap().t;
                }
            }
        };
        if t0.is_nan() {
            break; // queue drained with stages outstanding → stalled
        }
        now = now.max(t0);
        let batch_eps = 1e-9 * now.abs().max(1.0);

        let mut opened: Vec<usize> = Vec::new(); // active idx joining solver
        let mut completed: Vec<usize> = Vec::new(); // active idx finishing
        while let Some(ev) = heap.peek() {
            if ev.t > t0 + batch_eps {
                break;
            }
            let ev = heap.pop().unwrap();
            match ev.kind {
                EvKind::Gate(i) => {
                    if active[i].remaining_bytes <= REMNANT_BYTES {
                        // Degenerate zero-byte flow: completes at the gate.
                        completed.push(i);
                    } else {
                        opened.push(i);
                    }
                    events += 1;
                }
                EvKind::FlowDone(i, stamp) => {
                    if active[i].done || active[i].stamp != stamp {
                        continue; // stale entry, lazily deleted
                    }
                    completed.push(i);
                    events += 1;
                }
                EvKind::Compute(_) => {
                    events += 1; // handled by the settle fixpoint above
                }
            }
        }

        // ---- apply the batch to the solver ----------------------------
        for &i in &completed {
            let f = &mut active[i];
            settle!(f, now);
            // Credit the fp remnant so byte-hop conservation holds exactly.
            if f.remaining_bytes > 0.0 {
                byte_hops += f.remaining_bytes * f.hops;
                f.remaining_bytes = 0.0;
            }
            f.done = true;
            f.stamp += 1;
            alive -= 1;
            flows_left[f.stage] -= 1;
        }
        let mut done_ids: Vec<FlowId> = Vec::with_capacity(completed.len());
        for &i in &completed {
            if let Some(id) = active[i].solver_id.take() {
                sid_to_active[id] = usize::MAX;
                done_ids.push(id);
            }
        }
        if !done_ids.is_empty() {
            rates.remove_flows(net, &done_ids);
            byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
        }
        if !opened.is_empty() {
            // Register the newly-gated flows in one call.
            let chans: Vec<Vec<Channel>> = opened
                .iter()
                .map(|&i| active[i].channels.take().expect("gate fired twice"))
                .collect();
            let refs: Vec<&[Channel]> = chans.iter().map(|c| c.as_slice()).collect();
            let ids = rates.add_flows(net, &refs);
            for (&i, id) in opened.iter().zip(ids) {
                active[i].solver_id = Some(id);
                active[i].settled_us = now;
                if sid_to_active.len() <= id {
                    sid_to_active.resize(id + 1, usize::MAX);
                }
                sid_to_active[id] = i;
            }
            byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
        }
    }

    assert!(
        done_count == n,
        "DAG stalled: {}/{} stages done at t={now}µs (failed links or cyclic deps?)",
        done_count,
        n
    );
    SimReport {
        makespan_us: now,
        stage_done_us: stage_done,
        byte_hops,
        events,
        peak_flows: peak,
    }
}

/// After a solver change: re-settle every touched flow at its old rate
/// (returning the byte-hops drained in the process), adopt the new rate,
/// and push a fresh completion prediction. The old heap entry is
/// invalidated by the stamp bump — lazy deletion, no queue rebuild.
fn retime(
    active: &mut [ActiveFlow],
    sid_to_active: &[usize],
    rates: &Rates,
    now: f64,
    heap: &mut BinaryHeap<Ev>,
) -> f64 {
    let mut byte_hops = 0.0;
    for &fid in rates.touched() {
        let i = sid_to_active[fid];
        if i == usize::MAX {
            continue; // removed in this same batch
        }
        let f = &mut active[i];
        let new_rate = rates.rate(fid);
        if new_rate == f.rate_gb_s {
            // Unchanged rate → the pending completion prediction is
            // still exact; leave the heap entry alone (no churn).
            continue;
        }
        // Settle at the old rate up to now before the new rate applies.
        let dt = now - f.settled_us;
        if dt > 0.0 && f.rate_gb_s > 0.0 {
            let drained = (f.rate_gb_s * 1e3 * dt).min(f.remaining_bytes);
            f.remaining_bytes -= drained;
            byte_hops += drained * f.hops;
        }
        f.settled_us = now;
        f.rate_gb_s = new_rate;
        f.stamp += 1;
        if f.remaining_bytes <= REMNANT_BYTES {
            // Already (numerically) drained: complete at once.
            heap.push(Ev {
                t: now,
                kind: EvKind::FlowDone(i, f.stamp),
            });
        } else if new_rate > 0.0 {
            heap.push(Ev {
                t: now + f.remaining_bytes / (new_rate * 1e3),
                kind: EvKind::FlowDone(i, f.stamp),
            });
        }
        // rate 0 (blocked): no event — the stall assert reports it.
    }
    byte_hops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::{CableClass, NodeId, Topology};

    fn k4() -> Topology {
        // K4 full-mesh, x8 lanes = 50 GB/s per link direction.
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn single_flow_time_matches_closed_form() {
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6; // 500 MB over 50 GB/s = 10_000 µs
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            bytes,
        )]));
        let r = run(&net, &dag);
        let expect = bytes / (50.0 * 1e3);
        assert!(
            (r.makespan_us - expect).abs() / expect < 0.01,
            "{} vs {expect}",
            r.makespan_us
        );
    }

    #[test]
    fn two_flows_on_one_link_take_twice_as_long() {
        let t = k4();
        let net = SimNet::new(&t);
        let f = |_| FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![f(0), f(1)]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    fn dependencies_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mk = || FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        let a = dag.push(Stage::new("a").with_flows(vec![mk()]));
        dag.push(Stage::new("b").with_flows(vec![mk()]).after(vec![a]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
        assert!(r.stage_done_us[0] < r.stage_done_us[1]);
    }

    #[test]
    fn compute_only_stage() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("gemm").with_compute(123.0));
        let r = run(&net, &dag);
        assert!((r.makespan_us - 123.0).abs() < 1e-6);
    }

    #[test]
    fn compute_overlaps_communication() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(
            Stage::new("overlap")
                .with_flows(vec![FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6)])
                .with_compute(20_000.0),
        );
        let r = run(&net, &dag);
        // max(10_000 comm, 20_000 compute) ≈ 20_000.
        assert!((r.makespan_us - 20_000.0).abs() < 50.0, "{}", r.makespan_us);
    }

    #[test]
    fn parallel_disjoint_flows_dont_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("par").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6),
            FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 500e6),
        ]));
        let r = run(&net, &dag);
        let expect = 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    fn empty_dag_is_a_noop() {
        let t = k4();
        let net = SimNet::new(&t);
        let r = run(&net, &StageDag::default());
        assert_eq!(r.makespan_us, 0.0);
        assert_eq!(r.events, 0);
    }

    #[test]
    fn completion_frees_bandwidth_for_survivors() {
        // Small flow + big flow share a link; once the small one drains,
        // the big one must speed up to the full link (the incremental
        // re-solve in action). Closed form: both at 25 GB/s until the
        // 100 MB flow ends (t1 = 100e6/25e3 = 4000 µs), then the 900 MB
        // flow finishes its remaining 800 MB at 50 GB/s (16_000 µs more).
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("pair").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 100e6),
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 900e6),
        ]));
        let r = run(&net, &dag);
        let expect = 4000.0 + 16_000.0;
        assert!(
            (r.makespan_us - expect).abs() / expect < 0.01,
            "{} vs {expect}",
            r.makespan_us
        );
    }

    #[test]
    #[should_panic(expected = "DAG stalled")]
    fn failed_link_stalls_and_reports() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        net.fail_link(l);
        let mut dag = StageDag::default();
        dag.push(Stage::new("x").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            1e6,
        )]));
        run(&net, &dag);
    }
}
