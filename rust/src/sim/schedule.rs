//! Stage-DAG execution over the fluid-flow simulator.
//!
//! A [`StageDag`] models a collective or a whole training iteration:
//! each [`Stage`] holds flows plus an optional local compute duration,
//! and starts when all its dependencies complete.
//!
//! The runner is event-driven around a **binary-heap event queue**
//! (gate openings, flow completions, compute completions) with lazy
//! deletion: a flow completion is predicted from its current rate and
//! stamped; when rates change the stamp is bumped and the stale heap
//! entry is simply skipped on pop, so rate changes never force a queue
//! rebuild. Rates come from the incremental [`Rates`] solver: at each
//! event batch only the affected flows are re-solved and only flows the
//! solver reports as touched are re-settled (their drained bytes
//! accounted at the old rate before the new rate applies). Events that
//! land at the same instant are processed as one batch — a single
//! remove/add pair on the solver — which keeps symmetric collectives
//! (all flows of a phase finishing together) linear instead of
//! quadratic. Under the default [`ResolveStrategy::Bounded`] both
//! halves of that pair are bounded re-solves: the removal runs the
//! rise-only re-solve and the **gate-open add runs the fall-only
//! re-solve** (PR 3), so a staggered stage gate — thousands of flows
//! joining a live contention component one event at a time, the
//! HRS-routed SuperPod shape — costs per-event work proportional to
//! the new flows' binding chains, not to the component.
//!
//! # SuperPod-scale memory (PR 2)
//!
//! Two mechanisms keep peak memory at O(active flows) instead of
//! O(all flows in the DAG):
//!
//! * **Lazy stage materialization** ([`StageFlows::Lazy`]): a stage may
//!   carry a closure that generates its flow vector on demand; the
//!   runner materializes it the moment the stage starts and moves the
//!   channel vectors straight into the solver, so a 5-phase SuperPod
//!   all-to-all never holds more than one phase's flows. Declared
//!   `count`/`bytes` metadata keeps [`Stage::flow_count`] and
//!   [`StageDag::total_bytes`] cheap without materializing.
//! * **Flow-slot recycling**: completed flows' slots (and their channel
//!   vectors) are reused by later stages via a free list; stale
//!   completion events are fended off by the per-slot stamp that lazy
//!   deletion already maintains.
//!
//! # Mid-run faults (PR 4)
//!
//! [`run_faulted`] executes a [`FaultPlan`] inside the same event heap:
//! fault events mutate a private [`SimNet`] clone, push the capacity
//! change through the solver's bounded mid-run re-solve
//! ([`Rates::links_changed`]) and — when the plan carries a
//! [`super::fault::RecoveryConfig`] — re-route every cut-off flow once
//! the failed link's routing tables have converged
//! ([`super::fault::RecoveryConfig::convergence_us`], hop-by-hop vs
//! direct notification): the blocked flow is retired from the solver
//! and respawned with its *remaining* bytes on a surviving path (APR
//! reselection). Without recovery, blocked flows wait for a `LinkUp` to
//! revive them; if the event queue drains first, the run ends in a
//! **structured stall report** ([`SimReport::stalled`], naming each
//! blocked flow and its dead links) instead of a panic.
//!
//! [`run_with`] exposes the solver [`ResolveStrategy`] so benches and
//! differential tests can pit the PR 1 full-component solver against the
//! rise-only solver on identical workloads ([`run`] uses the default).

use std::collections::{BTreeMap, BinaryHeap};
use std::sync::Arc;

use crate::routing::failure::FlapDamper;
use crate::topology::{Channel, LinkId, NodeId, Topology};

use super::fair::{FlowId, Rates, ResolveStrategy, SolverStats};
use super::fault::{FaultEvent, FaultPlan};
use super::flow::FlowSpec;
use super::network::SimNet;

/// Flows are considered drained below this remnant (bytes). Sub-byte
/// remnants otherwise produce completion deltas that underflow f64 time
/// resolution once `now` is large, starving the event loop.
///
/// Flows *created* at or below the remnant complete the instant their
/// gate opens (the previous linear-scan runner deadlocked on them: they
/// were excluded from event generation but never retired).
const REMNANT_BYTES: f64 = 0.5;

/// A stage's flows: either an eager vector (the PR 1 representation,
/// still the default for small hand-built DAGs) or a builder closure
/// materialized when the scheduler reaches the stage.
#[derive(Clone, Default)]
pub enum StageFlows {
    #[default]
    Empty,
    Eager(Vec<FlowSpec>),
    Lazy {
        /// Generates the stage's flows; must be deterministic and must
        /// produce exactly `count` flows totalling `bytes` payload
        /// bytes (the runner asserts the count). Receives the topology
        /// the simulation runs on, so producers capture only cheap
        /// parameters (node lists, dims, payload sizes).
        build: Arc<dyn Fn(&Topology) -> Vec<FlowSpec> + Send + Sync>,
        count: usize,
        bytes: f64,
    },
}

impl std::fmt::Debug for StageFlows {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageFlows::Empty => write!(f, "Empty"),
            StageFlows::Eager(v) => write!(f, "Eager({} flows)", v.len()),
            StageFlows::Lazy { count, bytes, .. } => {
                write!(f, "Lazy({count} flows, {bytes:.0} B)")
            }
        }
    }
}

/// One DAG stage.
#[derive(Clone, Debug, Default)]
pub struct Stage {
    pub name: String,
    flows: StageFlows,
    /// Local computation overlapped with nothing else in this stage; the
    /// stage ends when flows *and* compute are done.
    pub compute_us: f64,
    /// Indices of stages that must finish first.
    pub deps: Vec<usize>,
}

impl Stage {
    pub fn new(name: impl Into<String>) -> Stage {
        Stage {
            name: name.into(),
            ..Default::default()
        }
    }

    /// Attach an eager flow vector.
    pub fn with_flows(mut self, flows: Vec<FlowSpec>) -> Stage {
        self.flows = StageFlows::Eager(flows);
        self
    }

    /// Attach a lazy flow builder. `count` and `bytes` must match what
    /// `build` produces (count is asserted at materialization; bytes
    /// feeds [`StageDag::total_bytes`]).
    pub fn with_lazy_flows(
        mut self,
        count: usize,
        bytes: f64,
        build: impl Fn(&Topology) -> Vec<FlowSpec> + Send + Sync + 'static,
    ) -> Stage {
        self.flows = StageFlows::Lazy {
            build: Arc::new(build),
            count,
            bytes,
        };
        self
    }

    pub fn with_compute(mut self, us: f64) -> Stage {
        self.compute_us = us;
        self
    }

    pub fn after(mut self, deps: Vec<usize>) -> Stage {
        self.deps = deps;
        self
    }

    /// Number of flows this stage will release (no materialization).
    pub fn flow_count(&self) -> usize {
        match &self.flows {
            StageFlows::Empty => 0,
            StageFlows::Eager(v) => v.len(),
            StageFlows::Lazy { count, .. } => *count,
        }
    }

    /// Total payload bytes this stage carries (no materialization).
    pub fn flow_bytes(&self) -> f64 {
        match &self.flows {
            StageFlows::Empty => 0.0,
            StageFlows::Eager(v) => v.iter().map(|f| f.bytes).sum(),
            StageFlows::Lazy { bytes, .. } => *bytes,
        }
    }

    pub fn is_lazy(&self) -> bool {
        matches!(self.flows, StageFlows::Lazy { .. })
    }

    /// The eager flow vector, if this stage has one (tests and DAG
    /// composition helpers use this; lazy stages return `None`).
    pub fn eager_flows(&self) -> Option<&[FlowSpec]> {
        match &self.flows {
            StageFlows::Empty => Some(&[]),
            StageFlows::Eager(v) => Some(v),
            StageFlows::Lazy { .. } => None,
        }
    }

    /// Materialize this stage's flows (clones eager vectors).
    pub fn materialize_flows(&self, t: &Topology) -> Vec<FlowSpec> {
        match &self.flows {
            StageFlows::Empty => Vec::new(),
            StageFlows::Eager(v) => v.clone(),
            StageFlows::Lazy { build, count, .. } => {
                let v = build(t);
                assert_eq!(
                    v.len(),
                    *count,
                    "lazy stage '{}' declared {count} flows but built {}",
                    self.name,
                    v.len()
                );
                v
            }
        }
    }

    /// Non-panicking [`Stage::materialize_flows`]: a lazy builder whose
    /// output disagrees with the declared count is an `Err`, so the
    /// static auditor (`verify::audit`, rule AUD022) can report the
    /// defect instead of aborting mid-audit.
    pub fn try_materialize_flows(&self, t: &Topology) -> Result<Vec<FlowSpec>, String> {
        match &self.flows {
            StageFlows::Empty => Ok(Vec::new()),
            StageFlows::Eager(v) => Ok(v.clone()),
            StageFlows::Lazy { build, count, .. } => {
                let v = build(t);
                if v.len() != *count {
                    return Err(format!(
                        "lazy stage '{}' declared {count} flows but built {}",
                        self.name,
                        v.len()
                    ));
                }
                Ok(v)
            }
        }
    }
}

/// A collective / iteration schedule.
#[derive(Clone, Debug, Default)]
pub struct StageDag {
    pub stages: Vec<Stage>,
}

impl StageDag {
    pub fn push(&mut self, stage: Stage) -> usize {
        self.stages.push(stage);
        self.stages.len() - 1
    }

    /// Serially chain a list of stages (each depends on the previous).
    pub fn chain(stages: Vec<Stage>) -> StageDag {
        let mut dag = StageDag::default();
        let mut prev: Option<usize> = None;
        for mut s in stages {
            if let Some(p) = prev {
                s.deps.push(p);
            }
            prev = Some(dag.push(s));
        }
        dag
    }

    /// Total payload bytes across all stages (lazy stages answer from
    /// their declared metadata, no materialization).
    pub fn total_bytes(&self) -> f64 {
        self.stages.iter().map(|s| s.flow_bytes()).sum()
    }

    /// Total flow count across all stages.
    pub fn total_flow_count(&self) -> usize {
        self.stages.iter().map(|s| s.flow_count()).sum()
    }

    /// An all-eager copy of this DAG (every lazy stage materialized
    /// against `t`). The lazy/eager equivalence property test runs both
    /// through [`run`] and asserts identical reports.
    pub fn materialized(&self, t: &Topology) -> StageDag {
        StageDag {
            stages: self
                .stages
                .iter()
                .map(|s| Stage {
                    name: s.name.clone(),
                    flows: StageFlows::Eager(s.materialize_flows(t)),
                    compute_us: s.compute_us,
                    deps: s.deps.clone(),
                })
                .collect(),
        }
    }
}

/// Runner configuration (see [`run_with`]).
#[derive(Clone, Debug, Default)]
pub struct SimConfig {
    /// Re-solve strategy for the max-min solver.
    pub strategy: ResolveStrategy,
}

/// One flow left blocked on a dead channel when the event queue
/// drained — the structured stall outcome that replaced the old
/// "DAG stalled" panic (callers without a fault plan get a diagnosable
/// report; the fault-plan reroute path consumes the same information
/// live).
#[derive(Clone, Debug)]
pub struct StalledFlow {
    /// Index of the stage the flow belongs to.
    pub stage: usize,
    pub src: NodeId,
    pub dst: NodeId,
    /// Undrained payload at stall time.
    pub remaining_bytes: f64,
    /// The unusable (down or zero-capacity) links on the flow's path
    /// (deduplicated, path order).
    pub dead_links: Vec<LinkId>,
}

/// Result of executing a DAG.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Wall-clock makespan, µs — `+∞` when the run stalled (see
    /// [`SimReport::stalled`]).
    pub makespan_us: f64,
    /// Completion time of each stage, µs (`NaN` for stages that never
    /// finished in a stalled run).
    pub stage_done_us: Vec<f64>,
    /// Total bytes × distance actually carried (byte-hops).
    pub byte_hops: f64,
    /// Events processed (completions + stage starts) — perf metric.
    pub events: u64,
    /// Peak concurrently-active flows.
    pub peak_flows: usize,
    /// Flows blocked on dead channels when the event queue drained with
    /// stages outstanding; empty on a completed run.
    pub stalled: Vec<StalledFlow>,
    /// The instant progress stopped, µs — equals [`SimReport::makespan_us`]
    /// on a completed run; on a stalled run it is the (finite) event-loop
    /// time when the queue drained. An NPU death *without* a backup ends
    /// here: checkpoint/restart accounting
    /// ([`crate::reliability::checkpoint`]) charges the abort from this
    /// instant, not from the `+∞` makespan.
    pub stalled_at_us: f64,
    /// Mid-flight APR reroutes performed (fault plans with recovery).
    pub reroutes: u64,
    /// Fault-plan events executed before the run ended.
    pub fault_events: u64,
    /// Solver work counters for the whole run (re-solves, rate
    /// recomputes, the full-component equivalent, absorb restarts).
    pub solver: SolverStats,
}

impl SimReport {
    /// True if the run ended blocked instead of completing every stage.
    pub fn is_stalled(&self) -> bool {
        !self.stalled.is_empty()
    }
}

struct ActiveFlow {
    stage: usize,
    src: NodeId,
    dst: NodeId,
    /// Channels, present until the flow joins the solver (then owned by
    /// the solver's inverted index).
    channels: Option<Vec<Channel>>,
    hops: f64,
    /// Remaining payload bytes (capacity is GB/s and time µs, so drain
    /// is `rate × 1e3` bytes/µs).
    remaining_bytes: f64,
    rate_gb_s: f64,
    /// Last time `remaining_bytes` was brought up to date.
    settled_us: f64,
    /// Solver handle once the gate opened.
    solver_id: Option<FlowId>,
    done: bool,
    /// Lazy-deletion stamp for completion events. Survives slot reuse —
    /// a recycled slot keeps counting up, so events addressed to the
    /// previous occupant stay stale.
    stamp: u64,
}

impl Default for ActiveFlow {
    fn default() -> Self {
        ActiveFlow {
            stage: 0,
            src: NodeId(u32::MAX),
            dst: NodeId(u32::MAX),
            channels: None,
            hops: 0.0,
            remaining_bytes: 0.0,
            rate_gb_s: 0.0,
            settled_us: 0.0,
            solver_id: None,
            done: false,
            stamp: 0,
        }
    }
}

#[derive(Copy, Clone)]
enum EvKind {
    /// Gate opens: flow starts draining (joins the rate allocation).
    Gate(usize),
    /// Predicted completion of active flow (valid if stamp matches).
    FlowDone(usize, u64),
    /// Stage-local compute finishes.
    Compute(usize),
    /// Scripted fault-plan event (index into `FaultPlan::events`).
    Fault(usize),
    /// Routing tables converged for a cut-off flow: re-route it (valid
    /// if stamp matches — a revived or already-rerouted flow fences the
    /// event off via its stamp).
    Reroute(usize, u64),
}

struct Ev {
    t: f64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.t.total_cmp(&self.t) // reversed: min-heap on time
    }
}

/// Execute the DAG on the network with the default configuration.
/// Panics on cyclic dependencies.
pub fn run(net: &SimNet, dag: &StageDag) -> SimReport {
    run_with(net, dag, &SimConfig::default())
}

/// Execute the DAG with an explicit [`SimConfig`].
pub fn run_with(net: &SimNet, dag: &StageDag, cfg: &SimConfig) -> SimReport {
    debug_assert!(
        crate::verify::audit::stage_dag_check(dag).is_ok(),
        "defective stage DAG: {}",
        crate::verify::audit::stage_dag_check(dag).unwrap_err()
    );
    run_faulted(net, dag, cfg, &FaultPlan::default())
}

// ----------------------------------------------------------------------
// Component-parallel advancement (PR 10)
// ----------------------------------------------------------------------

/// Worker configuration for [`run_components`] and friends.
#[derive(Clone, Debug)]
pub struct ParallelConfig {
    /// Worker threads (≥ 1; clamped to the component count). Defaults
    /// to the machine's parallelism.
    pub workers: usize,
    /// Re-solve strategy for every component's solver.
    pub strategy: ResolveStrategy,
}

impl Default for ParallelConfig {
    fn default() -> ParallelConfig {
        ParallelConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            strategy: ResolveStrategy::default(),
        }
    }
}

impl ParallelConfig {
    /// Single-worker loop (the determinism baseline).
    pub fn serial() -> ParallelConfig {
        ParallelConfig {
            workers: 1,
            ..ParallelConfig::default()
        }
    }

    pub fn with_workers(mut self, workers: usize) -> ParallelConfig {
        self.workers = workers.max(1);
        self
    }

    pub fn with_strategy(mut self, strategy: ResolveStrategy) -> ParallelConfig {
        self.strategy = strategy;
        self
    }
}

/// Work-distribution loop shared by the component runners: run `job(i)`
/// for every `i < n` on `workers` threads, results in input order. The
/// same shape as [`super::sweep::sweep`] minus the per-scenario RNG —
/// determinism holds because each job is a pure function of its index,
/// never of thread assignment.
fn component_sweep<R, F>(workers: usize, n: usize, job: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(job).collect();
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = job(i);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("component produced no result")
        })
        .collect()
}

/// Advance independent components on worker threads: execute each DAG
/// of `dags` as its own event loop with its own max-min solver,
/// returning the per-component [`SimReport`]s in input order.
///
/// **Precondition**: the components must be *channel-disjoint* — no
/// two DAGs route a flow over the same link. Max-min fairness factors
/// across connected components (no shared channel → no shared
/// constraint), and the event loops share no other state, so the union
/// of the independent runs is exactly the allocation and timing the
/// one big serial loop over the combined DAG would compute — and
/// because each component's run is a pure function of
/// `(net, dag, strategy)`, the result vector is **bit-identical at any
/// worker count**: workers only decide *where* a component runs, never
/// *what* it computes. The caller owns the merge semantics (e.g.
/// `workload::symmetric` gates the DP tail on the max component
/// makespan and sums byte-hops/events/solver counters in input order);
/// the property tests in `rust/tests/properties.rs` pin the
/// bit-equality across worker counts and solver strategies.
pub fn run_components(net: &SimNet, dags: &[StageDag], cfg: &ParallelConfig) -> Vec<SimReport> {
    let sim_cfg = SimConfig {
        strategy: cfg.strategy,
    };
    component_sweep(cfg.workers, dags.len(), |i| {
        run_with(net, &dags[i], &sim_cfg)
    })
}

/// [`run_components`] under per-component [`FaultPlan`]s — `plans[i]`
/// applies to `dags[i]` only. The channel-disjointness precondition
/// extends to the plans: a fault event may touch any link, but if a
/// faulted link carries flows of *another* component, the serial
/// equivalence argument breaks and the caller has mis-partitioned.
pub fn run_components_faulted(
    net: &SimNet,
    dags: &[StageDag],
    cfg: &ParallelConfig,
    plans: &[FaultPlan],
) -> Vec<SimReport> {
    assert_eq!(dags.len(), plans.len(), "one fault plan per component");
    let sim_cfg = SimConfig {
        strategy: cfg.strategy,
    };
    component_sweep(cfg.workers, dags.len(), |i| {
        run_faulted(net, &dags[i], &sim_cfg, &plans[i])
    })
}

/// [`run_components`] plus per-component wall-clock seconds — the
/// telemetry behind the `fig22.par.*` speedup keys (serial-equivalent
/// wall = Σ component walls). The clock reads never feed back into the
/// simulation — the reports stay bit-identical to [`run_components`] —
/// which is why this, uniquely in the sim core, carries a scoped
/// exemption from the wall-clock determinism lint.
pub fn run_components_timed(
    net: &SimNet,
    dags: &[StageDag],
    cfg: &ParallelConfig,
) -> Vec<(SimReport, f64)> {
    let sim_cfg = SimConfig {
        strategy: cfg.strategy,
    };
    component_sweep(cfg.workers, dags.len(), |i| {
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now();
        let report = run_with(net, &dags[i], &sim_cfg);
        (report, t0.elapsed().as_secs_f64())
    })
}

/// Earliest time flow `i` may be rerouted: every dead link on its path
/// must have converged routing tables, and a backup substitution must
/// wait for the backup NPU's activation.
fn reroute_ready_at(
    i: usize,
    now: f64,
    active: &[ActiveFlow],
    rates: &Rates,
    net: &SimNet,
    table_at: &BTreeMap<LinkId, f64>,
    npu_backup: &BTreeMap<NodeId, (NodeId, f64)>,
) -> f64 {
    let mut at = now;
    let chans: &[Channel] = match (&active[i].channels, active[i].solver_id) {
        (Some(c), _) => c,
        (None, Some(id)) => rates.channels(id),
        (None, None) => &[],
    };
    for c in chans {
        if !net.is_usable(c.link) {
            // Links down since before the run have no entry: their
            // tables are treated as already converged.
            if let Some(&t_upd) = table_at.get(&c.link) {
                at = at.max(t_upd);
            }
        }
    }
    for nid in [active[i].src, active[i].dst] {
        if let Some(&(_, active_at)) = npu_backup.get(&nid) {
            at = at.max(active_at);
        }
    }
    at
}

/// Execute the DAG under a scripted [`FaultPlan`] (see the module docs
/// for the fault/recovery semantics). The caller's `net` is never
/// mutated — fault events apply to a private clone.
pub fn run_faulted(
    net: &SimNet,
    dag: &StageDag,
    cfg: &SimConfig,
    plan: &FaultPlan,
) -> SimReport {
    // Only a plan with events ever mutates capacities; the common
    // fault-free path (`run`/`run_with`) borrows the caller's net
    // instead of copying the O(channels) capacity state per run.
    let mut net: std::borrow::Cow<SimNet> = if plan.events.is_empty() {
        std::borrow::Cow::Borrowed(net)
    } else {
        std::borrow::Cow::Owned(net.clone())
    };
    let topo: &Topology = net.topo;
    let n = dag.stages.len();
    let mut dep_left: Vec<usize> = dag.stages.iter().map(|s| s.deps.len()).collect();
    let mut dependants: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, s) in dag.stages.iter().enumerate() {
        for &d in &s.deps {
            assert!(d < n, "dep out of range");
            dependants[d].push(i);
        }
    }

    let mut stage_done = vec![f64::NAN; n];
    let mut flows_left: Vec<usize> = dag.stages.iter().map(|s| s.flow_count()).collect();
    let mut compute_done_at: Vec<f64> = vec![f64::NAN; n];
    let mut started = vec![false; n];
    let mut done_count = 0usize;

    let mut active: Vec<ActiveFlow> = Vec::new();
    let mut free_slots: Vec<usize> = Vec::new();
    let mut rates = Rates::with_strategy(cfg.strategy);
    // Reverse map: solver FlowId → index in `active` (MAX = free).
    let mut sid_to_active: Vec<usize> = Vec::new();
    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut now = 0.0f64;
    let mut events = 0u64;
    let mut byte_hops = 0.0f64;
    let mut alive = 0usize;
    let mut peak = 0usize;
    // Fault-plan state: per-link routing-table convergence times and
    // dead-NPU → (backup, activation time) substitutions.
    let mut table_at: BTreeMap<LinkId, f64> = BTreeMap::new();
    let mut npu_backup: BTreeMap<NodeId, (NodeId, f64)> = BTreeMap::new();
    // Flap-damping memory: every link-down instant is recorded; reroute
    // path selection consults it only when the plan's RecoveryConfig
    // enables a hysteresis window.
    let mut flap = FlapDamper::new();
    let mut reroutes_done = 0u64;
    let mut fault_count = 0u64;
    for (k, ev) in plan.events.iter().enumerate() {
        heap.push(Ev {
            t: ev.0,
            kind: EvKind::Fault(k),
        });
    }

    // Spawn one gated flow into a (possibly recycled) slot. All inputs
    // are evaluated before any local binding — the caller's expressions
    // may reference names this macro would otherwise shadow.
    macro_rules! spawn_flow {
        ($stage:expr, $bytes:expr, $latency:expr, $channels:expr, $src:expr, $dst:expr) => {{
            let spawn_stage: usize = $stage;
            let spawn_bytes: f64 = $bytes;
            let gate = now + $latency;
            let channels: Vec<Channel> = $channels;
            let spawn_src: NodeId = $src;
            let spawn_dst: NodeId = $dst;
            let slot = match free_slots.pop() {
                Some(s) => s,
                None => {
                    active.push(ActiveFlow::default());
                    active.len() - 1
                }
            };
            let slot_f = &mut active[slot];
            slot_f.stage = spawn_stage;
            slot_f.src = spawn_src;
            slot_f.dst = spawn_dst;
            slot_f.hops = channels.len() as f64;
            slot_f.channels = Some(channels);
            slot_f.remaining_bytes = spawn_bytes;
            slot_f.rate_gb_s = 0.0;
            slot_f.settled_us = gate;
            slot_f.solver_id = None;
            slot_f.done = false;
            slot_f.stamp += 1; // fence off events for the previous occupant
            alive += 1;
            heap.push(Ev {
                t: gate,
                kind: EvKind::Gate(slot),
            });
        }};
    }

    // Start a stage: materialize + spawn its gated flows, compute event.
    macro_rules! start_stage {
        ($i:expr) => {{
            let i = $i;
            debug_assert!(!started[i]);
            started[i] = true;
            match &dag.stages[i].flows {
                StageFlows::Empty => {}
                StageFlows::Eager(v) => {
                    for f in v {
                        spawn_flow!(i, f.bytes, f.latency_us, f.channels.clone(), f.src, f.dst);
                    }
                }
                StageFlows::Lazy { build, count, .. } => {
                    let v = build(topo);
                    assert_eq!(
                        v.len(),
                        *count,
                        "lazy stage '{}' declared {} flows but built {}",
                        dag.stages[i].name,
                        count,
                        v.len()
                    );
                    for f in v {
                        // Move the channel vectors: the materialized
                        // stage is dropped right here, not retained.
                        spawn_flow!(i, f.bytes, f.latency_us, f.channels, f.src, f.dst);
                    }
                }
            }
            peak = peak.max(alive);
            compute_done_at[i] = now + dag.stages[i].compute_us;
            if dag.stages[i].compute_us > 0.0 {
                heap.push(Ev {
                    t: compute_done_at[i],
                    kind: EvKind::Compute(i),
                });
            }
            events += 1;
        }};
    }

    // Settle a flow's drained bytes up to `t` at its current rate.
    macro_rules! settle {
        ($f:expr, $t:expr) => {{
            let f = &mut *$f; // reborrow: caller keeps its &mut afterwards
            if !f.done && f.solver_id.is_some() {
                let dt = $t - f.settled_us;
                if dt > 0.0 && f.rate_gb_s > 0.0 {
                    let drained = (f.rate_gb_s * 1e3 * dt).min(f.remaining_bytes);
                    f.remaining_bytes -= drained;
                    byte_hops += drained * f.hops;
                }
            }
            f.settled_us = $t;
        }};
    }

    for i in 0..n {
        if dep_left[i] == 0 {
            start_stage!(i);
        }
    }

    loop {
        // Settle stage completions at the current instant (fixpoint:
        // zero-duration stages may cascade, starting new stages now).
        loop {
            let mut changed = false;
            for i in 0..n {
                if started[i]
                    && stage_done[i].is_nan()
                    && flows_left[i] == 0
                    && compute_done_at[i] <= now + 1e-9
                {
                    stage_done[i] = now;
                    done_count += 1;
                    events += 1;
                    changed = true;
                    for k in 0..dependants[i].len() {
                        let d = dependants[i][k];
                        dep_left[d] -= 1;
                        if dep_left[d] == 0 {
                            start_stage!(d);
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
        if done_count == n {
            break;
        }

        // ---- next event batch (lazy deletion + simultaneity merge) ----
        let t0 = loop {
            match heap.pop() {
                None => break f64::NAN,
                Some(ev) => {
                    if let EvKind::FlowDone(i, stamp) | EvKind::Reroute(i, stamp) = ev.kind {
                        if active[i].done || active[i].stamp != stamp {
                            continue; // stale
                        }
                    }
                    heap.push(ev); // fresh: put back, pop in the batch loop
                    break heap.peek().unwrap().t;
                }
            }
        };
        if t0.is_nan() {
            break; // queue drained with stages outstanding → stalled
        }
        now = now.max(t0);
        let batch_eps = 1e-9 * now.abs().max(1.0);

        let mut opened: Vec<usize> = Vec::new(); // active idx joining solver
        let mut completed: Vec<usize> = Vec::new(); // active idx finishing
        let mut faults: Vec<usize> = Vec::new(); // plan event idx
        let mut reroute_req: Vec<usize> = Vec::new(); // active idx to re-path
        while let Some(ev) = heap.peek() {
            if ev.t > t0 + batch_eps {
                break;
            }
            let ev = heap.pop().unwrap();
            match ev.kind {
                EvKind::Gate(i) => {
                    if active[i].remaining_bytes <= REMNANT_BYTES {
                        // Degenerate zero-byte flow: completes at the gate.
                        completed.push(i);
                    } else {
                        opened.push(i);
                    }
                    events += 1;
                }
                EvKind::FlowDone(i, stamp) => {
                    if active[i].done || active[i].stamp != stamp {
                        continue; // stale entry, lazily deleted
                    }
                    completed.push(i);
                    events += 1;
                }
                EvKind::Compute(_) => {
                    events += 1; // handled by the settle fixpoint above
                }
                EvKind::Fault(k) => {
                    faults.push(k);
                    events += 1;
                    fault_count += 1;
                }
                EvKind::Reroute(i, stamp) => {
                    if active[i].done || active[i].stamp != stamp {
                        continue; // revived or already rerouted: stale
                    }
                    reroute_req.push(i);
                    events += 1;
                }
            }
        }

        // ---- apply the batch to the solver ----------------------------
        for &i in &completed {
            let f = &mut active[i];
            settle!(f, now);
            // Credit the fp remnant so byte-hop conservation holds exactly.
            if f.remaining_bytes > 0.0 {
                byte_hops += f.remaining_bytes * f.hops;
                f.remaining_bytes = 0.0;
            }
            f.done = true;
            f.stamp += 1;
            // An un-gated degenerate flow still owns its channel vector;
            // drop it now so recycled slots don't hoard memory.
            f.channels = None;
            alive -= 1;
            flows_left[f.stage] -= 1;
        }
        let mut done_ids: Vec<FlowId> = Vec::with_capacity(completed.len());
        for &i in &completed {
            if let Some(id) = active[i].solver_id.take() {
                sid_to_active[id] = usize::MAX;
                done_ids.push(id);
            }
        }
        if !done_ids.is_empty() {
            rates.remove_flows(&net, &done_ids);
            byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
        }
        if !opened.is_empty() {
            // Register the newly-gated flows in one call.
            let chans: Vec<Vec<Channel>> = opened
                .iter()
                .map(|&i| active[i].channels.take().expect("gate fired twice"))
                .collect();
            let refs: Vec<&[Channel]> = chans.iter().map(|c| c.as_slice()).collect();
            let ids = rates.add_flows(&net, &refs);
            for (&i, id) in opened.iter().zip(ids) {
                active[i].solver_id = Some(id);
                active[i].settled_us = now;
                if sid_to_active.len() <= id {
                    sid_to_active.resize(id + 1, usize::MAX);
                }
                sid_to_active[id] = i;
            }
            byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
            // A flow that gated onto an already-dead channel sits at
            // rate 0: with recovery, its source re-routes as soon as
            // the failed links' tables have converged (immediately, for
            // faults that converged before this gate).
            if plan.recovery.is_some() {
                for &i in &opened {
                    let Some(id) = active[i].solver_id else { continue };
                    if rates.rate(id) > 0.0 {
                        continue;
                    }
                    let at = reroute_ready_at(
                        i, now, &active, &rates, &net, &table_at, &npu_backup,
                    );
                    heap.push(Ev {
                        t: at.max(now),
                        kind: EvKind::Reroute(i, active[i].stamp),
                    });
                }
            }
        }
        // ---- scripted fault events ------------------------------------
        if !faults.is_empty() {
            // Same-instant events apply in FaultPlan order, not heap
            // tie-break order (plan indices are append-ordered).
            faults.sort_unstable();
            let mut changed: Vec<LinkId> = Vec::new();
            for &k in &faults {
                match &plan.events[k].1 {
                    FaultEvent::LinkDown(l) => {
                        net.to_mut().fail_link(*l);
                        flap.record_down(*l, now);
                        changed.push(*l);
                    }
                    FaultEvent::LinkUp(l) => {
                        net.to_mut().restore_link(*l);
                        changed.push(*l);
                    }
                    FaultEvent::LinkCapacity(l, gb_s) => {
                        net.to_mut().set_link_capacity(*l, *gb_s);
                        if *gb_s == 0.0 {
                            flap.record_down(*l, now);
                        }
                        changed.push(*l);
                    }
                    FaultEvent::NpuDown { npu, backup } => {
                        for &(_, l) in topo.neighbors(*npu) {
                            if !net.is_down(l) {
                                net.to_mut().fail_link(l);
                                flap.record_down(l, now);
                                changed.push(l);
                            }
                        }
                        if let Some((b, activation_us)) = backup {
                            npu_backup.insert(*npu, (*b, now + *activation_us));
                        }
                    }
                }
            }
            // Push the capacity changes through the bounded mid-run
            // re-solve; touched flows re-settle at their old rate first.
            rates.links_changed(&net, &changed);
            byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
            if let Some(rc) = &plan.recovery {
                // Flows the fault cut off (re-solved to rate 0 on a dead
                // channel), grouped by dead link for the §4.2
                // notification model: the affected sources determine
                // each link's convergence latency.
                let mut affected_by_link: BTreeMap<LinkId, Vec<NodeId>> = BTreeMap::new();
                let mut cut: Vec<usize> = Vec::new();
                for &fid in rates.touched() {
                    let i = sid_to_active.get(fid).copied().unwrap_or(usize::MAX);
                    if i == usize::MAX || active[i].done || rates.rate(fid) > 0.0 {
                        continue;
                    }
                    cut.push(i);
                    for c in rates.channels(fid) {
                        if !net.is_usable(c.link) {
                            affected_by_link
                                .entry(c.link)
                                .or_default()
                                .push(active[i].src);
                        }
                    }
                }
                for &l in &changed {
                    if !net.is_usable(l) {
                        let empty: Vec<NodeId> = Vec::new();
                        let srcs = affected_by_link.get(&l).unwrap_or(&empty);
                        let conv = rc.convergence_us(topo, l, srcs);
                        table_at.insert(l, now + conv);
                    } else {
                        table_at.remove(&l);
                    }
                }
                for &i in &cut {
                    let at = reroute_ready_at(
                        i, now, &active, &rates, &net, &table_at, &npu_backup,
                    );
                    heap.push(Ev {
                        t: at.max(now),
                        kind: EvKind::Reroute(i, active[i].stamp),
                    });
                }
                // A restore can open a detour for a flow whose own
                // links stayed dead (its earlier reroute found no live
                // path and gave up) — such flows are not in `touched`,
                // so rescan every still-blocked flow and retry.
                // Duplicate events are harmless: the done-guard, stamp
                // fencing and the revived-rate check at processing make
                // extra reroute events no-ops.
                if changed.iter().any(|&l| net.is_usable(l)) {
                    for i in 0..active.len() {
                        let f = &active[i];
                        if f.done {
                            continue;
                        }
                        let Some(id) = f.solver_id else { continue };
                        if rates.rate(id) > 0.0 {
                            continue;
                        }
                        let at = reroute_ready_at(
                            i, now, &active, &rates, &net, &table_at, &npu_backup,
                        );
                        heap.push(Ev {
                            t: at.max(now),
                            kind: EvKind::Reroute(i, active[i].stamp),
                        });
                    }
                }
            }
        }
        // ---- mid-flight APR reroutes ----------------------------------
        if !reroute_req.is_empty() {
            let rc = plan
                .recovery
                .as_ref()
                .expect("reroute event without recovery config");
            let mut retired_ids: Vec<FlowId> = Vec::new();
            let mut respawns: Vec<(usize, f64, Vec<NodeId>)> = Vec::new();
            for &i in &reroute_req {
                // Two reroute events for one flow can land in the same
                // batch (a second fault re-schedules a still-cut flow
                // at a coinciding convergence time); the first retires
                // it, the rest are no-ops.
                if active[i].done {
                    continue;
                }
                // A restore may have revived the flow since (same-batch
                // LinkUp: the stamp only fences rate *changes*).
                if let Some(id) = active[i].solver_id {
                    if rates.rate(id) > 0.0 {
                        continue;
                    }
                }
                // Ready time is authoritative at *fire* time: a later
                // fault may have cut the same flow with a slower
                // convergence (its rate stayed 0, so no stamp bump
                // invalidated this event) — rerouting now would dodge a
                // failure the source has not been notified of yet.
                let at = reroute_ready_at(
                    i, now, &active, &rates, &net, &table_at, &npu_backup,
                );
                if at > now + batch_eps {
                    heap.push(Ev {
                        t: at,
                        kind: EvKind::Reroute(i, active[i].stamp),
                    });
                    continue;
                }
                let src = npu_backup.get(&active[i].src).map_or(active[i].src, |&(b, _)| b);
                let dst = npu_backup.get(&active[i].dst).map_or(active[i].dst, |&(b, _)| b);
                if src == dst {
                    // Backup substitution collapsed the endpoints (the
                    // flow targeted the node that now replaces its
                    // source, or two dead NPUs share one backup): the
                    // transfer is local, deliver it on the spot.
                    let f = &mut active[i];
                    f.remaining_bytes = 0.0; // zero hops: no wire bytes
                    f.done = true;
                    f.stamp += 1;
                    f.channels = None;
                    alive -= 1;
                    flows_left[f.stage] -= 1;
                    if let Some(id) = f.solver_id.take() {
                        sid_to_active[id] = usize::MAX;
                        retired_ids.push(id);
                    }
                    free_slots.push(i);
                    reroutes_done += 1;
                    continue;
                }
                // Flap damping: when a hysteresis window is configured,
                // first try a path avoiding links that went down inside
                // the window (a recently-flapped link is likely to flap
                // again and cut this flow right back). The avoidance
                // pass is the built-in live-link BFS; the configured
                // policy (Shortest or Custom) remains the authoritative
                // fallback, so damping never blocks a pair the raw
                // policy could route.
                let hyst = rc.flap_hysteresis_us;
                let picked = if hyst > 0.0 {
                    topo.shortest_path_filtered(src, dst, rc.npu_routable, |l| {
                        net.is_usable(l) && !flap.suppressed(l, now, hyst)
                    })
                    .or_else(|| rc.reroute.path(topo, &net, src, dst, rc.npu_routable))
                } else {
                    rc.reroute.path(topo, &net, src, dst, rc.npu_routable)
                };
                let Some(path) = picked else {
                    // Disconnected: leave the flow blocked — a later
                    // LinkUp may revive it, else the stall report names
                    // it.
                    continue;
                };
                debug_assert!(path.len() >= 2, "reroute returned a hopless path");
                let f = &mut active[i];
                settle!(f, now);
                let stage = f.stage;
                let rem = f.remaining_bytes;
                f.done = true;
                f.stamp += 1;
                f.channels = None;
                f.remaining_bytes = 0.0;
                alive -= 1;
                if let Some(id) = f.solver_id.take() {
                    sid_to_active[id] = usize::MAX;
                    retired_ids.push(id);
                }
                free_slots.push(i);
                respawns.push((stage, rem, path));
                reroutes_done += 1;
            }
            if !retired_ids.is_empty() {
                rates.remove_flows(&net, &retired_ids);
                byte_hops += retime(&mut active, &sid_to_active, &rates, now, &mut heap);
            }
            // Respawn with the remaining payload on the new path; the
            // stage's flow accounting is untouched (retire + respawn is
            // net zero), so the stage completes when the replacement
            // drains.
            for (stage, rem, path) in respawns {
                let spec = FlowSpec::along(topo, &path, rem);
                spawn_flow!(stage, spec.bytes, spec.latency_us, spec.channels, spec.src, spec.dst);
            }
            peak = peak.max(alive);
        }
        // Recycle the completed slots for stages started at the next
        // settle fixpoint. (Safe: their stamps were bumped above, so any
        // still-queued event for them is stale.)
        free_slots.extend_from_slice(&completed);
    }

    // ---- stall analysis / report --------------------------------------
    let mut stalled: Vec<StalledFlow> = Vec::new();
    if done_count < n {
        for f in &active {
            if f.done {
                continue;
            }
            let chans: &[Channel] = match (&f.channels, f.solver_id) {
                (Some(c), _) => c,
                (None, Some(id)) => rates.channels(id),
                (None, None) => &[],
            };
            let mut dead_links: Vec<LinkId> = Vec::new();
            for c in chans {
                if !net.is_usable(c.link) && !dead_links.contains(&c.link) {
                    dead_links.push(c.link);
                }
            }
            stalled.push(StalledFlow {
                stage: f.stage,
                src: f.src,
                dst: f.dst,
                remaining_bytes: f.remaining_bytes,
                dead_links,
            });
        }
        // No blocked flow to blame: the schedule itself is broken.
        assert!(
            !stalled.is_empty(),
            "DAG stalled with no blocked flows: {done_count}/{n} stages done at t={now}µs \
             (cyclic deps?)"
        );
    }
    SimReport {
        makespan_us: if stalled.is_empty() { now } else { f64::INFINITY },
        stalled_at_us: now,
        stage_done_us: stage_done,
        byte_hops,
        events,
        peak_flows: peak,
        stalled,
        reroutes: reroutes_done,
        fault_events: fault_count,
        solver: rates.stats().clone(),
    }
}

/// After a solver change: re-settle every touched flow at its old rate
/// (returning the byte-hops drained in the process), adopt the new rate,
/// and push a fresh completion prediction. The old heap entry is
/// invalidated by the stamp bump — lazy deletion, no queue rebuild.
fn retime(
    active: &mut [ActiveFlow],
    sid_to_active: &[usize],
    rates: &Rates,
    now: f64,
    heap: &mut BinaryHeap<Ev>,
) -> f64 {
    let mut byte_hops = 0.0;
    for &fid in rates.touched() {
        let i = sid_to_active.get(fid).copied().unwrap_or(usize::MAX);
        if i == usize::MAX {
            continue; // removed in this same batch
        }
        let f = &mut active[i];
        let new_rate = rates.rate(fid);
        if new_rate == f.rate_gb_s {
            // Unchanged rate → the pending completion prediction is
            // still exact; leave the heap entry alone (no churn).
            continue;
        }
        // Settle at the old rate up to now before the new rate applies.
        let dt = now - f.settled_us;
        if dt > 0.0 && f.rate_gb_s > 0.0 {
            let drained = (f.rate_gb_s * 1e3 * dt).min(f.remaining_bytes);
            f.remaining_bytes -= drained;
            byte_hops += drained * f.hops;
        }
        f.settled_us = now;
        f.rate_gb_s = new_rate;
        f.stamp += 1;
        if f.remaining_bytes <= REMNANT_BYTES {
            // Already (numerically) drained: complete at once.
            heap.push(Ev {
                t: now,
                kind: EvKind::FlowDone(i, f.stamp),
            });
        } else if new_rate > 0.0 {
            heap.push(Ev {
                t: now + f.remaining_bytes / (new_rate * 1e3),
                kind: EvKind::FlowDone(i, f.stamp),
            });
        }
        // rate 0 (blocked): no completion event — a scheduled reroute
        // revives the flow, a LinkUp re-solve restores it, or the
        // structured stall report names it.
    }
    byte_hops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::{CableClass, NodeId, Topology};

    fn k4() -> Topology {
        // K4 full-mesh, x8 lanes = 50 GB/s per link direction.
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn single_flow_time_matches_closed_form() {
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6; // 500 MB over 50 GB/s = 10_000 µs
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            bytes,
        )]));
        let r = run(&net, &dag);
        let expect = bytes / (50.0 * 1e3);
        assert!(
            (r.makespan_us - expect).abs() / expect < 0.01,
            "{} vs {expect}",
            r.makespan_us
        );
    }

    #[test]
    fn two_flows_on_one_link_take_twice_as_long() {
        let t = k4();
        let net = SimNet::new(&t);
        let f = |_| FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![f(0), f(1)]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    fn dependencies_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mk = || FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        let a = dag.push(Stage::new("a").with_flows(vec![mk()]));
        dag.push(Stage::new("b").with_flows(vec![mk()]).after(vec![a]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
        assert!(r.stage_done_us[0] < r.stage_done_us[1]);
    }

    #[test]
    fn compute_only_stage() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("gemm").with_compute(123.0));
        let r = run(&net, &dag);
        assert!((r.makespan_us - 123.0).abs() < 1e-6);
    }

    #[test]
    fn compute_overlaps_communication() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(
            Stage::new("overlap")
                .with_flows(vec![FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6)])
                .with_compute(20_000.0),
        );
        let r = run(&net, &dag);
        // max(10_000 comm, 20_000 compute) ≈ 20_000.
        assert!((r.makespan_us - 20_000.0).abs() < 50.0, "{}", r.makespan_us);
    }

    #[test]
    fn parallel_disjoint_flows_dont_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("par").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6),
            FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 500e6),
        ]));
        let r = run(&net, &dag);
        let expect = 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    fn empty_dag_is_a_noop() {
        let t = k4();
        let net = SimNet::new(&t);
        let r = run(&net, &StageDag::default());
        assert_eq!(r.makespan_us, 0.0);
        assert_eq!(r.events, 0);
    }

    #[test]
    fn completion_frees_bandwidth_for_survivors() {
        // Small flow + big flow share a link; once the small one drains,
        // the big one must speed up to the full link (the incremental
        // re-solve in action). Closed form: both at 25 GB/s until the
        // 100 MB flow ends (t1 = 100e6/25e3 = 4000 µs), then the 900 MB
        // flow finishes its remaining 800 MB at 50 GB/s (16_000 µs more).
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("pair").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 100e6),
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 900e6),
        ]));
        let r = run(&net, &dag);
        let expect = 4000.0 + 16_000.0;
        assert!(
            (r.makespan_us - expect).abs() / expect < 0.01,
            "{} vs {expect}",
            r.makespan_us
        );
    }

    #[test]
    fn both_strategies_produce_identical_reports() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        let a = dag.push(Stage::new("a").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 100e6),
            FlowSpec::along(&t, &[NodeId(0), NodeId(1), NodeId(2)], 250e6),
            FlowSpec::along(&t, &[NodeId(1), NodeId(2)], 400e6),
        ]));
        dag.push(
            Stage::new("b")
                .with_flows(vec![FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 80e6)])
                .after(vec![a]),
        );
        let rise = run_with(&net, &dag, &SimConfig::default());
        let bfs = run_with(
            &net,
            &dag,
            &SimConfig {
                strategy: ResolveStrategy::FullComponentBfs,
            },
        );
        assert!((rise.makespan_us - bfs.makespan_us).abs() < 1e-6 * bfs.makespan_us);
        assert!((rise.byte_hops - bfs.byte_hops).abs() < 1e-6 * bfs.byte_hops);
        assert_eq!(rise.peak_flows, bfs.peak_flows);
    }

    #[test]
    fn lazy_stage_materializes_and_matches_eager() {
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6;
        let mut lazy = StageDag::default();
        lazy.push(Stage::new("xfer").with_lazy_flows(2, 2.0 * bytes, move |t| {
            vec![
                FlowSpec::along(t, &[NodeId(0), NodeId(1)], bytes),
                FlowSpec::along(t, &[NodeId(2), NodeId(3)], bytes),
            ]
        }));
        assert!(lazy.stages[0].is_lazy());
        assert_eq!(lazy.stages[0].flow_count(), 2);
        assert!((lazy.total_bytes() - 2.0 * bytes).abs() < 1.0);
        let r1 = run(&net, &lazy);
        let r2 = run(&net, &lazy.materialized(&t));
        assert_eq!(r1.makespan_us, r2.makespan_us);
        assert_eq!(r1.byte_hops, r2.byte_hops);
        assert_eq!(r1.events, r2.events);
    }

    #[test]
    #[should_panic(expected = "declared 3 flows but built 2")]
    fn lazy_stage_count_mismatch_panics() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("bad").with_lazy_flows(3, 1e6, |t| {
            vec![
                FlowSpec::along(t, &[NodeId(0), NodeId(1)], 5e5),
                FlowSpec::along(t, &[NodeId(1), NodeId(2)], 5e5),
            ]
        }));
        run(&net, &dag);
    }

    #[test]
    fn flow_slots_are_recycled_across_stages() {
        // 6 serial stages of 2 flows each: peak concurrency is 2, so the
        // active table should recycle instead of growing 12 slots.
        let t = k4();
        let net = SimNet::new(&t);
        let mut stages = Vec::new();
        for k in 0..6 {
            stages.push(Stage::new(format!("s{k}")).with_flows(vec![
                FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 10e6),
                FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 10e6),
            ]));
        }
        let dag = StageDag::chain(stages);
        let r = run(&net, &dag);
        assert_eq!(r.peak_flows, 2);
        assert!((r.byte_hops - 12.0 * 10e6).abs() < 1.0);
    }

    /// Satellite fix: a flow sitting on a zero-capacity channel used to
    /// panic the runner ("DAG stalled"); now the run ends in a
    /// structured stall report naming the flow and its dead link.
    #[test]
    fn failed_link_stalls_and_reports() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        net.fail_link(l);
        let mut dag = StageDag::default();
        dag.push(Stage::new("x").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            1e6,
        )]));
        let r = run(&net, &dag);
        assert!(r.is_stalled());
        assert!(r.makespan_us.is_infinite());
        assert_eq!(r.stalled.len(), 1);
        let s = &r.stalled[0];
        assert_eq!(s.stage, 0);
        assert_eq!((s.src, s.dst), (NodeId(0), NodeId(1)));
        assert_eq!(s.dead_links, vec![l]);
        assert!((s.remaining_bytes - 1e6).abs() < 1.0, "{}", s.remaining_bytes);
        assert!(r.stage_done_us[0].is_nan());
    }

    #[test]
    #[should_panic(expected = "defective stage DAG")]
    fn cyclic_deps_still_panic() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        // 0 depends on 1 and 1 on 0: neither ever starts. The
        // verify::audit self-check in run_with rejects it up front.
        dag.push(Stage::new("a").with_compute(1.0).after(vec![1]));
        dag.push(Stage::new("b").with_compute(1.0).after(vec![0]));
        run(&net, &dag);
    }

    /// Mid-run fault with recovery: the flow loses its link halfway,
    /// reroutes after the convergence latency, and finishes on a detour
    /// — makespan sits strictly between the healthy run and the
    /// stall-until-restore naive bound.
    #[test]
    fn midrun_fault_reroutes_and_completes() {
        use crate::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6; // healthy: 10_000 µs at 50 GB/s
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            bytes,
        )]));
        let healthy = run(&net, &dag);

        let t_fail = 4_000.0;
        let t_restore = 60_000.0;
        let faults = FaultPlan::new()
            .at(t_fail, FaultEvent::LinkDown(l))
            .at(t_restore, FaultEvent::LinkUp(l));

        // Naive bound: no recovery — the flow stalls until the restore.
        let stall = run_faulted(&net, &dag, &SimConfig::default(), &faults);
        assert!(!stall.is_stalled(), "LinkUp must revive the flow");
        assert!(stall.makespan_us > t_restore, "{}", stall.makespan_us);

        // Recovered: the flow reroutes onto a 2-hop detour whose links
        // are idle, so it drains at the full 50 GB/s — only the
        // convergence latency and the re-gate delay are lost.
        let rec = run_faulted(
            &net,
            &dag,
            &SimConfig::default(),
            &faults.clone().with_recovery(RecoveryConfig::direct()),
        );
        assert!(!rec.is_stalled());
        assert_eq!(rec.reroutes, 1);
        // Only the LinkDown fires: the rerouted run completes long
        // before the scripted restore.
        assert_eq!(rec.fault_events, 1);
        assert!(
            rec.makespan_us > healthy.makespan_us,
            "rerouted {} vs healthy {}",
            rec.makespan_us,
            healthy.makespan_us
        );
        assert!(
            rec.makespan_us < stall.makespan_us,
            "rerouted {} vs stall bound {}",
            rec.makespan_us,
            stall.makespan_us
        );
        // Byte conservation across the reroute: 4000µs × 50 GB/s drained
        // direct (1 hop), the remaining 300 MB drained over 2 hops.
        let drained_direct = 4_000.0 * 50.0 * 1e3;
        let expect_hops = drained_direct + (bytes - drained_direct) * 2.0;
        assert!(
            (rec.byte_hops - expect_hops).abs() / expect_hops < 0.01,
            "byte-hops {} vs {expect_hops}",
            rec.byte_hops
        );
    }

    /// A fault landing before a stage's gate opens: the gated flow finds
    /// its path dead at open time and reroutes immediately (tables have
    /// long converged).
    #[test]
    fn gate_onto_dead_link_reroutes() {
        use crate::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
        let t = k4();
        let net = SimNet::new(&t);
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        let mut dag = StageDag::default();
        let a = dag.push(Stage::new("warmup").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(2), NodeId(3)],
            100e6,
        )]));
        dag.push(
            Stage::new("xfer")
                .with_flows(vec![FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 100e6)])
                .after(vec![a]),
        );
        // Link 0-1 dies during warmup, long before stage 2's gate.
        let plan = FaultPlan::new()
            .at(10.0, FaultEvent::LinkDown(l))
            .with_recovery(RecoveryConfig::direct());
        let r = run_faulted(&net, &dag, &SimConfig::default(), &plan);
        assert!(!r.is_stalled());
        assert_eq!(r.reroutes, 1);
        // The rerouted second stage drains 100 MB over a 2-hop detour.
        let warmup = 100e6 / (50.0 * 1e3);
        assert!(r.makespan_us >= 2.0 * warmup, "{}", r.makespan_us);
    }

    /// Review fix: a `LinkCapacity(l, 0.0)` rescale is a failure for
    /// recovery purposes — the reroute must leave the zero-bandwidth
    /// link (not re-select it forever), and without recovery the stall
    /// report names it.
    #[test]
    fn zero_capacity_rescale_reroutes_off_the_dead_link() {
        use crate::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
        let t = k4();
        let net = SimNet::new(&t);
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            500e6,
        )]));
        let faults = FaultPlan::new().at(4_000.0, FaultEvent::LinkCapacity(l, 0.0));
        let rec = run_faulted(
            &net,
            &dag,
            &SimConfig::default(),
            &faults.clone().with_recovery(RecoveryConfig::direct()),
        );
        assert!(!rec.is_stalled());
        assert_eq!(rec.reroutes, 1);
        let stall = run_faulted(&net, &dag, &SimConfig::default(), &faults);
        assert!(stall.is_stalled());
        assert_eq!(stall.stalled[0].dead_links, vec![l]);
    }

    /// Review fix: backup substitution can collapse a flow's endpoints
    /// (its destination is the very backup that replaces its dead
    /// source) — the transfer becomes local and must complete, not
    /// panic in `FlowSpec::along` on a hopless path.
    #[test]
    fn backup_collapse_to_local_delivery_completes() {
        use crate::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            100e6,
        )]));
        // NPU 0 dies mid-flow; its backup is NPU 1 — the flow's own
        // destination.
        let plan = FaultPlan::new()
            .at(
                500.0,
                FaultEvent::NpuDown {
                    npu: NodeId(0),
                    backup: Some((NodeId(1), 50.0)),
                },
            )
            .with_recovery(RecoveryConfig::direct());
        let r = run_faulted(&net, &dag, &SimConfig::default(), &plan);
        assert!(!r.is_stalled(), "{:?}", r.stalled);
        assert_eq!(r.reroutes, 1);
        // Local delivery happens at backup activation (500 + 50).
        assert!((r.makespan_us - 550.0).abs() < 1.0, "{}", r.makespan_us);
    }

    /// Review fix: a pending reroute from an earlier fault must not
    /// fire before a *later* fault's slower convergence on the same
    /// flow — the ready time is recomputed when the event fires and the
    /// reroute is deferred to the latest notified table update.
    #[test]
    fn staggered_faults_defer_reroute_to_latest_convergence() {
        use crate::routing::failure::{
            direct_notification_convergence_us, RecoveryModel,
        };
        use crate::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
        let t = k4();
        let net = SimNet::new(&t);
        let l01 = t.link_between(NodeId(0), NodeId(1)).unwrap();
        let l12 = t.link_between(NodeId(1), NodeId(2)).unwrap();
        let bytes = 500e6;
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1), NodeId(2)],
            bytes,
        )]));
        let plan = FaultPlan::new()
            .at(100.0, FaultEvent::LinkDown(l01))
            .at(120.0, FaultEvent::LinkDown(l12))
            .with_recovery(RecoveryConfig::direct());
        let r = run_faulted(&net, &dag, &SimConfig::default(), &plan);
        assert!(!r.is_stalled());
        assert_eq!(r.reroutes, 1);
        // The source hears about the second failure at 120 + conv(l12);
        // only then may it re-path, so the remaining ~495 MB cannot have
        // started draining before that.
        let conv_b =
            direct_notification_convergence_us(&t, l12, &[NodeId(0)], &RecoveryModel::default());
        let resume_floor = 120.0 + conv_b;
        let remaining_time = (bytes - 100.0 * 50.0 * 1e3) / (50.0 * 1e3);
        assert!(
            r.makespan_us > resume_floor + remaining_time * 0.99,
            "reroute fired before the later fault converged: {} vs floor {}",
            r.makespan_us,
            resume_floor + remaining_time
        );
    }

    /// Review fix: a reroute that finds no live path gives up — but a
    /// later restore that opens a detour *elsewhere* (not on the flow's
    /// own channel list) must retry it, not strand it in a stall.
    #[test]
    fn restore_elsewhere_retries_a_failed_reroute() {
        use crate::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
        let t = k4();
        let net = SimNet::new(&t);
        let (l01, l02, l03) = (
            t.link_between(NodeId(0), NodeId(1)).unwrap(),
            t.link_between(NodeId(0), NodeId(2)).unwrap(),
            t.link_between(NodeId(0), NodeId(3)).unwrap(),
        );
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            500e6,
        )]));
        // Node 0 is fully cut at t=100 (reroute finds nothing); at
        // t=5000 the 0-2 link comes back, opening the 0→2→1 detour —
        // which is NOT on the blocked flow's own path.
        let plan = FaultPlan::new()
            .at(100.0, FaultEvent::LinkDown(l01))
            .at(100.0, FaultEvent::LinkDown(l02))
            .at(100.0, FaultEvent::LinkDown(l03))
            .at(5_000.0, FaultEvent::LinkUp(l02))
            .with_recovery(RecoveryConfig::direct());
        let r = run_faulted(&net, &dag, &SimConfig::default(), &plan);
        assert!(!r.is_stalled(), "restored detour must be retried");
        assert_eq!(r.reroutes, 1);
        assert!(r.makespan_us > 5_000.0);
    }

    /// Review fix: two reroute events for one flow can land in the same
    /// batch (a second fault re-schedules the still-cut flow at a
    /// convergence time dominated by the first fault's slower link);
    /// the flow must be retired exactly once.
    #[test]
    fn coinciding_reroute_events_retire_the_flow_once() {
        use crate::sim::fault::{FaultEvent, FaultPlan, RecoveryConfig};
        use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
        let t = nd_fullmesh(
            "m44",
            &[
                DimSpec::new(4, 4, CableClass::PassiveElectrical, 0.3),
                DimSpec::new(4, 4, CableClass::PassiveElectrical, 1.0),
            ],
        );
        let node = |x: u32, y: u32| NodeId(y * 4 + x);
        let net = SimNet::new(&t);
        // X crosses l1 then l2; Y's last hop crosses l1 from a source 2
        // BFS hops out, so l1's (hop-by-hop) convergence is slower than
        // l2's — both of X's reroute events land at l1's table time.
        let x = FlowSpec::along(&t, &[node(0, 0), node(1, 0), node(1, 1)], 100e6);
        let y = FlowSpec::along(
            &t,
            &[node(2, 1), node(2, 0), node(1, 0), node(0, 0)],
            100e6,
        );
        let l1 = t.link_between(node(0, 0), node(1, 0)).unwrap();
        let l2 = t.link_between(node(1, 0), node(1, 1)).unwrap();
        let mut dag = StageDag::default();
        dag.push(Stage::new("pair").with_flows(vec![x, y]));
        let plan = FaultPlan::new()
            .at(100.0, FaultEvent::LinkDown(l1))
            .at(110.0, FaultEvent::LinkDown(l2))
            .with_recovery(RecoveryConfig::hop_by_hop());
        let r = run_faulted(&net, &dag, &SimConfig::default(), &plan);
        assert!(!r.is_stalled());
        assert_eq!(r.reroutes, 2, "each cut flow reroutes exactly once");
    }

    /// Without recovery and without restore, the mid-run fault ends in
    /// the structured stall report with the drained bytes accounted.
    #[test]
    fn midrun_fault_without_recovery_stalls_with_partial_progress() {
        use crate::sim::fault::{FaultEvent, FaultPlan};
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6;
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        let spec = FlowSpec::along(&t, &[NodeId(0), NodeId(1)], bytes);
        let gate = spec.latency_us;
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![spec]));
        let plan = FaultPlan::new().at(4_000.0, FaultEvent::LinkDown(l));
        let r = run_faulted(&net, &dag, &SimConfig::default(), &plan);
        assert!(r.is_stalled());
        assert_eq!(r.stalled.len(), 1);
        assert_eq!(r.stalled[0].dead_links, vec![l]);
        // Drained at 50 GB/s from the gate to the cut, no further.
        let drained = (4_000.0 - gate) * 50.0 * 1e3;
        assert!(
            (r.stalled[0].remaining_bytes - (bytes - drained)).abs() < 1.0,
            "{}",
            r.stalled[0].remaining_bytes
        );
        assert!((r.byte_hops - drained).abs() < 1.0);
    }
}
