//! Stage-DAG execution over the fluid-flow simulator.
//!
//! A [`StageDag`] models a collective or a whole training iteration:
//! each [`Stage`] holds flows plus an optional local compute duration,
//! and starts when all its dependencies complete. The runner advances a
//! fluid simulation: rates are max-min fair; the next event is the
//! earliest flow/compute completion; state is settled and rates are
//! recomputed at every event.

use crate::topology::Channel;

use super::fair::max_min_rates;
use super::flow::FlowSpec;
use super::network::SimNet;

/// Flows are considered drained below this remnant (bytes). Sub-byte
/// remnants otherwise produce completion deltas that underflow f64 time
/// resolution once `now` is large, starving the event loop.
const REMNANT_BYTES: f64 = 0.5;

/// One DAG stage.
#[derive(Clone, Debug, Default)]
pub struct Stage {
    pub name: String,
    pub flows: Vec<FlowSpec>,
    /// Local computation overlapped with nothing else in this stage; the
    /// stage ends when flows *and* compute are done.
    pub compute_us: f64,
    /// Indices of stages that must finish first.
    pub deps: Vec<usize>,
}

impl Stage {
    pub fn new(name: impl Into<String>) -> Stage {
        Stage {
            name: name.into(),
            ..Default::default()
        }
    }
    pub fn with_flows(mut self, flows: Vec<FlowSpec>) -> Stage {
        self.flows = flows;
        self
    }
    pub fn with_compute(mut self, us: f64) -> Stage {
        self.compute_us = us;
        self
    }
    pub fn after(mut self, deps: Vec<usize>) -> Stage {
        self.deps = deps;
        self
    }
}

/// A collective / iteration schedule.
#[derive(Clone, Debug, Default)]
pub struct StageDag {
    pub stages: Vec<Stage>,
}

impl StageDag {
    pub fn push(&mut self, stage: Stage) -> usize {
        self.stages.push(stage);
        self.stages.len() - 1
    }

    /// Serially chain a list of stages (each depends on the previous).
    pub fn chain(stages: Vec<Stage>) -> StageDag {
        let mut dag = StageDag::default();
        let mut prev: Option<usize> = None;
        for mut s in stages {
            if let Some(p) = prev {
                s.deps.push(p);
            }
            prev = Some(dag.push(s));
        }
        dag
    }

    pub fn total_bytes(&self) -> f64 {
        self.stages
            .iter()
            .flat_map(|s| &s.flows)
            .map(|f| f.bytes)
            .sum()
    }
}

/// Result of executing a DAG.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Wall-clock makespan, µs.
    pub makespan_us: f64,
    /// Completion time of each stage, µs.
    pub stage_done_us: Vec<f64>,
    /// Total bytes × distance actually carried (byte-hops).
    pub byte_hops: f64,
    /// Events processed (completions + stage starts) — perf metric.
    pub events: u64,
    /// Peak concurrently-active flows.
    pub peak_flows: usize,
}

struct ActiveFlow {
    stage: usize,
    channels: Vec<Channel>,
    /// Remaining payload (GB to keep rate units consistent: capacity is
    /// GB/s and time is µs, so we track bytes and convert).
    remaining_bytes: f64,
    /// Start gate: latency delay before bytes drain.
    gate_us: f64,
    rate_gb_s: f64,
}

/// Execute the DAG on the network. Panics on cyclic dependencies.
pub fn run(net: &SimNet, dag: &StageDag) -> SimReport {
    let n = dag.stages.len();
    let mut dep_left: Vec<usize> = dag.stages.iter().map(|s| s.deps.len()).collect();
    let mut dependants: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, s) in dag.stages.iter().enumerate() {
        for &d in &s.deps {
            assert!(d < n, "dep out of range");
            dependants[d].push(i);
        }
    }

    let mut stage_done = vec![f64::NAN; n];
    let mut flows_left: Vec<usize> = dag.stages.iter().map(|s| s.flows.len()).collect();
    let mut compute_done_at: Vec<f64> = vec![f64::NAN; n];
    let mut active: Vec<ActiveFlow> = Vec::new();
    let mut now = 0.0f64;
    let mut events = 0u64;
    let mut byte_hops = 0.0f64;
    let mut peak = 0usize;
    let mut started = vec![false; n];
    let mut done_count = 0usize;

    // Start all ready stages.
    let mut ready: Vec<usize> = (0..n).filter(|&i| dep_left[i] == 0).collect();

    let start_stage = |i: usize,
                           now: f64,
                           active: &mut Vec<ActiveFlow>,
                           compute_done_at: &mut Vec<f64>,
                           started: &mut Vec<bool>| {
        debug_assert!(!started[i]);
        started[i] = true;
        for f in &dag.stages[i].flows {
            active.push(ActiveFlow {
                stage: i,
                channels: f.channels.clone(),
                remaining_bytes: f.bytes,
                gate_us: now + f.latency_us,
                rate_gb_s: 0.0,
            });
        }
        compute_done_at[i] = now + dag.stages[i].compute_us;
    };

    for i in ready.drain(..) {
        start_stage(i, now, &mut active, &mut compute_done_at, &mut started);
        events += 1;
    }

    loop {
        // Settle stage completions at the current instant (fixpoint:
        // zero-duration stages may cascade).
        loop {
            let mut changed = false;
            for i in 0..n {
                if started[i]
                    && stage_done[i].is_nan()
                    && flows_left[i] == 0
                    && compute_done_at[i] <= now + 1e-9
                {
                    stage_done[i] = now;
                    done_count += 1;
                    events += 1;
                    changed = true;
                    for &d in &dependants[i] {
                        dep_left[d] -= 1;
                        if dep_left[d] == 0 {
                            start_stage(
                                d,
                                now,
                                &mut active,
                                &mut compute_done_at,
                                &mut started,
                            );
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
        if done_count == n {
            break;
        }

        peak = peak.max(active.len());
        // Recompute rates for gate-open flows.
        let open: Vec<usize> = (0..active.len())
            .filter(|&i| active[i].gate_us <= now + 1e-12 && active[i].remaining_bytes > 0.0)
            .collect();
        let chan_refs: Vec<&[Channel]> =
            open.iter().map(|&i| active[i].channels.as_slice()).collect();
        let rates = max_min_rates(net, &chan_refs);
        for (k, &i) in open.iter().enumerate() {
            active[i].rate_gb_s = rates[k];
        }

        // Next event: earliest of flow completion, gate opening, or
        // pending compute completion.
        let mut next = f64::INFINITY;
        for f in &active {
            if f.remaining_bytes <= REMNANT_BYTES {
                continue;
            }
            if f.gate_us > now + 1e-12 {
                next = next.min(f.gate_us);
            } else if f.rate_gb_s > 0.0 {
                // rate GB/s -> bytes per microsecond = rate * 1e3.
                let t = f.remaining_bytes / (f.rate_gb_s * 1e3);
                next = next.min(now + t);
            }
        }
        for i in 0..n {
            if started[i] && stage_done[i].is_nan() && compute_done_at[i] > now + 1e-9 {
                next = next.min(compute_done_at[i]);
            }
        }

        if !next.is_finite() {
            break; // stalled (failed links) or nothing left
        }
        // Guarantee monotone progress even if fp rounding collapses the
        // next event onto `now`.
        if next <= now {
            next = now + 1e-6;
        }

        // Drain bytes until `next`.
        let dt = next - now;
        for f in active.iter_mut() {
            if f.remaining_bytes > 0.0 && f.gate_us <= now + 1e-12 && f.rate_gb_s > 0.0 {
                let drained = (f.rate_gb_s * 1e3 * dt).min(f.remaining_bytes);
                f.remaining_bytes -= drained;
                byte_hops += drained * f.channels.len() as f64;
            }
        }
        now = next;
        events += 1;

        // Settle flow completions.
        let mut completed_stage_flows: Vec<usize> = Vec::new();
        active.retain(|f| {
            if f.remaining_bytes <= REMNANT_BYTES {
                completed_stage_flows.push(f.stage);
                false
            } else {
                true
            }
        });
        for s in completed_stage_flows {
            flows_left[s] -= 1;
        }
    }

    assert!(
        done_count == n,
        "DAG stalled: {}/{} stages done at t={now}µs (failed links or cyclic deps?)",
        done_count,
        n
    );
    SimReport {
        makespan_us: now,
        stage_done_us: stage_done,
        byte_hops,
        events,
        peak_flows: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
    use crate::topology::{CableClass, NodeId, Topology};

    fn k4() -> Topology {
        // K4 full-mesh, x8 lanes = 50 GB/s per link direction.
        nd_fullmesh(
            "k4",
            &[DimSpec::new(4, 8, CableClass::PassiveElectrical, 0.3)],
        )
    }

    #[test]
    fn single_flow_time_matches_closed_form() {
        let t = k4();
        let net = SimNet::new(&t);
        let bytes = 500e6; // 500 MB over 50 GB/s = 10_000 µs
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            bytes,
        )]));
        let r = run(&net, &dag);
        let expect = bytes / (50.0 * 1e3);
        assert!(
            (r.makespan_us - expect).abs() / expect < 0.01,
            "{} vs {expect}",
            r.makespan_us
        );
    }

    #[test]
    fn two_flows_on_one_link_take_twice_as_long() {
        let t = k4();
        let net = SimNet::new(&t);
        let f = |_| FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        dag.push(Stage::new("xfer").with_flows(vec![f(0), f(1)]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    fn dependencies_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mk = || FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6);
        let mut dag = StageDag::default();
        let a = dag.push(Stage::new("a").with_flows(vec![mk()]));
        dag.push(Stage::new("b").with_flows(vec![mk()]).after(vec![a]));
        let r = run(&net, &dag);
        let expect = 2.0 * 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
        assert!(r.stage_done_us[0] < r.stage_done_us[1]);
    }

    #[test]
    fn compute_only_stage() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("gemm").with_compute(123.0));
        let r = run(&net, &dag);
        assert!((r.makespan_us - 123.0).abs() < 1e-6);
    }

    #[test]
    fn compute_overlaps_communication() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(
            Stage::new("overlap")
                .with_flows(vec![FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6)])
                .with_compute(20_000.0),
        );
        let r = run(&net, &dag);
        // max(10_000 comm, 20_000 compute) ≈ 20_000.
        assert!((r.makespan_us - 20_000.0).abs() < 50.0, "{}", r.makespan_us);
    }

    #[test]
    fn parallel_disjoint_flows_dont_serialize() {
        let t = k4();
        let net = SimNet::new(&t);
        let mut dag = StageDag::default();
        dag.push(Stage::new("par").with_flows(vec![
            FlowSpec::along(&t, &[NodeId(0), NodeId(1)], 500e6),
            FlowSpec::along(&t, &[NodeId(2), NodeId(3)], 500e6),
        ]));
        let r = run(&net, &dag);
        let expect = 500e6 / (50.0 * 1e3);
        assert!((r.makespan_us - expect).abs() / expect < 0.01);
    }

    #[test]
    #[should_panic(expected = "DAG stalled")]
    fn failed_link_stalls_and_reports() {
        let t = k4();
        let mut net = SimNet::new(&t);
        let l = t.link_between(NodeId(0), NodeId(1)).unwrap();
        net.fail_link(l);
        let mut dag = StageDag::default();
        dag.push(Stage::new("x").with_flows(vec![FlowSpec::along(
            &t,
            &[NodeId(0), NodeId(1)],
            1e6,
        )]));
        run(&net, &dag);
    }
}
