//! Topology-aware parallelization (§5.2): search-space generation with
//! the paper's pruning heuristic, and the iterative cost-model search.

pub mod search;
pub mod space;

pub use search::{search, SearchOutcome};
pub use space::{enumerate_configs, SearchSpace};
