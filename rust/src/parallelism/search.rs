//! Cost-model-driven parallelization search (§5.2 Steps ②③).
//!
//! Evaluates every enumerated configuration with the topology-aware cost
//! model and returns the fastest. The evaluator is pluggable: the
//! default is the pure-rust [`iteration_time`] model; the coordinator
//! swaps in the AOT-compiled PJRT batch evaluator
//! (`runtime::CostModel`), which computes the same α-β formulas on
//! device — Step ② in one call for the whole batch.

use crate::workload::models::ModelConfig;
use crate::workload::placement::{Placement, TierBandwidth};
use crate::workload::step::{iteration_time, IterBreakdown};
use crate::workload::traffic::ParallelismConfig;

use super::space::{enumerate_configs, SearchSpace};

/// Search result.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    pub best: ParallelismConfig,
    pub best_iter: IterBreakdown,
    /// (config, total_us) for every evaluated candidate, sorted fastest
    /// first — used by benches exploring the space.
    pub ranked: Vec<(ParallelismConfig, f64)>,
}

/// Batch evaluator signature: total iteration µs per config.
pub type Evaluator<'a> = dyn Fn(&[ParallelismConfig]) -> Vec<f64> + 'a;

/// Run the search with the built-in rust evaluator.
pub fn search(m: &ModelConfig, space: &SearchSpace, bw: &TierBandwidth) -> SearchOutcome {
    let eval = |cfgs: &[ParallelismConfig]| -> Vec<f64> {
        cfgs.iter()
            .map(|c| iteration_time(m, c, &Placement::topology_aware(c), bw).total_us)
            .collect()
    };
    search_with(m, space, bw, &eval)
}

/// Run the search with a custom (e.g. PJRT) batch evaluator.
pub fn search_with(
    m: &ModelConfig,
    space: &SearchSpace,
    bw: &TierBandwidth,
    eval: &Evaluator,
) -> SearchOutcome {
    let cfgs = enumerate_configs(m, space);
    assert!(
        !cfgs.is_empty(),
        "no feasible parallelism for {} on {} NPUs",
        m.name,
        space.scale
    );
    let times = eval(&cfgs);
    let mut ranked: Vec<(ParallelismConfig, f64)> =
        cfgs.into_iter().zip(times).collect();
    ranked.sort_by(|a, b| a.1.total_cmp(&b.1));
    let best = ranked[0].0;
    let best_iter = iteration_time(m, &best, &Placement::topology_aware(&best), bw);
    SearchOutcome {
        best,
        best_iter,
        ranked,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::by_name;

    #[test]
    fn search_finds_tp_in_high_bandwidth_domain() {
        let m = by_name("gpt3-175b").unwrap();
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let out = search(&m, &SearchSpace::paper_default(512, 8192.0), &bw);
        // The winner should exploit the board-level mesh: TP > 1.
        assert!(out.best.tp > 1, "best {:?}", out.best);
        assert!(out.best_iter.total_us > 0.0);
        // Ranking is sorted.
        for w in out.ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn custom_evaluator_is_honored() {
        let m = by_name("llama-70b").unwrap();
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let space = SearchSpace::paper_default(128, 8192.0);
        // Perverse evaluator that prefers the LAST config.
        let eval = |cfgs: &[crate::workload::ParallelismConfig]| -> Vec<f64> {
            (0..cfgs.len()).rev().map(|i| i as f64 + 1.0).collect()
        };
        let out = search_with(&m, &space, &bw, &eval);
        let all = enumerate_configs(&m, &space);
        assert_eq!(out.best, *all.last().unwrap());
    }

    #[test]
    fn longer_sequences_shift_towards_sp() {
        let m = by_name("gpt3-175b").unwrap();
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let long = search(&m, &SearchSpace::paper_default(1024, 1_048_576.0), &bw);
        // 1M-token sequences force meaningful context sharding.
        assert!(long.best.sp >= 8, "long-seq best {:?}", long.best);
    }
}
