//! Parallelism search-space enumeration (§5.2 Step ①).
//!
//! "We prune the search space using a priority-based heuristic: TP and
//! SP (or CP), which involve high communication volumes, are prioritized
//! for high-bandwidth domains ... For MoE models requiring EP, we force
//! SP*DP as an integer multiple of EP." Plus memory feasibility: weights
//! + optimizer state + activations must fit HBM.

use crate::workload::models::ModelConfig;
use crate::workload::traffic::ParallelismConfig;

/// Per-NPU HBM capacity (bytes).
pub const HBM_BYTES: f64 = 64e9;
/// Bytes per parameter held regardless of DP (bf16 weights + grads).
pub const BYTES_PER_PARAM_LOCAL: f64 = 4.0;
/// Optimizer-state bytes per parameter (fp32 master + Adam moments),
/// ZeRO-sharded across the DP group.
pub const BYTES_PER_PARAM_OPT: f64 = 14.0;
/// Activation bytes per token per layer (with recompute discount).
pub const ACT_BYTES_PER_TOKEN_LAYER: f64 = 8.0;

/// Enumeration bounds.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub scale: usize,
    pub seq_len: f64,
    /// Global tokens per iteration (sets microbatch count).
    pub global_tokens: f64,
    pub max_tp: usize,
    pub max_sp: usize,
    pub max_pp: usize,
}

impl SearchSpace {
    pub fn paper_default(scale: usize, seq_len: f64) -> SearchSpace {
        SearchSpace {
            scale,
            seq_len,
            // Weak scaling: global batch grows with the cluster, like the
            // paper's linearity setup (Fig 22 keeps per-NPU work fixed).
            global_tokens: scale as f64 * 8192.0,
            max_tp: 8,
            max_sp: 64,
            // Dense-1T at 1K NPUs needs tp×pp ≥ ~290 to fit HBM.
            max_pp: 64,
        }
    }
}

fn pow2s_upto(n: usize) -> impl Iterator<Item = usize> {
    (0..).map(|i| 1usize << i).take_while(move |&v| v <= n)
}

/// Does the per-NPU memory footprint fit?
pub fn memory_feasible(m: &ModelConfig, p: &ParallelismConfig) -> bool {
    let ep = if m.is_moe() { p.ep.max(1) } else { 1 };
    // Experts shard over EP; attention shards over TP×PP only.
    let attn = m.attn_params_per_layer() * m.layers as f64;
    let ffn = m.ffn_params_per_expert() * m.experts.unwrap_or(1) as f64 * m.layers as f64;
    let params_per_npu = attn / (p.tp * p.pp) as f64 + ffn / (p.tp * p.pp * ep) as f64;
    // ZeRO-1: optimizer state shards over DP replicas.
    let state = params_per_npu
        * (BYTES_PER_PARAM_LOCAL + BYTES_PER_PARAM_OPT / p.dp.max(1) as f64);
    let act = p.tokens_per_microbatch * ACT_BYTES_PER_TOKEN_LAYER * m.layers as f64
        / (p.pp * p.tp * p.sp) as f64
        * 2.0; // a couple of microbatches in flight
    state + act < HBM_BYTES * 0.9
}

/// Enumerate feasible configs for `m` on `scale` NPUs.
pub fn enumerate_configs(m: &ModelConfig, space: &SearchSpace) -> Vec<ParallelismConfig> {
    let mut out = Vec::new();
    for tp in pow2s_upto(space.max_tp) {
        for sp in pow2s_upto(space.max_sp) {
            // SP splits the sequence; keep ≥ 512 tokens per shard.
            if space.seq_len / (sp as f64) < 512.0 {
                continue;
            }
            for pp in pow2s_upto(space.max_pp) {
                if m.layers % pp != 0 {
                    continue;
                }
                let denom = tp * sp * pp;
                if space.scale % denom != 0 {
                    continue;
                }
                let dp = space.scale / denom;
                let eps: Vec<usize> = if m.is_moe() {
                    let experts = m.experts.unwrap();
                    pow2s_upto(experts)
                        .filter(|&ep| ep > 1 && (sp * dp) % ep == 0)
                        .collect()
                } else {
                    vec![1]
                };
                for ep in eps {
                    let tokens_mb = space.seq_len;
                    let microbatches = (space.global_tokens / (dp as f64 * tokens_mb))
                        .round()
                        .max(1.0) as usize;
                    let cfg = ParallelismConfig {
                        tp,
                        sp,
                        ep,
                        pp,
                        dp,
                        microbatches,
                        tokens_per_microbatch: tokens_mb,
                    };
                    if memory_feasible(m, &cfg) {
                        out.push(cfg);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::by_name;

    #[test]
    fn enumerates_nonempty_for_paper_scales() {
        for (name, scale) in [("llama-70b", 128), ("gpt3-175b", 512), ("gpt4-2t", 1024)] {
            let m = by_name(name).unwrap();
            let cfgs = enumerate_configs(&m, &SearchSpace::paper_default(scale, 8192.0));
            assert!(!cfgs.is_empty(), "{name}@{scale}");
            for c in &cfgs {
                assert_eq!(c.npus(), scale, "{c:?}");
            }
        }
    }

    #[test]
    fn moe_constraint_sp_dp_multiple_of_ep() {
        let m = by_name("gpt4-2t").unwrap();
        let cfgs = enumerate_configs(&m, &SearchSpace::paper_default(1024, 8192.0));
        assert!(cfgs.iter().all(|c| (c.sp * c.dp) % c.ep == 0));
        assert!(cfgs.iter().all(|c| c.ep > 1), "MoE must use EP");
    }

    #[test]
    fn memory_excludes_undersharded_giants() {
        let m = by_name("dense-1t").unwrap();
        let bad = ParallelismConfig {
            tp: 1,
            sp: 1,
            ep: 1,
            pp: 1,
            dp: 1024,
            microbatches: 1,
            tokens_per_microbatch: 8192.0,
        };
        assert!(!memory_feasible(&m, &bad), "1T on one NPU cannot fit");
    }

    #[test]
    fn long_sequences_admit_large_sp() {
        let m = by_name("gpt3-175b").unwrap();
        // At 8K scale there is room for SP≥32 alongside the TP×PP shards
        // that the 175B memory footprint requires.
        let cfgs = enumerate_configs(&m, &SearchSpace::paper_default(8192, 1_048_576.0));
        assert!(cfgs.iter().any(|c| c.sp >= 32), "1M seq should allow SP≥32");
    }
}
