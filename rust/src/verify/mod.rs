//! Static model verification: machine-checkable invariants over the
//! *constructed* model — topologies, path sets, stage DAGs, fault
//! plans — without simulating anything.
//!
//! Every PR so far validated its wiring with ad-hoc out-of-tree
//! mirrors (per-hop link existence, lane budgets, byte-hop
//! conservation, balanced rotations). [`audit`] moves those checks
//! into the repo as a first-class static-analysis pass: a catalog of
//! rules with stable `AUD0xx` diagnostic codes and a structured
//! [`audit::AuditReport`], wired three ways —
//!
//! 1. `debug_assert!`-gated self-audits in the
//!    [`crate::workload::ClusterMap`] / [`crate::sim::StageDag`]
//!    constructors,
//! 2. the `rust/tests/audit.rs` suite running the full catalog over
//!    every built-in fabric,
//! 3. the `audit_smoke` bench, which also **mutation-tests the auditor
//!    itself** ([`mutate`]): seeded defects must each be caught by
//!    their specific code, asserted in CI via `BENCH_audit.json`.
//!
//! The audit is also the eligibility gate for the ROADMAP item-3
//! topology bake-off: a third-party fabric bolted onto `ClusterMap`
//! enters the tournament only if [`audit::audit_fabric`] comes back
//! clean. See `docs/AUDIT.md` for the rule catalog with paper
//! provenance.

pub mod audit;
pub mod mutate;

pub use audit::{
    audit_fabric, AuditConfig, AuditReport, Finding, CATALOG,
};
