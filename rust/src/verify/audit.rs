//! The static invariant checker: a catalog of `AUD0xx` rules over the
//! constructed model, producing a structured [`AuditReport`].
//!
//! Four rule families (see [`CATALOG`] and `docs/AUDIT.md`):
//!
//! * **topology** (`AUD00x`) — every claimed path hop is a live link,
//!   lane budgets / port radix hold, parallel-link multiplicity is
//!   consistent, link parameters are finite;
//! * **path set** (`AUD01x`) — weights normalized, plane/HRS selection
//!   is a balanced rotation (the PR 3 lesson as a lint), families are
//!   diverse, switched fabrics relay through switches only, sampled
//!   families are 2-VL deadlock-free, lazy path-count metadata exact;
//! * **DAG** (`AUD02x`) — acyclic, deps valid, lazy/eager metadata
//!   agree, iteration / checkpoint / shrunk DAGs conserve the Table 1
//!   analytic byte volumes;
//! * **fault/replica** (`AUD03x`) — fault timelines well-ordered and
//!   finite, blast groups inside their declared domains, replica maps
//!   partition the workload exactly once.
//!
//! Rules never panic on a defective model — they record findings — so
//! the seeded-mutation harness ([`super::mutate`]) can assert each
//! defect class maps to its specific code.

use std::collections::{BTreeMap, BTreeSet};

use crate::reliability::faultgen::{BlastClass, FaultDomains, FaultGroup};
use crate::reliability::montecarlo::ReplicaMap;
use crate::routing::apr::{hrs_plane_pair, PathSet, RoutedPath};
use crate::routing::tfc::verify_deadlock_free;
use crate::sim::fault::{FaultEvent, FaultPlan};
use crate::sim::schedule::StageDag;
use crate::topology::{NodeId, Topology};
use crate::workload::step::IterationSpec;
use crate::workload::traffic::{analyze, BYTES_PER_ACT};
use crate::workload::{ClusterMap, ModelConfig, ParallelismConfig};

/// Every rule the auditor knows, `(code, one-line description)`. The
/// single source of truth for `docs/AUDIT.md` and the
/// `audit.rules_checked` bench metric.
pub const CATALOG: &[(&str, &str)] = &[
    ("AUD001", "every hop of every claimed path is a live link of the topology"),
    ("AUD002", "paths are loop-free with the declared endpoints"),
    ("AUD003", "no node exceeds its Table 3 UB lane budget (NPU/LRS/HRS port radix)"),
    ("AUD004", "parallel-link multiplicity is consistent between adjacency and links_between"),
    ("AUD005", "every link's lanes, capacity and length are finite and non-negative"),
    ("AUD010", "path-set weights are non-negative, finite and normalized"),
    ("AUD011", "plane/HRS selection is a balanced rotation, not a collision-prone hash"),
    ("AUD012", "multi-path families are diverse: no duplicate paths, a middle-disjoint pair exists"),
    ("AUD013", "on switched fabrics (no NPU-NPU links) paths relay only through switches"),
    ("AUD014", "sampled path families are deadlock-free with 2 VLs under TFC"),
    ("AUD015", "pair_paths families match the lazy pair_path_count metadata exactly"),
    ("AUD020", "stage DAGs are acyclic"),
    ("AUD021", "stage deps are in-range, non-self, and a root stage exists"),
    ("AUD022", "lazy stage metadata (flow count, bytes) agrees with materialized flows"),
    ("AUD023", "iteration DAG wire bytes conserve the Table 1 analytic volumes"),
    ("AUD024", "checkpoint DAG ships exactly bytes_per_rank per workload NPU to storage"),
    ("AUD025", "shrunk iteration DAGs never terminate a flow at a dead-replica NPU"),
    ("AUD030", "fault timelines are well-ordered with finite, in-range parameters"),
    ("AUD031", "blast groups stay inside their declared fault-domain radius"),
    ("AUD032", "a replica map partitions the workload NPUs into dp equal replicas exactly once"),
];

/// One rule violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Stable diagnostic code (`AUD0xx`, see [`CATALOG`]).
    pub code: &'static str,
    /// What was being audited (fabric name, stage name, pair, …).
    pub subject: String,
    /// Human-readable specifics.
    pub detail: String,
}

/// Structured result of an audit run: which rules were exercised and
/// every violation found. Clean ⇔ no findings.
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    findings: Vec<Finding>,
    checked: BTreeSet<&'static str>,
}

impl AuditReport {
    pub fn new() -> AuditReport {
        AuditReport::default()
    }

    /// Record that a rule ran (even if it found nothing).
    fn mark(&mut self, code: &'static str) {
        debug_assert!(
            CATALOG.iter().any(|&(c, _)| c == code),
            "unknown audit code {code}"
        );
        self.checked.insert(code);
    }

    fn fail(&mut self, code: &'static str, subject: &str, detail: String) {
        self.mark(code);
        self.findings.push(Finding {
            code,
            subject: subject.to_string(),
            detail,
        });
    }

    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// True if any finding carries `code`.
    pub fn has(&self, code: &str) -> bool {
        self.findings.iter().any(|f| f.code == code)
    }

    pub fn findings(&self) -> &[Finding] {
        &self.findings
    }

    /// Distinct rule codes exercised by this report.
    pub fn rules_checked(&self) -> usize {
        self.checked.len()
    }

    /// Codes exercised (sorted, deduplicated).
    pub fn checked_codes(&self) -> Vec<&'static str> {
        self.checked.iter().copied().collect()
    }

    /// Fold another report into this one (union of checked rules,
    /// concatenated findings) — the suite/bench aggregate.
    pub fn merge(&mut self, other: AuditReport) {
        self.checked.extend(other.checked);
        self.findings.extend(other.findings);
    }

    /// Render findings grouped by code, one line each.
    pub fn render(&self) -> String {
        if self.is_clean() {
            return format!("clean ({} rules checked)", self.rules_checked());
        }
        let mut by_code: BTreeMap<&str, Vec<&Finding>> = BTreeMap::new();
        for f in &self.findings {
            by_code.entry(f.code).or_default().push(f);
        }
        let mut out = String::new();
        for (code, fs) in by_code {
            for f in fs {
                out.push_str(&format!("{code} [{}]: {}\n", f.subject, f.detail));
            }
        }
        out
    }
}

/// Knobs for the sampled rules (pair selection in
/// [`audit_cluster_map`], selector seeds in [`audit_plane_selector`]).
/// Sampling is deterministic — same config, same pairs.
#[derive(Clone, Copy, Debug)]
pub struct AuditConfig {
    /// Ordered NPU pairs sampled per cluster map.
    pub max_pairs: usize,
    /// Rotation seeds audited per sampled pair.
    pub sels: u64,
}

impl Default for AuditConfig {
    fn default() -> Self {
        AuditConfig {
            max_pairs: 64,
            sels: 4,
        }
    }
}

// ---------------------------------------------------------------------
// Topology rules (AUD003/004/005)
// ---------------------------------------------------------------------

/// AUD003 + AUD004 + AUD005 over the whole graph.
pub fn audit_topology(r: &mut AuditReport, t: &Topology) {
    let sub = &t.name;

    r.mark("AUD003");
    if let Err(e) = t.check_lane_budgets() {
        r.fail("AUD003", sub, e);
    }

    r.mark("AUD005");
    for (i, l) in t.links.iter().enumerate() {
        if l.lanes == 0 {
            r.fail("AUD005", sub, format!("link {i} has zero lanes"));
        }
        if !(l.length_m.is_finite() && l.length_m >= 0.0) {
            r.fail(
                "AUD005",
                sub,
                format!("link {i} length {} must be finite and ≥ 0", l.length_m),
            );
        }
        let cap = l.capacity_gb_s();
        if !(cap.is_finite() && cap >= 0.0) {
            r.fail("AUD005", sub, format!("link {i} capacity {cap} invalid"));
        }
    }

    r.mark("AUD004");
    // Adjacency, links_between and link_between must describe the same
    // multigraph: every adjacency entry names a link whose endpoints
    // are the pair, each link appears exactly twice across adjacency
    // (once per side), and the pair's first link is what link_between
    // answers.
    let mut seen_per_link = vec![0usize; t.link_count()];
    for n in 0..t.node_count() {
        let n = NodeId(n as u32);
        for &(peer, l) in t.neighbors(n) {
            seen_per_link[l.idx()] += 1;
            let link = t.link(l);
            if !((link.a == n && link.b == peer) || (link.b == n && link.a == peer)) {
                r.fail(
                    "AUD004",
                    sub,
                    format!("adjacency {n}→{peer} names link {l} with endpoints {}-{}",
                        link.a, link.b),
                );
            }
        }
    }
    for (i, &c) in seen_per_link.iter().enumerate() {
        if c != 2 {
            r.fail(
                "AUD004",
                sub,
                format!("link {i} appears {c} times in adjacency (expected 2)"),
            );
        }
    }
    for (i, l) in t.links.iter().enumerate() {
        let set = t.links_between(l.a, l.b);
        if !set.contains(&crate::topology::LinkId(i as u32)) {
            r.fail(
                "AUD004",
                sub,
                format!("link {i} missing from links_between({}, {})", l.a, l.b),
            );
        }
        match t.link_between(l.a, l.b) {
            Some(first) => {
                if !set.contains(&first) {
                    r.fail(
                        "AUD004",
                        sub,
                        format!("link_between({}, {}) = {first} not in the pair's set", l.a, l.b),
                    );
                }
            }
            None => r.fail(
                "AUD004",
                sub,
                format!("link_between({}, {}) is None but link {i} joins them", l.a, l.b),
            ),
        }
    }
}

// ---------------------------------------------------------------------
// Path rules (AUD001/002/012/013)
// ---------------------------------------------------------------------

/// AUD001 + AUD002 for one claimed path with declared endpoints.
pub fn audit_path(r: &mut AuditReport, t: &Topology, sub: &str, path: &[NodeId], a: NodeId, b: NodeId) {
    r.mark("AUD001");
    r.mark("AUD002");
    if path.len() < 2 {
        r.fail("AUD002", sub, format!("path {path:?} has < 2 nodes"));
        return;
    }
    if path[0] != a || *path.last().unwrap() != b {
        r.fail(
            "AUD002",
            sub,
            format!("path {path:?} does not run {a} → {b}"),
        );
    }
    let mut seen = BTreeSet::new();
    for n in path {
        if n.idx() >= t.node_count() {
            r.fail("AUD001", sub, format!("path node {n} outside topology"));
            return;
        }
        if !seen.insert(*n) {
            r.fail("AUD002", sub, format!("path {path:?} repeats node {n}"));
        }
    }
    for w in path.windows(2) {
        if t.link_between(w[0], w[1]).is_none() {
            r.fail(
                "AUD001",
                sub,
                format!("hop {}-{} of path {path:?} is not a link", w[0], w[1]),
            );
        }
    }
}

/// AUD001/002 per path plus the family-level diversity (AUD012) and
/// switched-relay (AUD013) rules for one APR path family of `a → b`.
///
/// `switched_only` says the topology has no NPU-NPU links (Fig 16-d
/// Clos rack), so every interior hop must be a switch.
pub fn audit_path_family(
    r: &mut AuditReport,
    t: &Topology,
    sub: &str,
    paths: &[Vec<NodeId>],
    a: NodeId,
    b: NodeId,
    switched_only: bool,
) {
    for p in paths {
        audit_path(r, t, sub, p, a, b);
    }

    r.mark("AUD013");
    if switched_only {
        for p in paths {
            for n in p.iter().skip(1).rev().skip(1) {
                if n.idx() < t.node_count() && t.node(*n).kind.is_npu() {
                    r.fail(
                        "AUD013",
                        sub,
                        format!("switched fabric relays through NPU {n} in {p:?}"),
                    );
                }
            }
        }
    }

    r.mark("AUD012");
    let distinct: BTreeSet<&[NodeId]> = paths.iter().map(|p| p.as_slice()).collect();
    if distinct.len() != paths.len() {
        r.fail(
            "AUD012",
            sub,
            format!("family of {} paths has only {} distinct", paths.len(), distinct.len()),
        );
    }
    if paths.len() >= 2 {
        // "Middle" links: hops not incident to either endpoint. Plane /
        // HRS / relay diversity means at least one pair of paths shares
        // no middle link (endpoint attach hops may legitimately be
        // shared — a 1D-FM-A NPU has exactly one attach LRS).
        let middles: Vec<BTreeSet<(NodeId, NodeId)>> = paths
            .iter()
            .map(|p| {
                p.windows(2)
                    .filter(|w| w[0] != a && w[0] != b && w[1] != a && w[1] != b)
                    .map(|w| if w[0] < w[1] { (w[0], w[1]) } else { (w[1], w[0]) })
                    .collect()
            })
            .collect();
        let disjoint_pair = (0..middles.len()).any(|i| {
            (i + 1..middles.len()).any(|j| middles[i].is_disjoint(&middles[j]))
        });
        if !disjoint_pair {
            r.fail(
                "AUD012",
                sub,
                format!("no two of the {} paths are middle-link-disjoint", paths.len()),
            );
        }
    }
}

// ---------------------------------------------------------------------
// Path-set rules (AUD010/011/014/015)
// ---------------------------------------------------------------------

/// AUD010 (weights) plus per-path AUD001/002 for a weighted
/// [`PathSet`].
pub fn audit_path_set(r: &mut AuditReport, t: &Topology, sub: &str, ps: &PathSet) {
    r.mark("AUD010");
    if ps.weights.len() != ps.paths.len() {
        r.fail(
            "AUD010",
            sub,
            format!("{} weights for {} paths", ps.weights.len(), ps.paths.len()),
        );
    }
    let mut sum = 0.0;
    for (i, &w) in ps.weights.iter().enumerate() {
        if !(w.is_finite() && w >= 0.0) {
            r.fail("AUD010", sub, format!("weight[{i}] = {w} invalid"));
        } else {
            sum += w;
        }
    }
    if !ps.weights.is_empty() && (sum - 1.0).abs() > 1e-9 {
        r.fail("AUD010", sub, format!("weights sum to {sum}, not 1"));
    }
    for p in &ps.paths {
        if let (Some(&a), Some(&b)) = (p.nodes.first(), p.nodes.last()) {
            audit_path(r, t, sub, &p.nodes, a, b);
        }
    }
}

/// AUD011: the plane/HRS selector must be a *balanced rotation* —
/// deterministic, never the same plane twice, covering every ordered
/// plane pair, and picking each plane as first choice equally often
/// over a full rotation period. A collision-prone hash (the PR 3 bug)
/// fails the exact-balance check.
pub fn audit_plane_selector(
    r: &mut AuditReport,
    sub: &str,
    planes: usize,
    sel: &dyn Fn(u64) -> (usize, usize),
) {
    r.mark("AUD011");
    if planes < 2 {
        return;
    }
    let rounds = (planes * (planes - 1) * 4) as u64;
    let mut first = vec![0usize; planes];
    let mut pairs = BTreeSet::new();
    for seed in 0..rounds {
        let (a, b) = sel(seed);
        if a >= planes || b >= planes {
            r.fail("AUD011", sub, format!("seed {seed}: plane ({a}, {b}) out of range"));
            continue;
        }
        if a == b {
            r.fail("AUD011", sub, format!("seed {seed}: both paths on plane {a}"));
        }
        if sel(seed) != (a, b) {
            r.fail("AUD011", sub, format!("seed {seed}: selector is not deterministic"));
        }
        first[a] += 1;
        pairs.insert((a, b));
    }
    let (min, max) = (
        first.iter().copied().min().unwrap_or(0),
        first.iter().copied().max().unwrap_or(0),
    );
    if min != max {
        r.fail(
            "AUD011",
            sub,
            format!("first-plane counts {first:?} are skewed (balanced rotation picks each exactly {} times)",
                rounds as usize / planes),
        );
    }
    if pairs.len() != planes * (planes - 1) {
        r.fail(
            "AUD011",
            sub,
            format!("only {}/{} ordered plane pairs ever selected", pairs.len(),
                planes * (planes - 1)),
        );
    }
}

/// AUD014: the joint TFC check over a sampled set of routed paths —
/// 2-VL assignable and an acyclic channel-dependency graph.
pub fn audit_tfc(r: &mut AuditReport, t: &Topology, sub: &str, paths: &[RoutedPath]) {
    r.mark("AUD014");
    if let Err(e) = verify_deadlock_free(t, paths) {
        r.fail("AUD014", sub, e);
    }
}

/// Sampled audit of a [`ClusterMap`]'s APR path construction: AUD001,
/// AUD002, AUD012, AUD013 and AUD015 over a deterministic pair sample.
pub fn audit_cluster_map(
    r: &mut AuditReport,
    t: &Topology,
    map: &ClusterMap,
    cfg: &AuditConfig,
) {
    let n = map.npu_count();
    if n < 2 {
        return;
    }
    let switched_only = !t
        .links
        .iter()
        .any(|l| t.node(l.a).kind.is_npu() && t.node(l.b).kind.is_npu());
    r.mark("AUD015");

    // Deterministic stride walk over ordered pairs: anchors spread
    // across the rank space, partners at coprime-ish offsets so the
    // sample hits same-board, cross-board, cross-rack and cross-pod
    // relations on every fabric size.
    let mut pairs: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut i = 0usize;
    while pairs.len() < cfg.max_pairs && i < cfg.max_pairs * 4 {
        let a = (i * 13) % n;
        let b = (a + 1 + (i * 29) % (n - 1)) % n;
        if a != b {
            pairs.insert((a, b));
        }
        i += 1;
    }
    for &(a, b) in &pairs {
        let (na, nb) = (map.npus()[a], map.npus()[b]);
        for sel in 0..cfg.sels {
            let paths = map.pair_paths(a, b, sel, &[]);
            let declared = map.pair_path_count(a, b, &[]);
            if paths.len() != declared {
                r.fail(
                    "AUD015",
                    &t.name,
                    format!("pair {a}-{b} sel {sel}: {} paths but pair_path_count says {declared}",
                        paths.len()),
                );
            }
            let sub = format!("{} pair {a}-{b} sel {sel}", t.name);
            audit_path_family(r, t, &sub, &paths, na, nb, switched_only);
        }
    }
}

// ---------------------------------------------------------------------
// DAG rules (AUD020/021/022/023/024/025)
// ---------------------------------------------------------------------

/// Structural DAG check shared by [`audit_stage_dag`] and the
/// `debug_assert!` self-audit in [`crate::sim::schedule::run_with`]:
/// deps in-range and non-self, a root exists, no cycle.
pub fn stage_dag_check(dag: &StageDag) -> Result<(), String> {
    let n = dag.stages.len();
    for (i, s) in dag.stages.iter().enumerate() {
        for &d in &s.deps {
            if d >= n {
                return Err(format!("stage {i} ('{}') dep {d} out of range (n={n})", s.name));
            }
            if d == i {
                return Err(format!("stage {i} ('{}') depends on itself", s.name));
            }
        }
    }
    if n > 0 && !dag.stages.iter().any(|s| s.deps.is_empty()) {
        return Err("no root stage (every stage has deps)".into());
    }
    // Kahn's algorithm; dep edges run d → dependent.
    let mut indeg = vec![0usize; n];
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, s) in dag.stages.iter().enumerate() {
        indeg[i] = s.deps.len();
        for &d in &s.deps {
            out[d].push(i);
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut done = 0;
    while let Some(v) = queue.pop() {
        done += 1;
        for &w in &out[v] {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                queue.push(w);
            }
        }
    }
    if done != n {
        return Err(format!("cycle among {} stages (topo-sorted only {done})", n));
    }
    Ok(())
}

/// AUD020 + AUD021 for a [`StageDag`].
pub fn audit_stage_dag(r: &mut AuditReport, sub: &str, dag: &StageDag) {
    r.mark("AUD020");
    r.mark("AUD021");
    let n = dag.stages.len();
    let mut structural_ok = true;
    for (i, s) in dag.stages.iter().enumerate() {
        for &d in &s.deps {
            if d >= n {
                r.fail("AUD021", sub, format!("stage {i} ('{}') dep {d} out of range", s.name));
                structural_ok = false;
            } else if d == i {
                r.fail("AUD021", sub, format!("stage {i} ('{}') depends on itself", s.name));
                structural_ok = false;
            }
        }
    }
    if n > 0 && !dag.stages.iter().any(|s| s.deps.is_empty()) {
        r.fail("AUD021", sub, "no root stage (every stage has deps)".into());
        return;
    }
    if !structural_ok {
        // Out-of-range / self deps make the cycle check unreliable;
        // AUD021 already flagged the DAG.
        return;
    }
    if let Err(e) = stage_dag_check(dag) {
        r.fail("AUD020", sub, e);
    }
}

/// AUD022: every lazy stage's declared metadata must agree with what
/// its builder actually produces (flow count exactly, payload bytes to
/// relative 1e-6).
pub fn audit_stage_dag_flows(r: &mut AuditReport, t: &Topology, sub: &str, dag: &StageDag) {
    r.mark("AUD022");
    for (i, s) in dag.stages.iter().enumerate() {
        if !s.is_lazy() {
            continue;
        }
        match s.try_materialize_flows(t) {
            Err(e) => r.fail("AUD022", sub, format!("stage {i}: {e}")),
            Ok(flows) => {
                let built: f64 = flows.iter().map(|f| f.bytes).sum();
                let declared = s.flow_bytes();
                if (built - declared).abs() > 1e-6 * declared.abs().max(1.0) {
                    r.fail(
                        "AUD022",
                        sub,
                        format!("stage {i} ('{}') declares {declared} B but builds {built} B",
                            s.name),
                    );
                }
            }
        }
    }
}

/// AUD023: the iteration DAG's declared wire bytes, grouped by stage
/// family, must equal the Table 1 analytic volumes — computed here
/// *independently* from [`analyze`] rather than by re-running the DAG
/// builder's arithmetic.
///
/// Expected totals (per-iteration, whole cluster):
/// * TP/SP/EP: `npus × row.total × ccu_exposed / pp` — Table 1 prices
///   the full model per participating NPU; each NPU holds `1/pp` of
///   the layers.
/// * DP: `npus × row.total × dp_exposed` (reduce-scatter + all-gather
///   halves).
/// * PP: `2 · microbatches · (pp − 1) · dp · act` where
///   `act = tokens_per_microbatch × hidden × BYTES_PER_ACT` — the
///   boundary tensor goes once per TP group (the documented deliberate
///   `act/(sp·tp)`-per-pair exception to Table 1's `act/sp`).
/// * compute stages carry zero wire bytes; no other stage names may
///   appear.
pub fn audit_iteration_bytes(
    r: &mut AuditReport,
    sub: &str,
    m: &ModelConfig,
    p: &ParallelismConfig,
    spec: &IterationSpec,
    dag: &StageDag,
) {
    r.mark("AUD023");
    let traffic = analyze(m, p);
    let npus = p.npus() as f64;
    let pp = p.pp as f64;
    let expect_sliced = |tech: &str, fan: usize| -> f64 {
        if fan < 2 {
            return 0.0;
        }
        traffic
            .row(tech)
            .map_or(0.0, |row| npus / pp * row.total * spec.ccu_exposed)
    };
    let mut want: BTreeMap<&str, f64> = BTreeMap::new();
    want.insert("tp", expect_sliced("TP", p.tp));
    want.insert("sp", expect_sliced("SP", p.sp));
    want.insert("ep", expect_sliced("EP", p.ep));
    want.insert(
        "dp",
        if p.dp >= 2 {
            traffic
                .row("DP")
                .map_or(0.0, |row| npus * row.total * spec.dp_exposed)
        } else {
            0.0
        },
    );
    let act = p.tokens_per_microbatch * m.hidden as f64 * BYTES_PER_ACT;
    want.insert(
        "pp",
        2.0 * p.microbatches as f64 * (p.pp - 1) as f64 * p.dp as f64 * act,
    );

    let mut got: BTreeMap<&str, f64> = BTreeMap::new();
    for s in &dag.stages {
        let b = s.flow_bytes();
        let family = if s.name == "dp-rs" || s.name == "dp-ag" {
            "dp"
        } else if s.name.ends_with("-tp") {
            "tp"
        } else if s.name.ends_with("-sp") {
            "sp"
        } else if s.name.ends_with("-ep") {
            "ep"
        } else if s.name.ends_with("-send") {
            "pp"
        } else if s.name.ends_with("-comp") {
            if b != 0.0 {
                r.fail("AUD023", sub, format!("compute stage '{}' carries {b} wire bytes", s.name));
            }
            continue;
        } else {
            r.fail("AUD023", sub, format!("unrecognized stage '{}' in iteration DAG", s.name));
            continue;
        };
        *got.entry(family).or_insert(0.0) += b;
    }
    for (family, &w) in &want {
        let g = got.get(family).copied().unwrap_or(0.0);
        if (g - w).abs() > 1e-6 * w.abs().max(1.0) {
            r.fail(
                "AUD023",
                sub,
                format!("{family} bytes: DAG carries {g:.3e}, Table 1 implies {w:.3e}"),
            );
        }
    }
}

/// AUD024: the checkpoint flow DAG must be one stage shipping exactly
/// `bytes_per_rank` per workload NPU, every flow running NPU ↔ storage.
pub fn audit_checkpoint_dag(
    r: &mut AuditReport,
    t: &Topology,
    sub: &str,
    map: &ClusterMap,
    storage: &[NodeId],
    bytes_per_rank: f64,
    to_storage: bool,
    dag: &StageDag,
) {
    r.mark("AUD024");
    if dag.stages.len() != 1 {
        r.fail("AUD024", sub, format!("{} stages (expected 1)", dag.stages.len()));
        return;
    }
    let flows = match dag.stages[0].try_materialize_flows(t) {
        Ok(f) => f,
        Err(e) => {
            r.fail("AUD024", sub, e);
            return;
        }
    };
    if flows.len() != map.npu_count() {
        r.fail(
            "AUD024",
            sub,
            format!("{} flows for {} workload NPUs", flows.len(), map.npu_count()),
        );
    }
    let npus: BTreeSet<NodeId> = map.npus().iter().copied().collect();
    let stores: BTreeSet<NodeId> = storage.iter().copied().collect();
    let mut seen_rank: BTreeSet<NodeId> = BTreeSet::new();
    for f in &flows {
        if (f.bytes - bytes_per_rank).abs() > 1e-6 * bytes_per_rank.abs().max(1.0) {
            r.fail(
                "AUD024",
                sub,
                format!("flow {} → {} carries {} B, not bytes_per_rank {}", f.src, f.dst,
                    f.bytes, bytes_per_rank),
            );
        }
        let (rank, store) = if to_storage { (f.src, f.dst) } else { (f.dst, f.src) };
        if !npus.contains(&rank) {
            r.fail("AUD024", sub, format!("flow endpoint {rank} is not a workload NPU"));
        } else if !seen_rank.insert(rank) {
            r.fail("AUD024", sub, format!("NPU {rank} checkpoints twice"));
        }
        if !stores.contains(&store) {
            r.fail("AUD024", sub, format!("flow endpoint {store} is not a storage node"));
        }
    }
}

/// AUD025: no flow of a shrunk iteration DAG may *terminate* at a
/// dead-replica NPU (dead nodes may still relay — APR draws relays
/// from outside the communicating group).
pub fn audit_shrunk_dag(
    r: &mut AuditReport,
    t: &Topology,
    sub: &str,
    dag: &StageDag,
    dead: &BTreeSet<NodeId>,
) {
    r.mark("AUD025");
    for (i, s) in dag.stages.iter().enumerate() {
        match s.try_materialize_flows(t) {
            Err(e) => r.fail("AUD025", sub, format!("stage {i}: {e}")),
            Ok(flows) => {
                for f in flows {
                    if dead.contains(&f.src) || dead.contains(&f.dst) {
                        r.fail(
                            "AUD025",
                            sub,
                            format!("stage {i} ('{}') flow {} → {} touches a dead replica",
                                s.name, f.src, f.dst),
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Fault / replica rules (AUD030/031/032)
// ---------------------------------------------------------------------

/// AUD030: fault timeline well-ordered (non-decreasing timestamps — the
/// same-instant group semantics depend on plan order, so an unsorted
/// plan silently reorders blast radii through the event heap), with
/// finite parameters and in-range link/node ids.
pub fn audit_fault_plan(r: &mut AuditReport, t: &Topology, sub: &str, plan: &FaultPlan) {
    r.mark("AUD030");
    let mut last = 0.0f64;
    for (i, (at, ev)) in plan.events.iter().enumerate() {
        if !(at.is_finite() && *at >= 0.0) {
            r.fail("AUD030", sub, format!("event {i} at t={at}"));
        } else if *at < last {
            r.fail(
                "AUD030",
                sub,
                format!("event {i} at t={at} after t={last} (timeline not sorted)"),
            );
        } else {
            last = *at;
        }
        let check_link = |r: &mut AuditReport, l: crate::topology::LinkId| {
            if l.idx() >= t.link_count() {
                r.fail("AUD030", sub, format!("event {i} names link {l} outside topology"));
            }
        };
        match ev {
            FaultEvent::LinkDown(l) | FaultEvent::LinkUp(l) => check_link(r, *l),
            FaultEvent::LinkCapacity(l, gb_s) => {
                check_link(r, *l);
                if !(gb_s.is_finite() && *gb_s >= 0.0) {
                    r.fail("AUD030", sub, format!("event {i} capacity {gb_s}"));
                }
            }
            FaultEvent::NpuDown { npu, backup } => {
                if npu.idx() >= t.node_count() {
                    r.fail("AUD030", sub, format!("event {i} names node {npu} outside topology"));
                }
                if let Some((b, act)) = backup {
                    if b.idx() >= t.node_count() {
                        r.fail("AUD030", sub, format!("event {i} backup {b} outside topology"));
                    }
                    if !(act.is_finite() && *act >= 0.0) {
                        r.fail("AUD030", sub, format!("event {i} activation {act}"));
                    }
                }
            }
        }
    }
}

/// AUD031: a sampled blast group must stay inside its declared
/// [`FaultDomains`] radius — some single domain element of the group's
/// class contains every event.
pub fn audit_fault_group(
    r: &mut AuditReport,
    sub: &str,
    d: &FaultDomains,
    g: &FaultGroup,
) {
    r.mark("AUD031");
    let links: Vec<crate::topology::LinkId> = g
        .events
        .iter()
        .filter_map(|e| match e {
            FaultEvent::LinkDown(l) | FaultEvent::LinkUp(l) | FaultEvent::LinkCapacity(l, _) => {
                Some(*l)
            }
            FaultEvent::NpuDown { .. } => None,
        })
        .collect();
    let npus: Vec<(NodeId, Option<NodeId>)> = g
        .events
        .iter()
        .filter_map(|e| match e {
            FaultEvent::NpuDown { npu, backup } => Some((*npu, backup.map(|(b, _)| b))),
            _ => None,
        })
        .collect();
    match g.class {
        BlastClass::SingleLink => {
            if links.len() != 1 || !npus.is_empty() {
                r.fail("AUD031", sub, format!("SingleLink group has {} links, {} NPU events",
                    links.len(), npus.len()));
            }
            for l in &links {
                if !d.links().contains(l) {
                    r.fail("AUD031", sub, format!("link {l} outside the link domain"));
                }
            }
        }
        BlastClass::SwitchDeath => {
            let fits = d
                .switches()
                .iter()
                .any(|(_, inc)| links.iter().all(|l| inc.contains(l)));
            if links.is_empty() || !npus.is_empty() || !fits {
                r.fail(
                    "AUD031",
                    sub,
                    format!("SwitchDeath links {links:?} are not one switch's incident set"),
                );
            }
        }
        BlastClass::BackplanePartition => {
            let fits = d
                .partitions()
                .iter()
                .any(|part| !links.is_empty() && links.iter().all(|l| part.contains(l)));
            if !fits {
                r.fail(
                    "AUD031",
                    sub,
                    format!("partition blast {links:?} matches no declared backplane partition"),
                );
            }
        }
        BlastClass::RackPower | BlastClass::NpuDeath => {
            let fits = (0..d.rack_count()).any(|i| {
                let (rack_npus, backup, switch_links) = d.rack_domain(i);
                links.iter().all(|l| switch_links.contains(l))
                    && npus.iter().all(|(n, b)| {
                        (rack_npus.contains(n) || Some(*n) == backup)
                            && b.map_or(true, |b| Some(b) == backup)
                    })
            });
            if !fits || npus.is_empty() {
                r.fail(
                    "AUD031",
                    sub,
                    format!("{:?} blast ({} links, {} NPUs) fits no rack domain", g.class,
                        links.len(), npus.len()),
                );
            }
        }
    }
}

/// AUD032: the replica map must partition the workload NPUs into `dp`
/// equal replicas — every mapped NPU in exactly one replica, nothing
/// missing, nothing extra.
pub fn audit_replica_map(
    r: &mut AuditReport,
    sub: &str,
    map: &ClusterMap,
    p: &ParallelismConfig,
    rm: &ReplicaMap,
) {
    r.mark("AUD032");
    if rm.dp != p.dp {
        r.fail("AUD032", sub, format!("replica map has dp={}, config says {}", rm.dp, p.dp));
    }
    if rm.len() != map.npu_count() {
        r.fail(
            "AUD032",
            sub,
            format!("replica map covers {} nodes, workload has {}", rm.len(), map.npu_count()),
        );
    }
    let mut sizes = vec![0usize; rm.dp.max(1)];
    for &n in map.npus() {
        match rm.replica_of(n) {
            None => r.fail("AUD032", sub, format!("workload NPU {n} has no replica")),
            Some(k) if k >= rm.dp => {
                r.fail("AUD032", sub, format!("NPU {n} in replica {k} ≥ dp {}", rm.dp))
            }
            Some(k) => sizes[k] += 1,
        }
    }
    if rm.dp > 0 && map.npu_count() % rm.dp == 0 {
        let each = map.npu_count() / rm.dp;
        for (k, &s) in sizes.iter().enumerate() {
            if s != each {
                r.fail("AUD032", sub, format!("replica {k} has {s} ranks, expected {each}"));
            }
        }
    }
}

// ---------------------------------------------------------------------
// The bake-off seam
// ---------------------------------------------------------------------

/// Full static audit of one fabric: topology rules, sampled APR path
/// rules, and the balanced-rotation lint on the shared
/// [`hrs_plane_pair`] selector. This is the eligibility gate a
/// candidate topology must pass before entering the ROADMAP item-3
/// bake-off: wire it into a [`ClusterMap`], call `audit_fabric`, and a
/// clean report admits it to the tournament.
pub fn audit_fabric(t: &Topology, map: &ClusterMap, cfg: &AuditConfig) -> AuditReport {
    let mut r = AuditReport::new();
    audit_topology(&mut r, t);
    audit_cluster_map(&mut r, t, map, cfg);
    for planes in [2usize, 4, 8] {
        audit_plane_selector(&mut r, &format!("hrs_plane_pair/{planes}"), planes, &|s| {
            hrs_plane_pair(s, planes)
        });
    }
    r
}
