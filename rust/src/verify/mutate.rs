//! The seeded-defect harness that mutation-tests the auditor itself.
//!
//! Each [`Mutation`] builds a model with exactly one planted defect and
//! runs the relevant audit rules over it. The contract, asserted by
//! `rust/tests/audit.rs` and the `audit_smoke` bench via
//! `BENCH_audit.json`:
//!
//! * the report **contains** the mutation's expected `AUD0xx` code
//!   (the defect class is caught), and
//! * **every** finding in the report carries that code (no collateral
//!   noise — a mutation that trips unrelated rules would mask false
//!   positives).
//!
//! An auditor change that silently stops detecting a defect class
//! breaks `mutations_caught == mutations_seeded` in CI.

use std::collections::BTreeSet;

use crate::reliability::faultgen::{BlastClass, FaultDomains, FaultGroup};
use crate::reliability::montecarlo::ReplicaMap;
use crate::routing::apr::{PathKind, PathSet, RoutedPath};
use crate::sim::fault::{FaultEvent, FaultPlan};
use crate::sim::flow::FlowSpec;
use crate::sim::schedule::{Stage, StageDag};
use crate::topology::rack::{ubmesh_rack, RackConfig};
use crate::topology::variants::rack_clos;
use crate::topology::{
    CableClass, Link, LinkId, LinkRole, Location, NodeKind, Topology,
};
use crate::workload::models::by_name;
use crate::workload::step::{checkpoint_flow_dag, iteration_dag, IterationSpec, RankOrder};
use crate::workload::{ClusterMap, ParallelismConfig};

use super::audit::{
    audit_checkpoint_dag, audit_fault_group, audit_fault_plan, audit_iteration_bytes,
    audit_path, audit_path_family, audit_path_set, audit_plane_selector,
    audit_replica_map, audit_shrunk_dag, audit_stage_dag, audit_stage_dag_flows,
    audit_topology, AuditReport,
};

/// One planted defect: `run()` builds the defective model and audits
/// it; the resulting report must contain `expect` and nothing else.
pub struct Mutation {
    pub name: &'static str,
    /// The diagnostic code this defect class must be caught by.
    pub expect: &'static str,
    pub run: fn() -> AuditReport,
}

fn rack_fixture() -> (Topology, ClusterMap) {
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let map = ClusterMap::rack(&h);
    (t, map)
}

fn rack_parallelism(dp: usize) -> ParallelismConfig {
    // 64-NPU rack: tp·sp·pp·dp = 64 for every dp in {2, 4}.
    ParallelismConfig {
        tp: 16 / dp,
        sp: 2,
        ep: 1,
        pp: 2,
        dp,
        microbatches: 2,
        tokens_per_microbatch: 4096.0,
    }
}

// Each mutation is a standalone fn so `Mutation::run` stays a plain
// fn pointer (no captures, trivially Send + 'static for the bench).

/// M1: a claimed path hops between two non-adjacent NPUs.
fn m_path_dead_hop() -> AuditReport {
    let (t, map) = rack_fixture();
    // npus[0] = board 0 slot 0, npus[9] = board 1 slot 1: neither the
    // board X-mesh nor the same-slot Y-mesh joins them.
    let (a, b) = (map.npus()[0], map.npus()[9]);
    let mut r = AuditReport::new();
    audit_path(&mut r, &t, "m:path-dead-hop", &[a, b], a, b);
    r
}

/// M2: a path revisits a node (every hop individually live).
fn m_path_loop() -> AuditReport {
    let (t, map) = rack_fixture();
    let (n0, n1, n2) = (map.npus()[0], map.npus()[1], map.npus()[2]);
    let mut r = AuditReport::new();
    audit_path(&mut r, &t, "m:path-loop", &[n0, n1, n0, n2], n0, n2);
    r
}

/// M3: a 40-lane cable on an x32-budget CPU port.
fn m_lane_overrun() -> AuditReport {
    let mut t = Topology::new("m:lane-overrun");
    let a = t.add_node(NodeKind::Cpu, Location::default());
    let b = t.add_node(NodeKind::Hrs, Location::default());
    t.add_link(a, b, 40, CableClass::Backplane, LinkRole::Backplane, 0.1);
    let mut r = AuditReport::new();
    audit_topology(&mut r, &t);
    r
}

/// M19: a link appended to the link table without adjacency entries
/// (the multigraph views disagree).
fn m_phantom_link() -> AuditReport {
    let (mut t, map) = rack_fixture();
    t.links.push(Link {
        a: map.npus()[0],
        b: map.npus()[9],
        lanes: 2,
        class: CableClass::PassiveElectrical,
        role: LinkRole::BoardX,
        length_m: 1.0,
    });
    let mut r = AuditReport::new();
    audit_topology(&mut r, &t);
    r
}

/// M4: a NaN cable length slipped into the link table.
fn m_nan_length() -> AuditReport {
    let (mut t, _) = rack_fixture();
    t.links[0].length_m = f64::NAN;
    let mut r = AuditReport::new();
    audit_topology(&mut r, &t);
    r
}

/// M5: path-set weights carrying a negative entry (and summing ≠ 1).
fn m_skewed_weights() -> AuditReport {
    let (t, map) = rack_fixture();
    let (n0, n1, n2) = (map.npus()[0], map.npus()[1], map.npus()[2]);
    let ps = PathSet {
        paths: vec![
            RoutedPath { nodes: vec![n0, n1], kind: PathKind::Direct, dims: vec![0] },
            RoutedPath { nodes: vec![n0, n2], kind: PathKind::Direct, dims: vec![0] },
        ],
        weights: vec![1.3, -0.3],
    };
    let mut r = AuditReport::new();
    audit_path_set(&mut r, &t, "m:skewed-weights", &ps);
    r
}

/// M6: the PR 3 bug as a selector — a multiplicative hash whose two
/// picks collide on the same plane for many seeds.
fn m_hash_selector() -> AuditReport {
    let mut r = AuditReport::new();
    audit_plane_selector(&mut r, "m:hash-selector", 4, &|s| {
        let h = s.wrapping_mul(2654435761);
        ((h % 4) as usize, ((h >> 7) % 4) as usize)
    });
    r
}

/// M7: a "multi-path" family that is the same path twice.
fn m_duplicate_paths() -> AuditReport {
    let (t, map) = rack_fixture();
    let (n0, n1) = (map.npus()[0], map.npus()[1]);
    let p = vec![n0, n1];
    let mut r = AuditReport::new();
    audit_path_family(&mut r, &t, "m:duplicate-paths", &[p.clone(), p], n0, n1, false);
    r
}

/// M8: a Clos-rack path relaying through another NPU instead of a
/// switch.
fn m_npu_relay_on_clos() -> AuditReport {
    let (t, h) = rack_clos();
    let path = vec![h.npus[0], h.hrs[0], h.npus[2], h.hrs[1], h.npus[1]];
    let mut r = AuditReport::new();
    audit_path_family(&mut r, &t, "m:npu-relay", &[path], h.npus[0], h.npus[1], true);
    r
}

/// M9: a dependency cycle behind a legitimate root stage.
fn m_dag_cycle() -> AuditReport {
    let mut dag = StageDag::default();
    dag.push(Stage::new("root"));
    dag.push(Stage::new("a"));
    dag.push(Stage::new("b"));
    dag.stages[1].deps = vec![2];
    dag.stages[2].deps = vec![1];
    let mut r = AuditReport::new();
    audit_stage_dag(&mut r, "m:dag-cycle", &dag);
    r
}

/// M10: a dependency on a stage index that does not exist.
fn m_dep_out_of_range() -> AuditReport {
    let mut dag = StageDag::default();
    dag.push(Stage::new("root"));
    dag.push(Stage::new("a"));
    dag.stages[1].deps = vec![7];
    let mut r = AuditReport::new();
    audit_stage_dag(&mut r, "m:dep-out-of-range", &dag);
    r
}

/// M11: a lazy stage declaring 5 flows / 5 kB whose builder produces 2.
fn m_lazy_count_lie() -> AuditReport {
    let (t, map) = rack_fixture();
    let (n0, n1) = (map.npus()[0], map.npus()[1]);
    let dag = StageDag::chain(vec![Stage::new("lying").with_lazy_flows(
        5,
        5_000.0,
        move |t| {
            vec![
                FlowSpec::along(t, &[n0, n1], 500.0),
                FlowSpec::along(t, &[n1, n0], 500.0),
            ]
        },
    )]);
    let mut r = AuditReport::new();
    audit_stage_dag_flows(&mut r, &t, "m:lazy-count-lie", &dag);
    r
}

/// M12: an extra TP stage smuggled into the iteration DAG, inflating
/// the wire bytes past the Table 1 volume.
fn m_byte_inflation() -> AuditReport {
    let (t, map) = rack_fixture();
    let m = by_name("llama-70b").unwrap();
    let p = rack_parallelism(2);
    let spec = IterationSpec::default();
    let mut dag = iteration_dag(&t, &map, &m, &p, RankOrder::TopologyAware, &spec);
    let (n0, n1) = (map.npus()[0], map.npus()[1]);
    dag.push(Stage::new("s0-f9-tp").with_flows(vec![FlowSpec::along(&t, &[n0, n1], 1e6)]));
    let mut r = AuditReport::new();
    audit_iteration_bytes(&mut r, "m:byte-inflation", &m, &p, &spec, &dag);
    r
}

/// M13: a fault timeline with events out of order.
fn m_unsorted_plan() -> AuditReport {
    let (t, _) = rack_fixture();
    let plan = FaultPlan {
        events: vec![
            (50.0, FaultEvent::LinkDown(LinkId(0))),
            (10.0, FaultEvent::LinkUp(LinkId(0))),
        ],
        recovery: None,
    };
    let mut r = AuditReport::new();
    audit_fault_plan(&mut r, &t, "m:unsorted-plan", &plan);
    r
}

/// M14: a backplane-partition blast on a fabric whose domains declare
/// no backplane partitions at all.
fn m_blast_outside_domain() -> AuditReport {
    let (t, h) = rack_clos();
    let d = FaultDomains::flat(&t, &h.npus, &h.hrs);
    let g = FaultGroup {
        class: BlastClass::BackplanePartition,
        events: vec![FaultEvent::LinkDown(LinkId(0))],
        aborts: false,
    };
    let mut r = AuditReport::new();
    audit_fault_group(&mut r, "m:blast-outside-domain", &d, &g);
    r
}

/// M15: a replica map built for dp=4 audited against a dp=2 config.
fn m_dp_mismatch() -> AuditReport {
    let (_, map) = rack_fixture();
    let rm = ReplicaMap::new(&map, &rack_parallelism(4), RankOrder::TopologyAware);
    let mut r = AuditReport::new();
    audit_replica_map(&mut r, "m:dp-mismatch", &map, &rack_parallelism(2), &rm);
    r
}

/// M16: a routed path whose dimension order restarts twice — TFC needs
/// 3 VLs, one more than the UB-Mesh budget.
fn m_vl_overflow() -> AuditReport {
    let mut t = Topology::new("m:vl-overflow");
    let n: Vec<_> = (0..6)
        .map(|i| t.add_node(NodeKind::Npu, Location::new(0, 0, 0, 0, i as u8)))
        .collect();
    for w in n.windows(2) {
        t.add_link(w[0], w[1], 2, CableClass::PassiveElectrical, LinkRole::BoardX, 1.0);
    }
    let path = RoutedPath {
        nodes: n,
        kind: PathKind::Detour,
        dims: vec![0, 1, 0, 1, 0],
    };
    let mut r = AuditReport::new();
    super::audit::audit_tfc(&mut r, &t, "m:vl-overflow", &[path]);
    r
}

/// M17: a checkpoint DAG that silently dropped one rank's flow.
fn m_ckpt_flow_dropped() -> AuditReport {
    let (mut t, map) = rack_fixture();
    let storage = vec![t.add_node(NodeKind::Hrs, Location::default())];
    // Attach storage behind the rack's inter-rack LRS layer so every
    // rank has a switch path to it.
    for lrs in t.nodes_of_kind(NodeKind::Lrs) {
        t.add_link(lrs, storage[0], 2, CableClass::Optical, LinkRole::Dcn, 100.0);
    }
    let dag = checkpoint_flow_dag(&t, &map, &storage, 10e6, true);
    let mut flows = dag.stages[0].eager_flows().unwrap().to_vec();
    flows.pop();
    let broken = StageDag::chain(vec![Stage::new("ckpt-write").with_flows(flows)]);
    let mut r = AuditReport::new();
    audit_checkpoint_dag(&mut r, &t, "m:ckpt-flow-dropped", &map, &storage, 10e6, true, &broken);
    r
}

/// M18: a DAG claimed to be shrunk while a dead replica's rank still
/// terminates flows.
fn m_shrink_skipped() -> AuditReport {
    let (t, map) = rack_fixture();
    let m = by_name("llama-70b").unwrap();
    let p = rack_parallelism(2);
    let dag = iteration_dag(&t, &map, &m, &p, RankOrder::TopologyAware, &IterationSpec::default());
    let dead: BTreeSet<_> = [map.npus()[0]].into_iter().collect();
    let mut r = AuditReport::new();
    audit_shrunk_dag(&mut r, &t, "m:shrink-skipped", &dag, &dead);
    r
}

/// The full seeded-defect matrix, one entry per defect class. Order is
/// stable (sorted by expected code) so `BENCH_audit.json` diffs
/// cleanly.
pub fn seeded_mutations() -> Vec<Mutation> {
    vec![
        Mutation { name: "path-dead-hop", expect: "AUD001", run: m_path_dead_hop },
        Mutation { name: "path-loop", expect: "AUD002", run: m_path_loop },
        Mutation { name: "lane-overrun", expect: "AUD003", run: m_lane_overrun },
        Mutation { name: "phantom-link", expect: "AUD004", run: m_phantom_link },
        Mutation { name: "nan-length", expect: "AUD005", run: m_nan_length },
        Mutation { name: "skewed-weights", expect: "AUD010", run: m_skewed_weights },
        Mutation { name: "hash-selector", expect: "AUD011", run: m_hash_selector },
        Mutation { name: "duplicate-paths", expect: "AUD012", run: m_duplicate_paths },
        Mutation { name: "npu-relay-on-clos", expect: "AUD013", run: m_npu_relay_on_clos },
        Mutation { name: "vl-overflow", expect: "AUD014", run: m_vl_overflow },
        Mutation { name: "dag-cycle", expect: "AUD020", run: m_dag_cycle },
        Mutation { name: "dep-out-of-range", expect: "AUD021", run: m_dep_out_of_range },
        Mutation { name: "lazy-count-lie", expect: "AUD022", run: m_lazy_count_lie },
        Mutation { name: "byte-inflation", expect: "AUD023", run: m_byte_inflation },
        Mutation { name: "ckpt-flow-dropped", expect: "AUD024", run: m_ckpt_flow_dropped },
        Mutation { name: "shrink-skipped", expect: "AUD025", run: m_shrink_skipped },
        Mutation { name: "unsorted-plan", expect: "AUD030", run: m_unsorted_plan },
        Mutation { name: "blast-outside-domain", expect: "AUD031", run: m_blast_outside_domain },
        Mutation { name: "dp-mismatch", expect: "AUD032", run: m_dp_mismatch },
    ]
}
