//! Relative price book (DESIGN.md §1 substitution: the paper's in-house
//! CapEx numbers are proprietary; we normalize to NPU = 1.0 with
//! street-price ratios for the rest).
//!
//! These constants are calibration anchors — set once, used by every
//! experiment (DESIGN.md §5). They are chosen so the Clos baseline's
//! network share lands near the paper's "67% of system cost", which
//! published cluster TCO analyses also support.

/// Price units relative to one NPU.
pub const NPU: f64 = 1.0;
pub const BACKUP_NPU: f64 = 1.0;
pub const CPU: f64 = 0.12;
/// Low-radix switch: commodity ASIC, x72 lanes.
pub const LRS: f64 = 0.04;
/// High-radix switch: x512 lanes, large buffers.
pub const HRS: f64 = 0.75;
/// Cables, per physical cable.
pub const PASSIVE_CABLE: f64 = 0.002;
pub const ACTIVE_CABLE: f64 = 0.010;
pub const OPTICAL_CABLE: f64 = 0.012;
/// Per optical transceiver module (2 per optical cable bundle).
pub const OPTICAL_MODULE: f64 = 0.045;

/// Power draw (kW) per component — OpEx inputs.
pub const NPU_KW: f64 = 0.75;
pub const CPU_KW: f64 = 0.30;
pub const LRS_KW: f64 = 0.15;
pub const HRS_KW: f64 = 0.80;
pub const OPTICAL_MODULE_KW: f64 = 0.015;

/// Electricity + facility cost per kW-year, in NPU-price units.
pub const KW_YEAR: f64 = 0.002;
/// System lifetime (years) for TCO.
pub const LIFETIME_YEARS: f64 = 5.0;
/// Maintenance cost per failure event (truck roll + part), NPU units.
pub const COST_PER_REPAIR: f64 = 0.02;
