//! Cost-efficiency (Eq. 1): `Average Performance / (OpEx + CapEx)`.

use super::capex::CapexReport;
use super::opex::OpexReport;

/// Eq. 1 with performance relative to a baseline (the paper uses
/// training throughput relative to Clos).
pub fn cost_efficiency(perf: f64, capex: &CapexReport, opex: &OpexReport) -> f64 {
    perf / (capex.total() + opex.total())
}

#[cfg(test)]
mod tests {
    use super::super::capex::{capex_full_clos, capex_ubmesh};
    use super::super::opex::opex;
    use super::*;
    use crate::topology::superpod::SuperPodConfig;

    #[test]
    fn headline_cost_efficiency_near_2x() {
        // Paper: UB-Mesh at ~95% of Clos performance with far lower TCO
        // → 2.04× cost-efficiency.
        let ub_capex = capex_ubmesh(&SuperPodConfig::default());
        let clos_capex = capex_full_clos("x64T Clos", 8192, 64);
        let ub = cost_efficiency(0.95, &ub_capex, &opex(&ub_capex, 88.9));
        let clos = cost_efficiency(1.0, &clos_capex, &opex(&clos_capex, 632.8));
        let ratio = ub / clos;
        assert!(
            (1.6..2.9).contains(&ratio),
            "cost-efficiency ratio {ratio} (paper: 2.04×)"
        );
    }

    #[test]
    fn efficiency_monotone_in_perf() {
        let c = capex_full_clos("c", 1024, 16);
        let o = opex(&c, 10.0);
        assert!(cost_efficiency(1.0, &c, &o) > cost_efficiency(0.5, &c, &o));
    }
}
