//! System cost model (§6.4): CapEx from component censuses, OpEx from
//! power + maintenance, and the cost-efficiency metric of Eq. 1.

pub mod capex;
pub mod efficiency;
pub mod opex;
pub mod prices;

pub use capex::CapexReport;
pub use efficiency::cost_efficiency;
