//! CapEx accounting per architecture (Fig 21).
//!
//! UB-Mesh CapEx comes from the real constructed-topology census; Clos
//! baselines use the analytic [`ClosDesign`] sizing (building the 8K ×
//! x64 Clos graph would be pointless — only counts enter the cost).

use crate::topology::census::Census;
use crate::topology::clos::ClosDesign;
use crate::topology::superpod::SuperPodConfig;
use crate::topology::{CableClass, NodeKind};

use super::prices;

/// Component counts + price rollup for one architecture.
#[derive(Clone, Debug, Default)]
pub struct CapexReport {
    pub name: String,
    pub npus: usize,
    pub backup_npus: usize,
    pub cpus: usize,
    pub lrs: usize,
    pub hrs: usize,
    pub passive_cables: u64,
    pub active_cables: u64,
    pub optical_cables: u64,
    pub optical_modules: u64,
}

impl CapexReport {
    pub fn compute_cost(&self) -> f64 {
        self.npus as f64 * prices::NPU
            + self.backup_npus as f64 * prices::BACKUP_NPU
            + self.cpus as f64 * prices::CPU
    }

    pub fn network_cost(&self) -> f64 {
        self.lrs as f64 * prices::LRS
            + self.hrs as f64 * prices::HRS
            + self.passive_cables as f64 * prices::PASSIVE_CABLE
            + self.active_cables as f64 * prices::ACTIVE_CABLE
            + self.optical_cables as f64 * prices::OPTICAL_CABLE
            + self.optical_modules as f64 * prices::OPTICAL_MODULE
    }

    pub fn total(&self) -> f64 {
        self.compute_cost() + self.network_cost()
    }

    /// "UB-Mesh successfully reduces the ratio of network infrastructure
    /// cost in the system from 67% to 20%."
    pub fn network_share(&self) -> f64 {
        self.network_cost() / self.total()
    }

    /// Total power (kW) — OpEx input.
    pub fn power_kw(&self) -> f64 {
        self.npus as f64 * prices::NPU_KW
            + self.backup_npus as f64 * prices::NPU_KW
            + self.cpus as f64 * prices::CPU_KW
            + self.lrs as f64 * prices::LRS_KW
            + self.hrs as f64 * prices::HRS_KW
            + self.optical_modules as f64 * prices::OPTICAL_MODULE_KW
    }
}

/// CapEx of the UB-Mesh SuperPod from its constructed census.
pub fn capex_ubmesh(cfg: &SuperPodConfig) -> CapexReport {
    let (t, _) = crate::topology::superpod::ubmesh_superpod(cfg);
    let c = Census::of(&t);
    CapexReport {
        name: "4D-FM+Clos (UB-Mesh)".into(),
        npus: c.count(NodeKind::Npu),
        backup_npus: c.count(NodeKind::BackupNpu),
        cpus: c.count(NodeKind::Cpu),
        lrs: c.count(NodeKind::Lrs),
        hrs: c.count(NodeKind::Hrs),
        passive_cables: c.cables_of(CableClass::PassiveElectrical),
        active_cables: c.cables_of(CableClass::ActiveElectrical),
        optical_cables: c.cables_of(CableClass::Optical),
        optical_modules: c.optical_modules,
    }
}

/// CapEx of a mesh-intra-rack + Clos-inter-rack hybrid ("2D-FM+x16" /
/// "1D-FM+x16" of Fig 21): racks keep `rack_lrs` LRS and the intra-rack
/// mesh cables; all `lanes_per_npu` inter-rack lanes go to a
/// non-blocking HRS fabric.
pub fn capex_fm_clos(
    name: &str,
    npus: usize,
    lanes_per_npu: u32,
    mesh_dims: u32,
) -> CapexReport {
    let racks = npus / 64;
    let fabric = ClosDesign::non_blocking(npus, lanes_per_npu, 512);
    // Intra-rack mesh cables: X always (224/rack), Y only for 2D (224).
    let passive = match mesh_dims {
        2 => racks as u64 * 448,
        1 => racks as u64 * 224,
        _ => 0,
    };
    // 1D/2D-FM racks keep the LRS backplane (72/rack for 2D, 32 LRS +
    // 4 in-rack HRS for 1D-FM-A, Fig 16-b).
    let lrs = racks * 72;
    let rack_hrs = if mesh_dims == 1 { racks * 4 } else { 0 };
    CapexReport {
        name: name.into(),
        npus,
        backup_npus: racks,
        cpus: racks * 4,
        lrs,
        hrs: fabric.total_switches() + rack_hrs,
        passive_cables: passive + npus as u64, // NPU→leaf attach bundles
        active_cables: 0,
        optical_cables: fabric.optical_cables(),
        optical_modules: fabric.optical_modules(),
    }
}

/// CapEx of the fully symmetric Clos ("x64T Clos" when lanes = 64).
pub fn capex_full_clos(name: &str, npus: usize, lanes_per_npu: u32) -> CapexReport {
    let fabric = ClosDesign::non_blocking(npus, lanes_per_npu, 512);
    let racks = npus / 64;
    CapexReport {
        name: name.into(),
        npus,
        backup_npus: 0,
        cpus: racks * 4,
        lrs: racks * 18, // CPU-attach LRS (the paper's Clos keeps some)
        hrs: fabric.total_switches(),
        passive_cables: npus as u64,
        active_cables: 0,
        optical_cables: fabric.optical_cables(),
        optical_modules: fabric.optical_modules(),
    }
}

/// CapEx surcharge for widening the backplane-mesh lanes beyond the
/// x72 LRS the census prices. A board-side LRS spends
/// `17 × mesh_lanes + 32` lanes (17 full-mesh peers in its plane plus
/// the x32 NPU/out attach): the default x2 mesh fits the x72 budget
/// exactly (66), but the fig20 mesh sweep's wider widths need a larger
/// (costlier) part — priced pro-rata over the base radix per LRS. Zero
/// when the width still fits x72, so the default topology's census
/// stays authoritative.
pub fn lrs_radix_surcharge(lrs_count: usize, mesh_lanes: u32) -> f64 {
    let base = NodeKind::Lrs.ub_lanes();
    let radix = 17 * mesh_lanes + 32;
    lrs_count as f64 * prices::LRS * f64::from(radix.saturating_sub(base)) / f64::from(base)
}

/// Switch / optical savings vs a baseline (the 98% / 93% claims).
pub fn savings(ub: &CapexReport, clos: &CapexReport) -> (f64, f64) {
    let hrs_saved = 1.0 - ub.hrs as f64 / clos.hrs.max(1) as f64;
    let optics_saved = 1.0 - ub.optical_modules as f64 / clos.optical_modules.max(1) as f64;
    (hrs_saved, optics_saved)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_superpod() -> SuperPodConfig {
        let mut cfg = SuperPodConfig::default();
        cfg.pods = 2;
        cfg.pod.rows = 2;
        cfg.pod.cols = 2;
        cfg
    }

    #[test]
    fn ubmesh_capex_is_compute_dominated() {
        let r = capex_ubmesh(&small_superpod());
        assert!(r.network_share() < 0.35, "network share {}", r.network_share());
        assert!(r.npus > 0 && r.lrs > 0);
    }

    #[test]
    fn clos_capex_is_network_heavy() {
        let r = capex_full_clos("x64T Clos", 8192, 64);
        assert!(
            r.network_share() > 0.45,
            "Clos network share {} (paper: 67%)",
            r.network_share()
        );
    }

    #[test]
    fn fig21_ordering_holds() {
        // 4D-FM < 2D-FM+x16 < 1D-FM+x16 < x64T Clos (total cost).
        let ub = capex_ubmesh(&SuperPodConfig::default());
        let fm2 = capex_fm_clos("2D-FM+x16", 8192, 16, 2);
        let fm1 = capex_fm_clos("1D-FM+x16", 8192, 16, 1);
        let clos = capex_full_clos("x64T Clos", 8192, 64);
        assert!(ub.total() < fm2.total());
        assert!(fm2.total() <= fm1.total() * 1.05);
        assert!(fm1.total() < clos.total());
        // Paper: 2.46× CapEx reduction vs x64T Clos; accept 1.8–3.2×.
        let ratio = clos.total() / ub.total();
        assert!((1.8..3.2).contains(&ratio), "x64T/UB CapEx ratio {ratio}");
    }

    #[test]
    fn switch_and_optics_savings_match_headline() {
        let ub = capex_ubmesh(&SuperPodConfig::default());
        let clos = capex_full_clos("x64T Clos", 8192, 64);
        let (hrs_saved, optics_saved) = savings(&ub, &clos);
        // Paper: 98% HRS and 93% optical-module savings.
        assert!(hrs_saved > 0.95, "HRS saved {hrs_saved}");
        assert!(optics_saved > 0.85, "optics saved {optics_saved}");
    }

    #[test]
    fn optical_cable_lane_bundling_consistent() {
        assert_eq!(crate::topology::clos::OPTICAL_CABLE_LANES, 8);
    }

    #[test]
    fn mesh_width_surcharge_prices_oversize_lrs_only() {
        // x1 (49 lanes) and the default x2 (66) fit the x72 budget.
        assert_eq!(lrs_radix_surcharge(9216, 1), 0.0);
        assert_eq!(lrs_radix_surcharge(9216, 2), 0.0);
        // x4 needs a 100-lane part: 28 excess / 72 × 0.04 per LRS over
        // the 8K SuperPod's 9216 LRS.
        let m4 = lrs_radix_surcharge(9216, 4);
        assert!((m4 - 9216.0 * prices::LRS * 28.0 / 72.0).abs() < 1e-9);
        // x8 (168 lanes) costs more than 3× the x4 surcharge.
        assert!(lrs_radix_surcharge(9216, 8) > 3.0 * m4);
    }
}
