//! OpEx model: electricity + maintenance over the system lifetime.
//!
//! "UB-Mesh reduces OpEx by about 35% compared with Clos, due to its
//! much fewer use of switches and optic modules. ... OpEx accounts for
//! around 30% of TCO."

use super::capex::CapexReport;
use super::prices;

/// Lifetime OpEx in NPU-price units.
#[derive(Clone, Debug)]
pub struct OpexReport {
    pub power_cost: f64,
    pub maintenance_cost: f64,
}

impl OpexReport {
    pub fn total(&self) -> f64 {
        self.power_cost + self.maintenance_cost
    }
}

/// Compute lifetime OpEx for an architecture. `annual_failures` comes
/// from the reliability model's AFR census.
pub fn opex(capex: &CapexReport, annual_failures: f64) -> OpexReport {
    let power_cost = capex.power_kw() * prices::KW_YEAR * prices::LIFETIME_YEARS;
    let maintenance_cost =
        annual_failures * prices::COST_PER_REPAIR * prices::LIFETIME_YEARS;
    OpexReport {
        power_cost,
        maintenance_cost,
    }
}

/// Network-only OpEx (excludes the NPUs/CPUs both architectures share) —
/// the quantity the 35%-reduction claim compares.
pub fn network_opex(capex: &CapexReport, annual_failures: f64) -> f64 {
    let network_kw = capex.lrs as f64 * prices::LRS_KW
        + capex.hrs as f64 * prices::HRS_KW
        + capex.optical_modules as f64 * prices::OPTICAL_MODULE_KW;
    network_kw * prices::KW_YEAR * prices::LIFETIME_YEARS
        + annual_failures * prices::COST_PER_REPAIR * prices::LIFETIME_YEARS
}

#[cfg(test)]
mod tests {
    use super::super::capex::{capex_full_clos, capex_ubmesh};
    use super::*;
    use crate::topology::superpod::SuperPodConfig;

    #[test]
    fn clos_network_opex_higher() {
        let ub = capex_ubmesh(&SuperPodConfig::default());
        let clos = capex_full_clos("x64T", 8192, 64);
        // AFR numbers roughly per Table 6.
        let ub_opex = network_opex(&ub, 88.9);
        let clos_opex = network_opex(&clos, 632.8);
        assert!(
            ub_opex < clos_opex * 0.7,
            "UB net-OpEx {ub_opex} vs Clos {clos_opex} (paper: −35%)"
        );
    }

    #[test]
    fn opex_components_positive() {
        let ub = capex_ubmesh(&SuperPodConfig::default());
        let o = opex(&ub, 88.9);
        assert!(o.power_cost > 0.0 && o.maintenance_cost > 0.0);
    }
}
