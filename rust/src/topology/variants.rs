//! Intra-rack baseline architectures of Fig 16 (b)–(d), used by the Fig
//! 17 exploration and the CapEx comparison (Fig 21).
//!
//! * **1D-FM-A** — keeps the on-board X full-mesh; cross-board traffic
//!   goes through 32 LRS (x16 per NPU); inter-rack through 4 HRS (x16
//!   per NPU).
//! * **1D-FM-B** — replaces the cross-board LRS with 8 HRS which also
//!   carry inter-rack traffic (x32 per NPU inter-rack).
//! * **Clos** — no direct NPU-NPU links at all: 16 HRS in a symmetric
//!   single-stage fabric ("4×4 HRS"), x4 from every NPU to every HRS,
//!   with x256 per HRS left for inter-rack (x64 per NPU aggregate).

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::node::{Location, NodeKind};
use super::ublink::X_LANES_PER_NEIGHBOR;

/// Handles into a variant rack.
#[derive(Clone, Debug)]
pub struct VariantHandles {
    /// NPUs in rank order (board-major).
    pub npus: Vec<NodeId>,
    /// Low-radix switches.
    pub lrs: Vec<NodeId>,
    /// High-radix switches.
    pub hrs: Vec<NodeId>,
}

fn add_npus(t: &mut Topology, boards: usize, slots: usize) -> Vec<NodeId> {
    let mut npus = Vec::with_capacity(boards * slots);
    for b in 0..boards {
        for s in 0..slots {
            npus.push(t.add_node(NodeKind::Npu, Location::new(0, 0, 0, b as u8, s as u8)));
        }
    }
    npus
}

fn board_x_mesh(t: &mut Topology, npus: &[NodeId], boards: usize, slots: usize, lanes: u32) {
    for b in 0..boards {
        for s1 in 0..slots {
            for s2 in (s1 + 1)..slots {
                t.add_link(
                    npus[b * slots + s1],
                    npus[b * slots + s2],
                    lanes,
                    CableClass::PassiveElectrical,
                    LinkRole::BoardX,
                    0.3,
                );
            }
        }
    }
}

/// Fig 16-(b): 1D-FM-A. X-mesh on board + 32 cross-board LRS + 4
/// inter-rack HRS.
pub fn rack_1dfm_a() -> (Topology, VariantHandles) {
    let (boards, slots) = (8, 8);
    let mut t = Topology::new("rack-1dfm-a");
    let npus = add_npus(&mut t, boards, slots);
    board_x_mesh(&mut t, &npus, boards, slots, X_LANES_PER_NEIGHBOR);

    // 32 LRS for cross-board communication; each NPU has x16 to its LRS
    // (2 NPUs per LRS → 32 down-lanes per LRS).
    let lrs: Vec<NodeId> = (0..32)
        .map(|_| t.add_node(NodeKind::Lrs, Location::default()))
        .collect();
    for (i, &n) in npus.iter().enumerate() {
        t.add_link(
            n,
            lrs[i / 2],
            16,
            CableClass::Backplane,
            LinkRole::NpuSwitch,
            0.5,
        );
    }
    // LRS full-mesh so any cross-board pair is LRS-routable (x1 links:
    // 31 mesh + 32 down = 63 ≤ x72 budget).
    for i in 0..lrs.len() {
        for j in (i + 1)..lrs.len() {
            t.add_link(
                lrs[i],
                lrs[j],
                1,
                CableClass::Backplane,
                LinkRole::LrsMesh,
                0.5,
            );
        }
    }

    // 4 HRS for inter-rack: x16 per NPU, x4 to each HRS.
    let hrs: Vec<NodeId> = (0..4)
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    for &n in &npus {
        for &h in &hrs {
            t.add_link(n, h, 4, CableClass::Backplane, LinkRole::NpuSwitch, 0.5);
        }
    }
    debug_assert!(t.check_lane_budgets().is_ok());
    (
        t,
        VariantHandles {
            npus,
            lrs,
            hrs,
        },
    )
}

/// Fig 16-(c): 1D-FM-B. X-mesh on board + 8 HRS for cross-board AND
/// inter-rack (x32 per NPU inter-rack), 4 LRS for CPU attach.
pub fn rack_1dfm_b() -> (Topology, VariantHandles) {
    let (boards, slots) = (8, 8);
    let mut t = Topology::new("rack-1dfm-b");
    let npus = add_npus(&mut t, boards, slots);
    board_x_mesh(&mut t, &npus, boards, slots, X_LANES_PER_NEIGHBOR);

    // 8 HRS: each NPU x4 to each (32 lanes); HRS has 256 down + 256 up.
    let hrs: Vec<NodeId> = (0..8)
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    for &n in &npus {
        for &h in &hrs {
            t.add_link(n, h, 4, CableClass::Backplane, LinkRole::NpuSwitch, 0.5);
        }
    }
    // 4 LRS for NPU-CPU communication (x1 per NPU; CPUs omitted here —
    // the CPU pool attaches identically to the 2D-FM rack's).
    let lrs: Vec<NodeId> = (0..4)
        .map(|_| t.add_node(NodeKind::Lrs, Location::default()))
        .collect();
    for (i, &n) in npus.iter().enumerate() {
        t.add_link(
            n,
            lrs[i % 4],
            1,
            CableClass::Backplane,
            LinkRole::Backplane,
            0.5,
        );
    }
    debug_assert!(t.check_lane_budgets().is_ok());
    (
        t,
        VariantHandles {
            npus,
            lrs,
            hrs,
        },
    )
}

/// Fig 16-(d): intra-rack Clos. No direct NPU-NPU links; 16 HRS, x4 from
/// every NPU to every HRS (x64 per NPU), x256 per HRS for inter-rack.
pub fn rack_clos() -> (Topology, VariantHandles) {
    let (boards, slots) = (8, 8);
    let mut t = Topology::new("rack-clos");
    let npus = add_npus(&mut t, boards, slots);
    let hrs: Vec<NodeId> = (0..16)
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    for &n in &npus {
        for &h in &hrs {
            t.add_link(n, h, 4, CableClass::Backplane, LinkRole::NpuSwitch, 0.5);
        }
    }
    debug_assert!(t.check_lane_budgets().is_ok());
    (
        t,
        VariantHandles {
            npus,
            lrs: vec![],
            hrs,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a_has_board_mesh_and_switches() {
        let (t, h) = rack_1dfm_a();
        assert_eq!(h.npus.len(), 64);
        assert_eq!(h.lrs.len(), 32);
        assert_eq!(h.hrs.len(), 4);
        // same-board pair: direct; cross-board: via LRS (2 switch hops max)
        let p = t.shortest_path(h.npus[0], h.npus[9], true).unwrap();
        assert!(p.len() - 1 <= 3);
        t.check_lane_budgets().unwrap();
    }

    #[test]
    fn b_routes_cross_board_via_hrs() {
        let (t, h) = rack_1dfm_b();
        let p = t.shortest_path(h.npus[0], h.npus[8], false).unwrap();
        // npu -> HRS -> npu.
        assert_eq!(p.len(), 3);
        assert_eq!(t.node(p[1]).kind, NodeKind::Hrs);
    }

    #[test]
    fn clos_is_single_switch_hop_everywhere() {
        let (t, h) = rack_clos();
        for &b in &[h.npus[1], h.npus[13], h.npus[63]] {
            let p = t.shortest_path(h.npus[0], b, false).unwrap();
            assert_eq!(p.len(), 3, "one HRS hop");
        }
        // No NPU-NPU links at all.
        assert!(t
            .links
            .iter()
            .all(|l| !(t.node(l.a).kind.is_npu() && t.node(l.b).kind.is_npu())));
    }

    #[test]
    fn npu_lane_budgets() {
        for (t, h) in [rack_1dfm_a(), rack_1dfm_b(), rack_clos()] {
            for &n in &h.npus {
                assert!(t.lanes_used(n) <= 72);
            }
        }
    }
}
