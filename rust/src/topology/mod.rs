//! Topology model and builders for UB-Mesh and baseline architectures.
//!
//! The paper's §3 describes the nD-FullMesh topology and its concrete
//! 4D-FullMesh realization (UB-Mesh-Pod / SuperPod). This module provides
//! the graph substrate plus builders for:
//!
//! * [`rack::ubmesh_rack`] — 2D-FullMesh rack: 8 boards × 8 NPUs, the
//!   64+1 backup NPU, the 18-LRS backplane (Fig 7-b, Fig 8).
//! * [`pod::ubmesh_pod`] — 4×4 racks in a 2D-FullMesh = the 4D-FullMesh
//!   UB-Mesh-Pod (Fig 7-a/c).
//! * [`superpod::ubmesh_superpod`] — pods joined by HRS Clos (§3.3.4).
//! * [`variants`] — 1D-FM-A / 1D-FM-B intra-rack baselines (Fig 16).
//! * [`clos::clos_cluster`] — symmetric Clos baselines.
//! * [`torus`] / [`dragonfly`] — §2.3 comparison topologies.
//! * [`ndmesh::nd_fullmesh`] — the generic recursive builder (§3.1).
//! * [`census`] — cable/switch/optic censuses feeding Table 2 & Fig 21.

pub mod census;
pub mod clos;
pub mod dcn;
pub mod dragonfly;
pub mod graph;
pub mod ids;
pub mod link;
pub mod ndmesh;
pub mod node;
pub mod pod;
pub mod rack;
pub mod superpod;
pub mod torus;
pub mod ublink;
pub mod variants;

pub use graph::Topology;
pub use ids::{Channel, LinkId, NodeId};
pub use link::{CableClass, Link, LinkRole};
pub use node::{Location, Node, NodeKind};
