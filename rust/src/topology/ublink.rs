//! Unified Bus (UB) lane bandwidth/latency model (§3.2.2).
//!
//! All component interconnects in UB-Mesh are UB lanes; bandwidth is
//! allocated per dimension by assigning lane counts (Fig 5-b). These
//! constants are the calibration anchors referenced by DESIGN.md §5 —
//! they are set once and every experiment derives from them.

use super::link::CableClass;

/// Unidirectional bandwidth per UB lane, GB/s.
///
/// Chosen so an NPU's x72 total IO ≈ 3.6 Tbps, satisfying the paper's R2
/// ("interconnect bandwidth exceeding 3.2 Tbps per node").
pub const LANE_GB_S: f64 = 6.25;

/// Default lane allocation for a UB-Mesh NPU's x72 IO (Fig 5-b + §3.3):
/// 7 X-neighbors × x4 + 7 Y-neighbors × x4 + x16 to the LRS backplane
/// (inter-rack, CPU, backup) = 72.
pub const X_LANES_PER_NEIGHBOR: u32 = 4;
pub const Y_LANES_PER_NEIGHBOR: u32 = 4;
pub const NPU_BACKPLANE_LANES: u32 = 16;

/// Per-cable-class propagation + serialization-overhead latency, µs.
/// Electrical short-reach links are fastest; optical adds transceiver
/// latency. Values are per-hop one-way.
pub fn hop_latency_us(class: CableClass) -> f64 {
    match class {
        CableClass::PassiveElectrical => 0.15,
        CableClass::ActiveElectrical => 0.25,
        CableClass::Optical => 0.60,
        CableClass::Backplane => 0.10,
    }
}

/// Switch traversal latency, µs (applies when the hop's endpoint is a
/// switch that forwards the packet).
pub const SWITCH_LATENCY_US: f64 = 0.35;

/// Per-message software/protocol overhead at the source (α in the α-β
/// model), µs. UB's unified protocol avoids PCIe/NIC protocol conversion
/// (§3.2.2), so this is small.
pub const MESSAGE_ALPHA_US: f64 = 2.0;

/// Bandwidth of `lanes` UB lanes, GB/s unidirectional.
#[inline]
pub fn lanes_gb_s(lanes: u32) -> f64 {
    lanes as f64 * LANE_GB_S
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npu_io_exceeds_3_2_tbps() {
        // R2: x72 lanes ≥ 3.2 Tbps = 400 GB/s.
        assert!(lanes_gb_s(72) >= 400.0);
    }

    #[test]
    fn default_lane_budget_sums_to_72() {
        assert_eq!(
            7 * X_LANES_PER_NEIGHBOR + 7 * Y_LANES_PER_NEIGHBOR + NPU_BACKPLANE_LANES,
            72
        );
    }

    #[test]
    fn optical_slower_than_electrical() {
        assert!(
            hop_latency_us(CableClass::Optical)
                > hop_latency_us(CableClass::PassiveElectrical)
        );
    }
}
