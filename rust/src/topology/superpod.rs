//! UB-Mesh-SuperPod: multiple pods joined by a symmetric HRS Clos tier
//! (§3.3.4). "We choose to adopt the symmetrical Clos topology in the
//! Pod-level interconnection ... use high-radix Pod-switches (HRS) to
//! connect each rack in the SuperPod, scaling up to 8K NPUs."

use super::graph::Topology;
use super::ids::NodeId;
use super::node::{Location, NodeKind};
use super::pod::{build_pod, wire_uplinks, PodConfig, PodHandles};

/// SuperPod parameters. Default: 8 pods × 1024 NPUs = 8K.
#[derive(Clone, Debug)]
pub struct SuperPodConfig {
    pub pods: usize,
    pub pod: PodConfig,
    /// Rack-uplink oversubscription ratio N:1 (1 = the paper's x256 per
    /// rack). Each uplink LRS exposes x32/N toward the HRS tier, so the
    /// rack's aggregate uplink shrinks to 256/N lanes while the HRS
    /// tier stays sized for 1:1 — the §3.3.4 switch-port economy knob
    /// the Rail-only comparison argues over.
    pub uplink_oversub: u32,
}

impl Default for SuperPodConfig {
    fn default() -> Self {
        SuperPodConfig {
            pods: 8,
            pod: PodConfig::default(),
            uplink_oversub: 1,
        }
    }
}

impl SuperPodConfig {
    pub fn npus(&self) -> usize {
        self.pods * self.pod.npus()
    }
    pub fn racks(&self) -> usize {
        self.pods * self.pod.racks()
    }
    /// Single-tier HRS count: every rack exposes x256 uplink; each HRS is
    /// x512. 128 racks × 256 / 512 = 64 for the default 8K SuperPod.
    pub fn hrs_count(&self) -> usize {
        let uplink_per_rack = self.pod.rack.planes as u32 * 2 * self.pod.rack.ir_lrs_out_lanes;
        (self.racks() * uplink_per_rack as usize).div_ceil(512)
    }
}

/// Handles into a constructed SuperPod.
#[derive(Clone, Debug)]
pub struct SuperPodHandles {
    pub pods: Vec<PodHandles>,
    /// The pod-level HRS Clos tier.
    pub hrs: Vec<NodeId>,
    /// Uplink wiring map, racks in pod-major order: `rack_uplinks[r][k]`
    /// is rack `r`'s `k`-th uplink LRS (`k = plane*2 + slot`, slots 6/7)
    /// and its HRS neighbors in wiring order. Identical `(k, j)` indices
    /// resolve to the same HRS node for every rack (see
    /// [`wire_uplinks`]), which the HRS-routed collectives rely on.
    pub rack_uplinks: Vec<Vec<(NodeId, Vec<NodeId>)>>,
}

impl SuperPodHandles {
    /// All regular NPUs in rank order (pod-major, then rack-major).
    pub fn npus(&self) -> Vec<NodeId> {
        self.pods.iter().flat_map(|p| p.npus()).collect()
    }

    /// Uplink "planes" available for APR path selection: the number of
    /// uplink LRS per rack (backplane planes × 2 slots).
    pub fn uplink_planes(&self) -> usize {
        self.rack_uplinks.first().map_or(0, |r| r.len())
    }
}

/// Build the SuperPod: pods with intra-pod 4D-FullMesh, plus a single
/// HRS tier every rack uplinks into (x256 per rack).
pub fn ubmesh_superpod(cfg: &SuperPodConfig) -> (Topology, SuperPodHandles) {
    assert_eq!(
        cfg.pod.uplink_hrs, 0,
        "SuperPod wires its own HRS tier; set pod.uplink_hrs = 0"
    );
    let mut t = Topology::new("ubmesh-superpod");
    let mut pods = Vec::with_capacity(cfg.pods);
    for p in 0..cfg.pods {
        pods.push(build_pod(&mut t, &cfg.pod, p as u16));
    }
    let hrs: Vec<NodeId> = (0..cfg.hrs_count())
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    let all_racks: Vec<_> = pods.iter().flat_map(|p| p.racks.clone()).collect();
    let rack_uplinks = wire_uplinks(
        &mut t,
        &all_racks,
        &hrs,
        cfg.pod.rack.planes,
        cfg.uplink_oversub,
    );
    debug_assert!(t.check_lane_budgets().is_ok());
    (
        t,
        SuperPodHandles {
            pods,
            hrs,
            rack_uplinks,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::link::LinkRole;

    fn small() -> SuperPodConfig {
        // 2 pods × 2×2 racks to keep unit tests fast; full scale is
        // exercised by the census/benches.
        let mut cfg = SuperPodConfig::default();
        cfg.pods = 2;
        cfg.pod.rows = 2;
        cfg.pod.cols = 2;
        cfg
    }

    #[test]
    fn default_is_8k() {
        let cfg = SuperPodConfig::default();
        assert_eq!(cfg.npus(), 8192);
        assert_eq!(cfg.racks(), 128);
        assert_eq!(cfg.hrs_count(), 64);
    }

    #[test]
    fn small_superpod_connected() {
        let (t, h) = ubmesh_superpod(&small());
        assert_eq!(h.npus().len(), 2 * 4 * 64);
        assert!(t.npus_connected());
        t.check_lane_budgets().unwrap();
    }

    #[test]
    fn cross_pod_traffic_goes_through_hrs() {
        let (t, h) = ubmesh_superpod(&small());
        let a = h.pods[0].racks[0].npus[0];
        let b = h.pods[1].racks[0].npus[0];
        let p = t.shortest_path(a, b, true).unwrap();
        assert!(
            p.iter().any(|n| t.node(*n).kind == NodeKind::Hrs),
            "cross-pod path must traverse the HRS tier"
        );
    }

    #[test]
    fn uplink_map_is_rack_invariant_and_links_exist() {
        let (t, h) = ubmesh_superpod(&small());
        assert_eq!(h.uplink_planes(), 8); // 4 planes × 2 slots
        let first = &h.rack_uplinks[0];
        for rack in &h.rack_uplinks {
            assert_eq!(rack.len(), first.len());
            for (k, (lrs, targets)) in rack.iter().enumerate() {
                // Same (k, j) → same HRS node across racks.
                assert_eq!(targets, &first[k].1, "per-rack wiring must repeat");
                for &hn in targets {
                    assert!(
                        t.link_between(*lrs, hn).is_some(),
                        "map entry without a physical link"
                    );
                }
            }
        }
    }

    #[test]
    fn oversubscription_thins_uplinks_but_keeps_connectivity() {
        let base = small();
        let mut over = small();
        over.uplink_oversub = 4;
        let (t1, _) = ubmesh_superpod(&base);
        let (t4, h4) = ubmesh_superpod(&over);
        let lanes = |t: &Topology| -> u32 {
            t.links
                .iter()
                .filter(|l| l.role == LinkRole::PodUplink)
                .map(|l| l.lanes)
                .sum()
        };
        assert_eq!(lanes(&t1), 4 * lanes(&t4), "4:1 must quarter uplink lanes");
        assert!(t4.npus_connected());
        t4.check_lane_budgets().unwrap();
        // Cross-pod paths still traverse the HRS tier.
        let a = h4.pods[0].racks[0].npus[0];
        let b = h4.pods[1].racks[0].npus[0];
        let p = t4.shortest_path(a, b, true).unwrap();
        assert!(p.iter().any(|n| t4.node(*n).kind == NodeKind::Hrs));
    }

    #[test]
    fn uplink_lanes_per_rack_are_x256() {
        let (t, h) = ubmesh_superpod(&small());
        let rack0 = &h.pods[0].racks[0];
        let ups: u32 = t
            .links
            .iter()
            .filter(|l| l.role == LinkRole::PodUplink)
            .filter(|l| {
                let lrs: Vec<_> = (0..4).flat_map(|p| [rack0.ir_lrs[p][6], rack0.ir_lrs[p][7]]).collect();
                lrs.contains(&l.a) || lrs.contains(&l.b)
            })
            .map(|l| l.lanes)
            .sum();
        assert_eq!(ups, 256);
    }
}
