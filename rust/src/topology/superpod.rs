//! UB-Mesh-SuperPod: multiple pods joined by a symmetric HRS Clos tier
//! (§3.3.4). "We choose to adopt the symmetrical Clos topology in the
//! Pod-level interconnection ... use high-radix Pod-switches (HRS) to
//! connect each rack in the SuperPod, scaling up to 8K NPUs."

use super::graph::Topology;
use super::ids::NodeId;
use super::node::{Location, NodeKind};
use super::pod::{build_pod, wire_uplinks, PodConfig, PodHandles};

/// SuperPod parameters. Default: 8 pods × 1024 NPUs = 8K.
#[derive(Clone, Debug)]
pub struct SuperPodConfig {
    pub pods: usize,
    pub pod: PodConfig,
}

impl Default for SuperPodConfig {
    fn default() -> Self {
        SuperPodConfig {
            pods: 8,
            pod: PodConfig::default(),
        }
    }
}

impl SuperPodConfig {
    pub fn npus(&self) -> usize {
        self.pods * self.pod.npus()
    }
    pub fn racks(&self) -> usize {
        self.pods * self.pod.racks()
    }
    /// Single-tier HRS count: every rack exposes x256 uplink; each HRS is
    /// x512. 128 racks × 256 / 512 = 64 for the default 8K SuperPod.
    pub fn hrs_count(&self) -> usize {
        let uplink_per_rack = self.pod.rack.planes as u32 * 2 * self.pod.rack.ir_lrs_out_lanes;
        (self.racks() * uplink_per_rack as usize).div_ceil(512)
    }
}

/// Handles into a constructed SuperPod.
#[derive(Clone, Debug)]
pub struct SuperPodHandles {
    pub pods: Vec<PodHandles>,
    /// The pod-level HRS Clos tier.
    pub hrs: Vec<NodeId>,
}

impl SuperPodHandles {
    /// All regular NPUs in rank order (pod-major, then rack-major).
    pub fn npus(&self) -> Vec<NodeId> {
        self.pods.iter().flat_map(|p| p.npus()).collect()
    }
}

/// Build the SuperPod: pods with intra-pod 4D-FullMesh, plus a single
/// HRS tier every rack uplinks into (x256 per rack).
pub fn ubmesh_superpod(cfg: &SuperPodConfig) -> (Topology, SuperPodHandles) {
    assert_eq!(
        cfg.pod.uplink_hrs, 0,
        "SuperPod wires its own HRS tier; set pod.uplink_hrs = 0"
    );
    let mut t = Topology::new("ubmesh-superpod");
    let mut pods = Vec::with_capacity(cfg.pods);
    for p in 0..cfg.pods {
        pods.push(build_pod(&mut t, &cfg.pod, p as u16));
    }
    let hrs: Vec<NodeId> = (0..cfg.hrs_count())
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    let all_racks: Vec<_> = pods.iter().flat_map(|p| p.racks.clone()).collect();
    wire_uplinks(&mut t, &all_racks, &hrs, cfg.pod.rack.planes);
    debug_assert!(t.check_lane_budgets().is_ok());
    (t, SuperPodHandles { pods, hrs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::link::LinkRole;

    fn small() -> SuperPodConfig {
        // 2 pods × 2×2 racks to keep unit tests fast; full scale is
        // exercised by the census/benches.
        let mut cfg = SuperPodConfig::default();
        cfg.pods = 2;
        cfg.pod.rows = 2;
        cfg.pod.cols = 2;
        cfg
    }

    #[test]
    fn default_is_8k() {
        let cfg = SuperPodConfig::default();
        assert_eq!(cfg.npus(), 8192);
        assert_eq!(cfg.racks(), 128);
        assert_eq!(cfg.hrs_count(), 64);
    }

    #[test]
    fn small_superpod_connected() {
        let (t, h) = ubmesh_superpod(&small());
        assert_eq!(h.npus().len(), 2 * 4 * 64);
        assert!(t.npus_connected());
        t.check_lane_budgets().unwrap();
    }

    #[test]
    fn cross_pod_traffic_goes_through_hrs() {
        let (t, h) = ubmesh_superpod(&small());
        let a = h.pods[0].racks[0].npus[0];
        let b = h.pods[1].racks[0].npus[0];
        let p = t.shortest_path(a, b, true).unwrap();
        assert!(
            p.iter().any(|n| t.node(*n).kind == NodeKind::Hrs),
            "cross-pod path must traverse the HRS tier"
        );
    }

    #[test]
    fn uplink_lanes_per_rack_are_x256() {
        let (t, h) = ubmesh_superpod(&small());
        let rack0 = &h.pods[0].racks[0];
        let ups: u32 = t
            .links
            .iter()
            .filter(|l| l.role == LinkRole::PodUplink)
            .filter(|l| {
                let lrs: Vec<_> = (0..4).flat_map(|p| [rack0.ir_lrs[p][6], rack0.ir_lrs[p][7]]).collect();
                lrs.contains(&l.a) || lrs.contains(&l.b)
            })
            .map(|l| l.lanes)
            .sum();
        assert_eq!(ups, 256);
    }
}
