//! DCN attachment beyond the SuperPod (§3.3.4, Fig 7-c).
//!
//! "Racks in SuperPods are also connected to the large-scale DCN either
//! via UB switches (*Solution-(a)*) or via the NICs located on CPU
//! boards (*Solution-(b)*). The DCN domain usually supports large-scale
//! Data Parallelism training ... and can scale to 100K NPUs or more."
//!
//! Both solutions are modeled: (a) adds DCN switches hanging off each
//! rack's uplink LRS; (b) routes DCN traffic through the CPUs' NICs
//! (lower bandwidth, frees UB lanes). The DP tier of
//! [`crate::workload::placement::TierBandwidth`] reflects the choice.

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::node::{Location, NodeKind};
use super::rack::RackHandles;
use super::ublink::LANE_GB_S;

/// How the SuperPod reaches the DCN.
#[derive(Copy, Clone, PartialEq, Debug)]
pub enum DcnAttach {
    /// Solution-(a): UB switches — x8 UB lanes per rack to DCN switches.
    UbSwitch { lanes_per_rack: u32 },
    /// Solution-(b): NICs on CPU boards — `gb_s` per NIC, one per CPU.
    CpuNic { nic_gb_s: f64 },
}

impl DcnAttach {
    /// Per-NPU DCN bandwidth (GB/s) for the DP tier.
    pub fn per_npu_gb_s(&self, cpus_per_rack: usize) -> f64 {
        match self {
            DcnAttach::UbSwitch { lanes_per_rack } => {
                *lanes_per_rack as f64 * LANE_GB_S / 64.0
            }
            DcnAttach::CpuNic { nic_gb_s } => nic_gb_s * cpus_per_rack as f64 / 64.0,
        }
    }
}

/// Wire a rack to `dcn` switches per Solution-(a) (UB switch attach).
pub fn attach_dcn_ub(
    t: &mut Topology,
    rack: &RackHandles,
    dcn: &[NodeId],
    lanes_per_rack: u32,
) {
    assert!(!dcn.is_empty());
    // The DCN lanes come out of the uplink LRS (plane 0, slot 7).
    let lrs = rack.ir_lrs[0][7];
    let per = (lanes_per_rack / dcn.len() as u32).max(1);
    for &d in dcn {
        t.add_link(lrs, d, per, CableClass::Optical, LinkRole::Dcn, 2000.0);
    }
}

/// Wire a rack's CPUs to `dcn` switches per Solution-(b) (NIC attach).
pub fn attach_dcn_nic(t: &mut Topology, rack: &RackHandles, dcn: &[NodeId], nic_lanes: u32) {
    assert!(!dcn.is_empty());
    for (i, &cpu) in rack.cpus.iter().enumerate() {
        t.add_link(
            cpu,
            dcn[i % dcn.len()],
            nic_lanes,
            CableClass::Optical,
            LinkRole::Dcn,
            2000.0,
        );
    }
}

/// Add a DCN switch layer and attach every rack of a built pod/superpod.
pub fn add_dcn_layer(
    t: &mut Topology,
    racks: &[RackHandles],
    switches: usize,
    attach: DcnAttach,
) -> Vec<NodeId> {
    let dcn: Vec<NodeId> = (0..switches)
        .map(|_| t.add_node(NodeKind::DcnSwitch, Location::default()))
        .collect();
    for r in racks {
        match attach {
            DcnAttach::UbSwitch { lanes_per_rack } => {
                attach_dcn_ub(t, r, &dcn, lanes_per_rack)
            }
            DcnAttach::CpuNic { .. } => attach_dcn_nic(t, r, &dcn, 4),
        }
    }
    dcn
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::pod::{build_pod, PodConfig};

    fn pod_with_dcn(attach: DcnAttach) -> (Topology, Vec<NodeId>) {
        let mut t = Topology::new("pod+dcn");
        let mut cfg = PodConfig::default();
        cfg.rows = 2;
        cfg.cols = 2;
        let h = build_pod(&mut t, &cfg, 0);
        let dcn = add_dcn_layer(&mut t, &h.racks, 2, attach);
        (t, dcn)
    }

    #[test]
    fn ub_switch_attach_connects_all_racks() {
        let (t, dcn) = pod_with_dcn(DcnAttach::UbSwitch { lanes_per_rack: 8 });
        for &d in &dcn {
            assert!(!t.neighbors(d).is_empty());
        }
        // Any NPU can reach the DCN.
        let npu = t.npus[0];
        let path = t.shortest_path(npu, dcn[0], true).unwrap();
        assert!(path.len() <= 5);
        t.check_lane_budgets().unwrap();
    }

    #[test]
    fn nic_attach_goes_through_cpus() {
        let (t, dcn) = pod_with_dcn(DcnAttach::CpuNic { nic_gb_s: 12.5 });
        let path = t.shortest_path(t.npus[0], dcn[0], true).unwrap();
        // NPU → LRS → CPU → DCN (through the CPU pool).
        assert!(path
            .iter()
            .any(|&n| t.node(n).kind == crate::topology::NodeKind::Cpu));
    }

    #[test]
    fn per_npu_bandwidths_reflect_solution() {
        let a = DcnAttach::UbSwitch { lanes_per_rack: 8 };
        let b = DcnAttach::CpuNic { nic_gb_s: 12.5 };
        // (a): 8 × 6.25 / 64 ≈ 0.78 GB/s per NPU of pure DCN bandwidth;
        // (b): 4 NICs × 12.5 / 64 ≈ 0.78 — comparable by design, but (a)
        // consumes UB lanes while (b) rides the CPU boards.
        assert!(a.per_npu_gb_s(4) > 0.0);
        assert!(b.per_npu_gb_s(4) > 0.0);
    }
}
