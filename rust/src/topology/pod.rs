//! The 4D-FullMesh UB-Mesh-Pod (§3.3.3, Fig 7-a/c).
//!
//! 16 racks in a 4×4 grid. Racks in the same row form a 1D full-mesh in
//! the Z dimension (active electrical, ~10 m); racks in the same column
//! form a 1D full-mesh in the α dimension (optical, ~100 m). Each
//! rack-to-rack bundle is UB x128 (Fig 8-d): one x32 cable per backplane
//! plane. Per plane, inter-rack LRS 0–2 serve the row neighbors, 3–5 the
//! column neighbors, and 6–7 the pod-level HRS uplink (x256 aggregate per
//! rack, §3.3.4).

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::node::{Location, NodeKind};
use super::rack::{build_rack, RackConfig, RackHandles};

/// Pod construction parameters. `Default` reproduces the paper's pod.
#[derive(Clone, Debug)]
pub struct PodConfig {
    pub rows: usize,
    pub cols: usize,
    pub rack: RackConfig,
    /// Lanes per plane of a row (Z) rack-to-rack bundle (x32 × 4 = x128).
    pub row_lanes_per_plane: u32,
    /// Lanes per plane of a column (α) bundle.
    pub col_lanes_per_plane: u32,
    /// Pod-level HRS for cross-pod/Borrow traffic; 0 = no uplink layer
    /// (the SuperPod builder wires its own HRS tier instead).
    pub uplink_hrs: usize,
}

impl Default for PodConfig {
    fn default() -> Self {
        PodConfig {
            rows: 4,
            cols: 4,
            rack: RackConfig::default(),
            row_lanes_per_plane: 32,
            col_lanes_per_plane: 32,
            uplink_hrs: 0,
        }
    }
}

impl PodConfig {
    pub fn racks(&self) -> usize {
        self.rows * self.cols
    }
    pub fn npus(&self) -> usize {
        self.racks() * self.rack.npus()
    }
}

/// Handles into a constructed pod.
#[derive(Clone, Debug)]
pub struct PodHandles {
    /// Racks in row-major order.
    pub racks: Vec<RackHandles>,
    /// Pod-level HRS (empty unless `uplink_hrs > 0`).
    pub hrs: Vec<NodeId>,
    pub rows: usize,
    pub cols: usize,
}

impl PodHandles {
    pub fn rack(&self, row: usize, col: usize) -> &RackHandles {
        &self.racks[row * self.cols + col]
    }

    /// All regular NPUs in rank order (rack-major).
    pub fn npus(&self) -> Vec<NodeId> {
        self.racks.iter().flat_map(|r| r.npus.clone()).collect()
    }
}

/// Index of neighbor `b` among the sorted peers of `a` in a group of
/// `size` (used to pick which inter-rack LRS carries which bundle; the
/// workload-layer path builder mirrors the same slot arithmetic).
pub(crate) fn neighbor_slot(a: usize, b: usize) -> usize {
    debug_assert_ne!(a, b);
    if b < a {
        b
    } else {
        b - 1
    }
}

/// Build a pod into `t`. Exposed for the SuperPod builder.
pub fn build_pod(t: &mut Topology, cfg: &PodConfig, pod: u16) -> PodHandles {
    let mut racks = Vec::with_capacity(cfg.racks());
    for r in 0..cfg.rows {
        for c in 0..cfg.cols {
            racks.push(build_rack(t, &cfg.rack, pod, r as u8, c as u8));
        }
    }
    let planes = cfg.rack.planes;
    let rack_at = |r: usize, c: usize| -> &RackHandles { &racks[r * cfg.cols + c] };

    // Z dimension: row full-mesh (active electrical, ~10 m).
    for r in 0..cfg.rows {
        for c1 in 0..cfg.cols {
            for c2 in (c1 + 1)..cfg.cols {
                let s1 = neighbor_slot(c1, c2); // 0..cols-1 ≤ 2
                let s2 = neighbor_slot(c2, c1);
                for p in 0..planes {
                    let a = rack_at(r, c1).ir_lrs[p][s1];
                    let b = rack_at(r, c2).ir_lrs[p][s2];
                    t.add_link(
                        a,
                        b,
                        cfg.row_lanes_per_plane,
                        CableClass::ActiveElectrical,
                        LinkRole::RowZ,
                        10.0,
                    );
                }
            }
        }
    }

    // α dimension: column full-mesh (optical, ~100 m). LRS offset 3.
    for c in 0..cfg.cols {
        for r1 in 0..cfg.rows {
            for r2 in (r1 + 1)..cfg.rows {
                let s1 = 3 + neighbor_slot(r1, r2);
                let s2 = 3 + neighbor_slot(r2, r1);
                for p in 0..planes {
                    let a = rack_at(r1, c).ir_lrs[p][s1];
                    let b = rack_at(r2, c).ir_lrs[p][s2];
                    t.add_link(
                        a,
                        b,
                        cfg.col_lanes_per_plane,
                        CableClass::Optical,
                        LinkRole::ColAlpha,
                        100.0,
                    );
                }
            }
        }
    }

    // Optional pod-local HRS uplink tier (for Borrow routing / cross-pod).
    let mut hrs = Vec::new();
    if cfg.uplink_hrs > 0 {
        let loc = Location::new(pod, 0, 0, 0, 0);
        for _ in 0..cfg.uplink_hrs {
            hrs.push(t.add_node(NodeKind::Hrs, loc));
        }
        wire_uplinks(t, &racks, &hrs, planes, 1);
    }

    PodHandles {
        racks,
        hrs,
        rows: cfg.rows,
        cols: cfg.cols,
    }
}

/// Wire each rack's uplink LRS (slots 6,7 per plane, x32 each = x256 per
/// rack at 1:1) across `hrs` switches, round-robin so each uplink LRS
/// spreads evenly. `oversub` is the rack-uplink oversubscription ratio
/// N:1 — it divides each uplink LRS's out-facing lanes by N (fewer
/// HRS-side switch ports and/or thinner cables; the HRS tier itself is
/// left sized for 1:1, so oversubscription trades switch-port spend for
/// inter-pod bandwidth, the §3.3.4 cost knob). Total per rack =
/// planes × 2 × 32/N lanes.
///
/// Returns the wiring map — per rack, per uplink-LRS index
/// `k = plane*2 + slot` (slot ∈ {0, 1} for ir_lrs slots 6/7): the
/// uplink LRS node and its HRS neighbors in wiring order. The counter
/// resets per rack, so `map[r][k].1[j]` is the *same* HRS node for
/// every rack `r` — which is what lets the HRS-routed collectives pick
/// a (plane, switch) pair once and know both endpoint racks reach it.
pub fn wire_uplinks(
    t: &mut Topology,
    racks: &[RackHandles],
    hrs: &[NodeId],
    planes: usize,
    oversub: u32,
) -> Vec<Vec<(NodeId, Vec<NodeId>)>> {
    assert!(!hrs.is_empty());
    assert!(
        oversub >= 1 && oversub <= 32 && 32 % oversub == 0,
        "oversubscription ratio {oversub}:1 must divide the x32 uplink \
         LRS budget (1, 2, 4, 8, 16 or 32) — anything else silently \
         builds a different ratio than requested"
    );
    let mut map = Vec::with_capacity(racks.len());
    for rh in racks {
        // Collect the 2·planes uplink LRS of the rack.
        let ups: Vec<NodeId> = (0..planes)
            .flat_map(|p| [rh.ir_lrs[p][6], rh.ir_lrs[p][7]])
            .collect();
        // Each uplink LRS has x32/N outward; split it over a set of HRS.
        let effective = (32 / oversub).max(1);
        let per_lrs_targets = (hrs.len() / ups.len()).max(1).min(effective as usize);
        let lanes_per_link = (effective / per_lrs_targets as u32).max(1);
        let mut h = 0usize;
        let mut rack_map = Vec::with_capacity(ups.len());
        for &u in &ups {
            let mut targets = Vec::with_capacity(per_lrs_targets);
            for _ in 0..per_lrs_targets {
                let hn = hrs[h % hrs.len()];
                t.add_link(
                    u,
                    hn,
                    lanes_per_link,
                    CableClass::Optical,
                    LinkRole::PodUplink,
                    1000.0,
                );
                targets.push(hn);
                h += 1;
            }
            rack_map.push((u, targets));
        }
        map.push(rack_map);
    }
    map
}

/// A standalone UB-Mesh-Pod (1024 NPUs with default config).
pub fn ubmesh_pod(cfg: &PodConfig) -> (Topology, PodHandles) {
    let mut t = Topology::new("ubmesh-pod-4dfm");
    let h = build_pod(&mut t, cfg, 0);
    debug_assert!(t.check_lane_budgets().is_ok());
    (t, h)
}

/// Baseline: same racks but **no** direct rack-to-rack links; all
/// inter-rack lanes go to a non-blocking HRS tier (Fig 18-b).
pub fn pod_clos(rack_cfg: &RackConfig, racks_n: usize) -> (Topology, PodHandles) {
    let mut t = Topology::new("pod-clos");
    let mut racks = Vec::new();
    for i in 0..racks_n {
        racks.push(build_rack(
            &mut t,
            rack_cfg,
            0,
            (i / 4) as u8,
            (i % 4) as u8,
        ));
    }
    // All 8 IR-LRS per plane face the HRS tier: racks_n × planes × 8 × x32.
    let total_lanes = racks_n as u32 * rack_cfg.planes as u32 * 8 * rack_cfg.ir_lrs_out_lanes;
    let hrs_n = (total_lanes as usize).div_ceil(512);
    let hrs: Vec<NodeId> = (0..hrs_n)
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    for rh in &racks {
        let irs = rh.all_ir_lrs();
        // Spread each IR-LRS's x32 across the HRS tier.
        for (i, &lrs) in irs.iter().enumerate() {
            let targets = hrs_n.min(8);
            let lanes = rack_cfg.ir_lrs_out_lanes / targets as u32;
            for k in 0..targets {
                let h = (i * targets + k) % hrs_n;
                t.add_link(
                    lrs,
                    hrs[h],
                    lanes.max(1),
                    CableClass::Optical,
                    LinkRole::NpuSwitch,
                    100.0,
                );
            }
        }
    }
    let h = PodHandles {
        racks,
        hrs,
        rows: racks_n.div_ceil(4),
        cols: 4,
    };
    (t, h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pod_shape_matches_paper() {
        let cfg = PodConfig::default();
        let (t, h) = ubmesh_pod(&cfg);
        assert_eq!(h.npus().len(), 1024, "4D-FullMesh pod = 1024 NPUs");
        // Row (Z) bundles: 4 rows × C(4,2) pairs × 4 planes.
        let z = t.links.iter().filter(|l| l.role == LinkRole::RowZ).count();
        assert_eq!(z, 4 * 6 * 4);
        let a = t
            .links
            .iter()
            .filter(|l| l.role == LinkRole::ColAlpha)
            .count();
        assert_eq!(a, 4 * 6 * 4);
        t.check_lane_budgets().unwrap();
    }

    #[test]
    fn rack_to_rack_bundle_is_x128() {
        let cfg = PodConfig::default();
        let (t, _h) = ubmesh_pod(&cfg);
        // Sum lanes of one row-pair bundle: racks (0,0)-(0,1), 4 planes x32.
        let lanes: u32 = t
            .links
            .iter()
            .filter(|l| l.role == LinkRole::RowZ)
            .take(4)
            .map(|l| l.lanes)
            .sum();
        assert_eq!(lanes, 128);
    }

    #[test]
    fn cross_rack_npus_reachable_and_short() {
        let cfg = PodConfig::default();
        let (t, h) = ubmesh_pod(&cfg);
        assert!(t.npus_connected());
        // NPU in rack (0,0) to NPU in rack (0,3): npu -> board LRS ->
        // ir LRS -> peer ir LRS -> board LRS -> npu ≤ 6 hops.
        let a = h.rack(0, 0).npus[0];
        let b = h.rack(0, 3).npus[63];
        let p = t.shortest_path(a, b, true).unwrap();
        assert!(p.len() - 1 <= 6, "path too long: {} hops", p.len() - 1);
    }

    #[test]
    fn uplink_tier_optional() {
        let mut cfg = PodConfig::default();
        cfg.uplink_hrs = 8;
        let (t, h) = ubmesh_pod(&cfg);
        assert_eq!(h.hrs.len(), 8);
        t.check_lane_budgets().unwrap();
        let up = t
            .links
            .iter()
            .filter(|l| l.role == LinkRole::PodUplink)
            .count();
        assert!(up > 0);
    }

    #[test]
    fn pod_clos_fully_switched() {
        let (t, h) = pod_clos(&RackConfig::default(), 16);
        // 16 racks × 1024 lanes = 16384 → 32 HRS.
        assert_eq!(h.hrs.len(), 32);
        let z = t.links.iter().filter(|l| l.role == LinkRole::RowZ).count();
        assert_eq!(z, 0);
        t.check_lane_budgets().unwrap();
    }
}
