//! Node kinds and physical locations.

/// What a node *is* (Table 3 hardware modules + DCN).
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum NodeKind {
    /// Regular AI compute unit; UB x72 IO, UB controller can route (§3.3.1).
    Npu,
    /// The "+1" backup NPU of the 64+1 high-availability design (§3.3.2).
    BackupNpu,
    /// Host CPU, UB x32 IO; pooled behind LRS (§3.2.1).
    Cpu,
    /// Low-Radix Switch, UB x72 (Table 3).
    Lrs,
    /// High-Radix Switch, UB x512 (Table 3).
    Hrs,
    /// Data-center-network switch beyond the SuperPod (§3.3.4).
    DcnSwitch,
}

impl NodeKind {
    /// Total UB lane capacity per Table 3.
    pub fn ub_lanes(self) -> u32 {
        match self {
            NodeKind::Npu | NodeKind::BackupNpu => 72,
            NodeKind::Cpu => 32,
            NodeKind::Lrs => 72,
            NodeKind::Hrs => 512,
            NodeKind::DcnSwitch => 512,
        }
    }

    pub fn is_switch(self) -> bool {
        matches!(self, NodeKind::Lrs | NodeKind::Hrs | NodeKind::DcnSwitch)
    }

    pub fn is_npu(self) -> bool {
        matches!(self, NodeKind::Npu | NodeKind::BackupNpu)
    }
}

/// Physical coordinates in the UB-Mesh hierarchy. Drives structured
/// addressing (§4.1.2), cable-length classes (Table 2) and placement.
///
/// Dimension naming follows Fig 5: X = intra-board, Y = cross-board in
/// rack, Z = rack row within pod, α (alpha) = rack column within pod,
/// β/γ = pod level and beyond.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug, Default)]
pub struct Location {
    /// Pod index within the SuperPod.
    pub pod: u16,
    /// Rack row within the pod (Z dimension), 0..4 for UB-Mesh-Pod.
    pub rack_row: u8,
    /// Rack column within the pod (α dimension), 0..4 for UB-Mesh-Pod.
    pub rack_col: u8,
    /// Board within the rack (Y dimension), 0..8.
    pub board: u8,
    /// NPU slot on the board (X dimension), 0..8.
    pub slot: u8,
}

impl Location {
    pub fn new(pod: u16, rack_row: u8, rack_col: u8, board: u8, slot: u8) -> Self {
        Location {
            pod,
            rack_row,
            rack_col,
            board,
            slot,
        }
    }

    /// Rack index within the pod (row-major over the 4×4 grid).
    pub fn rack(&self, cols: u8) -> u16 {
        self.rack_row as u16 * cols as u16 + self.rack_col as u16
    }

    /// True if both locations are in the same rack of the same pod.
    pub fn same_rack(&self, o: &Location) -> bool {
        self.pod == o.pod && self.rack_row == o.rack_row && self.rack_col == o.rack_col
    }

    /// True if same rack and same board.
    pub fn same_board(&self, o: &Location) -> bool {
        self.same_rack(o) && self.board == o.board
    }
}

/// A node in the topology graph.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub loc: Location,
}

impl Node {
    pub fn new(kind: NodeKind, loc: Location) -> Self {
        Node { kind, loc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_capacities_match_table3() {
        assert_eq!(NodeKind::Npu.ub_lanes(), 72);
        assert_eq!(NodeKind::Cpu.ub_lanes(), 32);
        assert_eq!(NodeKind::Lrs.ub_lanes(), 72);
        assert_eq!(NodeKind::Hrs.ub_lanes(), 512);
    }

    #[test]
    fn location_relations() {
        let a = Location::new(0, 1, 2, 3, 4);
        let b = Location::new(0, 1, 2, 3, 5);
        let c = Location::new(0, 1, 2, 4, 4);
        let d = Location::new(1, 1, 2, 3, 4);
        assert!(a.same_board(&b));
        assert!(a.same_rack(&c) && !a.same_board(&c));
        assert!(!a.same_rack(&d));
        assert_eq!(a.rack(4), 6);
    }
}
