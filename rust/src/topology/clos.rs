//! Cluster-scale symmetric Clos baselines (Fig 1-a, Fig 3, §6.4).
//!
//! Two forms:
//!
//! * [`ClosDesign`] — analytic non-blocking fat-tree sizing (switch and
//!   cable counts per tier) valid at any scale. Feeds the CapEx (Fig 21)
//!   and reliability (Table 6) comparisons, where the paper also reasons
//!   about counts rather than wiring.
//! * [`clos_cluster`] — a concrete 2-tier graph for scales where the
//!   spine fan-out permits ≥1 lane per leaf-spine pair (≤ ~1K NPUs at
//!   x16). Used as a simulation baseline.

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::node::{Location, NodeKind};

/// Lanes bundled into one physical optical cable (e.g. a 400G-class
/// transceiver pair). Granularity for cable/module counting.
pub const OPTICAL_CABLE_LANES: u32 = 8;

/// Analytic non-blocking folded-Clos design.
#[derive(Clone, Debug)]
pub struct ClosDesign {
    pub npus: usize,
    pub lanes_per_npu: u32,
    pub radix: u32,
    pub tiers: u32,
    /// High-radix switches per tier (leaf, agg, core).
    pub switches_per_tier: Vec<usize>,
    /// Endpoint-to-leaf lanes (electrical, short reach).
    pub endpoint_lanes: u64,
    /// Inter-switch lanes (optical).
    pub fabric_lanes: u64,
}

impl ClosDesign {
    /// Size a non-blocking fabric for `npus` endpoints of
    /// `lanes_per_npu` each, with `radix`-lane switches.
    pub fn non_blocking(npus: usize, lanes_per_npu: u32, radix: u32) -> ClosDesign {
        let e = npus as u64 * lanes_per_npu as u64;
        let half = (radix / 2) as u64;
        let leaves = e.div_ceil(half) as usize;
        // 2-tier works when every leaf can give ≥1 lane to every spine.
        let spines2 = e.div_ceil(radix as u64) as usize;
        if spines2 <= half as usize {
            ClosDesign {
                npus,
                lanes_per_npu,
                radix,
                tiers: 2,
                switches_per_tier: vec![leaves, spines2],
                endpoint_lanes: e,
                fabric_lanes: e, // leaf→spine
            }
        } else {
            // 3-tier folded Clos: leaf 2E/R, agg 2E/R, core E/R.
            let agg = (2 * e).div_ceil(radix as u64) as usize;
            let core = e.div_ceil(radix as u64) as usize;
            ClosDesign {
                npus,
                lanes_per_npu,
                radix,
                tiers: 3,
                switches_per_tier: vec![leaves, agg, core],
                endpoint_lanes: e,
                fabric_lanes: 2 * e, // leaf→agg + agg→core
            }
        }
    }

    pub fn total_switches(&self) -> usize {
        self.switches_per_tier.iter().sum()
    }

    /// Optical cables (fabric links are long-reach optical).
    pub fn optical_cables(&self) -> u64 {
        self.fabric_lanes / OPTICAL_CABLE_LANES as u64
    }

    /// Optical transceiver modules = 2 per cable.
    pub fn optical_modules(&self) -> u64 {
        2 * self.optical_cables()
    }
}

/// Concrete 2-tier Clos graph. `lanes_per_npu` must divide into the leaf
/// layer so that each leaf-spine pair carries ≥ 1 lane.
pub fn clos_cluster(name: &str, npus: usize, lanes_per_npu: u32, radix: u32) -> (Topology, Vec<NodeId>) {
    let design = ClosDesign::non_blocking(npus, lanes_per_npu, radix);
    assert_eq!(
        design.tiers, 2,
        "clos_cluster builds 2-tier graphs only (requested scale needs {} tiers; \
         use ClosDesign for analytic counts)",
        design.tiers
    );
    let leaves_n = design.switches_per_tier[0];
    let spines_n = design.switches_per_tier[1];
    let mut t = Topology::new(name);
    let npu_ids: Vec<NodeId> = (0..npus)
        .map(|i| {
            t.add_node(
                NodeKind::Npu,
                Location::new(0, 0, 0, (i / 8) as u8, (i % 8) as u8),
            )
        })
        .collect();
    let leaves: Vec<NodeId> = (0..leaves_n)
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    let spines: Vec<NodeId> = (0..spines_n)
        .map(|_| t.add_node(NodeKind::Hrs, Location::default()))
        .collect();
    // Endpoints spread across leaves.
    let per_leaf = npus.div_ceil(leaves_n);
    for (i, &n) in npu_ids.iter().enumerate() {
        t.add_link(
            n,
            leaves[i / per_leaf],
            lanes_per_npu,
            CableClass::PassiveElectrical,
            LinkRole::NpuSwitch,
            2.0,
        );
    }
    // Leaf→spine: split each leaf's uplink evenly.
    let up_per_leaf = (radix / 2).max(1);
    let lanes_per_pair = (up_per_leaf / spines_n as u32).max(1);
    for &l in &leaves {
        for &s in &spines {
            t.add_link(l, s, lanes_per_pair, CableClass::Optical, LinkRole::Spine, 100.0);
        }
    }
    (t, npu_ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_clos_is_3_tier_and_large() {
        // 8K NPUs at x64 (the "x64T Clos" baseline of Fig 21).
        let d = ClosDesign::non_blocking(8192, 64, 512);
        assert_eq!(d.tiers, 3);
        // leaf 2E/R = 2048, agg 2048, core 1024.
        assert_eq!(d.switches_per_tier, vec![2048, 2048, 1024]);
        assert_eq!(d.total_switches(), 5120);
        assert_eq!(d.fabric_lanes, 2 * 8192 * 64);
        assert!(d.optical_modules() > 200_000);
    }

    #[test]
    fn small_scale_is_2_tier() {
        let d = ClosDesign::non_blocking(64, 64, 512);
        assert_eq!(d.tiers, 2);
        assert_eq!(d.switches_per_tier[0], 16);
    }

    #[test]
    fn concrete_2tier_graph_connects() {
        let (t, npus) = clos_cluster("clos-64", 64, 16, 512);
        assert!(t.npus_connected());
        let p = t.shortest_path(npus[0], npus[63], false).unwrap();
        assert!(p.len() <= 5); // npu-leaf-(spine)-leaf-npu
    }

    #[test]
    fn nonblocking_bisection() {
        // Leaf up-capacity equals down-capacity.
        let d = ClosDesign::non_blocking(1024, 16, 512);
        let down_per_leaf = d.endpoint_lanes as f64 / d.switches_per_tier[0] as f64;
        assert!(down_per_leaf <= (d.radix / 2) as f64 + 1e-9);
    }
}
