//! Links: UB cables between nodes, classified per Table 2.

use super::ids::NodeId;
use super::ublink;

/// Physical cable class (Table 2). Determines reach, cost, AFR and
/// per-hop latency.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum CableClass {
    /// ~1 m copper, XY dimensions (intra-rack). 86.7% of cables.
    PassiveElectrical,
    /// ~10 m copper with retimers, Z dimension (rack row). 7.2%.
    ActiveElectrical,
    /// 100–1000 m fiber with optical modules at both ends (α, β, γ).
    Optical,
    /// In-chassis backplane trace (NPU↔LRS within a rack).
    Backplane,
}

impl CableClass {
    /// Optical modules consumed by one cable of this class.
    pub fn optical_modules(self) -> u32 {
        match self {
            CableClass::Optical => 2,
            _ => 0,
        }
    }
}

/// What the link is *for* — the dimension of the nD-FullMesh it belongs
/// to, or the switch attachment it implements. Used by routing (dimension
/// ordering), census (Table 2 rows) and bandwidth accounting.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum LinkRole {
    /// X dimension: NPU↔NPU on the same board (1D-FullMesh).
    BoardX,
    /// Y dimension: NPU↔NPU across boards in a rack (2D-FullMesh).
    RackY,
    /// Z dimension: rack↔rack within a row (LRS↔LRS, active electrical).
    RowZ,
    /// α dimension: rack↔rack across rows (LRS↔LRS, optical).
    ColAlpha,
    /// NPU/CPU/backup ↔ LRS backplane attach.
    Backplane,
    /// LRS↔LRS within a rack's switch plane.
    LrsMesh,
    /// Rack (LRS) ↔ HRS pod-level Clos uplink (β/γ, optical).
    PodUplink,
    /// HRS↔HRS spine links (Clos baselines, multi-tier).
    Spine,
    /// NPU ↔ switch in Clos / 1D-FM-A/B baselines.
    NpuSwitch,
    /// Switch ↔ DCN.
    Dcn,
    /// Direct NPU↔NPU link of a generic nD mesh dimension `d` ≥ 2
    /// (used by the generic builder / torus / dragonfly).
    Dim(u8),
}

impl LinkRole {
    /// The nD-FullMesh dimension index used by dimension-ordered routing
    /// and TFC VL assignment. Switch attaches count as the highest
    /// "escape" dimension.
    pub fn dim(self) -> u8 {
        match self {
            LinkRole::BoardX => 0,
            LinkRole::RackY => 1,
            LinkRole::RowZ => 2,
            LinkRole::ColAlpha => 3,
            LinkRole::Dim(d) => d,
            LinkRole::Backplane | LinkRole::LrsMesh | LinkRole::NpuSwitch => 4,
            LinkRole::PodUplink | LinkRole::Spine | LinkRole::Dcn => 5,
        }
    }
}

/// An undirected physical cable carrying `lanes` UB lanes in each
/// direction (full duplex). Flow simulation treats each direction as an
/// independent channel of `lanes × LANE_GB_S` capacity.
#[derive(Clone, Debug)]
pub struct Link {
    pub a: NodeId,
    pub b: NodeId,
    pub lanes: u32,
    pub class: CableClass,
    pub role: LinkRole,
    /// Physical length in metres (Table 2 distance column).
    pub length_m: f64,
}

impl Link {
    /// Unidirectional capacity in GB/s.
    #[inline]
    pub fn capacity_gb_s(&self) -> f64 {
        ublink::lanes_gb_s(self.lanes)
    }

    /// One-way per-hop latency in µs.
    #[inline]
    pub fn latency_us(&self) -> f64 {
        ublink::hop_latency_us(self.class)
    }

    /// The endpoint that isn't `n` (panics if `n` is not an endpoint).
    #[inline]
    pub fn other(&self, n: NodeId) -> NodeId {
        if self.a == n {
            self.b
        } else {
            debug_assert_eq!(self.b, n, "node {n} not on link {self:?}");
            self.a
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optical_modules_only_on_optical() {
        assert_eq!(CableClass::Optical.optical_modules(), 2);
        assert_eq!(CableClass::PassiveElectrical.optical_modules(), 0);
        assert_eq!(CableClass::Backplane.optical_modules(), 0);
    }

    #[test]
    fn dims_are_ordered_x_to_escape() {
        assert!(LinkRole::BoardX.dim() < LinkRole::RackY.dim());
        assert!(LinkRole::RackY.dim() < LinkRole::RowZ.dim());
        assert!(LinkRole::RowZ.dim() < LinkRole::ColAlpha.dim());
        assert!(LinkRole::ColAlpha.dim() < LinkRole::Backplane.dim());
        assert!(LinkRole::Backplane.dim() < LinkRole::PodUplink.dim());
    }

    #[test]
    fn capacity_scales_with_lanes() {
        let l = Link {
            a: NodeId(0),
            b: NodeId(1),
            lanes: 16,
            class: CableClass::PassiveElectrical,
            role: LinkRole::BoardX,
            length_m: 1.0,
        };
        assert!((l.capacity_gb_s() - 16.0 * ublink::LANE_GB_S).abs() < 1e-9);
    }
}
