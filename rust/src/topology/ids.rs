//! Strongly-typed node/link identifiers.

/// Index of a node in [`super::Topology::nodes`].
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct NodeId(pub u32);

/// Index of a link in [`super::Topology::links`].
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct LinkId(pub u32);

impl NodeId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl LinkId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}", self.0)
    }
}

impl std::fmt::Display for LinkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "l{}", self.0)
    }
}

/// A directed view of a link: `(link, direction)`. Direction `false`
/// means a→b, `true` means b→a. Flow simulation and channel-dependency
/// analysis operate on directed channels.
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct Channel {
    pub link: LinkId,
    pub rev: bool,
}

impl Channel {
    pub fn forward(link: LinkId) -> Self {
        Channel { link, rev: false }
    }
    pub fn backward(link: LinkId) -> Self {
        Channel { link, rev: true }
    }
    /// Dense index: 2*link + rev. Used to index per-channel state arrays.
    #[inline]
    pub fn idx(self) -> usize {
        self.link.idx() * 2 + self.rev as usize
    }
}
