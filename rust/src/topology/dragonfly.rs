//! Dragonfly baseline (Kim et al., ISCA'08; §2.3 Fig 3).
//!
//! Groups of `a` switches, each with `p` endpoints and `h` global links;
//! switches within a group form a full-mesh; groups are connected by
//! global optical links. Included for the §2.3 comparison benches —
//! "DF is cheaper than Clos but still costly due to full NPU-switch
//! bandwidth requirements".

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::node::{Location, NodeKind};

/// Canonical balanced dragonfly: `a = 2p = 2h`, groups `g = a*h + 1`.
pub fn dragonfly(name: &str, p: usize, lanes: u32) -> (Topology, Vec<NodeId>) {
    let a = 2 * p;
    let h = p;
    let g = a * h + 1;
    let mut t = Topology::new(name);
    let mut routers = Vec::with_capacity(g * a);
    let mut npus = Vec::new();
    for gi in 0..g {
        for ai in 0..a {
            let r = t.add_node(NodeKind::Hrs, Location::new(gi as u16, 0, 0, ai as u8, 0));
            routers.push(r);
            for s in 0..p {
                let n = t.add_node(
                    NodeKind::Npu,
                    Location::new(gi as u16, 0, 0, ai as u8, s as u8),
                );
                t.add_link(n, r, lanes, CableClass::PassiveElectrical, LinkRole::NpuSwitch, 2.0);
                npus.push(n);
            }
        }
    }
    // Intra-group full mesh (electrical).
    for gi in 0..g {
        for i in 0..a {
            for j in (i + 1)..a {
                t.add_link(
                    routers[gi * a + i],
                    routers[gi * a + j],
                    lanes,
                    CableClass::ActiveElectrical,
                    LinkRole::Dim(0),
                    5.0,
                );
            }
        }
    }
    // Global links: router `ai` of group `gi` owns `h` consecutive global
    // ports; connect group pairs (gi < gj) through the canonical port
    // assignment: pair index k = gj-1 maps to (router, port) = (k / h, k % h).
    for gi in 0..g {
        for gj in (gi + 1)..g {
            let k_i = gj - 1; // peer index as seen from gi
            let k_j = gi; // peer index as seen from gj (gi < gj so no -1)
            let r_i = routers[gi * a + (k_i / h) % a];
            let r_j = routers[gj * a + (k_j / h) % a];
            t.add_link(r_i, r_j, lanes, CableClass::Optical, LinkRole::Dim(1), 200.0);
        }
    }
    (t, npus)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_df_shape() {
        let p = 2;
        let (t, npus) = dragonfly("df2", p, 4);
        let a = 2 * p;
        let g = a * p + 1; // 9 groups
        assert_eq!(npus.len(), g * a * p);
        assert!(t.npus_connected());
        // Every group pair has exactly one global link.
        let globals = t
            .links
            .iter()
            .filter(|l| l.role == LinkRole::Dim(1))
            .count();
        assert_eq!(globals, g * (g - 1) / 2);
    }

    #[test]
    fn df_diameter_small() {
        let (t, _) = dragonfly("df2", 2, 4);
        // NPU-router-(local)-global-(local)-router-NPU ≤ 7 hops.
        assert!(t.npu_diameter() <= 7);
    }
}
