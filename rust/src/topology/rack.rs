//! The UB-Mesh 2D-FullMesh rack (§3.3.1, §3.3.2, Fig 7-b, Fig 8).
//!
//! A rack holds 8 NPU boards × 8 NPUs. On each board the 8 NPUs form an
//! X-dimension 1D-FullMesh (passive electrical, x4 per neighbor); across
//! boards, same-slot NPUs form the Y-dimension full-mesh (passive
//! electrical, x4). The remaining x16 of each NPU's x72 budget attaches
//! to the backplane switch planes.
//!
//! The backplane comprises **4 planes × 18 LRS** ("the rack features
//! multiple back-plane switches ... 18 LRSes are fully-connected to form
//! one switch plane"): per plane, 8 LRS attach NPU boards, 8 LRS carry
//! inter-rack links, 1 LRS serves CPUs and 1 the backup NPU — matching
//! the paper's "two LRSes are used for CPUs and backup NPUs, eight for
//! regular NPUs and eight for inter-rack connection". Aggregate
//! inter-rack IO is 4 planes × 8 LRS × x32 = **four UB x256 IO** (Fig
//! 7-b), i.e. x16 per NPU (Fig 20 default).

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::node::{Location, NodeKind};
use super::ublink::{X_LANES_PER_NEIGHBOR, Y_LANES_PER_NEIGHBOR};

/// Rack construction parameters. `Default` reproduces the paper's rack.
#[derive(Clone, Debug)]
pub struct RackConfig {
    pub boards: usize,
    pub slots: usize,
    /// Lanes per X-dimension (intra-board) direct link.
    pub x_lanes: u32,
    /// Lanes per Y-dimension (cross-board) direct link.
    pub y_lanes: u32,
    /// Backplane switch planes.
    pub planes: usize,
    /// Lanes from each NPU to its board LRS, per plane.
    pub npu_plane_lanes: u32,
    /// Lanes between LRS pairs inside one plane's full-mesh.
    pub lrs_mesh_lanes: u32,
    /// Out-facing lanes per inter-rack LRS (consumed by pod wiring).
    pub ir_lrs_out_lanes: u32,
    /// Host CPUs in the rack.
    pub cpus: usize,
    /// Whether to include the 64+1 backup NPU.
    pub backup: bool,
}

impl Default for RackConfig {
    fn default() -> Self {
        RackConfig {
            boards: 8,
            slots: 8,
            x_lanes: X_LANES_PER_NEIGHBOR,
            y_lanes: Y_LANES_PER_NEIGHBOR,
            planes: 4,
            npu_plane_lanes: 4,
            lrs_mesh_lanes: 2,
            ir_lrs_out_lanes: 32,
            cpus: 4,
            backup: true,
        }
    }
}

impl RackConfig {
    pub fn npus(&self) -> usize {
        self.boards * self.slots
    }

    /// Aggregate inter-rack lanes the rack exposes (paper: 4 × x256).
    pub fn inter_rack_lanes(&self) -> u32 {
        (self.planes as u32) * 8 * self.ir_lrs_out_lanes
    }
}

/// Handles into a constructed rack, used by pod wiring and placement.
#[derive(Clone, Debug)]
pub struct RackHandles {
    /// NPUs in rank order (board-major: board*slots + slot).
    pub npus: Vec<NodeId>,
    /// The backup NPU, if configured.
    pub backup: Option<NodeId>,
    pub cpus: Vec<NodeId>,
    /// Per plane: the 8 board-attach LRS.
    pub npu_lrs: Vec<Vec<NodeId>>,
    /// Per plane: the 8 inter-rack LRS (out ports wired by the pod).
    pub ir_lrs: Vec<Vec<NodeId>>,
    /// Per plane: CPU LRS and backup LRS.
    pub cpu_lrs: Vec<NodeId>,
    pub bk_lrs: Vec<NodeId>,
}

impl RackHandles {
    /// NPU at (board, slot).
    pub fn npu(&self, board: usize, slot: usize, slots: usize) -> NodeId {
        self.npus[board * slots + slot]
    }

    /// All inter-rack LRS across planes, flattened.
    pub fn all_ir_lrs(&self) -> Vec<NodeId> {
        self.ir_lrs.iter().flatten().copied().collect()
    }
}

/// Build one UB-Mesh rack into `t` at pod/row/col coordinates.
pub fn build_rack(
    t: &mut Topology,
    cfg: &RackConfig,
    pod: u16,
    rack_row: u8,
    rack_col: u8,
) -> RackHandles {
    let at = |board: u8, slot: u8| Location::new(pod, rack_row, rack_col, board, slot);

    // --- NPUs -----------------------------------------------------------
    let mut npus = Vec::with_capacity(cfg.npus());
    for b in 0..cfg.boards {
        for s in 0..cfg.slots {
            npus.push(t.add_node(NodeKind::Npu, at(b as u8, s as u8)));
        }
    }

    // X full-mesh per board (Fig 8-a).
    for b in 0..cfg.boards {
        for s1 in 0..cfg.slots {
            for s2 in (s1 + 1)..cfg.slots {
                t.add_link(
                    npus[b * cfg.slots + s1],
                    npus[b * cfg.slots + s2],
                    cfg.x_lanes,
                    CableClass::PassiveElectrical,
                    LinkRole::BoardX,
                    0.3,
                );
            }
        }
    }
    // Y full-mesh per slot column across boards.
    for s in 0..cfg.slots {
        for b1 in 0..cfg.boards {
            for b2 in (b1 + 1)..cfg.boards {
                t.add_link(
                    npus[b1 * cfg.slots + s],
                    npus[b2 * cfg.slots + s],
                    cfg.y_lanes,
                    CableClass::PassiveElectrical,
                    LinkRole::RackY,
                    1.0,
                );
            }
        }
    }

    // --- Backplane LRS planes (Fig 7-b) ----------------------------------
    let mut npu_lrs = Vec::new();
    let mut ir_lrs = Vec::new();
    let mut cpu_lrs = Vec::new();
    let mut bk_lrs = Vec::new();
    for _p in 0..cfg.planes {
        let board_lrs: Vec<NodeId> = (0..cfg.boards)
            .map(|b| t.add_node(NodeKind::Lrs, at(b as u8, 0)))
            .collect();
        let inter_lrs: Vec<NodeId> = (0..8)
            .map(|_| t.add_node(NodeKind::Lrs, at(0, 0)))
            .collect();
        let c_lrs = t.add_node(NodeKind::Lrs, at(0, 0));
        let b_lrs = t.add_node(NodeKind::Lrs, at(0, 0));

        // Full LRS mesh within the plane ("18 LRSes are fully-connected").
        let plane: Vec<NodeId> = board_lrs
            .iter()
            .chain(inter_lrs.iter())
            .chain([&c_lrs, &b_lrs])
            .copied()
            .collect();
        for i in 0..plane.len() {
            for j in (i + 1)..plane.len() {
                t.add_link(
                    plane[i],
                    plane[j],
                    cfg.lrs_mesh_lanes,
                    CableClass::Backplane,
                    LinkRole::LrsMesh,
                    0.5,
                );
            }
        }

        // NPU board attach: board b's NPUs to board_lrs[b].
        for b in 0..cfg.boards {
            for s in 0..cfg.slots {
                t.add_link(
                    npus[b * cfg.slots + s],
                    board_lrs[b],
                    cfg.npu_plane_lanes,
                    CableClass::Backplane,
                    LinkRole::Backplane,
                    0.5,
                );
            }
        }

        npu_lrs.push(board_lrs);
        ir_lrs.push(inter_lrs);
        cpu_lrs.push(c_lrs);
        bk_lrs.push(b_lrs);
    }

    // --- CPUs (pooled behind LRS, §3.3.1) --------------------------------
    let mut cpus = Vec::new();
    let cpu_plane_lanes = (NodeKind::Cpu.ub_lanes() / cfg.planes as u32).max(1);
    for _ in 0..cfg.cpus {
        let c = t.add_node(NodeKind::Cpu, at(0, 0));
        for p in 0..cfg.planes {
            t.add_link(
                c,
                cpu_lrs[p],
                cpu_plane_lanes,
                CableClass::Backplane,
                LinkRole::Backplane,
                0.5,
            );
        }
        cpus.push(c);
    }

    // --- 64+1 backup NPU (§3.3.2, Fig 8-b) --------------------------------
    let backup = if cfg.backup {
        let b = t.add_node(NodeKind::BackupNpu, at(0, 0));
        for p in 0..cfg.planes {
            t.add_link(
                b,
                bk_lrs[p],
                16,
                CableClass::Backplane,
                LinkRole::Backplane,
                0.5,
            );
        }
        Some(b)
    } else {
        None
    };

    RackHandles {
        npus,
        backup,
        cpus,
        npu_lrs,
        ir_lrs,
        cpu_lrs,
        bk_lrs,
    }
}

/// A standalone single rack (used by intra-rack experiments, Fig 16-a).
pub fn ubmesh_rack(cfg: &RackConfig) -> (Topology, RackHandles) {
    let mut t = Topology::new("ubmesh-rack-2dfm");
    let h = build_rack(&mut t, cfg, 0, 0, 0);
    debug_assert!(t.check_lane_budgets().is_ok());
    (t, h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rack_shape_matches_paper() {
        let cfg = RackConfig::default();
        let (t, h) = ubmesh_rack(&cfg);
        assert_eq!(h.npus.len(), 64);
        assert!(h.backup.is_some());
        // 448 X+Y direct links (8 boards × C(8,2) + 8 slots × C(8,2)).
        let xy = t
            .links
            .iter()
            .filter(|l| matches!(l.role, LinkRole::BoardX | LinkRole::RackY))
            .count();
        assert_eq!(xy, 448);
        // 4 planes × 18 LRS.
        assert_eq!(t.nodes_of_kind(NodeKind::Lrs).len(), 72);
        // Aggregate inter-rack IO = 4 × x256 = x1024 = x16 per NPU.
        assert_eq!(cfg.inter_rack_lanes(), 1024);
    }

    #[test]
    fn lane_budgets_respected() {
        let (t, _) = ubmesh_rack(&RackConfig::default());
        t.check_lane_budgets().unwrap();
    }

    #[test]
    fn npu_lane_budget_fully_used() {
        let (t, h) = ubmesh_rack(&RackConfig::default());
        // Every regular NPU consumes exactly its x72: 7×4 X + 7×4 Y + 4×4 planes.
        for &n in &h.npus {
            assert_eq!(t.lanes_used(n), 72);
        }
    }

    #[test]
    fn same_board_pairs_are_1_hop() {
        let (t, h) = ubmesh_rack(&RackConfig::default());
        let a = h.npu(2, 1, 8);
        let b = h.npu(2, 6, 8);
        assert!(t.link_between(a, b).is_some());
    }

    #[test]
    fn cross_board_cross_slot_is_2_hops_direct() {
        let (t, h) = ubmesh_rack(&RackConfig::default());
        let a = h.npu(0, 1, 8);
        let b = h.npu(3, 5, 8);
        assert!(t.link_between(a, b).is_none());
        let p = t.shortest_path(a, b, true).unwrap();
        assert_eq!(p.len(), 3); // 2 hops
    }

    #[test]
    fn backup_reaches_all_npus_via_lrs_in_2_hops(){
        let (t, h) = ubmesh_rack(&RackConfig::default());
        let d = t.bfs_hops(h.backup.unwrap(), true);
        for &n in &h.npus {
            // backup -> bk_lrs -> (mesh) -> board lrs -> npu ≤ 3 hops
            assert!(d[n.idx()] <= 3, "backup too far from {n}");
        }
    }

    #[test]
    fn connected_including_cpus() {
        let (t, h) = ubmesh_rack(&RackConfig::default());
        assert!(t.npus_connected());
        let d = t.bfs_hops(h.cpus[0], true);
        assert!(h.npus.iter().all(|n| d[n.idx()] != u32::MAX));
    }
}
