//! Generic recursive nD-FullMesh builder (§3.1, Fig 4).
//!
//! `dims = [d0, d1, ..., dn-1]` produces `∏ di` NPUs at coordinates
//! `(c0, ..., cn-1)`. Two nodes are linked iff their coordinates differ
//! in exactly **one** position — i.e. each "row" of every dimension forms
//! a full-mesh, which is exactly the paper's recursive construction:
//! 1-D full-meshes between adjacent nodes, 2-D full-meshes between
//! adjacent 1-D meshes, and so on.

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::node::{Location, NodeKind};

/// Per-dimension link parameters.
#[derive(Clone, Debug)]
pub struct DimSpec {
    /// Group size of this dimension.
    pub size: usize,
    /// UB lanes per direct link in this dimension.
    pub lanes: u32,
    /// Cable class for links of this dimension.
    pub class: CableClass,
    /// Physical length (m).
    pub length_m: f64,
}

impl DimSpec {
    pub fn new(size: usize, lanes: u32, class: CableClass, length_m: f64) -> Self {
        DimSpec {
            size,
            lanes,
            class,
            length_m,
        }
    }
}

/// Decode flat index -> coordinate vector (row-major, dim 0 fastest).
pub fn coords_of(mut idx: usize, dims: &[usize]) -> Vec<usize> {
    let mut c = Vec::with_capacity(dims.len());
    for &d in dims {
        c.push(idx % d);
        idx /= d;
    }
    c
}

/// Encode coordinate vector -> flat index.
pub fn index_of(coords: &[usize], dims: &[usize]) -> usize {
    let mut idx = 0;
    let mut stride = 1;
    for (c, d) in coords.iter().zip(dims) {
        debug_assert!(c < d);
        idx += c * stride;
        stride *= d;
    }
    idx
}

/// Build an nD-FullMesh of NPUs. Node `i`'s coordinates are
/// `coords_of(i, sizes)`; the [`Location`] field packs the first four
/// dims as (slot, board, rack_row, rack_col) when present.
pub fn nd_fullmesh(name: &str, specs: &[DimSpec]) -> Topology {
    let sizes: Vec<usize> = specs.iter().map(|s| s.size).collect();
    let n: usize = sizes.iter().product();
    let mut t = Topology::new(name);
    for i in 0..n {
        let c = coords_of(i, &sizes);
        let loc = Location {
            slot: *c.first().unwrap_or(&0) as u8,
            board: *c.get(1).unwrap_or(&0) as u8,
            rack_row: *c.get(2).unwrap_or(&0) as u8,
            rack_col: *c.get(3).unwrap_or(&0) as u8,
            pod: *c.get(4).unwrap_or(&0) as u16,
        };
        t.add_node(NodeKind::Npu, loc);
    }
    // Full-mesh within each dimension row.
    for i in 0..n {
        let ci = coords_of(i, &sizes);
        for (d, spec) in specs.iter().enumerate() {
            // Partner j > i differing only in dimension d.
            for v in (ci[d] + 1)..spec.size {
                let mut cj = ci.clone();
                cj[d] = v;
                let j = index_of(&cj, &sizes);
                t.add_link(
                    NodeId(i as u32),
                    NodeId(j as u32),
                    spec.lanes,
                    spec.class,
                    LinkRole::Dim(d as u8),
                    spec.length_m,
                );
            }
        }
    }
    t
}

/// Number of links the nD-FullMesh construction should produce:
/// `N/di * C(di,2)` per dimension.
pub fn expected_links(sizes: &[usize]) -> usize {
    let n: usize = sizes.iter().product();
    sizes
        .iter()
        .map(|&d| (n / d) * (d * (d - 1) / 2))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(sizes: &[usize]) -> Vec<DimSpec> {
        sizes
            .iter()
            .map(|&s| DimSpec::new(s, 2, CableClass::PassiveElectrical, 1.0))
            .collect()
    }

    #[test]
    fn coords_roundtrip() {
        let dims = [3, 4, 5];
        for i in 0..60 {
            assert_eq!(index_of(&coords_of(i, &dims), &dims), i);
        }
    }

    #[test]
    fn d1_fullmesh_is_complete_graph() {
        let t = nd_fullmesh("k8", &spec(&[8]));
        assert_eq!(t.node_count(), 8);
        assert_eq!(t.link_count(), 28);
        assert_eq!(t.npu_diameter(), 1);
    }

    #[test]
    fn d2_fullmesh_diameter_2() {
        let t = nd_fullmesh("8x8", &spec(&[8, 8]));
        assert_eq!(t.node_count(), 64);
        assert_eq!(t.link_count(), expected_links(&[8, 8]));
        assert_eq!(t.link_count(), 2 * 8 * 28); // 448, §3.3.1
        assert_eq!(t.npu_diameter(), 2);
        assert!(t.npus_connected());
    }

    #[test]
    fn d4_fullmesh_diameter_4() {
        let t = nd_fullmesh("2x2x2x2", &spec(&[2, 2, 2, 2]));
        assert_eq!(t.node_count(), 16);
        assert_eq!(t.link_count(), expected_links(&[2, 2, 2, 2]));
        assert_eq!(t.npu_diameter(), 4);
    }

    #[test]
    fn per_node_degree_is_sum_of_dim_minus_1() {
        let t = nd_fullmesh("4x3", &spec(&[4, 3]));
        for &npu in &t.npus {
            assert_eq!(t.neighbors(npu).len(), (4 - 1) + (3 - 1));
        }
    }
}
