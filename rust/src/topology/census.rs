//! Cable / switch / optical-module census (Table 2, Fig 21 inputs,
//! Table 6 inputs).
//!
//! Walks a constructed [`Topology`] and tallies physical components by
//! class and role. The reliability model (AFR per component) and the
//! cost model (price per component) both consume a [`Census`], so every
//! headline ratio in the paper traces back to the same component counts.

use std::collections::BTreeMap;

use super::clos::OPTICAL_CABLE_LANES;
use super::graph::Topology;
use super::link::{CableClass, LinkRole};
use super::node::NodeKind;

/// Component tallies for one topology.
#[derive(Clone, Debug, Default)]
pub struct Census {
    /// Cables by class: (count, total lanes, total metres).
    pub cables: BTreeMap<CableClassKey, CableTally>,
    /// Nodes by kind.
    pub nodes: BTreeMap<NodeKindKey, usize>,
    /// Optical transceiver modules (2 per optical cable bundle).
    pub optical_modules: u64,
    /// Cables by nD dimension / role (Table 2 rows).
    pub by_role: BTreeMap<RoleKey, CableTally>,
}

/// BTreeMap-able wrappers (enums lack Ord derives upstream by design;
/// keys order deterministically for stable report output).
pub type CableClassKey = u8;
pub type NodeKindKey = u8;
pub type RoleKey = u8;

pub fn class_key(c: CableClass) -> CableClassKey {
    match c {
        CableClass::PassiveElectrical => 0,
        CableClass::ActiveElectrical => 1,
        CableClass::Optical => 2,
        CableClass::Backplane => 3,
    }
}

pub fn class_name(k: CableClassKey) -> &'static str {
    ["passive-electrical", "active-electrical", "optical", "backplane"][k as usize]
}

pub fn kind_key(k: NodeKind) -> NodeKindKey {
    match k {
        NodeKind::Npu => 0,
        NodeKind::BackupNpu => 1,
        NodeKind::Cpu => 2,
        NodeKind::Lrs => 3,
        NodeKind::Hrs => 4,
        NodeKind::DcnSwitch => 5,
    }
}

pub fn kind_name(k: NodeKindKey) -> &'static str {
    ["NPU", "BackupNPU", "CPU", "LRS", "HRS", "DCN"][k as usize]
}

fn role_key(r: LinkRole) -> RoleKey {
    match r {
        LinkRole::BoardX => 0,
        LinkRole::RackY => 1,
        LinkRole::RowZ => 2,
        LinkRole::ColAlpha => 3,
        LinkRole::PodUplink => 4,
        LinkRole::Backplane => 5,
        LinkRole::LrsMesh => 6,
        LinkRole::NpuSwitch => 7,
        LinkRole::Spine => 8,
        LinkRole::Dcn => 9,
        LinkRole::Dim(_) => 10,
    }
}

pub fn role_name(k: RoleKey) -> &'static str {
    [
        "X (board)",
        "Y (rack)",
        "Z (row)",
        "alpha (col)",
        "beta/gamma (uplink)",
        "backplane",
        "lrs-mesh",
        "npu-switch",
        "spine",
        "dcn",
        "dim",
    ][k as usize]
}

/// Per-bucket cable tally.
#[derive(Clone, Debug, Default)]
pub struct CableTally {
    pub cables: u64,
    pub lanes: u64,
    pub metres: f64,
}

impl Census {
    /// Tally a topology. Backplane traces are counted as cables too but
    /// excluded from [`Census::external_cables`] (they are PCB traces, not
    /// field-replaceable cables — Table 2 counts external cables only).
    pub fn of(t: &Topology) -> Census {
        let mut c = Census::default();
        for link in &t.links {
            let entry = c.cables.entry(class_key(link.class)).or_default();
            entry.cables += 1;
            entry.lanes += link.lanes as u64;
            entry.metres += link.length_m;
            let by_role = c.by_role.entry(role_key(link.role)).or_default();
            by_role.cables += 1;
            by_role.lanes += link.lanes as u64;
            by_role.metres += link.length_m;
            if link.class == CableClass::Optical {
                c.optical_modules +=
                    2 * (link.lanes as u64).div_ceil(OPTICAL_CABLE_LANES as u64);
            }
        }
        for node in &t.nodes {
            *c.nodes.entry(kind_key(node.kind)).or_default() += 1;
        }
        c
    }

    pub fn count(&self, kind: NodeKind) -> usize {
        self.nodes.get(&kind_key(kind)).copied().unwrap_or(0)
    }

    pub fn cables_of(&self, class: CableClass) -> u64 {
        self.cables
            .get(&class_key(class))
            .map(|t| t.cables)
            .unwrap_or(0)
    }

    pub fn lanes_of(&self, class: CableClass) -> u64 {
        self.cables
            .get(&class_key(class))
            .map(|t| t.lanes)
            .unwrap_or(0)
    }

    /// External (field) cables: everything but backplane traces.
    pub fn external_cables(&self) -> u64 {
        self.cables_of(CableClass::PassiveElectrical)
            + self.cables_of(CableClass::ActiveElectrical)
            + self.cables_of(CableClass::Optical)
    }

    /// Table 2: share of each external cable class by count.
    pub fn class_ratios(&self) -> Vec<(CableClassKey, f64)> {
        let total = self.external_cables() as f64;
        [
            CableClass::PassiveElectrical,
            CableClass::ActiveElectrical,
            CableClass::Optical,
        ]
        .iter()
        .map(|&c| (class_key(c), self.cables_of(c) as f64 / total))
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::pod::{ubmesh_pod, PodConfig};
    use crate::topology::rack::{ubmesh_rack, RackConfig};

    #[test]
    fn rack_census_counts() {
        let (t, _) = ubmesh_rack(&RackConfig::default());
        let c = Census::of(&t);
        assert_eq!(c.count(NodeKind::Npu), 64);
        assert_eq!(c.count(NodeKind::BackupNpu), 1);
        assert_eq!(c.count(NodeKind::Lrs), 72);
        // 448 passive X/Y cables.
        assert_eq!(c.cables_of(CableClass::PassiveElectrical), 448);
        assert_eq!(c.optical_modules, 0);
    }

    #[test]
    fn pod_census_passive_dominates() {
        let (t, _) = ubmesh_pod(&PodConfig::default());
        let c = Census::of(&t);
        let ratios = c.class_ratios();
        let passive = ratios[0].1;
        let active = ratios[1].1;
        let optical = ratios[2].1;
        // Table 2 shape: passive ≫ active ≥ optical.
        assert!(passive > 0.8, "passive share {passive}");
        assert!(active < 0.2 && optical < 0.1);
        assert!((passive + active + optical - 1.0).abs() < 1e-9);
    }

    #[test]
    fn handshake_lemma() {
        // Sum of node degrees = 2 × link count.
        let (t, _) = ubmesh_rack(&RackConfig::default());
        let degsum: usize = (0..t.node_count())
            .map(|i| t.neighbors(crate::topology::NodeId(i as u32)).len())
            .sum();
        assert_eq!(degsum, 2 * t.link_count());
    }

    #[test]
    fn optical_modules_follow_lanes() {
        let (t, _) = ubmesh_pod(&PodConfig::default());
        let c = Census::of(&t);
        // α links: 96 cables × x32 → each needs ceil(32/8)*2 = 8 modules.
        assert_eq!(c.optical_modules, 96 * 8);
    }
}
