//! The topology graph: nodes + undirected links + adjacency, with the
//! query operations every other layer builds on.

// The pair index below is the one sanctioned hash map in the crate
// (see clippy.toml): it is only ever probed, never iterated, so hash
// ordering cannot leak into results — and the O(1) probe is on the
// hot path of every adjacency query.
#[allow(clippy::disallowed_types)]
use std::collections::HashMap;

use super::ids::{LinkId, NodeId};
use super::link::{CableClass, Link, LinkRole};
use super::node::{Location, Node, NodeKind};

/// A cluster topology. Construct via the builders in [`super`] or
/// incrementally with [`Topology::add_node`] / [`Topology::add_link`].
#[allow(clippy::disallowed_types)]
#[derive(Clone, Debug, Default)]
pub struct Topology {
    pub name: String,
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// adjacency[n] = (neighbor, link) pairs.
    adj: Vec<Vec<(NodeId, LinkId)>>,
    /// Regular NPUs in rank order (excludes backups).
    pub npus: Vec<NodeId>,
    /// Backup NPUs (the "+1" of 64+1).
    pub backups: Vec<NodeId>,
    /// Pair → link index for O(1) "are these adjacent" queries.
    pair_index: HashMap<(NodeId, NodeId), LinkId>,
}

impl Topology {
    pub fn new(name: impl Into<String>) -> Self {
        Topology {
            name: name.into(),
            ..Default::default()
        }
    }

    pub fn add_node(&mut self, kind: NodeKind, loc: Location) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node::new(kind, loc));
        self.adj.push(Vec::new());
        match kind {
            NodeKind::Npu => self.npus.push(id),
            NodeKind::BackupNpu => self.backups.push(id),
            _ => {}
        }
        id
    }

    pub fn add_link(
        &mut self,
        a: NodeId,
        b: NodeId,
        lanes: u32,
        class: CableClass,
        role: LinkRole,
        length_m: f64,
    ) -> LinkId {
        let (id, prev) = self.push_link(a, b, lanes, class, role, length_m);
        assert!(prev.is_none(), "duplicate link {a}-{b}");
        id
    }

    /// Add a link that may parallel an existing `a`–`b` link (channel
    /// multiplicity: bonded cables, plane-redundant uplinks). The
    /// builders use [`Topology::add_link`], whose duplicate assert
    /// guards against accidental re-wiring; multi-link topologies opt in
    /// here. [`Topology::link_between`] keeps answering the first link
    /// of the pair — use [`Topology::links_between`] for the full set.
    pub fn add_parallel_link(
        &mut self,
        a: NodeId,
        b: NodeId,
        lanes: u32,
        class: CableClass,
        role: LinkRole,
        length_m: f64,
    ) -> LinkId {
        self.push_link(a, b, lanes, class, role, length_m).0
    }

    /// Shared wiring behind [`Topology::add_link`] /
    /// [`Topology::add_parallel_link`]: push the link, extend both
    /// adjacency lists, and record the pair's *first* link in the pair
    /// index. Returns the new id and the pair's previous first link.
    fn push_link(
        &mut self,
        a: NodeId,
        b: NodeId,
        lanes: u32,
        class: CableClass,
        role: LinkRole,
        length_m: f64,
    ) -> (LinkId, Option<LinkId>) {
        assert_ne!(a, b, "self-link");
        assert!(lanes > 0, "zero-lane link");
        assert!(
            length_m.is_finite() && length_m >= 0.0,
            "link {a}-{b} length {length_m} must be finite and ≥ 0"
        );
        let id = LinkId(self.links.len() as u32);
        self.links.push(Link {
            a,
            b,
            lanes,
            class,
            role,
            length_m,
        });
        self.adj[a.idx()].push((b, id));
        self.adj[b.idx()].push((a, id));
        let key = if a < b { (a, b) } else { (b, a) };
        let prev = match self.pair_index.entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => Some(*e.get()),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(id);
                None
            }
        };
        (id, prev)
    }

    #[inline]
    pub fn node(&self, n: NodeId) -> &Node {
        &self.nodes[n.idx()]
    }

    #[inline]
    pub fn link(&self, l: LinkId) -> &Link {
        &self.links[l.idx()]
    }

    #[inline]
    pub fn neighbors(&self, n: NodeId) -> &[(NodeId, LinkId)] {
        &self.adj[n.idx()]
    }

    /// The link between `a` and `b`, if directly connected. On a
    /// multi-link pair (see [`Topology::add_parallel_link`]) this is the
    /// first link wired; consumers that must see every parallel link
    /// (e.g. failure-notification sets) use [`Topology::links_between`].
    pub fn link_between(&self, a: NodeId, b: NodeId) -> Option<LinkId> {
        let key = if a < b { (a, b) } else { (b, a) };
        self.pair_index.get(&key).copied()
    }

    /// Every link between `a` and `b` — the hop's full link set,
    /// including parallel links.
    pub fn links_between(&self, a: NodeId, b: NodeId) -> Vec<LinkId> {
        self.neighbors(a)
            .iter()
            .filter(|&&(n, _)| n == b)
            .map(|&(_, l)| l)
            .collect()
    }

    /// True if the hop `a`–`b` exists and *some* link of the pair
    /// satisfies `usable` — the shared multi-link hop-liveness predicate
    /// behind APR path pruning and fault rerouting (one parallel alive
    /// keeps the hop alive).
    pub fn hop_usable(&self, a: NodeId, b: NodeId, usable: impl Fn(LinkId) -> bool) -> bool {
        self.neighbors(a)
            .iter()
            .any(|&(n, l)| n == b && usable(l))
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Nodes of a given kind.
    pub fn nodes_of_kind(&self, kind: NodeKind) -> Vec<NodeId> {
        (0..self.nodes.len() as u32)
            .map(NodeId)
            .filter(|n| self.node(*n).kind == kind)
            .collect()
    }

    /// Sum of UB lanes consumed at node `n` across its links. Used to
    /// assert IO budgets (e.g. NPU ≤ x72) during construction.
    pub fn lanes_used(&self, n: NodeId) -> u32 {
        self.neighbors(n)
            .iter()
            .map(|&(_, l)| self.link(l).lanes)
            .sum()
    }

    /// Assert that no node exceeds its Table 3 lane budget.
    /// Returns the worst offender for diagnostics.
    pub fn check_lane_budgets(&self) -> Result<(), String> {
        for (i, node) in self.nodes.iter().enumerate() {
            let n = NodeId(i as u32);
            let used = self.lanes_used(n);
            let cap = node.kind.ub_lanes();
            if used > cap {
                return Err(format!(
                    "{n} ({:?} at {:?}) uses {used} lanes > budget {cap}",
                    node.kind, node.loc
                ));
            }
        }
        Ok(())
    }

    /// BFS hop distance from `src` to every node (u32::MAX if unreachable).
    /// `npu_routable` controls whether NPUs may forward traffic (they can
    /// in UB-Mesh: the UB IO controller routes, §3.3.1).
    pub fn bfs_hops(&self, src: NodeId, npu_routable: bool) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.nodes.len()];
        let mut queue = std::collections::VecDeque::new();
        dist[src.idx()] = 0;
        queue.push_back(src);
        while let Some(u) = queue.pop_front() {
            let du = dist[u.idx()];
            // A node that may not forward still *receives*; it just can't
            // be an interior hop. We expand it only if routable or source.
            if u != src && !npu_routable && self.node(u).kind.is_npu() {
                continue;
            }
            for &(v, _) in self.neighbors(u) {
                if dist[v.idx()] == u32::MAX {
                    dist[v.idx()] = du + 1;
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// One shortest path (node sequence) from src to dst, BFS. NPUs are
    /// allowed as interior hops iff `npu_routable`.
    pub fn shortest_path(
        &self,
        src: NodeId,
        dst: NodeId,
        npu_routable: bool,
    ) -> Option<Vec<NodeId>> {
        self.shortest_path_filtered(src, dst, npu_routable, |_| true)
    }

    /// [`Topology::shortest_path`] restricted to links `accept` admits —
    /// the shared BFS behind live-link rerouting
    /// ([`crate::sim::fault::shortest_alive_path`] passes the up/down
    /// state as the predicate).
    pub fn shortest_path_filtered(
        &self,
        src: NodeId,
        dst: NodeId,
        npu_routable: bool,
        accept: impl Fn(LinkId) -> bool,
    ) -> Option<Vec<NodeId>> {
        if src == dst {
            return Some(vec![src]);
        }
        let mut prev = vec![NodeId(u32::MAX); self.nodes.len()];
        let mut seen = vec![false; self.nodes.len()];
        let mut queue = std::collections::VecDeque::new();
        seen[src.idx()] = true;
        queue.push_back(src);
        while let Some(u) = queue.pop_front() {
            if u != src && !npu_routable && self.node(u).kind.is_npu() {
                continue;
            }
            for &(v, l) in self.neighbors(u) {
                if !seen[v.idx()] && accept(l) {
                    seen[v.idx()] = true;
                    prev[v.idx()] = u;
                    if v == dst {
                        let mut path = vec![dst];
                        let mut cur = dst;
                        while cur != src {
                            cur = prev[cur.idx()];
                            path.push(cur);
                        }
                        path.reverse();
                        return Some(path);
                    }
                    queue.push_back(v);
                }
            }
        }
        None
    }

    /// Convert a node-sequence path to its link sequence.
    /// Panics if consecutive nodes are not adjacent.
    pub fn path_links(&self, path: &[NodeId]) -> Vec<LinkId> {
        path.windows(2)
            .map(|w| {
                self.link_between(w[0], w[1])
                    .unwrap_or_else(|| panic!("no link {}-{} in path", w[0], w[1]))
            })
            .collect()
    }

    /// Validate a node path: consecutive adjacency + no repeated node.
    pub fn validate_path(&self, path: &[NodeId]) -> Result<(), String> {
        if path.is_empty() {
            return Err("empty path".into());
        }
        let mut seen = std::collections::BTreeSet::new();
        for n in path {
            if !seen.insert(*n) {
                return Err(format!("node {n} repeated (loop)"));
            }
        }
        for w in path.windows(2) {
            if self.link_between(w[0], w[1]).is_none() {
                return Err(format!("{} and {} not adjacent", w[0], w[1]));
            }
        }
        Ok(())
    }

    /// Graph diameter restricted to NPU endpoints (hops, NPU-routable).
    pub fn npu_diameter(&self) -> u32 {
        let mut max = 0;
        for &src in &self.npus {
            let d = self.bfs_hops(src, true);
            for &dst in &self.npus {
                if d[dst.idx()] != u32::MAX {
                    max = max.max(d[dst.idx()]);
                }
            }
        }
        max
    }

    /// True if every NPU can reach every other NPU.
    pub fn npus_connected(&self) -> bool {
        if self.npus.is_empty() {
            return true;
        }
        let d = self.bfs_hops(self.npus[0], true);
        self.npus.iter().all(|n| d[n.idx()] != u32::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri() -> (Topology, NodeId, NodeId, NodeId) {
        let mut t = Topology::new("tri");
        let a = t.add_node(NodeKind::Npu, Location::default());
        let b = t.add_node(NodeKind::Npu, Location::default());
        let c = t.add_node(NodeKind::Npu, Location::default());
        t.add_link(a, b, 4, CableClass::PassiveElectrical, LinkRole::BoardX, 0.3);
        t.add_link(b, c, 4, CableClass::PassiveElectrical, LinkRole::BoardX, 0.3);
        (t, a, b, c)
    }

    #[test]
    fn adjacency_and_pair_index() {
        let (t, a, b, c) = tri();
        assert_eq!(t.neighbors(b).len(), 2);
        assert!(t.link_between(a, b).is_some());
        assert!(t.link_between(b, a).is_some());
        assert!(t.link_between(a, c).is_none());
    }

    #[test]
    fn bfs_and_shortest_path() {
        let (t, a, _b, c) = tri();
        let d = t.bfs_hops(a, true);
        assert_eq!(d[c.idx()], 2);
        let p = t.shortest_path(a, c, true).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(t.path_links(&p).len(), 2);
        t.validate_path(&p).unwrap();
    }

    #[test]
    fn npu_forwarding_can_be_disabled() {
        let (t, a, _b, c) = tri();
        // With NPU forwarding off, a cannot reach c through b.
        assert!(t.shortest_path(a, c, false).is_none());
    }

    #[test]
    fn lane_budget_enforced() {
        let mut t = Topology::new("over");
        let a = t.add_node(NodeKind::Cpu, Location::default()); // x32 budget
        let b = t.add_node(NodeKind::Hrs, Location::default());
        t.add_link(a, b, 40, CableClass::Backplane, LinkRole::Backplane, 0.1);
        assert!(t.check_lane_budgets().is_err());
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn nan_length_rejected_at_build() {
        let mut t = Topology::new("nan");
        let a = t.add_node(NodeKind::Npu, Location::default());
        let b = t.add_node(NodeKind::Npu, Location::default());
        t.add_link(a, b, 2, CableClass::PassiveElectrical, LinkRole::BoardX, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn negative_length_rejected_at_build() {
        let mut t = Topology::new("neg");
        let a = t.add_node(NodeKind::Npu, Location::default());
        let b = t.add_node(NodeKind::Npu, Location::default());
        t.add_link(a, b, 2, CableClass::PassiveElectrical, LinkRole::BoardX, -1.0);
    }

    #[test]
    #[should_panic(expected = "duplicate link")]
    fn duplicate_links_rejected() {
        let (mut t, a, b, _c) = tri();
        t.add_link(a, b, 1, CableClass::PassiveElectrical, LinkRole::BoardX, 0.3);
    }

    #[test]
    fn parallel_links_are_allowed_and_enumerable() {
        let (mut t, a, b, _c) = tri();
        let first = t.link_between(a, b).unwrap();
        let second =
            t.add_parallel_link(a, b, 2, CableClass::PassiveElectrical, LinkRole::BoardX, 0.3);
        assert_ne!(first, second);
        // link_between stays stable on the first link of the pair…
        assert_eq!(t.link_between(a, b), Some(first));
        // …while links_between exposes the full set, both directions.
        let all = t.links_between(a, b);
        assert_eq!(all, vec![first, second]);
        assert_eq!(t.links_between(b, a), vec![first, second]);
        // Adjacency carries both parallels.
        assert_eq!(t.neighbors(a).iter().filter(|&&(n, _)| n == b).count(), 2);
        // Hop liveness: one alive parallel keeps the hop alive; a hop
        // with no link at all is never usable.
        assert!(t.hop_usable(a, b, |l| l == second));
        assert!(!t.hop_usable(a, b, |_| false));
        assert!(!t.hop_usable(a, NodeId(2), |_| true), "a–c are not adjacent");
    }

    #[test]
    fn validate_path_rejects_loops() {
        let (t, a, b, _c) = tri();
        assert!(t.validate_path(&[a, b, a]).is_err());
    }
}
