//! 3D-Torus baseline (§2.3, Fig 3). Each node links to ±1 neighbors in
//! each dimension with wraparound — low cost, but low NPU-to-NPU
//! bandwidth and poor All-to-All support, which is exactly the contrast
//! the paper draws against the nD-FullMesh.

use super::graph::Topology;
use super::ids::NodeId;
use super::link::{CableClass, LinkRole};
use super::ndmesh::{coords_of, index_of};
use super::node::{Location, NodeKind};

/// Build a torus over `dims` (each ≥ 2) with `lanes` per link.
pub fn torus(name: &str, dims: &[usize], lanes: u32) -> (Topology, Vec<NodeId>) {
    assert!(dims.iter().all(|&d| d >= 2));
    let n: usize = dims.iter().product();
    let mut t = Topology::new(name);
    let ids: Vec<NodeId> = (0..n)
        .map(|i| {
            let c = coords_of(i, dims);
            t.add_node(
                NodeKind::Npu,
                Location {
                    slot: *c.first().unwrap_or(&0) as u8,
                    board: *c.get(1).unwrap_or(&0) as u8,
                    rack_row: *c.get(2).unwrap_or(&0) as u8,
                    rack_col: 0,
                    pod: 0,
                },
            )
        })
        .collect();
    for i in 0..n {
        let ci = coords_of(i, dims);
        for (d, &size) in dims.iter().enumerate() {
            // +1 neighbor with wraparound; dims of size 2 would create a
            // duplicate (0→1 and 1→0 wrap) so only add the wrap link once.
            let mut cj = ci.clone();
            cj[d] = (ci[d] + 1) % size;
            let j = index_of(&cj, dims);
            if i < j || (ci[d] + 1 == size && size > 2) {
                t.add_link(
                    ids[i],
                    ids[j],
                    lanes,
                    CableClass::ActiveElectrical,
                    LinkRole::Dim(d as u8),
                    5.0,
                );
            }
        }
    }
    (t, ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torus_4x4x4_shape() {
        let (t, ids) = torus("t444", &[4, 4, 4], 8);
        assert_eq!(ids.len(), 64);
        // 3 links per node (each link shared by 2): 64*3 = 192.
        assert_eq!(t.link_count(), 192);
        for &n in &ids {
            assert_eq!(t.neighbors(n).len(), 6);
        }
        assert!(t.npus_connected());
    }

    #[test]
    fn torus_diameter_is_sum_of_half_dims() {
        let (t, _) = torus("t44", &[4, 4], 8);
        assert_eq!(t.npu_diameter(), 4); // 2 + 2
    }

    #[test]
    fn dim2_has_no_duplicate_links() {
        let (t, ids) = torus("t22", &[2, 2], 8);
        assert_eq!(t.link_count(), 4);
        for &n in &ids {
            assert_eq!(t.neighbors(n).len(), 2);
        }
    }
}
