//! Typed wrappers over the AOT artifacts and the PJRT-backed batch cost
//! evaluator used by the parallelization search.
//!
//! Tier bandwidths arrive pre-reduced ([`TierBandwidth`] is the min
//! over each tier's physical hop chain — backplane mesh, uplink
//! oversubscription, HRS ports), so the PJRT kernel and the pure-rust
//! `iteration_time` price identical per-tier figures; nothing here
//! re-derives wiring.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::Result;

use crate::workload::models::ModelConfig;
use crate::workload::placement::{Placement, TierBandwidth, NTIERS};
use crate::workload::step::{CCU_OVERLAP, COMPUTE_EFFICIENCY, DP_OVERLAP, NPU_PEAK_TFLOPS};
use crate::workload::traffic::{analyze, ParallelismConfig};
use crate::topology::ublink::MESSAGE_ALPHA_US;

use super::client::{Engine, Exe};

/// Fixed artifact shapes — must match `python/compile/model.py`.
pub const APSP_SMALL: usize = 64;
pub const APSP_LARGE: usize = 256;
pub const COST_BATCH: usize = 256;
pub const COST_TIERS: usize = 6;
pub const LOAD_PATHS: usize = 1024;
pub const LOAD_LINKS: usize = 512;

/// INF sentinel shared with `python/compile/kernels/ref.py`.
pub const INF: f32 = 1.0e9;

/// All compiled entry points.
pub struct Artifacts {
    pub engine: Engine,
    apsp64: Exe,
    apsp256: Exe,
    costmodel: Exe,
    linkload: Exe,
}

impl Artifacts {
    /// Load from `dir` (usually `<repo>/artifacts`). Fails with a clear
    /// message when `make artifacts` hasn't been run.
    pub fn load(dir: &Path) -> Result<Artifacts> {
        if !dir.join("manifest.txt").exists() {
            bail!(
                "{} has no manifest.txt — run `make artifacts` first",
                dir.display()
            );
        }
        let engine = Engine::cpu()?;
        let load = |name: &str| -> Result<Exe> {
            engine.load_hlo_text(&dir.join(format!("{name}.hlo.txt")))
        };
        Ok(Artifacts {
            apsp64: load("apsp64")?,
            apsp256: load("apsp256")?,
            costmodel: load("costmodel")?,
            linkload: load("linkload")?,
            engine,
        })
    }

    /// Default artifact directory (crate root / artifacts).
    pub fn default_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// All-pairs shortest hops of an n-node adjacency (n ≤ 256; padded
    /// with INF to the artifact shape). `adj[i*n + j]` = hop cost, INF
    /// when unconnected; diagonal forced to 0 by the model.
    pub fn apsp(&self, adj: &[f32], n: usize) -> Result<Vec<f32>> {
        let (exe, m) = if n <= APSP_SMALL {
            (&self.apsp64, APSP_SMALL)
        } else if n <= APSP_LARGE {
            (&self.apsp256, APSP_LARGE)
        } else {
            bail!("apsp artifact supports ≤ {APSP_LARGE} nodes, got {n}");
        };
        assert_eq!(adj.len(), n * n);
        let mut padded = vec![INF; m * m];
        for i in 0..n {
            padded[i * m..i * m + n].copy_from_slice(&adj[i * n..(i + 1) * n]);
        }
        let out = exe.run_f32(&[(&padded, &[m, m])])?;
        // un-pad
        let mut result = vec![0.0f32; n * n];
        for i in 0..n {
            result[i * n..(i + 1) * n].copy_from_slice(&out[i * m..i * m + n]);
        }
        Ok(result)
    }

    /// Raw batched cost model: all arrays in the fixed [B, T] layout.
    pub fn cost_model_raw(&self, b: &CostBatch) -> Result<Vec<f32>> {
        self.costmodel.run_f32(&[
            (&b.volumes, &[COST_BATCH, COST_TIERS]),
            (&b.bandwidths, &[COST_BATCH, COST_TIERS]),
            (&b.transfers, &[COST_BATCH, COST_TIERS]),
            (&b.alphas, &[COST_TIERS]),
            (&b.compute_us, &[COST_BATCH]),
            (&b.exposure, &[COST_TIERS]),
        ])
    }

    /// Per-link loads from a weighted path×link incidence (padded).
    pub fn link_load(&self, incidence: &[f32], demand: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(incidence.len(), LOAD_PATHS * LOAD_LINKS);
        assert_eq!(demand.len(), LOAD_PATHS);
        self.linkload.run_f32(&[
            (incidence, &[LOAD_PATHS, LOAD_LINKS]),
            (demand, &[LOAD_PATHS]),
        ])
    }

    /// Evaluate a batch of parallelism configs on device — the PJRT
    /// incarnation of `workload::step::iteration_time` (§5.2 Step ②).
    /// Returns total iteration µs per config.
    pub fn evaluate_configs(
        &self,
        m: &ModelConfig,
        cfgs: &[ParallelismConfig],
        bw: &TierBandwidth,
    ) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(cfgs.len());
        for chunk in cfgs.chunks(COST_BATCH) {
            let batch = CostBatch::pack(m, chunk, bw);
            let times = self.cost_model_raw(&batch)?;
            out.extend(times[..chunk.len()].iter().map(|&t| t as f64));
        }
        Ok(out)
    }
}

/// Packed [B, T] arrays for one costmodel execution. Slot layout:
/// `[TP, SP, EP, PP, DP, bubble-as-compute-scale]` — the first five are
/// technique slots at their placement tier's bandwidth; the sixth is
/// unused (zero volume) and reserved.
pub struct CostBatch {
    pub volumes: Vec<f32>,
    pub bandwidths: Vec<f32>,
    pub transfers: Vec<f32>,
    pub alphas: Vec<f32>,
    pub compute_us: Vec<f32>,
    pub exposure: Vec<f32>,
}

impl CostBatch {
    /// Pack ≤ 256 configs; unused rows get benign values (bw = 1).
    pub fn pack(m: &ModelConfig, cfgs: &[ParallelismConfig], bw: &TierBandwidth) -> CostBatch {
        assert!(cfgs.len() <= COST_BATCH);
        let exposed = (1.0 - CCU_OVERLAP) as f32;
        let mut volumes = vec![0.0f32; COST_BATCH * COST_TIERS];
        let mut bandwidths = vec![1.0f32; COST_BATCH * COST_TIERS];
        let mut transfers = vec![0.0f32; COST_BATCH * COST_TIERS];
        let alphas = vec![MESSAGE_ALPHA_US as f32; COST_TIERS];
        let mut compute_us = vec![0.0f32; COST_BATCH];
        let exposure = vec![
            exposed,
            exposed,
            exposed,
            1.0,
            (1.0 - DP_OVERLAP) as f32,
            0.0,
        ];

        for (i, p) in cfgs.iter().enumerate() {
            let place = Placement::topology_aware(p);
            let traffic = analyze(m, p);
            let row = i * COST_TIERS;
            let mut put = |slot: usize, tech: &str, tier: usize, slice: f64| {
                if let Some(r) = traffic.row(tech) {
                    volumes[row + slot] = (r.total / slice) as f32;
                    transfers[row + slot] = (r.transfers / slice) as f32;
                    bandwidths[row + slot] = bw.gb_s[tier] as f32;
                }
            };
            let pp_slice = p.pp as f64;
            put(0, "TP", place.tp_tier as usize, pp_slice);
            put(1, "SP", place.sp_tier as usize, pp_slice);
            put(2, "EP", place.ep_tier as usize, pp_slice);
            put(3, "PP", place.pp_tier as usize, 1.0);
            put(4, "DP", place.dp_tier as usize, 1.0);

            let tokens = p.tokens_per_microbatch * p.microbatches as f64;
            let flops = m.flops_per_token() * tokens / (p.tp * p.sp * p.pp) as f64;
            let compute = flops / (NPU_PEAK_TFLOPS * 1e12 * COMPUTE_EFFICIENCY) * 1e6;
            // Fold the pipeline bubble into the compute term (same
            // formula as iteration_time's `busy × (pp-1)/mb`, applied to
            // compute only — the comm part of the bubble is second-order).
            let bubble = compute * (p.pp as f64 - 1.0) / p.microbatches as f64;
            compute_us[i] = (compute + bubble) as f32;
        }
        let _ = NTIERS;
        CostBatch {
            volumes,
            bandwidths,
            transfers,
            alphas,
            compute_us,
            exposure,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::by_name;
    use crate::workload::step::iteration_time;
    use crate::workload::traffic::table1_config;

    fn artifacts() -> Option<Artifacts> {
        let dir = Artifacts::default_dir();
        if dir.join("manifest.txt").exists() {
            Some(Artifacts::load(&dir).unwrap())
        } else {
            eprintln!("skipping: run `make artifacts`");
            None
        }
    }

    #[test]
    fn pjrt_cost_model_matches_rust_model() {
        let Some(a) = artifacts() else { return };
        let m = by_name("gpt4-2t").unwrap();
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let cfgs = vec![table1_config()];
        let pjrt = a.evaluate_configs(&m, &cfgs, &bw).unwrap();
        let rust = iteration_time(
            &m,
            &cfgs[0],
            &Placement::topology_aware(&cfgs[0]),
            &bw,
        );
        let rel = (pjrt[0] - rust.total_us).abs() / rust.total_us;
        // The PJRT path folds the bubble into compute-only, so allow a
        // few percent of divergence — ranking is what the search needs.
        assert!(
            rel < 0.05,
            "pjrt {} vs rust {} (rel {rel})",
            pjrt[0],
            rust.total_us
        );
    }

    #[test]
    fn pjrt_apsp_matches_graph_bfs() {
        let Some(a) = artifacts() else { return };
        use crate::topology::ndmesh::{nd_fullmesh, DimSpec};
        use crate::topology::CableClass;
        let t = nd_fullmesh(
            "m88",
            &[
                DimSpec::new(8, 4, CableClass::PassiveElectrical, 0.3),
                DimSpec::new(8, 4, CableClass::PassiveElectrical, 1.0),
            ],
        );
        let n = 64;
        let mut adj = vec![INF; n * n];
        for i in 0..n {
            adj[i * n + i] = 0.0;
        }
        for l in &t.links {
            adj[l.a.idx() * n + l.b.idx()] = 1.0;
            adj[l.b.idx() * n + l.a.idx()] = 1.0;
        }
        let d = a.apsp(&adj, n).unwrap();
        for src in [0usize, 17, 63] {
            let bfs = t.bfs_hops(crate::topology::NodeId(src as u32), true);
            for dst in 0..n {
                assert_eq!(
                    d[src * n + dst] as u32,
                    bfs[dst],
                    "apsp({src},{dst})"
                );
            }
        }
    }

    #[test]
    fn pjrt_linkload_uniform() {
        let Some(a) = artifacts() else { return };
        let inc = vec![1.0f32 / LOAD_PATHS as f32; LOAD_PATHS * LOAD_LINKS];
        let demand = vec![1.0f32; LOAD_PATHS];
        let loads = a.link_load(&inc, &demand).unwrap();
        assert_eq!(loads.len(), LOAD_LINKS);
        for &l in &loads {
            assert!((l - 1.0).abs() < 1e-3, "{l}");
        }
    }

    #[test]
    fn batch_packing_layout() {
        let m = by_name("gpt4-2t").unwrap();
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let b = CostBatch::pack(&m, &[table1_config()], &bw);
        assert_eq!(b.volumes.len(), COST_BATCH * COST_TIERS);
        // TP slot populated, reserved slot empty.
        assert!(b.volumes[0] > 0.0);
        assert_eq!(b.volumes[5], 0.0);
        assert!(b.compute_us[0] > 0.0);
        assert_eq!(b.compute_us[1], 0.0);
    }
}
