//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs: HLO **text** →
//! `HloModuleProto::from_text_file` → compile → execute. Text is the
//! interchange format because xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit instruction-id protos; the text parser reassigns ids.
//!
//! The real backend is gated behind the `pjrt` cargo feature because the
//! `xla` crate is unavailable in the default offline build. With the
//! feature off, a stub with the identical API reports the backend as
//! unavailable from [`Engine::cpu`]; every caller already degrades
//! gracefully (they fall back to the pure-rust cost model). With the
//! feature *on* but the crate still unvendored, [`super::xla_shim`]
//! supplies the same API surface so `cargo check --features pjrt` (the
//! CI gate) keeps this whole code path compiling; swap the `use` below
//! for the vendored crate to go live.

use std::path::Path;

use crate::util::error::Result;
#[cfg(feature = "pjrt")]
use crate::util::error::Context;
// Swap for the vendored `xla` crate (add it under [dependencies] and
// delete this line) when re-enabling the real backend.
#[cfg(feature = "pjrt")]
use super::xla_shim as xla;

/// A PJRT client plus compiled executables.
pub struct Engine {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

/// One compiled entry point.
pub struct Exe {
    #[cfg(feature = "pjrt")]
    inner: xla::PjRtLoadedExecutable,
    pub name: String,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Exe> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Exe {
            inner: exe,
            name: path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("exe")
                .to_string(),
        })
    }
}

#[cfg(feature = "pjrt")]
impl Exe {
    /// Execute with f32 inputs of the given shapes; returns the flattened
    /// f32 elements of the (single-output) tuple result.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshaping input to {dims:?}"))?;
            literals.push(lit);
        }
        let result = self
            .inner
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Stub: the default build carries no XLA; callers fall back to the
    /// pure-rust evaluators when this errors.
    pub fn cpu() -> Result<Engine> {
        Err(crate::anyhow!(
            "PJRT backend unavailable: built without the `pjrt` feature \
             (add the `xla` crate and build with --features pjrt)"
        ))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<Exe> {
        Err(crate::anyhow!("PJRT backend unavailable (stub build)"))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Exe {
    pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        Err(crate::anyhow!("PJRT backend unavailable (stub build)"))
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn cpu_client_comes_up() {
        let e = Engine::cpu().unwrap();
        assert!(e.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    fn loads_and_runs_apsp64_artifact() {
        let dir = artifacts_dir();
        if !dir.join("apsp64.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let e = Engine::cpu().unwrap();
        let exe = e.load_hlo_text(&dir.join("apsp64.hlo.txt")).unwrap();
        // Path graph 0-1-2 in a 64-node INF matrix.
        let inf = 1.0e9f32;
        let n = 64usize;
        let mut adj = vec![inf; n * n];
        for i in 0..n {
            adj[i * n + i] = 0.0;
        }
        adj[1] = 1.0; // (0,1)
        adj[n] = 1.0; // (1,0)
        adj[n + 2] = 1.0; // (1,2)
        adj[2 * n + 1] = 1.0; // (2,1)
        let out = exe.run_f32(&[(&adj, &[n, n])]).unwrap();
        assert_eq!(out.len(), n * n);
        assert_eq!(out[2], 2.0, "d(0,2) via node 1");
        assert_eq!(out[1], 1.0);
        assert!(out[3] > 1e8, "d(0,3) unreachable");
    }

}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_engine_reports_unavailable() {
        let e = Engine::cpu();
        assert!(e.unwrap_err().to_string().contains("pjrt"));
    }
}
