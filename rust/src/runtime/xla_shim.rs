//! Offline stand-in for the `xla` crate's API surface (the slice
//! `client.rs` uses), so `cargo check --features pjrt` keeps the real
//! PJRT code path *compiling* in the dependency-free build — the CI
//! gate that stops the feature from rotting while the crate itself
//! waits to be re-vendored (ROADMAP: "PJRT re-enable").
//!
//! Every runtime operation returns a clear error; nothing here executes.
//! Re-enabling the real backend is exactly two steps: add the vendored
//! `xla` crate under `[dependencies]`, and in `client.rs` replace
//! `use super::xla_shim as xla;` with the crate import. The signatures
//! below mirror xla_extension 0.5.x, so the swap is a no-op for the
//! call sites.

use crate::util::error::{Error, Result};

fn unavailable(what: &str) -> Error {
    crate::anyhow!(
        "PJRT shim: {what} requires the vendored `xla` crate \
         (built with --features pjrt but without the real backend)"
    )
}

/// Mirror of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "shim".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Mirror of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Mirror of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Mirror of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Mirror of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Mirror of `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}
