//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and execute them from the rust
//! hot path. Python is never on the request path — the HLO text is the
//! entire L1/L2 handoff.

pub mod artifacts;
pub mod client;
#[cfg(feature = "pjrt")]
pub mod xla_shim;

pub use artifacts::{Artifacts, CostBatch};
pub use client::Engine;
