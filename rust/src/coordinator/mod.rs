//! The L3 coordinator: glue from JobSpec to results.
//!
//! A [`job::Job`] picks an architecture, searches parallelism (§5.2),
//! places ranks, computes/simulates iteration time, and reports
//! throughput, MFU, and Clos-relative performance — the quantities Figs
//! 17/19/20/22 plot. [`metrics`] holds the linearity math (Eq. 2).

pub mod job;
pub mod metrics;

pub use job::{Arch, Job, JobReport, Routing};
pub use metrics::linearity;
