//! Cluster metrics: linearity (Eq. 2) and simple counters.

/// Eq. 2: `per-NPU perf at target scale / per-NPU perf at base scale`.
/// `perf` entries are (scale, cluster_throughput).
pub fn linearity(base: (usize, f64), target: (usize, f64)) -> f64 {
    let per_npu_base = base.1 / base.0 as f64;
    let per_npu_target = target.1 / target.0 as f64;
    per_npu_target / per_npu_base
}

/// Running statistics for coordinator-side telemetry.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn record(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_scaling_is_100pct() {
        assert!((linearity((128, 128.0), (256, 256.0)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn super_linear_possible() {
        // Fig 22: >100% when scale unlocks better parallelism.
        assert!(linearity((128, 128.0), (256, 260.0)) > 1.0);
    }

    #[test]
    fn stats_track_extremes() {
        let mut s = Stats::default();
        for v in [3.0, 1.0, 2.0] {
            s.record(v);
        }
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }
}
