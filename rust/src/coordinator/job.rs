//! Job orchestration: one LLM-training job on one architecture.

use crate::util::error::Result;

use crate::parallelism::search::{search_with, SearchOutcome};
use crate::parallelism::space::SearchSpace;
use crate::runtime::Artifacts;
use crate::workload::models::{self, ModelConfig};
use crate::workload::placement::TierBandwidth;
use crate::workload::step::throughput_tokens_per_s;
use crate::workload::traffic::ParallelismConfig;

/// Inter-rack routing strategy (§6.3, Fig 18/19).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Routing {
    /// Shortest paths on the 2D rack mesh only.
    Shortest,
    /// + APR non-shortest detour paths.
    Detour,
    /// + bandwidth borrowed from the HRS uplinks.
    Borrow,
}

impl Routing {
    /// Effective Z/α bandwidth multiplier, derived from the APR path
    /// census on the 4×4 rack grid: Shortest uses the direct x128
    /// bundle; Detour adds the 2 corner relays through the other rack
    /// of each row/col pair (sharing their bundles, ~+60% usable);
    /// Borrow adds the x256 uplink share (+25% of provision).
    pub fn boost(self) -> f64 {
        match self {
            Routing::Shortest => 1.0,
            Routing::Detour => 1.6,
            Routing::Borrow => 1.85,
        }
    }
}

/// Architectures under evaluation (Figs 16–21).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Arch {
    /// UB-Mesh 4D-FM with given inter-rack lanes/NPU, routing,
    /// backplane-mesh width (lanes per LRS pair) and uplink
    /// oversubscription — every knob the hop-chain tier model prices.
    UbMesh {
        inter_rack_lanes: u32,
        routing: Routing,
        mesh_lanes: u32,
        uplink_oversub: u32,
    },
    /// Intra-rack Clos (Fig 16-d) + 2D-FM inter-rack.
    ClosIntraRack,
    /// 1D-FM-A (Fig 16-b).
    Fm1dA,
    /// 1D-FM-B (Fig 16-c).
    Fm1dB,
    /// Fully symmetric Clos at x64 per NPU (cost baseline).
    FullClos,
}

impl Arch {
    pub fn name(&self) -> String {
        match self {
            Arch::UbMesh {
                inter_rack_lanes,
                routing,
                mesh_lanes,
                uplink_oversub,
            } => {
                let mut n = format!("2D-FM x{inter_rack_lanes} {routing:?}");
                if *mesh_lanes != 2 {
                    n.push_str(&format!(" mesh{mesh_lanes}"));
                }
                if *uplink_oversub != 1 {
                    n.push_str(&format!(" {uplink_oversub}:1"));
                }
                n
            }
            Arch::ClosIntraRack => "Clos(intra-rack)".into(),
            Arch::Fm1dA => "1D-FM-A".into(),
            Arch::Fm1dB => "1D-FM-B".into(),
            Arch::FullClos => "Clos(full x64)".into(),
        }
    }

    pub fn bandwidth(&self) -> TierBandwidth {
        match self {
            Arch::UbMesh {
                inter_rack_lanes,
                routing,
                mesh_lanes,
                uplink_oversub,
            } => TierBandwidth::ubmesh_mesh(
                *inter_rack_lanes,
                routing.boost(),
                *mesh_lanes,
                *uplink_oversub,
            ),
            Arch::ClosIntraRack => TierBandwidth::clos_intra_rack(16),
            Arch::Fm1dA => TierBandwidth::fm1d_a(),
            Arch::Fm1dB => TierBandwidth::fm1d_b(),
            Arch::FullClos => TierBandwidth::clos(64),
        }
    }

    /// The paper's default UB-Mesh configuration: x16 inter-rack,
    /// Detour routing, x2 backplane mesh, 1:1 uplinks.
    pub fn ubmesh_default() -> Arch {
        Arch::UbMesh {
            inter_rack_lanes: 16,
            routing: Routing::Detour,
            mesh_lanes: 2,
            uplink_oversub: 1,
        }
    }
}

/// One training job.
#[derive(Clone, Debug)]
pub struct Job {
    pub model: ModelConfig,
    pub scale: usize,
    pub seq_len: f64,
    pub arch: Arch,
}

/// Outcome of planning/simulating a job.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub arch: String,
    pub best: ParallelismConfig,
    pub iter_us: f64,
    pub mfu: f64,
    pub tokens_per_s: f64,
    pub comm_share: f64,
    pub evaluated: usize,
}

impl Job {
    pub fn new(model: &str, scale: usize, seq_len: f64, arch: Arch) -> Result<Job> {
        let model = models::by_name(model)
            .ok_or_else(|| crate::anyhow!("unknown model {model} (see Table 5)"))?;
        Ok(Job {
            model,
            scale,
            seq_len,
            arch,
        })
    }

    /// Plan the job: enumerate configs, evaluate (PJRT batch evaluator
    /// when `artifacts` is provided, pure-rust otherwise), pick the best.
    pub fn plan(&self, artifacts: Option<&Artifacts>) -> Result<JobReport> {
        let bw = self.arch.bandwidth();
        let space = SearchSpace::paper_default(self.scale, self.seq_len);
        let outcome: SearchOutcome = match artifacts {
            Some(a) => {
                let eval = |cfgs: &[ParallelismConfig]| -> Vec<f64> {
                    a.evaluate_configs(&self.model, cfgs, &bw)
                        .expect("PJRT cost-model execution failed")
                };
                search_with(&self.model, &space, &bw, &eval)
            }
            None => crate::parallelism::search::search(&self.model, &space, &bw),
        };
        let it = &outcome.best_iter;
        Ok(JobReport {
            arch: self.arch.name(),
            best: outcome.best,
            iter_us: it.total_us,
            mfu: it.mfu,
            tokens_per_s: throughput_tokens_per_s(&outcome.best, it),
            comm_share: it.comm_us() / it.total_us,
            evaluated: outcome.ranked.len(),
        })
    }

    /// Performance relative to another architecture on the same job
    /// (e.g. Fig 17's "relative to Clos"): ratio of tokens/s.
    pub fn relative_perf(&self, baseline: Arch, artifacts: Option<&Artifacts>) -> Result<f64> {
        let mine = self.plan(artifacts)?;
        let base = Job {
            arch: baseline,
            ..self.clone()
        }
        .plan(artifacts)?;
        Ok(mine.tokens_per_s / base.tokens_per_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_llama_on_ubmesh() {
        let job = Job::new("llama-70b", 128, 8192.0, Arch::ubmesh_default()).unwrap();
        let r = job.plan(None).unwrap();
        assert!(r.iter_us > 0.0);
        assert!(r.mfu > 0.1, "mfu {}", r.mfu);
        assert!(r.evaluated > 3);
        assert_eq!(r.best.npus(), 128);
    }

    #[test]
    fn ubmesh_within_7pct_of_clos_intra_rack() {
        // Fig 17 headline at job granularity.
        let job = Job::new("gpt3-175b", 1024, 32768.0, Arch::ubmesh_default()).unwrap();
        let rel = job.relative_perf(Arch::ClosIntraRack, None).unwrap();
        assert!(
            (0.90..=1.001).contains(&rel),
            "2D-FM at {rel:.3} of intra-rack Clos (paper ≥ 0.932)"
        );
    }

    #[test]
    fn detour_beats_shortest() {
        let mk = |routing| {
            Job::new(
                "gpt4-2t",
                1024,
                32768.0,
                Arch::UbMesh {
                    inter_rack_lanes: 16,
                    routing,
                    mesh_lanes: 2,
                    uplink_oversub: 1,
                },
            )
            .unwrap()
            .plan(None)
            .unwrap()
            .tokens_per_s
        };
        assert!(mk(Routing::Detour) >= mk(Routing::Shortest));
        assert!(mk(Routing::Borrow) >= mk(Routing::Detour));
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(Job::new("gpt5-100t", 64, 8192.0, Arch::FullClos).is_err());
    }
}
