//! `ubmesh` — coordinator CLI for the UB-Mesh reproduction.
//!
//! ```text
//! ubmesh run --model llama-70b --scale 128 --seq 8192 [--arch ubmesh|clos|1dfm-a|1dfm-b] [--no-pjrt]
//! ubmesh census [--pods N]            cable/component census (Table 2)
//! ubmesh capex                        CapEx comparison (Fig 21)
//! ubmesh reliability                  AFR/MTBF/availability (Table 6)
//! ubmesh traffic                      Table 1 traffic analysis
//! ubmesh routing --src 0 --dst 27     APR path exploration on a rack
//! ubmesh sweep --model gpt4-2t        seq-length sweep on all archs
//! ```

use ubmesh::util::error::Result;
use ubmesh::coordinator::{Arch, Job, Routing};
use ubmesh::runtime::Artifacts;
use ubmesh::util::cli::Args;
use ubmesh::util::table::{fmt, pct, ratio, Table};

fn arch_of(name: &str) -> Arch {
    match name {
        "ubmesh" => Arch::ubmesh_default(),
        "ubmesh-shortest" => Arch::UbMesh {
            inter_rack_lanes: 16,
            routing: Routing::Shortest,
            mesh_lanes: 2,
            uplink_oversub: 1,
        },
        "ubmesh-borrow" => Arch::UbMesh {
            inter_rack_lanes: 16,
            routing: Routing::Borrow,
            mesh_lanes: 2,
            uplink_oversub: 1,
        },
        "clos" => Arch::ClosIntraRack,
        "clos-full" => Arch::FullClos,
        "1dfm-a" => Arch::Fm1dA,
        "1dfm-b" => Arch::Fm1dB,
        other => panic!("unknown --arch {other}"),
    }
}

fn load_artifacts(args: &Args) -> Option<Artifacts> {
    if args.flag("no-pjrt") {
        return None;
    }
    match Artifacts::load(&Artifacts::default_dir()) {
        Ok(a) => {
            eprintln!(
                "[runtime] PJRT {} ready; AOT artifacts loaded",
                a.engine.platform()
            );
            Some(a)
        }
        Err(e) => {
            eprintln!("[runtime] PJRT evaluator unavailable ({e:#}); using rust cost model");
            None
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let model = args.get_or("model", "llama-70b").to_string();
    let scale: usize = args.get_parse("scale", 128);
    let seq: f64 = args.get_parse("seq", 8192.0);
    let arch = arch_of(args.get_or("arch", "ubmesh"));
    let artifacts = load_artifacts(args);

    let job = Job::new(&model, scale, seq, arch)?;
    let r = job.plan(artifacts.as_ref())?;
    let mut t = Table::with_title(
        format!("{model} @ {scale} NPUs, seq {seq}"),
        vec!["arch", "best parallelism", "iter(ms)", "MFU", "tokens/s", "comm%"],
    );
    t.row(vec![
        r.arch.clone(),
        format!(
            "tp{} sp{} ep{} pp{} dp{} mb{}",
            r.best.tp, r.best.sp, r.best.ep, r.best.pp, r.best.dp, r.best.microbatches
        ),
        fmt(r.iter_us / 1e3, 1),
        pct(r.mfu, 1),
        fmt(r.tokens_per_s, 0),
        pct(r.comm_share, 1),
    ]);
    t.print();
    let rel = job.relative_perf(Arch::ClosIntraRack, artifacts.as_ref())?;
    println!("relative to intra-rack Clos baseline: {}", pct(rel, 1));
    Ok(())
}

fn cmd_census(args: &Args) -> Result<()> {
    use ubmesh::topology::census::{class_name, role_name, Census};
    use ubmesh::topology::superpod::{ubmesh_superpod, SuperPodConfig};
    let mut cfg = SuperPodConfig::default();
    cfg.pods = args.get_parse("pods", 8);
    let (t, _) = ubmesh_superpod(&cfg);
    let c = Census::of(&t);
    println!(
        "SuperPod: {} NPUs, {} nodes, {} links",
        cfg.npus(),
        t.node_count(),
        t.link_count()
    );
    let mut tbl = Table::with_title("cable census (Table 2)", vec!["class", "cables", "share"]);
    for (k, share) in c.class_ratios() {
        tbl.row(vec![
            class_name(k).to_string(),
            format!("{}", c.cables.get(&k).map(|t| t.cables).unwrap_or(0)),
            pct(share, 1),
        ]);
    }
    tbl.print();
    let mut tbl = Table::with_title("by role", vec!["role", "cables", "lanes"]);
    for (k, tally) in &c.by_role {
        tbl.row(vec![
            role_name(*k).to_string(),
            format!("{}", tally.cables),
            format!("{}", tally.lanes),
        ]);
    }
    tbl.print();
    println!("optical modules: {}", c.optical_modules);
    Ok(())
}

fn cmd_capex(_args: &Args) -> Result<()> {
    use ubmesh::cost::capex::{capex_fm_clos, capex_full_clos, capex_ubmesh, savings};
    use ubmesh::topology::superpod::SuperPodConfig;
    let ub = capex_ubmesh(&SuperPodConfig::default());
    let rows = [
        ub.clone(),
        capex_fm_clos("2D-FM+x16 Clos", 8192, 16, 2),
        capex_fm_clos("1D-FM+x16 Clos", 8192, 16, 1),
        capex_full_clos("x64T Clos", 8192, 64),
    ];
    let mut t = Table::with_title(
        "CapEx (Fig 21), NPU-price units",
        vec![
            "architecture",
            "HRS",
            "optic-mods",
            "network",
            "total",
            "net-share",
            "vs UB-Mesh",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{}", r.hrs),
            format!("{}", r.optical_modules),
            fmt(r.network_cost(), 0),
            fmt(r.total(), 0),
            pct(r.network_share(), 0),
            ratio(r.total() / rows[0].total()),
        ]);
    }
    t.print();
    let (hrs_s, opt_s) = savings(&rows[0], &rows[3]);
    println!(
        "vs x64T Clos: HRS saved {}, optical modules saved {}",
        pct(hrs_s, 0),
        pct(opt_s, 0)
    );
    Ok(())
}

fn cmd_reliability(_args: &Args) -> Result<()> {
    use ubmesh::cost::capex::{capex_full_clos, capex_ubmesh};
    use ubmesh::reliability::afr::afr_of_capex;
    use ubmesh::reliability::availability::{availability, mtbf_hours, mttr};
    use ubmesh::topology::superpod::SuperPodConfig;
    let mut t = Table::with_title(
        "reliability (Table 6)",
        vec![
            "arch",
            "E-cable AFR",
            "optical AFR",
            "LRS",
            "HRS",
            "total",
            "MTBF(h)",
            "avail@75min",
        ],
    );
    for (name, capex) in [
        ("UB-Mesh", capex_ubmesh(&SuperPodConfig::default())),
        ("Clos", capex_full_clos("x64T", 8192, 64)),
    ] {
        let a = afr_of_capex(&capex);
        let mtbf = mtbf_hours(a.total());
        t.row(vec![
            name.to_string(),
            fmt(a.electrical_cables, 1),
            fmt(a.optical, 1),
            fmt(a.lrs, 1),
            fmt(a.hrs, 1),
            fmt(a.total(), 1),
            fmt(mtbf, 1),
            pct(availability(mtbf, mttr::BASELINE_HOURS), 2),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_traffic(_args: &Args) -> Result<()> {
    use ubmesh::util::table::bytes;
    use ubmesh::workload::models::by_name;
    use ubmesh::workload::traffic::{analyze, table1_config};
    let m = by_name("gpt4-2t").unwrap();
    let tbl = analyze(&m, &table1_config());
    let mut t = Table::with_title(
        "Table 1: MoE-2T traffic",
        vec!["technique", "pattern", "vol/transfer", "transfers", "total", "share"],
    );
    for r in &tbl.rows {
        t.row(vec![
            r.technique.to_string(),
            r.pattern.to_string(),
            bytes(r.volume_per_transfer),
            fmt(r.transfers, 0),
            bytes(r.total),
            pct(r.total / tbl.total(), 2),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_routing(args: &Args) -> Result<()> {
    use ubmesh::routing::apr::{paths_2d, to_routed, PathSet};
    use ubmesh::routing::tfc::verify_deadlock_free;
    use ubmesh::topology::rack::{ubmesh_rack, RackConfig};
    let src: usize = args.get_parse("src", 0);
    let dst: usize = args.get_parse("dst", 27);
    let (t, h) = ubmesh_rack(&RackConfig::default());
    let node = |x: usize, y: usize| h.npu(y, x, 8);
    let mesh = paths_2d((src % 8, src / 8), (dst % 8, dst / 8), 8, 8, true);
    let routed: Vec<_> = mesh.iter().map(|m| to_routed(m, node)).collect();
    let vls = verify_deadlock_free(&t, &routed).expect("TFC: deadlock-free");
    let ps = PathSet::weighted_by_bottleneck(routed.clone(), &t);
    let mut tbl = Table::with_title(
        format!("APR paths NPU{src} → NPU{dst} (rack 2D-FM)"),
        vec!["#", "kind", "hops", "bottleneck GB/s", "weight", "VLs"],
    );
    for (i, p) in ps.paths.iter().enumerate() {
        tbl.row(vec![
            format!("{i}"),
            format!("{:?}", p.kind),
            format!("{}", p.hops()),
            fmt(p.bottleneck_gb_s(&t), 0),
            fmt(ps.weights[i], 3),
            format!("{:?}", vls[i]),
        ]);
    }
    tbl.print();
    println!(
        "aggregate APR bandwidth: {} GB/s (vs single shortest path {} GB/s)",
        fmt(ps.aggregate_gb_s(&t), 0),
        fmt(ps.paths[0].bottleneck_gb_s(&t), 0)
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt4-2t").to_string();
    let scale: usize = args.get_parse("scale", 1024);
    let artifacts = load_artifacts(args);
    let archs = [
        Arch::ubmesh_default(),
        Arch::Fm1dA,
        Arch::Fm1dB,
        Arch::ClosIntraRack,
    ];
    let seqs = [8192.0, 32768.0, 262144.0, 1048576.0];
    let mut t = Table::with_title(
        format!("{model} @ {scale}: relative perf vs intra-rack Clos"),
        vec!["arch", "8K", "32K", "256K", "1M"],
    );
    for arch in archs {
        let mut cells = vec![arch.name()];
        for seq in seqs {
            let job = Job::new(&model, scale, seq, arch)?;
            let rel = job.relative_perf(Arch::ClosIntraRack, artifacts.as_ref())?;
            cells.push(pct(rel, 1));
        }
        t.row(cells);
    }
    t.print();
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("census") => cmd_census(&args),
        Some("capex") => cmd_capex(&args),
        Some("reliability") => cmd_reliability(&args),
        Some("traffic") => cmd_traffic(&args),
        Some("routing") => cmd_routing(&args),
        Some("sweep") => cmd_sweep(&args),
        _ => {
            eprintln!(
                "usage: ubmesh <run|census|capex|reliability|traffic|routing|sweep> [--options]"
            );
            eprintln!("see module docs in rust/src/main.rs");
            Ok(())
        }
    }
}
