//! DP-replica translation symmetry (PR 10): partition the measured
//! training iteration into **channel-disjoint, pairwise-translated
//! units** so the fig22 grid stays tractable at 32K–64K NPUs.
//!
//! On a [`RankOrder::TopologyAware`] layout the physical index of rank
//! `(tp, sp, pp, dp)` is `tp + TP·(sp + SP·(pp + PP·dp))` — DP is the
//! outermost stride, so consecutive DP replicas occupy consecutive
//! blocks of NPUs, and whole groups of replicas occupy whole **pods**
//! when the block size divides the pod size. Inside such a block, every
//! TP/SP exchange, EP all-to-all and PP boundary send of the iteration
//! touches only links owned by the block's pods:
//!
//! * intra-pod routing ([`ClusterMap::pair_paths`]) is pod-local — rack
//!   coordinates enter it modulo `racks_per_pod`;
//! * cross-pod paths climb the rack's **own** LRS→HRS uplinks; distinct
//!   pods share HRS switch *nodes* but never *links*, and links are the
//!   only capacitated resource in the fluid model;
//! * the per-pair path-selection nonces are replica-local by
//!   construction (`pair_sel` over within-group indices in exchanges,
//!   `sp_i·tp + tp_i` in PP sends).
//!
//! Two consequences, which this module packages:
//!
//! 1. **Component parallelism** — the unit DAGs are channel-disjoint,
//!    so [`crate::sim::run_components`] may advance them on worker
//!    threads, bit-identical to the one big serial event loop.
//! 2. **Representative solve** — consecutive units are whole-pod
//!    *translations* of each other: same capacities in the same relative
//!    link order, same flow structure, same event sequence. One unit's
//!    [`SimReport`] is bit-for-bit the report of every other unit, so
//!    the symmetric runner can solve one representative and reuse it
//!    `units − 1` times ([`SymmetricConfig::replica_cache`]).
//!
//! What breaks the symmetry — and is therefore excluded from the units —
//! is the **DP gradient tail**: DP groups couple every replica through
//! the HRS tier. The tail runs as its own DAG, gated on the slowest
//! unit's makespan. The gating is *exact*, not an approximation: in the
//! full iteration DAG every unit stage is an ancestor of `dp-rs`, the
//! tail has no other dependencies, and the tail's flows touch the units'
//! links only after every unit has drained — so `full makespan =
//! max(unit makespans) + tail makespan`, reproduced bitwise by
//! [`merge_symmetric`]. The `replica_cache == full solve` differential
//! and the `parallel == serial` property are pinned by
//! `rust/tests/symmetric.rs` and `rust/tests/properties.rs`; HRS-tier
//! coupling that *would* invalidate the cache (an EP extent straddling
//! unit boundaries, a slice cutting a pod in half) is rejected by
//! [`symmetric_iteration`] up front — the caller is automatically
//! demoted to [`iteration_dag`](super::step::iteration_dag)'s full
//! solve.

use crate::sim::{
    run_components_timed, run_with, ParallelConfig, ResolveStrategy, SimConfig, SimNet,
    SimReport, StageDag,
};
use crate::topology::Topology;
use crate::workload::cluster::ClusterMap;
use crate::workload::step::{dp_tail_dag, unit_iteration_dag, IterationSpec, RankOrder};
use crate::workload::{ModelConfig, ParallelismConfig};

/// The iteration, factored into translated units plus the coupling tail.
pub struct SymmetricIteration {
    /// DP replicas per unit.
    pub unit_dp: usize,
    /// Number of units (`p.dp / unit_dp`).
    pub units: usize,
    /// One DAG per unit, channel-disjoint and pairwise translated, in
    /// dp order (`unit u` covers replicas `u·unit_dp .. (u+1)·unit_dp`).
    pub unit_dags: Vec<StageDag>,
    /// The DP gradient tail (dependency-free); `None` when the model
    /// exposes no DP traffic.
    pub tail: Option<StageDag>,
}

/// Smallest dp-slice width that closes every coupling group: EP blocks
/// span `ep/sp` consecutive replicas when `ep > sp` (and a fraction of
/// one otherwise), and the slice must cover whole pods so its links are
/// private. `Err` explains which precondition failed — the caller then
/// falls back to the full (coupled) solve.
fn unit_width(
    map: &ClusterMap,
    p: &ParallelismConfig,
) -> Result<usize, &'static str> {
    let base = if p.ep > p.sp {
        if p.ep % p.sp != 0 {
            return Err("EP blocks straddle replicas: sp does not divide ep");
        }
        p.ep / p.sp
    } else {
        if p.ep > 1 && p.sp % p.ep != 0 {
            return Err("EP blocks straddle replicas: ep does not divide sp");
        }
        1
    };
    let pod = map
        .mesh_pod_npus()
        .ok_or("replica symmetry needs the 2D mesh fabric")?;
    let replica = p.tp * p.sp * p.pp;
    // Grow in multiples of the EP span until the slice covers whole
    // pods and divides dp evenly.
    let mut w = base;
    while w < p.dp {
        if p.dp % w == 0 && (replica * w) % pod == 0 {
            return Ok(w);
        }
        w += base;
    }
    if p.dp % base == 0 && w == p.dp && (replica * w) % pod == 0 {
        // One unit covering everything is formally valid but useless —
        // the caller should run the plain full DAG instead.
        return Err("no proper unit width: the only aligned slice is all of dp");
    }
    Err("no unit width aligns with both EP blocks and pod boundaries")
}

/// Factor the measured iteration of [`super::step::iteration_dag`] into
/// translation-symmetric units plus the DP tail. `Err` names the
/// precondition that failed (naive rank order, non-mesh fabric, EP or
/// pod misalignment, dp too small to split) — the demotion path back to
/// the full coupled solve.
pub fn symmetric_iteration(
    t: &Topology,
    map: &ClusterMap,
    m: &ModelConfig,
    p: &ParallelismConfig,
    order: RankOrder,
    spec: &IterationSpec,
) -> Result<SymmetricIteration, &'static str> {
    if order != RankOrder::TopologyAware {
        return Err("replica symmetry needs the topology-aware rank order");
    }
    if p.npus() != map.npu_count() {
        return Err("parallelism does not fill the mapped cluster");
    }
    if p.dp < 2 {
        return Err("dp < 2: nothing to factor");
    }
    let unit_dp = unit_width(map, p)?;
    let units = p.dp / unit_dp;
    let unit_dags = (0..units)
        .map(|u| {
            unit_iteration_dag(t, map, m, p, order, spec, u * unit_dp..(u + 1) * unit_dp)
        })
        .collect();
    let tail_dag = dp_tail_dag(t, map, m, p, order, spec);
    Ok(SymmetricIteration {
        unit_dp,
        units,
        unit_dags,
        tail: (!tail_dag.stages.is_empty()).then_some(tail_dag),
    })
}

/// How to execute a [`SymmetricIteration`].
#[derive(Clone, Debug)]
pub struct SymmetricConfig {
    /// Worker threads for the unit components (the tail always runs
    /// serially — it is one coupled component).
    pub workers: usize,
    /// Solve one representative unit and reuse its report for the
    /// translated others, instead of solving every unit.
    pub replica_cache: bool,
    /// Solver strategy for every event loop.
    pub strategy: ResolveStrategy,
}

impl Default for SymmetricConfig {
    fn default() -> SymmetricConfig {
        SymmetricConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            replica_cache: true,
            strategy: ResolveStrategy::default(),
        }
    }
}

/// Result of a symmetric run: the merged whole-iteration report plus
/// the wall-clock telemetry the fig22 bench publishes.
pub struct SymmetricReport {
    /// The whole-iteration report, bit-identical to what the serial
    /// event loop over [`super::step::iteration_dag`]'s full DAG
    /// produces for makespan/byte-hops and to the in-order sum of the
    /// per-component counters.
    pub report: SimReport,
    /// Wall seconds per *executed* unit run (length 1 with the replica
    /// cache, `units` without).
    pub unit_walls_s: Vec<f64>,
    /// Wall seconds of the tail run (0.0 when there is no tail).
    pub tail_wall_s: f64,
    /// Units whose report came from the representative instead of a
    /// solve of their own.
    pub cached_units: usize,
}

impl SymmetricReport {
    /// Wall seconds a single-worker, no-cache run would have spent:
    /// executed walls, with the representative's wall standing in for
    /// each cached unit. The `fig22.par.speedup` numerator.
    pub fn serial_equivalent_wall_s(&self) -> f64 {
        let unit_sum: f64 = self.unit_walls_s.iter().sum();
        let rep = self.unit_walls_s.first().copied().unwrap_or(0.0);
        unit_sum + rep * self.cached_units as f64 + self.tail_wall_s
    }

    /// Wall seconds actually spent (max over concurrent workers is not
    /// observable from here; this is the sum of what this thread paid:
    /// the component sweep returns per-unit walls, so the *caller*
    /// wraps the whole run in its own clock for the denominator).
    pub fn executed_wall_s(&self) -> f64 {
        self.unit_walls_s.iter().sum::<f64>() + self.tail_wall_s
    }
}

/// Merge per-unit reports and the (optional, already gate-shifted-free)
/// tail report into the whole-iteration [`SimReport`].
///
/// The merge is the factored image of the serial loop: makespan is
/// `max(unit makespans) + tail makespan` (the tail starts when the last
/// backward queue drains), stage completion times concatenate in unit
/// order with the tail's shifted by the gate, and the additive counters
/// (byte-hops, events, reroutes, fault events, solver work) sum in the
/// same order on every path — cache or no cache — so the two modes are
/// comparable bitwise.
pub fn merge_symmetric(units: &[SimReport], tail: Option<&SimReport>) -> SimReport {
    assert!(!units.is_empty(), "merge needs at least one unit report");
    let gate = units
        .iter()
        .map(|r| r.makespan_us)
        .fold(f64::NEG_INFINITY, f64::max);
    let mut merged = SimReport {
        makespan_us: gate,
        stage_done_us: Vec::new(),
        byte_hops: 0.0,
        events: 0,
        peak_flows: 0,
        stalled: Vec::new(),
        stalled_at_us: 0.0,
        reroutes: 0,
        fault_events: 0,
        solver: Default::default(),
    };
    // The units run concurrently in simulated time, so their active
    // flow sets coexist: the serial loop's peak is the sum, not the max.
    let mut unit_peak_sum = 0usize;
    let mut stage_base = 0usize;
    let mut stall_time = f64::NEG_INFINITY;
    for r in units {
        merged.stage_done_us.extend_from_slice(&r.stage_done_us);
        merged.byte_hops += r.byte_hops;
        merged.events += r.events;
        unit_peak_sum += r.peak_flows;
        for s in &r.stalled {
            let mut s = s.clone();
            s.stage += stage_base;
            merged.stalled.push(s);
        }
        if r.is_stalled() {
            stall_time = stall_time.max(r.stalled_at_us);
        }
        merged.reroutes += r.reroutes;
        merged.fault_events += r.fault_events;
        merged.solver.merge(&r.solver);
        stage_base += r.stage_done_us.len();
    }
    merged.peak_flows = unit_peak_sum;
    if let Some(tr) = tail {
        merged.makespan_us = gate + tr.makespan_us;
        merged
            .stage_done_us
            .extend(tr.stage_done_us.iter().map(|&d| gate + d));
        merged.byte_hops += tr.byte_hops;
        merged.events += tr.events;
        merged.peak_flows = merged.peak_flows.max(tr.peak_flows);
        for s in &tr.stalled {
            let mut s = s.clone();
            s.stage += stage_base;
            merged.stalled.push(s);
        }
        if tr.is_stalled() {
            stall_time = stall_time.max(gate + tr.stalled_at_us);
        }
        merged.reroutes += tr.reroutes;
        merged.fault_events += tr.fault_events;
        merged.solver.merge(&tr.solver);
    }
    merged.stalled_at_us = if merged.stalled.is_empty() {
        merged.makespan_us
    } else {
        stall_time
    };
    merged
}

/// Execute a [`SymmetricIteration`]: units as parallel components
/// (solving one representative when the cache is on), then the tail,
/// serially, gated on the slowest unit.
pub fn run_symmetric(
    net: &SimNet,
    sym: &SymmetricIteration,
    cfg: &SymmetricConfig,
) -> SymmetricReport {
    let pcfg = ParallelConfig::serial()
        .with_workers(cfg.workers)
        .with_strategy(cfg.strategy);
    let (unit_reports, unit_walls_s, cached_units) = if cfg.replica_cache {
        let timed = run_components_timed(net, &sym.unit_dags[..1], &pcfg);
        let (rep, wall) = timed.into_iter().next().expect("representative unit");
        let reports: Vec<SimReport> = (0..sym.units).map(|_| rep.clone()).collect();
        (reports, vec![wall], sym.units - 1)
    } else {
        let timed = run_components_timed(net, &sym.unit_dags, &pcfg);
        let mut reports = Vec::with_capacity(timed.len());
        let mut walls = Vec::with_capacity(timed.len());
        for (r, w) in timed {
            reports.push(r);
            walls.push(w);
        }
        (reports, walls, 0)
    };
    let sim_cfg = SimConfig {
        strategy: cfg.strategy,
    };
    let (tail_report, tail_wall_s) = match &sym.tail {
        Some(tdag) => {
            #[allow(clippy::disallowed_methods)]
            let t0 = std::time::Instant::now();
            let tr = run_with(net, tdag, &sim_cfg);
            (Some(tr), t0.elapsed().as_secs_f64())
        }
        None => (None, 0.0),
    };
    SymmetricReport {
        report: merge_symmetric(&unit_reports, tail_report.as_ref()),
        unit_walls_s,
        tail_wall_s,
        cached_units,
    }
}
