//! Data-traffic derivation per parallelism technique (Table 1, §2.2).
//!
//! Volumes are derived from model shapes and parallelism degrees with
//! Megatron-style counting:
//!
//! * **TP**  — 4 AllReduces per layer per microbatch (2 fwd + 2 bwd) of
//!   the full activation; ring transfer volume = 2(p-1)/p × bytes.
//! * **SP**  — 4 AllGathers + the bwd ReduceScatters per layer per
//!   microbatch of the sequence-sharded activation ((p-1)/p × bytes).
//! * **EP**  — 2 All2Alls per MoE layer per microbatch (dispatch +
//!   combine) of the top-k routed token slice.
//! * **PP**  — boundary activation P2P, 2 per microbatch per stage edge.
//! * **DP**  — one gradient AllReduce per iteration, bucketed.
//!
//! With the paper's MoE-2T proxy (GPT4-2T) at TP=8, SP=2 (on top of the
//! 8-way tensor shard), EP=16, PP=8, 13 microbatches of 8K tokens, the
//! shares land on Table 1's hierarchy: TP ≈ 53%, SP ≈ 44%, EP ≈ 1.5%,
//! PP ≈ 0.1%, DP ≈ 1.3% — `benches/table1_traffic.rs` prints both.

use super::models::ModelConfig;

pub const BYTES_PER_ACT: f64 = 2.0; // bf16 activations
pub const BYTES_PER_GRAD: f64 = 2.0; // bf16 gradients

/// Parallelism degrees + iteration shape (§2.2, Fig 2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParallelismConfig {
    pub tp: usize,
    pub sp: usize,
    pub ep: usize,
    pub pp: usize,
    pub dp: usize,
    /// Pipeline microbatches in flight per iteration.
    pub microbatches: usize,
    /// Tokens per microbatch (per DP replica).
    pub tokens_per_microbatch: f64,
}

impl ParallelismConfig {
    pub fn npus(&self) -> usize {
        self.tp * self.sp * self.pp * self.dp
    }

    /// Tokens processed per iteration across the cluster.
    pub fn tokens_per_iter(&self) -> f64 {
        self.tokens_per_microbatch * self.microbatches as f64 * self.dp as f64
    }
}

/// One row of Table 1.
#[derive(Clone, Debug)]
pub struct TrafficRow {
    pub technique: &'static str,
    pub pattern: &'static str,
    /// Bytes moved per transfer (per participating NPU).
    pub volume_per_transfer: f64,
    /// Number of transfers per iteration.
    pub transfers: f64,
    /// Total bytes per iteration.
    pub total: f64,
}

/// The full Table 1 analysis for one (model, parallelism) pair.
#[derive(Clone, Debug)]
pub struct TrafficTable {
    pub rows: Vec<TrafficRow>,
}

impl TrafficTable {
    pub fn total(&self) -> f64 {
        self.rows.iter().map(|r| r.total).sum()
    }

    pub fn share(&self, technique: &str) -> f64 {
        let t = self.total();
        self.rows
            .iter()
            .filter(|r| r.technique == technique)
            .map(|r| r.total)
            .sum::<f64>()
            / t
    }

    pub fn row(&self, technique: &str) -> Option<&TrafficRow> {
        self.rows.iter().find(|r| r.technique == technique)
    }
}

/// Derive the per-iteration traffic table (Table 1) for a model +
/// parallelism configuration.
pub fn analyze(m: &ModelConfig, p: &ParallelismConfig) -> TrafficTable {
    let mut rows = Vec::new();
    let layers = m.layers as f64;
    let mb = p.microbatches as f64;
    // Activation bytes of a full microbatch at one layer boundary.
    let act = p.tokens_per_microbatch * m.hidden as f64 * BYTES_PER_ACT;

    // --- TP: 4 AllReduces / layer / microbatch of the SP-sharded act.
    if p.tp > 1 {
        let shard = act / p.sp as f64;
        let vol = 2.0 * (p.tp as f64 - 1.0) / p.tp as f64 * shard;
        let transfers = layers * mb * 4.0;
        rows.push(TrafficRow {
            technique: "TP",
            pattern: "AllReduce",
            volume_per_transfer: vol,
            transfers,
            total: vol * transfers,
        });
    }

    // --- SP: 4 AllGathers + 2 ReduceScatters / layer / microbatch.
    if p.sp > 1 {
        let shard = act / p.sp as f64;
        let vol_ag = (p.sp as f64 - 1.0) * shard; // gather all peers' shards
        let transfers_ag = layers * mb * 4.0;
        let vol_rs = (p.sp as f64 - 1.0) / p.sp as f64 * act / 2.0;
        let transfers_rs = layers * mb * 4.0 / 3.0;
        rows.push(TrafficRow {
            technique: "SP",
            pattern: "AllGather",
            volume_per_transfer: vol_ag,
            transfers: transfers_ag,
            total: vol_ag * transfers_ag + vol_rs * transfers_rs,
        });
    }

    // --- EP: 2 All2Alls / MoE layer / microbatch.
    if m.is_moe() && p.ep > 1 {
        // Each NPU dispatches its token slice to top-k experts; the
        // routed slice per transfer is tokens/(tp·sp) × hidden × k / ep.
        let routed = p.tokens_per_microbatch / (p.tp * p.sp) as f64
            * m.hidden as f64
            * BYTES_PER_ACT
            * m.active_experts as f64
            * (p.ep as f64 - 1.0)
            / p.ep as f64;
        let transfers = layers * mb * 2.0;
        rows.push(TrafficRow {
            technique: "EP",
            pattern: "AlltoAll",
            volume_per_transfer: routed,
            transfers,
            total: routed * transfers,
        });
    }

    // --- PP: boundary P2P, fwd + bwd per microbatch (per stage edge).
    if p.pp > 1 {
        let vol = act / p.sp as f64; // boundary act is SP-sharded too
        let transfers = 2.0 * mb;
        rows.push(TrafficRow {
            technique: "PP",
            pattern: "P2P",
            volume_per_transfer: vol,
            transfers,
            total: vol * transfers,
        });
    }

    // --- DP: gradient AllReduce once per iteration, bucketed.
    if p.dp > 1 {
        let grads = m.params() / (p.tp * p.pp * p.ep.max(1)) as f64 * BYTES_PER_GRAD;
        let buckets = 64.0_f64.min(grads / 8e6).max(1.0);
        let vol = 2.0 * (p.dp as f64 - 1.0) / p.dp as f64 * grads / buckets;
        rows.push(TrafficRow {
            technique: "DP",
            pattern: "AllReduce",
            volume_per_transfer: vol,
            transfers: buckets,
            total: vol * buckets,
        });
    }

    TrafficTable { rows }
}

/// The paper's Table 1 configuration: MoE-2T (GPT4-2T proxy) with the
/// parallelism the transfer counts imply (96 layers × 13 µbatches × 4 =
/// 4992 TP transfers; 2 × 13 = 26 PP transfers).
pub fn table1_config() -> ParallelismConfig {
    ParallelismConfig {
        tp: 8,
        sp: 2,
        ep: 16,
        pp: 8,
        dp: 4,
        microbatches: 13,
        tokens_per_microbatch: 8192.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::by_name;

    #[test]
    fn table1_shares_are_hierarchical() {
        let m = by_name("gpt4-2t").unwrap();
        let t = analyze(&m, &table1_config());
        let (tp, sp, ep, pp, dp) = (
            t.share("TP"),
            t.share("SP"),
            t.share("EP"),
            t.share("PP"),
            t.share("DP"),
        );
        // Paper: 52.9 / 44.08 / 1.54 / 0.14 / 1.34 (%).
        assert!((0.40..0.65).contains(&tp), "TP share {tp}");
        assert!((0.30..0.55).contains(&sp), "SP share {sp}");
        assert!(ep < 0.05, "EP share {ep}");
        assert!(pp < 0.01, "PP share {pp}");
        assert!(dp < 0.05, "DP share {dp}");
        // TP+SP dominate: "approximately 97% of the total traffic".
        assert!(tp + sp > 0.90, "TP+SP = {}", tp + sp);
    }

    #[test]
    fn table1_transfer_counts_match_paper() {
        let m = by_name("gpt4-2t").unwrap();
        let t = analyze(&m, &table1_config());
        assert_eq!(t.row("TP").unwrap().transfers, 4992.0);
        assert_eq!(t.row("PP").unwrap().transfers, 26.0);
        assert_eq!(t.row("DP").unwrap().transfers, 64.0);
    }

    #[test]
    fn tp_volume_near_360mb() {
        let m = by_name("gpt4-2t").unwrap();
        let t = analyze(&m, &table1_config());
        let v = t.row("TP").unwrap().volume_per_transfer;
        // Paper: 360 MB. Our derivation: 2·7/8 × 8192×12288×2/2 ≈ 176 MB
        // per SP-shard — within 2× of the paper, whose exact microbatch
        // shape is unpublished. Keep it in a sane band.
        assert!(v > 50e6 && v < 700e6, "TP volume {v}");
    }

    #[test]
    fn dense_model_has_no_ep_traffic() {
        let m = by_name("gpt3-175b").unwrap();
        let t = analyze(&m, &table1_config());
        assert!(t.row("EP").is_none());
    }

    #[test]
    fn single_degree_produces_no_row() {
        let m = by_name("gpt3-175b").unwrap();
        let mut p = table1_config();
        p.tp = 1;
        p.dp = 1;
        let t = analyze(&m, &p);
        assert!(t.row("TP").is_none());
        assert!(t.row("DP").is_none());
    }
}
