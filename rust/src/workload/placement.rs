//! Topology-aware rank placement (§5.2): map parallelism groups onto the
//! UB-Mesh hierarchy so the heaviest traffic stays in the
//! highest-bandwidth tier.
//!
//! The pruning heuristic from the paper: "TP and SP (or CP), which
//! involve high communication volumes, are prioritized for
//! high-bandwidth domains, while PP and DP ... is the lowest priority."

use crate::topology::superpod::SuperPodConfig;
use crate::topology::ublink::LANE_GB_S;
use crate::workload::cluster::{ubmesh_hop_chains, HopCap};

/// Communication tiers of the UB-Mesh hierarchy, ordered by bandwidth.
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Tier {
    /// Intra-board X full-mesh.
    Board = 0,
    /// Intra-rack Y full-mesh.
    Rack = 1,
    /// Rack row (Z) direct links.
    Row = 2,
    /// Rack column (α) direct links.
    Col = 3,
    /// Pod-level HRS Clos (β/γ).
    Pod = 4,
    /// DCN beyond the SuperPod.
    Dcn = 5,
}

pub const NTIERS: usize = 6;

/// NPUs reachable within each tier (cumulative group sizes for the
/// default UB-Mesh: board 8, rack 64, row 256, pod 1024, superpod 8192).
pub const TIER_SPAN: [usize; NTIERS] = [8, 64, 256, 1024, 8192, usize::MAX];

/// Per-NPU usable bandwidth (GB/s) when a collective spans exactly this
/// tier: the **min over the real hop chain** for that tier
/// ([`ubmesh_hop_chains`]) — NPU plane attach, board-LRS ↔ inter-rack
/// LRS backplane-mesh lanes, uplink-LRS lanes with
/// `SuperPodConfig::uplink_oversub` applied, HRS ports. The pre-PR-6
/// model priced Row/Col/Pod off the NPU's inter-rack provision alone
/// and over-reported those tiers ~1.5–2× whenever the x2 backplane-mesh
/// stage was the binding hop (it is, at every default provision).
#[derive(Clone, Copy, Debug)]
pub struct TierBandwidth {
    pub gb_s: [f64; NTIERS],
}

impl TierBandwidth {
    /// Min-over-hops reduction of per-tier chains (see
    /// [`ubmesh_hop_chains`]).
    pub fn from_chains(chains: &[Vec<HopCap>; NTIERS]) -> TierBandwidth {
        let mut gb_s = [0.0; NTIERS];
        for (g, chain) in gb_s.iter_mut().zip(chains) {
            *g = chain
                .iter()
                .map(HopCap::gb_s)
                .fold(f64::INFINITY, f64::min);
        }
        TierBandwidth { gb_s }
    }

    /// Paper-default UB-Mesh with `inter_rack_lanes` per NPU (Fig 20
    /// explores x4..x32; default x16) and a routing multiplier for the
    /// Z/α tiers (Shortest = 1.0; Detour/Borrow > 1, Fig 19), at the
    /// paper's x2 backplane-mesh width and 1:1 uplinks.
    pub fn ubmesh(inter_rack_lanes_per_npu: u32, routing_boost: f64) -> TierBandwidth {
        TierBandwidth::ubmesh_mesh(inter_rack_lanes_per_npu, routing_boost, 2, 1)
    }

    /// UB-Mesh with every provisioning knob exposed: inter-rack lanes
    /// per NPU, routing boost, backplane-mesh width (lanes per LRS
    /// pair; x2 default, swept by the fig20 mesh section), and uplink
    /// oversubscription. Builds the corresponding [`SuperPodConfig`]
    /// and reduces its hop chains, so the analytic tiers and the DES
    /// wiring always read the same knowledge.
    pub fn ubmesh_mesh(
        inter_rack_lanes_per_npu: u32,
        routing_boost: f64,
        mesh_lanes: u32,
        uplink_oversub: u32,
    ) -> TierBandwidth {
        let mut cfg = SuperPodConfig::default();
        // x16 per NPU ↔ x32 out-facing lanes per inter-rack LRS (the
        // rack exposes 4 planes × 8 IR-LRS over 64 NPUs).
        cfg.pod.rack.ir_lrs_out_lanes = 2 * inter_rack_lanes_per_npu;
        cfg.pod.row_lanes_per_plane = 2 * inter_rack_lanes_per_npu;
        cfg.pod.col_lanes_per_plane = 2 * inter_rack_lanes_per_npu;
        cfg.pod.rack.lrs_mesh_lanes = mesh_lanes;
        cfg.uplink_oversub = uplink_oversub;
        TierBandwidth::from_chains(&ubmesh_hop_chains(&cfg, routing_boost))
    }

    /// Non-oversubscribed Clos: the leaf tier runs the full per-NPU
    /// provision; everything past the rack crosses the aggregation
    /// layer ([`TierBandwidth::clos_oversub`] with 1:1), and the DCN
    /// tier stays NIC-limited like every other architecture.
    pub fn clos(lanes_per_npu: u32) -> TierBandwidth {
        TierBandwidth::clos_oversub(lanes_per_npu, 1)
    }

    /// Clos with an oversubscribed aggregation layer: tiers above the
    /// rack drain through the spine at `leaf / oversub`. The old model
    /// filled all six tiers with the flat leaf figure, exempting Clos
    /// from the hop accounting UB-Mesh pays.
    pub fn clos_oversub(lanes_per_npu: u32, oversub: u32) -> TierBandwidth {
        let leaf = lanes_per_npu as f64 * LANE_GB_S;
        let agg = leaf / oversub as f64;
        let dcn = agg.min(12.5);
        TierBandwidth {
            gb_s: [leaf, leaf, agg, agg, agg, dcn],
        }
    }

    /// The routing boost shared by every Fig 17 architecture's inter-rack
    /// tiers (the paper fixes inter-rack to 2D-FM with its best routing
    /// when exploring intra-rack variants).
    pub const FIG17_INTER_RACK_BOOST: f64 = 1.6;

    /// Fig 16-d / Fig 17 baseline: intra-rack Clos (x64 per NPU through
    /// 16 HRS) while the *inter-rack* fabric stays the 2D-FM of §6.3 —
    /// "we fix the inter-rack architecture (2D-FM)". Inter-rack tiers are
    /// identical to UB-Mesh's (same provision, same routing), so only the
    /// intra-rack difference is measured.
    pub fn clos_intra_rack(inter_rack_lanes_per_npu: u32) -> TierBandwidth {
        let full = 64.0 * LANE_GB_S;
        let ub = TierBandwidth::ubmesh(inter_rack_lanes_per_npu, Self::FIG17_INTER_RACK_BOOST);
        TierBandwidth {
            gb_s: [full, full, ub.gb_s[2], ub.gb_s[3], ub.gb_s[4], ub.gb_s[5]],
        }
    }

    /// 1D-FM-A (Fig 16-b): board mesh + 32 LRS cross-board (x16 per NPU)
    /// + x16 inter-rack, behind the same fixed 2D-FM inter-rack fabric.
    pub fn fm1d_a() -> TierBandwidth {
        let board = 7.0 * 4.0 * LANE_GB_S;
        let rack = 16.0 * LANE_GB_S;
        let ub = TierBandwidth::ubmesh(16, Self::FIG17_INTER_RACK_BOOST);
        TierBandwidth {
            gb_s: [board, rack, ub.gb_s[2], ub.gb_s[3], ub.gb_s[4], ub.gb_s[5]],
        }
    }

    /// 1D-FM-B (Fig 16-c): board mesh + 8 HRS cross-board (x32 per NPU)
    /// with x32 inter-rack provision. Under the hop-chain model the
    /// extra inter-rack lanes are backplane-mesh-capped (x32 ties x16),
    /// so its edge over 2D-FM comes from the rack tier alone.
    pub fn fm1d_b() -> TierBandwidth {
        let board = 7.0 * 4.0 * LANE_GB_S;
        let rack = 32.0 * LANE_GB_S;
        let ub = TierBandwidth::ubmesh(32, Self::FIG17_INTER_RACK_BOOST);
        TierBandwidth {
            gb_s: [board, rack, ub.gb_s[2], ub.gb_s[3], ub.gb_s[4], ub.gb_s[5]],
        }
    }
}

/// The tier a contiguous group of `span` NPUs communicates over.
pub fn tier_for_span(span: usize) -> Tier {
    match span {
        s if s <= TIER_SPAN[0] => Tier::Board,
        s if s <= TIER_SPAN[1] => Tier::Rack,
        s if s <= TIER_SPAN[2] => Tier::Row,
        s if s <= TIER_SPAN[3] => Tier::Col,
        s if s <= TIER_SPAN[4] => Tier::Pod,
        _ => Tier::Dcn,
    }
}

/// Placement of one parallelism config on the hierarchy: which tier each
/// technique's collectives traverse. Groups are nested contiguously in
/// priority order TP → SP → EP → PP → DP (§5.2's heuristic).
#[derive(Clone, Debug)]
pub struct Placement {
    pub tp_tier: Tier,
    pub sp_tier: Tier,
    pub ep_tier: Tier,
    pub pp_tier: Tier,
    pub dp_tier: Tier,
}

impl Placement {
    pub fn topology_aware(p: &crate::workload::ParallelismConfig) -> Placement {
        // Contiguous nesting: TP innermost, then SP, EP (shares the
        // SP×DP extent per the paper's "SP*DP as an integer multiple of
        // EP"), then PP, DP outermost.
        let tp_span = p.tp;
        let sp_span = p.tp * p.sp;
        let ep_span = (p.tp * p.sp * p.ep).min(p.npus());
        let pp_span = p.tp * p.sp * p.pp;
        let dp_span = p.npus();
        Placement {
            tp_tier: tier_for_span(tp_span),
            sp_tier: tier_for_span(sp_span),
            ep_tier: tier_for_span(ep_span),
            pp_tier: tier_for_span(pp_span),
            dp_tier: tier_for_span(dp_span),
        }
    }

    /// Naive placement that ignores the topology (PP innermost) — the
    /// "not optimally distributed" contrast of §5.
    pub fn naive(p: &crate::workload::ParallelismConfig) -> Placement {
        let pp_span = p.pp;
        let dp_span = p.pp * p.dp;
        let tp_span = p.pp * p.dp * p.tp;
        let sp_span = p.pp * p.dp * p.tp * p.sp;
        Placement {
            tp_tier: tier_for_span(tp_span),
            sp_tier: tier_for_span(sp_span),
            ep_tier: tier_for_span(sp_span),
            pp_tier: tier_for_span(pp_span),
            dp_tier: tier_for_span(dp_span),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traffic::table1_config;

    #[test]
    fn tiers_ordered_by_bandwidth() {
        let bw = TierBandwidth::ubmesh(16, 1.0);
        assert!(bw.gb_s[0] >= bw.gb_s[2]);
        assert!(bw.gb_s[2] >= bw.gb_s[4]);
        assert!(bw.gb_s[4] >= bw.gb_s[5]);
    }

    fn assert_tiers(bw: &TierBandwidth, want: [f64; NTIERS]) {
        for (i, (&got, &w)) in bw.gb_s.iter().zip(&want).enumerate() {
            assert!((got - w).abs() < 1e-9, "tier {i}: got {got}, want {w}");
        }
    }

    #[test]
    fn clos_pays_its_aggregation_hop() {
        // Leaf tiers run the full x64 provision; the DCN tier is
        // NIC-capped like every architecture (min over leaf, agg, NIC).
        assert_tiers(
            &TierBandwidth::clos(64),
            [400.0, 400.0, 400.0, 400.0, 400.0, 12.5],
        );
        // 4:1 aggregation oversubscription: past-rack tiers drain at
        // leaf/4 = 100 GB/s; DCN min(100, 12.5) stays NIC-bound.
        assert_tiers(
            &TierBandwidth::clos_oversub(64, 4),
            [400.0, 400.0, 100.0, 100.0, 100.0, 12.5],
        );
    }

    #[test]
    fn ubmesh_tiers_are_min_over_hops() {
        // x16 Shortest, hand-computed per tier:
        //   board/rack: 7 neighbors × x4          = 175
        //   row/col:  min(attach 4×4 = 100,
        //                 mesh 4p × 8LRS × 3slots × x2 / 64 = 3 → 18.75,
        //                 wire 3 × x32 × 4p / 64 = 6 → 37.5)  = 18.75
        //   pod:      min(attach 100,
        //                 mesh-up 4p × 8LRS × 2slots × x2 / 64 = 2 → 12.5,
        //                 uplink 4p × 2 × x32 / 64 = 4 → 25,
        //                 hrs 25)                              = 12.5
        //   dcn:      min(pod chain, NIC 12.5)                 = 12.5
        assert_tiers(
            &TierBandwidth::ubmesh(16, 1.0),
            [175.0, 175.0, 18.75, 18.75, 12.5, 12.5],
        );
        // Detour (1.6): 6 mesh slots → 37.5; the boosted wire stage
        // (60) no longer binds. Borrow (1.85): all 8 slots → 50.
        assert_tiers(
            &TierBandwidth::ubmesh(16, 1.6),
            [175.0, 175.0, 37.5, 37.5, 12.5, 12.5],
        );
        assert_tiers(
            &TierBandwidth::ubmesh(16, 1.85),
            [175.0, 175.0, 50.0, 50.0, 12.5, 12.5],
        );
    }

    #[test]
    fn uplink_oversub_reaches_the_analytic_pod_tier() {
        // 1:1 and 2:1 both leave the x2 backplane-mesh uplink slots
        // (12.5 GB/s) binding; 4:1 drops the uplink-LRS stage to 6.25.
        for (oversub, pod) in [(1, 12.5), (2, 12.5), (4, 6.25)] {
            let bw = TierBandwidth::ubmesh_mesh(16, 1.0, 2, oversub);
            assert!(
                (bw.gb_s[4] - pod).abs() < 1e-9,
                "oversub {oversub}: pod {} want {pod}",
                bw.gb_s[4]
            );
            assert!((bw.gb_s[5] - pod.min(12.5)).abs() < 1e-9);
        }
    }

    #[test]
    fn mesh_width_lifts_the_backplane_ceiling() {
        // Widening the LRS-pair mesh lanes raises the mesh-bound tiers
        // until the next hop binds: at x16 Detour, x4 mesh moves Row to
        // the wire stage (60) and Pod to the uplink stage (25); x8 mesh
        // leaves them there (Row attach-capped only from x32 provision).
        let m4 = TierBandwidth::ubmesh_mesh(16, 1.6, 4, 1);
        assert_tiers(&m4, [175.0, 175.0, 60.0, 60.0, 25.0, 12.5]);
        let m8 = TierBandwidth::ubmesh_mesh(16, 1.6, 8, 1);
        assert!((m8.gb_s[2] - 60.0).abs() < 1e-9, "wire stage binds");
        assert!((m8.gb_s[4] - 25.0).abs() < 1e-9, "uplink stage binds");
        // x32 provision + x8 mesh: Row hits the NPU plane attach (100).
        let wide = TierBandwidth::ubmesh_mesh(32, 1.6, 8, 1);
        assert!((wide.gb_s[2] - 100.0).abs() < 1e-9);
        assert!((wide.gb_s[4] - 50.0).abs() < 1e-9);
    }

    #[test]
    fn topology_aware_puts_tp_on_board() {
        let p = table1_config();
        let place = Placement::topology_aware(&p);
        assert_eq!(place.tp_tier, Tier::Board);
        assert_eq!(place.sp_tier, Tier::Rack);
        assert!(place.dp_tier >= place.sp_tier);
    }

    #[test]
    fn naive_placement_pushes_tp_out() {
        let p = table1_config();
        let naive = Placement::naive(&p);
        let aware = Placement::topology_aware(&p);
        assert!(naive.tp_tier > aware.tp_tier);
    }

    #[test]
    fn fig20_lanes_scale_until_the_mesh_caps() {
        // Under the corrected model the inter-rack provision only pays
        // off while the wire stage is the binding hop: x4 → x8 doubles
        // the Detour Row tier (15 → 30), but from x16 up the x2
        // backplane mesh (37.5 GB/s) is the ceiling — x32 buys nothing.
        let row = |lanes| TierBandwidth::ubmesh(lanes, 1.6).gb_s[2];
        assert!((row(4) - 15.0).abs() < 1e-9);
        assert!((row(8) - 30.0).abs() < 1e-9);
        assert!((row(16) - 37.5).abs() < 1e-9);
        assert!((row(32) - row(16)).abs() < 1e-9, "mesh-capped");
    }
}
