//! Topology-aware rank placement (§5.2): map parallelism groups onto the
//! UB-Mesh hierarchy so the heaviest traffic stays in the
//! highest-bandwidth tier.
//!
//! The pruning heuristic from the paper: "TP and SP (or CP), which
//! involve high communication volumes, are prioritized for
//! high-bandwidth domains, while PP and DP ... is the lowest priority."

use crate::topology::ublink::LANE_GB_S;

/// Communication tiers of the UB-Mesh hierarchy, ordered by bandwidth.
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Tier {
    /// Intra-board X full-mesh.
    Board = 0,
    /// Intra-rack Y full-mesh.
    Rack = 1,
    /// Rack row (Z) direct links.
    Row = 2,
    /// Rack column (α) direct links.
    Col = 3,
    /// Pod-level HRS Clos (β/γ).
    Pod = 4,
    /// DCN beyond the SuperPod.
    Dcn = 5,
}

pub const NTIERS: usize = 6;

/// NPUs reachable within each tier (cumulative group sizes for the
/// default UB-Mesh: board 8, rack 64, row 256, pod 1024, superpod 8192).
pub const TIER_SPAN: [usize; NTIERS] = [8, 64, 256, 1024, 8192, usize::MAX];

/// Per-NPU usable bandwidth (GB/s) when a collective spans exactly this
/// tier, for a given inter-rack lane provision and routing strategy
/// multiplier. Derived from the §3.3 lane budgets:
/// * board: 7 neighbors × 4 lanes;
/// * rack: 7 Y-neighbors × 4 lanes;
/// * row/col: the rack's x128/neighbor bundles shared by 64 NPUs,
///   3 reachable neighbor racks each → 6 lanes/NPU at x16 provision;
/// * pod: x256 uplink per rack / 64;
/// * DCN: NIC-limited.
#[derive(Clone, Copy, Debug)]
pub struct TierBandwidth {
    pub gb_s: [f64; NTIERS],
}

impl TierBandwidth {
    /// Paper-default UB-Mesh with `inter_rack_lanes` per NPU (Fig 20
    /// explores x4..x32; default x16) and a routing multiplier for the
    /// Z/α tiers (Shortest = 1.0; Detour/Borrow > 1, Fig 19).
    pub fn ubmesh(inter_rack_lanes_per_npu: u32, routing_boost: f64) -> TierBandwidth {
        let board = 7.0 * 4.0 * LANE_GB_S;
        let rack = 7.0 * 4.0 * LANE_GB_S;
        // Of the NPU's inter-rack provision, 3/4 serves the two direct
        // dims (row+col at 3 neighbors each), 1/4 the pod uplink.
        let direct = inter_rack_lanes_per_npu as f64 * 0.75 * LANE_GB_S;
        let row = direct / 2.0 * routing_boost;
        let col = direct / 2.0 * routing_boost;
        let pod = inter_rack_lanes_per_npu as f64 * 0.25 * LANE_GB_S;
        let dcn = 12.5;
        TierBandwidth {
            gb_s: [board, rack, row, col, pod, dcn],
        }
    }

    /// Non-oversubscribed Clos: full x64-per-NPU bandwidth at every tier
    /// (the idealized upper bound).
    pub fn clos(lanes_per_npu: u32) -> TierBandwidth {
        TierBandwidth {
            gb_s: [lanes_per_npu as f64 * LANE_GB_S; NTIERS],
        }
    }

    /// The routing boost shared by every Fig 17 architecture's inter-rack
    /// tiers (the paper fixes inter-rack to 2D-FM with its best routing
    /// when exploring intra-rack variants).
    pub const FIG17_INTER_RACK_BOOST: f64 = 1.6;

    /// Fig 16-d / Fig 17 baseline: intra-rack Clos (x64 per NPU through
    /// 16 HRS) while the *inter-rack* fabric stays the 2D-FM of §6.3 —
    /// "we fix the inter-rack architecture (2D-FM)". Inter-rack tiers are
    /// identical to UB-Mesh's (same provision, same routing), so only the
    /// intra-rack difference is measured.
    pub fn clos_intra_rack(inter_rack_lanes_per_npu: u32) -> TierBandwidth {
        let full = 64.0 * LANE_GB_S;
        let ub = TierBandwidth::ubmesh(inter_rack_lanes_per_npu, Self::FIG17_INTER_RACK_BOOST);
        TierBandwidth {
            gb_s: [full, full, ub.gb_s[2], ub.gb_s[3], ub.gb_s[4], ub.gb_s[5]],
        }
    }

    /// 1D-FM-A (Fig 16-b): board mesh + 32 LRS cross-board (x16 per NPU)
    /// + x16 inter-rack, behind the same fixed 2D-FM inter-rack fabric.
    pub fn fm1d_a() -> TierBandwidth {
        let board = 7.0 * 4.0 * LANE_GB_S;
        let rack = 16.0 * LANE_GB_S;
        let ub = TierBandwidth::ubmesh(16, Self::FIG17_INTER_RACK_BOOST);
        TierBandwidth {
            gb_s: [board, rack, ub.gb_s[2], ub.gb_s[3], ub.gb_s[4], ub.gb_s[5]],
        }
    }

    /// 1D-FM-B (Fig 16-c): board mesh + 8 HRS cross-board (x32 per NPU)
    /// with x32 inter-rack provision ("thanks to higher inter-rack
    /// bandwidth" it lands slightly above 2D-FM, Fig 17).
    pub fn fm1d_b() -> TierBandwidth {
        let board = 7.0 * 4.0 * LANE_GB_S;
        let rack = 32.0 * LANE_GB_S;
        let ub = TierBandwidth::ubmesh(32, Self::FIG17_INTER_RACK_BOOST);
        TierBandwidth {
            gb_s: [board, rack, ub.gb_s[2], ub.gb_s[3], ub.gb_s[4], ub.gb_s[5]],
        }
    }
}

/// The tier a contiguous group of `span` NPUs communicates over.
pub fn tier_for_span(span: usize) -> Tier {
    match span {
        s if s <= TIER_SPAN[0] => Tier::Board,
        s if s <= TIER_SPAN[1] => Tier::Rack,
        s if s <= TIER_SPAN[2] => Tier::Row,
        s if s <= TIER_SPAN[3] => Tier::Col,
        s if s <= TIER_SPAN[4] => Tier::Pod,
        _ => Tier::Dcn,
    }
}

/// Placement of one parallelism config on the hierarchy: which tier each
/// technique's collectives traverse. Groups are nested contiguously in
/// priority order TP → SP → EP → PP → DP (§5.2's heuristic).
#[derive(Clone, Debug)]
pub struct Placement {
    pub tp_tier: Tier,
    pub sp_tier: Tier,
    pub ep_tier: Tier,
    pub pp_tier: Tier,
    pub dp_tier: Tier,
}

impl Placement {
    pub fn topology_aware(p: &crate::workload::ParallelismConfig) -> Placement {
        // Contiguous nesting: TP innermost, then SP, EP (shares the
        // SP×DP extent per the paper's "SP*DP as an integer multiple of
        // EP"), then PP, DP outermost.
        let tp_span = p.tp;
        let sp_span = p.tp * p.sp;
        let ep_span = (p.tp * p.sp * p.ep).min(p.npus());
        let pp_span = p.tp * p.sp * p.pp;
        let dp_span = p.npus();
        Placement {
            tp_tier: tier_for_span(tp_span),
            sp_tier: tier_for_span(sp_span),
            ep_tier: tier_for_span(ep_span),
            pp_tier: tier_for_span(pp_span),
            dp_tier: tier_for_span(dp_span),
        }
    }

    /// Naive placement that ignores the topology (PP innermost) — the
    /// "not optimally distributed" contrast of §5.
    pub fn naive(p: &crate::workload::ParallelismConfig) -> Placement {
        let pp_span = p.pp;
        let dp_span = p.pp * p.dp;
        let tp_span = p.pp * p.dp * p.tp;
        let sp_span = p.pp * p.dp * p.tp * p.sp;
        Placement {
            tp_tier: tier_for_span(tp_span),
            sp_tier: tier_for_span(sp_span),
            ep_tier: tier_for_span(sp_span),
            pp_tier: tier_for_span(pp_span),
            dp_tier: tier_for_span(dp_span),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traffic::table1_config;

    #[test]
    fn tiers_ordered_by_bandwidth() {
        let bw = TierBandwidth::ubmesh(16, 1.0);
        assert!(bw.gb_s[0] >= bw.gb_s[2]);
        assert!(bw.gb_s[2] >= bw.gb_s[4]);
        assert!(bw.gb_s[4] >= bw.gb_s[5]);
    }

    #[test]
    fn clos_is_flat() {
        let bw = TierBandwidth::clos(64);
        assert!(bw.gb_s.iter().all(|&b| (b - 400.0).abs() < 1e-9));
    }

    #[test]
    fn topology_aware_puts_tp_on_board() {
        let p = table1_config();
        let place = Placement::topology_aware(&p);
        assert_eq!(place.tp_tier, Tier::Board);
        assert_eq!(place.sp_tier, Tier::Rack);
        assert!(place.dp_tier >= place.sp_tier);
    }

    #[test]
    fn naive_placement_pushes_tp_out() {
        let p = table1_config();
        let naive = Placement::naive(&p);
        let aware = Placement::topology_aware(&p);
        assert!(naive.tp_tier > aware.tp_tier);
    }

    #[test]
    fn fig20_bandwidth_scales_with_lanes() {
        let x4 = TierBandwidth::ubmesh(4, 1.0);
        let x32 = TierBandwidth::ubmesh(32, 1.0);
        assert!(x32.gb_s[2] > x4.gb_s[2] * 7.0);
    }
}
