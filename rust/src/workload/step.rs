//! Training-iteration model: analytic iteration time (the calibrated
//! cost model of §5.2) and a DES stage-DAG builder used to validate it
//! at rack scale.

use crate::sim::{Stage, StageDag};
use crate::topology::rack::RackHandles;
use crate::topology::ublink::MESSAGE_ALPHA_US;
use crate::topology::{NodeId, Topology};

use super::models::ModelConfig;
use super::placement::{Placement, TierBandwidth};
use super::traffic::{analyze, ParallelismConfig};

/// NPU peak bf16 throughput (TFLOP/s) — CCU-assisted (§7), Ascend-class.
pub const NPU_PEAK_TFLOPS: f64 = 256.0;
/// Achievable kernel efficiency on dense layers (fraction of peak).
pub const COMPUTE_EFFICIENCY: f64 = 0.55;
/// Fraction of DP gradient AllReduce hidden under backward compute.
pub const DP_OVERLAP: f64 = 0.7;
/// Fraction of TP/SP/EP collective time hidden under compute by the
/// CCU's compute-communication overlap (§7: the Collective Communication
/// Unit "can seamlessly co-operate with compute cores to achieve
/// efficient compute-communication overlap"). The paper's baseline Clos
/// enjoys the same overlap, so this narrows *absolute* comm exposure for
/// both — which is how 2D-FM lands within 7% of Clos (Fig 17).
pub const CCU_OVERLAP: f64 = 0.65;

/// Iteration-time breakdown (µs).
#[derive(Clone, Debug)]
pub struct IterBreakdown {
    pub compute_us: f64,
    pub tp_us: f64,
    pub sp_us: f64,
    pub ep_us: f64,
    pub pp_us: f64,
    pub dp_us: f64,
    pub bubble_us: f64,
    pub total_us: f64,
    /// Model FLOPs utilization.
    pub mfu: f64,
}

impl IterBreakdown {
    pub fn comm_us(&self) -> f64 {
        self.tp_us + self.sp_us + self.ep_us + self.pp_us + self.dp_us
    }
}

/// Analytic iteration time for a (model, parallelism, placement,
/// bandwidth) tuple. Volumes come from the Table 1 derivation; each
/// technique's wire bytes drain at the bandwidth of the tier its group
/// spans. This is the model the AOT-compiled L2 evaluator
/// (`artifacts/costmodel.hlo.txt`) computes in batch.
pub fn iteration_time(
    m: &ModelConfig,
    p: &ParallelismConfig,
    place: &Placement,
    bw: &TierBandwidth,
) -> IterBreakdown {
    let traffic = analyze(m, p);
    // Table 1 volumes are whole-model totals; a rank participates only
    // in its own pipeline slice, so layer-local techniques (TP/SP/EP)
    // divide by pp. DP grads and PP boundaries are already per-rank.
    let t_of = |tech: &str, tier: super::placement::Tier, slice: f64| -> f64 {
        traffic
            .row(tech)
            .map(|r| {
                let b = bw.gb_s[tier as usize];
                (r.total / (b * 1e3) + r.transfers * MESSAGE_ALPHA_US) / slice
            })
            .unwrap_or(0.0)
    };
    let pp_slice = p.pp as f64;
    let exposed = 1.0 - CCU_OVERLAP;
    let tp_us = t_of("TP", place.tp_tier, pp_slice) * exposed;
    let sp_us = t_of("SP", place.sp_tier, pp_slice) * exposed;
    let ep_us = t_of("EP", place.ep_tier, pp_slice) * exposed;
    let pp_us = t_of("PP", place.pp_tier, 1.0);
    let dp_us = t_of("DP", place.dp_tier, 1.0) * (1.0 - DP_OVERLAP);

    // Per-NPU compute across the iteration.
    let tokens_per_replica = p.tokens_per_microbatch * p.microbatches as f64;
    let flops_per_npu =
        m.flops_per_token() * tokens_per_replica / (p.tp * p.sp * p.pp) as f64;
    let compute_us = flops_per_npu / (NPU_PEAK_TFLOPS * 1e12 * COMPUTE_EFFICIENCY) * 1e6;

    // Pipeline bubble: (pp-1)/mb of the busy time.
    let busy = compute_us + tp_us + sp_us + ep_us;
    let bubble_us = busy * (p.pp as f64 - 1.0) / p.microbatches as f64;

    let total_us = busy + bubble_us + pp_us + dp_us;
    let mfu = (flops_per_npu / (NPU_PEAK_TFLOPS * 1e12)) / (total_us / 1e6);
    IterBreakdown {
        compute_us,
        tp_us,
        sp_us,
        ep_us,
        pp_us,
        dp_us,
        bubble_us,
        total_us,
        mfu,
    }
}

/// Tokens/second for the whole cluster under this breakdown.
pub fn throughput_tokens_per_s(p: &ParallelismConfig, iter: &IterBreakdown) -> f64 {
    p.tokens_per_iter() / (iter.total_us / 1e6)
}

/// Build a DES stage DAG for a scaled-down iteration on one rack
/// (TP=8 on boards, SP=8 across boards), used to validate the analytic
/// model. `layers` counts transformer layers to simulate (keep small).
pub fn rack_iteration_dag(
    t: &Topology,
    h: &RackHandles,
    m: &ModelConfig,
    tokens_per_microbatch: f64,
    layers: usize,
) -> StageDag {
    let act = tokens_per_microbatch * m.hidden as f64 * super::traffic::BYTES_PER_ACT;
    let mut stages: Vec<Stage> = Vec::new();
    let boards: Vec<Vec<NodeId>> = (0..8)
        .map(|b| (0..8).map(|s| h.npu(b, s, 8)).collect())
        .collect();
    let cols: Vec<Vec<NodeId>> = (0..8)
        .map(|s| (0..8).map(|b| h.npu(b, s, 8)).collect())
        .collect();
    let flops_per_layer =
        6.0 * m.active_params() / m.layers as f64 * tokens_per_microbatch / 64.0;
    let compute_us = flops_per_layer / (NPU_PEAK_TFLOPS * 1e12 * COMPUTE_EFFICIENCY) * 1e6;

    for l in 0..layers {
        // TP AllReduce on every board (direct full-mesh reduce-scatter +
        // allgather), SP-sharded activation.
        let shard = act / 8.0;
        let mut tp_flows = Vec::new();
        for b in &boards {
            // Reduce-scatter + allgather wire patterns fused into one
            // overlapped stage — both are the direct shard exchange, so
            // build the flow set once and release it twice.
            let xchg = crate::collectives::hierarchical::fullmesh_shard_exchange_flows(
                t, b, shard,
            );
            tp_flows.extend(xchg.iter().cloned());
            tp_flows.extend(xchg);
        }
        stages.push(
            Stage::new(format!("L{l}-tp"))
                .with_flows(tp_flows)
                .with_compute(compute_us),
        );
        // SP AllGather across columns.
        let mut sp_flows = Vec::new();
        for c in &cols {
            sp_flows.extend(
                crate::collectives::hierarchical::fullmesh_shard_exchange_flows(
                    t, c, act,
                ),
            );
        }
        stages.push(Stage::new(format!("L{l}-sp")).with_flows(sp_flows));
    }
    StageDag::chain(stages)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{self, SimNet};
    use crate::topology::rack::{ubmesh_rack, RackConfig};
    use crate::workload::models::by_name;
    use crate::workload::traffic::table1_config;

    #[test]
    fn iteration_breakdown_sane() {
        let m = by_name("gpt4-2t").unwrap();
        let p = table1_config();
        let place = Placement::topology_aware(&p);
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let it = iteration_time(&m, &p, &place, &bw);
        assert!(it.total_us > 0.0);
        assert!(it.mfu > 0.05 && it.mfu < 0.6, "mfu {}", it.mfu);
        assert!(it.compute_us > 0.0 && it.comm_us() > 0.0);
    }

    #[test]
    fn clos_is_upper_bound_and_gap_small() {
        // Fig 17's headline: 2D-FM within 7% of Clos.
        let m = by_name("gpt3-175b").unwrap();
        let p = table1_config();
        let place = Placement::topology_aware(&p);
        let ub = iteration_time(&m, &p, &place, &TierBandwidth::ubmesh(16, 1.0));
        let clos = iteration_time(&m, &p, &place, &TierBandwidth::clos_intra_rack(16));
        assert!(clos.total_us <= ub.total_us);
        let rel = clos.total_us / ub.total_us;
        assert!(
            (0.85..1.0).contains(&rel),
            "2D-FM at {:.3} of Clos (paper: 0.932–0.959)",
            rel
        );
    }

    #[test]
    fn topology_aware_beats_naive_placement() {
        let m = by_name("gpt4-2t").unwrap();
        let p = table1_config();
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let aware = iteration_time(&m, &p, &Placement::topology_aware(&p), &bw);
        let naive = iteration_time(&m, &p, &Placement::naive(&p), &bw);
        assert!(naive.total_us > aware.total_us);
        assert!(
            naive.comm_us() > aware.comm_us() * 1.5,
            "aware comm {} naive comm {}",
            aware.comm_us(),
            naive.comm_us()
        );
    }

    #[test]
    fn rack_des_within_2x_of_analytic() {
        let (t, h) = ubmesh_rack(&RackConfig::default());
        let m = by_name("llama-70b").unwrap();
        let dag = rack_iteration_dag(&t, &h, &m, 8192.0, 2);
        let net = SimNet::new(&t);
        let r = sim::schedule::run(&net, &dag);
        // Analytic equivalent: 2 layers of TP (board tier) + SP (rack).
        let act = 8192.0 * m.hidden as f64 * 2.0;
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let tp = 2.0 * (2.0 * 7.0 / 8.0 * act / 8.0) / (bw.gb_s[0] * 1e3);
        let sp = 2.0 * (7.0 / 8.0 * act) / (bw.gb_s[1] * 1e3) * 8.0 / 7.0;
        let flops = 6.0 * m.active_params() / m.layers as f64 * 8192.0 / 64.0 * 2.0;
        let comp = flops / (NPU_PEAK_TFLOPS * 1e12 * COMPUTE_EFFICIENCY) * 1e6;
        let analytic = tp.max(comp) + sp;
        let ratio = r.makespan_us / analytic;
        assert!(
            (0.4..2.5).contains(&ratio),
            "DES {} vs analytic {analytic} (ratio {ratio})",
            r.makespan_us
        );
    }

    #[test]
    fn throughput_scales_with_dp() {
        let m = by_name("gpt3-175b").unwrap();
        let mut p = table1_config();
        let place = Placement::topology_aware(&p);
        let bw = TierBandwidth::ubmesh(16, 1.0);
        let t1 = throughput_tokens_per_s(&p, &iteration_time(&m, &p, &place, &bw));
        p.dp *= 4;
        let place2 = Placement::topology_aware(&p);
        let t4 = throughput_tokens_per_s(&p, &iteration_time(&m, &p, &place2, &bw));
        assert!(t4 > 3.0 * t1, "dp 4x should give ~4x tokens/s");
    }

    #[test]
    fn ccost_module_linked() {
        // collective closed forms feed the same units
        assert!(crate::collectives::cost::xfer_us(1e6, 1.0) > 0.0);
    }
}
